#!/usr/bin/env bash
# Bench smoke runner: emits BENCH_PR10.json with GVE-Louvain edges/sec
# for every planted GraphFamily at 1 and 4 threads (median of
# GVE_BENCH_REPEATS, default 3; GVE_BENCH_SCALE shifts graph sizes),
# the PR-2 dynamic scenario (per-seeding-strategy throughput over a
# 10-batch / 1%-churn timeline on the web family), the PR-3 service
# scenario (the same stream replayed through the long-lived
# CommunityService: ingest ops/sec + epoch-latency cells per strategy),
# the PR-6 scan_engine scenario (hybrid SmallTable on/off ×
# dynamic/degree-bucketed scheduling on the web family: table ops,
# edges scanned and the small-path fraction), the PR-7 trace scenario
# (tracing off vs on on the web family at the top thread count:
# measured span-capture overhead % + mean per-pass parallelism
# efficiency), the PR-8 metrics scenario (the live registry on vs off
# on the same cell: measured overhead %, contract < 1%), and the PR-9
# server scenario (the dynamic timeline streamed through a live
# loopback LouvainServer as binary Ops frames vs the in-process
# replay: ops/sec per path + the wire's wall-time overhead), and the
# PR-10 late_pass scenario (the adaptive late-pass engine on vs off on
# the web family: per-pass effective widths chosen by the cost model +
# the count of team dispatches inside pass windows from a traced run).
#
# Usage:
#   scripts/bench_smoke.sh                 # writes BENCH_PR10.json
#   scripts/bench_smoke.sh out.json        # custom output path
#   scripts/bench_smoke.sh out.json --baseline BENCH_PR10.json
#   scripts/bench_smoke.sh out.json --baseline b.json --noise-pct 15
#   scripts/bench_smoke.sh out.json --trace slowest.json
#
# `--baseline FILE` (PR 8) turns the run into a regression gate: the
# runner re-reads FILE, matches throughput cells by identity
# (family/strategy/schedule/path × threads) and exits non-zero if any
# current rate sits more than --noise-pct (default 25%) below its
# baseline. Rates, not wall times — bigger is always better, so the
# gate is one-sided. `--trace PATH` additionally dumps a Chrome trace
# of the slowest static cell (open at https://ui.perfetto.dev).
#
# Producing a baseline (same runner, same machine): commits before
# PR 1 carry no Cargo manifests and are not buildable; PR 1's
# yardstick was BENCH_PR1.json, PR 2's BENCH_PR2.json, PRs 3-5's
# BENCH_PR3.json, PR 6's BENCH_PR6.json, PR 7's BENCH_PR7.json,
# PR 8's BENCH_PR8.json and PR 9's BENCH_PR9.json (the static
# "results" array here stays schema-compatible with all of them, so
# any of those files also works as --baseline input for its
# sections). From PR 4 on:
#   uncommitted changes:  git stash && scripts/bench_smoke.sh base.json \
#                           && git stash pop \
#                           && scripts/bench_smoke.sh BENCH_PR10.json --baseline base.json
#   committed baseline:   git worktree add /tmp/bb <rev>
#                         (cd /tmp/bb && scripts/bench_smoke.sh /tmp/base.json)
#                         git worktree remove /tmp/bb
# Beyond the gated rates: in "dynamic"/"service" delta-screening
# should beat full per batch/epoch, in "scan_engine" hybrid=true
# should cut table_ops with small_fraction > 0.5 on the web family,
# "trace"/"metrics" overhead_pct should stay in the low single
# digits / under 1% respectively, in "server" the wire path should
# land within a small factor of direct — the detection work dominates
# the framing at smoke scales — and in "late_pass" the adaptive cells
# should show pass_widths shrinking toward 1 on the late passes with
# team_jobs_in_passes below the fixed-width cells'.
set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${1:-BENCH_PR10.json}"
if [ $# -gt 0 ]; then shift; fi
cargo run --release --manifest-path rust/Cargo.toml --bin bench_smoke -- "$OUT" "$@"
