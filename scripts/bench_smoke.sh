#!/usr/bin/env bash
# Bench smoke runner: emits BENCH_PR7.json with GVE-Louvain edges/sec
# for every planted GraphFamily at 1 and 4 threads (median of
# GVE_BENCH_REPEATS, default 3; GVE_BENCH_SCALE shifts graph sizes),
# the PR-2 dynamic scenario (per-seeding-strategy throughput over a
# 10-batch / 1%-churn timeline on the web family), the PR-3 service
# scenario (the same stream replayed through the long-lived
# CommunityService: ingest ops/sec + epoch-latency cells per strategy),
# the PR-6 scan_engine scenario (hybrid SmallTable on/off ×
# dynamic/degree-bucketed scheduling on the web family: table ops,
# edges scanned and the small-path fraction), and the PR-7 trace
# scenario (tracing off vs on on the web family at the top thread
# count: measured span-capture overhead % + mean per-pass parallelism
# efficiency derived from the per-worker busy spans).
#
# Usage:
#   scripts/bench_smoke.sh                 # writes BENCH_PR7.json
#   scripts/bench_smoke.sh out.json        # custom output path
#
# Comparing against a baseline (same runner, same machine): commits
# before PR 1 carry no Cargo manifests and are not buildable; PR 1's
# yardstick was BENCH_PR1.json, PR 2's BENCH_PR2.json, PRs 3-5's
# BENCH_PR3.json and PR 6's BENCH_PR6.json (the static "results" array
# here stays schema-compatible with all of them, "dynamic" with PR 2's,
# "service" with PR 3's, "scan_engine" with PR 6's). From PR 4 on:
#   uncommitted changes:  git stash && scripts/bench_smoke.sh base.json \
#                           && git stash pop && scripts/bench_smoke.sh
#   committed baseline:   git worktree add /tmp/bb <rev>
#                         (cd /tmp/bb && scripts/bench_smoke.sh /tmp/base.json)
#                         git worktree remove /tmp/bb
#   # then diff edges_per_sec / ops_per_sec; every family should be >=
#   # baseline, in "dynamic" delta-screening should beat full per batch,
#   # in "service" delta-screening should beat full per epoch, in
#   # "scan_engine" hybrid=true should cut table_ops on the web family
#   # with small_fraction > 0.5, and in "trace" overhead_pct should
#   # stay in the low single digits.
set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${1:-BENCH_PR7.json}"
cargo run --release --manifest-path rust/Cargo.toml --bin bench_smoke -- "$OUT"
