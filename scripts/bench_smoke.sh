#!/usr/bin/env bash
# Bench smoke runner: emits BENCH_PR2.json with GVE-Louvain edges/sec
# for every planted GraphFamily at 1 and 4 threads (median of
# GVE_BENCH_REPEATS, default 3; GVE_BENCH_SCALE shifts graph sizes),
# plus the PR-2 dynamic scenario: per-seeding-strategy throughput over
# a 10-batch / 1%-churn timeline on the web family.
#
# Usage:
#   scripts/bench_smoke.sh                 # writes BENCH_PR2.json
#   scripts/bench_smoke.sh out.json        # custom output path
#
# Comparing against a baseline (same runner, same machine): commits
# before PR 1 carry no Cargo manifests and are not buildable; PR 1's
# yardstick was BENCH_PR1.json (static cells only — the "results" array
# here is schema-compatible with it). From PR 3 on:
#   uncommitted changes:  git stash && scripts/bench_smoke.sh base.json \
#                           && git stash pop && scripts/bench_smoke.sh
#   committed baseline:   git worktree add /tmp/bb <rev>
#                         (cd /tmp/bb && scripts/bench_smoke.sh /tmp/base.json)
#                         git worktree remove /tmp/bb
#   # then diff the edges_per_sec fields; every family should be >= baseline,
#   # and in "dynamic" delta-screening should beat full per batch.
set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${1:-BENCH_PR2.json}"
cargo run --release --manifest-path rust/Cargo.toml --bin bench_smoke -- "$OUT"
