//! Integration tests for the live-telemetry subsystem (PR 8
//! acceptance criteria):
//!
//! * a counter scraped *while* a writer hammers it is monotone across
//!   scrapes and lands exactly on the total once the writer joins —
//!   the sharded relaxed cells lose nothing;
//! * histogram buckets sit exactly on the documented log2 boundaries
//!   (`le = 2^i - 1`, inclusive), with zero in its own bucket and the
//!   `+Inf` tail absorbing the rest;
//! * the Prometheus rendering is well-formed: `# HELP`/`# TYPE` once
//!   per family, every sample line `name{labels} value`, histogram
//!   `_bucket` series cumulative with ascending `le` and a final
//!   `+Inf` equal to `_count`;
//! * the HTTP introspection server answers `/healthz`, `/metrics`,
//!   `/metrics.json` and `/epochs` over loopback — including an
//!   `/epochs` body backed by a real `CommunityService` snapshot
//!   handle — and 404s elsewhere;
//! * Louvain results are bit-exact with the registry enabled vs
//!   disabled: instruments observe, never steer.
//!
//! The enabled flag is process-global and the registry is
//! process-wide, so tests that toggle the flag serialize through
//! [`flag_lock`] and every test uses throwaway metric names or a
//! private `Registry` — never deltas on the shared wired sites, which
//! other tests in this binary may bump concurrently.

use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::gve::GveLouvain;
use gve_louvain::louvain::params::LouvainParams;
use gve_louvain::obs::http::{IntrospectionServer, ServeState};
use gve_louvain::obs::{self, bucket_le, render, Histogram, Registry, HIST_BUCKETS};
use gve_louvain::service::{CommunityService, ServiceConfig};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Serializes tests that flip the process-global enabled flag.
fn flag_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

#[test]
fn counter_scraped_under_load_is_monotone_and_exact() {
    const PER_THREAD: u64 = 200_000;
    const WRITERS: usize = 4;
    let reg = Arc::new(Registry::default());
    let c = reg.counter("obs_test_hammer_total", "test", &[]);
    let stop = Arc::new(AtomicBool::new(false));

    let writers: Vec<_> = (0..WRITERS)
        .map(|_| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for _ in 0..PER_THREAD {
                    c.inc();
                }
            })
        })
        .collect();

    // Scrape concurrently: each observed value must be >= the last
    // (every shard is monotone) and <= the eventual total.
    let scraper = {
        let reg = Arc::clone(&reg);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut scrapes = 0usize;
            while !stop.load(Ordering::Relaxed) {
                for m in reg.snapshot().metrics {
                    if let obs::MetricValue::Counter(v) = m.value {
                        assert!(v >= last, "scrape went backwards: {v} < {last}");
                        assert!(v <= PER_THREAD * WRITERS as u64);
                        last = v;
                        scrapes += 1;
                    }
                }
            }
            scrapes
        })
    };

    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().unwrap();
    assert!(scrapes > 0, "the scraper never ran");
    assert_eq!(c.value(), PER_THREAD * WRITERS as u64);
}

#[test]
fn histogram_buckets_sit_on_log2_boundaries() {
    let h = Histogram::default();
    // One value per interesting edge: 0, each power of two, and the
    // value just below it.
    h.record(0);
    h.record(1);
    h.record(2);
    h.record(3);
    h.record(4);
    h.record(1 << 20);
    h.record((1 << 20) - 1);
    h.record(u64::MAX); // tail bucket
    let s = h.snapshot();
    assert_eq!(s.count, 8);

    assert_eq!(s.buckets[0], 1, "zero lives alone in bucket 0");
    assert_eq!(s.buckets[1], 1, "bucket 1 = [1, 2)");
    assert_eq!(s.buckets[2], 2, "bucket 2 = [2, 4) holds 2 and 3");
    assert_eq!(s.buckets[3], 1, "bucket 3 = [4, 8)");
    assert_eq!(s.buckets[20], 1, "2^20 - 1 tops bucket 20");
    assert_eq!(s.buckets[21], 1, "2^20 opens bucket 21");
    assert_eq!(s.buckets[HIST_BUCKETS - 1], 1, "u64::MAX goes to +Inf");

    // The le bound is inclusive: value 2^i - 1 is in the bucket whose
    // bound is exactly 2^i - 1.
    assert_eq!(bucket_le(20), Some((1 << 20) - 1));
    assert_eq!(bucket_le(HIST_BUCKETS - 1), None);
}

#[test]
fn prometheus_text_is_well_formed() {
    let reg = Registry::default();
    reg.counter("obs_test_render_total", "a counter", &[]).add(3);
    reg.counter("obs_test_render_total", "a counter", &[("family", "web")]).add(4);
    reg.gauge("obs_test_render_bytes", "a gauge", &[("component", "ws")]).set(-17);
    let h = reg.histogram("obs_test_render_ns", "a histogram", &[]);
    h.record(0);
    h.record(5);
    h.record(5);

    let text = render::prometheus_text(&reg.snapshot());

    // HELP/TYPE exactly once per family, even with two labelled series.
    assert_eq!(text.matches("# HELP obs_test_render_total").count(), 1);
    assert_eq!(text.matches("# TYPE obs_test_render_total counter").count(), 1);
    assert_eq!(text.matches("# TYPE obs_test_render_bytes gauge").count(), 1);
    assert_eq!(text.matches("# TYPE obs_test_render_ns histogram").count(), 1);

    assert!(text.contains("obs_test_render_total 3"));
    assert!(text.contains("obs_test_render_total{family=\"web\"} 4"));
    assert!(text.contains("obs_test_render_bytes{component=\"ws\"} -17"));

    // Histogram series: cumulative buckets with ascending le, then
    // +Inf == _count, plus _sum.
    assert!(text.contains("obs_test_render_ns_bucket{le=\"0\"} 1"));
    assert!(text.contains("obs_test_render_ns_bucket{le=\"7\"} 3"), "5 lands in [4, 8)");
    assert!(text.contains("obs_test_render_ns_bucket{le=\"+Inf\"} 3"));
    assert!(text.contains("obs_test_render_ns_sum 10"));
    assert!(text.contains("obs_test_render_ns_count 3"));

    // Every non-comment line is `name[{labels}] value`.
    let mut last_le: Option<f64> = None;
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(value.parse::<f64>().is_ok(), "unparsable value in {line:?}");
        let name = series.split('{').next().unwrap();
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name in {line:?}"
        );
        // Ascending le within the one histogram family.
        if let Some(le) = series
            .strip_prefix("obs_test_render_ns_bucket{le=\"")
            .and_then(|r| r.strip_suffix("\"}"))
        {
            let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
            if let Some(prev) = last_le {
                assert!(le > prev, "le not ascending at {line:?}");
            }
            last_le = Some(le);
        }
    }
    assert_eq!(last_le, Some(f64::INFINITY), "bucket series ends at +Inf");
}

/// One blocking HTTP GET against the introspection server.
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect to introspection server");
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
        .unwrap();
    stream.flush().unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read response");
    let (head, body) = response.split_once("\r\n\r\n").expect("header terminator");
    (head.to_string(), body.to_string())
}

#[test]
fn http_endpoints_answer_over_loopback() {
    // Register something scrapable before snapshotting.
    obs::registry().counter("obs_test_http_total", "test", &[]).add(11);

    // A real (tiny) service backs /epochs.
    let g = generate(GraphFamily::Web, 7, 42);
    let svc = CommunityService::new(g, ServiceConfig::default());
    let state = ServeState {
        snapshots: Some(svc.handle()),
        summary: Arc::new(Mutex::new(svc.metrics().summary())),
        ..Default::default()
    };
    let server = IntrospectionServer::start(0, state).expect("bind ephemeral loopback port");
    let addr = server.local_addr();

    let (head, body) = http_get(addr, "/healthz");
    assert!(head.starts_with("HTTP/1.1 200"), "healthz head: {head}");
    assert_eq!(body, "ok\n");

    let (head, body) = http_get(addr, "/metrics");
    assert!(head.starts_with("HTTP/1.1 200"));
    assert!(head.contains("text/plain"));
    assert!(body.contains("obs_test_http_total 11"));
    assert!(body.contains("# TYPE obs_test_http_total counter"));

    let (head, body) = http_get(addr, "/metrics.json");
    assert!(head.starts_with("HTTP/1.1 200"));
    assert!(head.contains("application/json"));
    assert!(body.contains("\"obs_test_http_total\""));
    assert_eq!(body.matches('{').count(), body.matches('}').count());

    let (head, body) = http_get(addr, "/epochs");
    assert!(head.starts_with("HTTP/1.1 200"));
    assert!(body.contains("\"epoch\":0"), "boot snapshot is epoch 0: {body}");
    assert!(body.contains("\"vertices\":"));
    assert!(body.contains("\"epoch_percentiles\""));
    assert_eq!(body.matches('{').count(), body.matches('}').count());

    let (head, _) = http_get(addr, "/nope");
    assert!(head.starts_with("HTTP/1.1 404"), "unknown path head: {head}");

    drop(server); // stop + join; the port must come free without hanging
}

#[test]
fn louvain_result_is_bit_exact_with_registry_disabled() {
    let _guard = flag_lock();
    let g = generate(GraphFamily::Web, 9, 42);
    let params = LouvainParams::with_threads(2);

    obs::set_enabled(true);
    let on = GveLouvain::new(params.clone()).run(&g);
    obs::set_enabled(false);
    let off = GveLouvain::new(params).run(&g);
    obs::set_enabled(true);

    assert_eq!(on.membership, off.membership, "membership must not depend on metrics");
    assert_eq!(
        on.modularity.to_bits(),
        off.modularity.to_bits(),
        "modularity must be bit-identical"
    );
    assert_eq!(on.passes, off.passes);
    assert_eq!(on.num_communities, off.num_communities);
}
