//! Cross-module integration tests: generators → IO → Louvain →
//! aggregation → reports, plus the config-driven runner.

use gve_louvain::baselines::System;
use gve_louvain::coordinator::config::Config;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::{compare_on_entry, mean_speedup};
use gve_louvain::coordinator::suite;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::io;
use gve_louvain::louvain::modularity::modularity;
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};

#[test]
fn full_pipeline_generate_persist_reload_cluster() {
    let dir = std::env::temp_dir().join("gve_integration");
    std::fs::create_dir_all(&dir).unwrap();
    for f in GraphFamily::ALL {
        let g = generate(f, 9, 7);
        let path = dir.join(format!("{}.bin", f.name()));
        io::write_binary(&g, &path).unwrap();
        let g2 = io::read_binary(&path).unwrap();
        assert_eq!(g, g2);
        let out = GveLouvain::new(LouvainParams::default()).run(&g2);
        // Membership must be a valid dense clustering of the input.
        assert_eq!(out.membership.len(), g.num_vertices());
        let q = modularity(&g, &out.membership);
        assert!((q - out.modularity).abs() < 1e-12);
        assert!(q > 0.3, "{f:?}: q={q}");
    }
}

#[test]
fn suite_runs_all_entries_at_small_scale() {
    for entry in &suite::SUITE {
        let g = entry.graph(-4, 11);
        g.validate().unwrap();
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert!(out.modularity > 0.2, "{}: q={}", entry.name, out.modularity);
        assert!(out.passes >= 1);
    }
}

#[test]
fn mtx_round_trip_preserves_clustering() {
    let g = generate(GraphFamily::Web, 9, 13);
    let dir = std::env::temp_dir().join("gve_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("web.mtx");
    io::write_matrix_market(&g, &path).unwrap();
    let g2 = io::read_matrix_market(&path).unwrap();
    let q1 = GveLouvain::new(LouvainParams::default()).run(&g).modularity;
    let q2 = GveLouvain::new(LouvainParams::default()).run(&g2).modularity;
    assert!((q1 - q2).abs() < 0.03, "q1={q1} q2={q2}");
}

#[test]
fn runner_comparison_and_speedups() {
    let entry = suite::find("com-Orkut").unwrap();
    let systems = [System::GveLouvain, System::Grappolo];
    let cells = compare_on_entry(entry, -3, &systems, 1, 2, 42);
    assert_eq!(cells.len(), 2);
    assert!(mean_speedup(&cells, System::GveLouvain, System::Grappolo).is_some());
    // Render as a report table (arity checks).
    let mut t = Table::new("integration", &["graph", "system", "q"]);
    for c in &cells {
        t.row(vec![c.graph.into(), c.system.name().into(), format!("{:.3}", c.modularity)]);
    }
    assert!(t.render().contains("com-Orkut"));
}

#[test]
fn config_file_drives_runner() {
    let cfg = Config::parse(
        r#"
name = "it"
[run]
systems = ["gve-louvain"]
graphs = "asia_osm"
offset = -4
"#,
    )
    .unwrap();
    assert_eq!(cfg.get_str("run", "graphs", ""), "asia_osm");
    let entry = suite::find(&cfg.get_str("run", "graphs", "")).unwrap();
    let cells = compare_on_entry(
        entry,
        cfg.get_int("run", "offset", 0) as i32,
        &[System::GveLouvain],
        1,
        1,
        42,
    );
    assert_eq!(cells.len(), 1);
    assert!(cells[0].modularity > 0.5);
}

#[test]
fn repeated_runs_are_deterministic_end_to_end() {
    let entry = suite::find("uk-2002").unwrap();
    let g1 = entry.graph(-4, 42);
    let g2 = entry.graph(-4, 42);
    assert_eq!(g1, g2);
    let a = GveLouvain::new(LouvainParams::default()).run(&g1);
    let b = GveLouvain::new(LouvainParams::default()).run(&g2);
    assert_eq!(a.membership, b.membership);
}

#[test]
fn family_phase_split_shapes_match_fig14() {
    // Web graphs: local-moving dominates; the first pass carries the
    // bulk of the time (paper: 67% on average, driven by the high-degree
    // families).
    let g = generate(GraphFamily::Web, 12, 3);
    let out = GveLouvain::new(LouvainParams::default()).run(&g);
    let (mv, ag, _) = out.phase_split();
    assert!(mv > ag, "web: local-moving should dominate ({mv:.2} vs {ag:.2})");
    assert!(out.first_pass_fraction() > 0.5, "web: first pass should dominate");
}

#[test]
fn dendrogram_membership_is_consistent_with_pass_counts() {
    let g = generate(GraphFamily::Road, 11, 5);
    let out = GveLouvain::new(LouvainParams::default()).run(&g);
    // Every community id in range, community count consistent.
    let max = *out.membership.iter().max().unwrap() as usize;
    assert_eq!(max + 1, out.num_communities);
    // Communities shrink monotonically across passes.
    let mut prev = usize::MAX;
    for p in &out.pass_stats {
        assert!(p.communities <= prev);
        prev = p.communities;
    }
}
