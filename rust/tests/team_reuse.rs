//! Integration tests for the persistent worker-team runtime and the
//! zero-allocation pass workspace (PR 1 acceptance criteria):
//!
//! * index coverage across all `Schedule` kinds under team reuse;
//! * membership / modularity / super-graph equality between the team
//!   path and the scoped spawn-per-loop reference path;
//! * OS-thread spawns per `GveLouvain::run` are O(1) in
//!   passes/iterations, and the workspace (team + `TablePool`) is
//!   reused across passes and repeated runs.

use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::aggregation::{aggregate_csr, aggregate_csr_with, AggScratch};
use gve_louvain::louvain::hashtable::TablePool;
use gve_louvain::louvain::local_moving::local_moving;
use gve_louvain::louvain::modularity::modularity;
use gve_louvain::louvain::params::{LouvainParams, TableKind};
use gve_louvain::louvain::gve::GveLouvain;
use gve_louvain::parallel::pool::ParallelOpts;
use gve_louvain::parallel::schedule::Schedule;
use gve_louvain::parallel::team::{Exec, Team};
use std::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn one_team_covers_every_schedule_kind_many_times() {
    let team = Team::new(4);
    for round in 0..4 {
        for schedule in Schedule::ALL {
            let n = 12_345;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let opts = ParallelOpts { threads: 4, schedule, chunk: 97, record: round % 2 == 0 };
            let stats = team.run(n, opts, |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "{schedule:?} round={round}: missed or doubled an index"
            );
            if opts.record {
                let covered: usize = stats.chunks.iter().map(|c| c.len).sum();
                assert_eq!(covered, n, "{schedule:?}: chunk records must cover the range");
            }
        }
    }
    assert_eq!(team.spawned_workers(), 3, "reuse must not spawn more workers");
}

#[test]
fn local_moving_team_equals_scoped_reference() {
    // Single-threaded runs are deterministic on both executors, so the
    // migration must be observationally identical.
    let team = Team::new(1);
    for family in GraphFamily::ALL {
        let g = generate(family, 9, 77);
        let n = g.num_vertices();
        let m = g.total_weight();
        let params = LouvainParams::default();
        let k = g.vertex_weights();

        let run = |exec: Exec| {
            let mut memb: Vec<u32> = (0..n as u32).collect();
            let mut sigma = k.clone();
            let mut aff = vec![1u32; n];
            let pool = TablePool::new(TableKind::FarKv, n, 1);
            let out =
                local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, exec);
            (memb, sigma, out.dq_total, out.iterations)
        };
        let scoped = run(Exec::scoped());
        let teamed = run(Exec::team(&team));
        assert_eq!(scoped.0, teamed.0, "{family:?}: membership diverged");
        assert_eq!(scoped.1, teamed.1, "{family:?}: sigma diverged");
        assert_eq!(scoped.2, teamed.2, "{family:?}: dq diverged");
        assert_eq!(scoped.3, teamed.3, "{family:?}: iteration count diverged");
        let q = modularity(&g, &teamed.0);
        assert!(q > 0.0, "{family:?}: q={q}");
    }
}

#[test]
fn aggregation_team_equals_scoped_reference_multithreaded() {
    // Aggregation is deterministic even at 4 threads (rows are sorted),
    // so team + reused scratch must reproduce the scoped graphs exactly.
    let team = Team::new(4);
    let mut scratch = AggScratch::new();
    let g = generate(GraphFamily::Web, 10, 99);
    let n = g.num_vertices();
    let params = LouvainParams { threads: 4, ..Default::default() };
    for ncomm in [173usize, 61, 9] {
        let memb: Vec<u32> = (0..n).map(|v| (v % ncomm) as u32).collect();
        let pool = TablePool::new(TableKind::FarKv, ncomm, 4);
        let scoped = aggregate_csr(&g, &memb, ncomm, &pool, &params);
        let teamed =
            aggregate_csr_with(&g, &memb, ncomm, &pool, &params, Exec::team(&team), &mut scratch);
        assert_eq!(scoped.graph, teamed.graph, "ncomm={ncomm}");
    }
}

#[test]
fn gve_run_spawns_o1_threads_and_reuses_them() {
    let g = generate(GraphFamily::Social, 11, 7);
    let algo = GveLouvain::new(LouvainParams::with_threads(4));
    let out = algo.run(&g);
    let loops_lower_bound = out.passes
        + out.pass_stats.iter().map(|p| p.iterations).sum::<usize>();
    assert!(loops_lower_bound >= 2, "degenerate run, nothing to prove");
    // The scoped runtime would have spawned 3 threads per parallel
    // loop; the team spawns 3 total, period.
    assert_eq!(algo.spawned_workers(), 3);
    for _ in 0..3 {
        let _ = algo.run(&g);
    }
    assert_eq!(algo.spawned_workers(), 3, "repeated runs must reuse the team");
}

#[test]
fn gve_quality_unchanged_across_thread_counts() {
    // End-to-end sanity on the migrated pass loop: 1- vs 4-thread runs
    // (team runtime) agree in quality, and repeated single-threaded
    // runs are bit-identical (workspace reuse leaks no state).
    let g = generate(GraphFamily::Web, 11, 3);
    let a1 = GveLouvain::new(LouvainParams::with_threads(1));
    let r1 = a1.run(&g);
    let r1b = a1.run(&g);
    assert_eq!(r1.membership, r1b.membership);
    assert_eq!(r1.modularity, r1b.modularity);

    let r4 = GveLouvain::new(LouvainParams::with_threads(4)).run(&g);
    assert!((r1.modularity - r4.modularity).abs() < 0.02, "q1={} q4={}", r1.modularity, r4.modularity);
    assert!(r1.modularity > 0.8);
}
