//! Property-based tests on the crate's core invariants, via the
//! in-tree `prop` framework (proptest is unavailable offline).

use gve_louvain::graph::builder::GraphBuilder;
use gve_louvain::graph::generators::{planted_partition, PlantedPartition};
use gve_louvain::louvain::aggregation::aggregate_csr;
use gve_louvain::louvain::dendrogram;
use gve_louvain::louvain::hashtable::TablePool;
use gve_louvain::louvain::modularity::{community_weights, delta_modularity, modularity};
use gve_louvain::louvain::params::{LouvainParams, TableKind};
use gve_louvain::louvain::renumber::{count_communities, renumber_communities};
use gve_louvain::parallel::scan::{exclusive_scan, exclusive_scan_serial};
use gve_louvain::prop::{forall, Gen};

/// Random small undirected graph.
fn random_graph(g: &mut Gen) -> gve_louvain::graph::Csr {
    let n = g.usize(2, 120);
    let edges = g.usize(1, 400);
    let mut b = GraphBuilder::new(n);
    for _ in 0..edges {
        let u = g.usize(0, n - 1) as u32;
        let v = g.usize(0, n - 1) as u32;
        b.push(u, v, g.f64(0.25, 4.0) as f32);
    }
    b.build_undirected()
}

#[test]
fn prop_renumber_is_idempotent_and_dense() {
    forall("renumber-idempotent", 200, |g| {
        let n = g.usize(1, 200);
        let mut m = g.membership(n, 50);
        let n1 = renumber_communities(&mut m);
        assert_eq!(n1, count_communities(&m));
        let snapshot = m.clone();
        let n2 = renumber_communities(&mut m);
        assert_eq!(n1, n2);
        assert_eq!(m, snapshot, "renumbering dense ids must be identity");
        if !m.is_empty() {
            assert_eq!(*m.iter().max().unwrap() as usize + 1, n1);
        }
    });
}

#[test]
fn prop_modularity_in_valid_range() {
    forall("modularity-range", 100, |g| {
        let graph = random_graph(g);
        let memb = {
            let mut m = g.membership(graph.num_vertices(), 20);
            renumber_communities(&mut m);
            m
        };
        let q = modularity(&graph, &memb);
        assert!((-0.5 - 1e-9..=1.0 + 1e-9).contains(&q), "q={q}");
    });
}

#[test]
fn prop_delta_modularity_matches_recompute() {
    forall("dq-recompute", 100, |g| {
        let graph = random_graph(g);
        let n = graph.num_vertices();
        let mut memb = g.membership(n, 8);
        renumber_communities(&mut memb);
        let m = graph.total_weight();
        if m == 0.0 {
            return;
        }
        let (_, big) = community_weights(&graph, &memb);
        let i = g.usize(0, n - 1);
        let d = memb[i] as usize;
        // Candidate community from a random neighbour (or skip).
        let (ts, _) = graph.edges(i);
        if ts.is_empty() {
            return;
        }
        let c = memb[ts[g.usize(0, ts.len() - 1)] as usize] as usize;
        if c == d {
            return;
        }
        let mut k_to = vec![0f64; big.len()];
        for (t, w) in graph.neighbours(i) {
            if t as usize != i {
                k_to[memb[t as usize] as usize] += w as f64;
            }
        }
        let k_i = graph.vertex_weight(i);
        let dq = delta_modularity(k_to[c], k_to[d], k_i, big[c], big[d], m);
        let q0 = modularity(&graph, &memb);
        memb[i] = c as u32;
        let q1 = modularity(&graph, &memb);
        assert!(
            (q1 - q0 - dq).abs() < 1e-7,
            "Eq.2 violated: q0={q0} q1={q1} dq={dq} (seed {:#x})",
            g.case_seed
        );
    });
}

#[test]
fn prop_aggregation_preserves_total_weight_and_symmetry() {
    forall("aggregation-weight", 60, |g| {
        let graph = random_graph(g);
        let n = graph.num_vertices();
        let mut memb = g.membership(n, 12);
        let nc = renumber_communities(&mut memb);
        let pool = TablePool::new(TableKind::FarKv, nc.max(1), 1);
        let out = aggregate_csr(&graph, &memb, nc, &pool, &LouvainParams::default());
        let (gw, sw) = (graph.total_weight(), out.graph.total_weight());
        assert!((gw - sw).abs() <= 1e-5 * (1.0 + gw), "m not preserved: {gw} vs {sw}");
        assert!(out.graph.is_symmetric());
        assert_eq!(out.graph.num_vertices(), nc);
    });
}

#[test]
fn prop_aggregated_modularity_is_preserved_under_identity() {
    // Q of the partition on G equals Q of singletons on the aggregated
    // graph (the fundamental Louvain invariant that makes passes
    // composable).
    forall("aggregate-q-invariant", 60, |g| {
        let graph = random_graph(g);
        let n = graph.num_vertices();
        let mut memb = g.membership(n, 10);
        let nc = renumber_communities(&mut memb);
        if graph.total_weight() == 0.0 {
            return;
        }
        let pool = TablePool::new(TableKind::FarKv, nc.max(1), 1);
        let sg = aggregate_csr(&graph, &memb, nc, &pool, &LouvainParams::default()).graph;
        let q_orig = modularity(&graph, &memb);
        let singleton: Vec<u32> = (0..nc as u32).collect();
        let q_super = modularity(&sg, &singleton);
        assert!(
            (q_orig - q_super).abs() < 1e-6,
            "invariant violated: {q_orig} vs {q_super} (seed {:#x})",
            g.case_seed
        );
    });
}

#[test]
fn prop_dendrogram_flatten_equals_stepwise() {
    forall("dendrogram-flatten", 150, |g| {
        let n = g.usize(1, 100);
        let mut levels = Vec::new();
        let mut size = n;
        for _ in 0..g.usize(1, 4) {
            let next = g.usize(1, size);
            levels.push(g.vec(size, |g| g.usize(0, next - 1) as u32));
            size = next;
        }
        let flat = dendrogram::flatten(&levels);
        let mut manual = levels[0].clone();
        for l in &levels[1..] {
            dendrogram::lookup(&mut manual, l);
        }
        assert_eq!(flat, manual);
    });
}

#[test]
fn prop_parallel_scan_matches_serial() {
    forall("scan-parallel", 60, |g| {
        let n = g.usize(0, 40_000);
        let base = g.vec(n, |g| g.usize(0, 9));
        let mut a = base.clone();
        let mut b = base;
        let ta = exclusive_scan_serial(&mut a);
        let tb = exclusive_scan(&mut b, g.usize(1, 8));
        assert_eq!(ta, tb);
        assert_eq!(a, b);
    });
}

#[test]
fn prop_gve_louvain_never_lowers_modularity_vs_singletons() {
    forall("gve-vs-singletons", 25, |g| {
        let p = PlantedPartition {
            n: g.usize(32, 512),
            n_communities: g.usize(2, 16),
            avg_degree: g.f64(2.0, 16.0),
            mixing: g.f64(0.0, 0.6),
            degree_exponent: g.f64(2.0, 3.0),
            max_degree: 64,
            community_size_exponent: 1.1,
            seed: g.u64(0, u64::MAX / 2),
        };
        let graph = planted_partition(&p);
        if graph.total_weight() == 0.0 {
            return;
        }
        let out = gve_louvain::louvain::gve::GveLouvain::new(LouvainParams::default()).run(&graph);
        let singletons: Vec<u32> = (0..graph.num_vertices() as u32).collect();
        let q0 = modularity(&graph, &singletons);
        assert!(
            out.modularity >= q0 - 1e-9,
            "worse than singletons: {} < {q0} (seed {:#x})",
            out.modularity,
            g.case_seed
        );
    });
}
