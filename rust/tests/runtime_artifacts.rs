//! Runtime tests over the real AOT artifacts (Pallas → HLO → PJRT).
//!
//! These need `make artifacts`; if no artifacts directory exists the
//! tests are skipped with a notice (CI runs `make test`, which builds
//! them first).

use gve_louvain::gpusim::nulouvain::NuParams;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};
use gve_louvain::runtime::artifacts::{find_artifacts_dir, Manifest};
use gve_louvain::runtime::executor::MoveExecutor;
use gve_louvain::runtime::pjrt_louvain::PjrtLouvain;
use gve_louvain::runtime::tile::TileBuilder;

fn executor() -> Option<MoveExecutor> {
    if find_artifacts_dir().is_none() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(MoveExecutor::discover().expect("compile artifacts"))
}

#[test]
fn manifest_discovers_tile_classes() {
    let Some(dir) = find_artifacts_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let m = Manifest::load(&dir).unwrap();
    let classes = m.tile_classes();
    assert!(classes.len() >= 3, "expected >=3 tile classes, got {classes:?}");
    assert!(m.modularity().is_some());
}

#[test]
fn executor_compiles_and_reports_classes() {
    let Some(exec) = executor() else { return };
    assert_eq!(exec.platform(), "cpu");
    let classes = exec.classes();
    assert!(classes.iter().any(|&(_, md)| md == 32));
    assert!(classes.iter().any(|&(_, md)| md >= 512));
}

#[test]
fn pjrt_move_step_matches_rust_scan_reference() {
    // Cross-language oracle: the PJRT kernel's (community, dq) choices
    // must match an independent Rust implementation of Eq. 2 over the
    // same tile contract.
    let Some(exec) = executor() else { return };
    let g = generate(GraphFamily::Web, 9, 31);
    let n = g.num_vertices();
    let memb: Vec<u32> = (0..n as u32).map(|v| v % 13).collect();
    let k = g.vertex_weights();
    let mut sigma = vec![0f64; n];
    for v in 0..n {
        sigma[memb[v] as usize] += k[v];
    }
    let m = g.total_weight();
    let builder = TileBuilder::new(exec.classes());
    let vertices: Vec<usize> = (0..n).collect();
    let (tiles, _) = builder.pack(&g, &vertices, &memb, &k, &sigma);

    for tile in tiles.iter().take(4) {
        let moves = exec.move_step(tile, m, false).expect("move step");
        for (row, &(v, c, dq, accepted)) in moves.rows.iter().enumerate() {
            // Rust reference scan over the same padded slots.
            let md = tile.md;
            let mut acc: std::collections::BTreeMap<i32, f64> = Default::default();
            for slot in 0..md {
                let cc = tile.nbr_comm[row * md + slot];
                if cc < 0 {
                    continue;
                }
                *acc.entry(cc).or_default() += tile.nbr_wt[row * md + slot] as f64;
            }
            let d = tile.self_comm[row];
            let k_to_d = acc.get(&d).copied().unwrap_or(0.0);
            let k_i = tile.ktot[row] as f64;
            let mut best = (d, f64::MIN);
            for slot in 0..md {
                let cc = tile.nbr_comm[row * md + slot];
                if cc < 0 || cc == d {
                    continue;
                }
                let s_c = tile.sigma_nbr[row * md + slot] as f64;
                let s_d = tile.sigma_self[row] as f64;
                let dq = (acc[&cc] - k_to_d) / m - k_i * (k_i + s_c - s_d) / (2.0 * m * m);
                if dq > best.1 {
                    best = (cc, dq);
                }
            }
            if accepted {
                assert_eq!(c as i32, best.0, "vertex {v}: community mismatch");
                assert!(
                    (dq as f64 - best.1).abs() < 1e-4 * (1.0 + best.1.abs()),
                    "vertex {v}: dq {dq} vs ref {}",
                    best.1
                );
                assert!(best.1 > 0.0);
            } else {
                // No improving admissible candidate.
                assert!(best.1 <= 1e-6, "vertex {v}: kernel rejected dq={}", best.1);
            }
        }
    }
}

#[test]
fn pjrt_pick_less_respected_on_device() {
    let Some(exec) = executor() else { return };
    let g = generate(GraphFamily::Road, 9, 33);
    let n = g.num_vertices();
    let memb: Vec<u32> = (0..n as u32).collect();
    let k = g.vertex_weights();
    let sigma = k.clone();
    let m = g.total_weight();
    let builder = TileBuilder::new(exec.classes());
    let vertices: Vec<usize> = (0..n).collect();
    let (tiles, _) = builder.pack(&g, &vertices, &memb, &k, &sigma);
    for tile in tiles.iter().take(3) {
        let moves = exec.move_step(tile, m, true).unwrap();
        for (v, c, _, accepted) in moves.rows {
            if accepted {
                assert!(c < memb[v], "pick-less violated: {v} -> {c}");
            }
        }
    }
}

#[test]
fn pjrt_louvain_full_run_agrees_with_gve() {
    let Some(exec) = executor() else { return };
    let g = generate(GraphFamily::Web, 10, 35);
    let pjrt = PjrtLouvain::new(&exec, NuParams::default()).run(&g).unwrap();
    let gve = GveLouvain::new(LouvainParams::default()).run(&g);
    assert!(
        pjrt.modularity > gve.modularity - 0.08,
        "pjrt={} gve={}",
        pjrt.modularity,
        gve.modularity
    );
    assert_eq!(pjrt.truncated_slots, 0, "no vertex should exceed MD=512 here");
    // Device modularity agrees with host (f32 reduction tolerance).
    let dev = pjrt.modularity_device.expect("device Q");
    assert!((dev - pjrt.modularity).abs() < 1e-3, "host {} vs device {dev}", pjrt.modularity);
}

#[test]
fn device_modularity_chunking_is_exact() {
    let Some(exec) = executor() else { return };
    // > one chunk of communities: exercise the chunked reduction.
    let n = 10_000usize;
    let sigma: Vec<f64> = (0..n).map(|i| (i % 17) as f64).collect();
    let big: Vec<f64> = (0..n).map(|i| (i % 23) as f64 + sigma[i]).collect();
    let m = 12_345.0;
    let dev = exec.modularity(&sigma, &big, m).unwrap();
    let host: f64 = sigma
        .iter()
        .zip(&big)
        .map(|(s, b)| s / (2.0 * m) - (b / (2.0 * m)).powi(2))
        .sum();
    assert!((dev - host).abs() < 1e-4, "dev={dev} host={host}");
}
