//! GPU simulator vs CPU implementation cross-checks: the two paths
//! implement the same mathematics through different execution models,
//! so quality and aggregation structure must agree.

use gve_louvain::gpusim::hashtable::{PerVertexTables, ProbeStrategy, TableRegion, ValueKind};
use gve_louvain::gpusim::kernels::aggregate as gpu_aggregate;
use gve_louvain::gpusim::{NuLouvain, NuParams};
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::aggregation::aggregate_csr;
use gve_louvain::louvain::hashtable::TablePool;
use gve_louvain::louvain::params::{LouvainParams, TableKind};
use gve_louvain::louvain::renumber::renumber_communities;
use gve_louvain::louvain::{gve::GveLouvain};
use gve_louvain::prop::{forall, Gen};

#[test]
fn aggregation_identical_across_execution_models() {
    forall("gpu-vs-cpu-aggregate", 30, |g: &mut Gen| {
        let fam = *g.pick(&GraphFamily::ALL);
        let graph = generate(fam, 8, g.u64(0, 1 << 40));
        let n = graph.num_vertices();
        let mut memb = g.membership(n, 24);
        let nc = renumber_communities(&mut memb);
        // CPU path.
        let pool = TablePool::new(TableKind::FarKv, nc.max(1), 1);
        let cpu = aggregate_csr(&graph, &memb, nc, &pool, &LouvainParams::default()).graph;
        // GPU path (f64 values to match CPU numerics).
        let mut tables = PerVertexTables::new(
            graph.num_edges().max(1),
            ValueKind::F64,
            ProbeStrategy::QuadraticDouble,
        );
        let gpu = gpu_aggregate(&graph, &memb, nc, &mut tables, &NuParams::default()).graph;
        assert_eq!(cpu.offsets, gpu.offsets, "{fam:?}");
        assert_eq!(cpu.targets, gpu.targets, "{fam:?}");
        for (a, b) in cpu.weights.iter().zip(&gpu.weights) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{fam:?}: {a} vs {b}");
        }
    });
}

#[test]
fn nu_and_gve_quality_within_one_percentish() {
    // Paper Fig 13c: ν-Louvain averages 0.5% lower modularity.
    let mut diffs = Vec::new();
    for f in GraphFamily::ALL {
        let g = generate(f, 10, 21);
        let gve = GveLouvain::new(LouvainParams::default()).run(&g);
        let nu = NuLouvain::new(NuParams::default()).run(&g);
        let rel = (gve.modularity - nu.modularity) / gve.modularity.max(1e-9);
        diffs.push(rel);
        assert!(rel < 0.12, "{f:?}: gve={} nu={}", gve.modularity, nu.modularity);
    }
    let mean = diffs.iter().sum::<f64>() / diffs.len() as f64;
    assert!(mean.abs() < 0.06, "mean relative gap {mean}");
}

#[test]
fn probe_strategy_does_not_change_results_only_probes() {
    let g = generate(GraphFamily::Social, 9, 23);
    let mut base: Option<Vec<u32>> = None;
    for s in ProbeStrategy::ALL {
        let out = NuLouvain::new(NuParams { probe: s, ..Default::default() }).run(&g);
        match &base {
            None => base = Some(out.membership),
            Some(b) => assert_eq!(
                &out.membership, b,
                "{s:?}: probe strategy changed communities"
            ),
        }
    }
}

#[test]
fn probe_costs_rank_as_fig7_expects() {
    // Collision-heavy synthetic access pattern: linear probing must pay
    // the most probes, the hybrid the least-or-equal.
    let mut totals = std::collections::BTreeMap::new();
    for s in ProbeStrategy::ALL {
        let mut t = PerVertexTables::new(4096, ValueKind::F32, s);
        let r = TableRegion::for_vertex(0, 1024); // p1 = 2047
        let mut total = 0u64;
        // Keys engineered to collide heavily at slots near 0.
        for k in 0..700u32 {
            total += t.accumulate(r, k * 2047 + (k % 5), 1.0).probes as u64;
        }
        totals.insert(s.name(), total);
    }
    assert!(
        totals["linear"] >= totals["quadratic-double"],
        "linear {} < hybrid {}",
        totals["linear"],
        totals["quadratic-double"]
    );
}

#[test]
fn f32_tables_cheaper_quality_equal() {
    let g = generate(GraphFamily::Web, 10, 27);
    let f32_run = NuLouvain::new(NuParams { values: ValueKind::F32, ..Default::default() }).run(&g);
    let f64_run = NuLouvain::new(NuParams { values: ValueKind::F64, ..Default::default() }).run(&g);
    assert!((f32_run.modularity - f64_run.modularity).abs() < 0.02);
}

#[test]
fn occupancy_collapse_grows_with_pass_depth_on_sparse_families() {
    // Road/k-mer graphs run many passes; occupancy in the last pass must
    // be a small fraction of the first (the paper's §5.2.3 explanation).
    for f in [GraphFamily::Road, GraphFamily::Kmer] {
        let g = generate(f, 12, 29);
        let out = NuLouvain::new(NuParams::default()).run(&g);
        if out.passes < 2 {
            continue;
        }
        let first = out.pass_stats.first().unwrap().occupancy;
        let last = out.pass_stats.last().unwrap().occupancy;
        assert!(
            last < first * 0.9 + 1e-12,
            "{f:?}: occupancy did not collapse ({first} -> {last})"
        );
    }
}

#[test]
fn gpu_memory_model_scales_with_graph() {
    use gve_louvain::gpusim::DeviceModel;
    let d = DeviceModel::default();
    let small = d.nu_louvain_bytes(1 << 10, 1 << 14);
    let large = d.nu_louvain_bytes(1 << 20, 1 << 24);
    assert!(large > small * 500);
    assert!(d.nu_louvain_fits(1 << 20, 1 << 24));
}
