//! Integration tests for the adaptive late-pass engine (PR 10
//! acceptance criteria):
//!
//! * adaptive width selection never changes results: adaptive-on runs
//!   are bit-exact (membership and modularity `to_bits`) versus
//!   fixed-width runs across every `GraphFamily` — at one thread, and
//!   at four threads when every pass resolves to the serial fast path
//!   (the one multi-thread configuration where both runs execute every
//!   pass at the same width; asynchronous local-moving at width > 1 is
//!   nondeterministic by design, so cross-width comparisons are a
//!   quality bound, not a bit bound — see `louvain/README.md`);
//! * the `serial_pass_threshold` boundary is deterministic: a pass at
//!   exactly the threshold runs serially, one directed edge above it
//!   runs at full width;
//! * degree-bucketed dealing of the aggregation offsets/scatter/compact
//!   loops (through the pass's vertex `ScanOrder`) is bit-identical to
//!   flat dynamic dealing, at one thread and several;
//! * a traced adaptive run whose passes all take the serial fast path
//!   dispatches **zero** team jobs inside pass windows, while a
//!   fixed-width control dispatches plenty.
//!
//! The tracing enabled flag is process-global and `cargo test` runs
//! tests on multiple threads, so every test here serializes through
//! [`session_lock`] — including the untraced ones, which would
//! otherwise record team jobs into a concurrently-active session.

use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::Csr;
use gve_louvain::louvain::aggregation::{aggregate_csr, aggregate_csr_into, AggScratch};
use gve_louvain::louvain::gve::GveLouvain;
use gve_louvain::louvain::hashtable::TablePool;
use gve_louvain::louvain::params::{LouvainParams, TableKind};
use gve_louvain::parallel::schedule::{ScanOrder, Schedule};
use gve_louvain::parallel::team::{Exec, Team};
use gve_louvain::trace::TraceSession;
use std::sync::{Mutex, MutexGuard};

fn session_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

#[test]
fn fixed_width_runs_record_configured_width_per_pass() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Web, 10, 3);
    let out = GveLouvain::new(LouvainParams::with_threads(2)).run(&g);
    assert!(!out.pass_stats.is_empty());
    for (i, ps) in out.pass_stats.iter().enumerate() {
        assert_eq!(ps.effective_threads, 2, "pass {i}: fixed-width run must record threads");
    }
}

#[test]
fn adaptive_matches_fixed_bit_exactly_single_thread() {
    let _lock = session_lock();
    // At one thread the adaptive engine routes every pass through the
    // serial fast path (inline scoped executor) while the fixed run
    // dispatches the width-1 team — the two dealings are pinned
    // bit-identical, so full runs must agree bit-for-bit everywhere.
    for f in GraphFamily::ALL {
        let g = generate(f, 9, 7);
        let fixed = GveLouvain::new(LouvainParams { threads: 1, ..Default::default() }).run(&g);
        let adaptive = GveLouvain::new(LouvainParams {
            threads: 1,
            adaptive_width: true,
            ..Default::default()
        })
        .run(&g);
        assert_eq!(fixed.membership, adaptive.membership, "{f:?}");
        assert_eq!(fixed.modularity.to_bits(), adaptive.modularity.to_bits(), "{f:?}");
        assert_eq!(fixed.passes, adaptive.passes, "{f:?}");
        for ps in &adaptive.pass_stats {
            assert_eq!(ps.effective_threads, 1, "{f:?}");
        }
    }
}

#[test]
fn all_serial_adaptive_at_four_threads_matches_fixed_single_thread() {
    let _lock = session_lock();
    // serial_pass_threshold = MAX forces the serial fast path on every
    // pass of a 4-thread run: each pass then executes at width 1, the
    // one multi-thread configuration that must be bit-exact against a
    // plain single-thread run (the final renumber runs at full width in
    // one and width 1 in the other, and renumbering is deterministic at
    // any width).
    for f in GraphFamily::ALL {
        let g = generate(f, 9, 11);
        let fixed = GveLouvain::new(LouvainParams { threads: 1, ..Default::default() }).run(&g);
        let adaptive = GveLouvain::new(LouvainParams {
            threads: 4,
            adaptive_width: true,
            serial_pass_threshold: usize::MAX,
            ..Default::default()
        })
        .run(&g);
        assert_eq!(fixed.membership, adaptive.membership, "{f:?}");
        assert_eq!(fixed.modularity.to_bits(), adaptive.modularity.to_bits(), "{f:?}");
        assert_eq!(fixed.passes, adaptive.passes, "{f:?}");
        for (i, ps) in adaptive.pass_stats.iter().enumerate() {
            assert_eq!(ps.effective_threads, 1, "{f:?} pass {i} escaped the serial fast path");
        }
    }
}

#[test]
fn serial_threshold_boundary_is_deterministic() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Web, 10, 13);
    let edges0 = g.num_edges();
    assert!(edges0 > 1);
    // Exactly at the threshold: pass 0 runs serially.
    let at = GveLouvain::new(LouvainParams {
        threads: 4,
        adaptive_width: true,
        serial_pass_threshold: edges0,
        ..Default::default()
    })
    .run(&g);
    assert_eq!(at.pass_stats[0].effective_threads, 1);
    // One directed edge below it: pass 0 runs at full width.
    let above = GveLouvain::new(LouvainParams {
        threads: 4,
        adaptive_width: true,
        serial_pass_threshold: edges0 - 1,
        ..Default::default()
    })
    .run(&g);
    assert_eq!(above.pass_stats[0].effective_threads, 4);
}

#[test]
fn bucketed_aggregation_with_vertex_order_matches_dynamic_exactly() {
    let _lock = session_lock();
    // The PR 10 extension: the aggregation offsets scatters are dealt
    // through the pass's vertex ScanOrder and the compact/sort loops
    // through the fill's community order.  All of them must produce a
    // bit-identical supergraph versus flat dynamic dealing, at one
    // thread and several.
    let g = generate(GraphFamily::Web, 10, 43);
    let n = g.num_vertices();
    let memb: Vec<u32> = (0..n).map(|v| (v % 137) as u32).collect();
    for threads in [1usize, 4] {
        let pool = TablePool::new(TableKind::FarKv, 137, threads);
        let base = aggregate_csr(
            &g,
            &memb,
            137,
            &pool,
            &LouvainParams { threads, schedule: Schedule::Dynamic, ..Default::default() },
        );
        let p = LouvainParams { threads, schedule: Schedule::DegreeBucketed, ..Default::default() };
        let mut order = ScanOrder::default();
        order.build(n, p.small_degree, p.hub_degree, |v| g.degree(v));
        let team = Team::new(threads);
        let mut scratch = AggScratch::new();
        let mut out = Csr::default();
        let info = aggregate_csr_into(
            &g,
            &memb,
            137,
            &pool,
            &p,
            Some(&order),
            Exec::team(&team),
            &mut scratch,
            &mut out,
        );
        assert_eq!(base.graph, out, "threads={threads}");
        assert_eq!(base.counters.edges_scanned_agg, info.counters.edges_scanned_agg);
    }
}

#[test]
fn serial_fast_path_dispatches_no_team_jobs_inside_passes() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Web, 10, 17);

    // Count team.job spans that *start inside* a pass window — the
    // team still legitimately runs outside passes (workspace prepare,
    // the final full-width renumber).
    let jobs_in_passes = |trace: &gve_louvain::trace::Trace| {
        let windows: Vec<(u64, u64)> = trace
            .spans("pass")
            .map(|p| (p.start_ns, p.start_ns + p.dur_ns))
            .collect();
        trace
            .spans("team.job")
            .filter(|j| windows.iter().any(|&(lo, hi)| j.start_ns >= lo && j.start_ns < hi))
            .count()
    };

    // All-serial adaptive run: no dispatch, no barrier, no team.job.
    let session = TraceSession::start();
    let out = GveLouvain::new(LouvainParams {
        threads: 4,
        adaptive_width: true,
        serial_pass_threshold: usize::MAX,
        ..Default::default()
    })
    .run(&g);
    let trace = session.finish();
    assert!(out.passes > 0);
    assert_eq!(trace.count("pass"), out.passes);
    assert_eq!(
        jobs_in_passes(&trace),
        0,
        "serial fast path must not dispatch the team inside a pass"
    );
    // The pass span and the counters instant both carry the width.
    for p in trace.spans("pass") {
        assert_eq!(p.args[3], 1, "pass {} span width", p.args[0]);
    }
    for c in trace.events.iter().filter(|e| e.name == "pass.counters") {
        assert_eq!(c.args[1], 1, "pass {} counters width", c.args[0]);
    }

    // Fixed-width control at the same thread count: passes dispatch.
    let session = TraceSession::start();
    let out = GveLouvain::new(LouvainParams::with_threads(4)).run(&g);
    let trace = session.finish();
    assert!(out.passes > 0);
    assert!(jobs_in_passes(&trace) > 0, "fixed-width control must dispatch team jobs");
    for p in trace.spans("pass") {
        assert_eq!(p.args[3], 4, "pass {} span width", p.args[0]);
    }
}
