//! Integration tests for the tracing subsystem (PR 7 acceptance
//! criteria):
//!
//! * a traced run emits spans for every pass's local-moving and
//!   aggregation phases plus per-worker busy slices, and the spans obey
//!   stack discipline per thread (nested or disjoint, never partially
//!   overlapping);
//! * with tracing disabled nothing is recorded and results are
//!   bit-identical run to run — and a traced run does not perturb a
//!   deterministic single-threaded result either;
//! * replaying a deterministic run under two sessions yields an
//!   identical trace *structure* (event names and counts; timings of
//!   course differ);
//! * the Chrome export parses as a single well-formed JSON value
//!   (hand-rolled recursive-descent check — the offline registry has no
//!   serde) with thread metadata and complete events;
//! * the derived utilization table has one row per pass with
//!   efficiency in (0, 1].
//!
//! The enabled flag is process-global and `cargo test` runs tests on
//! multiple threads, so every test here serializes through
//! [`session_lock`] — including the "disabled" ones, which would
//! otherwise record into a concurrently-active session's sinks.

use gve_louvain::graph::delta::StreamOp;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::Csr;
use gve_louvain::louvain::gve::GveLouvain;
use gve_louvain::louvain::params::LouvainParams;
use gve_louvain::louvain::LouvainResult;
use gve_louvain::parallel::schedule::Schedule;
use gve_louvain::service::{BatchPolicy, CommunityService, ServiceConfig};
use gve_louvain::trace::{chrome, report, EventKind, Trace, TraceSession};
use std::collections::{HashMap, HashSet};
use std::sync::{Mutex, MutexGuard};

fn session_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn traced_run(params: LouvainParams, g: &Csr) -> (LouvainResult, Trace) {
    let session = TraceSession::start();
    let out = GveLouvain::new(params).run(g);
    (out, session.finish())
}

/// Per tid, spans must nest or be disjoint — a span partially
/// overlapping its enclosing span means a guard leaked across scopes.
fn assert_stack_discipline(trace: &Trace) {
    let mut by_tid: HashMap<u32, Vec<(u64, u64)>> = HashMap::new();
    for e in &trace.events {
        if e.kind == EventKind::Span {
            by_tid.entry(e.tid).or_default().push((e.start_ns, e.start_ns + e.dur_ns));
        }
    }
    for (tid, mut spans) in by_tid {
        // Start order; at equal starts the longer span is the parent.
        spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut stack: Vec<u64> = Vec::new();
        for (s, e) in spans {
            while stack.last().is_some_and(|&top| top <= s) {
                stack.pop();
            }
            if let Some(&top) = stack.last() {
                assert!(
                    e <= top,
                    "tid {tid}: span [{s}, {e}) partially overlaps an enclosing span ending {top}"
                );
            }
            stack.push(e);
        }
    }
}

#[test]
fn traced_run_emits_well_formed_spans_for_every_pass() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Web, 10, 7);
    let params =
        LouvainParams { threads: 2, schedule: Schedule::DegreeBucketed, ..LouvainParams::default() };
    let (out, trace) = traced_run(params, &g);
    let passes = out.pass_stats.len();
    assert!(passes > 0);
    assert_eq!(trace.dropped, 0, "scale-10 run must fit the rings");

    // Pass-granularity spans: one pass / move / counters-instant per
    // pass; aggregation only on passes that did not break first.
    assert_eq!(trace.count("pass"), passes);
    assert_eq!(trace.count("move"), passes);
    assert_eq!(trace.count("pass.counters"), passes);
    let aggs = trace.count("agg");
    assert!(
        aggs == passes || aggs + 1 == passes,
        "agg spans {aggs} vs {passes} passes (last pass may break before aggregating)"
    );
    for sub in ["agg.community_order", "agg.offsets", "agg.scatter", "agg.compact"] {
        assert_eq!(trace.count(sub), aggs, "one {sub} per aggregation");
    }
    assert!(trace.count("move.iter") >= passes, "every pass moves at least once");
    assert!(trace.count("scan_order.build") >= 1, "degree-bucketed runs build a ScanOrder");
    assert!(trace.count("move.buckets") >= 1, "bucketed iterations record bucket times");

    // The first pass span carries the input graph's shape.
    let first = trace.spans("pass").next().expect("pass span");
    assert_eq!(first.args[0], 0);
    assert_eq!(first.args[1], g.num_vertices() as u64);
    assert_eq!(first.args[2], g.num_edges() as u64);

    // Dispatch granularity: every worker.busy slice belongs to a
    // recorded team.job (correlated through arg slot 0).
    assert!(trace.count("team.job") > 0);
    assert!(trace.count("worker.busy") > 0);
    let jobs: HashSet<u64> = trace.spans("team.job").map(|e| e.args[0]).collect();
    for w in trace.spans("worker.busy") {
        assert!(jobs.contains(&w.args[0]), "worker.busy job {} has no team.job span", w.args[0]);
    }

    assert_stack_discipline(&trace);
}

#[test]
fn disabled_tracing_records_nothing_and_results_are_bit_exact() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Web, 9, 11);
    assert!(!gve_louvain::trace::enabled());
    let run = || GveLouvain::new(LouvainParams::default()).run(&g);
    let a = run();
    let b = run();
    assert_eq!(a.membership, b.membership);
    assert_eq!(a.modularity.to_bits(), b.modularity.to_bits());

    // The disabled runs above left nothing behind in any sink.
    let trace = TraceSession::start().finish();
    assert_eq!(trace.events.len(), 0, "disabled span sites must record nothing");

    // And recording does not perturb a deterministic run.
    let session = TraceSession::start();
    let c = run();
    let trace = session.finish();
    assert!(trace.count("pass") > 0);
    assert_eq!(a.membership, c.membership, "tracing changed the clustering");
    assert_eq!(a.modularity.to_bits(), c.modularity.to_bits());
}

#[test]
fn replaying_a_deterministic_run_yields_identical_structure() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Social, 9, 3);
    let (out_a, a) = traced_run(LouvainParams::default(), &g);
    let (out_b, b) = traced_run(LouvainParams::default(), &g);
    assert_eq!(out_a.membership, out_b.membership);
    let (sa, sb) = (a.structure(), b.structure());
    assert!(sa.contains_key("pass") && sa.contains_key("move.iter"));
    assert_eq!(sa, sb, "same run, same span structure (timings aside)");
}

/// Minimal strict JSON reader: panics (failing the test) on anything
/// malformed, checks every number parses as f64 and every string escape
/// is legal.
struct Json<'a> {
    b: &'a [u8],
    i: usize,
}

impl Json<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> u8 {
        self.ws();
        *self.b.get(self.i).expect("unexpected end of JSON")
    }

    fn eat(&mut self, c: u8) {
        self.ws();
        assert_eq!(self.b.get(self.i).copied(), Some(c), "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
    }

    fn value(&mut self) {
        match self.peek() {
            b'{' => {
                self.eat(b'{');
                if self.peek() != b'}' {
                    loop {
                        self.string();
                        self.eat(b':');
                        self.value();
                        if self.peek() != b',' {
                            break;
                        }
                        self.eat(b',');
                    }
                }
                self.eat(b'}');
            }
            b'[' => {
                self.eat(b'[');
                if self.peek() != b']' {
                    loop {
                        self.value();
                        if self.peek() != b',' {
                            break;
                        }
                        self.eat(b',');
                    }
                }
                self.eat(b']');
            }
            b'"' => self.string(),
            b't' => self.lit("true"),
            b'f' => self.lit("false"),
            b'n' => self.lit("null"),
            _ => self.number(),
        }
    }

    fn string(&mut self) {
        self.eat(b'"');
        loop {
            match self.b[self.i] {
                b'"' => break,
                b'\\' => {
                    self.i += 1;
                    match self.b[self.i] {
                        b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => self.i += 1,
                        b'u' => {
                            for k in 1..=4 {
                                assert!(self.b[self.i + k].is_ascii_hexdigit(), "bad \\u escape");
                            }
                            self.i += 5;
                        }
                        c => panic!("illegal escape \\{:?}", c as char),
                    }
                }
                c => {
                    assert!(c >= 0x20, "raw control byte {c:#x} inside a JSON string");
                    self.i += 1;
                }
            }
        }
        self.i += 1;
    }

    fn lit(&mut self, s: &str) {
        self.ws();
        assert!(self.b[self.i..].starts_with(s.as_bytes()), "expected literal {s}");
        self.i += s.len();
    }

    fn number(&mut self) {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number");
        assert!(
            !text.is_empty() && text.parse::<f64>().is_ok(),
            "bad JSON number {text:?} at byte {start}"
        );
    }
}

#[test]
fn chrome_export_is_valid_json_with_expected_shape() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Web, 9, 19);
    let (_out, trace) = traced_run(LouvainParams::with_threads(2), &g);
    assert!(!trace.events.is_empty());
    let json = chrome::to_chrome_json(&trace);
    let mut p = Json { b: json.as_bytes(), i: 0 };
    p.value();
    p.ws();
    assert_eq!(p.i, p.b.len(), "trailing bytes after the top-level JSON value");
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.contains("\"ph\":\"M\""), "thread_name metadata records");
    assert!(json.contains("\"ph\":\"X\""), "complete (duration) events");
    assert!(json.contains("\"name\":\"pass\""), "pass spans exported by name");
}

#[test]
fn utilization_table_has_one_row_per_pass() {
    let _lock = session_lock();
    let threads = 2usize;
    let g = generate(GraphFamily::Web, 9, 29);
    let (out, trace) = traced_run(LouvainParams::with_threads(threads), &g);
    let util = report::derive_pass_utilization(&trace, threads);
    assert_eq!(util.len(), out.pass_stats.len());
    for u in &util {
        assert!(u.wall_ns > 0, "pass {}: empty wall", u.pass);
        assert!(
            u.efficiency > 0.0 && u.efficiency <= 1.0,
            "pass {}: efficiency {} out of (0, 1]",
            u.pass,
            u.efficiency
        );
    }
    let rendered = report::utilization_table(&out, &trace, threads).render();
    for header in ["pass", "eff%", "small%"] {
        assert!(rendered.contains(header), "missing column {header:?}\n{rendered}");
    }
    assert!(
        rendered.lines().count() >= out.pass_stats.len() + 2,
        "fewer lines than passes + header:\n{rendered}"
    );
}

#[test]
fn service_epochs_record_apply_detect_publish_spans() {
    let _lock = session_lock();
    let g = generate(GraphFamily::Road, 7, 5);
    let cfg = ServiceConfig { policy: BatchPolicy::by_ops(1), ..Default::default() };
    let mut svc = CommunityService::new(g, cfg);
    let session = TraceSession::start();
    let snap = svc.submit(StreamOp::Insert(0, 5, 1.0));
    let trace = session.finish();
    assert!(snap.is_some(), "by_ops(1) publishes after a single op");
    for name in ["epoch.apply", "epoch.detect", "epoch.publish"] {
        assert_eq!(trace.count(name), 1, "{name}");
    }
    assert_stack_discipline(&trace);
}
