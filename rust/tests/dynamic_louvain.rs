//! Tier-2 integration tests for the PR-2 dynamic-graph subsystem:
//! `graph::delta` (batch application) + `louvain::dynamic` (seeded
//! re-detection) + the coordinator timeline replay.
//!
//! The acceptance bar (ISSUE 2): on a seeded churn timeline of ≥ 10
//! batches mutating ~1% of edges each, delta screening must beat full
//! recompute on wall time while final modularity stays within 0.01.

use gve_louvain::coordinator::dynamic::{churn_timeline, replay_timeline, summarize};
use gve_louvain::graph::delta::EdgeBatch;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::Csr;
use gve_louvain::louvain::dynamic::{DynamicLouvain, SeedStrategy};
use gve_louvain::louvain::LouvainParams;
use gve_louvain::parallel::ParallelOpts;
use gve_louvain::parallel::Exec;
use std::collections::BTreeMap;

const BATCHES: usize = 10;
const FRAC: f64 = 0.01;

/// Oracle: replay the batch on an edge map and rebuild from scratch.
fn rebuild(g: &Csr, batch: &EdgeBatch) -> Csr {
    let mut map: BTreeMap<(u32, u32), f32> = BTreeMap::new();
    for v in 0..g.num_vertices() {
        for (t, w) in g.neighbours(v) {
            map.insert((v as u32, t), w);
        }
    }
    for &(u, v) in &batch.deletions {
        map.remove(&(u, v));
        map.remove(&(v, u));
    }
    for &(u, v, w) in &batch.insertions {
        *map.entry((u, v)).or_insert(0.0) += w;
        if u != v {
            *map.entry((v, u)).or_insert(0.0) += w;
        }
    }
    // Rebuild CSR directly from the directed map (rows come out sorted).
    let n = g.num_vertices();
    let mut offsets = vec![0usize; n + 1];
    for &(u, _) in map.keys() {
        offsets[u as usize + 1] += 1;
    }
    for v in 0..n {
        offsets[v + 1] += offsets[v];
    }
    let mut targets = Vec::with_capacity(map.len());
    let mut weights = Vec::with_capacity(map.len());
    for (&(_, t), &w) in &map {
        targets.push(t);
        weights.push(w);
    }
    Csr { offsets, targets, weights }
}

#[test]
fn apply_batch_equals_rebuild_over_a_timeline() {
    // Sequential churn batches, each applied two ways: the parallel
    // merge and the from-scratch rebuild (deletions, insertions and
    // weight updates on already-present pairs all occur in churn).
    for family in [GraphFamily::Web, GraphFamily::Road] {
        let mut cur = generate(family, 9, 51);
        for i in 0..5 {
            let batch = gve_louvain::graph::generators::churn_batch(&cur, 0.02, 60 + i);
            let fast = cur.apply_batch(
                &batch,
                ParallelOpts { threads: 4, chunk: 64, ..Default::default() },
                Exec::scoped(),
            );
            let slow = rebuild(&cur, &batch);
            assert_eq!(fast, slow, "{family:?} batch {i}");
            fast.validate().unwrap();
            assert!(fast.is_symmetric(), "{family:?} batch {i}");
            cur = fast;
        }
    }
}

#[test]
fn weight_updates_and_deletions_roundtrip() {
    let g = generate(GraphFamily::Web, 8, 3);
    // Bump the weight of an existing edge, then delete it.
    let u = (0..g.num_vertices()).find(|&v| g.degree(v) > 0).unwrap() as u32;
    let v = g.edges(u as usize).0[0];
    let mut up = EdgeBatch::new();
    up.insert(u, v, 2.0);
    let g2 = g.apply_batch(&up, ParallelOpts::default(), Exec::scoped());
    assert_eq!(g2, rebuild(&g, &up));
    assert_eq!(g2.num_edges(), g.num_edges(), "weight update must not add slots");
    let mut del = EdgeBatch::new();
    del.delete(u, v);
    let g3 = g2.apply_batch(&del, ParallelOpts::default(), Exec::scoped());
    assert_eq!(g3, rebuild(&g2, &del));
    assert_eq!(g3.num_edges(), g.num_edges() - 2);
}

#[test]
fn dynamic_strategies_stay_within_epsilon_of_full_recompute() {
    let g0 = generate(GraphFamily::Web, 12, 42);
    let tl = churn_timeline(&g0, BATCHES, FRAC, 42);
    assert_eq!(tl.batches.len(), BATCHES);
    let cells = replay_timeline(&g0, &tl, &SeedStrategy::ALL, &LouvainParams::default());
    let summaries = summarize(&cells);
    assert_eq!(summaries.len(), 3);
    let full = summaries
        .iter()
        .find(|s| s.strategy == SeedStrategy::FullRecompute)
        .unwrap();
    for s in &summaries {
        // The acceptance ε: final modularity within 0.01 of full.
        assert!(
            (s.final_modularity - full.final_modularity).abs() <= 0.01,
            "{:?}: Q={} vs full {}",
            s.strategy,
            s.final_modularity,
            full.final_modularity
        );
        assert_eq!(s.batches, BATCHES);
    }
    // Every batch individually stays sane for the warm strategies
    // (churn keeps injecting inter-community noise edges, so the bar
    // is below the pristine-graph 0.9+).
    for c in &cells {
        assert!(c.modularity > 0.7, "{:?} batch {}: q={}", c.strategy, c.batch, c.modularity);
    }
}

#[test]
fn delta_screening_beats_full_recompute_on_wall_time() {
    let g0 = generate(GraphFamily::Web, 12, 7);
    let tl = churn_timeline(&g0, BATCHES, FRAC, 7);
    let cells = replay_timeline(&g0, &tl, &SeedStrategy::ALL, &LouvainParams::default());
    let summaries = summarize(&cells);
    let get = |s: SeedStrategy| summaries.iter().find(|x| x.strategy == s).unwrap();
    let full = get(SeedStrategy::FullRecompute);
    let delta = get(SeedStrategy::DeltaScreening);

    // Wall time: per-batch (median) and total, both strictly better.
    // Deliberately wall-clock (the ISSUE acceptance bar); the median
    // over 10 batches absorbs isolated scheduling hiccups, and the
    // machine-independent counter form of the same claim lives in
    // delta_screening_processes_fewer_vertices_than_full below.
    assert!(
        delta.median_wall_ns < full.median_wall_ns,
        "delta median {} !< full median {}",
        delta.median_wall_ns,
        full.median_wall_ns
    );
    assert!(
        delta.total_wall_ns < full.total_wall_ns,
        "delta total {} !< full total {}",
        delta.total_wall_ns,
        full.total_wall_ns
    );
    // Screening never seeds more than the graph (on this dense family
    // a 1% batch can saturate the seed; the win is the warm start).
    assert!(delta.mean_affected <= g0.num_vertices() as f64);
    // And the machine-independent evidence: warm starts take no more
    // passes than full recomputes across the timeline.
    let total_passes = |s: SeedStrategy| -> u64 {
        cells
            .iter()
            .filter(|c| c.strategy == s)
            .map(|c| c.passes as u64)
            .sum()
    };
    assert!(
        total_passes(SeedStrategy::DeltaScreening) <= total_passes(SeedStrategy::FullRecompute)
    );
}

#[test]
fn delta_screening_processes_fewer_vertices_than_full() {
    // Deterministic (counter-based, not wall-clock) form of the perf
    // claim: summed vertices_processed across a timeline.  Sparse
    // family, where the screened seed is a genuine subset.
    let g0 = generate(GraphFamily::Road, 12, 19);
    let tl = churn_timeline(&g0, 6, FRAC, 19);
    let mut totals = Vec::new();
    for strategy in [SeedStrategy::FullRecompute, SeedStrategy::DeltaScreening] {
        let mut dl = DynamicLouvain::new(LouvainParams::default(), strategy);
        dl.run_initial(&g0);
        let mut processed = 0u64;
        for (g, b) in tl.graphs.iter().zip(&tl.batches) {
            let out = dl.update(g, b);
            processed += out.result.counters.vertices_processed;
        }
        totals.push(processed);
    }
    assert!(
        totals[1] * 2 < totals[0],
        "delta screening should process <1/2 the vertices: full={} delta={}",
        totals[0],
        totals[1]
    );
}

#[test]
fn dynamic_driver_reuses_workspace_across_batches() {
    // O(1) OS spawns across the whole timeline (the PR-1 guarantee,
    // extended to the dynamic driver).
    let g0 = generate(GraphFamily::Social, 10, 23);
    let tl = churn_timeline(&g0, 4, FRAC, 23);
    let mut dl = DynamicLouvain::new(LouvainParams::with_threads(4), SeedStrategy::DeltaScreening);
    dl.run_initial(&g0);
    assert_eq!(dl.spawned_workers(), 3);
    for (g, b) in tl.graphs.iter().zip(&tl.batches) {
        let out = dl.update(g, b);
        assert!(out.result.modularity > 0.2);
    }
    assert_eq!(dl.spawned_workers(), 3, "spawns must be O(1) across batches");
}

#[test]
fn naive_dynamic_converges_in_fewer_iterations() {
    // The arXiv:2301.12390 claim that motivates the subsystem.
    let g0 = generate(GraphFamily::Web, 11, 31);
    let tl = churn_timeline(&g0, 5, FRAC, 31);
    let iters = |strategy: SeedStrategy| -> usize {
        let mut dl = DynamicLouvain::new(LouvainParams::default(), strategy);
        dl.run_initial(&g0);
        let mut total = 0usize;
        for (g, b) in tl.graphs.iter().zip(&tl.batches) {
            let out = dl.update(g, b);
            total += out.result.pass_stats.iter().map(|p| p.iterations).sum::<usize>();
        }
        total
    };
    let full = iters(SeedStrategy::FullRecompute);
    let naive = iters(SeedStrategy::NaiveDynamic);
    assert!(naive < full, "naive-dynamic iterations {naive} !< full {full}");
}
