//! Baseline suite behaviour: every system runs the quick suite, quality
//! ordering matches the paper's figures, OOM gates fire where Table 2
//! says they must.

use gve_louvain::baselines::{run_system, System};
use gve_louvain::coordinator::runner::compare_on_entry;
use gve_louvain::coordinator::suite;
use gve_louvain::gpusim::DeviceModel;

const ALL: [System; 7] = [
    System::GveLouvain,
    System::NuLouvain,
    System::Vite,
    System::Grappolo,
    System::NetworKit,
    System::CuGraph,
    System::Nido,
];

#[test]
fn every_system_runs_the_quick_suite() {
    for entry in suite::quick() {
        let g = entry.graph(-4, 42);
        for s in ALL {
            let out = run_system(s, &g, 1, 42);
            assert!(
                out.modularity > 0.15,
                "{s:?} on {}: q={}",
                entry.name,
                out.modularity
            );
            assert_eq!(out.membership.len(), g.num_vertices());
        }
    }
}

#[test]
fn nido_quality_worst_among_gpu_systems() {
    // Paper Fig 12c: Nido's modularity far below ν-Louvain's.
    let entry = suite::find("uk-2002").unwrap();
    let g = entry.graph(-3, 42);
    let nido = run_system(System::Nido, &g, 1, 42);
    let nu = run_system(System::NuLouvain, &g, 1, 42);
    assert!(
        nu.modularity >= nido.modularity,
        "nu {} < nido {}",
        nu.modularity,
        nido.modularity
    );
}

#[test]
fn oom_gates_reproduce_paper_exclusions() {
    let d = DeviceModel::default();
    // Paper: cuGraph fails on arabic-2005, uk-2005, webbase-2001,
    // it-2004, sk-2005; ν-Louvain only on sk-2005.
    let cugraph_oom: Vec<&str> = suite::SUITE
        .iter()
        .filter(|e| !d.cugraph_fits(e.paper_v, e.paper_e))
        .map(|e| e.name)
        .collect();
    assert_eq!(
        cugraph_oom,
        vec!["arabic-2005", "uk-2005", "webbase-2001", "it-2004", "sk-2005"],
    );
    let nu_oom: Vec<&str> = suite::SUITE
        .iter()
        .filter(|e| !d.nu_louvain_fits(e.paper_v, e.paper_e))
        .map(|e| e.name)
        .collect();
    assert_eq!(nu_oom, vec!["sk-2005"]);
}

#[test]
fn comparison_cells_gate_gpu_systems() {
    let entry = suite::find("webbase-2001").unwrap();
    let cells = compare_on_entry(entry, -6, &[System::CuGraph, System::NuLouvain], 1, 1, 42);
    let cu = cells.iter().find(|c| c.system == System::CuGraph).unwrap();
    let nu = cells.iter().find(|c| c.system == System::NuLouvain).unwrap();
    assert!(cu.modeled_ns.is_none(), "cuGraph must be OOM on webbase-2001");
    assert!(nu.modeled_ns.is_some(), "nu-louvain fits webbase-2001");
}

#[test]
fn gve_is_fastest_cpu_system_by_wall_clock() {
    // On identical machinery the adopted optimizations must win on wall
    // time too (the Fig 11 ordering at this host's scale).
    let entry = suite::find("com-LiveJournal").unwrap();
    let g = entry.graph(-3, 42);
    let gve = run_system(System::GveLouvain, &g, 1, 42);
    for s in [System::Vite, System::NetworKit] {
        let other = run_system(s, &g, 1, 42);
        assert!(
            gve.wall_ns <= other.wall_ns * 2,
            "{s:?} unexpectedly much faster: gve={} vs {}",
            gve.wall_ns,
            other.wall_ns
        );
    }
}

#[test]
fn modularity_agreement_band_across_systems() {
    // Paper Figs 11c/12c: all serious systems land within a few percent
    // of each other (Nido excepted).
    let entry = suite::find("indochina-2004").unwrap();
    let g = entry.graph(-3, 42);
    let qs: Vec<(System, f64)> = ALL
        .iter()
        .filter(|s| **s != System::Nido)
        .map(|&s| (s, run_system(s, &g, 1, 42).modularity))
        .collect();
    let best = qs.iter().map(|(_, q)| *q).fold(f64::MIN, f64::max);
    for (s, q) in &qs {
        assert!(
            *q > best - 0.12,
            "{s:?} too far below best: {q} vs {best}"
        );
    }
}
