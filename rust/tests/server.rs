//! Tier-2 integration tests for the PR-9 network serving subsystem:
//! the wire protocol, the single-writer daemon, and the epoch-delta
//! subscription stream — all over real loopback TCP.
//!
//! The acceptance bar (ISSUE 9): a `.ups` op timeline replayed over
//! TCP publishes snapshots bit-identical to the same timeline replayed
//! in process; a subscriber reconstructing membership purely from
//! delta frames matches every full snapshot; malformed frames, abrupt
//! disconnects and backpressure stalls leave the daemon serving; and a
//! shutdown drains cleanly with no admitted op lost.

use gve_louvain::coordinator::dynamic::churn_timeline;
use gve_louvain::coordinator::service::replay_service;
use gve_louvain::graph::delta::StreamOp;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::dynamic::SeedStrategy;
use gve_louvain::server::frame::{
    encode_frame, read_frame, Frame, Role, ERR_OVERSIZED, ERR_UNEXPECTED_TYPE,
};
use gve_louvain::server::{Client, LouvainServer, ServerConfig, Subscriber};
use gve_louvain::service::{BatchPolicy, ServiceConfig};
use std::io::Write as _;
use std::net::TcpStream;

const BATCHES: usize = 6;
const FRAC: f64 = 0.01;

/// Commit-only epoch cuts + single-threaded detection: the replay is
/// deterministic, so wire and in-process paths must agree bit for bit.
fn det_cfg() -> ServiceConfig {
    ServiceConfig {
        strategy: SeedStrategy::DeltaScreening,
        policy: BatchPolicy::by_ops(1 << 20),
        ..Default::default()
    }
}

fn server_cfg() -> ServerConfig {
    ServerConfig { service: det_cfg(), ..Default::default() }
}

/// Ops frames for each timeline batch, ending in an explicit Commit so
/// the daemon cuts exactly the timeline's epochs.
fn batch_frames(tl: &gve_louvain::coordinator::dynamic::ChurnTimeline) -> Vec<Vec<StreamOp>> {
    tl.batches
        .iter()
        .map(|b| b.to_ops().chain(std::iter::once(StreamOp::Commit)).collect())
        .collect()
}

/// The tentpole oracle: the TCP-replayed timeline publishes the same
/// epochs as `replay_service`, bit for bit, and a subscriber's
/// delta-reconstructed mirror tracks every one of them.
#[test]
fn wire_replay_is_bit_identical_to_in_process_replay() {
    let g0 = generate(GraphFamily::Web, 9, 42);
    let tl = churn_timeline(&g0, BATCHES, FRAC, 42);
    let (_, reference) = replay_service(&g0, &tl, det_cfg());

    let server = LouvainServer::start(g0.clone(), server_cfg()).unwrap();
    let addr = server.local_addr();
    // Subscribe before ingesting: once connect() returns the priming
    // snapshot (epoch 0), every later epoch must stream to us.
    let mut sub = Subscriber::connect(addr).unwrap();
    assert_eq!(sub.epoch(), 0);
    assert_eq!(sub.membership().len(), g0.num_vertices());

    let mut client = Client::connect(addr).unwrap();
    for ops in batch_frames(&tl) {
        client.send_ops(&ops).unwrap();
    }
    let rep = client.finish().unwrap();
    let total_ops: usize = tl.batches.iter().map(|b| b.len()).sum();
    assert_eq!(rep.accepted as usize, total_ops);
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.epoch, BATCHES as u64);

    // One event per batch, each bit-identical to the in-process epoch:
    // same membership (delta-reconstructed or full), same modularity
    // bits, same community count.
    for want in &reference {
        let ev = sub.next_event().unwrap().expect("epoch event before close");
        assert_eq!(ev.epoch, want.epoch);
        assert_eq!(sub.epoch(), want.epoch);
        assert_eq!(sub.membership(), want.membership(), "epoch {}", want.epoch);
        assert_eq!(
            sub.modularity().to_bits(),
            want.modularity.to_bits(),
            "epoch {} modularity diverged over the wire",
            want.epoch
        );
        assert_eq!(sub.num_communities() as usize, want.num_communities());
    }

    // The server's own query surface agrees with the last epoch.
    let last = server.handle().load();
    assert_eq!(last.epoch, BATCHES as u64);
    assert_eq!(last.membership(), reference.last().unwrap().membership());

    let report = server.shutdown();
    assert_eq!(report.ops_accepted as usize, total_ops);
    assert_eq!(report.ops_rejected, 0);
    assert_eq!(report.epochs_published, BATCHES as u64);
    assert_eq!(report.final_epoch, BATCHES as u64);
}

/// A mirror built purely from the subscription stream equals the full
/// snapshot a fresh subscriber is primed with at the same epoch.
#[test]
fn delta_reconstruction_matches_a_fresh_full_snapshot() {
    let g0 = generate(GraphFamily::Web, 9, 7);
    let tl = churn_timeline(&g0, BATCHES, FRAC, 7);

    let server = LouvainServer::start(g0, server_cfg()).unwrap();
    let addr = server.local_addr();
    let mut sub = Subscriber::connect(addr).unwrap();

    let mut client = Client::connect(addr).unwrap();
    for ops in batch_frames(&tl) {
        client.send_ops(&ops).unwrap();
    }
    client.finish().unwrap();

    // Fold the event stream into the mirror up to the final epoch.
    while sub.epoch() < BATCHES as u64 {
        sub.next_event().unwrap().expect("epoch event before close");
    }

    // A subscriber connecting now is primed with a full snapshot of
    // the same epoch — the deltas must have reconstructed it exactly.
    let fresh = Subscriber::connect(addr).unwrap();
    assert_eq!(fresh.epoch(), sub.epoch());
    assert_eq!(fresh.membership(), sub.membership());
    assert_eq!(fresh.modularity().to_bits(), sub.modularity().to_bits());
    assert_eq!(fresh.num_communities(), sub.num_communities());

    server.shutdown();
}

/// Admitted-but-uncommitted ops survive shutdown: the drain cuts the
/// pending partial batch into a final epoch before reporting.
#[test]
fn shutdown_drains_admitted_ops_without_a_final_commit() {
    let g0 = generate(GraphFamily::Web, 8, 11);
    let n = g0.num_vertices() as u32;
    let server = LouvainServer::start(g0, server_cfg()).unwrap();

    let mut client = Client::connect(server.local_addr()).unwrap();
    let ops: Vec<StreamOp> =
        (0..40u32).map(|k| StreamOp::Insert(k % n, (k * 7 + 1) % n, 1.0)).collect();
    client.send_ops(&ops).unwrap();
    // No Commit and no Bye: sync() proves the server admitted every op
    // into its pending batch, then the connection just goes away.
    client.sync().unwrap();
    assert_eq!(client.acked(), (ops.len() as u64, 0));
    drop(client);

    let report = server.shutdown();
    assert_eq!(report.ops_accepted, ops.len() as u64, "admitted ops lost in the drain");
    assert_eq!(report.epochs_published, 1, "the drain must cut the pending batch");
    assert_eq!(report.final_epoch, 1);
}

/// A malformed frame gets an Error answer and a closed connection —
/// and the daemon keeps serving everyone else.
#[test]
fn malformed_frames_are_answered_and_do_not_poison_the_daemon() {
    let g0 = generate(GraphFamily::Web, 8, 5);
    let n = g0.num_vertices() as u32;
    let server = LouvainServer::start(g0, server_cfg()).unwrap();
    let addr = server.local_addr();

    // Unknown frame type after a valid handshake.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&encode_frame(&Frame::Hello { role: Role::Ingest })).unwrap();
        match read_frame(&mut s).unwrap() {
            Some(Frame::Welcome { .. }) => {}
            other => panic!("expected welcome, got {other:?}"),
        }
        s.write_all(&[1, 0, 0, 0, 0x7f]).unwrap(); // len=1, unknown type
        match read_frame(&mut s).unwrap() {
            Some(Frame::Error { code, .. }) => assert_eq!(code, ERR_UNEXPECTED_TYPE),
            other => panic!("expected error frame, got {other:?}"),
        }
        assert!(read_frame(&mut s).unwrap().is_none(), "server must close after the error");
    }

    // Oversized length prefix instead of a Hello.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        match read_frame(&mut s).unwrap() {
            Some(Frame::Error { code, .. }) => assert_eq!(code, ERR_OVERSIZED),
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    // The daemon still serves a well-behaved client afterwards.
    let mut client = Client::connect(addr).unwrap();
    client.send_ops(&[StreamOp::Insert(0, n - 1, 1.0), StreamOp::Commit]).unwrap();
    let rep = client.finish().unwrap();
    assert_eq!(rep.accepted, 1);
    assert_eq!(rep.epoch, 1);
    server.shutdown();
}

/// An abrupt mid-stream disconnect (no Bye) leaves the daemon healthy:
/// later clients connect, ingest and finish normally.
#[test]
fn abrupt_disconnect_leaves_the_daemon_serving() {
    let g0 = generate(GraphFamily::Web, 8, 13);
    let n = g0.num_vertices() as u32;
    let server = LouvainServer::start(g0, server_cfg()).unwrap();
    let addr = server.local_addr();

    let mut rude = Client::connect(addr).unwrap();
    rude.send_ops(&[StreamOp::Insert(0, 1, 1.0)]).unwrap();
    drop(rude); // FIN mid-stream, no Bye

    let mut client = Client::connect(addr).unwrap();
    client.send_ops(&[StreamOp::Insert(1, n - 1, 1.0), StreamOp::Commit]).unwrap();
    let rep = client.finish().unwrap();
    assert_eq!(rep.accepted, 1);
    assert!(rep.epoch >= 1);
    let report = server.shutdown();
    assert!(report.ops_accepted >= 1);
}

/// Backpressure end to end: a depth-1 op queue and a tiny ack window
/// force the stall path on both sides, and nothing is lost.
#[test]
fn backpressure_stalls_deliver_every_op() {
    let g0 = generate(GraphFamily::Web, 7, 29);
    let n = g0.num_vertices() as u32;
    let cfg = ServerConfig {
        queue_depth: 1,
        outbox_depth: 2,
        service: ServiceConfig {
            strategy: SeedStrategy::DeltaScreening,
            // Frequent epoch cuts keep the single-writer thread busy so
            // the op queue genuinely fills.
            policy: BatchPolicy::by_ops(16),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = LouvainServer::start(g0, cfg).unwrap();

    let mut client = Client::connect_with_window(server.local_addr(), 4).unwrap();
    let total = 400u32;
    for k in 0..total {
        client.send_ops(&[StreamOp::Insert(k % n, (k * 13 + 1) % n, 1.0)]).unwrap();
        assert!(client.in_flight() <= 4, "ack window must bound in-flight ops");
    }
    let rep = client.finish().unwrap();
    assert_eq!(rep.accepted + rep.rejected, total as u64);
    assert_eq!(rep.rejected, 0);

    let report = server.shutdown();
    assert_eq!(report.ops_accepted, total as u64);
    assert!(report.epochs_published >= (total as u64) / 16, "by_ops(16) must keep cutting epochs");
}

/// The growth guard works over the wire: out-of-range endpoints are
/// rejected, counted, and reported in the acks — never applied.
#[test]
fn growth_guard_rejections_are_accounted_in_acks() {
    let g0 = generate(GraphFamily::Web, 8, 3);
    let n = g0.num_vertices();
    let cfg = ServerConfig {
        service: ServiceConfig { max_vertices: n, ..det_cfg() },
        ..Default::default()
    };
    let server = LouvainServer::start(g0, cfg).unwrap();

    let mut client = Client::connect(server.local_addr()).unwrap();
    let ops = vec![
        StreamOp::Insert(0, 1, 1.0),
        StreamOp::Insert(2, n as u32, 1.0),      // endpoint out of range
        StreamOp::Insert(3, 4, 1.0),
        StreamOp::Delete(n as u32 + 7, 0),       // endpoint out of range
        StreamOp::Commit,
    ];
    client.send_ops(&ops).unwrap();
    let rep = client.finish().unwrap();
    assert_eq!(rep.accepted, 2);
    assert_eq!(rep.rejected, 2);

    // The guard held: the published graph never grew past the ceiling.
    assert_eq!(server.handle().load().vertices, n);

    let report = server.shutdown();
    assert_eq!(report.ops_accepted, 2);
    assert_eq!(report.ops_rejected, 2);
}

/// `serve_state()` plugs the daemon into the PR-8 introspection server:
/// `/epochs` reports the recent-epoch ring the ingest thread maintains.
#[test]
fn introspection_over_the_daemon_reports_the_epoch_ring() {
    use gve_louvain::obs::http::IntrospectionServer;
    use std::io::Read as _;

    let g0 = generate(GraphFamily::Web, 8, 17);
    let n = g0.num_vertices() as u32;
    let server = LouvainServer::start(g0, server_cfg()).unwrap();
    let http = IntrospectionServer::start_on(
        std::net::SocketAddr::from(([127, 0, 0, 1], 0)),
        server.serve_state(),
    )
    .unwrap();

    let mut client = Client::connect(server.local_addr()).unwrap();
    client.send_ops(&[StreamOp::Insert(0, n - 1, 1.0), StreamOp::Commit]).unwrap();
    client.finish().unwrap();

    let mut s = TcpStream::connect(http.local_addr()).unwrap();
    s.write_all(b"GET /epochs HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).unwrap();
    assert!(body.contains("\"recent\":["), "{body}");
    assert!(body.contains("\"epoch\":0,"), "boot epoch in the ring: {body}");
    assert!(body.contains("\"epoch\":1,"), "published epoch in the ring: {body}");

    drop(http);
    server.shutdown();
}
