//! Integration tests for the degree-aware hybrid scan engine (PR 6
//! acceptance criteria):
//!
//! * single-threaded runs with the `SmallTable` fast path on are
//!   bit-identical to pure Far-KV on every `GraphFamily`, at the
//!   local-moving level (membership / Σ' / dq_total) and end to end;
//! * a planted hub-and-spokes graph populates all three `ScanOrder`
//!   buckets, and `Schedule::DegreeBucketed` keeps quality within 0.02
//!   of dynamic scheduling at 1 and 4 threads;
//! * `SmallTable` overflow spills to the pooled slab exactly past the
//!   `SMALL_TABLE_CAP` boundary, bit-exactly;
//! * the Web family (the fast path's target shape) completes most of
//!   its row scans in the small path.

use gve_louvain::graph::builder::GraphBuilder;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::Csr;
use gve_louvain::louvain::gve::GveLouvain;
use gve_louvain::louvain::hashtable::{TablePool, SMALL_TABLE_CAP};
use gve_louvain::louvain::local_moving::local_moving;
use gve_louvain::louvain::params::{LouvainParams, TableKind};
use gve_louvain::parallel::schedule::{ScanOrder, Schedule};
use gve_louvain::parallel::team::Exec;

/// One single-threaded local-moving phase with the given fast-path
/// threshold; everything else is the adopted configuration.
fn run_move(g: &Csr, small_degree: usize) -> (Vec<u32>, Vec<u64>, u64, usize) {
    let n = g.num_vertices();
    let m = g.total_weight();
    let params = LouvainParams { small_degree, ..LouvainParams::default() };
    let k = g.vertex_weights();
    let mut memb: Vec<u32> = (0..n as u32).collect();
    let mut sigma = k.clone();
    let mut aff = vec![1u32; n];
    let pool = TablePool::new(TableKind::FarKv, n, 1);
    let out = local_moving(
        g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped(),
    );
    let sigma_bits: Vec<u64> = sigma.iter().map(|x| x.to_bits()).collect();
    (memb, sigma_bits, out.dq_total.to_bits(), out.iterations)
}

#[test]
fn hybrid_local_moving_bit_identical_to_farkv_on_all_families() {
    for family in GraphFamily::ALL {
        let g = generate(family, 9, 31);
        let pure = run_move(&g, 0);
        for small in [16, 40] {
            let hybrid = run_move(&g, small);
            assert_eq!(pure.0, hybrid.0, "{family:?} small={small}: membership diverged");
            assert_eq!(pure.1, hybrid.1, "{family:?} small={small}: sigma bits diverged");
            assert_eq!(pure.2, hybrid.2, "{family:?} small={small}: dq bits diverged");
            assert_eq!(pure.3, hybrid.3, "{family:?} small={small}: iterations diverged");
        }
    }
}

#[test]
fn hybrid_full_run_bit_identical_to_farkv_single_thread() {
    // End to end (all passes, aggregation included) under the flat
    // dynamic schedule.  `DegreeBucketed` is deliberately excluded
    // here: its low-bucket boundary *is* `small_degree`, so toggling
    // the fast path also reorders the scan — a different (equally
    // valid) clustering, covered by the determinism test below.
    for family in GraphFamily::ALL {
        let g = generate(family, 9, 57);
        let run = |small_degree: usize| {
            GveLouvain::new(LouvainParams { small_degree, ..LouvainParams::default() }).run(&g)
        };
        let pure = run(0);
        let hybrid = run(16);
        assert_eq!(pure.membership, hybrid.membership, "{family:?}: membership diverged");
        assert_eq!(
            pure.modularity.to_bits(),
            hybrid.modularity.to_bits(),
            "{family:?}: modularity bits diverged"
        );
        assert_eq!(pure.passes, hybrid.passes, "{family:?}");
        // The hybrid actually took the fast path somewhere.
        assert!(hybrid.counters.small_path_scans > 0, "{family:?}");
        assert_eq!(pure.counters.small_path_scans, 0, "{family:?}");
    }
}

#[test]
fn degree_bucketed_single_thread_is_deterministic() {
    for family in GraphFamily::ALL {
        let g = generate(family, 9, 23);
        let run = || {
            GveLouvain::new(LouvainParams {
                schedule: Schedule::DegreeBucketed,
                ..LouvainParams::default()
            })
            .run(&g)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.membership, b.membership, "{family:?}");
        assert_eq!(a.modularity.to_bits(), b.modularity.to_bits(), "{family:?}");
        assert_eq!(a.passes, b.passes, "{family:?}");
    }
}

/// Hub-and-spokes planted graph: one degree-400 hub (high bucket), 20
/// degree-25 connectors (mid bucket), 400 low-degree spokes.
fn hub_and_spokes() -> Csr {
    let spokes = 400usize;
    let mids = 20usize;
    let mut b = GraphBuilder::new(1 + spokes + mids);
    for s in 0..spokes {
        b = b.edge(0, (1 + s) as u32, 1.0);
    }
    for i in 0..mids {
        let mid = (1 + spokes + i) as u32;
        for j in 0..25 {
            let spoke = (1 + (i * 25 + j) % spokes) as u32;
            b = b.edge(mid, spoke, 1.0);
        }
    }
    b.build_undirected()
}

#[test]
fn planted_hub_graph_fills_all_three_buckets() {
    let g = hub_and_spokes();
    let n = g.num_vertices();
    assert_eq!(g.degree(0), 400);
    assert_eq!(g.degree(401), 25);

    let mut order = ScanOrder::default();
    order.build(n, 16, 256, |v| g.degree(v));
    assert_eq!(order.lo_end, 400, "400 spokes in the low bucket");
    assert_eq!(order.mid_end, 420, "20 connectors in the mid bucket");
    assert_eq!(order.ids.len(), n);
    // High bucket is exactly the hub; mid bucket is exactly the
    // connectors, ascending; low bucket is the spokes, ascending.
    assert_eq!(&order.ids[order.mid_end..], &[0]);
    let mids: Vec<u32> = (401..421).collect();
    assert_eq!(&order.ids[order.lo_end..order.mid_end], &mids[..]);
    assert!(order.ids[..order.lo_end].windows(2).all(|w| w[0] < w[1]));
    assert!(order.ids[..order.lo_end].iter().all(|&v| (1..=400).contains(&v)));
}

#[test]
fn degree_bucketed_quality_matches_dynamic_on_hub_graph() {
    let g = hub_and_spokes();
    for threads in [1usize, 4] {
        let run = |schedule: Schedule| {
            GveLouvain::new(LouvainParams { threads, schedule, ..LouvainParams::default() })
                .run(&g)
        };
        let dynamic = run(Schedule::Dynamic);
        let bucketed = run(Schedule::DegreeBucketed);
        assert!(
            (dynamic.modularity - bucketed.modularity).abs() < 0.02,
            "t={threads}: dynamic={} bucketed={}",
            dynamic.modularity,
            bucketed.modularity
        );
        // The bucketed run scanned rows through both table paths: the
        // hub/connectors are over the small-degree threshold, the
        // spokes under it.
        assert!(bucketed.counters.small_path_scans > 0, "t={threads}");
        assert!(bucketed.counters.large_path_scans > 0, "t={threads}");
    }
}

#[test]
fn degree_bucketed_quality_matches_dynamic_multithreaded_web() {
    let g = generate(GraphFamily::Web, 10, 17);
    let run = |schedule: Schedule| {
        GveLouvain::new(LouvainParams { threads: 4, schedule, ..LouvainParams::default() })
            .run(&g)
            .modularity
    };
    let (qd, qb) = (run(Schedule::Dynamic), run(Schedule::DegreeBucketed));
    assert!((qd - qb).abs() < 0.02, "dynamic={qd} bucketed={qb}");
}

#[test]
fn web_family_mostly_takes_the_small_path() {
    // The acceptance shape: on the Web family (power-law, avg degree
    // 24, median well under the threshold) more than half of all row
    // scans complete in the SmallTable.
    let g = generate(GraphFamily::Web, 10, 5);
    let out = GveLouvain::new(LouvainParams::default()).run(&g);
    let (small, large) = (out.counters.small_path_scans, out.counters.large_path_scans);
    assert!(small > 0 && large > 0, "small={small} large={large}");
    assert!(small > large, "small path must dominate on web: small={small} large={large}");
}

#[test]
fn small_table_spills_exactly_past_the_capacity_boundary() {
    let pool = TablePool::new(TableKind::FarKv, 4 * SMALL_TABLE_CAP, 1);

    // Degree == capacity with all-distinct keys: stays small.
    let mut t = pool.hybrid_table(0, SMALL_TABLE_CAP);
    t.begin_row(SMALL_TABLE_CAP);
    for i in 0..SMALL_TABLE_CAP {
        t.accumulate(i as u32, 1.5);
    }
    assert!(t.used_small());
    assert_eq!(t.spills(), 0);
    assert_eq!(t.len(), SMALL_TABLE_CAP);

    // One more distinct key: the row spills to the pooled slab,
    // preserving first-touch order and every partial sum.
    t.begin_row(SMALL_TABLE_CAP);
    for i in 0..=SMALL_TABLE_CAP {
        t.accumulate(i as u32, 2.0);
    }
    assert!(!t.used_small());
    assert_eq!(t.spills(), 1);
    assert_eq!(t.len(), SMALL_TABLE_CAP + 1);
    let mut seen = Vec::new();
    t.for_each(|c, w| seen.push((c, w.to_bits())));
    let want: Vec<(u32, u64)> = (0..=SMALL_TABLE_CAP as u32).map(|c| (c, 2.0f64.to_bits())).collect();
    assert_eq!(seen, want);
}

#[test]
fn spilling_runs_stay_bit_identical_single_thread() {
    // small_degree past the SmallTable capacity: every row between 33
    // and 64 distinct neighbour communities starts small and spills
    // mid-scan.  Social (avg degree 40) exercises this constantly; the
    // result must still match pure Far-KV bit for bit.
    let g = generate(GraphFamily::Social, 9, 13);
    let run = |small_degree: usize| {
        GveLouvain::new(LouvainParams { small_degree, ..LouvainParams::default() }).run(&g)
    };
    let pure = run(0);
    let spilly = run(2 * SMALL_TABLE_CAP);
    assert_eq!(pure.membership, spilly.membership);
    assert_eq!(pure.modularity.to_bits(), spilly.modularity.to_bits());
}
