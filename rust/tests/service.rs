//! Tier-2 integration tests for the PR-3 service subsystem: streaming
//! ingest, epoch snapshots and the query surface over incremental
//! Louvain.
//!
//! The acceptance bar (ISSUE 3): a `CommunityService` replays a
//! ≥10-batch stream end-to-end with delta screening; queries between
//! batches return complete, epoch-consistent memberships; total wall
//! time beats per-batch full recompute; and the final modularity stays
//! within 0.01 of a cold full run on the final graph.

use gve_louvain::coordinator::dynamic::churn_timeline;
use gve_louvain::coordinator::service::{replay_service, summarize_service};
use gve_louvain::graph::delta::StreamOp;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::io::{write_update_stream, UpdateStreamReader};
use gve_louvain::louvain::dynamic::SeedStrategy;
use gve_louvain::louvain::{GveLouvain, LouvainParams};
use gve_louvain::service::{BatchPolicy, CommunityService, ServiceConfig};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const BATCHES: usize = 10;
const FRAC: f64 = 0.01;

fn cfg(strategy: SeedStrategy) -> ServiceConfig {
    ServiceConfig { strategy, ..Default::default() }
}

/// The ISSUE 3 oracle: end-to-end replay, epoch-consistent queries,
/// wall-time win over per-batch full recompute, quality within ε of a
/// cold run.
#[test]
fn service_oracle_delta_screening_beats_full_and_stays_accurate() {
    let g0 = generate(GraphFamily::Web, 12, 42);
    let tl = churn_timeline(&g0, BATCHES, FRAC, 42);

    // Delta-screening replay, checking the query surface after every
    // batch: each published epoch is complete and describes exactly the
    // timeline's graph at that point.
    let mut svc = CommunityService::new(g0.clone(), cfg(SeedStrategy::DeltaScreening));
    for (i, batch) in tl.batches.iter().enumerate() {
        let snap = svc.ingest_batch(batch);
        assert_eq!(snap.epoch, i as u64 + 1);
        snap.validate().unwrap();
        assert_eq!(snap.vertices, tl.graphs[i].num_vertices());
        assert_eq!(snap.edges, tl.graphs[i].num_edges());
        assert_eq!(svc.graph(), &tl.graphs[i], "batch {i} diverged from the timeline");
        assert!(snap.modularity > 0.7, "epoch {}: q={}", snap.epoch, snap.modularity);
        // The handle serves the same epoch a fresh query would see.
        assert_eq!(svc.handle().load().epoch, snap.epoch);
    }
    assert_eq!(svc.metrics().batches_applied, BATCHES as u64);

    // Full-recompute replay over the identical timeline.
    let (full_svc, _) = replay_service(&g0, &tl, cfg(SeedStrategy::FullRecompute));

    // Wall time: the screened service beats per-batch full recompute
    // end to end (batch application is identical; the win is seeded
    // detection).
    let delta_wall = svc.metrics().total_wall_ns();
    let full_wall = full_svc.metrics().total_wall_ns();
    assert!(
        delta_wall < full_wall,
        "delta service {delta_wall} !< full service {full_wall}"
    );

    // Quality: within 0.01 of a cold full run on the final graph.
    let cold = GveLouvain::new(LouvainParams::default()).run(tl.graphs.last().unwrap());
    let served = svc.snapshot();
    assert!(
        (served.modularity - cold.modularity).abs() <= 0.01,
        "served Q={} vs cold Q={}",
        served.modularity,
        cold.modularity
    );
    assert_eq!(served.membership().len(), cold.membership.len());
}

/// Satellite: a query issued *during* ingest sees exactly one complete
/// epoch — never a torn membership, never a half-published state.
#[test]
fn queries_during_ingest_see_complete_epochs() {
    let g0 = generate(GraphFamily::Web, 10, 7);
    let tl = churn_timeline(&g0, 8, 0.02, 7);
    let mut svc = CommunityService::new(
        g0,
        ServiceConfig { params: LouvainParams::with_threads(4), ..cfg(SeedStrategy::DeltaScreening) },
    );
    let handle = svc.handle();
    let done = Arc::new(AtomicBool::new(false));

    let reader = {
        let handle = Arc::clone(&handle);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut last_epoch = 0u64;
            let mut loads = 0u64;
            while !done.load(Ordering::Acquire) {
                let snap = handle.load();
                // A complete epoch: internally consistent, monotone.
                snap.validate().unwrap_or_else(|e| panic!("torn epoch {}: {e}", snap.epoch));
                assert!(snap.epoch >= last_epoch, "epoch went backwards");
                last_epoch = snap.epoch;
                loads += 1;
            }
            loads
        })
    };

    let mut published = Vec::new();
    for batch in &tl.batches {
        let snap = svc.ingest_batch(batch);
        published.push(snap);
    }
    done.store(true, Ordering::Release);
    let loads = reader.join().expect("reader thread panicked (torn epoch)");
    assert!(loads > 0, "reader never sampled the surface");

    // Every published epoch stays valid and immutable after the fact.
    for (i, snap) in published.iter().enumerate() {
        assert_eq!(snap.epoch, i as u64 + 1);
        snap.validate().unwrap();
    }
}

/// Satellite: replaying the same stream twice yields identical epoch
/// summaries (single-threaded detection is fully deterministic).
#[test]
fn replaying_the_same_stream_twice_is_identical() {
    let g0 = generate(GraphFamily::Web, 10, 19);
    let tl = churn_timeline(&g0, 6, FRAC, 19);

    let replay = || {
        let (svc, cells) = replay_service(&g0, &tl, cfg(SeedStrategy::DeltaScreening));
        let snap = svc.snapshot();
        let memb = snap.membership().to_vec();
        (cells, memb, svc.metrics().initial_modularity)
    };
    let (cells_a, memb_a, q0_a) = replay();
    let (cells_b, memb_b, q0_b) = replay();
    assert_eq!(q0_a.to_bits(), q0_b.to_bits());
    assert_eq!(cells_a.len(), cells_b.len());
    for (a, b) in cells_a.iter().zip(&cells_b) {
        assert_eq!(a.epoch, b.epoch);
        assert_eq!(a.stats.batch_ops, b.stats.batch_ops);
        assert_eq!(a.vertices, b.vertices);
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.num_communities(), b.num_communities());
        assert_eq!(a.stats.affected_seeded, b.stats.affected_seeded);
        assert_eq!(a.membership(), b.membership(), "epoch {}", a.epoch);
        assert_eq!(a.modularity.to_bits(), b.modularity.to_bits(), "epoch {}", a.epoch);
    }
    assert_eq!(memb_a, memb_b);
    let (sa, sb) = (summarize_service(&cells_a, q0_a), summarize_service(&cells_b, q0_b));
    assert_eq!(sa.epochs, sb.epochs);
    assert_eq!(sa.total_ops, sb.total_ops);
    assert_eq!(sa.final_modularity.to_bits(), sb.final_modularity.to_bits());
}

/// A file-backed `.ups` stream with explicit commits replays to exactly
/// the same epochs as the in-memory batch path.
#[test]
fn file_backed_stream_matches_in_memory_batches() {
    let g0 = generate(GraphFamily::Web, 9, 3);
    let tl = churn_timeline(&g0, 5, 0.02, 3);
    // Ops/commit-only flushing: the wall-clock trigger must not cut
    // batches differently between the two replays.
    let det_cfg = || ServiceConfig {
        policy: BatchPolicy::by_ops(1 << 20),
        ..cfg(SeedStrategy::DeltaScreening)
    };

    // In-memory reference.
    let (_, ref_cells) = replay_service(&g0, &tl, det_cfg());

    // The same batches as a stream file with commit boundaries.
    let ops: Vec<StreamOp> = tl
        .batches
        .iter()
        .flat_map(|b| b.to_ops().chain(std::iter::once(StreamOp::Commit)))
        .collect();
    let dir = std::env::temp_dir().join("gve_service_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream_match.ups");
    write_update_stream(&ops, &path).unwrap();

    let mut svc = CommunityService::new(g0.clone(), det_cfg());
    let epochs = svc.ingest_stream(UpdateStreamReader::open(&path).unwrap()).unwrap();
    assert_eq!(epochs, tl.batches.len());
    assert_eq!(svc.graph(), tl.graphs.last().unwrap());
    let snap = svc.snapshot();
    let reference = ref_cells.last().unwrap();
    assert_eq!(snap.epoch, reference.epoch);
    assert_eq!(snap.num_communities(), reference.num_communities());
    assert_eq!(snap.membership(), reference.membership());
    assert_eq!(
        snap.modularity.to_bits(),
        reference.modularity.to_bits(),
        "file-backed replay diverged from in-memory batches"
    );
}

/// Streaming ops that reference unseen vertex ids grow the service's
/// graph and keep the warm incremental path (no cold fallback).
#[test]
fn stream_growth_serves_new_vertices_warm() {
    let g0 = generate(GraphFamily::Road, 9, 11);
    let n = g0.num_vertices();
    let mut svc = CommunityService::new(
        g0,
        ServiceConfig { policy: BatchPolicy::by_ops(64), ..cfg(SeedStrategy::DeltaScreening) },
    );
    // A chain of brand-new vertices hanging off vertex 0, then a commit.
    let mut ops: Vec<StreamOp> = Vec::new();
    ops.push(StreamOp::Insert(0, n as u32, 1.0));
    for k in 0..10u32 {
        ops.push(StreamOp::Insert(n as u32 + k, n as u32 + k + 1, 1.0));
    }
    ops.push(StreamOp::Commit);
    let epochs = svc.ingest_ops(ops);
    assert_eq!(epochs, 1);
    let snap = svc.snapshot();
    snap.validate().unwrap();
    assert_eq!(snap.vertices, n + 11);
    assert!(snap.community_of(n + 10).is_some());
    // Warm: the seed covers a neighbourhood, not the whole graph.
    assert!(
        snap.stats.affected_seeded < n / 2,
        "growth epoch fell back to a cold seed ({} of {})",
        snap.stats.affected_seeded,
        snap.vertices
    );
    assert_eq!(svc.metrics().ops_ingested, 11);
}

/// Service-level spawn accounting: one persistent team for the whole
/// lifetime — boot, every batch, and the snapshot stats all reuse it
/// (the team itself is process-wide shared; sharing is unit-tested in
/// `parallel::team` / `louvain::workspace`).
#[test]
fn service_runs_spawn_o1_workers() {
    let g0 = generate(GraphFamily::Social, 9, 13);
    let tl = churn_timeline(&g0, 3, FRAC, 13);
    let cfg4 = ServiceConfig {
        params: LouvainParams::with_threads(4),
        ..cfg(SeedStrategy::DeltaScreening)
    };
    let (svc_a, cells_a) = replay_service(&g0, &tl, cfg4.clone());
    let (svc_b, _) = replay_service(&g0, &tl, cfg4);
    assert_eq!(cells_a.len(), 3);
    assert_eq!(svc_a.spawned_workers(), 3, "threads - 1, once, across the whole replay");
    assert_eq!(svc_b.spawned_workers(), 3);
}
