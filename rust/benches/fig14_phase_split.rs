//! Fig 14: GVE-Louvain phase split (local-moving / aggregation / other)
//! and pass split (first pass vs rest) per graph.
//!
//! Paper averages: 49% move / 35% aggregate / 16% other; 67% of runtime
//! in the first pass; road/k-mer graphs spend more in later passes.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::mean;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let mut t = Table::new(
        "Fig 14: GVE-Louvain phase and pass split",
        &["graph", "family", "move%", "agg%", "other%", "pass1%", "passes"],
    );
    let (mut mvs, mut ags, mut others, mut firsts) = (vec![], vec![], vec![], vec![]);
    for entry in &SUITE {
        let g = entry.graph(offset, seed);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        let (mv, ag, other) = out.phase_split();
        let first = out.first_pass_fraction();
        t.row(vec![
            entry.name.into(),
            entry.family.name().into(),
            format!("{:.0}", mv * 100.0),
            format!("{:.0}", ag * 100.0),
            format!("{:.0}", other * 100.0),
            format!("{:.0}", first * 100.0),
            format!("{}", out.passes),
        ]);
        mvs.push(mv);
        ags.push(ag);
        others.push(other);
        firsts.push(first);
    }
    print!("{}", t.render());
    println!(
        "\naverages: {:.0}% move / {:.0}% aggregate / {:.0}% other; {:.0}% in pass 1",
        mean(&mvs) * 100.0,
        mean(&ags) * 100.0,
        mean(&others) * 100.0,
        mean(&firsts) * 100.0
    );
    println!("(paper: 49% / 35% / 16%; 67% in the first pass)");
}
