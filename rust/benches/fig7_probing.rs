//! Fig 7: collision-resolution strategies for the per-vertex hashtables
//! (linear / quadratic / double / quadratic-double).
//!
//! Paper: quadratic-double wins — 1.05×, 1.32×, 1.12× over linear,
//! quadratic and double respectively. The probe counts feed the device
//! cost model, so the estimated runtime ranks strategies.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::geomean;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::gpusim::{NuLouvain, NuParams, ProbeStrategy};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let graphs: Vec<_> = suite::SUITE.iter().map(|e| e.graph(offset, seed)).collect();

    let mut t = Table::new(
        "Fig 7: probe strategy sweep (rel est. GPU runtime)",
        &["strategy", "rel runtime", "table ops", "modularity"],
    );
    let mut rows = Vec::new();
    for s in [
        ProbeStrategy::QuadraticDouble,
        ProbeStrategy::Linear,
        ProbeStrategy::Quadratic,
        ProbeStrategy::Double,
    ] {
        let mut times = Vec::new();
        let mut ops = 0u64;
        let mut qsum = 0.0;
        for g in &graphs {
            let out = NuLouvain::new(NuParams { probe: s, ..Default::default() }).run(g);
            times.push(out.est_gpu_ns as f64);
            ops += out.counters.table_ops;
            qsum += out.modularity;
        }
        rows.push((s.name(), geomean(&times), ops, qsum / graphs.len() as f64));
    }
    let base = rows[0].1;
    for (name, time, ops, q) in rows {
        t.row(vec![
            name.into(),
            format!("{:.3}", time / base),
            format!("{ops}"),
            format!("{q:.4}"),
        ]);
    }
    print!("{}", t.render());
    println!("\nPaper shape: quadratic-double fastest (1.0); quadratic worst");
    println!("(cannot traverse 2^k-1 moduli from one slot), linear/double between.");
}
