//! Fig 11 a/b/c: GVE-Louvain vs Vite, Grappolo, NetworKit, cuGraph —
//! runtime, speedup and modularity per suite graph.

use gve_louvain::baselines::System;
use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::fmt_ns;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::{compare_on_entry, mean_speedup, ComparisonCell};
use gve_louvain::coordinator::suite::SUITE;

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let systems = [
        System::GveLouvain,
        System::Vite,
        System::Grappolo,
        System::NetworKit,
        System::CuGraph,
    ];
    let mut cells: Vec<ComparisonCell> = Vec::new();
    let mut t = Table::new(
        "Fig 11a/c: runtime (modeled) and modularity per graph",
        &["graph", "gve", "vite", "grappolo", "networkit", "cugraph", "Q(gve)", "Q(best other)"],
    );
    for entry in &SUITE {
        let row_cells = compare_on_entry(entry, offset, &systems, 1, 1, seed);
        let get = |s: System| {
            row_cells
                .iter()
                .find(|c| c.system == s)
                .and_then(|c| c.modeled_ns)
                .map(|x| fmt_ns(x as u64))
                .unwrap_or_else(|| "OOM".into())
        };
        let q_gve = row_cells.iter().find(|c| c.system == System::GveLouvain).unwrap().modularity;
        let q_other = row_cells
            .iter()
            .filter(|c| c.system != System::GveLouvain)
            .map(|c| c.modularity)
            .fold(f64::MIN, f64::max);
        t.row(vec![
            entry.name.into(),
            get(System::GveLouvain),
            get(System::Vite),
            get(System::Grappolo),
            get(System::NetworKit),
            get(System::CuGraph),
            format!("{q_gve:.4}"),
            format!("{q_other:.4}"),
        ]);
        cells.extend(row_cells);
    }
    print!("{}", t.render());

    println!("\nFig 11b: mean speedup of GVE-Louvain:");
    for (s, paper) in [
        (System::Vite, "50x"),
        (System::Grappolo, "22x"),
        (System::NetworKit, "20x"),
        (System::CuGraph, "3.2x"),
    ] {
        match mean_speedup(&cells, System::GveLouvain, s) {
            Some(x) => println!("  vs {:<10} {x:>7.1}x  (paper: {paper})", s.name()),
            None => println!("  vs {:<10}      —  (OOM everywhere)", s.name()),
        }
    }
    println!("\nPaper shape (11c): GVE ≈ Grappolo/NetworKit quality (−0.6%),");
    println!("clearly above Vite on web graphs; cuGraph fails on the five");
    println!("largest web graphs (OOM).");
}
