//! Table 1: GVE-Louvain's speedup over the five comparison systems.
//!
//! Modeled times (CPU: 32-core projection; GPU: A100 device model) are
//! geometric-mean-aggregated across the suite, matching the paper's
//! aggregation. Absolute factors are shape targets (DESIGN.md §2).

use gve_louvain::baselines::System;
use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::{compare_on_entry, mean_speedup, ComparisonCell};
use gve_louvain::coordinator::suite::SUITE;

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let systems = [
        System::GveLouvain,
        System::Vite,
        System::Grappolo,
        System::NetworKit,
        System::Nido,
        System::CuGraph,
        System::NuLouvain,
    ];
    let mut cells: Vec<ComparisonCell> = Vec::new();
    for entry in &SUITE {
        cells.extend(compare_on_entry(entry, offset, &systems, 1, 1, seed));
    }
    let mut t = Table::new(
        "Table 1: speedup of GVE-Louvain vs other implementations",
        &["Louvain implementation", "Parallelism", "Our speedup", "Paper"],
    );
    for (sys, par, paper) in [
        (System::Vite, "Multi node (1 node)", "50x"),
        (System::Grappolo, "Multicore", "22x"),
        (System::NetworKit, "Multicore", "20x"),
        (System::Nido, "Multi GPU (1 GPU)", "56x"),
        (System::CuGraph, "Multi GPU (1 GPU)", "5.8x"),
        (System::NuLouvain, "GPU (ours)", "~1x"),
    ] {
        let s = mean_speedup(&cells, System::GveLouvain, sys)
            .map(|x| format!("{x:.1}x"))
            .unwrap_or_else(|| "OOM".into());
        t.row(vec![sys.name().into(), par.into(), s, paper.into()]);
    }
    print!("{}", t.render());
    println!("\nShape targets: Vite slowest CPU system by a large factor; Nido the");
    println!("slowest GPU system; cuGraph the closest competitor; ν ≈ parity.");
}
