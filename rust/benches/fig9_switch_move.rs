//! Fig 9: thread- vs block-per-vertex switch degree for the
//! local-moving phase, swept 1..1024 (paper optimum: 64).
//!
//! Low switch: low-degree vertices waste whole blocks (launch +
//! occupancy overhead). High switch: high-degree vertices serialize on
//! single lanes and stretch warp divergence. The device model exposes
//! both ends.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::geomean;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::gpusim::{NuLouvain, NuParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let graphs: Vec<_> = suite::quick().iter().map(|e| e.graph(offset, seed)).collect();

    let mut t = Table::new(
        "Fig 9: local-moving switch degree sweep (rel est. move-phase time)",
        &["switch degree", "rel move time"],
    );
    let mut rows = Vec::new();
    for sw in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024] {
        let mut times = Vec::new();
        for g in &graphs {
            let out = NuLouvain::new(NuParams { switch_move: sw, ..Default::default() }).run(g);
            let move_ns: u64 = out.pass_stats.iter().map(|p| p.move_est_ns).sum();
            times.push(move_ns as f64);
        }
        rows.push((sw, geomean(&times)));
    }
    let base = rows.iter().find(|(sw, _)| *sw == 64).unwrap().1;
    for (sw, time) in rows {
        t.row(vec![format!("{sw}"), format!("{:.3}", time / base)]);
    }
    print!("{}", t.render());
    println!("\nPaper shape: a valley around 64; both extremes slower.");
}
