//! Fig 15: runtime / |E| factor per graph.
//!
//! Paper: low-average-degree families (road, k-mer) and poorly
//! clustered social networks show a higher runtime/|E| ratio.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let mut t = Table::new(
        "Fig 15: GVE-Louvain runtime/|E| factor (ns per edge slot)",
        &["graph", "family", "D_avg", "time/|E| (ns)", "rel to web-min"],
    );
    let mut rows = Vec::new();
    for entry in &SUITE {
        let g = entry.graph(offset, seed);
        // Median of 3 runs.
        let mut times: Vec<u64> = (0..3)
            .map(|_| GveLouvain::new(LouvainParams::default()).run(&g).total_ns)
            .collect();
        times.sort_unstable();
        let per_edge = times[1] as f64 / g.num_edges() as f64;
        rows.push((entry, g.num_edges() as f64 / g.num_vertices() as f64, per_edge));
    }
    let web_min = rows
        .iter()
        .filter(|(e, _, _)| e.family.name() == "web")
        .map(|&(_, _, p)| p)
        .fold(f64::MAX, f64::min);
    for (entry, avg_deg, per_edge) in rows {
        t.row(vec![
            entry.name.into(),
            entry.family.name().into(),
            format!("{avg_deg:.1}"),
            format!("{per_edge:.1}"),
            format!("{:.2}", per_edge / web_min),
        ]);
    }
    print!("{}", t.render());
    println!("\nPaper shape: road/kmer (D_avg ≈ 2) and social graphs cost more");
    println!("per edge than dense, well-clustered web graphs.");
}
