//! Fig 10: thread- vs block-per-vertex switch degree for the
//! aggregation phase, swept 1..1024 (paper optimum: 128).

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::geomean;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::gpusim::{NuLouvain, NuParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let graphs: Vec<_> = suite::quick().iter().map(|e| e.graph(offset, seed)).collect();

    let mut t = Table::new(
        "Fig 10: aggregation switch degree sweep (rel est. agg-phase time)",
        &["switch degree", "rel agg time"],
    );
    let mut rows = Vec::new();
    for sw in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024] {
        let mut times = Vec::new();
        for g in &graphs {
            let out = NuLouvain::new(NuParams { switch_agg: sw, ..Default::default() }).run(g);
            let agg_ns: u64 = out.pass_stats.iter().map(|p| p.agg_est_ns).sum();
            times.push((agg_ns.max(1)) as f64);
        }
        rows.push((sw, geomean(&times)));
    }
    let base = rows.iter().find(|(sw, _)| *sw == 128).unwrap().1;
    for (sw, time) in rows {
        t.row(vec![format!("{sw}"), format!("{:.3}", time / base)]);
    }
    print!("{}", t.render());
    println!("\nPaper shape: a valley around 128 (community total degrees are");
    println!("larger than vertex degrees, so the optimum sits above Fig 9's 64).");
}
