//! Fig 2: impact of every §4.1 optimization on runtime and modularity.
//!
//! Each category compares the adopted choice against its alternatives:
//! relative runtime (geomean over the quick suite) and relative
//! modularity (arithmetic mean) — the paper's aggregation.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::{geomean, mean};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::graph::Csr;
use gve_louvain::louvain::params::{AggregationKind, TableKind};
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};
use gve_louvain::parallel::schedule::Schedule;

fn run_variant(graphs: &[Csr], params: &LouvainParams) -> (f64, f64) {
    let mut times = Vec::new();
    let mut qs = Vec::new();
    for g in graphs {
        let t0 = std::time::Instant::now();
        let out = GveLouvain::new(params.clone()).run(g);
        times.push(t0.elapsed().as_nanos() as f64);
        qs.push(out.modularity);
    }
    (geomean(&times), mean(&qs))
}

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let graphs: Vec<Csr> = suite::quick().iter().map(|e| e.graph(offset, seed)).collect();
    let base = LouvainParams::default();

    let categories: Vec<(&str, Vec<(&str, LouvainParams)>)> = vec![
        (
            "Fig 2a: OpenMP loop schedule (adopted: dynamic)",
            vec![
                ("dynamic", base.clone()),
                ("static", LouvainParams { schedule: Schedule::Static, ..base.clone() }),
                ("guided", LouvainParams { schedule: Schedule::Guided, ..base.clone() }),
                ("auto", LouvainParams { schedule: Schedule::Auto, ..base.clone() }),
            ],
        ),
        (
            "Fig 2b: iteration cap (adopted: 20; paper: 13% faster than 100)",
            vec![
                ("limit-20", base.clone()),
                ("limit-100", LouvainParams { max_iterations: 100, ..base.clone() }),
            ],
        ),
        (
            "Fig 2c: tolerance drop rate (adopted: 10; paper: 4% faster than 1)",
            vec![
                ("drop-10", base.clone()),
                ("drop-1 (no scaling)", LouvainParams { tolerance_drop: 1.0, ..base.clone() }),
            ],
        ),
        (
            "Fig 2d: initial tolerance (adopted: 0.01; paper: 14% faster than 1e-6)",
            vec![
                ("tol-0.01", base.clone()),
                ("tol-1e-6", LouvainParams { tolerance: 1e-6, ..base.clone() }),
            ],
        ),
        (
            "Fig 2e: aggregation tolerance (adopted: 0.8; paper: 14% faster than 1)",
            vec![
                ("tau_agg-0.8", base.clone()),
                ("tau_agg-1 (off)", LouvainParams { aggregation_tolerance: 1.0, ..base.clone() }),
            ],
        ),
        (
            "Fig 2f: vertex pruning (adopted: on; paper: 11% faster)",
            vec![
                ("pruning-on", base.clone()),
                ("pruning-off", LouvainParams { pruning: false, ..base.clone() }),
            ],
        ),
        (
            "Fig 2g/h: aggregation structure (adopted: CSR; paper: 2.2x over 2D)",
            vec![
                ("prefix-sum CSR", base.clone()),
                ("2D arrays", LouvainParams { aggregation: AggregationKind::TwoDim, ..base.clone() }),
            ],
        ),
        (
            "Fig 2i: hashtable (adopted: Far-KV; paper: 4.4x Map, 1.3x Close-KV)",
            vec![
                ("far-kv", base.clone()),
                ("close-kv", LouvainParams { table: TableKind::CloseKv, ..base.clone() }),
                ("map", LouvainParams { table: TableKind::Map, ..base.clone() }),
            ],
        ),
    ];

    for (title, variants) in categories {
        let mut t = Table::new(title, &["variant", "rel runtime", "rel modularity"]);
        let mut baseline: Option<(f64, f64)> = None;
        let _ = run_variant(&graphs, &variants[0].1); // warm
        for (name, params) in &variants {
            let (time, q) = run_variant(&graphs, params);
            let (bt, bq) = *baseline.get_or_insert((time, q));
            t.row(vec![
                (*name).into(),
                format!("{:.3}", time / bt),
                format!("{:.4}", q / bq),
            ]);
        }
        print!("{}", t.render());
        println!();
    }
}
