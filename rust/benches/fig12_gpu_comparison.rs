//! Fig 12 a/b/c: ν-Louvain vs Grappolo, NetworKit, Nido, cuGraph —
//! runtime, speedup and modularity per suite graph.

use gve_louvain::baselines::System;
use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::fmt_ns;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::{compare_on_entry, mean_speedup, ComparisonCell};
use gve_louvain::coordinator::suite::SUITE;

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let systems = [
        System::NuLouvain,
        System::Grappolo,
        System::NetworKit,
        System::Nido,
        System::CuGraph,
    ];
    let mut cells: Vec<ComparisonCell> = Vec::new();
    let mut t = Table::new(
        "Fig 12a/c: runtime (modeled) and modularity per graph",
        &["graph", "nu", "grappolo", "networkit", "nido", "cugraph", "Q(nu)", "Q(nido)"],
    );
    for entry in &SUITE {
        let row_cells = compare_on_entry(entry, offset, &systems, 1, 1, seed);
        let get = |s: System| {
            row_cells
                .iter()
                .find(|c| c.system == s)
                .and_then(|c| c.modeled_ns)
                .map(|x| fmt_ns(x as u64))
                .unwrap_or_else(|| "OOM".into())
        };
        let q = |s: System| row_cells.iter().find(|c| c.system == s).unwrap().modularity;
        t.row(vec![
            entry.name.into(),
            get(System::NuLouvain),
            get(System::Grappolo),
            get(System::NetworKit),
            get(System::Nido),
            get(System::CuGraph),
            format!("{:.4}", q(System::NuLouvain)),
            format!("{:.4}", q(System::Nido)),
        ]);
        cells.extend(row_cells);
    }
    print!("{}", t.render());

    println!("\nFig 12b: mean speedup of ν-Louvain:");
    for (s, paper) in [
        (System::Grappolo, "20x"),
        (System::NetworKit, "17x"),
        (System::Nido, "61x"),
        (System::CuGraph, "5.0x"),
    ] {
        match mean_speedup(&cells, System::NuLouvain, s) {
            Some(x) => println!("  vs {:<10} {x:>7.1}x  (paper: {paper})", s.name()),
            None => println!("  vs {:<10}      —  (OOM everywhere)", s.name()),
        }
    }
    println!("\nPaper shape (12c): ν-Louvain ~1% below the CPU systems' quality");
    println!("but ~45% above Nido; ν OOMs only on sk-2005.");
}
