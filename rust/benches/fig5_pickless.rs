//! Fig 5: Pick-Less swap mitigation every ρ ∈ {2, 4, 8, 16} iterations.
//!
//! Paper: PL4 yields the highest modularity while being 1.25× faster
//! than PL16. ρ=0 (PL disabled) is included to show the swap cost.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::{geomean, mean};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::gpusim::{NuLouvain, NuParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let graphs: Vec<_> = suite::SUITE.iter().map(|e| e.graph(offset, seed)).collect();

    let mut t = Table::new(
        "Fig 5: Pick-Less period sweep (rel est. GPU runtime / rel modularity)",
        &["variant", "rel runtime", "rel modularity", "iters total"],
    );
    let mut base: Option<(f64, f64)> = None;
    for rho in [2usize, 4, 8, 16, 0] {
        let mut times = Vec::new();
        let mut qs = Vec::new();
        let mut iters = 0usize;
        for g in &graphs {
            let out = NuLouvain::new(NuParams { rho, ..Default::default() }).run(g);
            times.push(out.est_gpu_ns as f64);
            qs.push(out.modularity);
            iters += out.pass_stats.iter().map(|p| p.iterations).sum::<usize>();
        }
        let (time, q) = (geomean(&times), mean(&qs));
        let (bt, bq) = *base.get_or_insert((time, q));
        let name = if rho == 0 { "PL-off".to_string() } else { format!("PL{rho}") };
        t.row(vec![
            name,
            format!("{:.3}", time / bt),
            format!("{:.4}", q / bq),
            format!("{iters}"),
        ]);
    }
    print!("{}", t.render());
    println!("\nPaper shape: PL4 best modularity, ~1.25x faster than PL16;");
    println!("disabling PL costs extra iterations (swap cycles) or quality.");
}
