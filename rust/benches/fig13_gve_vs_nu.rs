//! Fig 13 a/b/c: ν-Louvain vs GVE-Louvain — the paper's headline.
//!
//! Paper: ν achieves only ~1.03× average speedup over GVE (and is
//! *faster on road networks*), with 0.5% lower modularity; sk-2005
//! OOMs. The occupancy column shows why: later passes starve the GPU.

use gve_louvain::baselines::System;
use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::{fmt_ns, geomean};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::compare_on_entry;
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::gpusim::{NuLouvain, NuParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let mut t = Table::new(
        "Fig 13: GVE-Louvain vs ν-Louvain per graph",
        &["graph", "family", "gve (modeled)", "nu (modeled)", "nu/gve speedup", "Q(gve)", "Q(nu)", "nu last-pass occ"],
    );
    let mut ratios = Vec::new();
    let mut per_family: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    let mut qd = Vec::new();
    for entry in &SUITE {
        let cells = compare_on_entry(entry, offset, &[System::GveLouvain, System::NuLouvain], 1, 1, seed);
        let gve = cells.iter().find(|c| c.system == System::GveLouvain).unwrap();
        let nu = cells.iter().find(|c| c.system == System::NuLouvain).unwrap();
        let speedup = match (gve.modeled_ns, nu.modeled_ns) {
            (Some(a), Some(b)) if b > 0.0 => {
                let r = a / b;
                ratios.push(r);
                per_family.entry(entry.family.name()).or_default().push(r);
                format!("{r:.2}x")
            }
            _ => "OOM".into(),
        };
        qd.push((gve.modularity - nu.modularity) / gve.modularity.max(1e-9));
        // Occupancy of the final pass from a direct simulator run.
        let occ = {
            let g = entry.graph(offset, seed);
            let out = NuLouvain::new(NuParams::default()).run(&g);
            out.pass_stats.last().map(|p| p.occupancy).unwrap_or(0.0)
        };
        t.row(vec![
            entry.name.into(),
            entry.family.name().into(),
            gve.modeled_ns.map(|x| fmt_ns(x as u64)).unwrap_or_else(|| "OOM".into()),
            nu.modeled_ns.map(|x| fmt_ns(x as u64)).unwrap_or_else(|| "OOM".into()),
            speedup,
            format!("{:.4}", gve.modularity),
            format!("{:.4}", nu.modularity),
            format!("{:.3}", occ),
        ]);
    }
    print!("{}", t.render());
    println!("\nFig 13b summary:");
    println!("  geomean nu/gve speedup: {:.2}x (paper: 1.03x)", geomean(&ratios));
    for (fam, rs) in &per_family {
        println!("    {fam:<7}: {:.2}x", geomean(rs));
    }
    println!(
        "  mean modularity gap (gve - nu)/gve: {:.2}% (paper: 0.5%)",
        100.0 * qd.iter().sum::<f64>() / qd.len() as f64
    );
    println!("\nPaper shapes: parity on average, ν best on road networks,");
    println!("ν OOM on sk-2005, occupancy collapse in late passes.");
}
