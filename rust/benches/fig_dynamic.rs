//! fig_dynamic: dynamic-Louvain seeding strategies over a churn
//! timeline (PR 2; the arXiv:2301.12390 protocol on the planted
//! families).
//!
//! One representative graph per family, a 10-batch timeline mutating
//! ~1% of the edges per batch, replayed per [`SeedStrategy`].  Reported
//! per strategy: median per-batch wall time, speedup over full
//! recompute, final modularity and the mean seeded-affected fraction —
//! delta screening should win runtime at equal quality everywhere
//! except the weak-community social family, where perturbations
//! propagate further.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::dynamic::{churn_timeline, replay_timeline, summarize};
use gve_louvain::coordinator::metrics::fmt_ns;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::louvain::dynamic::SeedStrategy;
use gve_louvain::louvain::LouvainParams;

const BATCHES: usize = 10;
const FRAC: f64 = 0.01;

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let mut t = Table::new(
        "fig_dynamic: per-batch runtime vs full recompute (10 batches, 1% churn)",
        &["graph", "strategy", "median/batch", "speedup", "final Q", "affected%"],
    );
    for entry in suite::quick() {
        let g0 = entry.graph(offset, seed);
        let n = g0.num_vertices() as f64;
        let tl = churn_timeline(&g0, BATCHES, FRAC, seed);
        let cells = replay_timeline(&g0, &tl, &SeedStrategy::ALL, &LouvainParams::default());
        let summaries = summarize(&cells);
        let full_median = summaries
            .iter()
            .find(|s| s.strategy == SeedStrategy::FullRecompute)
            .map(|s| s.median_wall_ns)
            .unwrap_or(1);
        for s in &summaries {
            t.row(vec![
                entry.name.into(),
                s.strategy.name().into(),
                fmt_ns(s.median_wall_ns),
                format!("{:.2}x", full_median as f64 / s.median_wall_ns.max(1) as f64),
                format!("{:.4}", s.final_modularity),
                format!("{:.0}", s.mean_affected / n * 100.0),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\n(expected: delta-screening > naive-dynamic > full on runtime, Q within 0.01)");
}
