//! Fig 8: 32-bit vs 64-bit hashtable values.
//!
//! Paper: f32 maintains community quality with a moderate speedup
//! (halved value-buffer traffic). K, Σ and all other computation stay
//! f64 (§5.1.2) in both variants.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::{geomean, mean};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::gpusim::{NuLouvain, NuParams, ValueKind};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let graphs: Vec<_> = suite::SUITE.iter().map(|e| e.graph(offset, seed)).collect();

    let mut t = Table::new(
        "Fig 8: hashtable value precision (rel est. GPU runtime / rel modularity)",
        &["values", "rel runtime", "rel modularity"],
    );
    let mut base: Option<(f64, f64)> = None;
    for kind in [ValueKind::F32, ValueKind::F64] {
        let mut times = Vec::new();
        let mut qs = Vec::new();
        for g in &graphs {
            // f64 doubles the value-buffer bytes: reflect in the device
            // traffic by scaling measured bytes (values are half the
            // table traffic).
            let out = NuLouvain::new(NuParams { values: kind, ..Default::default() }).run(g);
            let factor = match kind {
                ValueKind::F32 => 1.0,
                ValueKind::F64 => 1.18, // value half of table traffic doubles
            };
            times.push(out.est_gpu_ns as f64 * factor);
            qs.push(out.modularity);
        }
        let (time, q) = (geomean(&times), mean(&qs));
        let (bt, bq) = *base.get_or_insert((time, q));
        t.row(vec![
            kind.name().into(),
            format!("{:.3}", time / bt),
            format!("{:.4}", q / bq),
        ]);
    }
    print!("{}", t.render());
    println!("\nPaper shape: Float ≈ Double quality, moderately faster.");
}
