//! Fig 16: strong scaling of GVE-Louvain, 1..64 threads, overall and
//! per phase.
//!
//! This host has ONE physical core, so multi-thread wall-clock would
//! only measure contention. Instead per-chunk work is recorded once
//! (`record_chunks`) and replayed through the schedule semantics onto a
//! modeled dual-Xeon (list scheduling + Amdahl + SMT derating past 32
//! cores) — DESIGN.md §2 documents the substitution. Paper: 10.4× at
//! 32 threads (≈1.6×/doubling), 11.4× at 64 (SMT/NUMA limited).

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::geomean;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite;
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};
use gve_louvain::parallel::replay::{modeled_runtime_ns, MachineModel};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let model = MachineModel::default();
    let graphs: Vec<_> = suite::quick().iter().map(|e| e.graph(offset, seed)).collect();

    // Record per-chunk work once per graph (single-threaded). The chunk
    // size is scaled down with the graphs: the paper's 2048 assumes
    // multi-million-vertex inputs; at bench scale it would leave a
    // single chunk per loop and nothing to schedule.
    let mut recordings = Vec::new();
    for g in &graphs {
        let chunk = (g.num_vertices() / 128).clamp(16, 2048);
        let params = LouvainParams { record_chunks: true, chunk, ..Default::default() };
        let out = GveLouvain::new(params).run(g);
        recordings.push((out.loops, out.serial_ns));
    }

    let mut t = Table::new(
        "Fig 16: strong scaling (replayed onto the dual-Xeon model)",
        &["threads", "speedup", "per-doubling", "paper"],
    );
    let t1: Vec<f64> = recordings
        .iter()
        .map(|(loops, serial)| modeled_runtime_ns(loops, *serial, 1, &model) as f64)
        .collect();
    let mut prev_speedup = 1.0;
    for threads in [1usize, 2, 4, 8, 16, 32, 64] {
        let tt: Vec<f64> = recordings
            .iter()
            .map(|(loops, serial)| modeled_runtime_ns(loops, *serial, threads, &model) as f64)
            .collect();
        let speedups: Vec<f64> = t1.iter().zip(&tt).map(|(a, b)| a / b).collect();
        let s = geomean(&speedups);
        let doubling = if threads == 1 { 1.0 } else { s / prev_speedup };
        prev_speedup = s;
        let paper = match threads {
            32 => "10.4x",
            64 => "11.4x",
            _ => "~1.6x/doubling",
        };
        t.row(vec![
            format!("{threads}"),
            format!("{s:.1}x"),
            format!("{doubling:.2}x"),
            paper.into(),
        ]);
    }
    print!("{}", t.render());
    println!("\nShape: near-linear to 8-16 threads, bandwidth+serial-fraction");
    println!("limited to ~10x at 32, marginal SMT gain at 64.");
}
