//! Table 2: the dataset — |V|, |E|, D_avg, and |Γ| found by GVE-Louvain.
//!
//! Paper columns reproduced per suite graph at the bench scale
//! (`GVE_BENCH_SCALE` offsets the generated sizes; the paper-scale
//! |V|/|E| are shown alongside).

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::graph::properties::{human, GraphProperties};
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let mut t = Table::new(
        &format!("Table 2: dataset (offset {offset})"),
        &["graph", "family", "|V|", "|E|", "D_avg", "|Γ|", "paper |V|", "paper |E|", "paper |Γ|"],
    );
    // Paper's |Γ| column for reference.
    let paper_gamma = [
        "4.24K", "42.8K", "3.66K", "20.8K", "2.76M", "5.28K", "3.47K",
        "2.54K", "29", "2.38K", "3.05K", "21.2K", "6.17K",
    ];
    for (e, pg) in SUITE.iter().zip(paper_gamma) {
        let g = e.graph(offset, seed);
        let p = GraphProperties::of(&g);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        t.row(vec![
            e.name.into(),
            e.family.name().into(),
            human(p.num_vertices as f64),
            human(p.num_edges as f64),
            format!("{:.1}", p.avg_degree),
            human(out.num_communities as f64),
            human(e.paper_v as f64),
            human(e.paper_e as f64),
            pg.into(),
        ]);
    }
    print!("{}", t.render());
    println!("\nShape check: web/social dense (D_avg >> road/kmer ≈ 2); |Γ| per");
    println!("family tracks the paper's ordering (few for web, many for road).");
}
