//! Fig 17: ν-Louvain phase split and pass split per graph.
//!
//! Paper averages: 57% local-moving / 40% aggregation / 3% other;
//! 67% of the estimated device time in the first pass; later passes
//! dominate on road / k-mer graphs.

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::mean;
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::gpusim::{NuLouvain, NuParams};

fn main() {
    let offset = bench_scale_offset();
    let seed = bench_seed();
    let mut t = Table::new(
        "Fig 17: ν-Louvain phase and pass split (estimated device time)",
        &["graph", "family", "move%", "agg%", "other%", "pass1%", "passes", "occ(first→last)"],
    );
    let (mut mvs, mut ags, mut firsts) = (vec![], vec![], vec![]);
    for entry in &SUITE {
        let g = entry.graph(offset, seed);
        let out = NuLouvain::new(NuParams::default()).run(&g);
        let (mv, ag, other) = out.phase_split();
        let first = out.first_pass_fraction();
        let occ_first = out.pass_stats.first().map(|p| p.occupancy).unwrap_or(0.0);
        let occ_last = out.pass_stats.last().map(|p| p.occupancy).unwrap_or(0.0);
        t.row(vec![
            entry.name.into(),
            entry.family.name().into(),
            format!("{:.0}", mv * 100.0),
            format!("{:.0}", ag * 100.0),
            format!("{:.0}", other * 100.0),
            format!("{:.0}", first * 100.0),
            format!("{}", out.passes),
            format!("{occ_first:.3}→{occ_last:.3}"),
        ]);
        mvs.push(mv);
        ags.push(ag);
        firsts.push(first);
    }
    print!("{}", t.render());
    println!(
        "\naverages: {:.0}% move / {:.0}% aggregate; {:.0}% in pass 1",
        mean(&mvs) * 100.0,
        mean(&ags) * 100.0,
        mean(&firsts) * 100.0
    );
    println!("(paper: 57% / 40% / 3%; 67% in the first pass)");
}
