//! # gve-louvain
//!
//! A reproduction of *"CPU vs. GPU for Community Detection: Performance
//! Insights from GVE-Louvain and ν-Louvain"* (Sahu, CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains everything the paper's evaluation depends on,
//! built from scratch (see `DESIGN.md` for the inventory):
//!
//! * [`graph`] — weighted CSR / holey-CSR graph substrate, synthetic
//!   generators mirroring the paper's four dataset families, and IO.
//! * [`parallel`] — an OpenMP-like scheduling substrate: a persistent
//!   worker team (spawn-once, park between loops; the hot path) plus a
//!   scoped fork-join reference pool, static / dynamic / guided / auto
//!   chunk schedules, parallel scan, atomic f64, deterministic PRNGs,
//!   and a replay model used for the strong-scaling study on this
//!   single-core testbed.
//! * [`louvain`] — the paper's CPU contribution: **GVE-Louvain** with
//!   per-thread collision-free hashtables (std-map / Close-KV /
//!   Far-KV), vertex pruning, threshold scaling, aggregation tolerance
//!   and prefix-sum CSR aggregation.
//! * [`gpusim`] — a lock-step warp/SM GPU-semantics simulator hosting
//!   **ν-Louvain**: per-vertex open-addressing hashtables (four probe
//!   sequences), Pick-Less swap mitigation, thread- vs block-per-vertex
//!   kernels, and an A100-like cost model.
//! * [`baselines`] — algorithmic signatures of Vite, Grappolo,
//!   NetworKit PLM, cuGraph and Nido for the comparison tables.
//! * [`runtime`] — the PJRT side: loads the AOT-lowered Pallas
//!   community-scan tile executables (`artifacts/*.hlo.txt`) and runs
//!   ν-Louvain's local-moving hot-spot through real XLA.
//! * [`service`] — the long-lived community-detection service (PR 3):
//!   streaming ingest with batch coalescing, incremental re-detection
//!   over the dynamic subsystem, and an epoch-snapshot query surface —
//!   the north-star serving story.
//! * [`server`] — the network serving subsystem (PR 9): a length-
//!   prefixed binary wire protocol speaking the `.ups` op vocabulary,
//!   a single-writer ingest daemon (`louvain_server`) wrapping
//!   [`service`] behind a bounded op queue with timer-driven
//!   max-latency flushes, epoch-delta subscription streams, and the
//!   in-process client the loopback tests and bench drive.
//! * [`obs`] — live telemetry (PR 8): a process-wide lock-free metrics
//!   registry (sharded counters/gauges, log2 latency histograms) with
//!   Prometheus text + JSON renderers, byte-level memory accounting for
//!   the long-lived buffers, and a std-`TcpListener` HTTP introspection
//!   server (`louvain_serve --http-port N` → `/metrics`, `/healthz`,
//!   `/epochs`) — the always-on complement to [`trace`]'s attachable
//!   sessions.
//! * [`trace`] — per-pass span tracing (PR 7): always compiled,
//!   branch-disabled (one relaxed load per site when off), per-worker
//!   ring-buffer `TraceSink`s, Chrome trace-event JSON export
//!   (Perfetto-loadable) and derived per-pass utilization tables.
//!   Capture with `repro run ... --trace out.json` or
//!   `louvain_serve ... --trace out.json`, then open the file at
//!   <https://ui.perfetto.dev> — the CLI also prints a per-pass table
//!   with parallelism efficiency and small-path fraction.
//! * [`coordinator`] — CLI, config, experiment runner, metrics
//!   (phase/pass splits) and report generation.
//! * [`prop`] / [`bench`] — in-tree property-testing and benchmark
//!   harnesses (the offline registry has neither proptest nor
//!   criterion).
//!
//! ## Quickstart
//!
//! ```no_run
//! use gve_louvain::graph::generators::{GraphFamily, generate};
//! use gve_louvain::louvain::{gve::GveLouvain, params::LouvainParams};
//!
//! let g = generate(GraphFamily::Web, 14, 42); // 2^14 vertices
//! let out = GveLouvain::new(LouvainParams::default()).run(&g);
//! println!("Q = {:.4}, {} communities, {} passes",
//!          out.modularity, out.num_communities, out.passes);
//! ```

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod gpusim;
pub mod graph;
pub mod louvain;
pub mod obs;
pub mod parallel;
pub mod prop;
pub mod runtime;
pub mod server;
pub mod service;
pub mod trace;

/// Crate-wide vertex id type (paper: 32-bit vertex identifiers).
pub type VertexId = u32;
/// Crate-wide edge weight type (paper: 32-bit edge weights).
pub type EdgeWeight = f32;
