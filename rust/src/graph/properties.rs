//! Graph property summaries (the columns of Table 2).

use super::csr::Csr;

/// Summary statistics of a graph (Table 2 columns + degree spread).
#[derive(Clone, Debug)]
pub struct GraphProperties {
    pub num_vertices: usize,
    /// Directed edge slots ("after adding reverse edges").
    pub num_edges: usize,
    pub avg_degree: f64,
    pub max_degree: usize,
    pub total_weight: f64,
    pub self_loops: usize,
    pub isolated: usize,
}

impl GraphProperties {
    pub fn of(g: &Csr) -> Self {
        let n = g.num_vertices();
        let mut max_degree = 0usize;
        let mut self_loops = 0usize;
        let mut isolated = 0usize;
        for v in 0..n {
            let d = g.degree(v);
            max_degree = max_degree.max(d);
            if d == 0 {
                isolated += 1;
            }
            self_loops += g.edges(v).0.iter().filter(|&&t| t as usize == v).count();
        }
        Self {
            num_vertices: n,
            num_edges: g.num_edges(),
            avg_degree: if n == 0 { 0.0 } else { g.num_edges() as f64 / n as f64 },
            max_degree,
            total_weight: g.total_weight(),
            self_loops,
            isolated,
        }
    }

    /// One Table 2-style row: |V|, |E|, D_avg.
    pub fn table_row(&self, name: &str) -> String {
        format!(
            "{:<16} {:>9} {:>10} {:>7.1}",
            name,
            human(self.num_vertices as f64),
            human(self.num_edges as f64),
            self.avg_degree
        )
    }
}

/// Human-readable magnitude (paper style: 3.07M, 3.80B).
pub fn human(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}B", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}K", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn properties_of_triangle() {
        let g = GraphBuilder::new(4).edge(0, 1, 1.0).edge(1, 2, 1.0).edge(0, 2, 1.0).build_undirected();
        let p = GraphProperties::of(&g);
        assert_eq!(p.num_vertices, 4);
        assert_eq!(p.num_edges, 6);
        assert_eq!(p.max_degree, 2);
        assert_eq!(p.isolated, 1);
        assert_eq!(p.self_loops, 0);
        assert_eq!(p.total_weight, 3.0);
    }

    #[test]
    fn self_loops_counted() {
        let g = GraphBuilder::new(2).edge(0, 0, 1.0).edge(0, 1, 1.0).build_undirected();
        assert_eq!(GraphProperties::of(&g).self_loops, 1);
    }

    #[test]
    fn human_formatting() {
        assert_eq!(human(3.07e6), "3.07M");
        assert_eq!(human(3.8e9), "3.80B");
        assert_eq!(human(42.0), "42");
        assert_eq!(human(2500.0), "2.5K");
    }

    #[test]
    fn family_rows_render() {
        let g = generate(GraphFamily::Web, 8, 1);
        let row = GraphProperties::of(&g).table_row("web-s8");
        assert!(row.contains("web-s8"));
    }
}
