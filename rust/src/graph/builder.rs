//! Edge-list → CSR builders (dedupe, symmetrize, self-loop policy).

use super::csr::Csr;
use crate::{EdgeWeight, VertexId};

/// Accumulating edge-list builder.
///
/// Duplicate `(u, v)` pairs have their weights summed (the convention
/// the aggregation phase relies on); `build_undirected` mirrors each
/// edge, `build_directed` keeps slots as inserted.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId, EdgeWeight)>,
    keep_self_loops: bool,
}

impl GraphBuilder {
    pub fn new(n: usize) -> Self {
        Self { n, edges: Vec::new(), keep_self_loops: true }
    }

    pub fn drop_self_loops(mut self) -> Self {
        self.keep_self_loops = false;
        self
    }

    /// Add an edge (chainable).
    pub fn edge(mut self, u: VertexId, v: VertexId, w: EdgeWeight) -> Self {
        self.push(u, v, w);
        self
    }

    /// Add an edge (by reference).
    pub fn push(&mut self, u: VertexId, v: VertexId, w: EdgeWeight) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        self.edges.push((u, v, w));
    }

    pub fn num_pending(&self) -> usize {
        self.edges.len()
    }

    /// Build an undirected CSR: each `(u,v)` lands in both adjacency
    /// lists (a self-loop lands once), parallel edges merged.
    pub fn build_undirected(self) -> Csr {
        let mut dir: Vec<(VertexId, VertexId, EdgeWeight)> = Vec::with_capacity(self.edges.len() * 2);
        for (u, v, w) in &self.edges {
            if u == v {
                if self.keep_self_loops {
                    dir.push((*u, *v, *w));
                }
            } else {
                dir.push((*u, *v, *w));
                dir.push((*v, *u, *w));
            }
        }
        build_from_directed(self.n, dir)
    }

    /// Build a directed CSR from the slots as inserted (parallel edges
    /// merged).
    pub fn build_directed(self) -> Csr {
        let keep = self.keep_self_loops;
        let dir = self
            .edges
            .into_iter()
            .filter(|(u, v, _)| keep || u != v)
            .collect();
        build_from_directed(self.n, dir)
    }
}

/// Counting-sort directed slots into CSR, merging duplicate targets.
fn build_from_directed(n: usize, mut edges: Vec<(VertexId, VertexId, EdgeWeight)>) -> Csr {
    // Sort by (source, target) to merge duplicates and give deterministic
    // neighbour order (ascending target) — the tie-break contract shared
    // with the Pallas tile builders.
    edges.sort_unstable_by_key(|&(u, v, _)| ((u as u64) << 32) | v as u64);

    let mut offsets = vec![0usize; n + 1];
    let mut targets: Vec<VertexId> = Vec::with_capacity(edges.len());
    let mut weights: Vec<EdgeWeight> = Vec::with_capacity(edges.len());

    let mut i = 0usize;
    while i < edges.len() {
        let (u, v, mut w) = edges[i];
        let mut j = i + 1;
        while j < edges.len() && edges[j].0 == u && edges[j].1 == v {
            w += edges[j].2;
            j += 1;
        }
        offsets[u as usize + 1] += 1;
        targets.push(v);
        weights.push(w);
        i = j;
    }
    for v in 0..n {
        offsets[v + 1] += offsets[v];
    }
    Csr { offsets, targets, weights }
}

/// Symmetrize an arbitrary directed CSR (paper: "after adding reverse
/// edges" — LAW web graphs are directed and get mirrored).
///
/// Pattern symmetrization: each unordered pair `{u, v}` appears once in
/// the output with the *maximum* weight over its directed instances
/// (SuiteSparse-script semantics for the unit-weight repro graphs).
pub fn symmetrize(g: &Csr) -> Csr {
    let mut pairs: Vec<(VertexId, VertexId, EdgeWeight)> = Vec::with_capacity(g.num_edges());
    for v in 0..g.num_vertices() {
        for (t, w) in g.neighbours(v) {
            let (a, b) = if (t as usize) < v { (t, v as VertexId) } else { (v as VertexId, t) };
            pairs.push((a, b, w));
        }
    }
    pairs.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)).then(y.2.total_cmp(&x.2)));
    pairs.dedup_by_key(|p| (p.0, p.1)); // keeps first = max weight
    let mut b = GraphBuilder::new(g.num_vertices());
    for (u, v, w) in pairs {
        b.push(u, v, w);
    }
    b.build_undirected()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn undirected_mirrors_edges() {
        let g = GraphBuilder::new(3).edge(0, 1, 1.0).edge(1, 2, 2.0).build_undirected();
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.edges(1).0, &[0, 2]);
        assert!(g.is_symmetric());
    }

    #[test]
    fn duplicate_edges_merge_weights() {
        let g = GraphBuilder::new(2)
            .edge(0, 1, 1.0)
            .edge(0, 1, 2.0)
            .build_undirected();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges(0).1, &[3.0]);
    }

    #[test]
    fn self_loops_kept_once() {
        let g = GraphBuilder::new(2).edge(0, 0, 5.0).edge(0, 1, 1.0).build_undirected();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.vertex_weight(0), 6.0);
        // total weight: (5 + 1 + 1)/2 = 3.5
        assert_eq!(g.total_weight(), 3.5);
    }

    #[test]
    fn self_loops_dropped_when_asked() {
        let g = GraphBuilder::new(2).drop_self_loops().edge(0, 0, 5.0).edge(0, 1, 1.0).build_undirected();
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn neighbour_order_ascending() {
        let g = GraphBuilder::new(5)
            .edge(0, 4, 1.0)
            .edge(0, 2, 1.0)
            .edge(0, 3, 1.0)
            .build_undirected();
        assert_eq!(g.edges(0).0, &[2, 3, 4]);
    }

    #[test]
    fn directed_build_keeps_direction() {
        let g = GraphBuilder::new(3).edge(0, 1, 1.0).edge(2, 1, 1.0).build_directed();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.degree(1), 0);
        assert!(!g.is_symmetric());
    }

    #[test]
    fn symmetrize_directed_graph() {
        let d = GraphBuilder::new(3).edge(0, 1, 1.0).edge(1, 2, 1.0).edge(2, 0, 1.0).build_directed();
        let s = symmetrize(&d);
        s.validate().unwrap();
        assert!(s.is_symmetric());
        assert_eq!(s.num_edges(), 6);
        assert!(s.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0).build_undirected();
        g.validate().unwrap();
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn isolated_vertices_have_zero_degree() {
        let g = GraphBuilder::new(10).edge(0, 1, 1.0).build_undirected();
        for v in 2..10 {
            assert_eq!(g.degree(v), 0);
        }
    }
}
