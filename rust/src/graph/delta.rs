//! Batch edge updates for evolving graphs: [`EdgeBatch`] +
//! [`Csr::apply_batch`].
//!
//! The paper evaluates GVE-Louvain on frozen snapshots; the ROADMAP
//! north star is a service watching graphs that *change*.  This module
//! is the mutation half of the PR-2 dynamic subsystem (the seeding half
//! lives in [`louvain::dynamic`](crate::louvain::dynamic)): a batch of
//! undirected insertions and deletions is applied to a CSR in parallel,
//! producing the updated CSR without touching untouched rows'
//! *contents* (their slots are copied, not re-derived).
//!
//! ## Batch semantics
//!
//! * The vertex set is fixed: every endpoint must be `< |V|` (growing
//!   the graph is a separate concern — see ROADMAP).
//! * **Insertion** `(u, v, w)` adds `w` to the edge's weight, creating
//!   the edge if absent — the same duplicate-merge convention as
//!   [`GraphBuilder`](super::builder::GraphBuilder).  Both directions
//!   are updated (a self-loop lands once, builder-style).
//! * **Deletion** `(u, v)` removes the edge entirely (both directions);
//!   deleting an absent edge is a no-op.
//! * Within one batch, deletions apply *before* insertions on the same
//!   pair: delete + insert replaces the weight rather than accumulating
//!   into the old one.
//!
//! ## Pipeline (all on the team runtime via [`Exec`])
//!
//! 1. Mirror the batch into directed per-endpoint ops and sort by
//!    `(src, dst)` — serial, O(B log B) in the batch size only.
//! 2. Per-vertex op counts via the parallel
//!    [`scatter_count`](crate::parallel::scatter::scatter_count)
//!    helper, prefix-summed into op ranges.
//! 3. Per-vertex capacity upper bounds (`degree + ops`) → exclusive
//!    scan → a reused *holey* CSR, exactly the aggregation-phase
//!    machinery ([`AggScratch`](crate::louvain::aggregation::AggScratch)
//!    style: [`DeltaScratch`] keeps every buffer across batches).
//! 4. Chunked per-vertex sorted merge of the old row with its ops into
//!    the holey CSR (rows stay target-sorted, the crate-wide contract).
//! 5. [`HoleyCsr::compact_into`](super::csr::HoleyCsr::compact_into)
//!    squeezes out deletion holes into the output CSR.

use super::csr::{Csr, HoleyCsr};
use crate::parallel::pool::ParallelOpts;
use crate::parallel::scan::exclusive_scan_exec;
use crate::parallel::scatter::scatter_count;
use crate::parallel::team::Exec;
use crate::{EdgeWeight, VertexId};

/// A batch of undirected edge mutations against a fixed vertex set.
#[derive(Clone, Debug, Default)]
pub struct EdgeBatch {
    /// Undirected weight additions (edge created if absent).
    pub insertions: Vec<(VertexId, VertexId, EdgeWeight)>,
    /// Undirected removals (no-op if absent).
    pub deletions: Vec<(VertexId, VertexId)>,
}

impl EdgeBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue an undirected insertion / weight addition.
    pub fn insert(&mut self, u: VertexId, v: VertexId, w: EdgeWeight) {
        self.insertions.push((u, v, w));
    }

    /// Queue an undirected deletion.
    pub fn delete(&mut self, u: VertexId, v: VertexId) {
        self.deletions.push((u, v));
    }

    /// Total queued operations (undirected count).
    pub fn len(&self) -> usize {
        self.insertions.len() + self.deletions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insertions.is_empty() && self.deletions.is_empty()
    }
}

/// One directed mutation slot (internal: batches are mirrored like the
/// builder mirrors undirected edges).
#[derive(Clone, Copy, Debug)]
struct DirectedOp {
    src: VertexId,
    dst: VertexId,
    w: EdgeWeight,
    del: bool,
}

/// Reusable batch-application scratch: directed op list, the op-count /
/// capacity arrays and the holey merge target.  The first batch sizes
/// everything; later batches reuse the allocations (the zero-allocation
/// pass-workspace contract, extended to the mutation path).
pub struct DeltaScratch {
    ops: Vec<DirectedOp>,
    src_keys: Vec<u32>,
    op_off: Vec<usize>,
    cap: Vec<usize>,
    holey: HoleyCsr,
}

impl DeltaScratch {
    pub fn new() -> Self {
        Self {
            ops: Vec::new(),
            src_keys: Vec::new(),
            op_off: Vec::new(),
            cap: Vec::new(),
            holey: HoleyCsr::with_offsets(vec![0]),
        }
    }
}

impl Default for DeltaScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Csr {
    /// Apply `batch`, returning the updated graph (fresh scratch, fresh
    /// output).  Convenience wrapper over [`Self::apply_batch_into`].
    pub fn apply_batch(&self, batch: &EdgeBatch, opts: ParallelOpts, exec: Exec) -> Csr {
        let mut out = Csr::default();
        self.apply_batch_into(batch, &mut DeltaScratch::new(), &mut out, opts, exec);
        out
    }

    /// Apply `batch` into `out`, reusing `scratch` across batches.
    ///
    /// See the [module docs](self) for semantics; panics if an endpoint
    /// is out of range.  `out`'s storage is resized in place, so a
    /// timeline replay allocates only while the graph grows.
    pub fn apply_batch_into(
        &self,
        batch: &EdgeBatch,
        scratch: &mut DeltaScratch,
        out: &mut Csr,
        opts: ParallelOpts,
        exec: Exec,
    ) {
        let n = self.num_vertices();

        // --- 1. Directed op list, sorted by (src, dst).
        scratch.ops.clear();
        scratch.src_keys.clear();
        for &(u, v) in &batch.deletions {
            assert!((u as usize) < n && (v as usize) < n, "deletion ({u},{v}) out of range (n={n})");
            scratch.ops.push(DirectedOp { src: u, dst: v, w: 0.0, del: true });
            if u != v {
                scratch.ops.push(DirectedOp { src: v, dst: u, w: 0.0, del: true });
            }
        }
        for &(u, v, w) in &batch.insertions {
            assert!((u as usize) < n && (v as usize) < n, "insertion ({u},{v}) out of range (n={n})");
            scratch.ops.push(DirectedOp { src: u, dst: v, w, del: false });
            if u != v {
                scratch.ops.push(DirectedOp { src: v, dst: u, w, del: false });
            }
        }
        // Stable sort: repeated insertions of one pair keep batch order
        // in *both* mirrored (src, dst) groups, so the two directions
        // sum their f32 weights in the same order and stay bit-equal.
        scratch
            .ops
            .sort_by_key(|o| ((o.src as u64) << 32) | o.dst as u64);
        scratch.src_keys.extend(scratch.ops.iter().map(|o| o.src));

        let scan_opts = ParallelOpts { record: false, ..opts };

        // --- 2. Per-vertex op ranges (scatter histogram → prefix sum).
        scratch.op_off.clear();
        scratch.op_off.resize(n + 1, 0);
        scatter_count(&scratch.src_keys, &mut scratch.op_off[..n], scan_opts, exec);
        exclusive_scan_exec(&mut scratch.op_off, opts.threads, exec);

        // --- 3. Capacity upper bounds (degree + ops; deletions only
        // ever shrink, so this never overflows the holey rows).
        scratch.cap.clear();
        scratch.cap.resize(n + 1, 0);
        {
            let op_off = &scratch.op_off;
            exec.run_disjoint_mut(&mut scratch.cap[..n], scan_opts, |r, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    let v = r.start + k;
                    *x = self.degree(v) + (op_off[v + 1] - op_off[v]);
                }
            });
        }
        exclusive_scan_exec(&mut scratch.cap, opts.threads, exec);
        scratch.holey.reset_with_offsets(&mut scratch.cap);

        // --- 4. Chunked sorted merge: old row × its ops.  Each vertex
        // is owned by exactly one chunk, so its holey row fills in
        // ascending target order.
        {
            let ops = &scratch.ops;
            let op_off = &scratch.op_off;
            let holey = &scratch.holey;
            exec.run(n, scan_opts, |range| {
                for v in range {
                    let row_ops = &ops[op_off[v]..op_off[v + 1]];
                    let (ts, ws) = self.edges(v);
                    if row_ops.is_empty() {
                        for (&t, &w) in ts.iter().zip(ws) {
                            holey.push_edge(v, t, w);
                        }
                        continue;
                    }
                    let (mut ei, mut oi) = (0usize, 0usize);
                    while ei < ts.len() || oi < row_ops.len() {
                        if oi >= row_ops.len() || (ei < ts.len() && ts[ei] < row_ops[oi].dst) {
                            holey.push_edge(v, ts[ei], ws[ei]);
                            ei += 1;
                            continue;
                        }
                        // All ops on one target, plus the old slot if present.
                        let t = row_ops[oi].dst;
                        let mut deleted = false;
                        let mut added = 0.0f32;
                        let mut has_insert = false;
                        while oi < row_ops.len() && row_ops[oi].dst == t {
                            if row_ops[oi].del {
                                deleted = true;
                            } else {
                                added += row_ops[oi].w;
                                has_insert = true;
                            }
                            oi += 1;
                        }
                        let old = if ei < ts.len() && ts[ei] == t {
                            let w = ws[ei];
                            ei += 1;
                            Some(w)
                        } else {
                            None
                        };
                        // Deletions apply first: delete + insert replaces.
                        let base = if deleted { None } else { old };
                        match (base, has_insert) {
                            (Some(b), true) => holey.push_edge(v, t, b + added),
                            (Some(b), false) => holey.push_edge(v, t, b),
                            (None, true) => holey.push_edge(v, t, added),
                            (None, false) => {} // pure delete (or absent)
                        }
                    }
                }
            });
        }

        // --- 5. Squeeze out the deletion holes.
        scratch.holey.compact_into(out, scan_opts, exec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::parallel::team::Team;
    use std::collections::BTreeMap;

    /// Reference implementation: replay the batch on an edge map and
    /// rebuild the CSR from scratch.
    fn rebuild(g: &Csr, batch: &EdgeBatch) -> Csr {
        let mut map: BTreeMap<(u32, u32), f32> = BTreeMap::new();
        for v in 0..g.num_vertices() {
            for (t, w) in g.neighbours(v) {
                map.insert((v as u32, t), w);
            }
        }
        for &(u, v) in &batch.deletions {
            map.remove(&(u, v));
            map.remove(&(v, u));
        }
        for &(u, v, w) in &batch.insertions {
            *map.entry((u, v)).or_insert(0.0) += w;
            if u != v {
                *map.entry((v, u)).or_insert(0.0) += w;
            }
        }
        let mut b = GraphBuilder::new(g.num_vertices());
        for (&(u, v), &w) in &map {
            b.push(u, v, w);
        }
        b.build_directed()
    }

    #[test]
    fn empty_batch_is_identity() {
        let g = generate(GraphFamily::Web, 8, 3);
        let out = g.apply_batch(&EdgeBatch::new(), ParallelOpts::default(), Exec::scoped());
        assert_eq!(out, g);
    }

    #[test]
    fn insert_delete_update_matches_rebuild() {
        // 0-1, 1-2, 0-2 triangle; delete the bridge, re-weight an edge,
        // add a new one, and delete+reinsert another.
        let g = GraphBuilder::new(4)
            .edge(0, 1, 1.0)
            .edge(1, 2, 2.0)
            .edge(0, 2, 3.0)
            .build_undirected();
        let mut b = EdgeBatch::new();
        b.delete(1, 2);
        b.insert(0, 1, 4.0); // weight update: 1 + 4
        b.insert(2, 3, 1.0); // new edge
        b.delete(0, 2);
        b.insert(0, 2, 7.0); // delete + insert replaces: 7, not 10
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        out.validate().unwrap();
        assert!(out.is_symmetric());
        assert_eq!(out, rebuild(&g, &b));
        assert_eq!(out.edges(0).0, &[1, 2]);
        assert_eq!(out.edges(0).1, &[5.0, 7.0]);
        assert_eq!(out.edges(3).0, &[2]);
        assert_eq!(out.degree(1), 1); // 1-2 gone
    }

    #[test]
    fn random_batches_match_rebuild_across_families() {
        use crate::parallel::prng::Xoshiro256;
        for f in GraphFamily::ALL {
            let g = generate(f, 9, 11);
            let n = g.num_vertices();
            let mut rng = Xoshiro256::new(77);
            let mut b = EdgeBatch::new();
            // Deletions of existing edges (integer weights keep f32 sums exact).
            for _ in 0..40 {
                let e = rng.below(g.num_edges() as u64) as usize;
                let v = g.offsets.partition_point(|&o| o <= e) - 1;
                b.delete(v as u32, g.targets[e]);
            }
            // Random insertions, including duplicates within the batch.
            for _ in 0..40 {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                b.insert(u, v, 2.0);
            }
            let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
            out.validate().unwrap();
            assert_eq!(out, rebuild(&g, &b), "{f:?}");
            assert!(out.is_symmetric(), "{f:?}");
        }
    }

    #[test]
    fn parallel_matches_serial_and_scratch_is_reused() {
        let g = generate(GraphFamily::Social, 9, 5);
        let mut b = EdgeBatch::new();
        b.insert(1, 2, 1.0);
        b.insert(10, 200, 3.0);
        b.delete(0, 1);
        let serial = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());

        let team = Team::new(4);
        let opts = ParallelOpts { threads: 4, chunk: 64, ..Default::default() };
        let mut scratch = DeltaScratch::new();
        let mut out = Csr::default();
        g.apply_batch_into(&b, &mut scratch, &mut out, opts, Exec::team(&team));
        assert_eq!(out, serial);

        // A second (smaller) batch through the same scratch + output.
        let tp = out.targets.as_ptr();
        let mut b2 = EdgeBatch::new();
        b2.delete(1, 2);
        let g2 = out.clone();
        g2.apply_batch_into(&b2, &mut scratch, &mut out, opts, Exec::team(&team));
        assert_eq!(out, g2.apply_batch(&b2, ParallelOpts::default(), Exec::scoped()));
        assert_eq!(out.targets.as_ptr(), tp, "output reallocated on a shrinking batch");
    }

    #[test]
    fn repeated_inserts_sum_bit_identically_in_both_directions() {
        // Non-associative f32 weights: the stable op sort keeps batch
        // order in both mirrored groups, so the two directed slots of
        // the pair must stay bit-equal (not just within tolerance).
        let g = GraphBuilder::new(3).edge(0, 1, 1.0).build_undirected();
        let mut b = EdgeBatch::new();
        b.insert(0, 2, 0.1);
        b.insert(0, 2, 0.2);
        b.insert(0, 2, 0.3);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        let w_fwd = out.edges(0).1[out.edges(0).0.iter().position(|&t| t == 2).unwrap()];
        let w_rev = out.edges(2).1[out.edges(2).0.iter().position(|&t| t == 0).unwrap()];
        assert_eq!(w_fwd.to_bits(), w_rev.to_bits());
    }

    #[test]
    fn self_loops_insert_and_delete_once() {
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let mut b = EdgeBatch::new();
        b.insert(0, 0, 5.0);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        assert_eq!(out.edges(0).0, &[0, 1]);
        assert_eq!(out.edges(0).1, &[5.0, 1.0]);
        let mut b2 = EdgeBatch::new();
        b2.delete(0, 0);
        let back = out.apply_batch(&b2, ParallelOpts::default(), Exec::scoped());
        assert_eq!(back, g);
    }

    #[test]
    fn deleting_absent_edges_is_noop() {
        let g = generate(GraphFamily::Road, 8, 2);
        let mut b = EdgeBatch::new();
        b.delete(0, (g.num_vertices() - 1) as u32);
        b.delete(1, 1);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        // Those pairs are (almost surely) absent in a lattice; if they
        // exist the rebuild oracle still agrees.
        assert_eq!(out, rebuild(&g, &b));
    }
}
