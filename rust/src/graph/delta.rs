//! Batch edge updates for evolving graphs: [`EdgeBatch`] +
//! [`Csr::apply_batch`].
//!
//! The paper evaluates GVE-Louvain on frozen snapshots; the ROADMAP
//! north star is a service watching graphs that *change*.  This module
//! is the mutation half of the PR-2 dynamic subsystem (the seeding half
//! lives in [`louvain::dynamic`](crate::louvain::dynamic)): a batch of
//! undirected insertions and deletions is applied to a CSR in parallel,
//! producing the updated CSR without touching untouched rows'
//! *contents* (their slots are copied, not re-derived).
//!
//! ## Batch semantics
//!
//! * The vertex set **grows on demand** (PR 3): an op referencing an id
//!   `>= |V|` extends the output graph to `1 + max id` — the new tail
//!   rows start empty and receive only their batch ops, so a streaming
//!   service admits new vertices without a rebuild or a cold Louvain
//!   run (the dynamic driver warm-starts them as singletons).
//! * **Insertion** `(u, v, w)` adds `w` to the edge's weight, creating
//!   the edge if absent — the same duplicate-merge convention as
//!   [`GraphBuilder`](super::builder::GraphBuilder).  Both directions
//!   are updated (a self-loop lands once, builder-style).
//! * **Deletion** `(u, v)` removes the edge entirely (both directions);
//!   deleting an absent edge is a no-op.
//! * Within one batch, deletions apply *before* insertions on the same
//!   pair: delete + insert replaces the weight rather than accumulating
//!   into the old one.
//!
//! ## Pipeline (all on the team runtime via [`Exec`])
//!
//! 1. Mirror the batch into directed per-endpoint ops and sort by
//!    `(src, dst)` on the team
//!    ([`sort_by_key_stable_parallel`](crate::parallel::sort::sort_by_key_stable_parallel),
//!    PR 3; serial below its cutover) — the sort must stay **stable**
//!    so repeated insertions of one pair keep batch order in both
//!    mirrored groups and the two directions sum f32 weights
//!    bit-identically.
//! 2. Per-vertex op counts via the parallel
//!    [`scatter_count`](crate::parallel::scatter::scatter_count)
//!    helper, prefix-summed into op ranges.
//! 3. Per-vertex capacity upper bounds (`degree + ops`) → exclusive
//!    scan → a reused *holey* CSR, exactly the aggregation-phase
//!    machinery ([`AggScratch`](crate::louvain::aggregation::AggScratch)
//!    style: [`DeltaScratch`] keeps every buffer across batches).
//! 4. Chunked per-vertex sorted merge of the old row with its ops into
//!    the holey CSR (rows stay target-sorted, the crate-wide contract).
//! 5. [`HoleyCsr::compact_into`](super::csr::HoleyCsr::compact_into)
//!    squeezes out deletion holes into the output CSR.

use super::csr::{Csr, HoleyCsr};
use crate::parallel::pool::ParallelOpts;
use crate::parallel::scan::exclusive_scan_exec;
use crate::parallel::scatter::scatter_count;
use crate::parallel::sort::sort_by_key_stable_parallel;
use crate::parallel::team::Exec;
use crate::{EdgeWeight, VertexId};

/// One edge-stream operation — the unit the service ingest path and the
/// `graph::io` update-stream format exchange (PR 3).  A stream is a
/// flat op sequence; [`Commit`](StreamOp::Commit) marks an explicit
/// epoch boundary for sources that want to pin batch edges (the
/// coalescing policy may also cut batches on its own).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StreamOp {
    /// Undirected insertion / weight addition.
    Insert(VertexId, VertexId, EdgeWeight),
    /// Undirected deletion (no-op if absent).
    Delete(VertexId, VertexId),
    /// Explicit flush point: close the pending batch.
    Commit,
}

/// A batch of undirected edge mutations against a fixed vertex set.
#[derive(Clone, Debug, Default)]
pub struct EdgeBatch {
    /// Undirected weight additions (edge created if absent).
    pub insertions: Vec<(VertexId, VertexId, EdgeWeight)>,
    /// Undirected removals (no-op if absent).
    pub deletions: Vec<(VertexId, VertexId)>,
}

impl EdgeBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue an undirected insertion / weight addition.
    pub fn insert(&mut self, u: VertexId, v: VertexId, w: EdgeWeight) {
        self.insertions.push((u, v, w));
    }

    /// Queue an undirected deletion.
    pub fn delete(&mut self, u: VertexId, v: VertexId) {
        self.deletions.push((u, v));
    }

    /// Total queued operations (undirected count).
    pub fn len(&self) -> usize {
        self.insertions.len() + self.deletions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insertions.is_empty() && self.deletions.is_empty()
    }

    /// Flatten into [`StreamOp`]s in application order (deletions
    /// first — the in-batch semantics — then insertions), without a
    /// trailing [`StreamOp::Commit`].
    pub fn to_ops(&self) -> impl Iterator<Item = StreamOp> + '_ {
        self.deletions
            .iter()
            .map(|&(u, v)| StreamOp::Delete(u, v))
            .chain(self.insertions.iter().map(|&(u, v, w)| StreamOp::Insert(u, v, w)))
    }

    /// Smallest vertex count that fits every endpoint (`1 + max id`;
    /// 0 for an empty batch) — the growth target of
    /// [`Csr::apply_batch_into`].
    pub fn min_vertex_count(&self) -> usize {
        let ins = self.insertions.iter().map(|&(u, v, _)| u.max(v));
        let dels = self.deletions.iter().map(|&(u, v)| u.max(v));
        ins.chain(dels).max().map(|m| m as usize + 1).unwrap_or(0)
    }
}

/// One directed mutation slot (internal: batches are mirrored like the
/// builder mirrors undirected edges).
#[derive(Clone, Copy, Debug)]
struct DirectedOp {
    src: VertexId,
    dst: VertexId,
    w: EdgeWeight,
    del: bool,
}

/// Reusable batch-application scratch: directed op list, the op-count /
/// capacity arrays and the holey merge target.  The first batch sizes
/// everything; later batches reuse the allocations (the zero-allocation
/// pass-workspace contract, extended to the mutation path).
pub struct DeltaScratch {
    ops: Vec<DirectedOp>,
    /// Merge buffer of the parallel stable op sort.
    ops_scratch: Vec<DirectedOp>,
    src_keys: Vec<u32>,
    op_off: Vec<usize>,
    cap: Vec<usize>,
    holey: HoleyCsr,
}

impl DeltaScratch {
    pub fn new() -> Self {
        Self {
            ops: Vec::new(),
            ops_scratch: Vec::new(),
            src_keys: Vec::new(),
            op_off: Vec::new(),
            cap: Vec::new(),
            holey: HoleyCsr::with_offsets(vec![0]),
        }
    }

    /// Heap bytes reserved across the merge buffers (capacity; the
    /// fields are private, so the accounting lives here — PR 8).
    /// Scratch is all high-water-mark storage: "used" equals reserved
    /// by design, so only one number is meaningful.
    pub fn reserved_bytes(&self) -> usize {
        let op = std::mem::size_of::<DirectedOp>();
        let us = std::mem::size_of::<usize>();
        self.ops.capacity() * op
            + self.ops_scratch.capacity() * op
            + self.src_keys.capacity() * std::mem::size_of::<u32>()
            + self.op_off.capacity() * us
            + self.cap.capacity() * us
            + self.holey.reserved_bytes()
    }
}

impl Default for DeltaScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Csr {
    /// Apply `batch`, returning the updated graph (fresh scratch, fresh
    /// output).  Convenience wrapper over [`Self::apply_batch_into`].
    pub fn apply_batch(&self, batch: &EdgeBatch, opts: ParallelOpts, exec: Exec) -> Csr {
        let mut out = Csr::default();
        self.apply_batch_into(batch, &mut DeltaScratch::new(), &mut out, opts, exec);
        out
    }

    /// Apply `batch` into `out`, reusing `scratch` across batches.
    ///
    /// See the [module docs](self) for semantics.  Endpoints `>= |V|`
    /// *grow* the output to `1 + max id` (PR 3) — fresh tail rows start
    /// empty and receive only their batch ops.  `out`'s storage is
    /// resized in place, so a timeline replay allocates only while the
    /// graph grows.
    pub fn apply_batch_into(
        &self,
        batch: &EdgeBatch,
        scratch: &mut DeltaScratch,
        out: &mut Csr,
        opts: ParallelOpts,
        exec: Exec,
    ) {
        let n_old = self.num_vertices();
        let n = n_old.max(batch.min_vertex_count());

        // --- 1. Directed op list, sorted by (src, dst).
        scratch.ops.clear();
        scratch.src_keys.clear();
        for &(u, v) in &batch.deletions {
            scratch.ops.push(DirectedOp { src: u, dst: v, w: 0.0, del: true });
            if u != v {
                scratch.ops.push(DirectedOp { src: v, dst: u, w: 0.0, del: true });
            }
        }
        for &(u, v, w) in &batch.insertions {
            scratch.ops.push(DirectedOp { src: u, dst: v, w, del: false });
            if u != v {
                scratch.ops.push(DirectedOp { src: v, dst: u, w, del: false });
            }
        }
        let scan_opts = ParallelOpts { record: false, ..opts };
        // Stable sort (team-parallel, PR 3): repeated insertions of one
        // pair keep batch order in *both* mirrored (src, dst) groups,
        // so the two directions sum their f32 weights in the same order
        // and stay bit-equal.
        sort_by_key_stable_parallel(
            &mut scratch.ops,
            &mut scratch.ops_scratch,
            |o| ((o.src as u64) << 32) | o.dst as u64,
            scan_opts,
            exec,
        );
        scratch.src_keys.extend(scratch.ops.iter().map(|o| o.src));

        // --- 2. Per-vertex op ranges (scatter histogram → prefix sum).
        scratch.op_off.clear();
        scratch.op_off.resize(n + 1, 0);
        scatter_count(&scratch.src_keys, &mut scratch.op_off[..n], scan_opts, exec);
        exclusive_scan_exec(&mut scratch.op_off, opts.threads, exec);

        // --- 3. Capacity upper bounds (degree + ops; deletions only
        // ever shrink, so this never overflows the holey rows).  Grown
        // tail vertices have no old row: capacity is their op count.
        scratch.cap.clear();
        scratch.cap.resize(n + 1, 0);
        {
            let op_off = &scratch.op_off;
            exec.run_disjoint_mut(&mut scratch.cap[..n], scan_opts, |r, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    let v = r.start + k;
                    let deg = if v < n_old { self.degree(v) } else { 0 };
                    *x = deg + (op_off[v + 1] - op_off[v]);
                }
            });
        }
        exclusive_scan_exec(&mut scratch.cap, opts.threads, exec);
        scratch.holey.reset_with_offsets(&mut scratch.cap);

        // --- 4. Chunked sorted merge: old row × its ops.  Each vertex
        // is owned by exactly one chunk, so its holey row fills in
        // ascending target order.
        {
            let ops = &scratch.ops;
            let op_off = &scratch.op_off;
            let holey = &scratch.holey;
            exec.run(n, scan_opts, |range| {
                for v in range {
                    let row_ops = &ops[op_off[v]..op_off[v + 1]];
                    let (ts, ws): (&[VertexId], &[EdgeWeight]) =
                        if v < n_old { self.edges(v) } else { (&[], &[]) };
                    if row_ops.is_empty() {
                        for (&t, &w) in ts.iter().zip(ws) {
                            holey.push_edge(v, t, w);
                        }
                        continue;
                    }
                    let (mut ei, mut oi) = (0usize, 0usize);
                    while ei < ts.len() || oi < row_ops.len() {
                        if oi >= row_ops.len() || (ei < ts.len() && ts[ei] < row_ops[oi].dst) {
                            holey.push_edge(v, ts[ei], ws[ei]);
                            ei += 1;
                            continue;
                        }
                        // All ops on one target, plus the old slot if present.
                        let t = row_ops[oi].dst;
                        let mut deleted = false;
                        let mut added = 0.0f32;
                        let mut has_insert = false;
                        while oi < row_ops.len() && row_ops[oi].dst == t {
                            if row_ops[oi].del {
                                deleted = true;
                            } else {
                                added += row_ops[oi].w;
                                has_insert = true;
                            }
                            oi += 1;
                        }
                        let old = if ei < ts.len() && ts[ei] == t {
                            let w = ws[ei];
                            ei += 1;
                            Some(w)
                        } else {
                            None
                        };
                        // Deletions apply first: delete + insert replaces.
                        let base = if deleted { None } else { old };
                        match (base, has_insert) {
                            (Some(b), true) => holey.push_edge(v, t, b + added),
                            (Some(b), false) => holey.push_edge(v, t, b),
                            (None, true) => holey.push_edge(v, t, added),
                            (None, false) => {} // pure delete (or absent)
                        }
                    }
                }
            });
        }

        // --- 5. Squeeze out the deletion holes.
        scratch.holey.compact_into(out, scan_opts, exec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::parallel::team::Team;
    use std::collections::BTreeMap;

    /// Reference implementation: replay the batch on an edge map and
    /// rebuild the CSR from scratch (growing to fit the batch, like
    /// `apply_batch`).
    fn rebuild(g: &Csr, batch: &EdgeBatch) -> Csr {
        let mut map: BTreeMap<(u32, u32), f32> = BTreeMap::new();
        for v in 0..g.num_vertices() {
            for (t, w) in g.neighbours(v) {
                map.insert((v as u32, t), w);
            }
        }
        for &(u, v) in &batch.deletions {
            map.remove(&(u, v));
            map.remove(&(v, u));
        }
        for &(u, v, w) in &batch.insertions {
            *map.entry((u, v)).or_insert(0.0) += w;
            if u != v {
                *map.entry((v, u)).or_insert(0.0) += w;
            }
        }
        let mut b = GraphBuilder::new(g.num_vertices().max(batch.min_vertex_count()));
        for (&(u, v), &w) in &map {
            b.push(u, v, w);
        }
        b.build_directed()
    }

    #[test]
    fn empty_batch_is_identity() {
        let g = generate(GraphFamily::Web, 8, 3);
        let out = g.apply_batch(&EdgeBatch::new(), ParallelOpts::default(), Exec::scoped());
        assert_eq!(out, g);
    }

    #[test]
    fn insert_delete_update_matches_rebuild() {
        // 0-1, 1-2, 0-2 triangle; delete the bridge, re-weight an edge,
        // add a new one, and delete+reinsert another.
        let g = GraphBuilder::new(4)
            .edge(0, 1, 1.0)
            .edge(1, 2, 2.0)
            .edge(0, 2, 3.0)
            .build_undirected();
        let mut b = EdgeBatch::new();
        b.delete(1, 2);
        b.insert(0, 1, 4.0); // weight update: 1 + 4
        b.insert(2, 3, 1.0); // new edge
        b.delete(0, 2);
        b.insert(0, 2, 7.0); // delete + insert replaces: 7, not 10
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        out.validate().unwrap();
        assert!(out.is_symmetric());
        assert_eq!(out, rebuild(&g, &b));
        assert_eq!(out.edges(0).0, &[1, 2]);
        assert_eq!(out.edges(0).1, &[5.0, 7.0]);
        assert_eq!(out.edges(3).0, &[2]);
        assert_eq!(out.degree(1), 1); // 1-2 gone
    }

    #[test]
    fn random_batches_match_rebuild_across_families() {
        use crate::parallel::prng::Xoshiro256;
        for f in GraphFamily::ALL {
            let g = generate(f, 9, 11);
            let n = g.num_vertices();
            let mut rng = Xoshiro256::new(77);
            let mut b = EdgeBatch::new();
            // Deletions of existing edges (integer weights keep f32 sums exact).
            for _ in 0..40 {
                let e = rng.below(g.num_edges() as u64) as usize;
                let v = g.offsets.partition_point(|&o| o <= e) - 1;
                b.delete(v as u32, g.targets[e]);
            }
            // Random insertions, including duplicates within the batch.
            for _ in 0..40 {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                b.insert(u, v, 2.0);
            }
            let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
            out.validate().unwrap();
            assert_eq!(out, rebuild(&g, &b), "{f:?}");
            assert!(out.is_symmetric(), "{f:?}");
        }
    }

    #[test]
    fn parallel_matches_serial_and_scratch_is_reused() {
        let g = generate(GraphFamily::Social, 9, 5);
        let mut b = EdgeBatch::new();
        b.insert(1, 2, 1.0);
        b.insert(10, 200, 3.0);
        b.delete(0, 1);
        let serial = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());

        let team = Team::new(4);
        let opts = ParallelOpts { threads: 4, chunk: 64, ..Default::default() };
        let mut scratch = DeltaScratch::new();
        let mut out = Csr::default();
        g.apply_batch_into(&b, &mut scratch, &mut out, opts, Exec::team(&team));
        assert_eq!(out, serial);

        // A second (smaller) batch through the same scratch + output.
        let tp = out.targets.as_ptr();
        let mut b2 = EdgeBatch::new();
        b2.delete(1, 2);
        let g2 = out.clone();
        g2.apply_batch_into(&b2, &mut scratch, &mut out, opts, Exec::team(&team));
        assert_eq!(out, g2.apply_batch(&b2, ParallelOpts::default(), Exec::scoped()));
        assert_eq!(out.targets.as_ptr(), tp, "output reallocated on a shrinking batch");
    }

    #[test]
    fn repeated_inserts_sum_bit_identically_in_both_directions() {
        // Non-associative f32 weights: the stable op sort keeps batch
        // order in both mirrored groups, so the two directed slots of
        // the pair must stay bit-equal (not just within tolerance).
        let g = GraphBuilder::new(3).edge(0, 1, 1.0).build_undirected();
        let mut b = EdgeBatch::new();
        b.insert(0, 2, 0.1);
        b.insert(0, 2, 0.2);
        b.insert(0, 2, 0.3);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        let w_fwd = out.edges(0).1[out.edges(0).0.iter().position(|&t| t == 2).unwrap()];
        let w_rev = out.edges(2).1[out.edges(2).0.iter().position(|&t| t == 0).unwrap()];
        assert_eq!(w_fwd.to_bits(), w_rev.to_bits());
    }

    #[test]
    fn batch_grows_the_vertex_set() {
        // Ops referencing ids >= n extend the graph in place (PR 3):
        // no rebuild, old rows untouched, tail rows hold only their ops.
        let g = GraphBuilder::new(3)
            .edge(0, 1, 1.0)
            .edge(1, 2, 2.0)
            .build_undirected();
        let mut b = EdgeBatch::new();
        b.insert(2, 5, 4.0); // grows to 6 vertices, 3..=4 isolated
        b.insert(5, 5, 1.0); // self-loop on a brand-new vertex
        assert_eq!(b.min_vertex_count(), 6);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        out.validate().unwrap();
        assert!(out.is_symmetric());
        assert_eq!(out.num_vertices(), 6);
        assert_eq!(out, rebuild(&g, &b));
        assert_eq!(out.edges(0).0, &[1]);
        assert_eq!(out.edges(5).0, &[2, 5]);
        assert_eq!(out.degree(3), 0);
        assert_eq!(out.degree(4), 0);
    }

    #[test]
    fn growth_deletions_and_duplicates_match_rebuild() {
        // A deletion naming an unseen id still grows (uniform rule) and
        // lands as a no-op; duplicate insertions on a new pair merge.
        let g = generate(GraphFamily::Road, 7, 3);
        let n = g.num_vertices();
        let mut b = EdgeBatch::new();
        b.delete(0, (n + 9) as u32);
        b.insert((n + 1) as u32, 2, 0.5);
        b.insert((n + 1) as u32, 2, 0.25);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        out.validate().unwrap();
        assert_eq!(out.num_vertices(), n + 10);
        assert_eq!(out, rebuild(&g, &b));

        // Growth through the reused-scratch path too.
        let mut scratch = DeltaScratch::new();
        let mut out2 = Csr::default();
        g.apply_batch_into(&b, &mut scratch, &mut out2, ParallelOpts::default(), Exec::scoped());
        assert_eq!(out2, out);
    }

    #[test]
    fn large_batches_take_the_parallel_sort_and_match_serial() {
        // > 2^13 directed ops crosses the parallel-sort cutover; the
        // stable sort has a unique output, so team and scoped paths
        // must agree bit-for-bit with the small-batch (serial) path.
        use crate::parallel::prng::Xoshiro256;
        let g = generate(GraphFamily::Web, 9, 31);
        let n = g.num_vertices() as u64;
        let mut rng = Xoshiro256::new(5);
        let mut b = EdgeBatch::new();
        for i in 0..6000 {
            let u = rng.below(n) as u32;
            let v = rng.below(n) as u32;
            // Repeated pairs with distinct f32 weights: tie order is
            // load-bearing (mirrored sums must stay bit-equal).
            if i % 3 == 0 {
                b.insert(1, 2, 0.1 + (i % 7) as f32 * 0.01);
            } else {
                b.insert(u, v, 1.0);
            }
        }
        for _ in 0..800 {
            let e = rng.below(g.num_edges() as u64) as usize;
            let v = g.offsets.partition_point(|&o| o <= e) - 1;
            b.delete(v as u32, g.targets[e]);
        }
        let serial = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        assert_eq!(serial, rebuild(&g, &b));
        let team = Team::new(4);
        let opts = ParallelOpts { threads: 4, chunk: 64, ..Default::default() };
        let par = g.apply_batch(&b, opts, Exec::team(&team));
        assert_eq!(par, serial);
        let w12 = par.edges(1).1[par.edges(1).0.iter().position(|&t| t == 2).unwrap()];
        let w21 = par.edges(2).1[par.edges(2).0.iter().position(|&t| t == 1).unwrap()];
        assert_eq!(w12.to_bits(), w21.to_bits());
    }

    #[test]
    fn batches_flatten_to_stream_ops_in_application_order() {
        let mut b = EdgeBatch::new();
        b.insert(0, 1, 2.0);
        b.delete(3, 4);
        // Deletions first — the in-batch application order.
        assert_eq!(
            b.to_ops().collect::<Vec<_>>(),
            vec![StreamOp::Delete(3, 4), StreamOp::Insert(0, 1, 2.0)]
        );
        assert_eq!(b.len(), 2);
        assert_eq!(b.min_vertex_count(), 5);
        assert_eq!(EdgeBatch::new().min_vertex_count(), 0);
    }

    #[test]
    fn self_loops_insert_and_delete_once() {
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let mut b = EdgeBatch::new();
        b.insert(0, 0, 5.0);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        assert_eq!(out.edges(0).0, &[0, 1]);
        assert_eq!(out.edges(0).1, &[5.0, 1.0]);
        let mut b2 = EdgeBatch::new();
        b2.delete(0, 0);
        let back = out.apply_batch(&b2, ParallelOpts::default(), Exec::scoped());
        assert_eq!(back, g);
    }

    #[test]
    fn deleting_absent_edges_is_noop() {
        let g = generate(GraphFamily::Road, 8, 2);
        let mut b = EdgeBatch::new();
        b.delete(0, (g.num_vertices() - 1) as u32);
        b.delete(1, 1);
        let out = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        // Those pairs are (almost surely) absent in a lattice; if they
        // exist the rebuild oracle still agrees.
        assert_eq!(out, rebuild(&g, &b));
    }
}
