//! Synthetic graph generators mirroring the paper's dataset families.
//!
//! The evaluation graphs (Table 2) are SuiteSparse datasets from four
//! families; none are redistributable inside this offline testbed, so
//! each family is substituted by a generator reproducing the structural
//! features that drive Louvain behaviour (DESIGN.md §2):
//!
//! * **Web** (LAW: indochina-2004 … sk-2005) — power-law degrees, high
//!   average degree, *strong* planted communities (few, large) → high
//!   modularity (~0.98 in the paper), first pass dominates.
//! * **Social** (SNAP: com-LiveJournal, com-Orkut) — power-law, high
//!   degree, *weak* community structure (high mixing) → low modularity,
//!   aggregation-heavy.
//! * **Road** (DIMACS10: asia_osm, europe_osm) — avg degree ≈ 2.1,
//!   spatial lattice, many small communities → later passes dominate.
//! * **K-mer** (GenBank: kmer_A2a, kmer_V1r) — avg degree ≈ 2.2, long
//!   chains with sparse branching → later passes dominate.
//!
//! Every generator is deterministic in `(scale, seed)`.

use super::builder::GraphBuilder;
use super::csr::Csr;
use crate::parallel::prng::Xoshiro256;
use crate::VertexId;

/// Dataset family of a generated graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GraphFamily {
    Web,
    Social,
    Road,
    Kmer,
    /// Plain RMAT (used by ablations that only need skew, no ground truth).
    Rmat,
}

impl GraphFamily {
    pub fn name(self) -> &'static str {
        match self {
            GraphFamily::Web => "web",
            GraphFamily::Social => "social",
            GraphFamily::Road => "road",
            GraphFamily::Kmer => "kmer",
            GraphFamily::Rmat => "rmat",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "web" => Some(GraphFamily::Web),
            "social" => Some(GraphFamily::Social),
            "road" => Some(GraphFamily::Road),
            "kmer" => Some(GraphFamily::Kmer),
            "rmat" => Some(GraphFamily::Rmat),
            _ => None,
        }
    }

    pub const ALL: [GraphFamily; 4] =
        [GraphFamily::Web, GraphFamily::Social, GraphFamily::Road, GraphFamily::Kmer];
}

/// Generate a family graph with `2^scale` vertices.
pub fn generate(family: GraphFamily, scale: u32, seed: u64) -> Csr {
    let n = 1usize << scale;
    match family {
        GraphFamily::Web => planted_partition(&PlantedPartition {
            n,
            n_communities: (n / 256).max(32).min(n / 8),
            avg_degree: 24.0,
            mixing: 0.03,
            degree_exponent: 2.1,
            max_degree: (n / 8).max(8),
            community_size_exponent: 1.1,
            seed,
        }),
        GraphFamily::Social => planted_partition(&PlantedPartition {
            n,
            n_communities: (n / 128).max(16).min(n / 8),
            avg_degree: 40.0,
            mixing: 0.35,
            degree_exponent: 2.3,
            max_degree: (n / 4).max(8),
            community_size_exponent: 1.2,
            seed,
        }),
        GraphFamily::Road => road(n, seed),
        GraphFamily::Kmer => kmer(n, seed),
        GraphFamily::Rmat => rmat(scale, 8, seed),
    }
}

/// Parameters of the planted-partition (LFR-lite) generator.
#[derive(Clone, Debug)]
pub struct PlantedPartition {
    pub n: usize,
    pub n_communities: usize,
    pub avg_degree: f64,
    /// Fraction of edge endpoints leaving the home community.
    pub mixing: f64,
    /// Power-law exponent of the degree distribution.
    pub degree_exponent: f64,
    pub max_degree: usize,
    /// Power-law exponent of community sizes.
    pub community_size_exponent: f64,
    pub seed: u64,
}

/// LFR-lite: power-law degrees + power-law community sizes + mixing.
pub fn planted_partition(p: &PlantedPartition) -> Csr {
    let mut rng = Xoshiro256::new(p.seed);
    let n = p.n;
    let nc = p.n_communities.max(1);

    // Community sizes ~ power law, then normalized to n members.
    let mut sizes: Vec<f64> = (0..nc)
        .map(|_| rng.powerlaw(1000, p.community_size_exponent) as f64)
        .collect();
    let total: f64 = sizes.iter().sum();
    for s in sizes.iter_mut() {
        *s = (*s / total * n as f64).max(1.0);
    }
    // Assign members contiguously then shuffle ids so community != id-range.
    let mut comm_of: Vec<u32> = Vec::with_capacity(n);
    for (c, s) in sizes.iter().enumerate() {
        let take = (*s).round() as usize;
        for _ in 0..take {
            if comm_of.len() < n {
                comm_of.push(c as u32);
            }
        }
    }
    while comm_of.len() < n {
        comm_of.push(rng.below(nc as u64) as u32);
    }
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    let mut community = vec![0u32; n];
    for (slot, &v) in perm.iter().enumerate() {
        community[v as usize] = comm_of[slot];
    }

    // Membership lists for intra-community endpoint sampling.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); nc];
    for (v, &c) in community.iter().enumerate() {
        members[c as usize].push(v as u32);
    }

    // Degree targets: truncated power law rescaled to the requested mean.
    let raw: Vec<f64> =
        (0..n).map(|_| rng.powerlaw(p.max_degree as u64, p.degree_exponent) as f64).collect();
    let mean = raw.iter().sum::<f64>() / n as f64;
    let scale = p.avg_degree / (2.0 * mean); // each generated edge adds 2 endpoints

    let mut b = GraphBuilder::new(n).drop_self_loops();
    for v in 0..n {
        let d = (raw[v] * scale).round() as usize;
        let c = community[v] as usize;
        for _ in 0..d {
            let intra = !rng.chance(p.mixing) && members[c].len() > 1;
            let u = if intra {
                loop {
                    let u = members[c][rng.below(members[c].len() as u64) as usize];
                    if u as usize != v {
                        break u;
                    }
                }
            } else {
                loop {
                    let u = rng.below(n as u64) as u32;
                    if u as usize != v {
                        break u;
                    }
                }
            };
            b.push(v as VertexId, u, 1.0);
        }
    }
    b.build_undirected()
}

/// Road-network analogue: 2-D lattice with sparse link retention
/// (target average degree ≈ 2.1, like asia_osm / europe_osm).
pub fn road(n: usize, seed: u64) -> Csr {
    let mut rng = Xoshiro256::new(seed ^ 0x0a0a);
    let side = (n as f64).sqrt().ceil() as usize;
    let keep = 0.53; // 4·keep ≈ 2.12 average degree
    let mut b = GraphBuilder::new(n).drop_self_loops();
    let idx = |r: usize, c: usize| (r * side + c) as u32;
    for r in 0..side {
        for c in 0..side {
            let v = idx(r, c) as usize;
            if v >= n {
                continue;
            }
            if c + 1 < side && ((idx(r, c + 1) as usize) < n) && rng.chance(keep) {
                b.push(v as VertexId, idx(r, c + 1), 1.0);
            }
            if r + 1 < side && ((idx(r + 1, c) as usize) < n) && rng.chance(keep) {
                b.push(v as VertexId, idx(r + 1, c), 1.0);
            }
        }
    }
    b.build_undirected()
}

/// Protein k-mer analogue: long chains with sparse branch links
/// (average degree ≈ 2.2, like kmer_A2a / kmer_V1r).
pub fn kmer(n: usize, seed: u64) -> Csr {
    let mut rng = Xoshiro256::new(seed ^ 0x4b4b);
    let mut b = GraphBuilder::new(n).drop_self_loops();
    let mut v = 0usize;
    while v < n {
        // Chain length ~ geometric with mean ≈ 64.
        let len = (1.0 + rng.unit_f64().ln() / (1.0f64 - 1.0 / 64.0).ln()) as usize;
        let len = len.clamp(2, 512).min(n - v);
        for i in 0..len.saturating_sub(1) {
            b.push((v + i) as VertexId, (v + i + 1) as VertexId, 1.0);
        }
        // Sparse branches off the chain (~10% of vertices).
        for i in 0..len {
            if rng.chance(0.10) {
                let u = rng.below(n as u64) as u32;
                if u as usize != v + i {
                    b.push((v + i) as VertexId, u, 1.0);
                }
            }
        }
        v += len;
    }
    b.build_undirected()
}

/// Churn workload generator (PR 2, dynamic subsystem): a batch mutating
/// roughly `frac` of `g`'s undirected edges **in total** — half uniform
/// deletions of existing edges, half uniform random unit-weight
/// insertions, `frac / 2` each side (the naive-dynamic /
/// delta-screening evaluation protocol of arXiv:2301.12390).
/// Deterministic in `(g, frac, seed)`.
pub fn churn_batch(g: &Csr, frac: f64, seed: u64) -> super::delta::EdgeBatch {
    use std::collections::HashSet;
    let mut rng = Xoshiro256::new(seed ^ 0xC4A2_D17A);
    let n = g.num_vertices();
    let slots = g.num_edges();
    let per_side = (((slots / 2) as f64 * frac * 0.5).round() as usize).max(1);
    let mut batch = super::delta::EdgeBatch::new();

    // Deletions: sample directed slots, canonicalize to unordered
    // pairs, dedupe.  Bounded tries so pathological graphs terminate.
    let mut seen: HashSet<(u32, u32)> = HashSet::new();
    let mut tries = 0usize;
    while slots > 0 && batch.deletions.len() < per_side && tries < per_side * 20 {
        tries += 1;
        let e = rng.below(slots as u64) as usize;
        let v = g.offsets.partition_point(|&o| o <= e) - 1;
        let t = g.targets[e] as usize;
        let (a, b) = if v <= t { (v as u32, t as u32) } else { (t as u32, v as u32) };
        if seen.insert((a, b)) {
            batch.delete(a, b);
        }
    }

    // Insertions: uniform random non-self pairs (an existing pair gets
    // its weight bumped — still churn, and `apply_batch` handles it).
    let mut itries = 0usize;
    while n > 1 && batch.insertions.len() < per_side && itries < per_side * 20 {
        itries += 1;
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        if u != v {
            batch.insert(u, v, 1.0);
        }
    }
    batch
}

/// RMAT(a=0.57, b=0.19, c=0.19, d=0.05) with `2^scale` vertices and
/// `edgefactor · 2^scale` undirected edges.
pub fn rmat(scale: u32, edgefactor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let m = n * edgefactor;
    let (a, b_, c) = (0.57, 0.19, 0.19);
    let mut rng = Xoshiro256::new(seed ^ 0x52_4d_41_54);
    let mut b = GraphBuilder::new(n).drop_self_loops();
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for bit in (0..scale).rev() {
            let r = rng.unit_f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b_ {
                (0, 1)
            } else if r < a + b_ + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u |= du << bit;
            v |= dv << bit;
        }
        if u != v {
            b.push(u as VertexId, v as VertexId, 1.0);
        }
    }
    b.build_undirected()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_all_families_valid_and_symmetric() {
        for f in GraphFamily::ALL {
            let g = generate(f, 10, 42);
            g.validate().unwrap();
            assert!(g.is_symmetric(), "{f:?} not symmetric");
            assert!(g.num_vertices() == 1 << 10);
            assert!(g.num_edges() > 0, "{f:?} empty");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        for f in GraphFamily::ALL {
            let a = generate(f, 9, 7);
            let b = generate(f, 9, 7);
            assert_eq!(a, b, "{f:?} not deterministic");
            let c = generate(f, 9, 8);
            assert_ne!(a, c, "{f:?} ignores seed");
        }
    }

    #[test]
    fn family_average_degrees_match_table2_shape() {
        let web = generate(GraphFamily::Web, 12, 1);
        let social = generate(GraphFamily::Social, 12, 1);
        let road = generate(GraphFamily::Road, 12, 1);
        let kmer = generate(GraphFamily::Kmer, 12, 1);
        let avg = |g: &Csr| g.num_edges() as f64 / g.num_vertices() as f64;
        // Paper Table 2: web 8.6–41, social 17–76, road ≈2.1, kmer ≈2.1–2.2.
        assert!(avg(&web) > 10.0, "web avg degree {}", avg(&web));
        assert!(avg(&social) > 15.0, "social avg degree {}", avg(&social));
        assert!((1.4..3.2).contains(&avg(&road)), "road avg degree {}", avg(&road));
        assert!((1.4..3.4).contains(&avg(&kmer)), "kmer avg degree {}", avg(&kmer));
        // Web/social are an order of magnitude denser than road/kmer.
        assert!(avg(&web) > 4.0 * avg(&road));
    }

    #[test]
    fn web_degrees_are_skewed() {
        let g = generate(GraphFamily::Web, 12, 3);
        let mut degs: Vec<usize> = (0..g.num_vertices()).map(|v| g.degree(v)).collect();
        degs.sort_unstable();
        let median = degs[degs.len() / 2];
        let max = *degs.last().unwrap();
        assert!(max > 8 * median.max(1), "no skew: median={median} max={max}");
    }

    #[test]
    fn churn_batch_is_deterministic_and_sized() {
        let g = generate(GraphFamily::Web, 10, 4);
        let a = churn_batch(&g, 0.01, 9);
        let b = churn_batch(&g, 0.01, 9);
        assert_eq!(a.insertions, b.insertions);
        assert_eq!(a.deletions, b.deletions);
        let c = churn_batch(&g, 0.01, 10);
        assert!(a.insertions != c.insertions || a.deletions != c.deletions);
        // frac is the TOTAL churn: ~0.5% of undirected edges per side.
        let per_side = g.num_edges() / 2 / 200;
        assert!(a.deletions.len() >= per_side / 2 && a.deletions.len() <= per_side * 2);
        assert!(a.insertions.len() >= per_side / 2 && a.insertions.len() <= per_side * 2);
        let total = a.deletions.len() + a.insertions.len();
        let budget = g.num_edges() / 2 / 100;
        assert!(total >= budget / 2 && total <= budget * 2, "total churn {total} vs budget {budget}");
        // Deletions name existing edges.
        for &(u, v) in &a.deletions {
            assert!(g.edges(u as usize).0.contains(&v), "deletion ({u},{v}) not in graph");
        }
    }

    #[test]
    fn churn_batch_applies_cleanly() {
        use crate::parallel::pool::ParallelOpts;
        use crate::parallel::team::Exec;
        let g = generate(GraphFamily::Social, 9, 6);
        let batch = churn_batch(&g, 0.02, 1);
        let out = g.apply_batch(&batch, ParallelOpts::default(), Exec::scoped());
        out.validate().unwrap();
        assert!(out.is_symmetric());
        assert_eq!(out.num_vertices(), g.num_vertices());
    }

    #[test]
    fn rmat_respects_edgefactor_roughly() {
        let g = rmat(10, 8, 5);
        let m = g.num_edges() / 2;
        // Dedup + self-loop removal eats some edges; expect within 40%.
        assert!(m > (1 << 10) * 8 * 6 / 10, "m={m}");
    }

    #[test]
    fn road_is_spatially_local() {
        let g = road(1 << 10, 9);
        let side = ((1usize << 10) as f64).sqrt().ceil() as usize;
        for v in 0..g.num_vertices() {
            for (t, _) in g.neighbours(v) {
                let (vr, vc) = (v / side, v % side);
                let (tr, tc) = (t as usize / side, t as usize % side);
                let dist = vr.abs_diff(tr) + vc.abs_diff(tc);
                assert_eq!(dist, 1, "non-lattice edge {v}->{t}");
            }
        }
    }
}
