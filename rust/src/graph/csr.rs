//! Weighted CSR and holey-CSR graph representations.
//!
//! * [`Csr`] — the immutable input / super-vertex graph: `offsets`
//!   (len N+1), `targets`, `weights`.  Undirected graphs store both
//!   directions; `|E|` counts directed slots to match the paper's
//!   Table 2 convention ("after adding reverse edges").
//! * [`HoleyCsr`] — preallocated CSR with per-vertex fill cursors, the
//!   target of the aggregation phase (offsets over-estimate degrees, so
//!   edge/weight arrays have gaps; `compact()` squeezes it into a
//!   [`Csr`]).

use crate::parallel::pool::{ParallelOpts, RawSend, WorkStats};
use crate::parallel::scan::exclusive_scan_exec;
use crate::parallel::team::Exec;
use crate::{EdgeWeight, VertexId};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Immutable weighted CSR graph.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub offsets: Vec<usize>,
    pub targets: Vec<VertexId>,
    pub weights: Vec<EdgeWeight>,
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Heap bytes *reserved* by the three arrays (capacity — what the
    /// allocator holds; memory-accounting surface, PR 8).
    pub fn reserved_bytes(&self) -> usize {
        self.offsets.capacity() * std::mem::size_of::<usize>()
            + self.targets.capacity() * std::mem::size_of::<VertexId>()
            + self.weights.capacity() * std::mem::size_of::<EdgeWeight>()
    }

    /// Heap bytes *logically used* (length — what the graph needs).
    /// The reserved − used gap is the ping-pong slack a steady-state
    /// service deliberately keeps.
    pub fn used_bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
            + self.targets.len() * std::mem::size_of::<VertexId>()
            + self.weights.len() * std::mem::size_of::<EdgeWeight>()
    }

    /// Number of directed edge slots (undirected edges count twice).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Neighbour slice of `v`: `(targets, weights)`.
    #[inline]
    pub fn edges(&self, v: usize) -> (&[VertexId], &[EdgeWeight]) {
        let (lo, hi) = (self.offsets[v], self.offsets[v + 1]);
        (&self.targets[lo..hi], &self.weights[lo..hi])
    }

    /// Iterator over `(target, weight)` pairs of `v`.
    #[inline]
    pub fn neighbours(&self, v: usize) -> impl Iterator<Item = (VertexId, EdgeWeight)> + '_ {
        let (t, w) = self.edges(v);
        t.iter().copied().zip(w.iter().copied())
    }

    /// Weighted degree `K_v = Σ_j w_vj` (f64 accumulation per paper §5.1.2).
    pub fn vertex_weight(&self, v: usize) -> f64 {
        self.edges(v).1.iter().map(|&w| w as f64).sum()
    }

    /// `K_v` for every vertex.
    pub fn vertex_weights(&self) -> Vec<f64> {
        (0..self.num_vertices()).map(|v| self.vertex_weight(v)).collect()
    }

    /// `K_v` for every vertex, computed in parallel chunks into `out`
    /// (resized in place, so a workspace-owned buffer is reused without
    /// reallocating).  This is the K'-init hot path of Algorithm 1
    /// line 4; the returned stats feed the Fig 16 scaling replay.
    pub fn vertex_weights_into(&self, out: &mut Vec<f64>, opts: ParallelOpts, exec: Exec) -> WorkStats {
        let n = self.num_vertices();
        // No clear(): every index of 0..n is written by the loop below
        // (disjoint exact cover), so only growth needs the zero-fill.
        out.resize(n, 0.0);
        exec.run_disjoint_mut(out, opts, |r, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = self.vertex_weight(r.start + k);
            }
        })
    }

    /// Convenience wrapper over [`Self::vertex_weights_into`] for
    /// callers with a thread count but no persistent team.
    pub fn vertex_weights_par(&self, threads: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.vertex_weights_into(
            &mut out,
            ParallelOpts { threads, ..ParallelOpts::default() },
            Exec::scoped(),
        );
        out
    }

    /// Total edge weight `m = Σ_ij w_ij / 2` (self-loops count once per
    /// stored slot, i.e. `w/2` per direction like every other edge).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().map(|&w| w as f64).sum::<f64>() / 2.0
    }

    /// Structural validation: sorted offsets, targets in range,
    /// non-negative weights. Returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.is_empty() {
            return Err("offsets empty (need at least [0])".into());
        }
        if self.offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        if *self.offsets.last().unwrap() != self.targets.len() {
            return Err(format!(
                "offsets end {} != targets len {}",
                self.offsets.last().unwrap(),
                self.targets.len()
            ));
        }
        if self.targets.len() != self.weights.len() {
            return Err("targets/weights length mismatch".into());
        }
        let n = self.num_vertices();
        for v in 0..n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("offsets not monotone at {v}"));
            }
        }
        if let Some(&t) = self.targets.iter().find(|&&t| (t as usize) >= n) {
            return Err(format!("target {t} out of range (n={n})"));
        }
        if self.weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return Err("non-finite or negative weight".into());
        }
        Ok(())
    }

    /// Check symmetry (every directed slot has a reverse with equal
    /// weight). O(E log E); intended for tests/generators.
    pub fn is_symmetric(&self) -> bool {
        use std::collections::HashMap;
        let mut fwd: HashMap<(u32, u32), f64> = HashMap::new();
        for v in 0..self.num_vertices() {
            for (t, w) in self.neighbours(v) {
                *fwd.entry((v as u32, t)).or_insert(0.0) += w as f64;
            }
        }
        fwd.iter().all(|(&(a, b), &w)| {
            fwd.get(&(b, a)).map(|&w2| (w - w2).abs() < 1e-6 * (1.0 + w.abs())).unwrap_or(false)
        })
    }
}

/// Preallocated CSR with per-vertex fill cursors (the aggregation
/// target). `offsets` over-estimate degrees; `fill[v]` tracks how many
/// slots of `v` are used. Writes are lock-free via atomic cursors.
#[derive(Debug)]
pub struct HoleyCsr {
    pub offsets: Vec<usize>,
    fill: Vec<AtomicUsize>,
    pub targets: Vec<VertexId>,
    pub weights: Vec<EdgeWeight>,
}

impl HoleyCsr {
    /// Heap bytes reserved by the holey arrays (capacity; PR 8 memory
    /// accounting — the fill cursors count too, they scale with |V|).
    pub fn reserved_bytes(&self) -> usize {
        self.offsets.capacity() * std::mem::size_of::<usize>()
            + self.fill.capacity() * std::mem::size_of::<AtomicUsize>()
            + self.targets.capacity() * std::mem::size_of::<VertexId>()
            + self.weights.capacity() * std::mem::size_of::<EdgeWeight>()
    }

    /// Allocate from an offsets array (already exclusive-scanned).
    pub fn with_offsets(offsets: Vec<usize>) -> Self {
        let cap = *offsets.last().unwrap_or(&0);
        let n = offsets.len().saturating_sub(1);
        Self {
            offsets,
            fill: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            targets: vec![0; cap],
            weights: vec![0.0; cap],
        }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Used degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.fill[v].load(Ordering::Relaxed)
    }

    /// Capacity reserved for `v`.
    #[inline]
    pub fn capacity(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Reserve the next slot of `v` atomically; returns the global slot
    /// index. Panics in debug if the over-estimate was violated.
    #[inline]
    pub fn claim_slot(&self, v: usize) -> usize {
        let k = self.fill[v].fetch_add(1, Ordering::Relaxed);
        debug_assert!(k < self.capacity(v), "holey CSR overflow at vertex {v}");
        self.offsets[v] + k
    }

    /// Write a claimed slot. The caller must own `slot` via
    /// [`claim_slot`]; distinct slots never alias, so the unsafe writes
    /// are race-free.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn write_slot(&self, slot: usize, target: VertexId, weight: EdgeWeight) {
        unsafe {
            *(self.targets.as_ptr() as *mut VertexId).add(slot) = target;
            *(self.weights.as_ptr() as *mut EdgeWeight).add(slot) = weight;
        }
    }

    /// Append an edge `(v -> target, weight)`.
    #[inline]
    pub fn push_edge(&self, v: usize, target: VertexId, weight: EdgeWeight) {
        let slot = self.claim_slot(v);
        self.write_slot(slot, target, weight);
    }

    /// Used neighbour slice of `v`.
    #[inline]
    pub fn edges(&self, v: usize) -> (&[VertexId], &[EdgeWeight]) {
        let lo = self.offsets[v];
        let hi = lo + self.degree(v);
        (&self.targets[lo..hi], &self.weights[lo..hi])
    }

    /// Reuse this holey CSR's storage for a new shape: swap in
    /// `offsets` (already exclusive-scanned; the old offsets vector is
    /// handed back through the argument so the caller's scratch keeps
    /// its capacity), reset every fill cursor and logically shrink the
    /// slot arrays.  Nothing is reallocated when the new capacity fits
    /// the old one — the zero-allocation pass-workspace contract.
    pub fn reset_with_offsets(&mut self, offsets: &mut Vec<usize>) {
        std::mem::swap(&mut self.offsets, offsets);
        let cap = *self.offsets.last().unwrap_or(&0);
        let n = self.offsets.len().saturating_sub(1);
        self.fill.clear();
        self.fill.resize_with(n, || AtomicUsize::new(0));
        // No clear() on the slot arrays: readers only ever see
        // [offsets[v], offsets[v] + fill[v]), and every slot in that
        // range is freshly written by push_edge — zeroing all `cap`
        // slots here would be a dead O(|E'|) memset per pass.
        self.targets.resize(cap, 0);
        self.weights.resize(cap, 0.0);
    }

    /// Squeeze out the holes into an immutable [`Csr`] (single thread).
    pub fn compact(&self) -> Csr {
        self.compact_with(ParallelOpts::default(), Exec::scoped()).0
    }

    /// Parallel compaction into a fresh [`Csr`] — see [`Self::compact_into`].
    pub fn compact_with(&self, opts: ParallelOpts, exec: Exec) -> (Csr, WorkStats) {
        let mut out = Csr::default();
        let stats = self.compact_into(&mut out, opts, exec);
        (out, stats)
    }

    /// Parallel compaction into a caller-owned [`Csr`]: prefix-sum over
    /// the *used* degrees, then a chunked row copy (disjoint target
    /// regions per vertex chunk).  The paper's aggregation is parallel
    /// end to end; the stats feed the scaling replay.
    ///
    /// `out`'s vectors are resized in place, so a workspace-owned
    /// ping-pong buffer is reused across Louvain passes without
    /// reallocating once sized by the largest pass (the last per-pass
    /// allocation on the aggregation path, removed in PR 2).
    pub fn compact_into(&self, out: &mut Csr, opts: ParallelOpts, exec: Exec) -> WorkStats {
        self.compact_into_spec(out, opts, None, exec)
    }

    /// [`Self::compact_into`] with an optional re-dealt row copy
    /// (PR 10): `deal` carries a bucketed
    /// [`DealSpec`](crate::parallel::schedule::DealSpec) plus the
    /// position→vertex id map it indexes, so the heavy rows are copied
    /// first in small dynamic chunks.  Only the row copy is re-dealt —
    /// the degree gather and prefix sum are O(|V'|) and stay flat.
    /// Rows are disjoint, so any dealing produces the same graph.
    pub fn compact_into_spec(
        &self,
        out: &mut Csr,
        opts: ParallelOpts,
        deal: Option<(crate::parallel::schedule::DealSpec, &[VertexId])>,
        exec: Exec,
    ) -> WorkStats {
        let n = self.num_vertices();
        // Used degree per vertex, then exclusive scan (the trailing 0
        // slot becomes the grand total).  No clear() before the resize:
        // the gather overwrites 0..n and only the trailing slot needs
        // an explicit zero, so stale contents never leak.
        out.offsets.resize(n + 1, 0);
        out.offsets[n] = 0;
        {
            // Not recorded: the PR-0 gather was a serial loop, so the
            // Fig 16 replay expects exactly one recorded loop (the row
            // copy) from compaction — and this loop's stats would be
            // dropped below anyway.
            let gather_opts = ParallelOpts { record: false, ..opts };
            let fill = &self.fill;
            exec.run_disjoint_mut(&mut out.offsets[..n], gather_opts, |r, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = fill[r.start + k].load(Ordering::Relaxed);
                }
            });
        }
        let total = exclusive_scan_exec(&mut out.offsets, opts.threads, exec);
        // resize (not clear+resize): every slot of 0..total is written
        // by the row copy below, so only growth needs the zero-fill.
        out.targets.resize(total, 0);
        out.weights.resize(total, 0.0);
        let tp = RawSend(out.targets.as_mut_ptr());
        let wp = RawSend(out.weights.as_mut_ptr());
        let offs = &out.offsets;
        let copy_row = move |v: usize| {
            let (tp, wp) = (tp, wp);
            let (ts, ws) = self.edges(v);
            let lo = offs[v];
            // SAFETY: [lo, lo+len) regions are disjoint per vertex.
            unsafe {
                std::ptr::copy_nonoverlapping(ts.as_ptr(), tp.0.add(lo), ts.len());
                std::ptr::copy_nonoverlapping(ws.as_ptr(), wp.0.add(lo), ws.len());
            }
        };
        match deal {
            Some((spec, ids)) => exec.run_ctx_spec(n, opts, spec, |_tid| (), move |_, range| {
                for pos in range {
                    copy_row(ids[pos] as usize);
                }
            }),
            None => exec.run(n, opts, move |range| {
                for v in range {
                    copy_row(v);
                }
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn triangle() -> Csr {
        // 0-1, 1-2, 0-2 with weights 1, 2, 3.
        GraphBuilder::new(3)
            .edge(0, 1, 1.0)
            .edge(1, 2, 2.0)
            .edge(0, 2, 3.0)
            .build_undirected()
    }

    #[test]
    fn csr_counts_and_degrees() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 6); // both directions
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(2), 2);
    }

    #[test]
    fn csr_weights_and_total() {
        let g = triangle();
        assert_eq!(g.vertex_weight(0), 4.0);
        assert_eq!(g.vertex_weight(1), 3.0);
        assert_eq!(g.vertex_weight(2), 5.0);
        assert_eq!(g.total_weight(), 6.0);
    }

    #[test]
    fn csr_validate_catches_bad_target() {
        let mut g = triangle();
        g.targets[0] = 99;
        assert!(g.validate().is_err());
    }

    #[test]
    fn csr_validate_catches_bad_offsets() {
        let g = Csr { offsets: vec![0, 2, 1], targets: vec![0, 1], weights: vec![1.0, 1.0] };
        assert!(g.validate().is_err());
        let g = Csr { offsets: vec![1, 2], targets: vec![0], weights: vec![1.0] };
        assert!(g.validate().is_err());
    }

    #[test]
    fn csr_symmetry() {
        assert!(triangle().is_symmetric());
        let asym = Csr { offsets: vec![0, 1, 1], targets: vec![1], weights: vec![1.0] };
        assert!(!asym.is_symmetric());
    }

    #[test]
    fn holey_push_and_compact() {
        let offsets = vec![0usize, 4, 8, 12]; // over-estimated degree 4 each
        let h = HoleyCsr::with_offsets(offsets);
        h.push_edge(0, 1, 1.0);
        h.push_edge(1, 0, 1.0);
        h.push_edge(1, 2, 2.5);
        h.push_edge(2, 1, 2.5);
        assert_eq!(h.degree(0), 1);
        assert_eq!(h.degree(1), 2);
        let c = h.compact();
        c.validate().unwrap();
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.edges(1).0, &[0, 2]);
        assert_eq!(c.edges(1).1, &[1.0, 2.5]);
    }

    #[test]
    fn vertex_weights_into_matches_serial_and_reuses_storage() {
        use crate::parallel::team::{Exec, Team};
        let g = triangle();
        assert_eq!(g.vertex_weights_par(4), g.vertex_weights());

        let team = Team::new(2);
        let mut buf = Vec::new();
        g.vertex_weights_into(
            &mut buf,
            ParallelOpts { threads: 2, ..ParallelOpts::default() },
            Exec::team(&team),
        );
        assert_eq!(buf, g.vertex_weights());
        let ptr = buf.as_ptr();
        // Second fill reuses the allocation (same or smaller n).
        g.vertex_weights_into(&mut buf, ParallelOpts::default(), Exec::team(&team));
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(buf, g.vertex_weights());
    }

    #[test]
    fn compact_parallel_matches_serial_structure() {
        use crate::parallel::team::{Exec, Team};
        // Holey CSR with gaps: capacities 4, used degrees vary.
        let h = HoleyCsr::with_offsets((0..=50).map(|i| i * 4).collect());
        for v in 0..50usize {
            for e in 0..(v % 4) {
                h.push_edge(v, e as u32, e as f32 + 0.5);
            }
        }
        let serial = h.compact();
        serial.validate().unwrap();
        let team = Team::new(4);
        let opts = ParallelOpts { threads: 4, chunk: 8, ..ParallelOpts::default() };
        let (par, _) = h.compact_with(opts, Exec::team(&team));
        assert_eq!(serial, par);
        let (scoped, _) = h.compact_with(opts, Exec::scoped());
        assert_eq!(serial, scoped);
    }

    #[test]
    fn compact_into_reuses_storage_and_matches_fresh() {
        // Big holey CSR sizes the output once; a smaller one compacted
        // into the same Csr must not reallocate (the ping-pong pass
        // contract) and must equal a fresh compaction.
        let big = HoleyCsr::with_offsets((0..=100).map(|i| i * 4).collect());
        for v in 0..100usize {
            for e in 0..(v % 4) {
                big.push_edge(v, e as u32, e as f32);
            }
        }
        let mut out = Csr::default();
        big.compact_into(&mut out, ParallelOpts::default(), Exec::scoped());
        assert_eq!(out, big.compact());
        let (op, tp, wp) = (out.offsets.as_ptr(), out.targets.as_ptr(), out.weights.as_ptr());

        let small = HoleyCsr::with_offsets((0..=20).map(|i| i * 3).collect());
        for v in 0..20usize {
            small.push_edge(v, (v % 5) as u32, 1.5);
        }
        big_stale_guard(&mut out); // poison so stale reuse would show
        small.compact_into(&mut out, ParallelOpts::default(), Exec::scoped());
        assert_eq!(out, small.compact());
        assert_eq!(out.offsets.as_ptr(), op, "offsets reallocated on shrink");
        assert_eq!(out.targets.as_ptr(), tp, "targets reallocated on shrink");
        assert_eq!(out.weights.as_ptr(), wp, "weights reallocated on shrink");
        out.validate().unwrap();
    }

    /// Overwrite `out`'s live slots with sentinel garbage (keeps the
    /// allocations) so the next compact_into must rewrite everything.
    fn big_stale_guard(out: &mut Csr) {
        for x in out.offsets.iter_mut() {
            *x = usize::MAX / 2;
        }
        for t in out.targets.iter_mut() {
            *t = u32::MAX;
        }
    }

    #[test]
    fn holey_reset_reuses_storage() {
        let mut h = HoleyCsr::with_offsets(vec![0, 8, 16, 24]);
        h.push_edge(0, 1, 1.0);
        h.push_edge(2, 0, 2.0);
        let cap_ptr = h.targets.as_ptr();
        // Shrink to two vertices with smaller capacity: no realloc.
        let mut offsets = vec![0usize, 4, 8];
        h.reset_with_offsets(&mut offsets);
        assert_eq!(h.num_vertices(), 2);
        assert_eq!(h.degree(0), 0);
        assert_eq!(h.degree(1), 0);
        assert_eq!(h.targets.as_ptr(), cap_ptr, "targets reallocated on shrink");
        // The old offsets vector is handed back for scratch reuse.
        assert_eq!(offsets, vec![0, 8, 16, 24]);
        h.push_edge(1, 0, 3.0);
        let c = h.compact();
        c.validate().unwrap();
        assert_eq!(c.edges(1).0, &[0]);
        assert_eq!(c.edges(1).1, &[3.0]);
    }

    #[test]
    fn holey_concurrent_pushes_all_land() {
        let n = 64;
        let h = HoleyCsr::with_offsets((0..=n).map(|i| i * 8).collect());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for v in 0..n {
                        h.push_edge(v, (t * 1000 + v) as u32, t as f32);
                    }
                });
            }
        });
        for v in 0..n {
            assert_eq!(h.degree(v), 4);
        }
        let c = h.compact();
        assert_eq!(c.num_edges(), 4 * n);
    }
}
