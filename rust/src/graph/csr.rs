//! Weighted CSR and holey-CSR graph representations.
//!
//! * [`Csr`] — the immutable input / super-vertex graph: `offsets`
//!   (len N+1), `targets`, `weights`.  Undirected graphs store both
//!   directions; `|E|` counts directed slots to match the paper's
//!   Table 2 convention ("after adding reverse edges").
//! * [`HoleyCsr`] — preallocated CSR with per-vertex fill cursors, the
//!   target of the aggregation phase (offsets over-estimate degrees, so
//!   edge/weight arrays have gaps; `compact()` squeezes it into a
//!   [`Csr`]).

use crate::{EdgeWeight, VertexId};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Immutable weighted CSR graph.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub offsets: Vec<usize>,
    pub targets: Vec<VertexId>,
    pub weights: Vec<EdgeWeight>,
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Number of directed edge slots (undirected edges count twice).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Neighbour slice of `v`: `(targets, weights)`.
    #[inline]
    pub fn edges(&self, v: usize) -> (&[VertexId], &[EdgeWeight]) {
        let (lo, hi) = (self.offsets[v], self.offsets[v + 1]);
        (&self.targets[lo..hi], &self.weights[lo..hi])
    }

    /// Iterator over `(target, weight)` pairs of `v`.
    #[inline]
    pub fn neighbours(&self, v: usize) -> impl Iterator<Item = (VertexId, EdgeWeight)> + '_ {
        let (t, w) = self.edges(v);
        t.iter().copied().zip(w.iter().copied())
    }

    /// Weighted degree `K_v = Σ_j w_vj` (f64 accumulation per paper §5.1.2).
    pub fn vertex_weight(&self, v: usize) -> f64 {
        self.edges(v).1.iter().map(|&w| w as f64).sum()
    }

    /// `K_v` for every vertex.
    pub fn vertex_weights(&self) -> Vec<f64> {
        (0..self.num_vertices()).map(|v| self.vertex_weight(v)).collect()
    }

    /// Total edge weight `m = Σ_ij w_ij / 2` (self-loops count once per
    /// stored slot, i.e. `w/2` per direction like every other edge).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().map(|&w| w as f64).sum::<f64>() / 2.0
    }

    /// Structural validation: sorted offsets, targets in range,
    /// non-negative weights. Returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.is_empty() {
            return Err("offsets empty (need at least [0])".into());
        }
        if self.offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        if *self.offsets.last().unwrap() != self.targets.len() {
            return Err(format!(
                "offsets end {} != targets len {}",
                self.offsets.last().unwrap(),
                self.targets.len()
            ));
        }
        if self.targets.len() != self.weights.len() {
            return Err("targets/weights length mismatch".into());
        }
        let n = self.num_vertices();
        for v in 0..n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("offsets not monotone at {v}"));
            }
        }
        if let Some(&t) = self.targets.iter().find(|&&t| (t as usize) >= n) {
            return Err(format!("target {t} out of range (n={n})"));
        }
        if self.weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return Err("non-finite or negative weight".into());
        }
        Ok(())
    }

    /// Check symmetry (every directed slot has a reverse with equal
    /// weight). O(E log E); intended for tests/generators.
    pub fn is_symmetric(&self) -> bool {
        use std::collections::HashMap;
        let mut fwd: HashMap<(u32, u32), f64> = HashMap::new();
        for v in 0..self.num_vertices() {
            for (t, w) in self.neighbours(v) {
                *fwd.entry((v as u32, t)).or_insert(0.0) += w as f64;
            }
        }
        fwd.iter().all(|(&(a, b), &w)| {
            fwd.get(&(b, a)).map(|&w2| (w - w2).abs() < 1e-6 * (1.0 + w.abs())).unwrap_or(false)
        })
    }
}

/// Preallocated CSR with per-vertex fill cursors (the aggregation
/// target). `offsets` over-estimate degrees; `fill[v]` tracks how many
/// slots of `v` are used. Writes are lock-free via atomic cursors.
#[derive(Debug)]
pub struct HoleyCsr {
    pub offsets: Vec<usize>,
    fill: Vec<AtomicUsize>,
    pub targets: Vec<VertexId>,
    pub weights: Vec<EdgeWeight>,
}

impl HoleyCsr {
    /// Allocate from an offsets array (already exclusive-scanned).
    pub fn with_offsets(offsets: Vec<usize>) -> Self {
        let cap = *offsets.last().unwrap_or(&0);
        let n = offsets.len().saturating_sub(1);
        Self {
            offsets,
            fill: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            targets: vec![0; cap],
            weights: vec![0.0; cap],
        }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Used degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.fill[v].load(Ordering::Relaxed)
    }

    /// Capacity reserved for `v`.
    #[inline]
    pub fn capacity(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Reserve the next slot of `v` atomically; returns the global slot
    /// index. Panics in debug if the over-estimate was violated.
    #[inline]
    pub fn claim_slot(&self, v: usize) -> usize {
        let k = self.fill[v].fetch_add(1, Ordering::Relaxed);
        debug_assert!(k < self.capacity(v), "holey CSR overflow at vertex {v}");
        self.offsets[v] + k
    }

    /// Write a claimed slot. The caller must own `slot` via
    /// [`claim_slot`]; distinct slots never alias, so the unsafe writes
    /// are race-free.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn write_slot(&self, slot: usize, target: VertexId, weight: EdgeWeight) {
        unsafe {
            *(self.targets.as_ptr() as *mut VertexId).add(slot) = target;
            *(self.weights.as_ptr() as *mut EdgeWeight).add(slot) = weight;
        }
    }

    /// Append an edge `(v -> target, weight)`.
    #[inline]
    pub fn push_edge(&self, v: usize, target: VertexId, weight: EdgeWeight) {
        let slot = self.claim_slot(v);
        self.write_slot(slot, target, weight);
    }

    /// Used neighbour slice of `v`.
    #[inline]
    pub fn edges(&self, v: usize) -> (&[VertexId], &[EdgeWeight]) {
        let lo = self.offsets[v];
        let hi = lo + self.degree(v);
        (&self.targets[lo..hi], &self.weights[lo..hi])
    }

    /// Squeeze out the holes into an immutable [`Csr`].
    pub fn compact(&self) -> Csr {
        let n = self.num_vertices();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut total = 0usize;
        for v in 0..n {
            total += self.degree(v);
            offsets.push(total);
        }
        let mut targets = Vec::with_capacity(total);
        let mut weights = Vec::with_capacity(total);
        for v in 0..n {
            let (t, w) = self.edges(v);
            targets.extend_from_slice(t);
            weights.extend_from_slice(w);
        }
        Csr { offsets, targets, weights }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn triangle() -> Csr {
        // 0-1, 1-2, 0-2 with weights 1, 2, 3.
        GraphBuilder::new(3)
            .edge(0, 1, 1.0)
            .edge(1, 2, 2.0)
            .edge(0, 2, 3.0)
            .build_undirected()
    }

    #[test]
    fn csr_counts_and_degrees() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 6); // both directions
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(2), 2);
    }

    #[test]
    fn csr_weights_and_total() {
        let g = triangle();
        assert_eq!(g.vertex_weight(0), 4.0);
        assert_eq!(g.vertex_weight(1), 3.0);
        assert_eq!(g.vertex_weight(2), 5.0);
        assert_eq!(g.total_weight(), 6.0);
    }

    #[test]
    fn csr_validate_catches_bad_target() {
        let mut g = triangle();
        g.targets[0] = 99;
        assert!(g.validate().is_err());
    }

    #[test]
    fn csr_validate_catches_bad_offsets() {
        let g = Csr { offsets: vec![0, 2, 1], targets: vec![0, 1], weights: vec![1.0, 1.0] };
        assert!(g.validate().is_err());
        let g = Csr { offsets: vec![1, 2], targets: vec![0], weights: vec![1.0] };
        assert!(g.validate().is_err());
    }

    #[test]
    fn csr_symmetry() {
        assert!(triangle().is_symmetric());
        let asym = Csr { offsets: vec![0, 1, 1], targets: vec![1], weights: vec![1.0] };
        assert!(!asym.is_symmetric());
    }

    #[test]
    fn holey_push_and_compact() {
        let offsets = vec![0usize, 4, 8, 12]; // over-estimated degree 4 each
        let h = HoleyCsr::with_offsets(offsets);
        h.push_edge(0, 1, 1.0);
        h.push_edge(1, 0, 1.0);
        h.push_edge(1, 2, 2.5);
        h.push_edge(2, 1, 2.5);
        assert_eq!(h.degree(0), 1);
        assert_eq!(h.degree(1), 2);
        let c = h.compact();
        c.validate().unwrap();
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.edges(1).0, &[0, 2]);
        assert_eq!(c.edges(1).1, &[1.0, 2.5]);
    }

    #[test]
    fn holey_concurrent_pushes_all_land() {
        let n = 64;
        let h = HoleyCsr::with_offsets((0..=n).map(|i| i * 8).collect());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for v in 0..n {
                        h.push_edge(v, (t * 1000 + v) as u32, t as f32);
                    }
                });
            }
        });
        for v in 0..n {
            assert_eq!(h.degree(v), 4);
        }
        let c = h.compact();
        assert_eq!(c.num_edges(), 4 * n);
    }
}
