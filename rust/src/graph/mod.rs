//! Graph substrate: weighted CSR, holey CSR, builders, generators,
//! batch deltas, IO.
//!
//! The paper stores the input graph and every super-vertex graph in
//! CSR; the aggregation phase writes into a *holey* CSR whose offsets
//! over-estimate each super-vertex degree (Algorithm 3 / Fig 4).
//! [`delta`] (PR 2) applies batches of edge insertions/deletions to a
//! CSR in parallel — the mutation substrate of the dynamic-Louvain
//! subsystem.

pub mod builder;
pub mod csr;
pub mod delta;
pub mod generators;
pub mod io;
pub mod properties;

pub use csr::{Csr, HoleyCsr};
pub use delta::{DeltaScratch, EdgeBatch, StreamOp};
