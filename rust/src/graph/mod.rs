//! Graph substrate: weighted CSR, holey CSR, builders, generators, IO.
//!
//! The paper stores the input graph and every super-vertex graph in
//! CSR; the aggregation phase writes into a *holey* CSR whose offsets
//! over-estimate each super-vertex degree (Algorithm 3 / Fig 4).

pub mod builder;
pub mod csr;
pub mod generators;
pub mod io;
pub mod properties;

pub use csr::{Csr, HoleyCsr};
