//! Graph IO: MatrixMarket (the SuiteSparse interchange the paper loads),
//! whitespace edge lists, a fast binary format (the "Vite/Nido binary
//! conversion" step of §5.2), and — PR 3 — the *update-stream* text
//! format feeding the long-lived community service
//! (`service::ingest`): a line-oriented log of edge mutations replayed
//! without materializing the whole stream in memory.

use super::builder::{symmetrize, GraphBuilder};
use super::csr::Csr;
use super::delta::StreamOp;
use crate::VertexId;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const BIN_MAGIC: &[u8; 8] = b"GVELOUV1";

/// Read a MatrixMarket `.mtx` coordinate file (1-indexed).
///
/// `pattern` matrices get weight 1; `general` symmetry is symmetrized
/// per the paper ("after adding reverse edges"), `symmetric` storage is
/// mirrored.
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    if !header.starts_with("%%MatrixMarket") {
        bail!("not a MatrixMarket file: {}", path.display());
    }
    let lower = header.to_lowercase();
    let pattern = lower.contains("pattern");
    let symmetric = lower.contains("symmetric");
    if !lower.contains("coordinate") {
        bail!("only coordinate format supported");
    }

    let mut line = String::new();
    // Skip comments.
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("unexpected EOF before size line");
        }
        if !line.starts_with('%') && !line.trim().is_empty() {
            break;
        }
    }
    let mut it = line.split_whitespace();
    let rows: usize = it.next().context("rows")?.parse()?;
    let cols: usize = it.next().context("cols")?.parse()?;
    let nnz: usize = it.next().context("nnz")?.parse()?;
    let n = rows.max(cols);

    let mut b = GraphBuilder::new(n);
    let mut seen = 0usize;
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("row idx")?.parse()?;
        let j: usize = it.next().context("col idx")?.parse()?;
        let w: f32 = if pattern { 1.0 } else { it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0) };
        if i == 0 || j == 0 || i > n || j > n {
            bail!("index out of range: {i} {j} (n={n})");
        }
        b.push((i - 1) as VertexId, (j - 1) as VertexId, w.abs());
        seen += 1;
    }
    if seen != nnz {
        bail!("nnz mismatch: header {nnz}, file {seen}");
    }
    if symmetric {
        Ok(b.build_undirected())
    } else {
        Ok(symmetrize(&b.build_directed()))
    }
}

/// Write a graph as MatrixMarket (symmetric coordinate real, lower
/// triangle + self-loops once).
pub fn write_matrix_market(g: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let mut entries: Vec<(usize, usize, f32)> = Vec::new();
    for v in 0..g.num_vertices() {
        for (t, wt) in g.neighbours(v) {
            if (t as usize) <= v {
                entries.push((v + 1, t as usize + 1, wt));
            }
        }
    }
    writeln!(w, "%%MatrixMarket matrix coordinate real symmetric")?;
    writeln!(w, "{} {} {}", g.num_vertices(), g.num_vertices(), entries.len())?;
    for (i, j, wt) in entries {
        writeln!(w, "{i} {j} {wt}")?;
    }
    Ok(())
}

/// Read a whitespace edge list (`u v [w]`, 0-indexed) as undirected.
pub fn read_edge_list(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    let mut n = 0usize;
    for line in r.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it.next().context("u")?.parse()?;
        let v: u32 = it.next().context("v")?.parse()?;
        let w: f32 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
        n = n.max(u as usize + 1).max(v as usize + 1);
        edges.push((u, v, w));
    }
    let mut b = GraphBuilder::new(n);
    for (u, v, w) in edges {
        b.push(u, v, w);
    }
    Ok(b.build_undirected())
}

/// Write the fast binary format (the analogue of Vite's conversion).
pub fn write_binary(g: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    for &o in &g.offsets {
        w.write_all(&(o as u64).to_le_bytes())?;
    }
    for &t in &g.targets {
        w.write_all(&t.to_le_bytes())?;
    }
    for &wt in &g.weights {
        w.write_all(&wt.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary format written by [`write_binary`].
pub fn read_binary(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf) as usize;
    let mut offsets = vec![0usize; n + 1];
    for o in offsets.iter_mut() {
        r.read_exact(&mut u64buf)?;
        *o = u64::from_le_bytes(u64buf) as usize;
    }
    let mut targets = vec![0u32; m];
    let mut u32buf = [0u8; 4];
    for t in targets.iter_mut() {
        r.read_exact(&mut u32buf)?;
        *t = u32::from_le_bytes(u32buf);
    }
    let mut weights = vec![0f32; m];
    for w in weights.iter_mut() {
        r.read_exact(&mut u32buf)?;
        *w = f32::from_le_bytes(u32buf);
    }
    let g = Csr { offsets, targets, weights };
    g.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(g)
}

/// Write an update stream (`.ups`): one op per line —
/// `a u v [w]` (insert, weight default 1), `d u v` (delete), `c`
/// (commit / epoch boundary), `#`-comments.  The streaming counterpart
/// of the edge-list format, for `service::ingest` replay files.
pub fn write_update_stream<'a>(
    ops: impl IntoIterator<Item = &'a StreamOp>,
    path: &Path,
) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# gve-louvain update stream: a u v [w] | d u v | c")?;
    for op in ops {
        match *op {
            StreamOp::Insert(u, v, wt) => writeln!(w, "a {u} {v} {wt}")?,
            StreamOp::Delete(u, v) => writeln!(w, "d {u} {v}")?,
            StreamOp::Commit => writeln!(w, "c")?,
        }
    }
    Ok(())
}

/// Streaming reader for the [`write_update_stream`] format: yields one
/// [`StreamOp`] at a time off a `BufRead`, so a service can replay
/// arbitrarily long logs in O(1) memory.
pub struct UpdateStreamReader<R: BufRead> {
    reader: R,
    line: String,
    lineno: usize,
}

impl UpdateStreamReader<BufReader<std::fs::File>> {
    /// Open a `.ups` file for streaming.
    pub fn open(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        Ok(Self::new(BufReader::new(f)))
    }
}

impl<R: BufRead> UpdateStreamReader<R> {
    pub fn new(reader: R) -> Self {
        Self { reader, line: String::new(), lineno: 0 }
    }

    /// Next operation, or `None` at end of stream.
    pub fn next_op(&mut self) -> Result<Option<StreamOp>> {
        loop {
            self.line.clear();
            if self.reader.read_line(&mut self.line)? == 0 {
                return Ok(None);
            }
            self.lineno += 1;
            let t = self.line.trim();
            if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
                continue;
            }
            let mut it = t.split_whitespace();
            let tag = it.next().unwrap(); // non-empty after trim
            // Both missing tokens *and* malformed numbers carry the
            // line number — a corrupt line deep in a long replay file
            // must be findable from the error alone.
            let ctx = |what: &str| format!("update stream line {}: {what}", self.lineno);
            let field = |tok: Option<&str>, what: &str| -> Result<VertexId> {
                tok.with_context(|| ctx(what))?.parse().with_context(|| ctx(what))
            };
            let op = match tag {
                "a" => {
                    let u = field(it.next(), "u")?;
                    let v = field(it.next(), "v")?;
                    let w: f32 = match it.next() {
                        Some(s) => s.parse().with_context(|| ctx("w"))?,
                        None => 1.0,
                    };
                    StreamOp::Insert(u, v, w)
                }
                "d" => {
                    let u = field(it.next(), "u")?;
                    let v = field(it.next(), "v")?;
                    StreamOp::Delete(u, v)
                }
                "c" => StreamOp::Commit,
                other => bail!("update stream line {}: unknown op {other:?}", self.lineno),
            };
            return Ok(Some(op));
        }
    }
}

impl<R: BufRead> Iterator for UpdateStreamReader<R> {
    type Item = Result<StreamOp>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_op().transpose()
    }
}

/// Read a whole update stream into memory (tests / small files; the
/// service consumes [`UpdateStreamReader`] directly instead).
pub fn read_update_stream(path: &Path) -> Result<Vec<StreamOp>> {
    UpdateStreamReader::open(path)?.collect()
}

// ---------------------------------------------------------------------------
// Binary op codec (PR 9): the `.ups` vocabulary on the wire.
//
// The serving daemon's Ops frames carry the same three operations as
// the text format, under the same tag bytes (`a`/`d`/`c`), in a fixed
// little-endian layout:
//
//   insert:  b'a'  u:u32le  v:u32le  w:f32le     (13 bytes)
//   delete:  b'd'  u:u32le  v:u32le              (9 bytes)
//   commit:  b'c'                                (1 byte)
//
// Sharing tag bytes keeps the two encodings one vocabulary: a hex dump
// of a wire frame reads like a `.ups` file, and the decoder's error
// space is identical (unknown tag, truncated fields).

/// Encoded size of one op in the binary codec.
pub fn encoded_op_len(op: &StreamOp) -> usize {
    match op {
        StreamOp::Insert(..) => 13,
        StreamOp::Delete(..) => 9,
        StreamOp::Commit => 1,
    }
}

/// Append one op's binary encoding to `buf`.
pub fn encode_op(op: &StreamOp, buf: &mut Vec<u8>) {
    match *op {
        StreamOp::Insert(u, v, w) => {
            buf.push(b'a');
            buf.extend_from_slice(&u.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
            buf.extend_from_slice(&w.to_le_bytes());
        }
        StreamOp::Delete(u, v) => {
            buf.push(b'd');
            buf.extend_from_slice(&u.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
        }
        StreamOp::Commit => buf.push(b'c'),
    }
}

/// Encode a run of ops back to back (an Ops-frame payload body).
pub fn encode_ops<'a>(ops: impl IntoIterator<Item = &'a StreamOp>) -> Vec<u8> {
    let mut buf = Vec::new();
    for op in ops {
        encode_op(op, &mut buf);
    }
    buf
}

/// Decode one op from the front of `buf`; returns the op and the bytes
/// consumed.  Errors on an unknown tag or truncated fields — the same
/// failure modes as the text reader, minus the line numbers (the wire
/// layer supplies frame context instead).
pub fn decode_op(buf: &[u8]) -> Result<(StreamOp, usize)> {
    let tag = *buf.first().context("empty op buffer")?;
    let u32_at = |off: usize| -> Result<u32> {
        let raw: [u8; 4] = buf
            .get(off..off + 4)
            .with_context(|| format!("op {:?} truncated at byte {off}", tag as char))?
            .try_into()
            .unwrap();
        Ok(u32::from_le_bytes(raw))
    };
    match tag {
        b'a' => {
            let u = u32_at(1)?;
            let v = u32_at(5)?;
            let w = f32::from_le_bytes(u32_at(9)?.to_le_bytes());
            Ok((StreamOp::Insert(u, v, w), 13))
        }
        b'd' => Ok((StreamOp::Delete(u32_at(1)?, u32_at(5)?), 9)),
        b'c' => Ok((StreamOp::Commit, 1)),
        other => bail!("unknown op tag {other:#04x}"),
    }
}

/// Decode exactly `count` ops from `buf`, requiring the buffer to be
/// fully consumed (frame payloads carry their op count up front, so
/// trailing garbage is a protocol error, not padding).
pub fn decode_ops(buf: &[u8], count: usize) -> Result<Vec<StreamOp>> {
    let mut ops = Vec::with_capacity(count.min(1 << 16));
    let mut off = 0usize;
    for i in 0..count {
        let (op, used) =
            decode_op(&buf[off..]).with_context(|| format!("op {i} of {count}"))?;
        ops.push(op);
        off += used;
    }
    if off != buf.len() {
        bail!("{} trailing bytes after {count} ops", buf.len() - off);
    }
    Ok(ops)
}

/// Load any supported format by extension (`.mtx`, `.bin`, else edge list).
pub fn load(path: &Path) -> Result<Csr> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("mtx") => read_matrix_market(path),
        Some("bin") => read_binary(path),
        _ => read_edge_list(path),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("gve_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_round_trip() {
        let g = generate(GraphFamily::Web, 8, 1);
        let p = tmp("web.bin");
        write_binary(&g, &p).unwrap();
        let h = read_binary(&p).unwrap();
        assert_eq!(g, h);
    }

    #[test]
    fn matrix_market_round_trip() {
        let g = generate(GraphFamily::Road, 8, 2);
        let p = tmp("road.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(g.num_vertices(), h.num_vertices());
        assert_eq!(g.num_edges(), h.num_edges());
        assert_eq!(g.total_weight(), h.total_weight());
        assert!(h.is_symmetric());
    }

    #[test]
    fn matrix_market_pattern_general() {
        let p = tmp("pat.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix coordinate pattern general\n% comment\n3 3 2\n1 2\n3 1\n").unwrap();
        let g = read_matrix_market(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 4); // two undirected edges
        assert!(g.is_symmetric());
        assert!(g.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn matrix_market_rejects_bad_header() {
        let p = tmp("bad.mtx");
        std::fs::write(&p, "garbage\n1 1 0\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
    }

    #[test]
    fn matrix_market_rejects_nnz_mismatch() {
        let p = tmp("mismatch.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix coordinate pattern general\n3 3 5\n1 2\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
    }

    #[test]
    fn edge_list_parses_comments_and_weights() {
        let p = tmp("el.txt");
        std::fs::write(&p, "# comment\n0 1 2.5\n1 2\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.edges(0).1, &[2.5]);
        assert_eq!(g.edges(2).1, &[1.0]);
    }

    #[test]
    fn update_stream_round_trip() {
        let ops = vec![
            StreamOp::Insert(0, 1, 2.5),
            StreamOp::Delete(3, 4),
            StreamOp::Commit,
            StreamOp::Insert(5, 5, 1.0),
            StreamOp::Commit,
        ];
        let p = tmp("ops.ups");
        write_update_stream(&ops, &p).unwrap();
        assert_eq!(read_update_stream(&p).unwrap(), ops);
        // Streaming reader yields the same sequence one op at a time.
        let mut r = UpdateStreamReader::open(&p).unwrap();
        let mut got = Vec::new();
        while let Some(op) = r.next_op().unwrap() {
            got.push(op);
        }
        assert_eq!(got, ops);
    }

    #[test]
    fn update_stream_parses_defaults_and_comments() {
        let p = tmp("defaults.ups");
        std::fs::write(&p, "# header\n\na 0 1\n% alt comment\nd 2 0\nc\n").unwrap();
        assert_eq!(
            read_update_stream(&p).unwrap(),
            vec![StreamOp::Insert(0, 1, 1.0), StreamOp::Delete(2, 0), StreamOp::Commit]
        );
    }

    #[test]
    fn update_stream_rejects_garbage() {
        let p = tmp("bad.ups");
        std::fs::write(&p, "a 0 1\nx 1 2\n").unwrap();
        let err = read_update_stream(&p).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let p2 = tmp("trunc.ups");
        std::fs::write(&p2, "a 0\n").unwrap();
        assert!(read_update_stream(&p2).is_err());
        // Malformed numbers carry the line number and field too.
        let p3 = tmp("badnum.ups");
        std::fs::write(&p3, "a 0 1\nc\na 12 x 1.0\n").unwrap();
        let err = read_update_stream(&p3).unwrap_err().to_string();
        assert!(err.contains("line 3") && err.contains('v'), "{err}");
    }

    #[test]
    fn binary_op_codec_round_trips() {
        let ops = vec![
            StreamOp::Insert(0, u32::MAX, -2.5),
            StreamOp::Delete(7, 0),
            StreamOp::Commit,
            StreamOp::Insert(1, 2, 1.0),
        ];
        let buf = encode_ops(&ops);
        assert_eq!(buf.len(), ops.iter().map(encoded_op_len).sum::<usize>());
        // Tag bytes match the `.ups` text vocabulary.
        assert_eq!(buf[0], b'a');
        assert_eq!(buf[13], b'd');
        assert_eq!(buf[22], b'c');
        assert_eq!(decode_ops(&buf, ops.len()).unwrap(), ops);
    }

    #[test]
    fn binary_op_codec_rejects_malformed_input() {
        // Unknown tag.
        assert!(decode_op(b"x123").is_err());
        // Truncated insert.
        let mut buf = Vec::new();
        encode_op(&StreamOp::Insert(1, 2, 3.0), &mut buf);
        assert!(decode_op(&buf[..7]).is_err());
        // Count / payload mismatches both directions.
        assert!(decode_ops(&buf, 2).is_err(), "count larger than payload");
        let mut extra = buf.clone();
        extra.push(b'c');
        assert!(decode_ops(&extra, 1).is_err(), "trailing bytes");
        assert!(decode_ops(&[], 0).unwrap().is_empty());
    }

    #[test]
    fn load_dispatches_on_extension() {
        let g = generate(GraphFamily::Kmer, 7, 3);
        let pb = tmp("k.bin");
        write_binary(&g, &pb).unwrap();
        assert_eq!(load(&pb).unwrap(), g);
        let pm = tmp("k.mtx");
        write_matrix_market(&g, &pm).unwrap();
        assert_eq!(load(&pm).unwrap().num_edges(), g.num_edges());
    }
}
