//! `repro` — the CLI for the GVE-Louvain / ν-Louvain reproduction.
//!
//! Subcommands:
//!
//! * `suite`                — list the 13-graph evaluation suite (Table 2)
//! * `generate`             — write a suite/family graph to disk
//! * `run`                  — run one system on one graph
//! * `compare`              — cross-system comparison (Figs 11–13 rows)
//! * `pjrt`                 — run the PJRT three-layer ν-Louvain path
//! * `config`               — run an experiment described by a TOML file
//!
//! Arguments are hand-parsed (`--key value` / flags); the offline
//! registry has no clap.

use anyhow::{bail, Context, Result};
use gve_louvain::baselines::{gve_outcome_with_params, run_system, System};
use gve_louvain::coordinator::cli::{louvain_params_from, Opts};
use gve_louvain::coordinator::metrics::{edges_per_sec, fmt_ns};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::{compare_on_entry, mean_speedup};
use gve_louvain::coordinator::{config::Config, suite};
use gve_louvain::gpusim::nulouvain::NuParams;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::io;
use gve_louvain::graph::properties::GraphProperties;
use gve_louvain::runtime::executor::MoveExecutor;
use gve_louvain::runtime::pjrt_louvain::PjrtLouvain;
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let opts = Opts::parse(&args[1..]);
    match cmd.as_str() {
        "suite" => cmd_suite(&opts),
        "generate" => cmd_generate(&opts),
        "run" => cmd_run(&opts),
        "compare" => cmd_compare(&opts),
        "pjrt" => cmd_pjrt(&opts),
        "config" => cmd_config(&opts),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        r#"repro — GVE-Louvain / ν-Louvain reproduction CLI

USAGE: repro <subcommand> [--key value ...]

  suite     [--offset N]                      list the Table 2 suite
  generate  --graph NAME|--family F [--scale S] [--seed N] --out PATH
  run       --system S --graph NAME [--offset N] [--threads T] [--seed N]
            systems: gve-louvain nu-louvain vite grappolo networkit cugraph nido
            gve-louvain also takes the scan-engine knobs:
              [--schedule static|dynamic|guided|auto|degree-bucketed]
              [--chunk C] [--table map|close-kv|far-kv]
              [--small-degree D] [--hub-degree H] [--prefetch-distance P]
            the adaptive late-pass engine (gve-louvain only):
              [--adaptive-width] [--serial-pass-threshold N] [--width-gain G]
            and per-pass tracing (gve-louvain only):
              [--trace out.json]  write Chrome trace-event JSON (open in
                                  Perfetto) + print per-pass utilization
  compare   [--graphs quick|all] [--systems a,b,c] [--offset N] [--repeats R]
  pjrt      --graph NAME [--offset N]         three-layer PJRT ν-Louvain
  config    --file PATH                       run a configs/*.toml experiment
"#
    );
}

fn parse_system(s: &str) -> Result<System> {
    Ok(match s {
        "gve-louvain" | "gve" => System::GveLouvain,
        "nu-louvain" | "nu" => System::NuLouvain,
        "vite" => System::Vite,
        "grappolo" => System::Grappolo,
        "networkit" => System::NetworKit,
        "cugraph" => System::CuGraph,
        "nido" => System::Nido,
        other => bail!("unknown system {other:?}"),
    })
}

fn load_graph(opts: &Opts) -> Result<(gve_louvain::graph::Csr, String)> {
    let seed = opts.get_i("seed", 42) as u64;
    if let Some(path) = opts.flags.get("input") {
        let g = io::load(&PathBuf::from(path))?;
        return Ok((g, path.clone()));
    }
    let name = opts.get("graph", "");
    if !name.is_empty() {
        let entry = suite::find(&name).with_context(|| format!("unknown suite graph {name:?}"))?;
        let offset = opts.get_i("offset", 0) as i32;
        return Ok((entry.graph(offset, seed), name));
    }
    let fam = opts.get("family", "web");
    let family = GraphFamily::parse(&fam).with_context(|| format!("unknown family {fam:?}"))?;
    let scale = opts.get_i("scale", 12) as u32;
    Ok((generate(family, scale, seed), format!("{fam}-s{scale}")))
}

fn cmd_suite(opts: &Opts) -> Result<()> {
    let offset = opts.get_i("offset", 0) as i32;
    let seed = opts.get_i("seed", 42) as u64;
    let mut t = Table::new(
        "Evaluation suite (Table 2 mirror)",
        &["graph", "family", "|V|", "|E|", "D_avg", "paper |V|", "paper |E|"],
    );
    for e in &suite::SUITE {
        let g = e.graph(offset, seed);
        let p = GraphProperties::of(&g);
        t.row(vec![
            e.name.into(),
            e.family.name().into(),
            format!("{}", p.num_vertices),
            format!("{}", p.num_edges),
            format!("{:.1}", p.avg_degree),
            gve_louvain::graph::properties::human(e.paper_v as f64),
            gve_louvain::graph::properties::human(e.paper_e as f64),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_generate(opts: &Opts) -> Result<()> {
    let (g, name) = load_graph(opts)?;
    let out = opts.flags.get("out").context("--out PATH required")?;
    let path = PathBuf::from(out);
    match path.extension().and_then(|e| e.to_str()) {
        Some("mtx") => io::write_matrix_market(&g, &path)?,
        _ => io::write_binary(&g, &path)?,
    }
    println!("wrote {name} ({} vertices, {} edges) to {out}", g.num_vertices(), g.num_edges());
    Ok(())
}

fn cmd_run(opts: &Opts) -> Result<()> {
    let system = parse_system(&opts.get("system", "gve-louvain"))?;
    let (g, name) = load_graph(opts)?;
    let threads = opts.get_i("threads", 1) as usize;
    let seed = opts.get_i("seed", 42) as u64;
    // Traced run (PR 7): wrap the run in a TraceSession, dump Chrome
    // trace-event JSON, and print the derived per-pass utilization
    // table.  GVE only — the baselines don't expose pass stats.
    if let Some(trace_path) = opts.flags.get("trace") {
        if system != System::GveLouvain {
            bail!("--trace is only supported with --system gve-louvain");
        }
        let params = louvain_params_from(opts);
        let trace_threads = params.threads;
        let session = gve_louvain::trace::TraceSession::start();
        let result = gve_louvain::louvain::gve::GveLouvain::new(params).run(&g);
        let trace = session.finish();
        gve_louvain::trace::chrome::write(&trace, trace_path)
            .with_context(|| format!("writing trace to {trace_path}"))?;
        print!(
            "{}",
            gve_louvain::trace::report::utilization_table(&result, &trace, trace_threads)
                .render()
        );
        println!(
            "gve-louvain on {name}: Q={:.4} |Γ|={} passes={} wall={} rate={:.1}M edges/s",
            result.modularity,
            result.num_communities,
            result.passes,
            fmt_ns(result.total_ns),
            edges_per_sec(g.num_edges(), result.total_ns) / 1e6,
        );
        println!(
            "trace: {} events across {} threads ({} dropped) -> {trace_path} (open in https://ui.perfetto.dev)",
            trace.events.len(),
            trace.threads.len(),
            trace.dropped,
        );
        if trace.dropped > 0 {
            println!(
                "trace: dropped by thread: {}",
                gve_louvain::trace::report::dropped_summary(&trace)
            );
        }
        return Ok(());
    }
    // GVE honours the full scan-engine knob set (--schedule --chunk
    // --table --small-degree --hub-degree --prefetch-distance); the
    // baseline re-implementations keep their documented configs.
    let out = if system == System::GveLouvain {
        gve_outcome_with_params(&g, louvain_params_from(opts))
    } else {
        run_system(system, &g, threads, seed)
    };
    println!(
        "{} on {name}: Q={:.4} |Γ|={} passes={} wall={} modeled={} rate={:.1}M edges/s",
        system.name(),
        out.modularity,
        out.num_communities,
        out.passes,
        fmt_ns(out.wall_ns),
        out.modeled_ns.map(fmt_ns).unwrap_or_else(|| "OOM".into()),
        edges_per_sec(g.num_edges(), out.wall_ns) / 1e6,
    );
    Ok(())
}

fn cmd_compare(opts: &Opts) -> Result<()> {
    let systems: Vec<System> = opts
        .get("systems", "gve-louvain,nu-louvain,vite,grappolo,networkit,cugraph,nido")
        .split(',')
        .map(parse_system)
        .collect::<Result<_>>()?;
    let entries: Vec<&suite::SuiteEntry> = match opts.get("graphs", "quick").as_str() {
        "all" => suite::SUITE.iter().collect(),
        "quick" => suite::quick(),
        name => vec![suite::find(name).with_context(|| format!("unknown graph {name:?}"))?],
    };
    let offset = opts.get_i("offset", -2) as i32;
    let repeats = opts.get_i("repeats", 1) as usize;
    let threads = opts.get_i("threads", 1) as usize;
    let seed = opts.get_i("seed", 42) as u64;

    let mut t = Table::new(
        "Cross-system comparison (Figs 11-13 rows)",
        &["graph", "system", "modeled", "wall", "Q", "|Γ|", "passes"],
    );
    let mut all_cells = Vec::new();
    for entry in entries {
        let cells = compare_on_entry(entry, offset, &systems, threads, repeats, seed);
        for c in &cells {
            t.row(vec![
                c.graph.into(),
                c.system.name().into(),
                c.modeled_ns.map(|x| fmt_ns(x as u64)).unwrap_or_else(|| "OOM".into()),
                fmt_ns(c.wall_ns as u64),
                format!("{:.4}", c.modularity),
                format!("{}", c.num_communities),
                format!("{}", c.passes),
            ]);
        }
        all_cells.extend(cells);
    }
    print!("{}", t.render());
    if systems.contains(&System::GveLouvain) {
        for &other in &systems {
            if other == System::GveLouvain {
                continue;
            }
            if let Some(s) = mean_speedup(&all_cells, System::GveLouvain, other) {
                println!("gve-louvain speedup vs {:<12}: {s:.1}x", other.name());
            }
        }
    }
    Ok(())
}

fn cmd_pjrt(opts: &Opts) -> Result<()> {
    let (g, name) = load_graph(opts)?;
    let exec = MoveExecutor::discover()?;
    println!("PJRT platform: {} | tile classes {:?}", exec.platform(), exec.classes());
    let out = PjrtLouvain::new(&exec, NuParams::default()).run(&g)?;
    println!(
        "pjrt nu-louvain on {name}: Q={:.4} (device Q={}) |Γ|={} passes={} wall={} dispatches={}",
        out.modularity,
        out.modularity_device.map(|q| format!("{q:.4}")).unwrap_or_else(|| "-".into()),
        out.num_communities,
        out.passes,
        fmt_ns(out.wall_ns),
        out.dispatches,
    );
    Ok(())
}

fn cmd_config(opts: &Opts) -> Result<()> {
    let path = opts.flags.get("file").context("--file PATH required")?;
    let cfg = Config::load(&PathBuf::from(path))?;
    let name = cfg.get_str("", "name", "experiment");
    println!("experiment: {name}");
    let systems: Vec<System> = cfg
        .get("run", "systems")
        .and_then(|v| v.as_array().map(|a| a.to_vec()))
        .unwrap_or_default()
        .iter()
        .filter_map(|v| v.as_str().map(|s| s.to_string()))
        .map(|s| parse_system(&s))
        .collect::<Result<_>>()?;
    let systems = if systems.is_empty() { vec![System::GveLouvain] } else { systems };
    let graphs = cfg.get_str("run", "graphs", "quick");
    let args = vec![
        "--systems".to_string(),
        systems.iter().map(|s| s.name()).collect::<Vec<_>>().join(","),
        "--graphs".to_string(),
        graphs,
        "--offset".to_string(),
        cfg.get_int("run", "offset", -2).to_string(),
        "--repeats".to_string(),
        cfg.get_int("run", "repeats", 1).to_string(),
        "--threads".to_string(),
        cfg.get_int("run", "threads", 1).to_string(),
        "--seed".to_string(),
        cfg.get_int("run", "seed", 42).to_string(),
    ];
    cmd_compare(&Opts::parse(&args))
}
