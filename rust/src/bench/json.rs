//! Minimal JSON reader for the bench yardsticks (PR 8).
//!
//! The repo *writes* its `BENCH_PRn.json` files by hand (no serde in
//! the offline registry); the `--baseline` regression gate needs to
//! *read* them back.  This is a small recursive-descent parser over
//! the full JSON grammar — objects, arrays, strings with escapes,
//! numbers, booleans, null — returning an owned [`Json`] tree with
//! path-style accessors.  It is for trusted, repo-produced files:
//! errors carry a byte offset but recovery is not attempted.

/// An owned JSON value. Object keys keep insertion order (a `Vec`, not
/// a map — bench files are small and order aids debugging).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object member lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// `self[key]` as f64 — the common accessor for bench cells.
    pub fn num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    /// `self[key]` as &str.
    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn eat_literal(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') if self.eat_literal("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.eat_literal("false") => Ok(Json::Bool(false)),
            Some(b'n') if self.eat_literal("null") => Ok(Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            members.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape at byte {}", self.pos))?;
                            self.pos += 4;
                            // Surrogates never appear in repo-written
                            // files; map them to U+FFFD rather than fail.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar (strings are valid UTF-8:
                    // the input was a &str).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .unwrap()
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_nesting() {
        let v = Json::parse(
            r#"{"a": 1.5, "b": [true, false, null, "x\ny"], "c": {"d": -2e3}}"#,
        )
        .unwrap();
        assert_eq!(v.num("a"), Some(1.5));
        let b = v.get("b").and_then(Json::as_arr).unwrap();
        assert_eq!(b[0].as_bool(), Some(true));
        assert_eq!(b[2], Json::Null);
        assert_eq!(b[3].as_str(), Some("x\ny"));
        assert_eq!(v.get("c").and_then(|c| c.num("d")), Some(-2000.0));
    }

    #[test]
    fn round_trips_a_bench_shaped_document() {
        let doc = r#"{
          "bench": "bench_pr8_smoke",
          "results": [
            {"family": "web", "threads": 1, "edges_per_sec": 12345.6},
            {"family": "web", "threads": 4, "edges_per_sec": 45678.9}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.str("bench"), Some("bench_pr8_smoke"));
        let cells = v.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[1].num("threads"), Some(4.0));
        assert_eq!(cells[1].num("edges_per_sec"), Some(45678.9));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("123 45").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes_and_multibyte_passthrough() {
        assert_eq!(Json::parse("\"caf\\u00e9\"").unwrap().as_str(), Some("café"));
        assert_eq!(Json::parse(r#""café""#).unwrap().as_str(), Some("café"));
    }
}
