//! Benchmark harness (the offline registry has no criterion).
//!
//! Every `rust/benches/*.rs` target is a plain `main()` using
//! [`BenchSet`]: named measurements with warmup + repeats, printed as a
//! [`Table`](crate::coordinator::report::Table) whose rows mirror the
//! corresponding paper table/figure.  `GVE_BENCH_SCALE` (env) shifts
//! suite scales so CI can run quick versions.

use crate::coordinator::metrics::{fmt_ns, geomean, median};
use crate::coordinator::report::Table;
use std::time::Instant;

pub mod json;

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<u64>,
    /// Optional quality metric attached to the run (e.g. modularity).
    pub quality: Option<f64>,
}

impl Measurement {
    pub fn median_ns(&self) -> u64 {
        median(&self.samples_ns.iter().map(|&x| x as f64).collect::<Vec<_>>()) as u64
    }

    pub fn geomean_ns(&self) -> f64 {
        geomean(&self.samples_ns.iter().map(|&x| x as f64).collect::<Vec<_>>())
    }
}

/// A collection of measurements with uniform repeat policy.
pub struct BenchSet {
    pub title: String,
    pub warmup: usize,
    pub repeats: usize,
    pub measurements: Vec<Measurement>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        let repeats = std::env::var("GVE_BENCH_REPEATS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        Self { title: title.to_string(), warmup: 1, repeats, measurements: Vec::new() }
    }

    /// Time `body` (returns an optional quality metric to record).
    pub fn measure(&mut self, name: &str, mut body: impl FnMut() -> Option<f64>) {
        for _ in 0..self.warmup {
            let _ = body();
        }
        let mut samples = Vec::with_capacity(self.repeats);
        let mut quality = None;
        for _ in 0..self.repeats {
            let t0 = Instant::now();
            quality = body();
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        self.measurements.push(Measurement { name: name.to_string(), samples_ns: samples, quality });
    }

    /// Record an externally-computed value (modeled times).
    pub fn record(&mut self, name: &str, ns: u64, quality: Option<f64>) {
        self.measurements
            .push(Measurement { name: name.to_string(), samples_ns: vec![ns], quality });
    }

    /// Render with runtimes relative to `baseline` (paper Fig 2 style).
    pub fn table_relative(&self, baseline: &str) -> Table {
        let base = self
            .measurements
            .iter()
            .find(|m| m.name == baseline)
            .map(|m| m.geomean_ns())
            .unwrap_or(1.0);
        let mut t = Table::new(&self.title, &["variant", "time", "relative", "quality"]);
        for m in &self.measurements {
            let g = m.geomean_ns();
            t.row(vec![
                m.name.clone(),
                fmt_ns(g as u64),
                format!("{:.3}", g / base.max(1.0)),
                m.quality.map(|q| format!("{q:.4}")).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// Render absolute times.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&self.title, &["case", "median", "geomean", "quality"]);
        for m in &self.measurements {
            t.row(vec![
                m.name.clone(),
                fmt_ns(m.median_ns()),
                fmt_ns(m.geomean_ns() as u64),
                m.quality.map(|q| format!("{q:.4}")).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }
}

/// Suite scale offset for benches (`GVE_BENCH_SCALE`, default -2:
/// quick-but-representative sizes on this 1-core host).
pub fn bench_scale_offset() -> i32 {
    std::env::var("GVE_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(-2)
}

/// Bench seed (`GVE_BENCH_SEED`, default 42).
pub fn bench_seed() -> u64 {
    std::env::var("GVE_BENCH_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let mut b = BenchSet::new("t");
        b.repeats = 2;
        b.warmup = 0;
        b.measure("noop", || {
            std::hint::black_box(1 + 1);
            Some(0.5)
        });
        assert_eq!(b.measurements.len(), 1);
        assert_eq!(b.measurements[0].samples_ns.len(), 2);
        assert_eq!(b.measurements[0].quality, Some(0.5));
    }

    #[test]
    fn relative_table_has_baseline_one() {
        let mut b = BenchSet::new("t");
        b.record("base", 1000, None);
        b.record("fast", 500, None);
        let t = b.table_relative("base");
        let rendered = t.render();
        assert!(rendered.contains("1.000"));
        assert!(rendered.contains("0.500"));
    }
}
