//! Service-side observability: cumulative ingest counters, per-epoch
//! latency history and quality drift (PR 3).
//!
//! Everything the `louvain_serve` binary and the bench's `"service"`
//! scenario report comes from here; the counters are plain fields
//! updated by the single-threaded ingest loop (readers see them via
//! `CommunityService::metrics`, not concurrently).

use super::snapshot::EpochStats;
use crate::coordinator::metrics::median;

/// Cumulative service counters plus the full epoch-latency history.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    /// Edge ops accepted (commit markers excluded).
    pub ops_ingested: u64,
    /// Stream ops dropped by the `max_vertices` growth guard.
    pub ops_rejected: u64,
    /// Batches applied / epochs published past the initial one.
    pub batches_applied: u64,
    /// Across *update* epochs only — the boot epoch's full run is a
    /// different animal and lives in `epoch_history[0]`; keeping it out
    /// of the totals makes every derived rate here agree with
    /// `coordinator::service::summarize_service` (whose cells exclude
    /// the boot epoch too).
    pub total_apply_ns: u64,
    pub total_detect_ns: u64,
    /// Per-epoch stats in publish order (initial epoch included).
    pub epoch_history: Vec<EpochStats>,
    /// Modularity of the initial full run.
    pub initial_modularity: f64,
    /// Modularity of the latest epoch.
    pub last_modularity: f64,
    /// Lowest modularity ever published (worst-case drift).
    pub min_modularity: f64,
}

impl ServiceMetrics {
    pub(crate) fn record_initial(&mut self, stats: EpochStats, modularity: f64) {
        self.initial_modularity = modularity;
        self.last_modularity = modularity;
        self.min_modularity = modularity;
        self.epoch_history.push(stats);
    }

    pub(crate) fn record_epoch(&mut self, stats: EpochStats, modularity: f64) {
        self.batches_applied += 1;
        self.total_apply_ns += stats.apply_ns;
        self.total_detect_ns += stats.detect_ns;
        self.last_modularity = modularity;
        self.min_modularity = self.min_modularity.min(modularity);
        self.epoch_history.push(stats);
    }

    /// Ingest-to-publish wall time across the update epochs so far
    /// (boot excluded, see the field docs).
    pub fn total_wall_ns(&self) -> u64 {
        self.total_apply_ns + self.total_detect_ns
    }

    /// Sustained ingest throughput: accepted ops over update-epoch wall
    /// time (apply + detect — the time the ingest loop was busy on
    /// them; ops only exist after boot).
    pub fn ingest_ops_per_sec(&self) -> f64 {
        let ns = self.total_wall_ns();
        if ns == 0 {
            return 0.0;
        }
        self.ops_ingested as f64 * 1e9 / ns as f64
    }

    /// Median ingest-to-publish latency over *update* epochs (the
    /// initial full run is a different animal and excluded).
    pub fn median_epoch_ns(&self) -> u64 {
        let walls: Vec<f64> = self
            .epoch_history
            .iter()
            .skip(1)
            .map(|e| e.wall_ns() as f64)
            .collect();
        if walls.is_empty() {
            0
        } else {
            median(&walls) as u64
        }
    }

    /// Worst epoch latency (same exclusion as the median).
    pub fn max_epoch_ns(&self) -> u64 {
        self.epoch_history.iter().skip(1).map(|e| e.wall_ns()).max().unwrap_or(0)
    }

    /// Signed quality drift since the initial run (negative = lost
    /// modularity under churn).
    pub fn quality_drift(&self) -> f64 {
        self.last_modularity - self.initial_modularity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(apply_ns: u64, detect_ns: u64) -> EpochStats {
        EpochStats { apply_ns, detect_ns, ..Default::default() }
    }

    #[test]
    fn counters_accumulate_and_derive() {
        let mut m = ServiceMetrics::default();
        m.record_initial(stats(0, 100), 0.9);
        m.ops_ingested = 30;
        m.record_epoch(stats(10, 40), 0.88);
        m.record_epoch(stats(10, 20), 0.91);
        m.record_epoch(stats(10, 60), 0.86);
        assert_eq!(m.batches_applied, 3);
        // Totals cover update epochs only — the boot run's 100ns stays
        // in epoch_history[0] but out of every derived rate.
        assert_eq!(m.total_apply_ns, 30);
        assert_eq!(m.total_detect_ns, 120);
        assert_eq!(m.total_wall_ns(), 150);
        assert_eq!(m.epoch_history.len(), 4);
        assert_eq!(m.epoch_history[0].detect_ns, 100);
        // Median over update epochs only: {50, 30, 70} → 50.
        assert_eq!(m.median_epoch_ns(), 50);
        assert_eq!(m.max_epoch_ns(), 70);
        assert!((m.quality_drift() - (0.86 - 0.9)).abs() < 1e-12);
        assert!((m.min_modularity - 0.86).abs() < 1e-12);
        assert!((m.ingest_ops_per_sec() - 30.0 * 1e9 / 150.0).abs() < 1e-6);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = ServiceMetrics::default();
        assert_eq!(m.median_epoch_ns(), 0);
        assert_eq!(m.max_epoch_ns(), 0);
        assert_eq!(m.ingest_ops_per_sec(), 0.0);
    }
}
