//! Service-side observability: cumulative ingest counters, per-epoch
//! latency history and quality drift (PR 3).
//!
//! Everything the `louvain_serve` binary and the bench's `"service"`
//! scenario report comes from here; the counters are plain fields
//! updated by the single-threaded ingest loop (readers see them via
//! `CommunityService::metrics`, not concurrently).

use super::snapshot::EpochStats;
use crate::coordinator::metrics::median;

/// Retained epoch-stat entries; a long-lived service overwrites the
/// oldest past this point instead of growing without bound (PR 6).
pub const EPOCH_HISTORY_CAP: usize = 1024;

/// Bounded ring of per-epoch stats in publish order.  Index 0 is the
/// *oldest retained* epoch: until the ring wraps that is the boot
/// epoch, afterwards `evicted()` says how many fell off the front.
#[derive(Clone, Debug, Default)]
pub struct EpochHistory {
    buf: Vec<EpochStats>,
    /// Position of the oldest retained entry once the ring is full.
    start: usize,
    evicted: u64,
}

impl EpochHistory {
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Epochs overwritten after the ring filled up.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    pub fn push(&mut self, s: EpochStats) {
        if self.buf.len() < EPOCH_HISTORY_CAP {
            self.buf.push(s);
        } else {
            self.buf[self.start] = s;
            self.start = (self.start + 1) % self.buf.len();
            self.evicted += 1;
        }
    }

    /// Oldest-to-newest iteration over the retained entries.
    pub fn iter(&self) -> impl Iterator<Item = &EpochStats> {
        self.buf[self.start..].iter().chain(self.buf[..self.start].iter())
    }
}

impl std::ops::Index<usize> for EpochHistory {
    type Output = EpochStats;

    fn index(&self, i: usize) -> &EpochStats {
        assert!(i < self.buf.len(), "epoch index {i} out of range {}", self.buf.len());
        &self.buf[(self.start + i) % self.buf.len()]
    }
}

/// Nearest-rank latency percentiles over the retained update epochs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EpochPercentiles {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
}

/// Cumulative service counters plus the retained epoch-latency history.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    /// Edge ops accepted (commit markers excluded).
    pub ops_ingested: u64,
    /// Stream ops dropped by the `max_vertices` growth guard.
    pub ops_rejected: u64,
    /// Batches applied / epochs published past the initial one.
    pub batches_applied: u64,
    /// Across *update* epochs only — the boot epoch's full run is a
    /// different animal and lives in `epoch_history[0]`; keeping it out
    /// of the totals makes every derived rate here agree with
    /// `coordinator::service::summarize_service` (whose cells exclude
    /// the boot epoch too).
    pub total_apply_ns: u64,
    pub total_detect_ns: u64,
    /// Per-epoch stats in publish order (initial epoch included until
    /// the ring wraps), bounded at [`EPOCH_HISTORY_CAP`] entries.
    pub epoch_history: EpochHistory,
    /// Modularity of the initial full run.
    pub initial_modularity: f64,
    /// Modularity of the latest epoch.
    pub last_modularity: f64,
    /// Lowest modularity ever published (worst-case drift).
    pub min_modularity: f64,
}

impl ServiceMetrics {
    pub(crate) fn record_initial(&mut self, stats: EpochStats, modularity: f64) {
        self.initial_modularity = modularity;
        self.last_modularity = modularity;
        self.min_modularity = modularity;
        self.epoch_history.push(stats);
    }

    pub(crate) fn record_epoch(&mut self, stats: EpochStats, modularity: f64) {
        self.batches_applied += 1;
        self.total_apply_ns += stats.apply_ns;
        self.total_detect_ns += stats.detect_ns;
        self.last_modularity = modularity;
        self.min_modularity = self.min_modularity.min(modularity);
        self.epoch_history.push(stats);
    }

    /// Ingest-to-publish wall time across the update epochs so far
    /// (boot excluded, see the field docs).
    pub fn total_wall_ns(&self) -> u64 {
        self.total_apply_ns + self.total_detect_ns
    }

    /// Sustained ingest throughput: accepted ops over update-epoch wall
    /// time (apply + detect — the time the ingest loop was busy on
    /// them; ops only exist after boot).
    pub fn ingest_ops_per_sec(&self) -> f64 {
        let ns = self.total_wall_ns();
        if ns == 0 {
            return 0.0;
        }
        self.ops_ingested as f64 * 1e9 / ns as f64
    }

    /// Entries to skip at the front of the retained history so the
    /// derived latencies cover *update* epochs only: the boot epoch is
    /// entry 0 until the ring wraps, after which it has already been
    /// evicted and every retained entry is an update epoch.
    fn boot_skip(&self) -> usize {
        if self.epoch_history.evicted() == 0 {
            1
        } else {
            0
        }
    }

    /// Median ingest-to-publish latency over retained *update* epochs
    /// (the initial full run is a different animal and excluded).
    pub fn median_epoch_ns(&self) -> u64 {
        let walls: Vec<f64> = self
            .epoch_history
            .iter()
            .skip(self.boot_skip())
            .map(|e| e.wall_ns() as f64)
            .collect();
        if walls.is_empty() {
            0
        } else {
            median(&walls) as u64
        }
    }

    /// Worst retained epoch latency (same exclusion as the median).
    pub fn max_epoch_ns(&self) -> u64 {
        self.epoch_history.iter().skip(self.boot_skip()).map(|e| e.wall_ns()).max().unwrap_or(0)
    }

    /// Nearest-rank p50/p95/p99 ingest-to-publish latency over retained
    /// update epochs (boot excluded like the median; all-zero when no
    /// update epoch has been published yet).
    pub fn epoch_percentiles(&self) -> EpochPercentiles {
        let mut walls: Vec<u64> = self
            .epoch_history
            .iter()
            .skip(self.boot_skip())
            .map(|e| e.wall_ns())
            .collect();
        if walls.is_empty() {
            return EpochPercentiles::default();
        }
        walls.sort_unstable();
        let nearest = |p: f64| {
            let rank = ((p / 100.0) * walls.len() as f64).ceil() as usize;
            walls[rank.clamp(1, walls.len()) - 1]
        };
        EpochPercentiles { p50: nearest(50.0), p95: nearest(95.0), p99: nearest(99.0) }
    }

    /// Signed quality drift since the initial run (negative = lost
    /// modularity under churn).
    pub fn quality_drift(&self) -> f64 {
        self.last_modularity - self.initial_modularity
    }

    /// Plain-value summary for cross-thread publication (PR 8): the
    /// ingest loop copies this into the shared cell the introspection
    /// server's `/epochs` endpoint renders, so the HTTP thread never
    /// touches the live (single-writer) `ServiceMetrics`.
    pub fn summary(&self) -> ServiceSummary {
        ServiceSummary {
            epochs_published: self.batches_applied,
            ops_ingested: self.ops_ingested,
            ops_rejected: self.ops_rejected,
            ingest_ops_per_sec: self.ingest_ops_per_sec(),
            median_epoch_ns: self.median_epoch_ns(),
            max_epoch_ns: self.max_epoch_ns(),
            percentiles: self.epoch_percentiles(),
            initial_modularity: self.initial_modularity,
            last_modularity: self.last_modularity,
            quality_drift: self.quality_drift(),
        }
    }
}

/// Entries retained by the `/epochs` introspection ring (PR 9): enough
/// to catch bursts between scrapes without the endpoint body growing
/// past a few KiB.
pub const RECENT_EPOCHS_CAP: usize = 32;

/// One `/epochs` ring entry: the shape-level facts of a published
/// epoch (no membership — that is the subscription stream's job).
#[derive(Clone, Copy, Debug, Default)]
pub struct RecentEpoch {
    pub epoch: u64,
    pub vertices: usize,
    pub edges: usize,
    pub modularity: f64,
    pub num_communities: usize,
    pub stats: EpochStats,
}

/// Bounded ring of the last [`RECENT_EPOCHS_CAP`] published epochs,
/// oldest first.  Unlike [`EpochHistory`] (the metrics-side 1024-entry
/// latency record) this is sized for an HTTP response body: scrapers
/// polling `/epochs` every few seconds still see every epoch of a
/// burst (ROADMAP PR-8 follow-on).
#[derive(Clone, Debug, Default)]
pub struct RecentEpochs {
    buf: Vec<RecentEpoch>,
    start: usize,
}

impl RecentEpoch {
    /// Ring entry summarising one published snapshot.
    pub fn of(snap: &super::snapshot::EpochSnapshot) -> Self {
        Self {
            epoch: snap.epoch,
            vertices: snap.vertices,
            edges: snap.edges,
            modularity: snap.modularity,
            num_communities: snap.num_communities(),
            stats: snap.stats,
        }
    }
}

impl RecentEpochs {
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, e: RecentEpoch) {
        if self.buf.len() < RECENT_EPOCHS_CAP {
            self.buf.push(e);
        } else {
            self.buf[self.start] = e;
            self.start = (self.start + 1) % self.buf.len();
        }
    }

    /// Oldest-to-newest iteration over the retained entries.
    pub fn iter(&self) -> impl Iterator<Item = &RecentEpoch> {
        self.buf[self.start..].iter().chain(self.buf[..self.start].iter())
    }
}

/// `Copy` snapshot of the derived [`ServiceMetrics`] values (PR 8) —
/// what `/epochs` reports beyond the current [`EpochSnapshot`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceSummary {
    /// Update epochs published (`batches_applied`; boot excluded).
    pub epochs_published: u64,
    pub ops_ingested: u64,
    pub ops_rejected: u64,
    pub ingest_ops_per_sec: f64,
    pub median_epoch_ns: u64,
    pub max_epoch_ns: u64,
    pub percentiles: EpochPercentiles,
    pub initial_modularity: f64,
    pub last_modularity: f64,
    pub quality_drift: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(apply_ns: u64, detect_ns: u64) -> EpochStats {
        EpochStats { apply_ns, detect_ns, ..Default::default() }
    }

    #[test]
    fn counters_accumulate_and_derive() {
        let mut m = ServiceMetrics::default();
        m.record_initial(stats(0, 100), 0.9);
        m.ops_ingested = 30;
        m.record_epoch(stats(10, 40), 0.88);
        m.record_epoch(stats(10, 20), 0.91);
        m.record_epoch(stats(10, 60), 0.86);
        assert_eq!(m.batches_applied, 3);
        // Totals cover update epochs only — the boot run's 100ns stays
        // in epoch_history[0] but out of every derived rate.
        assert_eq!(m.total_apply_ns, 30);
        assert_eq!(m.total_detect_ns, 120);
        assert_eq!(m.total_wall_ns(), 150);
        assert_eq!(m.epoch_history.len(), 4);
        assert_eq!(m.epoch_history[0].detect_ns, 100);
        // Median over update epochs only: {50, 30, 70} → 50.
        assert_eq!(m.median_epoch_ns(), 50);
        assert_eq!(m.max_epoch_ns(), 70);
        assert!((m.quality_drift() - (0.86 - 0.9)).abs() < 1e-12);
        assert!((m.min_modularity - 0.86).abs() < 1e-12);
        assert!((m.ingest_ops_per_sec() - 30.0 * 1e9 / 150.0).abs() < 1e-6);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = ServiceMetrics::default();
        assert_eq!(m.median_epoch_ns(), 0);
        assert_eq!(m.max_epoch_ns(), 0);
        assert_eq!(m.ingest_ops_per_sec(), 0.0);
        assert_eq!(m.epoch_percentiles(), EpochPercentiles::default());
    }

    #[test]
    fn epoch_percentiles_nearest_rank() {
        let mut m = ServiceMetrics::default();
        m.record_initial(stats(0, 1_000_000), 0.9);
        // Update-epoch walls 10, 20, ..., 1000 (boot excluded).
        for i in 1..=100u64 {
            m.record_epoch(stats(0, i * 10), 0.9);
        }
        let p = m.epoch_percentiles();
        assert_eq!(p.p50, 500);
        assert_eq!(p.p95, 950);
        assert_eq!(p.p99, 990);
        // One update epoch: every percentile is that sample.
        let mut m = ServiceMetrics::default();
        m.record_initial(stats(0, 999), 0.9);
        m.record_epoch(stats(3, 4), 0.9);
        assert_eq!(m.epoch_percentiles(), EpochPercentiles { p50: 7, p95: 7, p99: 7 });
    }

    #[test]
    fn recent_epochs_ring_keeps_the_newest_32() {
        let mut r = RecentEpochs::default();
        assert!(r.is_empty());
        for i in 0..(RECENT_EPOCHS_CAP as u64 + 5) {
            r.push(RecentEpoch { epoch: i, ..Default::default() });
        }
        assert_eq!(r.len(), RECENT_EPOCHS_CAP);
        let epochs: Vec<u64> = r.iter().map(|e| e.epoch).collect();
        assert_eq!(epochs[0], 5, "oldest retained is the 6th pushed");
        assert_eq!(*epochs.last().unwrap(), RECENT_EPOCHS_CAP as u64 + 4);
        assert!(epochs.windows(2).all(|w| w[1] == w[0] + 1));
    }

    #[test]
    fn history_ring_is_bounded_and_drops_oldest() {
        let mut m = ServiceMetrics::default();
        m.record_initial(stats(0, 7), 0.9);
        let extra = 25;
        for i in 0..(EPOCH_HISTORY_CAP as u64 - 1 + extra) {
            m.record_epoch(stats(0, 1000 + i), 0.9);
        }
        let h = &m.epoch_history;
        assert_eq!(h.len(), EPOCH_HISTORY_CAP, "history must stay bounded");
        assert_eq!(h.evicted(), extra, "boot + {} oldest epochs evicted", extra - 1);
        // Oldest retained entry is update epoch `extra - 1`
        // (0-indexed), newest is the last pushed.
        assert_eq!(h[0].detect_ns, 1000 + extra - 1);
        assert_eq!(h[h.len() - 1].detect_ns, 1000 + EPOCH_HISTORY_CAP as u64 - 2 + extra);
        // iter() agrees with Index and stays oldest-to-newest.
        let walls: Vec<u64> = h.iter().map(|e| e.detect_ns).collect();
        assert_eq!(walls.len(), EPOCH_HISTORY_CAP);
        assert!(walls.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(walls[0], h[0].detect_ns);
        // Post-wrap the boot epoch is gone, so nothing is skipped:
        // max is the newest wall, and batches_applied still counts
        // every update epoch ever applied.
        assert_eq!(m.max_epoch_ns(), 1000 + EPOCH_HISTORY_CAP as u64 - 2 + extra);
        assert_eq!(m.batches_applied, EPOCH_HISTORY_CAP as u64 - 1 + extra);
    }
}
