//! The service's graph state: a current CSR plus everything needed to
//! mutate it in place, batch after batch, without steady-state
//! allocation (PR 3).
//!
//! [`GraphStore`] extends the zero-allocation workspace contract to the
//! service's lifetime: the current graph and a spare [`Csr`] form a
//! ping-pong pair — [`Csr::apply_batch_into`] compacts each batch into
//! the spare slot, which then *becomes* current — and the
//! [`DeltaScratch`] keeps every merge buffer across batches.  Once the
//! graph's high-water mark is reached, an update stream of steady size
//! churns with zero allocations; growth batches (new vertices — see
//! `graph::delta`) regrow the pair once and keep going.

use crate::graph::delta::{DeltaScratch, EdgeBatch};
use crate::graph::Csr;
use crate::parallel::pool::ParallelOpts;
use crate::parallel::team::Exec;

/// Owned, mutable-by-batches graph state of a `CommunityService`.
/// (Batch counting lives in `ServiceMetrics` — one counter, one apply
/// path.)
pub struct GraphStore {
    cur: Csr,
    spare: Csr,
    scratch: DeltaScratch,
}

impl GraphStore {
    pub fn new(g: Csr) -> Self {
        Self { cur: g, spare: Csr::default(), scratch: DeltaScratch::new() }
    }

    /// The current graph (the state queries' epochs are detected on).
    pub fn graph(&self) -> &Csr {
        &self.cur
    }

    pub fn num_vertices(&self) -> usize {
        self.cur.num_vertices()
    }

    /// Directed edge slots.
    pub fn num_edges(&self) -> usize {
        self.cur.num_edges()
    }

    /// Heap bytes reserved across the whole store: both ping-pong
    /// slots plus every merge buffer (PR 8 memory accounting — this is
    /// the service's long-lived graph footprint).
    pub fn reserved_bytes(&self) -> usize {
        self.cur.reserved_bytes() + self.spare.reserved_bytes() + self.scratch.reserved_bytes()
    }

    /// Heap bytes the *current* graph logically needs.  The gap to
    /// [`Self::reserved_bytes`] is the deliberate steady-state slack
    /// (spare slot + scratch high-water marks).
    pub fn used_bytes(&self) -> usize {
        self.cur.used_bytes()
    }

    /// Apply `batch` to the current graph on `exec` (growing the vertex
    /// set if the batch references new ids), reusing the scratch and
    /// the ping-pong pair.
    pub fn apply(&mut self, batch: &EdgeBatch, opts: ParallelOpts, exec: Exec) {
        self.cur
            .apply_batch_into(batch, &mut self.scratch, &mut self.spare, opts, exec);
        std::mem::swap(&mut self.cur, &mut self.spare);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{churn_batch, generate, GraphFamily};

    #[test]
    fn apply_matches_one_shot_path_across_a_timeline() {
        let g0 = generate(GraphFamily::Web, 9, 8);
        let mut store = GraphStore::new(g0.clone());
        let mut reference = g0;
        for i in 0..4 {
            let b = churn_batch(store.graph(), 0.02, 40 + i);
            let expect = reference.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
            store.apply(&b, ParallelOpts::default(), Exec::scoped());
            assert_eq!(store.graph(), &expect, "batch {i}");
            store.graph().validate().unwrap();
            reference = expect;
        }
    }

    #[test]
    fn shrinking_batches_keep_slot_storage() {
        // Pure deletions shrink the graph: both ping-pong slots and the
        // scratch stay allocation-stable once sized (the service's
        // steady-state contract; the delta layer asserts the same for
        // a single output CSR).
        let g0 = generate(GraphFamily::Web, 8, 4);
        let mut store = GraphStore::new(g0);
        let del_batch = |g: &Csr, seed: u64| {
            let mut c = churn_batch(g, 0.02, seed);
            c.insertions.clear();
            c
        };
        // Two batches size both slots.
        for i in 0..2 {
            let b = del_batch(store.graph(), 70 + i);
            store.apply(&b, ParallelOpts::default(), Exec::scoped());
        }
        let ptrs = (store.cur.targets.as_ptr(), store.spare.targets.as_ptr());
        for i in 2..5 {
            let b = del_batch(store.graph(), 70 + i);
            store.apply(&b, ParallelOpts::default(), Exec::scoped());
            // Swapped pairs only — never a fresh allocation.
            let now = (store.cur.targets.as_ptr(), store.spare.targets.as_ptr());
            assert!(
                now == ptrs || now == (ptrs.1, ptrs.0),
                "batch {i} reallocated a ping-pong slot"
            );
        }
    }

    #[test]
    fn growth_batches_extend_the_store() {
        let g0 = generate(GraphFamily::Road, 7, 2);
        let n = g0.num_vertices();
        let mut store = GraphStore::new(g0);
        let mut b = EdgeBatch::new();
        b.insert(0, (n + 2) as u32, 1.0);
        store.apply(&b, ParallelOpts::default(), Exec::scoped());
        assert_eq!(store.num_vertices(), n + 3);
        assert_eq!(store.graph().edges(n + 2).0, &[0]);
    }
}
