//! Long-lived community-detection service (PR 3 tentpole): streaming
//! ingest, epoch snapshots and a query surface over incremental
//! Louvain.
//!
//! The paper's CPU case rests on handling irregular, *shrinking*
//! workloads flexibly — exactly the shape of a service that ingests
//! edge churn continuously instead of clustering once.  This module is
//! the first top-level subsystem aimed at the ROADMAP north-star
//! *serving* story rather than paper-figure reproduction; it composes
//! the whole stack built by PRs 1–2:
//!
//! * [`store::GraphStore`] — the current [`Csr`] plus the
//!   [`DeltaScratch`](crate::graph::delta::DeltaScratch) and a
//!   ping-pong spare, so batch application stops allocating at steady
//!   state (and grows in place when a batch introduces new vertices);
//! * [`ingest::IngestBuffer`] — coalesces a stream of
//!   [`StreamOp`]s into [`EdgeBatch`]es under a max-ops / max-latency /
//!   explicit-commit [`BatchPolicy`];
//! * [`DynamicLouvain`] — re-detection per batch with a configurable
//!   [`SeedStrategy`] (warm starts + delta screening), its workspace
//!   backed by the *process-wide shared*
//!   [`Team`](crate::parallel::team::Team);
//! * [`snapshot::EpochSnapshot`] — the query surface: immutable,
//!   `Arc`-swapped epochs (`membership`, community sizes, modularity,
//!   stats), so reads never block ingest and never see a torn
//!   membership;
//! * [`metrics::ServiceMetrics`] — ingest throughput, per-epoch
//!   latency, quality drift.
//!
//! Streams come from `graph::io`'s update-stream format
//! ([`UpdateStreamReader`](crate::graph::io::UpdateStreamReader)), the
//! churn generator, or ad-hoc [`submit`](CommunityService::submit)
//! calls; `coordinator::service` replays churn timelines through a
//! service deterministically, and the `louvain_serve` binary drives a
//! file-backed stream end to end.
//!
//! ## Threading model
//!
//! One writer, many readers: `&mut self` ingest methods form the
//! single-threaded update loop (batch application and detection both
//! parallelize *internally* on the shared team); readers hold a
//! [`SnapshotHandle`] and query concurrently, epoch-consistently,
//! without ever taking the writer's locks.

pub mod delta;
pub mod ingest;
pub mod metrics;
pub mod snapshot;
pub mod store;

pub use delta::{epoch_delta, EpochDelta};
pub use ingest::{BatchPolicy, IngestBuffer};
pub use metrics::{RecentEpoch, RecentEpochs, ServiceMetrics, ServiceSummary};
pub use snapshot::{EpochSnapshot, EpochStats, SnapshotCell, SnapshotHandle};
pub use store::GraphStore;

use crate::graph::delta::{EdgeBatch, StreamOp};
use crate::graph::Csr;
use crate::louvain::dynamic::{DynamicLouvain, SeedStrategy};
use crate::louvain::params::LouvainParams;
use crate::parallel::scatter::scatter_count;
use std::sync::Arc;
use std::time::Instant;

/// Everything configurable about a [`CommunityService`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub params: LouvainParams,
    pub strategy: SeedStrategy,
    pub policy: BatchPolicy,
    /// Growth guard on the *stream* boundary: a submitted op with an
    /// endpoint id `>= max_vertices` is rejected (counted in
    /// [`ServiceMetrics::ops_rejected`]) instead of growing the graph.
    /// An **absolute** ceiling, deliberately: it is trivially invariant
    /// to where the batch policy cuts, and it bounds memory against
    /// *cumulative* corruption (ascending runaway ids), which any
    /// relative per-op allowance ratchets past.  `apply_batch` growth
    /// stays unbounded for programmatic callers; a long-lived service
    /// fed from a file or socket must not let corrupt lines march it
    /// toward 2^32 vertex rows.
    pub max_vertices: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            params: LouvainParams::default(),
            strategy: SeedStrategy::DeltaScreening,
            policy: BatchPolicy::default(),
            max_vertices: 1 << 26,
        }
    }
}

/// The long-lived service: owns the graph state, the detector and the
/// published epoch; see the [module docs](self).
pub struct CommunityService {
    store: GraphStore,
    detector: DynamicLouvain,
    buffer: IngestBuffer,
    cell: SnapshotHandle,
    metrics: ServiceMetrics,
    epoch: u64,
    max_vertices: usize,
}

impl CommunityService {
    /// Boot the service on `g0`: runs the initial full detection and
    /// publishes epoch 0 before returning, so the query surface is
    /// never empty.
    pub fn new(g0: Csr, cfg: ServiceConfig) -> Self {
        Self::new_with_clock(g0, cfg, Arc::new(crate::trace::SystemClock))
    }

    /// [`CommunityService::new`] with an explicit time source for the
    /// ingest latency trigger — tests inject a
    /// [`MockClock`](crate::trace::MockClock) so the max-latency flush
    /// path runs without real sleeps (PR 7).
    pub fn new_with_clock(g0: Csr, cfg: ServiceConfig, clock: Arc<dyn crate::trace::Clock>) -> Self {
        let n0 = g0.num_vertices();
        let mut detector = DynamicLouvain::new(cfg.params, cfg.strategy);
        let t0 = Instant::now();
        let first = detector.run_initial(&g0);
        let detect_ns = t0.elapsed().as_nanos() as u64;
        let stats = EpochStats {
            batch_ops: 0,
            affected_seeded: g0.num_vertices(),
            passes: first.passes,
            apply_ns: 0,
            detect_ns,
        };
        let sizes = community_sizes(&detector, &first.membership, first.num_communities);
        let snapshot = EpochSnapshot::new(
            0,
            g0.num_vertices(),
            g0.num_edges(),
            first.modularity,
            stats,
            first.membership,
            sizes,
        );
        let mut metrics = ServiceMetrics::default();
        metrics.record_initial(stats, snapshot.modularity);
        Self {
            store: GraphStore::new(g0),
            detector,
            buffer: IngestBuffer::with_clock(cfg.policy, clock),
            cell: Arc::new(SnapshotCell::new(snapshot)),
            metrics,
            epoch: 0,
            // A graph booted above the ceiling keeps working; the
            // guard then only blocks *further* growth.
            max_vertices: cfg.max_vertices.max(n0),
        }
    }

    /// The current epoch snapshot (readers prefer a [`handle`](Self::handle)).
    pub fn snapshot(&self) -> Arc<EpochSnapshot> {
        self.cell.load()
    }

    /// A shared reader handle: clone across threads; each
    /// [`SnapshotCell::load`] returns a complete epoch.
    pub fn handle(&self) -> SnapshotHandle {
        Arc::clone(&self.cell)
    }

    /// The current graph state (the one the *next* epoch will describe;
    /// the published epoch describes the state as of its batch).
    pub fn graph(&self) -> &Csr {
        self.store.graph()
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    pub fn strategy(&self) -> SeedStrategy {
        self.detector.strategy()
    }

    /// Latest published epoch id.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// OS workers spawned by the detector's (shared) team — `threads -
    /// 1`, once, for the service's whole lifetime.
    pub fn spawned_workers(&self) -> usize {
        self.detector.spawned_workers()
    }

    /// Ops buffered but not yet folded into an epoch.
    pub fn pending_ops(&self) -> usize {
        self.buffer.pending_ops()
    }

    /// Queue one op through the coalescing policy.  Returns the new
    /// epoch when this op triggered a flush (max-ops, max-latency or an
    /// explicit [`StreamOp::Commit`]), `None` while coalescing.
    ///
    /// Ops whose endpoints exceed the [`ServiceConfig::max_vertices`]
    /// growth guard are dropped (counted in
    /// [`ServiceMetrics::ops_rejected`]) — the stream is the untrusted
    /// boundary.
    pub fn submit(&mut self, op: StreamOp) -> Option<Arc<EpochSnapshot>> {
        let max_id = match op {
            StreamOp::Insert(u, v, _) | StreamOp::Delete(u, v) => Some(u.max(v)),
            StreamOp::Commit => None,
        };
        if let Some(id) = max_id {
            // An absolute ceiling: admission is independent of both the
            // batch-cut position and everything admitted before.
            if id as usize >= self.max_vertices {
                self.metrics.ops_rejected += 1;
                crate::obs::sites::service_ops_rejected().inc();
                return None;
            }
            self.metrics.ops_ingested += 1;
            crate::obs::sites::service_ops_ingested().inc();
        }
        if self.buffer.push(op) {
            self.flush()
        } else {
            None
        }
    }

    /// Flush if a policy trigger is due — the driver-side tick that
    /// makes the **max-latency** bound real: `push` only evaluates
    /// triggers when an op arrives, so a stream that goes quiet needs
    /// its driver to call `poll` periodically (or `flush` at
    /// end-of-stream, as [`Self::ingest_stream`] does).
    pub fn poll(&mut self) -> Option<Arc<EpochSnapshot>> {
        if self.buffer.due() {
            self.flush()
        } else {
            None
        }
    }

    /// Cut the pending ops into an epoch now, regardless of policy.
    /// `None` when nothing is pending (a commit on an empty buffer is
    /// not an epoch).
    pub fn flush(&mut self) -> Option<Arc<EpochSnapshot>> {
        if self.buffer.is_empty() {
            return None;
        }
        let batch = self.buffer.take();
        Some(self.apply_and_publish(&batch))
    }

    /// Ingest a pre-cut batch directly (the churn-timeline replay
    /// path), bypassing the coalescing buffer: one batch, one epoch.
    pub fn ingest_batch(&mut self, batch: &EdgeBatch) -> Arc<EpochSnapshot> {
        self.metrics.ops_ingested += batch.len() as u64;
        crate::obs::sites::service_ops_ingested().add(batch.len() as u64);
        self.apply_and_publish(batch)
    }

    /// Drain a fallible op stream (e.g. an
    /// [`UpdateStreamReader`](crate::graph::io::UpdateStreamReader))
    /// through the buffer; the trailing partial batch is flushed at end
    /// of stream.  Returns the number of epochs published.
    pub fn ingest_stream<E>(
        &mut self,
        ops: impl IntoIterator<Item = Result<StreamOp, E>>,
    ) -> Result<usize, E> {
        let mut epochs = 0usize;
        for op in ops {
            if self.submit(op?).is_some() {
                epochs += 1;
            }
        }
        if self.flush().is_some() {
            epochs += 1;
        }
        Ok(epochs)
    }

    /// Infallible-stream convenience over [`Self::ingest_stream`].
    pub fn ingest_ops(&mut self, ops: impl IntoIterator<Item = StreamOp>) -> usize {
        let infallible = ops.into_iter().map(Ok::<_, std::convert::Infallible>);
        match self.ingest_stream(infallible) {
            Ok(n) => n,
            Err(e) => match e {},
        }
    }

    /// The update loop body: apply the batch to the store, re-detect
    /// with the configured strategy, publish the next epoch.
    fn apply_and_publish(&mut self, batch: &EdgeBatch) -> Arc<EpochSnapshot> {
        use crate::trace::{self, Category};
        let next_epoch = self.epoch + 1;
        let t_apply = Instant::now();
        {
            let _sp = trace::span(
                "epoch.apply",
                Category::Service,
                [next_epoch, batch.len() as u64, 0, 0],
            );
            let Self { store, detector, .. } = self;
            detector.with_team_exec(|exec, opts| store.apply(batch, opts, exec));
        }
        let apply_ns = t_apply.elapsed().as_nanos() as u64;

        let t_detect = Instant::now();
        let mut detect_span =
            trace::span("epoch.detect", Category::Service, [next_epoch, 0, 0, 0]);
        let outcome = {
            let Self { store, detector, .. } = self;
            detector.update(store.graph(), batch)
        };
        if let Some(g) = detect_span.as_mut() {
            g.args = [
                next_epoch,
                outcome.affected_seeded as u64,
                outcome.result.passes as u64,
                0,
            ];
        }
        drop(detect_span);
        let detect_ns = t_detect.elapsed().as_nanos() as u64;

        self.epoch += 1;
        let stats = EpochStats {
            batch_ops: batch.len(),
            affected_seeded: outcome.affected_seeded,
            passes: outcome.result.passes,
            apply_ns,
            detect_ns,
        };
        let _publish_span = trace::span(
            "epoch.publish",
            Category::Service,
            [next_epoch, self.store.num_vertices() as u64, 0, 0],
        );
        let sizes = community_sizes(
            &self.detector,
            &outcome.result.membership,
            outcome.result.num_communities,
        );
        let snapshot = EpochSnapshot::new(
            self.epoch,
            self.store.num_vertices(),
            self.store.num_edges(),
            outcome.result.modularity,
            stats,
            outcome.result.membership,
            sizes,
        );
        self.metrics.record_epoch(stats, snapshot.modularity);
        // Live-telemetry mirrors (PR 8): one histogram record, one
        // counter bump and two gauge writes per *epoch* — nothing here
        // is per-op.
        {
            use crate::obs::sites;
            sites::service_epochs_published().inc();
            sites::service_epoch_latency().record(stats.wall_ns());
            sites::service_quality_drift_micro()
                .set((self.metrics.quality_drift() * 1e6) as i64);
            sites::mem_bytes("reserved", "graph_store").set(self.store.reserved_bytes() as i64);
            sites::mem_bytes("used", "graph_store").set(self.store.used_bytes() as i64);
        }
        let arc = Arc::new(snapshot);
        self.cell.store(Arc::clone(&arc));
        arc
    }
}

/// Community-size histogram on the detector's team (dense membership →
/// member counts; the scatter idiom of the warm-start Σ' init).
fn community_sizes(detector: &DynamicLouvain, membership: &[u32], n_comm: usize) -> Vec<usize> {
    let mut sizes = vec![0usize; n_comm];
    detector.with_team_exec(|exec, opts| {
        scatter_count(membership, &mut sizes, opts, exec);
    });
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{churn_batch, generate, GraphFamily};

    fn quick_cfg(strategy: SeedStrategy) -> ServiceConfig {
        ServiceConfig { strategy, ..Default::default() }
    }

    #[test]
    fn boot_publishes_a_complete_epoch_zero() {
        let g = generate(GraphFamily::Web, 9, 1);
        let svc = CommunityService::new(g.clone(), ServiceConfig::default());
        let snap = svc.snapshot();
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.vertices, g.num_vertices());
        assert_eq!(snap.edges, g.num_edges());
        snap.validate().unwrap();
        assert!(snap.modularity > 0.5);
        assert_eq!(svc.epoch(), 0);
        assert_eq!(svc.metrics().epoch_history.len(), 1);
    }

    #[test]
    fn ingest_batch_publishes_and_updates_state() {
        let g = generate(GraphFamily::Web, 9, 2);
        let mut svc = CommunityService::new(g.clone(), quick_cfg(SeedStrategy::DeltaScreening));
        let b = churn_batch(&g, 0.02, 7);
        let expect = {
            use crate::parallel::pool::ParallelOpts;
            use crate::parallel::team::Exec;
            g.apply_batch(&b, ParallelOpts::default(), Exec::scoped())
        };
        let snap = svc.ingest_batch(&b);
        assert_eq!(snap.epoch, 1);
        assert_eq!(svc.graph(), &expect);
        assert_eq!(snap.vertices, expect.num_vertices());
        snap.validate().unwrap();
        assert_eq!(svc.metrics().ops_ingested, b.len() as u64);
        assert_eq!(svc.metrics().batches_applied, 1);
        assert!(svc.metrics().total_wall_ns() > 0);
    }

    #[test]
    fn submit_coalesces_until_policy_fires() {
        let g = generate(GraphFamily::Road, 8, 3);
        let cfg = ServiceConfig {
            policy: BatchPolicy::by_ops(4),
            ..quick_cfg(SeedStrategy::NaiveDynamic)
        };
        let mut svc = CommunityService::new(g, cfg);
        let mut epochs = 0;
        for i in 0..10u32 {
            if svc.submit(StreamOp::Insert(i, i + 1, 1.0)).is_some() {
                epochs += 1;
            }
        }
        assert_eq!(epochs, 2, "10 ops at max-ops 4 → 2 flushes");
        assert_eq!(svc.pending_ops(), 2);
        // Commit cuts the partial batch; empty commits publish nothing.
        assert!(svc.submit(StreamOp::Commit).is_some());
        assert_eq!(svc.epoch(), 3);
        assert!(svc.submit(StreamOp::Commit).is_none());
        assert!(svc.flush().is_none());
        assert_eq!(svc.epoch(), 3);
    }

    #[test]
    fn queries_see_only_published_epochs() {
        let g = generate(GraphFamily::Web, 8, 5);
        let cfg = ServiceConfig { policy: BatchPolicy::by_ops(100), ..Default::default() };
        let mut svc = CommunityService::new(g, cfg);
        let handle = svc.handle();
        let before = handle.load();
        // Buffered-but-unflushed ops must not leak into the surface.
        svc.submit(StreamOp::Insert(0, 7, 1.0));
        svc.submit(StreamOp::Delete(0, 1));
        assert_eq!(handle.load().epoch, before.epoch);
        assert_eq!(handle.load().membership(), before.membership());
        let flushed = svc.flush().unwrap();
        assert_eq!(handle.load().epoch, flushed.epoch);
        assert_eq!(flushed.epoch, 1);
    }

    #[test]
    fn growth_ops_extend_the_service_vertex_set() {
        let g = generate(GraphFamily::Road, 8, 9);
        let n = g.num_vertices();
        let mut svc = CommunityService::new(g, quick_cfg(SeedStrategy::DeltaScreening));
        let mut b = EdgeBatch::new();
        b.insert(0, n as u32, 1.0);
        b.insert(n as u32, (n + 1) as u32, 1.0);
        let snap = svc.ingest_batch(&b);
        assert_eq!(snap.vertices, n + 2);
        snap.validate().unwrap();
        assert!(snap.community_of(n + 1).is_some());
        assert!(snap.community_of(n + 2).is_none());
        // Warm path, not a cold fallback: the batch only seeds a
        // neighbourhood.
        assert!(snap.stats.affected_seeded < n);
    }

    #[test]
    fn growth_guard_rejects_runaway_ids() {
        // Corrupt stream lines must not march the graph toward 2^32
        // vertex rows — neither one huge id nor an ascending sequence
        // (the ceiling is absolute, so it cannot be ratcheted past).
        let g = generate(GraphFamily::Road, 7, 1);
        let n = g.num_vertices();
        let cfg =
            ServiceConfig { max_vertices: n + 16, ..quick_cfg(SeedStrategy::NaiveDynamic) };
        let mut svc = CommunityService::new(g, cfg);
        assert!(svc.submit(StreamOp::Insert(0, u32::MAX, 1.0)).is_none());
        assert!(svc.submit(StreamOp::Delete(0, (n + 16) as u32)).is_none());
        assert_eq!(svc.metrics().ops_rejected, 2);
        assert_eq!(svc.metrics().ops_ingested, 0);
        assert_eq!(svc.pending_ops(), 0, "rejected ops must not be queued");
        // Just inside the guard is still accepted (growth is a feature).
        assert!(svc.submit(StreamOp::Insert(0, (n + 15) as u32, 1.0)).is_none());
        assert_eq!(svc.metrics().ops_ingested, 1);
        let snap = svc.flush().unwrap();
        assert_eq!(snap.vertices, n + 16);
        snap.validate().unwrap();
        // Admitting growth does not raise the ceiling: an ascending
        // corrupt sequence stays rejected after the flush.
        assert!(svc.submit(StreamOp::Insert(0, (n + 16) as u32, 1.0)).is_none());
        assert_eq!(svc.metrics().ops_rejected, 3);
    }

    #[test]
    fn poll_fires_the_latency_trigger_on_an_idle_stream() {
        use std::time::Duration;
        let g = generate(GraphFamily::Road, 7, 5);
        let cfg = ServiceConfig {
            // Huge max-ops, small latency budget: only the clock
            // trigger can cut this batch — and once the stream goes
            // quiet, only a poll() can observe it.
            policy: BatchPolicy {
                max_ops: usize::MAX,
                max_latency: Duration::from_millis(20),
            },
            ..quick_cfg(SeedStrategy::NaiveDynamic)
        };
        let mut svc = CommunityService::new(g, cfg);
        assert!(svc.poll().is_none(), "nothing pending, nothing to publish");
        let epoch = match svc.submit(StreamOp::Insert(0, 1, 1.0)) {
            // Pathological scheduling stall between push and its due()
            // check can flush immediately; the contract still held.
            Some(snap) => snap,
            None => {
                // Stream idle, op pending, budget expiring: poll is the
                // only thing that can publish.
                std::thread::sleep(Duration::from_millis(40));
                svc.poll().expect("idle stream: poll must fire the latency trigger")
            }
        };
        assert_eq!(epoch.epoch, 1);
        assert_eq!(epoch.stats.batch_ops, 1);
        assert!(svc.poll().is_none(), "buffer drained");
    }

    #[test]
    fn mock_clock_poll_flushes_the_idle_stream_without_sleeping() {
        // The no-sleep twin of the test above (PR 7): a MockClock
        // injected through new_with_clock drives the max-latency bound
        // deterministically.
        use crate::trace::MockClock;
        use std::time::Duration;
        let g = generate(GraphFamily::Road, 7, 5);
        let cfg = ServiceConfig {
            policy: BatchPolicy {
                max_ops: usize::MAX,
                max_latency: Duration::from_millis(20),
            },
            ..quick_cfg(SeedStrategy::NaiveDynamic)
        };
        let clock = Arc::new(MockClock::new());
        let mut svc = CommunityService::new_with_clock(g, cfg, clock.clone());
        assert!(svc.submit(StreamOp::Insert(0, 1, 1.0)).is_none(), "budget not yet spent");
        clock.advance(Duration::from_millis(19));
        assert!(svc.poll().is_none(), "1ms of budget left");
        clock.advance(Duration::from_millis(1));
        let snap = svc.poll().expect("budget exhausted: poll must publish");
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.stats.batch_ops, 1);
        assert!(svc.poll().is_none(), "buffer drained");
    }

    #[test]
    fn coalesced_insert_then_delete_stays_deleted_wherever_the_cut_lands() {
        // End-to-end form of the ingest-buffer temporal contract: the
        // same op log must converge to the same graph whether the ops
        // share one epoch or split across two.
        let g = generate(GraphFamily::Road, 7, 8);
        let log = [
            StreamOp::Insert(0, 5, 9.0),
            StreamOp::Delete(0, 5),
            StreamOp::Insert(2, 3, 4.0),
        ];
        let run = |max_ops: usize| {
            let cfg = ServiceConfig {
                policy: BatchPolicy::by_ops(max_ops),
                ..quick_cfg(SeedStrategy::NaiveDynamic)
            };
            let mut svc = CommunityService::new(g.clone(), cfg);
            svc.ingest_ops(log);
            svc
        };
        let coarse = run(100); // one epoch holds all three ops
        let fine = run(1); // one epoch per op
        assert_eq!(coarse.graph(), fine.graph(), "batch-cut position changed the graph");
        assert!(!coarse.graph().edges(0).0.contains(&5), "deleted edge resurrected");
        assert!(coarse.graph().edges(2).0.contains(&3));
    }

    #[test]
    fn spawns_stay_o1_across_the_service_lifetime() {
        let g = generate(GraphFamily::Web, 9, 11);
        let cfg = ServiceConfig {
            params: LouvainParams::with_threads(4),
            ..quick_cfg(SeedStrategy::DeltaScreening)
        };
        let mut svc = CommunityService::new(g, cfg);
        for i in 0..3 {
            let b = churn_batch(svc.graph(), 0.02, 60 + i);
            svc.ingest_batch(&b);
        }
        // threads - 1, once — across boot + batches + snapshot stats.
        assert_eq!(svc.detector.spawned_workers(), 3);
    }
}
