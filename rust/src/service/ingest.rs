//! Ingest-side batching: coalesce a stream of edge ops into
//! [`EdgeBatch`]es under a flush policy (PR 3).
//!
//! The dynamic-Louvain economics only work per *batch* — screening and
//! warm-starting amortize one detection pass over many ops — so the
//! service never detects per op.  [`IngestBuffer`] accumulates ops and
//! declares a flush when any of three triggers fires:
//!
//! * **max-ops** — the pending batch reached [`BatchPolicy::max_ops`]
//!   (bounds detection work per epoch);
//! * **max-latency** — the *oldest* pending op has waited
//!   [`BatchPolicy::max_latency`] (bounds staleness of the query
//!   surface under a *trickling* stream; a stream that goes fully idle
//!   needs the driver's `CommunityService::poll` tick, since `push`
//!   only runs when an op arrives);
//! * **explicit commit** — the stream carried a
//!   [`StreamOp::Commit`] marker (deterministic epoch boundaries for
//!   replay files and tests; replays that must be bit-reproducible use
//!   commits or max-ops, never the wall-clock trigger).
//!
//! The buffer only *decides*; the service owns applying the batch and
//! publishing the epoch.
//!
//! ## Temporal semantics under coalescing
//!
//! [`EdgeBatch`] applies *all* deletions before *all* insertions —
//! within one batch, `delete + insert` means "replace".  A raw op log
//! is *temporal*: `insert` then `delete` of the same pair must end
//! deleted, wherever the policy cuts the batch.  The buffer therefore
//! cancels pending insertions of a pair when a deletion of that pair
//! arrives (they are temporally dead — the delete removes the edge
//! regardless), so the coalesced batch reproduces the log's sequential
//! meaning exactly: ops before the delete vanish, inserts after it
//! replace (which is precisely the batch rule).

use crate::graph::delta::{EdgeBatch, StreamOp};
use crate::trace::{Clock, SystemClock};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// When the pending batch is cut into an epoch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush once this many undirected ops are pending.
    pub max_ops: usize,
    /// Flush once the oldest pending op has waited this long.
    /// Evaluated when an op arrives ([`IngestBuffer::push`]) and on
    /// explicit [`IngestBuffer::due`] checks — a stream that goes
    /// quiet needs a driver-side tick (`CommunityService::poll`) for
    /// this bound to hold; `push` alone cannot fire on silence.
    pub max_latency: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 4096 ops ≈ one screening seed worth of work on the planted
        // families; 50 ms keeps interactive queries fresh.
        Self { max_ops: 4096, max_latency: Duration::from_millis(50) }
    }
}

impl BatchPolicy {
    /// A policy that flushes only on max-ops / explicit commits —
    /// deterministic for replays regardless of machine speed.
    pub fn by_ops(max_ops: usize) -> Self {
        Self { max_ops: max_ops.max(1), max_latency: Duration::MAX }
    }
}

/// Op accumulator applying a [`BatchPolicy`].
pub struct IngestBuffer {
    policy: BatchPolicy,
    pending: EdgeBatch,
    /// Canonical `(min, max)` pair → indices of its pending insertions,
    /// so a deletion cancels them (temporal semantics, module docs) in
    /// O(its own inserts) instead of rescanning the whole list.
    insert_idx: HashMap<(u32, u32), Vec<u32>>,
    /// Tombstones parallel to `pending.insertions`; compacted once at
    /// [`Self::take`], keeping ingest O(1) amortized per op.
    dead: Vec<bool>,
    dead_count: usize,
    /// Arrival time (clock ns) of the oldest pending op (latency
    /// trigger).
    oldest_ns: Option<u64>,
    /// Time source for the latency trigger — `SystemClock` in
    /// production, injectable ([`IngestBuffer::with_clock`]) so tests
    /// drive the max-latency path without real sleeps (PR 7; the trace
    /// subsystem shares the same `Clock` abstraction).
    clock: Arc<dyn Clock>,
}

fn canonical(u: u32, v: u32) -> (u32, u32) {
    if u <= v {
        (u, v)
    } else {
        (v, u)
    }
}

impl IngestBuffer {
    pub fn new(policy: BatchPolicy) -> Self {
        Self::with_clock(policy, Arc::new(SystemClock))
    }

    /// [`IngestBuffer::new`] with an explicit time source (tests pass a
    /// [`MockClock`](crate::trace::MockClock)).
    pub fn with_clock(policy: BatchPolicy, clock: Arc<dyn Clock>) -> Self {
        Self {
            policy,
            pending: EdgeBatch::new(),
            insert_idx: HashMap::new(),
            dead: Vec::new(),
            dead_count: 0,
            oldest_ns: None,
            clock,
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Queue one op; returns `true` when the batch should flush *now*
    /// ([`StreamOp::Commit`] queues nothing and always returns `true`).
    pub fn push(&mut self, op: StreamOp) -> bool {
        if matches!(op, StreamOp::Commit) {
            return true;
        }
        if self.pending.is_empty() {
            self.oldest_ns = Some(self.clock.now_ns());
        }
        match op {
            StreamOp::Insert(u, v, w) => {
                self.insert_idx
                    .entry(canonical(u, v))
                    .or_default()
                    .push(self.pending.insertions.len() as u32);
                self.dead.push(false);
                self.pending.insert(u, v, w);
            }
            StreamOp::Delete(u, v) => {
                // Cancel temporally-earlier insertions of this pair
                // (module docs) before queueing the delete.
                if let Some(idxs) = self.insert_idx.remove(&canonical(u, v)) {
                    for i in idxs {
                        self.dead[i as usize] = true;
                        self.dead_count += 1;
                    }
                }
                self.pending.delete(u, v);
            }
            StreamOp::Commit => unreachable!("handled above"),
        }
        self.due()
    }

    /// Whether a trigger has fired for the pending ops.
    pub fn due(&self) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        // u128 waiting-time compare: `Duration::MAX.as_nanos()` (the
        // by_ops sentinel) overflows u64, and must never fire.
        let waited = |t: u64| {
            u128::from(self.clock.now_ns().saturating_sub(t)) >= self.policy.max_latency.as_nanos()
        };
        self.pending.len() >= self.policy.max_ops
            || self.oldest_ns.map(waited).unwrap_or(false)
    }

    pub fn pending_ops(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Hand the pending batch over (leaving the buffer empty), dropping
    /// delete-cancelled insertions.  The service calls this on flush;
    /// callers draining a stream manually use it for the trailing
    /// partial batch.
    pub fn take(&mut self) -> EdgeBatch {
        self.oldest_ns = None;
        self.insert_idx.clear();
        let mut batch = std::mem::take(&mut self.pending);
        // Coalescing wins are invisible in the batch itself — count the
        // cancelled insertions for the live registry (PR 8).
        crate::obs::sites::service_ops_coalesced().add(self.dead_count as u64);
        if self.dead_count > 0 {
            let dead = std::mem::take(&mut self.dead);
            // retain visits in order, so the parallel tombstone list
            // lines up index-for-index.
            let mut it = dead.iter();
            batch.insertions.retain(|_| !*it.next().expect("tombstones parallel insertions"));
            self.dead_count = 0;
        } else {
            self.dead.clear();
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_on_max_ops() {
        let mut buf = IngestBuffer::new(BatchPolicy::by_ops(3));
        assert!(!buf.push(StreamOp::Insert(0, 1, 1.0)));
        assert!(!buf.push(StreamOp::Delete(1, 2)));
        assert!(buf.push(StreamOp::Insert(2, 3, 1.0)));
        let b = buf.take();
        assert_eq!(b.len(), 3);
        assert!(buf.is_empty());
        assert!(!buf.due());
    }

    #[test]
    fn commit_forces_flush_without_queueing() {
        let mut buf = IngestBuffer::new(BatchPolicy::by_ops(100));
        buf.push(StreamOp::Insert(0, 1, 1.0));
        assert!(buf.push(StreamOp::Commit));
        assert_eq!(buf.pending_ops(), 1, "commit carries no edge");
        // A commit with nothing pending is still a flush signal; the
        // service skips publishing when take() would be empty.
        let mut empty = IngestBuffer::new(BatchPolicy::by_ops(100));
        assert!(empty.push(StreamOp::Commit));
        assert!(empty.is_empty());
    }

    #[test]
    fn latency_trigger_fires_on_old_ops() {
        let mut buf = IngestBuffer::new(BatchPolicy {
            max_ops: usize::MAX,
            max_latency: Duration::from_millis(0),
        });
        // Zero latency budget: the first op is immediately due.
        assert!(buf.push(StreamOp::Insert(0, 1, 1.0)));
        assert!(buf.due());
        buf.take();
        assert!(!buf.due(), "empty buffer is never due");
    }

    #[test]
    fn mock_clock_drives_the_latency_trigger_without_sleeping() {
        use crate::trace::MockClock;
        let clock = Arc::new(MockClock::new());
        let mut buf = IngestBuffer::with_clock(
            BatchPolicy { max_ops: usize::MAX, max_latency: Duration::from_millis(50) },
            clock.clone(),
        );
        assert!(!buf.push(StreamOp::Insert(0, 1, 1.0)));
        clock.advance(Duration::from_millis(49));
        assert!(!buf.due(), "49ms < 50ms budget");
        clock.advance(Duration::from_millis(1));
        assert!(buf.due(), "oldest op has now waited the full budget");
        buf.take();
        assert!(!buf.due());
        // The oldest-op anchor resets per batch, not per push.
        buf.push(StreamOp::Insert(2, 3, 1.0));
        clock.advance(Duration::from_millis(30));
        buf.push(StreamOp::Insert(4, 5, 1.0));
        clock.advance(Duration::from_millis(30));
        assert!(buf.due(), "60ms since the *oldest* op, 30ms since the newest");
    }

    #[test]
    fn by_ops_policy_ignores_the_clock() {
        let buf = IngestBuffer::new(BatchPolicy::by_ops(10));
        assert_eq!(buf.policy().max_latency, Duration::MAX);
        // `Duration::MAX.as_nanos()` overflows u64 — the trigger compares
        // in u128 so the sentinel can never fire, even at clock extremes.
        use crate::trace::MockClock;
        let clock = Arc::new(MockClock::new());
        let mut buf = IngestBuffer::with_clock(BatchPolicy::by_ops(10), clock.clone());
        buf.push(StreamOp::Insert(0, 1, 1.0));
        clock.set_ns(u64::MAX);
        assert!(!buf.due(), "by_ops never flushes on time");
    }

    #[test]
    fn delete_cancels_earlier_inserts_of_the_pair() {
        // Temporal log: insert (1,2) then delete it — coalesced into one
        // batch, the edge must end *deleted* (the batch layer's
        // delete-before-insert rule would otherwise resurrect it).
        let mut buf = IngestBuffer::new(BatchPolicy::by_ops(100));
        buf.push(StreamOp::Insert(1, 2, 5.0));
        buf.push(StreamOp::Insert(2, 1, 3.0)); // same undirected pair
        buf.push(StreamOp::Insert(3, 4, 1.0)); // unrelated, must survive
        buf.push(StreamOp::Delete(1, 2));
        let b = buf.take();
        assert_eq!(b.insertions, vec![(3, 4, 1.0)]);
        assert_eq!(b.deletions, vec![(1, 2)]);

        // Insert *after* the delete: batch replace == temporal order.
        buf.push(StreamOp::Delete(5, 6));
        buf.push(StreamOp::Insert(5, 6, 2.0));
        let b2 = buf.take();
        assert_eq!(b2.insertions, vec![(5, 6, 2.0)]);
        assert_eq!(b2.deletions, vec![(5, 6)]);

        // take() reset the pair set: a fresh insert of (1,2) is kept.
        buf.push(StreamOp::Insert(1, 2, 7.0));
        assert_eq!(buf.take().insertions, vec![(1, 2, 7.0)]);
    }
}
