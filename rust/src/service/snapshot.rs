//! Immutable epoch snapshots and the atomically-swapped cell readers
//! hold (PR 3).
//!
//! The service's query surface is *epoch-consistent*: every detection
//! pass publishes one [`EpochSnapshot`] — the renumbered membership
//! plus everything a query needs (community sizes, modularity, graph
//! shape, timing) — as a fresh `Arc` swapped into the [`SnapshotCell`].
//! Readers clone the `Arc` and query at leisure; they can *never*
//! observe a half-updated membership, because snapshots are immutable
//! and the swap is a single pointer store.  Reads never wait on batch
//! application or detection — the cell's lock is held only for the
//! pointer copy on either side.

use std::sync::{Arc, Mutex};

/// Per-epoch bookkeeping published alongside the membership (feeds the
/// service metrics and the bench's epoch-latency cells).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochStats {
    /// Undirected ops in the batch that produced this epoch (0 for the
    /// initial epoch).
    pub batch_ops: usize,
    /// Vertices seeded as affected by the detection strategy.
    pub affected_seeded: usize,
    /// Louvain passes of the detection run.
    pub passes: usize,
    /// Wall time applying the batch to the CSR.
    pub apply_ns: u64,
    /// Wall time of the (seeded) detection run.
    pub detect_ns: u64,
}

impl EpochStats {
    /// Ingest-to-publish latency of this epoch.
    pub fn wall_ns(&self) -> u64 {
        self.apply_ns + self.detect_ns
    }
}

/// One complete, immutable detection result over one graph state.
#[derive(Clone, Debug)]
pub struct EpochSnapshot {
    /// Monotone epoch id (0 = the initial full run).
    pub epoch: u64,
    /// Vertices of the graph this epoch describes.
    pub vertices: usize,
    /// Directed edge slots of that graph.
    pub edges: usize,
    /// Modularity of `membership` on that graph.
    pub modularity: f64,
    pub stats: EpochStats,
    /// Dense renumbered membership (`membership[v] < num_communities`).
    membership: Vec<u32>,
    /// Member count per dense community id.
    community_sizes: Vec<usize>,
}

impl EpochSnapshot {
    /// Assemble a snapshot; `community_sizes.len()` is `|Γ|`.
    pub(crate) fn new(
        epoch: u64,
        vertices: usize,
        edges: usize,
        modularity: f64,
        stats: EpochStats,
        membership: Vec<u32>,
        community_sizes: Vec<usize>,
    ) -> Self {
        Self { epoch, vertices, edges, modularity, stats, membership, community_sizes }
    }

    /// Full-resolution membership (dense community ids).
    pub fn membership(&self) -> &[u32] {
        &self.membership
    }

    /// Community of vertex `v`, or `None` past this epoch's vertex set
    /// (ids the service hasn't seen yet — growth lands next epoch).
    pub fn community_of(&self, v: usize) -> Option<u32> {
        self.membership.get(v).copied()
    }

    pub fn num_communities(&self) -> usize {
        self.community_sizes.len()
    }

    /// Member count of dense community `c` (0 if out of range).
    pub fn community_size(&self, c: u32) -> usize {
        self.community_sizes.get(c as usize).copied().unwrap_or(0)
    }

    pub fn community_sizes(&self) -> &[usize] {
        &self.community_sizes
    }

    /// Internal-consistency check: the invariant every published
    /// snapshot upholds (and the torn-read test hammers): membership
    /// covers exactly `vertices` slots, ids are dense in `|Γ|`, and the
    /// size histogram accounts for every vertex.
    pub fn validate(&self) -> Result<(), String> {
        if self.membership.len() != self.vertices {
            return Err(format!(
                "membership len {} != vertices {}",
                self.membership.len(),
                self.vertices
            ));
        }
        let nc = self.community_sizes.len();
        if let Some(&c) = self.membership.iter().find(|&&c| c as usize >= nc) {
            return Err(format!("community id {c} out of range (|Γ|={nc})"));
        }
        let total: usize = self.community_sizes.iter().sum();
        if total != self.vertices {
            return Err(format!("sizes sum {total} != vertices {}", self.vertices));
        }
        if self.vertices > 0 && self.community_sizes.iter().any(|&s| s == 0) {
            return Err("empty community in a dense renumbering".into());
        }
        if !self.modularity.is_finite() {
            return Err(format!("non-finite modularity {}", self.modularity));
        }
        Ok(())
    }
}

/// The swap point between the ingest loop and readers: holds the
/// current epoch's `Arc`.  `load` and `store` each hold the lock only
/// long enough to copy the pointer, so queries never block behind a
/// detection pass (there is no `ArcSwap` in the offline registry; a
/// `Mutex<Arc<_>>` pointer swap is its std spelling).
#[derive(Debug)]
pub struct SnapshotCell {
    cur: Mutex<Arc<EpochSnapshot>>,
}

/// What readers hold: a shared handle to the service's snapshot cell.
/// Clone freely; send across threads.
pub type SnapshotHandle = Arc<SnapshotCell>;

impl SnapshotCell {
    pub fn new(first: EpochSnapshot) -> Self {
        Self { cur: Mutex::new(Arc::new(first)) }
    }

    /// The current epoch (an `Arc` clone — O(1), non-blocking in
    /// practice).
    pub fn load(&self) -> Arc<EpochSnapshot> {
        Arc::clone(&self.cur.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Publish a new epoch (the ingest side only).
    pub(crate) fn store(&self, next: Arc<EpochSnapshot>) {
        *self.cur.lock().unwrap_or_else(|e| e.into_inner()) = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(epoch: u64, membership: Vec<u32>, sizes: Vec<usize>) -> EpochSnapshot {
        let n = membership.len();
        EpochSnapshot::new(epoch, n, 2 * n, 0.5, EpochStats::default(), membership, sizes)
    }

    #[test]
    fn queries_and_validation() {
        let s = snap(3, vec![0, 1, 0, 2, 1], vec![2, 2, 1]);
        s.validate().unwrap();
        assert_eq!(s.community_of(0), Some(0));
        assert_eq!(s.community_of(99), None);
        assert_eq!(s.num_communities(), 3);
        assert_eq!(s.community_size(1), 2);
        assert_eq!(s.community_size(9), 0);
        assert_eq!(s.membership(), &[0, 1, 0, 2, 1]);
    }

    #[test]
    fn validate_catches_inconsistencies() {
        // Wrong vertex count.
        let mut s = snap(0, vec![0, 0], vec![2]);
        s.vertices = 3;
        assert!(s.validate().is_err());
        // Out-of-range id.
        assert!(snap(0, vec![0, 5], vec![2]).validate().is_err());
        // Histogram mismatch.
        assert!(snap(0, vec![0, 0], vec![1, 1]).validate().is_err());
        // Empty community.
        assert!(snap(0, vec![0, 0], vec![2, 0]).validate().is_err());
    }

    #[test]
    fn cell_swaps_whole_epochs() {
        let cell = SnapshotCell::new(snap(0, vec![0], vec![1]));
        let a = cell.load();
        assert_eq!(a.epoch, 0);
        cell.store(Arc::new(snap(1, vec![0, 0], vec![2])));
        // The old Arc is still fully intact for readers that hold it.
        assert_eq!(a.epoch, 0);
        assert_eq!(a.membership(), &[0]);
        let b = cell.load();
        assert_eq!(b.epoch, 1);
        assert_eq!(b.membership(), &[0, 0]);
    }
}
