//! Membership deltas between consecutive [`EpochSnapshot`]s (PR 9).
//!
//! Subscribers to the serving daemon do not want the full `Vec<u32>`
//! membership on every epoch — a small churn batch typically reassigns
//! a handful of vertices, and the delta-screening strategy's affected
//! seed set is *exactly* the set of vertices whose community changed
//! (ROADMAP "snapshot deltas" item).  [`epoch_delta`] computes that
//! set between two snapshots; [`EpochDelta::apply_to`] replays it onto
//! a mirror membership so a consumer can reconstruct every epoch from
//! one full snapshot plus the delta stream.
//!
//! Renumbering caveat: community ids are *dense per epoch* — an
//! aggregation pass or a detection run can relabel communities even
//! where the partition barely moved.  A delta is therefore only
//! meaningful against the exact `base_epoch` it was computed from;
//! the server sends a full snapshot instead whenever the delta would
//! be no cheaper than the membership itself ([`EpochDelta::is_major`]).

use super::snapshot::EpochSnapshot;

/// The membership changes from one published epoch to the next.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EpochDelta {
    /// Epoch this delta produces when applied onto `base_epoch`.
    pub epoch: u64,
    /// Epoch the changes were computed against.
    pub base_epoch: u64,
    /// Vertex count of the *new* epoch (growth shows up as trailing
    /// "changes" for every vertex past the base's vertex count).
    pub vertices: usize,
    /// `|Γ|` of the new epoch.
    pub num_communities: usize,
    /// Modularity of the new epoch.
    pub modularity: f64,
    /// `(vertex, new_community)` pairs, ascending by vertex id.
    pub changes: Vec<(u32, u32)>,
}

impl EpochDelta {
    /// A delta that touches at least half the membership carries no
    /// savings over a full snapshot frame (each change costs two words
    /// to one); the server sends a full frame instead.  Renumbering
    /// cascades — where a relabel flips most ids without moving the
    /// partition — land here too, which is what makes the subscription
    /// stream safe across renumber-invalidating epochs.
    pub fn is_major(&self) -> bool {
        self.changes.len() * 2 >= self.vertices
    }

    /// Replay this delta onto a mirror of the base epoch's membership.
    /// Grows (or shrinks) the mirror to the new vertex count first;
    /// grown slots are always present in `changes`, so the fill value
    /// is never observable.
    pub fn apply_to(&self, membership: &mut Vec<u32>) {
        membership.resize(self.vertices, 0);
        for &(v, c) in &self.changes {
            membership[v as usize] = c;
        }
    }
}

/// Compute the membership changes from `prev` to `next`.
///
/// Over the common vertex prefix a change is a differing community id;
/// every vertex past `prev.vertices` (batch-driven growth) is a change
/// by definition.  The result lists vertices in ascending order, which
/// the wire codec and [`EpochDelta::apply_to`] both rely on being
/// deterministic.
pub fn epoch_delta(prev: &EpochSnapshot, next: &EpochSnapshot) -> EpochDelta {
    let pm = prev.membership();
    let nm = next.membership();
    let common = pm.len().min(nm.len());
    let mut changes = Vec::new();
    for v in 0..common {
        if pm[v] != nm[v] {
            changes.push((v as u32, nm[v]));
        }
    }
    for (v, &c) in nm.iter().enumerate().skip(common) {
        changes.push((v as u32, c));
    }
    EpochDelta {
        epoch: next.epoch,
        base_epoch: prev.epoch,
        vertices: next.vertices,
        num_communities: next.num_communities(),
        modularity: next.modularity,
        changes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::snapshot::EpochStats;

    fn snap(epoch: u64, membership: Vec<u32>) -> EpochSnapshot {
        let n = membership.len();
        let nc = membership.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut sizes = vec![0usize; nc];
        for &c in &membership {
            sizes[c as usize] += 1;
        }
        EpochSnapshot::new(epoch, n, 2 * n, 0.5, EpochStats::default(), membership, sizes)
    }

    #[test]
    fn delta_lists_changed_and_grown_vertices() {
        let a = snap(4, vec![0, 1, 0, 1]);
        let b = snap(5, vec![0, 0, 0, 1, 2, 2]);
        let d = epoch_delta(&a, &b);
        assert_eq!(d.epoch, 5);
        assert_eq!(d.base_epoch, 4);
        assert_eq!(d.vertices, 6);
        assert_eq!(d.num_communities, 3);
        assert_eq!(d.changes, vec![(1, 0), (4, 2), (5, 2)]);
    }

    #[test]
    fn apply_reconstructs_the_next_membership() {
        let a = snap(0, vec![0, 1, 0, 1]);
        let b = snap(1, vec![0, 0, 0, 1, 2, 2]);
        let d = epoch_delta(&a, &b);
        let mut mirror = a.membership().to_vec();
        d.apply_to(&mut mirror);
        assert_eq!(mirror, b.membership());
        // Shrink (renumber drops trailing vertices) round-trips too.
        let d_back = epoch_delta(&b, &a);
        d_back.apply_to(&mut mirror);
        assert_eq!(mirror, a.membership());
    }

    #[test]
    fn identical_epochs_yield_an_empty_delta() {
        let a = snap(7, vec![2, 0, 1]);
        let b = snap(8, vec![2, 0, 1]);
        let d = epoch_delta(&a, &b);
        assert!(d.changes.is_empty());
        assert!(!d.is_major());
        let mut mirror = a.membership().to_vec();
        d.apply_to(&mut mirror);
        assert_eq!(mirror, b.membership());
    }

    #[test]
    fn majority_changes_flag_a_major_delta() {
        let a = snap(0, vec![0, 0, 0, 0]);
        // Renumber-style relabel: half the vertices flip.
        let b = snap(1, vec![1, 0, 1, 0]);
        let d = epoch_delta(&a, &b);
        assert_eq!(d.changes.len(), 2);
        assert!(d.is_major(), "2 changes * 2 >= 4 vertices");
        let c = snap(1, vec![1, 0, 0, 0]);
        assert!(!epoch_delta(&a, &c).is_major());
    }

    #[test]
    fn deltas_chain_across_many_epochs() {
        // Reconstruct a whole sequence purely from deltas — the
        // subscriber contract the loopback e2e test asserts over TCP.
        let seq = [
            vec![0, 0, 1, 1],
            vec![0, 1, 1, 1],
            vec![0, 1, 1, 1, 2],
            vec![2, 1, 0, 1, 2],
            vec![0, 0],
        ];
        let snaps: Vec<EpochSnapshot> =
            seq.iter().enumerate().map(|(i, m)| snap(i as u64, m.clone())).collect();
        let mut mirror = snaps[0].membership().to_vec();
        for w in snaps.windows(2) {
            epoch_delta(&w[0], &w[1]).apply_to(&mut mirror);
            assert_eq!(mirror, w[1].membership());
        }
    }
}
