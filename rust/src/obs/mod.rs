//! Live telemetry: a process-wide metrics registry (PR 8).
//!
//! PR 7's tracing answers "what happened during *that* run" — you
//! attach a session, finish it, and study the timeline offline.  The
//! ROADMAP north-star is a long-lived service, and a service needs the
//! complementary surface: **always-on** counters, gauges and latency
//! histograms that a scraper can poll from a *running* process without
//! attaching anything.  This module is that surface:
//!
//! * [`Counter`] / [`Gauge`] — per-worker **sharded** relaxed atomics
//!   ([`SHARDS`] cache-padded cells, one per thread-affine slot), so
//!   hot-path increments never bounce a shared cache line between
//!   workers; shards are merged on scrape.
//! * [`Histogram`] — fixed-bucket log2 latency histogram; recording is
//!   zero-alloc (three relaxed `fetch_add`s), rendering produces
//!   Prometheus cumulative buckets.
//! * [`Registry`] — instruments registered under `&'static str` names
//!   with label support; [`Registry::snapshot`] walks the registry
//!   under its lock and reads every instrument into plain values, one
//!   consistent point-in-time view for the renderers
//!   ([`render::prometheus_text`], [`render::json`]).
//! * [`http::IntrospectionServer`] — a minimal `std::net::TcpListener`
//!   HTTP server (the repo's first wire protocol) serving `/metrics`,
//!   `/healthz` and `/epochs` from a dedicated thread, so scrapes never
//!   block the ingest thread.
//!
//! ## Cost discipline
//!
//! Mirrors the trace subsystem's branch-disabled pattern: every record
//! path starts with one relaxed load of a process-global enabled bit
//! ([`enabled`]).  Metrics default **on** (unlike tracing) because the
//! per-op cost is a single relaxed `fetch_add` on a thread-affine
//! padded cell; `bench_smoke`'s metrics cell measures the on/off delta
//! and the acceptance bar is < 1 %.  [`set_enabled(false)`] turns every
//! instrument into a single load-and-return, and results are bit-exact
//! either way (`tests/obs.rs`) — instruments observe, never steer.

pub mod http;
pub mod render;

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};

/// Shard count for [`Counter`]/[`Gauge`] (power of two; threads are
/// assigned round-robin, so up to this many writers proceed without
/// sharing a cache line).
pub const SHARDS: usize = 16;

/// Bucket count for [`Histogram`]: bucket 0 holds zero, bucket `i`
/// holds values in `[2^(i-1), 2^i)`, the last bucket absorbs the tail
/// (2^42 ns ≈ 73 min — far beyond any epoch latency here).
pub const HIST_BUCKETS: usize = 44;

// ---------------------------------------------------------------------------
// Global enable bit (trace-style: one relaxed load on every record).

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Whether instruments record (default **true**; see module docs).
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Flip recording globally.  Reads ([`Counter::value`], scrapes) keep
/// working either way — disabling freezes values, it does not clear.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Relaxed);
}

// ---------------------------------------------------------------------------
// Thread → shard assignment.

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Round-robin shard slot, fixed per thread at first use.
    static SHARD: usize = NEXT_SHARD.fetch_add(1, Relaxed) & (SHARDS - 1);
}

#[inline(always)]
fn shard_index() -> usize {
    SHARD.with(|s| *s)
}

/// One atomic on its own cache line (shards must not false-share).
#[repr(align(64))]
#[derive(Default)]
struct PadU64(AtomicU64);

#[repr(align(64))]
#[derive(Default)]
struct PadI64(AtomicI64);

// ---------------------------------------------------------------------------
// Instruments.

/// Monotonic counter: sharded relaxed adds, summed on scrape.
#[derive(Default)]
pub struct Counter {
    shards: [PadU64; SHARDS],
}

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        if !enabled() {
            return;
        }
        self.shards[shard_index()].0.fetch_add(n, Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Merged value (sum of shards).  Concurrent writers may land
    /// mid-sum; the result is always ≥ any previously observed value
    /// for a fixed writer set (each shard is monotone).
    pub fn value(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Relaxed)).sum()
    }
}

/// Up/down gauge over signed shards (merged on scrape).
///
/// `add`/`sub` are safe from any thread; [`Gauge::set`] rewrites all
/// shards and is reserved for single-writer gauges (memory accounting,
/// the drift gauge — both owned by one thread in this codebase).
#[derive(Default)]
pub struct Gauge {
    shards: [PadI64; SHARDS],
}

impl Gauge {
    #[inline]
    pub fn add(&self, d: i64) {
        if !enabled() {
            return;
        }
        self.shards[shard_index()].0.fetch_add(d, Relaxed);
    }

    #[inline]
    pub fn sub(&self, d: i64) {
        self.add(-d);
    }

    /// Overwrite the merged value (single-writer gauges only; a racing
    /// `add` on another shard can be lost for shards rewritten before
    /// the add lands — acceptable for the set-style gauges here).
    pub fn set(&self, v: i64) {
        if !enabled() {
            return;
        }
        for (i, s) in self.shards.iter().enumerate() {
            s.0.store(if i == 0 { v } else { 0 }, Relaxed);
        }
    }

    pub fn value(&self) -> i64 {
        self.shards.iter().map(|s| s.0.load(Relaxed)).sum()
    }
}

/// Fixed-bucket log2 histogram; `record` is zero-alloc.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

/// Bucket index for a value: 0 for 0, else `floor(log2(v)) + 1`
/// clamped to the last bucket.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive Prometheus `le` upper bound of bucket `i` (`None` is the
/// `+Inf` tail bucket).  Integer values make `< 2^i` ⇔ `≤ 2^i - 1`.
pub fn bucket_le(i: usize) -> Option<u64> {
    if i == 0 {
        Some(0)
    } else if i < HIST_BUCKETS - 1 {
        Some((1u64 << i) - 1)
    } else {
        None
    }
}

impl Histogram {
    #[inline]
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.buckets[bucket_of(v)].fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.count.fetch_add(1, Relaxed);
    }

    /// Point-in-time read.  `count` is recomputed from the bucket reads
    /// so the snapshot is internally consistent (`count == Σ buckets`)
    /// even under concurrent recording; `sum` may trail by in-flight
    /// records.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: [u64; HIST_BUCKETS] = std::array::from_fn(|i| self.buckets[i].load(Relaxed));
        let count = buckets.iter().sum();
        HistogramSnapshot { buckets, sum: self.sum.load(Relaxed), count }
    }
}

/// Plain-value copy of a [`Histogram`].
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    /// Bucket-resolution percentile estimate (upper bound of the bucket
    /// where the cumulative count crosses `p`); 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (p.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_le(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

// ---------------------------------------------------------------------------
// Registry.

/// Owned label set (`name="value"` pairs, rendered sorted as given).
pub type Labels = Vec<(&'static str, String)>;

#[derive(Clone)]
enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Instrument {
    fn kind(&self) -> &'static str {
        match self {
            Instrument::Counter(_) => "counter",
            Instrument::Gauge(_) => "gauge",
            Instrument::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    name: &'static str,
    help: &'static str,
    labels: Labels,
    inst: Instrument,
}

/// Process-wide instrument registry (get-or-register semantics: the
/// same `(name, labels)` always yields the same instrument).
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

fn owned_labels(labels: &[(&'static str, &str)]) -> Labels {
    labels.iter().map(|&(k, v)| (k, v.to_string())).collect()
}

impl Registry {
    fn get_or_register(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
        make: impl FnOnce() -> Instrument,
    ) -> Instrument {
        let labels = owned_labels(labels);
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            return e.inst.clone();
        }
        let inst = make();
        // One name, one type: Prometheus families cannot mix kinds.
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            assert_eq!(
                e.inst.kind(),
                inst.kind(),
                "metric {name} already registered as {}",
                e.inst.kind()
            );
        }
        entries.push(Entry { name, help, labels, inst: inst.clone() });
        inst
    }

    pub fn counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Counter> {
        match self.get_or_register(name, help, labels, || {
            Instrument::Counter(Arc::new(Counter::default()))
        }) {
            Instrument::Counter(c) => c,
            other => panic!("metric {name} is a {}", other.kind()),
        }
    }

    pub fn gauge(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Gauge> {
        match self
            .get_or_register(name, help, labels, || Instrument::Gauge(Arc::new(Gauge::default())))
        {
            Instrument::Gauge(g) => g,
            other => panic!("metric {name} is a {}", other.kind()),
        }
    }

    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Histogram> {
        match self.get_or_register(name, help, labels, || {
            Instrument::Histogram(Arc::new(Histogram::default()))
        }) {
            Instrument::Histogram(h) => h,
            other => panic!("metric {name} is a {}", other.kind()),
        }
    }

    /// One consistent point-in-time view: the registry is walked under
    /// its lock and every instrument is read into plain values in a
    /// single pass (no instrument is read twice, none is skipped).
    pub fn snapshot(&self) -> Snapshot {
        let entries = self.entries.lock().unwrap();
        Snapshot {
            metrics: entries
                .iter()
                .map(|e| Metric {
                    name: e.name,
                    help: e.help,
                    labels: e.labels.clone(),
                    value: match &e.inst {
                        Instrument::Counter(c) => MetricValue::Counter(c.value()),
                        Instrument::Gauge(g) => MetricValue::Gauge(g.value()),
                        Instrument::Histogram(h) => MetricValue::Histogram(h.snapshot()),
                    },
                })
                .collect(),
        }
    }
}

/// Plain-value scrape result (input to the renderers).
pub struct Snapshot {
    pub metrics: Vec<Metric>,
}

pub struct Metric {
    pub name: &'static str,
    pub help: &'static str,
    pub labels: Labels,
    pub value: MetricValue,
}

pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram(HistogramSnapshot),
}

// ---------------------------------------------------------------------------
// Wired sites: the instruments the rest of the crate records into.
// One lazy accessor per site keeps hot paths at "one OnceLock load +
// one relaxed add" with the registry lock paid exactly once.

macro_rules! counter_site {
    ($fn_name:ident, $name:literal, $help:literal) => {
        pub fn $fn_name() -> &'static Counter {
            static SITE: OnceLock<Arc<Counter>> = OnceLock::new();
            &**SITE.get_or_init(|| registry().counter($name, $help, &[]))
        }
    };
}

macro_rules! gauge_site {
    ($fn_name:ident, $name:literal, $help:literal) => {
        pub fn $fn_name() -> &'static Gauge {
            static SITE: OnceLock<Arc<Gauge>> = OnceLock::new();
            &**SITE.get_or_init(|| registry().gauge($name, $help, &[]))
        }
    };
}

macro_rules! histogram_site {
    ($fn_name:ident, $name:literal, $help:literal) => {
        pub fn $fn_name() -> &'static Histogram {
            static SITE: OnceLock<Arc<Histogram>> = OnceLock::new();
            &**SITE.get_or_init(|| registry().histogram($name, $help, &[]))
        }
    };
}

/// Well-known instruments wired through the crate's layers.
pub mod sites {
    use super::*;

    // Service / ingest (service::mod, service::ingest).
    counter_site!(
        service_ops_ingested,
        "gve_service_ops_ingested_total",
        "Stream ops accepted by CommunityService::submit"
    );
    counter_site!(
        service_ops_rejected,
        "gve_service_ops_rejected_total",
        "Stream ops rejected (vertex id beyond max_vertices)"
    );
    counter_site!(
        service_ops_coalesced,
        "gve_service_ops_coalesced_total",
        "Pending insertions cancelled by a later delete of the same pair"
    );
    counter_site!(
        service_epochs_published,
        "gve_service_epochs_published_total",
        "Epoch snapshots published"
    );
    histogram_site!(
        service_epoch_latency,
        "gve_service_epoch_latency_ns",
        "End-to-end epoch latency (apply + detect + publish), ns"
    );
    gauge_site!(
        service_quality_drift_micro,
        "gve_service_quality_drift_micro",
        "Modularity drift since boot, microunits (drift * 1e6)"
    );

    // Worker team (parallel::team).
    counter_site!(
        team_jobs_dispatched,
        "gve_team_jobs_dispatched_total",
        "Parallel jobs dispatched to the persistent worker team"
    );
    counter_site!(
        team_worker_busy_ns,
        "gve_team_worker_busy_ns_total",
        "Wall ns team members spent inside job bodies"
    );

    // Louvain core (louvain::gve, louvain::local_moving).
    counter_site!(louvain_runs, "gve_louvain_runs_total", "Complete Louvain runs");
    counter_site!(louvain_passes, "gve_louvain_passes_total", "Louvain passes executed");
    counter_site!(
        louvain_move_iterations,
        "gve_louvain_move_iterations_total",
        "Local-moving iterations executed"
    );
    counter_site!(
        louvain_moves_applied,
        "gve_louvain_moves_applied_total",
        "Vertex community moves applied"
    );
    counter_site!(
        louvain_small_path_scans,
        "gve_louvain_small_path_scans_total",
        "Vertex scans taking the small-degree fast path"
    );
    counter_site!(
        louvain_large_path_scans,
        "gve_louvain_large_path_scans_total",
        "Vertex scans taking the hashtable path"
    );
    histogram_site!(
        louvain_move_iter_moves,
        "gve_louvain_move_iter_moves",
        "Moves applied per local-moving iteration (pruning convergence)"
    );

    // Trace subsystem (trace::TraceSession::finish).
    counter_site!(
        trace_dropped_events,
        "gve_trace_dropped_events_total",
        "Trace events dropped by saturated per-thread sinks"
    );

    // Serving daemon (server::daemon, PR 9).
    counter_site!(
        server_connections_opened,
        "gve_server_connections_opened_total",
        "Wire-protocol connections accepted by the serving daemon"
    );
    gauge_site!(
        server_connections_active,
        "gve_server_connections_active",
        "Wire-protocol connections currently open"
    );
    counter_site!(
        server_frames_rx,
        "gve_server_frames_rx_total",
        "Wire frames received across all connections"
    );
    counter_site!(
        server_ops_rx,
        "gve_server_ops_rx_total",
        "Stream ops received in Ops frames (pre-admission)"
    );
    counter_site!(
        server_ingest_stalls,
        "gve_server_ingest_stalls_total",
        "Reader threads that blocked on the full ingest queue"
    );
    counter_site!(
        server_deltas_tx,
        "gve_server_deltas_tx_total",
        "Epoch delta frames fanned out to subscribers"
    );
    counter_site!(
        server_snapshots_tx,
        "gve_server_snapshots_tx_total",
        "Full snapshot frames sent (subscribe priming + major deltas)"
    );
    counter_site!(
        server_subscribers_dropped,
        "gve_server_subscribers_dropped_total",
        "Subscribers dropped for not draining their outbox"
    );
    counter_site!(
        server_errors_tx,
        "gve_server_errors_tx_total",
        "Error frames sent before closing a misbehaving connection"
    );

    /// Memory-accounting byte gauge, labelled by component; `kind` is
    /// `"reserved"` (buffer capacity) or `"used"` (logical length).
    pub fn mem_bytes(kind: &'static str, component: &'static str) -> Arc<Gauge> {
        let name = match kind {
            "reserved" => "gve_mem_reserved_bytes",
            "used" => "gve_mem_used_bytes",
            other => panic!("mem gauge kind must be reserved|used, got {other}"),
        };
        registry().gauge(
            name,
            "Heap bytes by component (reserved = capacity, used = logical)",
            &[("component", component)],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_shards_merge() {
        let c = Counter::default();
        c.add(5);
        c.inc();
        assert_eq!(c.value(), 6);
    }

    #[test]
    fn gauge_set_overwrites_adds() {
        let g = Gauge::default();
        g.add(10);
        g.sub(3);
        assert_eq!(g.value(), 7);
        g.set(100);
        assert_eq!(g.value(), 100);
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // le bound of bucket i is 2^i - 1 (inclusive).
        assert_eq!(bucket_le(0), Some(0));
        assert_eq!(bucket_le(1), Some(1));
        assert_eq!(bucket_le(2), Some(3));
        assert_eq!(bucket_le(HIST_BUCKETS - 1), None);
    }

    #[test]
    fn histogram_percentile_estimates() {
        let h = Histogram::default();
        for v in [1u64, 1, 1, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 1003);
        assert_eq!(s.percentile(0.5), 1);
        assert!(s.percentile(0.99) >= 1000);
    }

    #[test]
    fn registry_get_or_register_dedups() {
        let r = Registry::default();
        let a = r.counter("t_total", "h", &[]);
        let b = r.counter("t_total", "h", &[]);
        a.inc();
        assert_eq!(b.value(), 1, "same (name, labels) is the same instrument");
        let l1 = r.counter("t_total", "h", &[("k", "x")]);
        l1.add(9);
        assert_eq!(a.value(), 1, "distinct labels are distinct instruments");
        let snap = r.snapshot();
        assert_eq!(snap.metrics.len(), 2);
    }
}
