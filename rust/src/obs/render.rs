//! Scrape renderers: Prometheus text exposition format and JSON.
//!
//! Both take a [`Snapshot`] (plain values, no atomics) so a render
//! never touches live instruments.  The Prometheus renderer follows
//! text format 0.0.4: `# HELP` / `# TYPE` once per family, samples
//! grouped under their family, histograms as cumulative `_bucket`
//! series plus `_sum` / `_count`.  The JSON renderer is hand-rolled
//! like every other writer in this crate (the offline registry has no
//! serde).

use super::{bucket_le, HistogramSnapshot, Labels, Metric, MetricValue, Snapshot};

/// Escape a label value per the exposition format (`\\`, `\"`, `\n`).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render `{k="v",...}`; `extra` appends a pre-formatted pair (the
/// histogram `le`).  Empty labels render as nothing.
fn label_block(labels: &Labels, extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn write_histogram(out: &mut String, name: &str, labels: &Labels, h: &HistogramSnapshot) {
    let mut cum = 0u64;
    for (i, &c) in h.buckets.iter().enumerate() {
        cum += c;
        // Skip interior zero-count buckets to keep scrapes compact;
        // cumulative counts stay correct because `cum` carries over.
        if c == 0 && i != h.buckets.len() - 1 {
            continue;
        }
        let le = match bucket_le(i) {
            Some(b) => b.to_string(),
            None => "+Inf".to_string(),
        };
        out.push_str(&format!(
            "{name}_bucket{} {cum}\n",
            label_block(labels, Some(("le", le.as_str())))
        ));
    }
    out.push_str(&format!("{name}_sum{} {}\n", label_block(labels, None), h.sum));
    out.push_str(&format!("{name}_count{} {}\n", label_block(labels, None), h.count));
}

/// Prometheus text format 0.0.4.
pub fn prometheus_text(snap: &Snapshot) -> String {
    // Group samples by family (first-seen order) so HELP/TYPE lead
    // each family exactly once, as the format requires.
    let mut families: Vec<(&str, Vec<&Metric>)> = Vec::new();
    for m in &snap.metrics {
        match families.iter_mut().find(|(n, _)| *n == m.name) {
            Some((_, v)) => v.push(m),
            None => families.push((m.name, vec![m])),
        }
    }
    let mut out = String::new();
    for (name, metrics) in families {
        let first = metrics[0];
        let kind = match first.value {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histogram(_) => "histogram",
        };
        out.push_str(&format!("# HELP {name} {}\n", first.help));
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        for m in metrics {
            match &m.value {
                MetricValue::Counter(v) => {
                    out.push_str(&format!("{name}{} {v}\n", label_block(&m.labels, None)));
                }
                MetricValue::Gauge(v) => {
                    out.push_str(&format!("{name}{} {v}\n", label_block(&m.labels, None)));
                }
                MetricValue::Histogram(h) => write_histogram(&mut out, name, &m.labels, h),
            }
        }
    }
    out
}

fn json_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            _ => out.push(c),
        }
    }
    out
}

fn json_labels(labels: &Labels) -> String {
    let parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("\"{k}\":\"{}\"", json_escape(v))).collect();
    format!("{{{}}}", parts.join(","))
}

/// JSON rendering of the same snapshot (`/metrics.json`).
pub fn json(snap: &Snapshot) -> String {
    let mut items: Vec<String> = Vec::with_capacity(snap.metrics.len());
    for m in &snap.metrics {
        let head = format!(
            "{{\"name\":\"{}\",\"help\":\"{}\",\"labels\":{}",
            m.name,
            json_escape(m.help),
            json_labels(&m.labels)
        );
        let body = match &m.value {
            MetricValue::Counter(v) => format!("{head},\"type\":\"counter\",\"value\":{v}}}"),
            MetricValue::Gauge(v) => format!("{head},\"type\":\"gauge\",\"value\":{v}}}"),
            MetricValue::Histogram(h) => {
                let mut buckets = Vec::new();
                let mut cum = 0u64;
                for (i, &c) in h.buckets.iter().enumerate() {
                    cum += c;
                    if c == 0 && i != h.buckets.len() - 1 {
                        continue;
                    }
                    let le = match bucket_le(i) {
                        Some(b) => format!("\"{b}\""),
                        None => "\"+Inf\"".to_string(),
                    };
                    buckets.push(format!("{{\"le\":{le},\"cumulative\":{cum}}}"));
                }
                format!(
                    "{head},\"type\":\"histogram\",\"count\":{},\"sum\":{},\"buckets\":[{}]}}",
                    h.count,
                    h.sum,
                    buckets.join(",")
                )
            }
        };
        items.push(body);
    }
    format!("{{\"metrics\":[{}]}}", items.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;

    fn sample_snapshot() -> Snapshot {
        let r = Registry::default();
        r.counter("a_total", "counts a", &[]).add(3);
        r.gauge("b_bytes", "gauges b", &[("component", "pool")]).set(-7);
        let h = r.histogram("c_ns", "times c", &[]);
        h.record(0);
        h.record(5);
        r.snapshot()
    }

    #[test]
    fn prometheus_families_lead_with_help_and_type() {
        let text = prometheus_text(&sample_snapshot());
        assert!(text.contains("# HELP a_total counts a\n"));
        assert!(text.contains("# TYPE a_total counter\n"));
        assert!(text.contains("a_total 3\n"));
        assert!(text.contains("b_bytes{component=\"pool\"} -7\n"));
        assert!(text.contains("# TYPE c_ns histogram\n"));
        assert!(text.contains("c_ns_bucket{le=\"0\"} 1\n"));
        // 5 lands in bucket 3 (le = 7); cumulative includes the zero.
        assert!(text.contains("c_ns_bucket{le=\"7\"} 2\n"));
        assert!(text.contains("c_ns_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("c_ns_sum 5\n"));
        assert!(text.contains("c_ns_count 2\n"));
    }

    #[test]
    fn label_values_are_escaped() {
        let labels: Labels = vec![("k", "a\"b\\c\nd".to_string())];
        assert_eq!(label_block(&labels, None), "{k=\"a\\\"b\\\\c\\nd\"}");
    }

    #[test]
    fn json_is_structurally_sound() {
        let j = json(&sample_snapshot());
        assert!(j.starts_with("{\"metrics\":["));
        assert!(j.contains("\"type\":\"histogram\""));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }
}
