//! Minimal HTTP/1.1 introspection server over `std::net::TcpListener`
//! — the repo's first wire protocol (PR 8, seeds the ROADMAP "real
//! server front end" item).
//!
//! One dedicated thread owns the listener and serves requests
//! sequentially; nothing here shares a lock with the ingest loop:
//!
//! * `/metrics` — Prometheus text scrape of the process registry;
//! * `/metrics.json` — the same snapshot as JSON;
//! * `/healthz` — liveness probe (`200 ok`);
//! * `/epochs` — current [`EpochSnapshot`] stats plus the ingest
//!   loop's latest [`ServiceSummary`] (epoch percentiles, drift,
//!   throughput) as JSON.
//!
//! `/epochs` reads through a [`SnapshotHandle`] (an `Arc` swap — the
//! same lock-free query surface every other reader uses) and a tiny
//! `Mutex<ServiceSummary>` the ingest loop overwrites with a `Copy`
//! struct after each publish; the scrape side holds that mutex only
//! for a by-value copy, so scrapes never block ingest in any
//! observable way.
//!
//! The listener binds loopback only: this is an introspection port,
//! not a public API.  Bind port 0 to let the OS pick (tests do).

use super::{registry, render};
use crate::service::metrics::{RecentEpochs, ServiceSummary};
use crate::service::SnapshotHandle;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// What the server reads from the service side (both optional so the
/// endpoint also works for processes that run no service).
#[derive(Clone, Default)]
pub struct ServeState {
    /// Lock-free reader handle to the current epoch.
    pub snapshots: Option<SnapshotHandle>,
    /// Latest derived metrics, overwritten by the ingest loop after
    /// each publish (`ServiceMetrics::summary`).
    pub summary: Arc<Mutex<ServiceSummary>>,
    /// Ring of the last 32 published epochs (PR 9): the ingest loop
    /// pushes one entry per publish so scrapers catch bursts between
    /// polls instead of only the latest epoch.
    pub recent: Arc<Mutex<RecentEpochs>>,
}

/// Handle to the serving thread; dropping it stops the server.
pub struct IntrospectionServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl IntrospectionServer {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and start serving on a
    /// dedicated `gve-obs-http` thread.
    pub fn start(port: u16, state: ServeState) -> std::io::Result<Self> {
        Self::start_on(SocketAddr::from(([127, 0, 0, 1], port)), state)
    }

    /// [`Self::start`] with an explicit bind address (PR 9 `--http-bind`
    /// knob).  Loopback remains the default everywhere; binding wider
    /// is an explicit operator decision — the endpoints expose process
    /// internals, so treat a non-loopback bind like any other debug
    /// port.
    pub fn start_on(bind: SocketAddr, state: ServeState) -> std::io::Result<Self> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("gve-obs-http".into())
            .spawn(move || serve_loop(listener, stop2, state))?;
        Ok(Self { addr, stop, join: Some(join) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for IntrospectionServer {
    fn drop(&mut self) {
        self.stop.store(true, Relaxed);
        // The accept loop is blocked in accept(); a throwaway local
        // connection wakes it so it can observe the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(listener: TcpListener, stop: Arc<AtomicBool>, state: ServeState) {
    for conn in listener.incoming() {
        if stop.load(Relaxed) {
            break;
        }
        let Ok(mut stream) = conn else { continue };
        let _ = handle(&mut stream, &state);
    }
}

/// Read up to the header terminator (bounded), answer, close.
fn handle(stream: &mut TcpStream, state: &ServeState) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !contains_terminator(&buf) && buf.len() < 16 * 1024 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let request_line = std::str::from_utf8(&buf)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("")
        .to_string();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");

    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain; charset=utf-8", "method not allowed\n".to_string())
    } else {
        match path {
            "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
            "/metrics" => (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                render::prometheus_text(&registry().snapshot()),
            ),
            "/metrics.json" => {
                ("200 OK", "application/json", render::json(&registry().snapshot()))
            }
            "/epochs" => ("200 OK", "application/json", epochs_json(state)),
            _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
        }
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

fn contains_terminator(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
}

/// `/epochs` body: current snapshot stats + derived service summary.
fn epochs_json(state: &ServeState) -> String {
    let summary = *state.summary.lock().unwrap();
    let snap_part = match &state.snapshots {
        Some(h) => {
            let s = h.load();
            format!(
                "\"epoch\":{},\"vertices\":{},\"edges\":{},\"modularity\":{:.6},\
                 \"num_communities\":{},\"stats\":{{\"batch_ops\":{},\"affected_seeded\":{},\
                 \"passes\":{},\"apply_ns\":{},\"detect_ns\":{},\"wall_ns\":{}}}",
                s.epoch,
                s.vertices,
                s.edges,
                s.modularity,
                s.num_communities(),
                s.stats.batch_ops,
                s.stats.affected_seeded,
                s.stats.passes,
                s.stats.apply_ns,
                s.stats.detect_ns,
                s.stats.wall_ns(),
            )
        }
        None => "\"epoch\":null".to_string(),
    };
    let recent = {
        let ring = state.recent.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::from("[");
        for (i, e) in ring.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            use std::fmt::Write as _;
            let _ = write!(
                out,
                "{{\"epoch\":{},\"vertices\":{},\"edges\":{},\"modularity\":{:.6},\
                 \"num_communities\":{},\"batch_ops\":{},\"wall_ns\":{}}}",
                e.epoch,
                e.vertices,
                e.edges,
                e.modularity,
                e.num_communities,
                e.stats.batch_ops,
                e.stats.wall_ns(),
            );
        }
        out.push(']');
        out
    };
    format!(
        "{{{snap_part},\"epochs_published\":{},\"ops_ingested\":{},\"ops_rejected\":{},\
         \"ingest_ops_per_sec\":{:.1},\"epoch_percentiles\":{{\"p50\":{},\"p95\":{},\"p99\":{}}},\
         \"median_epoch_ns\":{},\"max_epoch_ns\":{},\"initial_modularity\":{:.6},\
         \"last_modularity\":{:.6},\"quality_drift\":{:.6},\"recent\":{recent}}}",
        summary.epochs_published,
        summary.ops_ingested,
        summary.ops_rejected,
        summary.ingest_ops_per_sec,
        summary.percentiles.p50,
        summary.percentiles.p95,
        summary.percentiles.p99,
        summary.median_epoch_ns,
        summary.max_epoch_ns,
        summary.initial_modularity,
        summary.last_modularity,
        summary.quality_drift,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epochs_json_without_a_service_is_still_valid() {
        let body = epochs_json(&ServeState::default());
        assert!(body.starts_with("{\"epoch\":null,"));
        assert!(body.ends_with("\"recent\":[]}"));
        assert_eq!(body.matches('{').count(), body.matches('}').count());
    }

    #[test]
    fn epochs_json_renders_the_recent_ring() {
        use crate::service::metrics::RecentEpoch;
        let state = ServeState::default();
        {
            let mut ring = state.recent.lock().unwrap();
            for i in 0..3u64 {
                ring.push(RecentEpoch { epoch: i, vertices: 10, ..Default::default() });
            }
        }
        let body = epochs_json(&state);
        assert_eq!(body.matches('{').count(), body.matches('}').count());
        assert!(body.contains("\"recent\":[{\"epoch\":0,"), "{body}");
        assert!(body.contains("\"epoch\":2,"), "{body}");
    }

    #[test]
    fn terminator_detection() {
        assert!(contains_terminator(b"GET / HTTP/1.1\r\n\r\n"));
        assert!(contains_terminator(b"GET / HTTP/1.0\n\n"));
        assert!(!contains_terminator(b"GET / HTTP/1.1\r\n"));
    }
}
