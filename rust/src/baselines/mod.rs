//! Baseline Louvain implementations for the comparison studies
//! (Table 1, Figs 11–12).
//!
//! The paper compares against released binaries of five systems; none
//! run in this offline, GPU-less testbed, so each baseline is
//! re-implemented with its *documented algorithmic signature*
//! (DESIGN.md §5) on top of this crate's substrates.  The signatures —
//! not absolute constants — are what produce each system's relative
//! standing:
//!
//! | Baseline  | Signature |
//! |-----------|-----------|
//! | Vite      | synchronous double-buffered sweeps, map tables, threshold cycling, per-sweep collective overhead (distributed heritage) |
//! | Grappolo  | greedy-coloring prepass, color-class-ordered sweeps, map tables, threshold scaling |
//! | NetworKit | asynchronous PLM, Close-KV tables, move-until-quiet, no threshold scaling / pruning / aggregation tolerance |
//! | cuGraph   | GPU sim, no Pick-Less, bounded iterations, RAPIDS-sized memory footprint (OOM gates) |
//! | Nido      | GPU sim, batch-partitioned communities, Luby-style coloring, per-batch processing (quality loss) |

pub mod common;
pub mod cugraph;
pub mod grappolo;
pub mod networkit;
pub mod nido;
pub mod vite;

use crate::graph::Csr;

/// Which system a result came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum System {
    GveLouvain,
    NuLouvain,
    Vite,
    Grappolo,
    NetworKit,
    CuGraph,
    Nido,
}

impl System {
    pub fn name(self) -> &'static str {
        match self {
            System::GveLouvain => "gve-louvain",
            System::NuLouvain => "nu-louvain",
            System::Vite => "vite",
            System::Grappolo => "grappolo",
            System::NetworKit => "networkit",
            System::CuGraph => "cugraph",
            System::Nido => "nido",
        }
    }

    pub fn is_gpu(self) -> bool {
        matches!(self, System::NuLouvain | System::CuGraph | System::Nido)
    }
}

/// Uniform result record for cross-system comparisons.
#[derive(Clone, Debug)]
pub struct BaselineOutcome {
    pub system: System,
    pub membership: Vec<u32>,
    pub modularity: f64,
    pub num_communities: usize,
    pub passes: usize,
    /// Measured wall time of this implementation on this host (1 core).
    pub wall_ns: u64,
    /// Modeled time on the paper's hardware (32-core Xeon for CPU
    /// systems via work accounting, A100 via the device model for GPU
    /// systems). `None` when the run would OOM (excluded in the paper's
    /// figures too).
    pub modeled_ns: Option<u64>,
}

/// Run a baseline by kind with its adopted configuration.
pub fn run_system(system: System, g: &Csr, threads: usize, seed: u64) -> BaselineOutcome {
    match system {
        System::GveLouvain => gve_outcome(g, threads),
        System::NuLouvain => nu_outcome(g),
        System::Vite => vite::run(g, threads, seed),
        System::Grappolo => grappolo::run(g, threads, seed),
        System::NetworKit => networkit::run(g, threads, seed),
        System::CuGraph => cugraph::run(g, seed),
        System::Nido => nido::run(g, seed),
    }
}

/// GVE-Louvain wrapped in the uniform record.
pub fn gve_outcome(g: &Csr, threads: usize) -> BaselineOutcome {
    use crate::louvain::params::LouvainParams;
    gve_outcome_with_params(g, LouvainParams::with_threads(threads))
}

/// GVE-Louvain with a caller-chosen configuration (the `repro run`
/// CLI path: scan-engine knobs like `--small-degree` / `--schedule
/// degree-bucketed` flow through here).
pub fn gve_outcome_with_params(
    g: &Csr,
    params: crate::louvain::params::LouvainParams,
) -> BaselineOutcome {
    use crate::louvain::gve::GveLouvain;
    let threads = params.threads.max(1);
    let t0 = std::time::Instant::now();
    let out = GveLouvain::new(params).run(g);
    let wall = t0.elapsed().as_nanos() as u64;
    BaselineOutcome {
        system: System::GveLouvain,
        modeled_ns: Some(common::cpu_modeled_ns(wall, threads, 32)),
        membership: out.membership,
        modularity: out.modularity,
        num_communities: out.num_communities,
        passes: out.passes,
        wall_ns: wall,
    }
}

/// ν-Louvain wrapped in the uniform record.
pub fn nu_outcome(g: &Csr) -> BaselineOutcome {
    use crate::gpusim::{NuLouvain, NuParams};
    let t0 = std::time::Instant::now();
    let out = NuLouvain::new(NuParams::default()).run(g);
    let wall = t0.elapsed().as_nanos() as u64;
    BaselineOutcome {
        system: System::NuLouvain,
        modeled_ns: if out.fits_memory { Some(out.est_gpu_ns) } else { None },
        membership: out.membership,
        modularity: out.modularity,
        num_communities: out.num_communities,
        passes: out.passes,
        wall_ns: wall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn all_systems_run_and_find_structure() {
        let g = generate(GraphFamily::Web, 9, 3);
        for s in [
            System::GveLouvain,
            System::NuLouvain,
            System::Vite,
            System::Grappolo,
            System::NetworKit,
            System::CuGraph,
            System::Nido,
        ] {
            let out = run_system(s, &g, 1, 42);
            assert!(out.modularity > 0.3, "{s:?}: q={}", out.modularity);
            assert!(out.num_communities > 1, "{s:?}");
            assert_eq!(out.membership.len(), g.num_vertices(), "{s:?}");
            assert!(out.wall_ns > 0);
        }
    }

    #[test]
    fn gve_beats_or_matches_baseline_quality_on_web() {
        let g = generate(GraphFamily::Web, 10, 5);
        let gve = run_system(System::GveLouvain, &g, 1, 42);
        let nido = run_system(System::Nido, &g, 1, 42);
        // Paper: GVE finds ~43-45% higher modularity than Nido.
        assert!(gve.modularity >= nido.modularity, "gve={} nido={}", gve.modularity, nido.modularity);
    }

    #[test]
    fn system_names_unique() {
        let names: std::collections::BTreeSet<_> = [
            System::GveLouvain,
            System::NuLouvain,
            System::Vite,
            System::Grappolo,
            System::NetworKit,
            System::CuGraph,
            System::Nido,
        ]
        .iter()
        .map(|s| s.name())
        .collect();
        assert_eq!(names.len(), 7);
    }
}
