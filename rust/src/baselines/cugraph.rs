//! cuGraph Louvain signature (RAPIDS; Kang et al., IPDPSW'23).
//!
//! Encoded traits: GPU execution, **no Pick-Less** (cuGraph bounds
//! oscillation with a fixed iteration budget instead), no aggregation
//! tolerance, and the RAPIDS memory footprint that OOMs on the paper's
//! five largest web graphs (`DeviceModel::cugraph_bytes`).

use super::{BaselineOutcome, System};
use crate::gpusim::{DeviceModel, NuLouvain, NuParams};
use crate::graph::Csr;
use std::time::Instant;

pub fn run(g: &Csr, _seed: u64) -> BaselineOutcome {
    let params = NuParams {
        // cuGraph has no Pick-Less heuristic, but its up-down dendrogram
        // resolve breaks symmetric oscillation; modeled as monotone
        // iterations every other step (ρ = 2).
        rho: 2,
        max_iterations: 12, // bounded oscillation budget
        tolerance: 1e-4,
        tolerance_drop: 1.0,
        aggregation_tolerance: 1.0, // aggregate every pass
        ..Default::default()
    };
    let dev = DeviceModel::default();
    let fits = dev.cugraph_fits(g.num_vertices() as u64, g.num_edges() as u64);
    let t0 = Instant::now();
    let out = NuLouvain::new(params).run(g);
    let wall = t0.elapsed().as_nanos() as u64;
    // cuGraph builds Louvain from generic vertex/edge-centric primitives
    // (materialized frontiers, radix-sort grouping, multiple passes over
    // edge partitions) rather than ν-Louvain's fused per-vertex-hashtable
    // kernels; the paper measures ν 5.0× faster. Charged as a constant
    // primitive-overhead factor on the modeled device time.
    const PRIMITIVE_OVERHEAD: f64 = 4.0;
    BaselineOutcome {
        system: System::CuGraph,
        modeled_ns: if fits { Some((out.est_gpu_ns as f64 * PRIMITIVE_OVERHEAD) as u64) } else { None },
        membership: out.membership,
        modularity: out.modularity,
        num_communities: out.num_communities,
        passes: out.passes,
        wall_ns: wall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn cugraph_finds_communities() {
        let g = generate(GraphFamily::Web, 9, 13);
        let out = run(&g, 42);
        assert!(out.modularity > 0.5, "q={}", out.modularity);
        assert!(out.modeled_ns.is_some());
    }

    #[test]
    fn cugraph_quality_competitive() {
        // Paper Fig 11c: cuGraph ~0.7% higher modularity than GVE.
        let g = generate(GraphFamily::Social, 9, 15);
        let out = run(&g, 42);
        assert!(out.modularity > 0.35, "q={}", out.modularity);
    }
}
