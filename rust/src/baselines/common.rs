//! Shared machinery for the baseline signatures: synchronous
//! (double-buffered) local-moving, greedy graph coloring, and the
//! CPU-time projection helper.

use crate::graph::Csr;
use crate::louvain::modularity::delta_modularity;
use crate::parallel::pool::ParallelOpts;
use crate::parallel::team::Exec;
use std::collections::BTreeMap;

/// One synchronous local-moving sweep: every vertex picks its best
/// community against the *current* membership; all moves apply
/// afterwards (Vite's bulk-synchronous steps).  When `colors` is given,
/// the sweep runs color class by color class, applying at each class
/// boundary (Grappolo's coloring order).
///
/// Returns `(next_membership, dq_total, moves)`.
pub fn sync_sweep(
    g: &Csr,
    membership: &[u32],
    k: &[f64],
    sigma: &[f64],
    m: f64,
    colors: Option<(&[u32], u32)>,
) -> (Vec<u32>, f64, u64) {
    sync_sweep_opts(g, membership, k, sigma, m, colors, false)
}

/// [`sync_sweep`] with an optional monotone constraint (moves only to
/// lower community ids), the standard BSP oscillation breaker that
/// distributed Louvain codes apply on alternating sweeps.  Runs the
/// compute phase serially on the calling thread.
#[allow(clippy::too_many_arguments)]
pub fn sync_sweep_opts(
    g: &Csr,
    membership: &[u32],
    k: &[f64],
    sigma: &[f64],
    m: f64,
    colors: Option<(&[u32], u32)>,
    monotone: bool,
) -> (Vec<u32>, f64, u64) {
    sync_sweep_exec(
        g,
        membership,
        k,
        sigma,
        m,
        colors,
        monotone,
        ParallelOpts { threads: 1, ..ParallelOpts::default() },
        Exec::scoped(),
    )
}

/// [`sync_sweep_opts`] on an executor (PR 10: the baselines run their
/// sweeps on the shared [`Team`](crate::parallel::team::Team), same
/// runtime as the GVE path).  The compute phase fans each vertex's
/// decision out over `exec` into a per-vertex slot — a pure function of
/// the class-start snapshot, so any width and any dealing fill the
/// slots identically — and the apply phase stays serial in ascending
/// vertex order, the exact order the original serial sweep applied in.
/// Results are therefore bit-identical to the serial path at every
/// thread count.
#[allow(clippy::too_many_arguments)]
pub fn sync_sweep_exec(
    g: &Csr,
    membership: &[u32],
    k: &[f64],
    sigma: &[f64],
    m: f64,
    colors: Option<(&[u32], u32)>,
    monotone: bool,
    opts: ParallelOpts,
    exec: Exec,
) -> (Vec<u32>, f64, u64) {
    /// Sentinel community id: "this vertex stays" (or is outside the
    /// current color class).
    const NO_MOVE: u32 = u32::MAX;
    let n = g.num_vertices();
    let mut next = membership.to_vec();
    let mut sigma = sigma.to_vec();
    let mut dq_total = 0.0;
    let mut moves = 0u64;
    let n_classes = colors.map(|(_, nc)| nc).unwrap_or(1);
    let mut decided: Vec<(u32, f64)> = vec![(NO_MOVE, 0.0); n];

    for class in 0..n_classes {
        // Compute phase: decisions against the state at class start.
        let snapshot = next.clone();
        let snap = &snapshot;
        let sig = &sigma;
        exec.run_disjoint_mut(&mut decided, opts, |r, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                let i = r.start + off;
                *slot = (NO_MOVE, 0.0);
                if let Some((cols, _)) = colors {
                    if cols[i] != class {
                        continue;
                    }
                }
                let d = snap[i];
                let mut table: BTreeMap<u32, f64> = BTreeMap::new();
                for (j, w) in g.neighbours(i) {
                    if j as usize == i {
                        continue;
                    }
                    *table.entry(snap[j as usize]).or_insert(0.0) += w as f64;
                }
                let k_to_d = table.get(&d).copied().unwrap_or(0.0);
                let mut best = (d, 0.0f64);
                for (&c, &k_to_c) in &table {
                    if c == d {
                        continue;
                    }
                    if monotone && c >= d {
                        continue;
                    }
                    let dq =
                        delta_modularity(k_to_c, k_to_d, k[i], sig[c as usize], sig[d as usize], m);
                    if dq > best.1 {
                        best = (c, dq);
                    }
                }
                if best.0 != d && best.1 > 0.0 {
                    *slot = (best.0, best.1);
                }
            }
        });
        // Apply phase: serial, ascending vertex id.
        for (i, &(c, dq)) in decided.iter().enumerate() {
            if c == NO_MOVE {
                continue;
            }
            let d = next[i];
            sigma[d as usize] -= k[i];
            sigma[c as usize] += k[i];
            next[i] = c;
            dq_total += dq;
            moves += 1;
        }
    }
    (next, dq_total, moves)
}

/// Greedy first-fit coloring in vertex order; returns `(colors, count)`.
pub fn greedy_coloring(g: &Csr) -> (Vec<u32>, u32) {
    let n = g.num_vertices();
    let mut colors = vec![u32::MAX; n];
    let mut max_color = 0u32;
    let mut used: Vec<bool> = Vec::new();
    for v in 0..n {
        used.clear();
        used.resize(max_color as usize + 2, false);
        for (t, _) in g.neighbours(v) {
            let c = colors[t as usize];
            if c != u32::MAX && (c as usize) < used.len() {
                used[c as usize] = true;
            }
        }
        let c = used.iter().position(|&u| !u).unwrap() as u32;
        colors[v] = c;
        max_color = max_color.max(c);
    }
    (colors, max_color + 1)
}

/// Project a 1-core wall measurement onto `target_cores` of the paper's
/// Xeon using a parallel-efficiency curve consistent with the paper's
/// own scaling result (1.6× per thread doubling ⇒ efficiency
/// `0.8^log2(T)`); used when full chunk records are unavailable.
pub fn cpu_modeled_ns(wall_1core_ns: u64, ran_threads: usize, target_cores: usize) -> u64 {
    let _ = ran_threads;
    let t = target_cores.max(1) as f64;
    let speedup = t.powf(0.678); // 1.6x per doubling: log2(1.6) ≈ 0.678
    (wall_1core_ns as f64 / speedup) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::modularity::modularity;

    #[test]
    fn coloring_is_proper() {
        for f in [GraphFamily::Web, GraphFamily::Road] {
            let g = generate(f, 9, 7);
            let (colors, nc) = greedy_coloring(&g);
            assert!(nc >= 1);
            for v in 0..g.num_vertices() {
                for (t, _) in g.neighbours(v) {
                    if t as usize != v {
                        assert_ne!(colors[v], colors[t as usize], "{f:?}: edge {v}-{t}");
                    }
                }
            }
        }
    }

    #[test]
    fn coloring_uses_few_colors_on_sparse_graphs() {
        let g = generate(GraphFamily::Road, 10, 9);
        let (_, nc) = greedy_coloring(&g);
        assert!(nc <= 8, "road coloring used {nc} colors");
    }

    #[test]
    fn sync_sweep_improves_modularity() {
        let g = generate(GraphFamily::Web, 9, 11);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let m = g.total_weight();
        let (next, dq, moves) = sync_sweep(&g, &memb, &k, &sigma, m, None);
        assert!(dq > 0.0);
        assert!(moves > 0);
        assert!(modularity(&g, &next) > modularity(&g, &memb));
    }

    #[test]
    fn colored_sweep_also_improves() {
        let g = generate(GraphFamily::Road, 9, 13);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let m = g.total_weight();
        let (colors, nc) = greedy_coloring(&g);
        let (next, dq, _) = sync_sweep(&g, &memb, &k, &sigma, m, Some((&colors, nc)));
        assert!(dq > 0.0);
        assert!(modularity(&g, &next) > modularity(&g, &memb));
    }

    #[test]
    fn model_projection_monotone() {
        assert!(cpu_modeled_ns(1_000_000, 1, 32) < 1_000_000);
        assert!(cpu_modeled_ns(1_000_000, 1, 32) > 1_000_000 / 32);
    }

    #[test]
    fn exec_sweep_matches_serial_bit_exactly() {
        // The team-ported compute phase fills per-vertex slots from a
        // snapshot; the serial apply order is fixed — so width-4 team
        // sweeps must be bit-identical to the serial path, colored or
        // not, monotone or not.
        use crate::parallel::team::Team;
        let g = generate(GraphFamily::Web, 9, 17);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let m = g.total_weight();
        let (colors, nc) = greedy_coloring(&g);
        let team = Team::new(4);
        for colored in [false, true] {
            let cols = colored.then_some((&colors[..], nc));
            for monotone in [false, true] {
                let serial = sync_sweep_opts(&g, &memb, &k, &sigma, m, cols, monotone);
                let teamed = sync_sweep_exec(
                    &g,
                    &memb,
                    &k,
                    &sigma,
                    m,
                    cols,
                    monotone,
                    ParallelOpts { threads: 4, ..ParallelOpts::default() },
                    Exec::team(&team),
                );
                assert_eq!(serial.0, teamed.0, "colored={colored} monotone={monotone}");
                assert_eq!(serial.1.to_bits(), teamed.1.to_bits());
                assert_eq!(serial.2, teamed.2);
            }
        }
    }

    #[test]
    fn bulk_sync_sweep_can_swap_symmetric_pairs() {
        // The known BSP pathology (why Vite needs threshold cycling):
        // a single edge with both endpoints moving simultaneously.
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let memb = vec![0u32, 1];
        let k = g.vertex_weights();
        let sigma = k.clone();
        let (next, _, moves) = sync_sweep(&g, &memb, &k, &sigma, g.total_weight(), None);
        assert_eq!(moves, 2);
        assert_eq!(next, vec![1, 0]);
    }
}
