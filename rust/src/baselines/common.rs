//! Shared machinery for the baseline signatures: synchronous
//! (double-buffered) local-moving, greedy graph coloring, and the
//! CPU-time projection helper.

use crate::graph::Csr;
use crate::louvain::modularity::delta_modularity;
use std::collections::BTreeMap;

/// One synchronous local-moving sweep: every vertex picks its best
/// community against the *current* membership; all moves apply
/// afterwards (Vite's bulk-synchronous steps).  When `colors` is given,
/// the sweep runs color class by color class, applying at each class
/// boundary (Grappolo's coloring order).
///
/// Returns `(next_membership, dq_total, moves)`.
pub fn sync_sweep(
    g: &Csr,
    membership: &[u32],
    k: &[f64],
    sigma: &[f64],
    m: f64,
    colors: Option<(&[u32], u32)>,
) -> (Vec<u32>, f64, u64) {
    sync_sweep_opts(g, membership, k, sigma, m, colors, false)
}

/// [`sync_sweep`] with an optional monotone constraint (moves only to
/// lower community ids), the standard BSP oscillation breaker that
/// distributed Louvain codes apply on alternating sweeps.
#[allow(clippy::too_many_arguments)]
pub fn sync_sweep_opts(
    g: &Csr,
    membership: &[u32],
    k: &[f64],
    sigma: &[f64],
    m: f64,
    colors: Option<(&[u32], u32)>,
    monotone: bool,
) -> (Vec<u32>, f64, u64) {
    let n = g.num_vertices();
    let mut next = membership.to_vec();
    let mut sigma = sigma.to_vec();
    let mut dq_total = 0.0;
    let mut moves = 0u64;
    let n_classes = colors.map(|(_, nc)| nc).unwrap_or(1);

    for class in 0..n_classes {
        // Compute phase: decisions against the state at class start.
        let snapshot = next.clone();
        let mut decided: Vec<(usize, u32, f64)> = Vec::new();
        for i in 0..n {
            if let Some((cols, _)) = colors {
                if cols[i] != class {
                    continue;
                }
            }
            let d = snapshot[i];
            let mut table: BTreeMap<u32, f64> = BTreeMap::new();
            for (j, w) in g.neighbours(i) {
                if j as usize == i {
                    continue;
                }
                *table.entry(snapshot[j as usize]).or_insert(0.0) += w as f64;
            }
            let k_to_d = table.get(&d).copied().unwrap_or(0.0);
            let mut best = (d, 0.0f64);
            for (&c, &k_to_c) in &table {
                if c == d {
                    continue;
                }
                if monotone && c >= d {
                    continue;
                }
                let dq = delta_modularity(k_to_c, k_to_d, k[i], sigma[c as usize], sigma[d as usize], m);
                if dq > best.1 {
                    best = (c, dq);
                }
            }
            if best.0 != d && best.1 > 0.0 {
                decided.push((i, best.0, best.1));
            }
        }
        // Apply phase.
        for (i, c, dq) in decided {
            let d = next[i];
            sigma[d as usize] -= k[i];
            sigma[c as usize] += k[i];
            next[i] = c;
            dq_total += dq;
            moves += 1;
        }
    }
    (next, dq_total, moves)
}

/// Greedy first-fit coloring in vertex order; returns `(colors, count)`.
pub fn greedy_coloring(g: &Csr) -> (Vec<u32>, u32) {
    let n = g.num_vertices();
    let mut colors = vec![u32::MAX; n];
    let mut max_color = 0u32;
    let mut used: Vec<bool> = Vec::new();
    for v in 0..n {
        used.clear();
        used.resize(max_color as usize + 2, false);
        for (t, _) in g.neighbours(v) {
            let c = colors[t as usize];
            if c != u32::MAX && (c as usize) < used.len() {
                used[c as usize] = true;
            }
        }
        let c = used.iter().position(|&u| !u).unwrap() as u32;
        colors[v] = c;
        max_color = max_color.max(c);
    }
    (colors, max_color + 1)
}

/// Project a 1-core wall measurement onto `target_cores` of the paper's
/// Xeon using a parallel-efficiency curve consistent with the paper's
/// own scaling result (1.6× per thread doubling ⇒ efficiency
/// `0.8^log2(T)`); used when full chunk records are unavailable.
pub fn cpu_modeled_ns(wall_1core_ns: u64, ran_threads: usize, target_cores: usize) -> u64 {
    let _ = ran_threads;
    let t = target_cores.max(1) as f64;
    let speedup = t.powf(0.678); // 1.6x per doubling: log2(1.6) ≈ 0.678
    (wall_1core_ns as f64 / speedup) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::modularity::modularity;

    #[test]
    fn coloring_is_proper() {
        for f in [GraphFamily::Web, GraphFamily::Road] {
            let g = generate(f, 9, 7);
            let (colors, nc) = greedy_coloring(&g);
            assert!(nc >= 1);
            for v in 0..g.num_vertices() {
                for (t, _) in g.neighbours(v) {
                    if t as usize != v {
                        assert_ne!(colors[v], colors[t as usize], "{f:?}: edge {v}-{t}");
                    }
                }
            }
        }
    }

    #[test]
    fn coloring_uses_few_colors_on_sparse_graphs() {
        let g = generate(GraphFamily::Road, 10, 9);
        let (_, nc) = greedy_coloring(&g);
        assert!(nc <= 8, "road coloring used {nc} colors");
    }

    #[test]
    fn sync_sweep_improves_modularity() {
        let g = generate(GraphFamily::Web, 9, 11);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let m = g.total_weight();
        let (next, dq, moves) = sync_sweep(&g, &memb, &k, &sigma, m, None);
        assert!(dq > 0.0);
        assert!(moves > 0);
        assert!(modularity(&g, &next) > modularity(&g, &memb));
    }

    #[test]
    fn colored_sweep_also_improves() {
        let g = generate(GraphFamily::Road, 9, 13);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let m = g.total_weight();
        let (colors, nc) = greedy_coloring(&g);
        let (next, dq, _) = sync_sweep(&g, &memb, &k, &sigma, m, Some((&colors, nc)));
        assert!(dq > 0.0);
        assert!(modularity(&g, &next) > modularity(&g, &memb));
    }

    #[test]
    fn model_projection_monotone() {
        assert!(cpu_modeled_ns(1_000_000, 1, 32) < 1_000_000);
        assert!(cpu_modeled_ns(1_000_000, 1, 32) > 1_000_000 / 32);
    }

    #[test]
    fn bulk_sync_sweep_can_swap_symmetric_pairs() {
        // The known BSP pathology (why Vite needs threshold cycling):
        // a single edge with both endpoints moving simultaneously.
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let memb = vec![0u32, 1];
        let k = g.vertex_weights();
        let sigma = k.clone();
        let (next, _, moves) = sync_sweep(&g, &memb, &k, &sigma, g.total_weight(), None);
        assert_eq!(moves, 2);
        assert_eq!(next, vec![1, 0]);
    }
}
