//! Nido signature (Chou & Ghosh, PACT'22): batched GPU clustering for
//! graphs larger than device memory.
//!
//! Encoded traits: the vertex set is partitioned into batches sized to
//! fit the device; each batch is processed on its own with communities
//! **confined to the batch** (cross-batch merges only happen at the
//! coarser super-vertex levels), plus a Luby-style coloring prepass.
//! Confinement is what costs quality — the paper reports ν-Louvain
//! finding 45% higher modularity than Nido — and the serial batch sweep
//! is what costs time (61× slower than ν-Louvain).

use super::{BaselineOutcome, System};
use crate::gpusim::device::{DeviceModel, KernelWork};
use crate::gpusim::hashtable::{PerVertexTables, ProbeStrategy, ValueKind};
use crate::gpusim::kernels::{aggregate, move_iteration};
use crate::gpusim::nulouvain::NuParams;
use crate::graph::Csr;
use crate::louvain::dendrogram;
use crate::louvain::modularity::modularity;
use crate::louvain::renumber::renumber_communities;
use std::time::Instant;

const BATCHES: usize = 4;
const MAX_PASSES: usize = 10;

pub fn run(g: &Csr, _seed: u64) -> BaselineOutcome {
    let params = NuParams { rho: 0, ..Default::default() };
    let dev = DeviceModel::default();
    let t0 = Instant::now();
    let n0 = g.num_vertices();
    let m = g.total_weight();
    let mut top: Vec<u32> = (0..n0 as u32).collect();
    let mut owned: Option<Csr> = None;
    let mut passes = 0usize;
    let mut est_gpu_ns = 0u64;

    for pass in 0..MAX_PASSES {
        let gp: &Csr = owned.as_ref().unwrap_or(g);
        let np = gp.num_vertices();
        let k = gp.vertex_weights();
        let mut sigma = k.clone();
        let mut membership: Vec<u32> = (0..np as u32).collect();
        let mut tables = PerVertexTables::new(gp.num_edges().max(1), ValueKind::F32, ProbeStrategy::QuadraticDouble);
        // Batch id of each community (confinement home). Later passes run
        // as one batch (the coarse graph fits).
        let n_batches = if pass == 0 { BATCHES } else { 1 };
        let batch_of = |v: usize| (v * n_batches / np.max(1)).min(n_batches - 1);

        let mut iters = 0usize;
        for batch in 0..n_batches {
            // Per-batch device upload overhead (host<->device transfer).
            est_gpu_ns += 200_000;
            for _li in 0..params.max_iterations {
                let mut affected: Vec<u32> =
                    (0..np).map(|v| (batch_of(v) == batch) as u32).collect();
                let out = move_iteration(
                    gp, &mut membership, &k, &mut sigma, &mut affected, &mut tables, &params, m,
                    true, // Luby-coloring stand-in: monotone moves only
                );
                iters += 1;
                est_gpu_ns += dev.kernel_ns(&out.work_thread) + dev.kernel_ns(&out.work_block);
                // Confine: revert cross-batch moves (Nido's partitioned
                // clustering cannot form cross-batch communities).
                let mut reverts = 0u64;
                for v in 0..np {
                    if batch_of(v) == batch && batch_of(membership[v] as usize) != batch {
                        let c = membership[v] as usize;
                        sigma[c] -= k[v];
                        membership[v] = v as u32;
                        sigma[v] += k[v];
                        reverts += 1;
                    }
                }
                let _ = reverts;
                if out.dq <= 1e-3 {
                    break;
                }
            }
        }
        passes += 1;

        let n_comm = renumber_communities(&mut membership);
        dendrogram::lookup(&mut top, &membership);
        if iters <= n_batches || (n_comm as f64) / (np as f64) > 0.95 {
            break;
        }
        let agg = aggregate(gp, &membership, n_comm, &mut tables, &params);
        est_gpu_ns += dev.kernel_ns(&agg.work_thread) + dev.kernel_ns(&agg.work_block);
        owned = Some(agg.graph);
    }

    let wall = t0.elapsed().as_nanos() as u64;
    let n_comm = renumber_communities(&mut top);
    BaselineOutcome {
        system: System::Nido,
        modularity: modularity(g, &top),
        membership: top,
        num_communities: n_comm,
        passes,
        wall_ns: wall,
        // Nido streams batches, so it never OOMs — that is its point.
        modeled_ns: Some(est_gpu_ns),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::nu_outcome;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn nido_runs_and_finds_some_structure() {
        let g = generate(GraphFamily::Web, 9, 17);
        let out = run(&g, 42);
        assert!(out.modularity > 0.1, "q={}", out.modularity);
        assert!(out.num_communities > 1);
    }

    #[test]
    fn nido_quality_below_nu_louvain() {
        // Paper Fig 12c: ν-Louvain 45% higher modularity than Nido.
        let g = generate(GraphFamily::Web, 10, 19);
        let nido = run(&g, 42);
        let nu = nu_outcome(&g);
        assert!(
            nu.modularity > nido.modularity,
            "nu={} nido={}",
            nu.modularity,
            nido.modularity
        );
    }
}
