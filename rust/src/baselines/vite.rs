//! Vite signature (Ghosh et al., HPEC'18): distributed-memory Louvain
//! run on one node.
//!
//! Encoded traits: bulk-synchronous sweeps (double-buffered membership,
//! the MPI ghost-exchange structure), `std::map`-style tables,
//! **threshold cycling** (the tolerance cycles between coarse and fine
//! instead of decaying monotonically), no pruning, and a per-sweep
//! collective-communication overhead added to the modeled time.

use super::common::cpu_modeled_ns;
use super::{BaselineOutcome, System};
use crate::graph::Csr;
use crate::louvain::aggregation::{aggregate_csr_with, AggScratch};
use crate::louvain::dendrogram;
use crate::louvain::hashtable::TablePool;
use crate::louvain::modularity::modularity;
use crate::louvain::params::{LouvainParams, TableKind};
use crate::louvain::renumber::renumber_communities;
use crate::parallel::pool::ParallelOpts;
use crate::parallel::team::{shared_team, Exec};
use std::time::Instant;

const MAX_PASSES: usize = 10;
const MAX_SWEEPS: usize = 40;
/// Modeled MPI collective cost per bulk-synchronous sweep (one node,
/// 32 ranks: allreduce + ghost exchange).
const COLLECTIVE_NS_PER_SWEEP: u64 = 250_000;

/// Threshold cycling: coarse for two sweeps, fine for one, repeating.
fn cycled_tolerance(sweep: usize, base: f64) -> f64 {
    if sweep % 3 == 2 {
        base / 100.0
    } else {
        base
    }
}

pub fn run(g: &Csr, threads: usize, _seed: u64) -> BaselineOutcome {
    let t0 = Instant::now();
    let n0 = g.num_vertices();
    let m = g.total_weight();
    let mut top: Vec<u32> = (0..n0 as u32).collect();
    let mut owned: Option<Csr> = None;
    let mut passes = 0usize;
    let mut sweeps_total = 0u64;
    // Aggregation resources hoisted out of the pass loop: the pool and
    // scratch are sized by the first aggregation and reused afterwards
    // (the pass-workspace contract; Vite itself keeps per-rank buffers
    // alive across passes too).
    let mut agg_pool: Option<TablePool> = None;
    let mut agg_scratch = AggScratch::new();
    // PR 10: sweeps run on the process-wide shared team — the same
    // runtime as the GVE path, so Fig-11 comparisons are apples to
    // apples — with the same `pass` span coverage.
    let team = shared_team(threads.max(1));
    let exec = Exec::team(&team);
    let opts = ParallelOpts { threads: threads.max(1), ..ParallelOpts::default() };

    for pass in 0..MAX_PASSES {
        let gp: &Csr = owned.as_ref().unwrap_or(g);
        let np = gp.num_vertices();
        let _pass_span = crate::trace::span(
            "pass",
            crate::trace::Category::Pass,
            [pass as u64, np as u64, gp.num_edges() as u64, threads.max(1) as u64],
        );
        let k = gp.vertex_weights();
        let mut membership: Vec<u32> = (0..np as u32).collect();
        let mut sigma = k.clone();
        let mut pass_dq = 0.0;

        let mut sweeps = 0usize;
        for sweep in 0..MAX_SWEEPS {
            let tol = cycled_tolerance(sweep, 1e-2);
            // Alternate monotone sweeps: the standard BSP oscillation
            // breaker (symmetric pairs would otherwise swap forever).
            let monotone = sweep % 2 == 1;
            let (next, dq, moves) = super::common::sync_sweep_exec(
                gp, &membership, &k, &sigma, m, None, monotone, opts, exec,
            );
            membership = next;
            // Σ is rebuilt from scratch each sweep (the BSP exchange).
            sigma.iter_mut().for_each(|s| *s = 0.0);
            for v in 0..np {
                sigma[membership[v] as usize] += k[v];
            }
            sweeps += 1;
            pass_dq += dq;
            if dq <= tol || moves == 0 {
                break;
            }
        }
        sweeps_total += sweeps as u64;
        passes += 1;

        let n_comm = renumber_communities(&mut membership);
        dendrogram::lookup(&mut top, &membership);
        if sweeps <= 1 || n_comm == np {
            break;
        }
        let _ = pass_dq;
        // Vite's aggregation is map-based; reuse the CSR path with the
        // slow Map tables to retain the signature's cost profile.
        let pool = TablePool::ensure(&mut agg_pool, TableKind::Map, n_comm, 1);
        let params = LouvainParams { table: TableKind::Map, threads: 1, ..Default::default() };
        owned = Some(
            aggregate_csr_with(gp, &membership, n_comm, pool, &params, Exec::scoped(), &mut agg_scratch)
                .graph,
        );
    }

    let wall = t0.elapsed().as_nanos() as u64;
    let n_comm = renumber_communities(&mut top);
    BaselineOutcome {
        system: System::Vite,
        modularity: modularity(g, &top),
        membership: top,
        num_communities: n_comm,
        passes,
        wall_ns: wall,
        modeled_ns: Some(cpu_modeled_ns(wall, threads, 32) + sweeps_total * COLLECTIVE_NS_PER_SWEEP),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn vite_finds_communities() {
        let g = generate(GraphFamily::Web, 9, 3);
        let out = run(&g, 1, 42);
        assert!(out.modularity > 0.5, "q={}", out.modularity);
        assert!(out.num_communities > 1);
    }

    #[test]
    fn threshold_cycling_pattern() {
        assert_eq!(cycled_tolerance(0, 1e-2), 1e-2);
        assert_eq!(cycled_tolerance(1, 1e-2), 1e-2);
        assert_eq!(cycled_tolerance(2, 1e-2), 1e-4);
        assert_eq!(cycled_tolerance(5, 1e-2), 1e-4);
    }

    #[test]
    fn vite_models_collective_overhead() {
        let g = generate(GraphFamily::Road, 8, 5);
        let out = run(&g, 1, 42);
        // Modeled time includes the per-sweep collectives.
        assert!(out.modeled_ns.unwrap() > 0);
    }
}
