//! NetworKit PLM signature (Staudt & Meyerhenke, TPDS'16).
//!
//! Encoded traits: asynchronous parallel local moving (like GVE), but
//! **Close-KV** tables (the packed layout whose false sharing §4.1.9
//! blames — 1.3× slower), move-until-quiet convergence (no ΔQ
//! tolerance, no threshold scaling), no pruning, no aggregation
//! tolerance — the paper measures GVE 20× faster.

use super::common::cpu_modeled_ns;
use super::{BaselineOutcome, System};
use crate::graph::Csr;
use crate::louvain::gve::GveLouvain;
use crate::louvain::params::{AggregationKind, LouvainParams, TableKind};
use std::time::Instant;

pub fn run(g: &Csr, threads: usize, _seed: u64) -> BaselineOutcome {
    let params = LouvainParams {
        max_passes: 10,
        max_iterations: 32,
        tolerance: 0.0,       // move until quiet
        tolerance_drop: 1.0,  // no threshold scaling
        aggregation_tolerance: 1.0,
        pruning: false,
        table: TableKind::CloseKv,
        aggregation: AggregationKind::Csr,
        threads,
        ..Default::default()
    };
    let t0 = Instant::now();
    let out = GveLouvain::new(params).run(g);
    let wall = t0.elapsed().as_nanos() as u64;
    // Close-KV false sharing costs ~1.3× on a real multicore (§4.1.9);
    // invisible on this 1-core host, so charged in the projection.
    const FALSE_SHARING_FACTOR: f64 = 1.3;
    BaselineOutcome {
        system: System::NetworKit,
        membership: out.membership,
        modularity: out.modularity,
        num_communities: out.num_communities,
        passes: out.passes,
        wall_ns: wall,
        modeled_ns: Some((cpu_modeled_ns(wall, threads, 32) as f64 * FALSE_SHARING_FACTOR) as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::gve_outcome;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn plm_quality_on_par_with_gve() {
        let g = generate(GraphFamily::Web, 9, 9);
        let nk = run(&g, 1, 42);
        let gve = gve_outcome(&g, 1);
        // Paper: NetworKit ≈ 0.6% higher modularity than GVE.
        assert!((nk.modularity - gve.modularity).abs() < 0.05,
                "nk={} gve={}", nk.modularity, gve.modularity);
    }

    #[test]
    fn plm_does_more_iterations_than_gve() {
        // No iteration cap at 20 / no tolerance: strictly more sweeps.
        let g = generate(GraphFamily::Social, 9, 11);
        let t0 = Instant::now();
        let _ = run(&g, 1, 42);
        let nk_time = t0.elapsed();
        let t1 = Instant::now();
        let _ = gve_outcome(&g, 1);
        let gve_time = t1.elapsed();
        // The signature must cost more work (wall time is a proxy even on
        // 1 core — same machinery, more sweeps + no pruning).
        assert!(nk_time >= gve_time / 2, "sanity: {nk_time:?} vs {gve_time:?}");
    }
}
