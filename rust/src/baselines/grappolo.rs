//! Grappolo signature (Halappanavar et al., HPEC'17): shared-memory
//! parallel Louvain with **graph-coloring-ordered** sweeps.
//!
//! Encoded traits: greedy coloring prepass; vertices processed color
//! class by color class (no two adjacent vertices decide concurrently —
//! their anti-swap mechanism); map-style tables; **threshold scaling**
//! (they introduced it); no pruning; full aggregation each pass.

use super::common::{cpu_modeled_ns, greedy_coloring, sync_sweep_exec};
use super::{BaselineOutcome, System};
use crate::graph::Csr;
use crate::louvain::aggregation::{aggregate_csr_with, AggScratch};
use crate::louvain::dendrogram;
use crate::louvain::hashtable::TablePool;
use crate::louvain::modularity::modularity;
use crate::louvain::params::{LouvainParams, TableKind};
use crate::louvain::renumber::renumber_communities;
use crate::parallel::pool::ParallelOpts;
use crate::parallel::team::{shared_team, Exec};
use std::time::Instant;

const MAX_PASSES: usize = 10;
const MAX_SWEEPS: usize = 30;

pub fn run(g: &Csr, threads: usize, _seed: u64) -> BaselineOutcome {
    let t0 = Instant::now();
    let n0 = g.num_vertices();
    let m = g.total_weight();
    let mut top: Vec<u32> = (0..n0 as u32).collect();
    let mut owned: Option<Csr> = None;
    let mut passes = 0usize;
    let mut tau = 1e-2; // threshold scaling start
    // Aggregation pool + scratch hoisted out of the pass loop and
    // reused (the pass-workspace contract).
    let mut agg_pool: Option<TablePool> = None;
    let mut agg_scratch = AggScratch::new();
    // PR 10: colored sweeps run on the process-wide shared team with
    // the same `pass` span coverage as the GVE path.
    let team = shared_team(threads.max(1));
    let exec = Exec::team(&team);
    let opts = ParallelOpts { threads: threads.max(1), ..ParallelOpts::default() };

    for pass in 0..MAX_PASSES {
        let gp: &Csr = owned.as_ref().unwrap_or(g);
        let np = gp.num_vertices();
        let _pass_span = crate::trace::span(
            "pass",
            crate::trace::Category::Pass,
            [pass as u64, np as u64, gp.num_edges() as u64, threads.max(1) as u64],
        );
        let (colors, n_colors) = greedy_coloring(gp);
        let k = gp.vertex_weights();
        let mut membership: Vec<u32> = (0..np as u32).collect();
        let mut sigma = k.clone();

        let mut sweeps = 0usize;
        for _ in 0..MAX_SWEEPS {
            let (next, dq, moves) = sync_sweep_exec(
                gp, &membership, &k, &sigma, m, Some((&colors, n_colors)), false, opts, exec,
            );
            membership = next;
            sigma.iter_mut().for_each(|s| *s = 0.0);
            for v in 0..np {
                sigma[membership[v] as usize] += k[v];
            }
            sweeps += 1;
            if dq <= tau || moves == 0 {
                break;
            }
        }
        passes += 1;

        let n_comm = renumber_communities(&mut membership);
        dendrogram::lookup(&mut top, &membership);
        if sweeps <= 1 || n_comm == np {
            break;
        }
        let pool = TablePool::ensure(&mut agg_pool, TableKind::Map, n_comm, 1);
        let params = LouvainParams { table: TableKind::Map, threads: 1, ..Default::default() };
        owned = Some(
            aggregate_csr_with(gp, &membership, n_comm, pool, &params, Exec::scoped(), &mut agg_scratch)
                .graph,
        );
        tau /= 10.0; // threshold scaling
    }

    let wall = t0.elapsed().as_nanos() as u64;
    let n_comm = renumber_communities(&mut top);
    BaselineOutcome {
        system: System::Grappolo,
        modularity: modularity(g, &top),
        membership: top,
        num_communities: n_comm,
        passes,
        wall_ns: wall,
        modeled_ns: Some(cpu_modeled_ns(wall, threads, 32)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn grappolo_finds_good_communities() {
        let g = generate(GraphFamily::Web, 9, 7);
        let out = run(&g, 1, 42);
        // Paper Fig 11c: Grappolo's modularity is on par with (slightly
        // above) GVE-Louvain.
        assert!(out.modularity > 0.7, "q={}", out.modularity);
    }

    #[test]
    fn coloring_prevents_adjacent_swaps() {
        // With color classes, the 2-vertex swap of the BSP sweep cannot
        // happen: the second vertex sees the first's new community.
        use crate::graph::builder::GraphBuilder;
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let out = run(&g, 1, 42);
        assert_eq!(out.num_communities, 1, "pair must merge, not oscillate");
    }
}
