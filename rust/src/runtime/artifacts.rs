//! Artifact manifest discovery.
//!
//! `python -m compile.aot` writes `manifest.txt` rows of
//! `file<TAB>kind<TAB>params`; this module parses them and locates the
//! artifacts directory (`$GVE_ARTIFACTS`, else `./artifacts`, walking up
//! from the current directory so tests work from any workspace subdir).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Local-moving tile step: `(tv, md)` fixed shape.
    MoveStep { tv: usize, md: usize },
    /// Modularity chunk reduction over `c` communities.
    Modularity { c: usize },
}

/// One manifest row.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub file: String,
    pub kind: ArtifactKind,
}

/// Parsed manifest + base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

/// Locate the artifacts directory.
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("GVE_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

impl Manifest {
    /// Load the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let mut entries = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut cols = line.split('\t');
            let file = cols.next().context("file col")?.to_string();
            let kind = cols.next().context("kind col")?;
            let params = cols.next().unwrap_or("");
            let kv: std::collections::HashMap<&str, usize> = params
                .split_whitespace()
                .filter_map(|p| {
                    let (k, v) = p.split_once('=')?;
                    Some((k, v.parse().ok()?))
                })
                .collect();
            let kind = match kind {
                "move_step" => ArtifactKind::MoveStep {
                    tv: *kv.get("tv").with_context(|| format!("line {ln}: tv"))?,
                    md: *kv.get("md").with_context(|| format!("line {ln}: md"))?,
                },
                "modularity" => ArtifactKind::Modularity {
                    c: *kv.get("c").with_context(|| format!("line {ln}: c"))?,
                },
                other => bail!("unknown artifact kind {other:?} at line {ln}"),
            };
            entries.push(ArtifactEntry { file, kind });
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// Discover + load, or explain what to run.
    pub fn discover() -> Result<Self> {
        let dir = find_artifacts_dir()
            .context("artifacts directory not found; run `make artifacts` first")?;
        Self::load(&dir)
    }

    /// All move-step tile classes, sorted by ascending `md`.
    pub fn tile_classes(&self) -> Vec<(usize, usize, PathBuf)> {
        let mut v: Vec<(usize, usize, PathBuf)> = self
            .entries
            .iter()
            .filter_map(|e| match e.kind {
                ArtifactKind::MoveStep { tv, md } => Some((tv, md, self.dir.join(&e.file))),
                _ => None,
            })
            .collect();
        v.sort_by_key(|&(_, md, _)| md);
        v
    }

    /// The modularity chunk artifact, if present.
    pub fn modularity(&self) -> Option<(usize, PathBuf)> {
        self.entries.iter().find_map(|e| match e.kind {
            ArtifactKind::Modularity { c } => Some((c, self.dir.join(&e.file))),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(rows: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gve_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), rows).unwrap();
        dir
    }

    #[test]
    fn parses_rows() {
        let dir = write_manifest(
            "a.hlo.txt\tmove_step\ttv=256 md=32\nb.hlo.txt\tmove_step\ttv=16 md=512\nq.hlo.txt\tmodularity\tc=4096\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        let classes = m.tile_classes();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].1, 32); // sorted by md
        assert_eq!(m.modularity().unwrap().0, 4096);
    }

    #[test]
    fn rejects_unknown_kind() {
        let dir = write_manifest("x\tbogus\t\n");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = std::env::temp_dir().join("gve_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
