//! ν-Louvain with its local-moving hot-spot on real XLA executables.
//!
//! This is the full three-layer path: the L1 Pallas community-scan
//! kernel (lowered inside the L2 `move_step` graph) executes through
//! PJRT for every tile, while the Rust coordinator owns Σ'/membership
//! state, pruning, convergence, renumbering, dendrogram and the
//! aggregation phase.  Lock-step semantics hold *within a tile* (all
//! rows were scanned against the same state snapshot), mirroring the
//! simulator's warp granularity — so Pick-Less is needed here too.

use super::executor::MoveExecutor;
use super::tile::TileBuilder;
use crate::gpusim::nulouvain::{pick_less_active, NuParams};
use crate::graph::Csr;
use crate::louvain::aggregation::{aggregate_csr_with, AggScratch};
use crate::louvain::dendrogram;
use crate::louvain::hashtable::TablePool;
use crate::louvain::modularity::modularity;
use crate::louvain::params::{LouvainParams, TableKind};
use crate::louvain::renumber::renumber_communities;
use crate::parallel::team::Exec;
use anyhow::Result;
use std::time::Instant;

/// Result of a PJRT-backed ν-Louvain run.
#[derive(Debug, Default)]
pub struct PjrtLouvainResult {
    pub membership: Vec<u32>,
    pub modularity: f64,
    /// Modularity recomputed through the device reduction artifact
    /// (cross-check against the host value).
    pub modularity_device: Option<f64>,
    pub num_communities: usize,
    pub passes: usize,
    pub wall_ns: u64,
    /// PJRT dispatches (tiles + modularity chunks).
    pub dispatches: u64,
    /// Neighbour slots dropped by tile truncation (0 unless a vertex
    /// exceeds the largest MD class).
    pub truncated_slots: u64,
}

/// The PJRT-backed ν-Louvain driver.
pub struct PjrtLouvain<'e> {
    pub executor: &'e MoveExecutor,
    pub params: NuParams,
}

impl<'e> PjrtLouvain<'e> {
    pub fn new(executor: &'e MoveExecutor, params: NuParams) -> Self {
        Self { executor, params }
    }

    pub fn run(&self, g: &Csr) -> Result<PjrtLouvainResult> {
        let p = &self.params;
        let t0 = Instant::now();
        let n0 = g.num_vertices();
        let m = g.total_weight();
        let mut result = PjrtLouvainResult {
            membership: (0..n0 as u32).collect(),
            ..Default::default()
        };
        if n0 == 0 || m == 0.0 {
            result.num_communities = n0;
            return Ok(result);
        }
        let builder = TileBuilder::new(self.executor.classes());
        let dispatches0 = self.executor.dispatches.get();
        let mut owned: Option<Csr> = None;
        let mut tau = p.tolerance;
        // CPU-side aggregation resources, hoisted out of the pass loop
        // and reused (the pass-workspace contract).
        let mut agg_pool: Option<TablePool> = None;
        let mut agg_scratch = AggScratch::new();

        for pass in 0..p.max_passes {
            let gp: &Csr = owned.as_ref().unwrap_or(g);
            let np = gp.num_vertices();
            let k = gp.vertex_weights();
            let mut sigma = k.clone();
            let mut membership: Vec<u32> = (0..np as u32).collect();
            let mut affected = vec![true; np];

            let mut iterations = 0usize;
            for li in 0..p.max_iterations {
                let pl = pick_less_active(li, p.rho);
                // Gather the active frontier.
                let active: Vec<usize> = (0..np).filter(|&v| affected[v]).collect();
                if active.is_empty() {
                    break;
                }
                for &v in &active {
                    affected[v] = false;
                }
                let (tiles, truncated) = builder.pack(gp, &active, &membership, &k, &sigma);
                result.truncated_slots += truncated;
                let mut dq_iter = 0f64;
                for tile in &tiles {
                    let moves = self.executor.move_step(tile, m, pl)?;
                    // Lock-step apply: every row of the tile saw the same
                    // snapshot; commit after the device call.
                    for (v, c, dq, accepted) in moves.rows {
                        if !accepted || membership[v] == c {
                            continue;
                        }
                        let d = membership[v] as usize;
                        sigma[d] -= k[v];
                        sigma[c as usize] += k[v];
                        membership[v] = c;
                        dq_iter += dq as f64;
                        for (t, _) in gp.neighbours(v) {
                            affected[t as usize] = true;
                        }
                    }
                }
                iterations += 1;
                if dq_iter <= tau {
                    break;
                }
            }

            let n_comm = renumber_communities(&mut membership);
            let converged = iterations <= 1;
            let low_shrink = (n_comm as f64) / (np as f64) > p.aggregation_tolerance;
            dendrogram::lookup(&mut result.membership, &membership);
            result.passes = pass + 1;
            if converged || low_shrink || pass + 1 == p.max_passes {
                break;
            }
            // Aggregation stays on the coordinator (CPU CSR path).
            let pool = TablePool::ensure(&mut agg_pool, TableKind::FarKv, n_comm, 1);
            let lp = LouvainParams::default();
            owned = Some(
                aggregate_csr_with(gp, &membership, n_comm, pool, &lp, Exec::scoped(), &mut agg_scratch)
                    .graph,
            );
            tau /= p.tolerance_drop;
        }

        result.num_communities = renumber_communities(&mut result.membership);
        result.modularity = modularity(g, &result.membership);
        // Device-side modularity cross-check (Eq. 1 through the artifact).
        let (sigma_c, big_c) =
            crate::louvain::modularity::community_weights(g, &result.membership);
        result.modularity_device = self.executor.modularity(&sigma_c, &big_c, m).ok();
        result.dispatches = self.executor.dispatches.get() - dispatches0;
        result.wall_ns = t0.elapsed().as_nanos() as u64;
        Ok(result)
    }
}
