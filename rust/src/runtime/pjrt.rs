//! PJRT client + executable wrappers over the `xla` crate.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Text is the interchange format
//! because jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects in serialized protos.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU runtime holding the client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled, loaded executable (jax-lowered with `return_tuple=True`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with input literals; returns the output tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Build an `f32` literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an `i32` literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract a flat `f32` vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a flat `i32` vector from a literal.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}
