//! Typed executors over the compiled artifacts.
//!
//! [`MoveExecutor`] owns one compiled executable per tile class plus the
//! modularity chunk evaluator, and dispatches packed [`Tile`]s to the
//! right executable.

use super::artifacts::Manifest;
use super::pjrt::{literal_f32, literal_i32, to_vec_f32, to_vec_i32, Executable, Runtime};
use super::tile::Tile;
use anyhow::{Context, Result};

/// Result of one tile move step.
#[derive(Clone, Debug)]
pub struct TileMoves {
    /// Per real row: (vertex, new_community, dq, accepted).
    pub rows: Vec<(usize, u32, f32, bool)>,
    /// Σ of accepted dq over the tile (device-reduced).
    pub dq_total: f32,
}

/// Executor holding the compiled move-step executables + modularity.
pub struct MoveExecutor {
    runtime: Runtime,
    /// `(tv, md, exe)` sorted by ascending md.
    move_exes: Vec<(usize, usize, Executable)>,
    modularity: Option<(usize, Executable)>,
    /// PJRT dispatches performed (perf accounting).
    pub dispatches: std::cell::Cell<u64>,
}

impl MoveExecutor {
    /// Compile all artifacts in the manifest.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let mut move_exes = Vec::new();
        for (tv, md, path) in manifest.tile_classes() {
            let exe = runtime.load_hlo_text(&path)?;
            move_exes.push((tv, md, exe));
        }
        if move_exes.is_empty() {
            anyhow::bail!("manifest has no move_step artifacts");
        }
        let modularity = match manifest.modularity() {
            Some((c, path)) => Some((c, runtime.load_hlo_text(&path)?)),
            None => None,
        };
        Ok(Self { runtime, move_exes, modularity, dispatches: std::cell::Cell::new(0) })
    }

    /// Discover artifacts and compile.
    pub fn discover() -> Result<Self> {
        Self::from_manifest(&Manifest::discover()?)
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Tile classes available, `(tv, md)` ascending by md.
    pub fn classes(&self) -> Vec<(usize, usize)> {
        self.move_exes.iter().map(|&(tv, md, _)| (tv, md)).collect()
    }

    /// Run one packed tile through its executable.
    ///
    /// `m` — total edge weight; `pick_less` — the PL constraint flag.
    pub fn move_step(&self, tile: &Tile, m: f64, pick_less: bool) -> Result<TileMoves> {
        let (tv, md) = (tile.tv, tile.md);
        let exe = &self
            .move_exes
            .iter()
            .find(|&&(etv, emd, _)| etv == tv && emd == md)
            .with_context(|| format!("no executable for tile class ({tv}, {md})"))?
            .2;
        let dims2 = [tv as i64, md as i64];
        let dims1 = [tv as i64];
        let inputs = [
            literal_i32(&tile.nbr_comm, &dims2)?,
            literal_f32(&tile.nbr_wt, &dims2)?,
            literal_i32(&tile.self_comm, &dims1)?,
            literal_f32(&tile.ktot, &dims1)?,
            literal_f32(&tile.sigma_nbr, &dims2)?,
            literal_f32(&tile.sigma_self, &dims1)?,
            literal_f32(&[m as f32, if pick_less { 1.0 } else { 0.0 }], &[1, 2])?,
        ];
        let outs = exe.run(&inputs)?;
        self.dispatches.set(self.dispatches.get() + 1);
        anyhow::ensure!(outs.len() == 4, "expected 4 outputs, got {}", outs.len());
        let out_comm = to_vec_i32(&outs[0])?;
        let dq = to_vec_f32(&outs[1])?;
        let accept = to_vec_i32(&outs[2])?;
        let dq_total = to_vec_f32(&outs[3])?[0];

        let rows = tile
            .vertices
            .iter()
            .enumerate()
            .map(|(row, &v)| (v, out_comm[row] as u32, dq[row], accept[row] != 0))
            .collect();
        Ok(TileMoves { rows, dq_total })
    }

    /// Evaluate modularity from per-community (σ, Σ) via the device
    /// reduction, chunked to the artifact's fixed width.
    pub fn modularity(&self, sigma: &[f64], big_sigma: &[f64], m: f64) -> Result<f64> {
        let (c, exe) = self.modularity.as_ref().context("no modularity artifact")?;
        let minv = literal_f32(&[(1.0 / (2.0 * m)) as f32], &[1])?;
        let mut q = 0f64;
        let mut lo = 0usize;
        while lo < sigma.len() {
            let hi = (lo + c).min(sigma.len());
            let mut s = vec![0f32; *c];
            let mut b = vec![0f32; *c];
            for i in lo..hi {
                s[i - lo] = sigma[i] as f32;
                b[i - lo] = big_sigma[i] as f32;
            }
            let outs = exe.run(&[
                literal_f32(&s, &[*c as i64])?,
                literal_f32(&b, &[*c as i64])?,
                minv.clone(),
            ])?;
            self.dispatches.set(self.dispatches.get() + 1);
            q += to_vec_f32(&outs[0])?[0] as f64;
            lo = hi;
        }
        Ok(q)
    }
}
