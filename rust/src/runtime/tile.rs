//! Tile packing: fixed-shape `(TV, MD)` batches for the PJRT move step.
//!
//! The Pallas kernel runs on fixed shapes, so vertices are routed by
//! degree to the smallest tile class whose `MD` fits (the
//! thread/block-per-vertex switch of Figs 9–10 re-expressed as
//! padding-class selection), packed `TV` at a time, and padded with
//! `PAD` slots.  `sigma_nbr` / `sigma_self` are gathered host-side —
//! the Σ' state lives with the Rust coordinator.

use crate::graph::Csr;

/// Padding community id (must match `ref.PAD` on the python side).
pub const PAD: i32 = -1;

/// One packed tile ready for the executor.
#[derive(Clone, Debug)]
pub struct Tile {
    pub tv: usize,
    pub md: usize,
    /// The real vertices in rows `0..vertices.len()` (rest is padding).
    pub vertices: Vec<usize>,
    pub nbr_comm: Vec<i32>,
    pub nbr_wt: Vec<f32>,
    pub self_comm: Vec<i32>,
    pub ktot: Vec<f32>,
    pub sigma_nbr: Vec<f32>,
    pub sigma_self: Vec<f32>,
}

impl Tile {
    fn empty(tv: usize, md: usize) -> Self {
        Self {
            tv,
            md,
            vertices: Vec::with_capacity(tv),
            nbr_comm: vec![PAD; tv * md],
            nbr_wt: vec![0.0; tv * md],
            self_comm: vec![0; tv],
            ktot: vec![0.0; tv],
            sigma_nbr: vec![0.0; tv * md],
            sigma_self: vec![0.0; tv],
        }
    }
}

/// Routes vertices into tile classes and packs tiles.
pub struct TileBuilder {
    /// `(tv, md)` classes sorted by ascending `md`.
    pub classes: Vec<(usize, usize)>,
}

impl TileBuilder {
    pub fn new(mut classes: Vec<(usize, usize)>) -> Self {
        classes.sort_by_key(|&(_, md)| md);
        assert!(!classes.is_empty(), "need at least one tile class");
        Self { classes }
    }

    /// Class index for a vertex of degree `d` (smallest md ≥ d;
    /// oversized vertices go to the largest class, truncated).
    pub fn class_for_degree(&self, d: usize) -> usize {
        for (ci, &(_, md)) in self.classes.iter().enumerate() {
            if d <= md {
                return ci;
            }
        }
        self.classes.len() - 1
    }

    /// Pack `vertices` (with current membership/Σ state) into tiles.
    ///
    /// Self-loops are excluded from the slots (the kernel's move-scan
    /// contract); degrees beyond the largest `MD` are truncated with a
    /// count returned in `truncated`.
    #[allow(clippy::too_many_arguments)]
    pub fn pack(
        &self,
        g: &Csr,
        vertices: &[usize],
        membership: &[u32],
        ktot: &[f64],
        sigma: &[f64],
    ) -> (Vec<Tile>, u64) {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.classes.len()];
        for &v in vertices {
            if g.degree(v) == 0 {
                continue;
            }
            buckets[self.class_for_degree(g.degree(v))].push(v);
        }
        let mut tiles = Vec::new();
        let mut truncated = 0u64;
        for (ci, bucket) in buckets.iter().enumerate() {
            let (tv, md) = self.classes[ci];
            for group in bucket.chunks(tv) {
                let mut tile = Tile::empty(tv, md);
                for (row, &v) in group.iter().enumerate() {
                    tile.vertices.push(v);
                    tile.self_comm[row] = membership[v] as i32;
                    tile.ktot[row] = ktot[v] as f32;
                    tile.sigma_self[row] = sigma[membership[v] as usize] as f32;
                    let (ts, ws) = g.edges(v);
                    let mut slot = 0usize;
                    for (t, w) in ts.iter().zip(ws) {
                        if *t as usize == v {
                            continue; // self-loop excluded from move scan
                        }
                        if slot >= md {
                            truncated += 1;
                            break;
                        }
                        let c = membership[*t as usize];
                        tile.nbr_comm[row * md + slot] = c as i32;
                        tile.nbr_wt[row * md + slot] = *w;
                        tile.sigma_nbr[row * md + slot] = sigma[c as usize] as f32;
                        slot += 1;
                    }
                }
                tiles.push(tile);
            }
        }
        (tiles, truncated)
    }

    /// Padding efficiency of a packing: real rows / total rows.
    pub fn occupancy(tiles: &[Tile]) -> f64 {
        let real: usize = tiles.iter().map(|t| t.vertices.len()).sum();
        let total: usize = tiles.iter().map(|t| t.tv).sum();
        if total == 0 {
            0.0
        } else {
            real as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};

    fn builder() -> TileBuilder {
        TileBuilder::new(vec![(256, 32), (64, 128), (16, 512)])
    }

    #[test]
    fn class_routing_by_degree() {
        let b = builder();
        assert_eq!(b.class_for_degree(1), 0);
        assert_eq!(b.class_for_degree(32), 0);
        assert_eq!(b.class_for_degree(33), 1);
        assert_eq!(b.class_for_degree(128), 1);
        assert_eq!(b.class_for_degree(129), 2);
        assert_eq!(b.class_for_degree(10_000), 2); // truncates
    }

    #[test]
    fn pack_simple_graph() {
        let g = GraphBuilder::new(4)
            .edge(0, 1, 1.0)
            .edge(1, 2, 2.0)
            .edge(2, 3, 1.0)
            .build_undirected();
        let b = builder();
        let memb: Vec<u32> = (0..4).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let (tiles, trunc) = b.pack(&g, &[0, 1, 2, 3], &memb, &k, &sigma);
        assert_eq!(trunc, 0);
        assert_eq!(tiles.len(), 1);
        let t = &tiles[0];
        assert_eq!(t.vertices, vec![0, 1, 2, 3]);
        assert_eq!((t.tv, t.md), (256, 32));
        // Row 1 = vertex 1: neighbours 0 (w1) and 2 (w2).
        assert_eq!(t.nbr_comm[1 * 32], 0);
        assert_eq!(t.nbr_wt[1 * 32], 1.0);
        assert_eq!(t.nbr_comm[1 * 32 + 1], 2);
        assert_eq!(t.nbr_wt[1 * 32 + 1], 2.0);
        assert_eq!(t.nbr_comm[1 * 32 + 2], PAD);
        assert_eq!(t.ktot[1], 3.0);
    }

    #[test]
    fn self_loops_excluded() {
        let g = GraphBuilder::new(2).edge(0, 0, 5.0).edge(0, 1, 1.0).build_undirected();
        let b = builder();
        let memb: Vec<u32> = vec![0, 1];
        let k = g.vertex_weights();
        let sigma = k.clone();
        let (tiles, _) = b.pack(&g, &[0], &memb, &k, &sigma);
        let t = &tiles[0];
        assert_eq!(t.nbr_comm[0], 1); // only the real neighbour
        assert_eq!(t.nbr_comm[1], PAD);
        assert_eq!(t.ktot[0], 6.0); // K includes the self-loop weight
    }

    #[test]
    fn pack_routes_realistic_graph_to_multiple_classes() {
        let g = generate(GraphFamily::Web, 11, 3);
        let b = builder();
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let all: Vec<usize> = (0..n).collect();
        let (tiles, _trunc) = b.pack(&g, &all, &memb, &k, &sigma);
        let mds: std::collections::BTreeSet<usize> = tiles.iter().map(|t| t.md).collect();
        assert!(mds.len() >= 2, "web graph should hit several classes: {mds:?}");
        let packed: usize = tiles.iter().map(|t| t.vertices.len()).sum();
        let isolated = (0..n).filter(|&v| g.degree(v) == 0).count();
        assert_eq!(packed, n - isolated);
        assert!(TileBuilder::occupancy(&tiles) > 0.2);
    }

    #[test]
    fn sigma_gather_is_consistent() {
        let g = generate(GraphFamily::Road, 8, 5);
        let b = builder();
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).map(|v| v % 7).collect();
        let k = g.vertex_weights();
        let mut sigma = vec![0f64; n];
        for v in 0..n {
            sigma[memb[v] as usize] += k[v];
        }
        let all: Vec<usize> = (0..n).collect();
        let (tiles, _) = b.pack(&g, &all, &memb, &k, &sigma);
        for t in &tiles {
            for (row, &v) in t.vertices.iter().enumerate() {
                assert_eq!(t.self_comm[row], memb[v] as i32);
                assert!((t.sigma_self[row] as f64 - sigma[memb[v] as usize]).abs() < 1e-3);
                for slot in 0..t.md {
                    let c = t.nbr_comm[row * t.md + slot];
                    if c == PAD {
                        break;
                    }
                    assert!((t.sigma_nbr[row * t.md + slot] as f64 - sigma[c as usize]).abs() < 1e-3);
                }
            }
        }
    }
}
