//! PJRT runtime: the Rust side of the three-layer AOT bridge.
//!
//! `make artifacts` lowers the L2 jax graphs (which call the L1 Pallas
//! community-scan kernel) to HLO *text*; this module loads those
//! artifacts with the `xla` crate's PJRT CPU client and exposes them as
//! typed executables.  Python never runs at serve time.
//!
//! * [`artifacts`] — manifest discovery (`artifacts/manifest.txt`);
//! * [`pjrt`] — client + executable wrappers;
//! * [`tile`] — packing vertices into fixed-shape `(TV, MD)` tiles
//!   (degree-routed tile classes = the paper's thread/block kernel
//!   partition re-expressed for a fixed-shape accelerator);
//! * [`executor`] — typed `move_step` / `modularity_chunk` calls;
//! * [`pjrt_louvain`] — ν-Louvain with its local-moving hot-spot
//!   running on the real XLA executables.

pub mod artifacts;
pub mod executor;
pub mod pjrt;
pub mod pjrt_louvain;
pub mod tile;

pub use artifacts::{ArtifactKind, Manifest};
pub use executor::MoveExecutor;
pub use pjrt::Runtime;
