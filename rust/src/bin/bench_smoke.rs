//! `bench_smoke` — the PR-1 perf-trajectory seed runner.
//!
//! Runs GVE-Louvain over every planted [`GraphFamily`] at 1 and 4
//! threads (warmup + repeats, median) and writes a `BENCH_PR1.json`
//! with edges/sec per cell — the fixed yardstick future PRs compare
//! against.  Hand-rolled JSON (the offline registry has no serde).
//!
//! Usage (see also `scripts/bench_smoke.sh` and the `bench-smoke`
//! cargo alias):
//!
//! ```text
//! bench_smoke [OUT.json]          # default BENCH_PR1.json
//! GVE_BENCH_SCALE=-3 bench_smoke  # shift graph scales (quick CI)
//! GVE_BENCH_REPEATS=5 bench_smoke
//! ```
//!
//! To compare against a pre-change baseline, run the *same* binary on
//! the baseline commit with a different output path and diff the
//! `edges_per_sec` fields:
//!
//! ```text
//! git stash && cargo bench-smoke BENCH_PR1_baseline.json && git stash pop
//! cargo bench-smoke BENCH_PR1.json
//! ```

use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::metrics::{edges_per_sec, median};
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::{gve::GveLouvain, params::LouvainParams};
use std::fmt::Write as _;
use std::time::Instant;

/// Base scale before `GVE_BENCH_SCALE` shifting (2^13 vertices).
const BASE_SCALE: i32 = 13;
const THREADS: [usize; 2] = [1, 4];

struct Cell {
    family: &'static str,
    threads: usize,
    vertices: usize,
    edges: usize,
    median_ns: u64,
    edges_per_sec: f64,
    modularity: f64,
    passes: usize,
    spawned_workers: usize,
}

/// Median via the crate-wide convention (`coordinator::metrics`), so
/// `BENCH_PR1.json` uses the same statistic as every other bench figure.
fn median_ns(samples: &[u64]) -> u64 {
    median(&samples.iter().map(|&x| x as f64).collect::<Vec<_>>()) as u64
}

fn main() {
    let out_path = std::env::args().nth(1).unwrap_or_else(|| "BENCH_PR1.json".into());
    let scale = (BASE_SCALE + bench_scale_offset()).max(6) as u32;
    let seed = bench_seed();
    let repeats: usize = std::env::var("GVE_BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
        .max(1);

    let mut cells: Vec<Cell> = Vec::new();
    for family in GraphFamily::ALL {
        let g = generate(family, scale, seed);
        for threads in THREADS {
            // One algorithm object per cell: the persistent team and
            // the pass workspace are reused across warmup + repeats,
            // exactly like a long-lived service would run it.
            let algo = GveLouvain::new(LouvainParams::with_threads(threads));
            let _ = algo.run(&g); // warmup (also builds the workspace)
            let mut samples = Vec::with_capacity(repeats);
            let mut quality = 0.0;
            let mut passes = 0;
            for _ in 0..repeats {
                let t0 = Instant::now();
                let out = algo.run(&g);
                samples.push(t0.elapsed().as_nanos() as u64);
                quality = out.modularity;
                passes = out.passes;
            }
            let med = median_ns(&samples);
            let cell = Cell {
                family: family.name(),
                threads,
                vertices: g.num_vertices(),
                edges: g.num_edges(),
                median_ns: med,
                edges_per_sec: edges_per_sec(g.num_edges(), med),
                modularity: quality,
                passes,
                spawned_workers: algo.spawned_workers(),
            };
            eprintln!(
                "{:>8} t={} |V|={:>7} |E|={:>8} {:>12} ns  {:>10.0} e/s  Q={:.4}  spawns={}",
                cell.family,
                cell.threads,
                cell.vertices,
                cell.edges,
                cell.median_ns,
                cell.edges_per_sec,
                cell.modularity,
                cell.spawned_workers,
            );
            cells.push(cell);
        }
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"bench_pr1_smoke\",");
    let _ = writeln!(json, "  \"unit\": \"directed edge slots per second, median of {repeats}\",");
    let _ = writeln!(json, "  \"scale\": {scale},");
    let _ = writeln!(json, "  \"seed\": {seed},");
    let _ = writeln!(json, "  \"results\": [");
    for (i, c) in cells.iter().enumerate() {
        let comma = if i + 1 < cells.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"family\": \"{}\", \"threads\": {}, \"vertices\": {}, \"edges\": {}, \
             \"median_ns\": {}, \"edges_per_sec\": {:.1}, \"modularity\": {:.6}, \
             \"passes\": {}, \"spawned_workers\": {}}}{}",
            c.family,
            c.threads,
            c.vertices,
            c.edges,
            c.median_ns,
            c.edges_per_sec,
            c.modularity,
            c.passes,
            c.spawned_workers,
            comma
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");

    std::fs::write(&out_path, &json).unwrap_or_else(|e| {
        eprintln!("error: cannot write {out_path}: {e}");
        std::process::exit(1);
    });
    println!("{json}");
    eprintln!("wrote {out_path}");
}
