//! `bench_smoke` — the perf-trajectory smoke runner (PR 1 static
//! cells, PR 2 dynamic cells, PR 3 service cells, PR 6 scan-engine
//! cells, PR 7 trace cells, PR 8 metrics cells + regression gate,
//! PR 9 server cells, PR 10 late-pass cells).
//!
//! Runs GVE-Louvain over every planted [`GraphFamily`] at 1 and 4
//! threads (warmup + repeats, median), replays a 10-batch / 1%-churn
//! dynamic timeline per [`SeedStrategy`] (PR 2), replays the
//! same-shaped stream through the long-lived `CommunityService` per
//! strategy (PR 3), runs the `"scan_engine"` scenario (PR 6): the Web
//! family with the hybrid SmallTable fast path on/off crossed with
//! dynamic vs degree-bucketed scheduling, reporting table ops, edges
//! scanned and the small-path fraction — and, since PR 7, the
//! `"trace"` scenario: the same web graph at the top thread count with
//! tracing off vs on, reporting the measured span-capture overhead %
//! and the mean per-pass parallelism efficiency derived from the
//! per-worker busy spans.  Since PR 8 there is also a `"metrics"`
//! scenario — the live registry's zero-cost contract, measured: the
//! same web run with the metrics registry enabled (the default) vs
//! disabled, reported as an overhead % that should sit inside noise
//! (< 1%).  Since PR 9 there is a `"server"` scenario — the network
//! serving subsystem, measured end to end: the dynamic timeline
//! streamed through a live loopback `LouvainServer` as binary Ops
//! frames (wire path: framing, the bounded op queue, the single-writer
//! ingest thread, acks) vs the same batches through
//! `coordinator::service::replay_service` in process (direct path),
//! reported as ops/sec per path plus the wire overhead %.  Since PR 10
//! there is a `"late_pass"` scenario — the adaptive late-pass engine:
//! the web family with `adaptive_width` off vs on crossed with the
//! thread counts, reporting the per-pass effective widths the cost
//! model chose plus the number of team dispatches issued inside pass
//! windows (from a traced run), so the serial fast path's engagement
//! on sub-threshold passes is visible in the JSON.  Output is a
//! `BENCH_PR10.json` — the fixed yardstick future PRs compare against.
//! Hand-rolled JSON writer; the reader for the gate below is
//! `bench::json` (the offline registry has no serde).
//!
//! Usage (see also `scripts/bench_smoke.sh` and the `bench-smoke`
//! cargo alias):
//!
//! ```text
//! bench_smoke [OUT.json]          # default BENCH_PR10.json
//! GVE_BENCH_SCALE=-3 bench_smoke  # shift graph scales (quick CI)
//! GVE_BENCH_REPEATS=5 bench_smoke
//! bench_smoke --trace slowest.json        # Chrome trace of the
//!                                         # slowest static cell
//! bench_smoke --baseline BENCH_PR10.json  # regression gate
//! bench_smoke --baseline BENCH_PR10.json --noise-pct 15
//! ```
//!
//! `--baseline FILE` (PR 8) turns the run into a gate: after writing
//! OUT.json it parses FILE, matches throughput cells by identity
//! (family/strategy/schedule × threads), and **exits non-zero** if any
//! current rate sits more than `--noise-pct` (default 25%) below its
//! baseline.  Rates, not wall times, so bigger is always better; the
//! default tolerance is wide because smoke scales are noisy — tighten
//! it on quiet machines.  To produce a baseline, run the same binary
//! on the baseline commit:
//!
//! ```text
//! git stash && cargo bench-smoke BENCH_PR10_baseline.json && git stash pop
//! cargo bench-smoke BENCH_PR10.json --baseline BENCH_PR10_baseline.json
//! ```

use gve_louvain::bench::json::Json;
use gve_louvain::bench::{bench_scale_offset, bench_seed};
use gve_louvain::coordinator::cli::Opts;
use gve_louvain::coordinator::dynamic::{churn_timeline, replay_timeline, summarize};
use gve_louvain::coordinator::metrics::{edges_per_sec, median};
use gve_louvain::coordinator::service::{replay_service, summarize_service};
use gve_louvain::graph::delta::StreamOp;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::dynamic::SeedStrategy;
use gve_louvain::louvain::{gve::GveLouvain, params::LouvainParams};
use gve_louvain::parallel::Schedule;
use gve_louvain::server::{Client, LouvainServer, ServerConfig};
use gve_louvain::service::{BatchPolicy, CommunityService, ServiceConfig};
use gve_louvain::obs;
use gve_louvain::trace::{chrome, report, TraceSession};
use std::fmt::Write as _;
use std::time::Instant;

/// Base scale before `GVE_BENCH_SCALE` shifting (2^13 vertices).
const BASE_SCALE: i32 = 13;
const THREADS: [usize; 2] = [1, 4];
/// Dynamic scenario shape (PR 2): batches per timeline, churn fraction.
const DYN_BATCHES: usize = 10;
const DYN_FRAC: f64 = 0.01;

struct Cell {
    family: &'static str,
    threads: usize,
    vertices: usize,
    edges: usize,
    median_ns: u64,
    edges_per_sec: f64,
    modularity: f64,
    passes: usize,
    spawned_workers: usize,
}

struct DynCell {
    strategy: &'static str,
    threads: usize,
    batches: usize,
    median_batch_ns: u64,
    edges_per_sec: f64,
    final_modularity: f64,
    mean_affected: f64,
}

struct ServiceCell {
    strategy: &'static str,
    threads: usize,
    epochs: usize,
    total_ops: usize,
    median_epoch_ns: u64,
    max_epoch_ns: u64,
    ops_per_sec: f64,
    final_modularity: f64,
    drift: f64,
}

/// PR 6 scan-engine cell: hybrid fast path on/off × schedule.
struct ScanCell {
    hybrid: bool,
    schedule: &'static str,
    threads: usize,
    median_ns: u64,
    edges_per_sec: f64,
    modularity: f64,
    table_ops: u64,
    edges_scanned: u64,
    small_path_scans: u64,
    large_path_scans: u64,
    /// Fraction of scanned rows the SmallTable completed.
    small_fraction: f64,
}

/// PR 7 trace cell: measured span-capture overhead + derived
/// utilization on the web family at the top thread count.
struct TraceCell {
    threads: usize,
    median_off_ns: u64,
    median_on_ns: u64,
    /// `(on / off - 1) × 100` — the overhead contract, measured.
    overhead_pct: f64,
    events: usize,
    passes: usize,
    /// Mean per-pass Σ worker-busy / (wall × threads).
    mean_efficiency: f64,
}

/// PR 9 server cell: the wire's cost, measured.  The same pre-cut
/// churn timeline pushed through a live loopback `LouvainServer`
/// (framing + bounded queue + single-writer ingest thread + acks) vs
/// `replay_service`'s in-process `ingest_batch` loop; `overhead_pct`
/// is the wall-time cost of the network path for identical work.
struct ServerCell {
    path: &'static str,
    threads: usize,
    epochs: u64,
    total_ops: usize,
    wall_ns: u64,
    ops_per_sec: f64,
    final_modularity: f64,
}

/// PR 10 late-pass cell: the adaptive engine's width decisions and
/// dispatch savings, measured.  `pass_widths` is the effective width
/// the cost model chose for each pass (all equal to `threads` when
/// `adaptive` is off); `team_jobs_in_passes` counts `team.job` spans
/// starting inside `pass` windows in a traced run — the dispatch
/// overhead the serial fast path removes on sub-threshold passes.
struct LatePassCell {
    adaptive: bool,
    threads: usize,
    median_ns: u64,
    edges_per_sec: f64,
    modularity: f64,
    passes: usize,
    pass_widths: Vec<usize>,
    team_jobs_in_passes: usize,
}

/// PR 8 metrics cell: the live registry's overhead contract, measured.
/// Same shape as the trace cell — web family, top thread count —
/// with the process-wide metrics registry enabled (the default) vs
/// disabled via `obs::set_enabled`.
struct MetricsCell {
    threads: usize,
    median_on_ns: u64,
    median_off_ns: u64,
    /// `(on / off - 1) × 100` — the < 1% contract, measured.
    overhead_pct: f64,
}

/// Median via the crate-wide convention (`coordinator::metrics`), so
/// `BENCH_PR3.json` uses the same statistic as every other bench figure.
fn median_ns(samples: &[u64]) -> u64 {
    median(&samples.iter().map(|&x| x as f64).collect::<Vec<_>>()) as u64
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = Opts::parse(&args);
    let out_path = opts
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "BENCH_PR10.json".into());
    let scale = (BASE_SCALE + bench_scale_offset()).max(6) as u32;
    let seed = bench_seed();
    let repeats: usize = std::env::var("GVE_BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
        .max(1);

    let mut cells: Vec<Cell> = Vec::new();
    for family in GraphFamily::ALL {
        let g = generate(family, scale, seed);
        for threads in THREADS {
            // One algorithm object per cell: the persistent team and
            // the pass workspace are reused across warmup + repeats,
            // exactly like a long-lived service would run it.
            let algo = GveLouvain::new(LouvainParams::with_threads(threads));
            let _ = algo.run(&g); // warmup (also builds the workspace)
            let mut samples = Vec::with_capacity(repeats);
            let mut quality = 0.0;
            let mut passes = 0;
            for _ in 0..repeats {
                let t0 = Instant::now();
                let out = algo.run(&g);
                samples.push(t0.elapsed().as_nanos() as u64);
                quality = out.modularity;
                passes = out.passes;
            }
            let med = median_ns(&samples);
            let cell = Cell {
                family: family.name(),
                threads,
                vertices: g.num_vertices(),
                edges: g.num_edges(),
                median_ns: med,
                edges_per_sec: edges_per_sec(g.num_edges(), med),
                modularity: quality,
                passes,
                spawned_workers: algo.spawned_workers(),
            };
            eprintln!(
                "{:>8} t={} |V|={:>7} |E|={:>8} {:>12} ns  {:>10.0} e/s  Q={:.4}  spawns={}",
                cell.family,
                cell.threads,
                cell.vertices,
                cell.edges,
                cell.median_ns,
                cell.edges_per_sec,
                cell.modularity,
                cell.spawned_workers,
            );
            cells.push(cell);
        }
    }

    // --- Dynamic scenario (PR 2): one web-family churn timeline per
    // thread count, replayed per seeding strategy.  edges/sec is the
    // sustained per-batch throughput (final |E| over the median batch
    // wall time).
    let mut dyn_cells: Vec<DynCell> = Vec::new();
    {
        let g0 = generate(GraphFamily::Web, scale, seed);
        let tl = churn_timeline(&g0, DYN_BATCHES, DYN_FRAC, seed);
        let final_edges = tl.graphs.last().map(|g| g.num_edges()).unwrap_or(0);
        for threads in THREADS {
            let params = LouvainParams::with_threads(threads);
            let cells = replay_timeline(&g0, &tl, &SeedStrategy::ALL, &params);
            for s in summarize(&cells) {
                let cell = DynCell {
                    strategy: s.strategy.name(),
                    threads,
                    batches: s.batches,
                    median_batch_ns: s.median_wall_ns,
                    edges_per_sec: edges_per_sec(final_edges, s.median_wall_ns),
                    final_modularity: s.final_modularity,
                    mean_affected: s.mean_affected,
                };
                eprintln!(
                    "dyn {:>15} t={} {:>12} ns/batch  {:>10.0} e/s  Q={:.4}  affected~{:.0}",
                    cell.strategy,
                    cell.threads,
                    cell.median_batch_ns,
                    cell.edges_per_sec,
                    cell.final_modularity,
                    cell.mean_affected,
                );
                dyn_cells.push(cell);
            }
        }
    }

    // --- Service scenario (PR 3): the dynamic timeline ingested
    // through the long-lived CommunityService — ingest rate and
    // epoch-latency cells per strategy (batches pre-cut, so the replay
    // is deterministic in the timeline).
    let mut svc_cells: Vec<ServiceCell> = Vec::new();
    {
        let g0 = generate(GraphFamily::Web, scale, seed);
        let tl = churn_timeline(&g0, DYN_BATCHES, DYN_FRAC, seed);
        for threads in THREADS {
            for strategy in SeedStrategy::ALL {
                let cfg = ServiceConfig {
                    params: LouvainParams::with_threads(threads),
                    strategy,
                    policy: BatchPolicy::default(),
                    ..Default::default()
                };
                let (svc, cells) = replay_service(&g0, &tl, cfg);
                let s = summarize_service(&cells, svc.metrics().initial_modularity);
                let cell = ServiceCell {
                    strategy: strategy.name(),
                    threads,
                    epochs: s.epochs,
                    total_ops: s.total_ops,
                    median_epoch_ns: s.median_epoch_ns,
                    max_epoch_ns: s.max_epoch_ns,
                    ops_per_sec: s.ops_per_sec,
                    final_modularity: s.final_modularity,
                    drift: s.drift,
                };
                eprintln!(
                    "svc {:>15} t={} {:>12} ns/epoch  {:>9.0} ops/s  Q={:.4} drift={:+.4}",
                    cell.strategy,
                    cell.threads,
                    cell.median_epoch_ns,
                    cell.ops_per_sec,
                    cell.final_modularity,
                    cell.drift,
                );
                svc_cells.push(cell);
            }
        }
    }

    // --- Scan-engine scenario (PR 6): the Web family (heavy-tailed —
    // the degree-aware machinery's home turf) with the hybrid
    // SmallTable fast path on/off crossed with dynamic vs
    // degree-bucketed scheduling.  The work counters (table ops, edges
    // scanned, small/large path split) come from the run itself, so a
    // regression in either the fast-path coverage or the total work is
    // visible in the JSON diff even when wall time is noisy.
    let mut scan_cells: Vec<ScanCell> = Vec::new();
    {
        let g = generate(GraphFamily::Web, scale, seed);
        let default_small = LouvainParams::default().small_degree;
        for threads in THREADS {
            for hybrid in [false, true] {
                for schedule in [Schedule::Dynamic, Schedule::DegreeBucketed] {
                    let params = LouvainParams {
                        threads,
                        schedule,
                        small_degree: if hybrid { default_small } else { 0 },
                        ..LouvainParams::default()
                    };
                    let algo = GveLouvain::new(params);
                    let _ = algo.run(&g); // warmup
                    let mut samples = Vec::with_capacity(repeats);
                    let mut last = None;
                    for _ in 0..repeats {
                        let t0 = Instant::now();
                        let out = algo.run(&g);
                        samples.push(t0.elapsed().as_nanos() as u64);
                        last = Some(out);
                    }
                    let out = last.expect("repeats >= 1");
                    let med = median_ns(&samples);
                    let c = &out.counters;
                    let rows = c.small_path_scans + c.large_path_scans;
                    let cell = ScanCell {
                        hybrid,
                        schedule: schedule.name(),
                        threads,
                        median_ns: med,
                        edges_per_sec: edges_per_sec(g.num_edges(), med),
                        modularity: out.modularity,
                        table_ops: c.table_ops,
                        edges_scanned: c.edges_scanned_move + c.edges_scanned_agg,
                        small_path_scans: c.small_path_scans,
                        large_path_scans: c.large_path_scans,
                        small_fraction: c.small_path_scans as f64 / rows.max(1) as f64,
                    };
                    eprintln!(
                        "scan hybrid={:<5} {:>15} t={} {:>12} ns  {:>10.0} e/s  Q={:.4}  small={:.1}%",
                        cell.hybrid,
                        cell.schedule,
                        cell.threads,
                        cell.median_ns,
                        cell.edges_per_sec,
                        cell.modularity,
                        cell.small_fraction * 100.0,
                    );
                    scan_cells.push(cell);
                }
            }
        }
    }

    // --- Trace scenario (PR 7): the observability overhead contract,
    // measured.  The web family at the top thread count: median wall
    // with tracing disabled (the always-compiled relaxed-load branch)
    // vs enabled (span capture into the per-worker rings), plus the
    // mean per-pass parallelism efficiency derived from the last
    // captured trace — the number the paper argues CPU Louvain wins on.
    let trace_cell: TraceCell;
    {
        let g = generate(GraphFamily::Web, scale, seed);
        let threads = *THREADS.last().expect("THREADS is non-empty");
        let algo = GveLouvain::new(LouvainParams::with_threads(threads));
        let _ = algo.run(&g); // warmup
        let mut off = Vec::with_capacity(repeats);
        for _ in 0..repeats {
            let t0 = Instant::now();
            let _ = algo.run(&g);
            off.push(t0.elapsed().as_nanos() as u64);
        }
        let mut on = Vec::with_capacity(repeats);
        let mut last = None;
        for _ in 0..repeats {
            let session = TraceSession::start();
            let t0 = Instant::now();
            let out = algo.run(&g);
            on.push(t0.elapsed().as_nanos() as u64);
            last = Some((out, session.finish()));
        }
        let (out, trace) = last.expect("repeats >= 1");
        let util = report::derive_pass_utilization(&trace, threads);
        let median_off_ns = median_ns(&off);
        let median_on_ns = median_ns(&on);
        trace_cell = TraceCell {
            threads,
            median_off_ns,
            median_on_ns,
            overhead_pct: (median_on_ns as f64 / median_off_ns.max(1) as f64 - 1.0) * 100.0,
            events: trace.events.len(),
            passes: out.passes,
            mean_efficiency: report::mean_efficiency(&util),
        };
        eprintln!(
            "trace t={} off {:>12} ns  on {:>12} ns  overhead {:+.2}%  {} events  eff~{:.2}",
            trace_cell.threads,
            trace_cell.median_off_ns,
            trace_cell.median_on_ns,
            trace_cell.overhead_pct,
            trace_cell.events,
            trace_cell.mean_efficiency,
        );
    }

    // --- Metrics scenario (PR 8): the live registry's zero-cost
    // contract, measured.  Same shape as the trace cell: the web
    // family at the top thread count with the registry enabled (the
    // default — one relaxed load + sharded relaxed adds per site) vs
    // disabled (the relaxed-load branch alone).  Unlike tracing, the
    // registry is on in production, so this overhead is the one users
    // always pay — the acceptance bar is < 1%, inside run-to-run noise.
    let metrics_cell: MetricsCell;
    {
        let g = generate(GraphFamily::Web, scale, seed);
        let threads = *THREADS.last().expect("THREADS is non-empty");
        let algo = GveLouvain::new(LouvainParams::with_threads(threads));
        let _ = algo.run(&g); // warmup
        let mut on = Vec::with_capacity(repeats);
        for _ in 0..repeats {
            let t0 = Instant::now();
            let _ = algo.run(&g);
            on.push(t0.elapsed().as_nanos() as u64);
        }
        obs::set_enabled(false);
        let mut off = Vec::with_capacity(repeats);
        for _ in 0..repeats {
            let t0 = Instant::now();
            let _ = algo.run(&g);
            off.push(t0.elapsed().as_nanos() as u64);
        }
        obs::set_enabled(true);
        let median_on_ns = median_ns(&on);
        let median_off_ns = median_ns(&off);
        metrics_cell = MetricsCell {
            threads,
            median_on_ns,
            median_off_ns,
            overhead_pct: (median_on_ns as f64 / median_off_ns.max(1) as f64 - 1.0) * 100.0,
        };
        eprintln!(
            "metrics t={} off {:>12} ns  on {:>12} ns  overhead {:+.2}%",
            metrics_cell.threads,
            metrics_cell.median_off_ns,
            metrics_cell.median_on_ns,
            metrics_cell.overhead_pct,
        );
    }

    // --- Server scenario (PR 9): the wire's cost, measured.  The same
    // pre-cut churn timeline twice — once through a live loopback
    // `LouvainServer` (binary Ops frames, explicit Commit per batch so
    // the daemon cuts exactly the timeline's epochs) and once through
    // the in-process `ingest_batch` loop `replay_service` uses.  Both
    // timers cover ingest through the final published epoch (the
    // client's `finish()` drains the server's final ack), and both
    // exclude the boot detection, which every config pays identically.
    let mut server_cells: Vec<ServerCell> = Vec::new();
    {
        let g0 = generate(GraphFamily::Web, scale, seed);
        let tl = churn_timeline(&g0, DYN_BATCHES, DYN_FRAC, seed);
        let total_ops: usize = tl.batches.iter().map(|b| b.len()).sum();
        let frames: Vec<Vec<StreamOp>> = tl
            .batches
            .iter()
            .map(|b| b.to_ops().chain(std::iter::once(StreamOp::Commit)).collect())
            .collect();
        for threads in THREADS {
            let cfg = ServiceConfig {
                params: LouvainParams::with_threads(threads),
                strategy: SeedStrategy::DeltaScreening,
                // Only the explicit Commits cut epochs on the wire.
                policy: BatchPolicy::by_ops(usize::MAX / 2),
                ..Default::default()
            };

            // Direct path: boot outside the timer, then ingest_batch.
            let mut svc = CommunityService::new(g0.clone(), cfg.clone());
            let t0 = Instant::now();
            let epochs: Vec<_> = tl.batches.iter().map(|b| svc.ingest_batch(b)).collect();
            let direct_wall_ns = (t0.elapsed().as_nanos() as u64).max(1);
            let direct = ServerCell {
                path: "direct",
                threads,
                epochs: epochs.len() as u64,
                total_ops,
                wall_ns: direct_wall_ns,
                ops_per_sec: total_ops as f64 * 1e9 / direct_wall_ns as f64,
                final_modularity: epochs.last().map(|e| e.modularity).unwrap_or(0.0),
            };

            // Wire path: live loopback server, boot outside the timer.
            let server = LouvainServer::start(
                g0.clone(),
                ServerConfig { service: cfg, ..Default::default() },
            )
            .expect("bind loopback server");
            let mut client = Client::connect(server.local_addr()).expect("connect ingest client");
            let t0 = Instant::now();
            for ops in &frames {
                client.send_ops(ops).expect("stream ops frame");
            }
            let rep = client.finish().expect("drain final ack");
            let wire_wall_ns = (t0.elapsed().as_nanos() as u64).max(1);
            let handle = server.handle();
            let report = server.shutdown();
            assert_eq!(rep.accepted as usize, total_ops, "wire replay lost ops");
            let wire = ServerCell {
                path: "wire",
                threads,
                epochs: report.epochs_published,
                total_ops,
                wall_ns: wire_wall_ns,
                ops_per_sec: total_ops as f64 * 1e9 / wire_wall_ns as f64,
                final_modularity: handle.load().modularity,
            };
            eprintln!(
                "server t={} direct {:>12} ns  wire {:>12} ns  overhead {:+.1}%  \
                 {:>9.0} vs {:>9.0} ops/s  Q={:.4}",
                threads,
                direct.wall_ns,
                wire.wall_ns,
                (wire.wall_ns as f64 / direct.wall_ns as f64 - 1.0) * 100.0,
                wire.ops_per_sec,
                direct.ops_per_sec,
                wire.final_modularity,
            );
            server_cells.push(direct);
            server_cells.push(wire);
        }
    }

    // --- Late-pass scenario (PR 10): the adaptive engine, measured.
    // The web family with `adaptive_width` off vs on crossed with the
    // thread counts.  Besides the usual median/throughput pair, each
    // cell records the per-pass effective widths the cost model chose
    // and — from one traced repeat — how many team jobs were dispatched
    // inside pass windows, so the serial fast path's zero-dispatch
    // contract on sub-threshold passes shows up as a hard number (the
    // off-cell minus the on-cell is the dispatch-overhead delta).
    let mut late_cells: Vec<LatePassCell> = Vec::new();
    {
        let g = generate(GraphFamily::Web, scale, seed);
        for threads in THREADS {
            for adaptive in [false, true] {
                let params = LouvainParams {
                    threads,
                    adaptive_width: adaptive,
                    ..LouvainParams::default()
                };
                let algo = GveLouvain::new(params);
                let _ = algo.run(&g); // warmup
                let mut samples = Vec::with_capacity(repeats);
                for _ in 0..repeats {
                    let t0 = Instant::now();
                    let _ = algo.run(&g);
                    samples.push(t0.elapsed().as_nanos() as u64);
                }
                // One traced repeat for the width trace + dispatch count.
                let session = TraceSession::start();
                let out = algo.run(&g);
                let trace = session.finish();
                let windows: Vec<(u64, u64)> = trace
                    .spans("pass")
                    .map(|p| (p.start_ns, p.start_ns + p.dur_ns))
                    .collect();
                let team_jobs_in_passes = trace
                    .spans("team.job")
                    .filter(|j| windows.iter().any(|&(lo, hi)| j.start_ns >= lo && j.start_ns < hi))
                    .count();
                let med = median_ns(&samples);
                let cell = LatePassCell {
                    adaptive,
                    threads,
                    median_ns: med,
                    edges_per_sec: edges_per_sec(g.num_edges(), med),
                    modularity: out.modularity,
                    passes: out.passes,
                    pass_widths: out.pass_stats.iter().map(|ps| ps.effective_threads).collect(),
                    team_jobs_in_passes,
                };
                eprintln!(
                    "late adaptive={:<5} t={} {:>12} ns  {:>10.0} e/s  Q={:.4}  w={:?}  jobs-in-pass={}",
                    cell.adaptive,
                    cell.threads,
                    cell.median_ns,
                    cell.edges_per_sec,
                    cell.modularity,
                    cell.pass_widths,
                    cell.team_jobs_in_passes,
                );
                late_cells.push(cell);
            }
        }
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"bench_pr10_smoke\",");
    let _ = writeln!(json, "  \"unit\": \"directed edge slots per second, median of {repeats}\",");
    let _ = writeln!(json, "  \"scale\": {scale},");
    let _ = writeln!(json, "  \"seed\": {seed},");
    let _ = writeln!(json, "  \"results\": [");
    for (i, c) in cells.iter().enumerate() {
        let comma = if i + 1 < cells.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"family\": \"{}\", \"threads\": {}, \"vertices\": {}, \"edges\": {}, \
             \"median_ns\": {}, \"edges_per_sec\": {:.1}, \"modularity\": {:.6}, \
             \"passes\": {}, \"spawned_workers\": {}}}{}",
            c.family,
            c.threads,
            c.vertices,
            c.edges,
            c.median_ns,
            c.edges_per_sec,
            c.modularity,
            c.passes,
            c.spawned_workers,
            comma
        );
    }
    let _ = writeln!(json, "  ],");
    let _ = writeln!(
        json,
        "  \"dynamic\": {{\"family\": \"web\", \"batches\": {DYN_BATCHES}, \"frac\": {DYN_FRAC}, \"results\": ["
    );
    for (i, c) in dyn_cells.iter().enumerate() {
        let comma = if i + 1 < dyn_cells.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"strategy\": \"{}\", \"threads\": {}, \"batches\": {}, \
             \"median_batch_ns\": {}, \"edges_per_sec\": {:.1}, \
             \"final_modularity\": {:.6}, \"mean_affected\": {:.1}}}{}",
            c.strategy,
            c.threads,
            c.batches,
            c.median_batch_ns,
            c.edges_per_sec,
            c.final_modularity,
            c.mean_affected,
            comma
        );
    }
    let _ = writeln!(json, "  ]}},");
    let _ = writeln!(
        json,
        "  \"service\": {{\"family\": \"web\", \"batches\": {DYN_BATCHES}, \"frac\": {DYN_FRAC}, \"results\": ["
    );
    for (i, c) in svc_cells.iter().enumerate() {
        let comma = if i + 1 < svc_cells.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"strategy\": \"{}\", \"threads\": {}, \"epochs\": {}, \
             \"total_ops\": {}, \"median_epoch_ns\": {}, \"max_epoch_ns\": {}, \
             \"ops_per_sec\": {:.1}, \"final_modularity\": {:.6}, \"drift\": {:.6}}}{}",
            c.strategy,
            c.threads,
            c.epochs,
            c.total_ops,
            c.median_epoch_ns,
            c.max_epoch_ns,
            c.ops_per_sec,
            c.final_modularity,
            c.drift,
            comma
        );
    }
    let _ = writeln!(json, "  ]}},");
    let _ = writeln!(json, "  \"scan_engine\": {{\"family\": \"web\", \"results\": [");
    for (i, c) in scan_cells.iter().enumerate() {
        let comma = if i + 1 < scan_cells.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"hybrid\": {}, \"schedule\": \"{}\", \"threads\": {}, \
             \"median_ns\": {}, \"edges_per_sec\": {:.1}, \"modularity\": {:.6}, \
             \"table_ops\": {}, \"edges_scanned\": {}, \"small_path_scans\": {}, \
             \"large_path_scans\": {}, \"small_fraction\": {:.4}}}{}",
            c.hybrid,
            c.schedule,
            c.threads,
            c.median_ns,
            c.edges_per_sec,
            c.modularity,
            c.table_ops,
            c.edges_scanned,
            c.small_path_scans,
            c.large_path_scans,
            c.small_fraction,
            comma
        );
    }
    let _ = writeln!(json, "  ]}},");
    let _ = writeln!(
        json,
        "  \"trace\": {{\"family\": \"web\", \"threads\": {}, \"median_off_ns\": {}, \
         \"median_on_ns\": {}, \"overhead_pct\": {:.2}, \"events\": {}, \"passes\": {}, \
         \"mean_efficiency\": {:.4}}},",
        trace_cell.threads,
        trace_cell.median_off_ns,
        trace_cell.median_on_ns,
        trace_cell.overhead_pct,
        trace_cell.events,
        trace_cell.passes,
        trace_cell.mean_efficiency,
    );
    let _ = writeln!(
        json,
        "  \"metrics\": {{\"family\": \"web\", \"threads\": {}, \"median_off_ns\": {}, \
         \"median_on_ns\": {}, \"overhead_pct\": {:.2}}},",
        metrics_cell.threads,
        metrics_cell.median_off_ns,
        metrics_cell.median_on_ns,
        metrics_cell.overhead_pct,
    );
    let _ = writeln!(
        json,
        "  \"server\": {{\"family\": \"web\", \"batches\": {DYN_BATCHES}, \"frac\": {DYN_FRAC}, \"results\": ["
    );
    for (i, c) in server_cells.iter().enumerate() {
        let comma = if i + 1 < server_cells.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"path\": \"{}\", \"threads\": {}, \"epochs\": {}, \"total_ops\": {}, \
             \"wall_ns\": {}, \"ops_per_sec\": {:.1}, \"final_modularity\": {:.6}}}{}",
            c.path,
            c.threads,
            c.epochs,
            c.total_ops,
            c.wall_ns,
            c.ops_per_sec,
            c.final_modularity,
            comma
        );
    }
    let _ = writeln!(json, "  ]}},");
    let _ = writeln!(json, "  \"late_pass\": {{\"family\": \"web\", \"results\": [");
    for (i, c) in late_cells.iter().enumerate() {
        let comma = if i + 1 < late_cells.len() { "," } else { "" };
        let widths = c
            .pass_widths
            .iter()
            .map(|w| w.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        let _ = writeln!(
            json,
            "    {{\"adaptive\": {}, \"threads\": {}, \"median_ns\": {}, \
             \"edges_per_sec\": {:.1}, \"modularity\": {:.6}, \"passes\": {}, \
             \"pass_widths\": [{}], \"team_jobs_in_passes\": {}}}{}",
            c.adaptive,
            c.threads,
            c.median_ns,
            c.edges_per_sec,
            c.modularity,
            c.passes,
            widths,
            c.team_jobs_in_passes,
            comma
        );
    }
    let _ = writeln!(json, "  ]}}");
    let _ = writeln!(json, "}}");

    std::fs::write(&out_path, &json).unwrap_or_else(|e| {
        eprintln!("error: cannot write {out_path}: {e}");
        std::process::exit(1);
    });
    println!("{json}");
    eprintln!("wrote {out_path}");

    // --- `--trace PATH` (PR 8, satellite): dump a Chrome trace of the
    // *slowest* static cell — the one whose profile is worth staring
    // at — so a bench regression comes with its own timeline attached.
    if let Some(trace_path) = opts.flags.get("trace") {
        let slowest = cells
            .iter()
            .max_by_key(|c| c.median_ns)
            .expect("static scenario produced at least one cell");
        let family = GraphFamily::parse(slowest.family).expect("cell family round-trips");
        let g = generate(family, scale, seed);
        let algo = GveLouvain::new(LouvainParams::with_threads(slowest.threads));
        let _ = algo.run(&g); // warmup
        let session = TraceSession::start();
        let _ = algo.run(&g);
        let trace = session.finish();
        if let Err(e) = chrome::write(&trace, trace_path) {
            eprintln!("error: cannot write {trace_path}: {e}");
            std::process::exit(1);
        }
        eprintln!(
            "trace: slowest static cell ({} t={}, {} ns median) -> {trace_path} \
             ({} events, {} dropped; open in https://ui.perfetto.dev)",
            slowest.family,
            slowest.threads,
            slowest.median_ns,
            trace.events.len(),
            trace.dropped,
        );
    }

    // --- `--baseline FILE` (PR 8): the regression gate.  Parse the
    // JSON we just wrote plus the committed yardstick, match
    // throughput cells by identity, and fail the run if any rate fell
    // more than the noise allowance below its baseline.
    if let Some(baseline_path) = opts.flags.get("baseline") {
        let noise_pct = opts.get_f("noise-pct", 25.0).max(0.0);
        let base_text = std::fs::read_to_string(baseline_path).unwrap_or_else(|e| {
            eprintln!("error: cannot read baseline {baseline_path}: {e}");
            std::process::exit(1);
        });
        let base = Json::parse(&base_text).unwrap_or_else(|e| {
            eprintln!("error: baseline {baseline_path} is not valid JSON: {e}");
            std::process::exit(1);
        });
        let cur = Json::parse(&json).expect("bench_smoke wrote invalid JSON");
        let regressions = gate_against_baseline(&cur, &base, noise_pct);
        if regressions > 0 {
            eprintln!(
                "regression gate: FAIL — {regressions} cell(s) more than {noise_pct:.0}% \
                 below baseline {baseline_path}"
            );
            std::process::exit(1);
        }
        eprintln!("regression gate: ok — all cells within {noise_pct:.0}% of baseline {baseline_path}");
    }
}

/// The comparable surface of a bench JSON: throughput cells keyed by
/// identity (section/family-or-strategy/threads).  Rates, not wall
/// times, so bigger is always better and the gate is one-sided.
fn collect_rates(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(cells) = doc.get("results").and_then(Json::as_arr) {
        for c in cells {
            if let (Some(f), Some(t), Some(r)) =
                (c.str("family"), c.num("threads"), c.num("edges_per_sec"))
            {
                out.push((format!("static/{f}/t{t}"), r));
            }
        }
    }
    for (section, metric) in [("dynamic", "edges_per_sec"), ("service", "ops_per_sec")] {
        let cells = doc.get(section).and_then(|s| s.get("results")).and_then(Json::as_arr);
        for c in cells.unwrap_or(&[]) {
            if let (Some(s), Some(t), Some(r)) =
                (c.str("strategy"), c.num("threads"), c.num(metric))
            {
                out.push((format!("{section}/{s}/t{t}"), r));
            }
        }
    }
    let server = doc.get("server").and_then(|s| s.get("results")).and_then(Json::as_arr);
    for c in server.unwrap_or(&[]) {
        if let (Some(p), Some(t), Some(r)) =
            (c.str("path"), c.num("threads"), c.num("ops_per_sec"))
        {
            out.push((format!("server/{p}/t{t}"), r));
        }
    }
    let scan = doc.get("scan_engine").and_then(|s| s.get("results")).and_then(Json::as_arr);
    for c in scan.unwrap_or(&[]) {
        if let (Some(h), Some(sch), Some(t), Some(r)) = (
            c.get("hybrid").and_then(Json::as_bool),
            c.str("schedule"),
            c.num("threads"),
            c.num("edges_per_sec"),
        ) {
            out.push((format!("scan/hybrid={h}/{sch}/t{t}"), r));
        }
    }
    let late = doc.get("late_pass").and_then(|s| s.get("results")).and_then(Json::as_arr);
    for c in late.unwrap_or(&[]) {
        if let (Some(a), Some(t), Some(r)) = (
            c.get("adaptive").and_then(Json::as_bool),
            c.num("threads"),
            c.num("edges_per_sec"),
        ) {
            out.push((format!("late_pass/adaptive={a}/t{t}"), r));
        }
    }
    out
}

/// Print the per-cell delta table (stderr, like all bench progress) and
/// count cells more than `noise_pct` *below* their baseline rate.
/// Cells present on only one side are reported but never gate — a PR
/// that adds a scenario must not need a time machine for its baseline.
fn gate_against_baseline(cur: &Json, base: &Json, noise_pct: f64) -> usize {
    let base_rates: std::collections::HashMap<String, f64> =
        collect_rates(base).into_iter().collect();
    let cur_rates = collect_rates(cur);
    let cur_keys: std::collections::HashSet<&str> =
        cur_rates.iter().map(|(k, _)| k.as_str()).collect();
    let mut regressions = 0;
    eprintln!("{:<44} {:>14} {:>14} {:>9}", "cell", "baseline", "current", "delta");
    for (key, cur_rate) in &cur_rates {
        match base_rates.get(key) {
            Some(&base_rate) => {
                let delta_pct = (cur_rate / base_rate.max(1e-9) - 1.0) * 100.0;
                let flag = if delta_pct < -noise_pct {
                    regressions += 1;
                    "  REGRESSION"
                } else {
                    ""
                };
                eprintln!(
                    "{key:<44} {base_rate:>14.0} {cur_rate:>14.0} {delta_pct:>+8.1}%{flag}"
                );
            }
            None => eprintln!("{key:<44} {:>14} {cur_rate:>14.0}       new", "-"),
        }
    }
    for key in base_rates.keys() {
        if !cur_keys.contains(key.as_str()) {
            eprintln!("{key:<44} baseline-only (not gated)");
        }
    }
    regressions
}
