//! `louvain_server` — the long-running network daemon (PR 9 tentpole).
//!
//! Boots a [`CommunityService`] on a graph and serves the wire
//! protocol: ingest connections stream `.ups` ops (add / delete /
//! commit) in binary frames and get cumulative acks back; subscriber
//! connections receive the epoch stream as compact membership deltas
//! (full snapshots on subscribe and on renumber-invalidating epochs).
//! A timer tick drives the service's max-latency flush bound, so
//! batches cut on time even when every stream goes quiet.
//!
//! ```text
//! louvain_server --family web --scale 12 --bind 9800 --http-bind 9184
//! louvain_server --input graph.bin --strategy delta --max-ops 2048 \
//!                --max-latency-ms 50 --threads 4
//! louvain_server --family web --duration 60     # exit after a minute
//! ```
//!
//! `--bind` / `--http-bind` take either a bare port (binds loopback —
//! the safe default for ports exposing process internals) or a full
//! `host:port` address.  `--http-bind` additionally starts the PR-8
//! introspection endpoint (`/metrics`, `/metrics.json`, `/healthz`,
//! `/epochs` with the last-32-epoch ring) backed by the same state the
//! ingest thread keeps fresh.  Wire-protocol spec:
//! `rust/src/server/README.md`.

use anyhow::{Context, Result};
use gve_louvain::coordinator::cli::{louvain_params_from, parse_bind, Opts};
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::io::load;
use gve_louvain::louvain::dynamic::SeedStrategy;
use gve_louvain::obs::http::IntrospectionServer;
use gve_louvain::server::{LouvainServer, ServerConfig};
use gve_louvain::service::{BatchPolicy, ServiceConfig};
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&Opts::parse(&args)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(opts: &Opts) -> Result<()> {
    let seed = opts.get_i("seed", 42) as u64;
    let strategy = SeedStrategy::parse(&opts.get("strategy", "delta"))
        .context("--strategy must be full | naive | delta")?;

    let (g0, g_name) = if let Some(path) = opts.flags.get("input") {
        (load(&PathBuf::from(path))?, path.clone())
    } else {
        let fam = opts.get("family", "web");
        let family = GraphFamily::parse(&fam).with_context(|| format!("unknown family {fam:?}"))?;
        let scale = opts.get_i("scale", 12) as u32;
        (generate(family, scale, seed), format!("{fam}-s{scale}"))
    };

    let max_ops = opts.get_i("max-ops", 4096).max(1) as usize;
    let policy = match opts.get_i("max-latency-ms", 0) {
        ms if ms > 0 => BatchPolicy { max_ops, max_latency: Duration::from_millis(ms as u64) },
        _ => BatchPolicy::by_ops(max_ops),
    };
    let cfg = ServerConfig {
        bind: parse_bind(&opts.get("bind", "0")).map_err(anyhow::Error::msg)?,
        service: ServiceConfig {
            params: louvain_params_from(opts),
            strategy,
            policy,
            ..Default::default()
        },
        queue_depth: opts.get_i("queue-depth", 256).max(1) as usize,
        outbox_depth: opts.get_i("outbox-depth", 64).max(2) as usize,
        tick: Duration::from_millis(opts.get_i("tick-ms", 5).max(1) as u64),
    };

    let server = LouvainServer::start(g0, cfg).context("starting louvain server")?;
    {
        let boot = server.handle().load();
        eprintln!(
            "serving {g_name} on {}: |V|={} |E|={} Q={:.4} |Γ|={} ({})",
            server.local_addr(),
            boot.vertices,
            boot.edges,
            boot.modularity,
            boot.num_communities(),
            strategy.name(),
        );
    }

    // Optional introspection endpoint, sharing the daemon's live state.
    let http = match opts.flags.get("http-bind") {
        Some(addr) => {
            let bind = parse_bind(addr).map_err(anyhow::Error::msg)?;
            let srv = IntrospectionServer::start_on(bind, server.serve_state())
                .with_context(|| format!("binding introspection server on {bind}"))?;
            eprintln!(
                "introspection: http://{}  (/metrics /metrics.json /healthz /epochs)",
                srv.local_addr()
            );
            Some(srv)
        }
        None => None,
    };

    let duration = opts.get_i("duration", 0).max(0) as u64;
    if duration > 0 {
        std::thread::sleep(Duration::from_secs(duration));
    } else {
        eprintln!("running until killed (pass --duration SECS to exit on a timer)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }

    drop(http);
    let report = server.shutdown();
    eprintln!(
        "drained: {} ops accepted, {} rejected, {} epochs published (final epoch {})",
        report.ops_accepted, report.ops_rejected, report.epochs_published, report.final_epoch,
    );
    Ok(())
}
