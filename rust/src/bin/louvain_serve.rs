//! `louvain_serve` — drive the long-lived community service over a
//! file-backed update stream (PR 3 tentpole surface).
//!
//! Boots a [`CommunityService`] on a graph, replays an update-stream
//! file (`graph::io` `.ups` format) through the coalescing ingest path,
//! and reports per-epoch latency, ingest throughput and quality drift.
//! Without `--stream` it generates a churn workload, *writes it to
//! disk* and replays it from there — the replay is file-backed either
//! way, and the written stream can be re-fed for deterministic
//! comparisons across strategies:
//!
//! ```text
//! louvain_serve --family web --scale 12 --batches 10 --frac 0.01 \
//!               --strategy delta --threads 4
//! louvain_serve --input graph.bin --stream updates.ups --max-ops 2048
//! louvain_serve --family web --write-stream /tmp/churn.ups   # keep it
//! louvain_serve --family web --trace serve.json   # Perfetto timeline
//! ```
//!
//! `--trace PATH` records the whole replay (epoch apply/detect/publish
//! spans, the per-pass Louvain spans inside each detection, per-worker
//! busy slices) into Chrome trace-event JSON — open it at
//! <https://ui.perfetto.dev>.
//!
//! `--http-bind ADDR` (PR 8, address knob PR 9) starts the live
//! introspection endpoint for the whole replay: `/metrics` (Prometheus
//! text), `/metrics.json`, `/healthz`, and `/epochs` (current epoch
//! snapshot + latency percentiles + drift + the last-32-epoch ring).
//! `ADDR` is either a bare port — binds loopback, 0 = OS-assigned,
//! printed at boot — or a full `host:port`; `--http-port N` stays as
//! an alias for `--http-bind N`.  The server runs on its own thread
//! and reads through the lock-free snapshot handle, so scraping never
//! blocks ingest.  Replays finish fast; `--linger SECS` keeps the
//! process (and the endpoint) alive after the final epoch so a scraper
//! can catch the end state:
//!
//! ```text
//! louvain_serve --family web --scale 12 --http-bind 9184 --linger 60 &
//! curl -s localhost:9184/epochs | python3 -m json.tool
//! curl -s localhost:9184/metrics | grep gve_service_
//! ```
//!
//! Arguments are hand-parsed (`--key value`); the offline registry has
//! no clap.

use anyhow::{Context, Result};
use gve_louvain::coordinator::cli::{louvain_params_from, parse_bind, Opts};
use gve_louvain::coordinator::dynamic::churn_timeline;
use gve_louvain::coordinator::metrics::{edges_per_sec, fmt_ns};
use gve_louvain::coordinator::report::Table;
use gve_louvain::graph::delta::StreamOp;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::graph::io::{load, write_update_stream, UpdateStreamReader};
use gve_louvain::louvain::dynamic::SeedStrategy;
use gve_louvain::obs::http::{IntrospectionServer, ServeState};
use gve_louvain::service::{
    BatchPolicy, CommunityService, EpochSnapshot, RecentEpoch, RecentEpochs, ServiceConfig,
};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&Opts::parse(&args)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(opts: &Opts) -> Result<()> {
    let seed = opts.get_i("seed", 42) as u64;
    let threads = opts.get_i("threads", 1) as usize;
    let strategy = SeedStrategy::parse(&opts.get("strategy", "delta"))
        .context("--strategy must be full | naive | delta")?;
    let max_ops = opts.get_i("max-ops", 4096).max(1) as usize;

    // --- Graph.
    let (g0, g_name) = if let Some(path) = opts.flags.get("input") {
        (load(&PathBuf::from(path))?, path.clone())
    } else {
        let fam = opts.get("family", "web");
        let family = GraphFamily::parse(&fam).with_context(|| format!("unknown family {fam:?}"))?;
        let scale = opts.get_i("scale", 12) as u32;
        (generate(family, scale, seed), format!("{fam}-s{scale}"))
    };

    // --- Stream: given file, or generate + write one.
    let stream_path = if let Some(p) = opts.flags.get("stream") {
        PathBuf::from(p)
    } else {
        let batches = opts.get_i("batches", 10).max(1) as usize;
        let frac = opts.get_f("frac", 0.01);
        let tl = churn_timeline(&g0, batches, frac, seed);
        let ops: Vec<StreamOp> = tl
            .batches
            .iter()
            .flat_map(|b| b.to_ops().chain(std::iter::once(StreamOp::Commit)))
            .collect();
        let path = opts
            .flags
            .get("write-stream")
            .map(PathBuf::from)
            .unwrap_or_else(|| std::env::temp_dir().join("louvain_serve_churn.ups"));
        write_update_stream(&ops, &path)?;
        eprintln!(
            "generated {} churn batches ({} ops) -> {}",
            batches,
            ops.iter().filter(|o| !matches!(o, StreamOp::Commit)).count(),
            path.display()
        );
        path
    };

    // --- Boot + replay.  The detection runs honour the full
    // scan-engine knob set (--schedule --table --small-degree ...).
    let cfg = ServiceConfig {
        params: louvain_params_from(opts),
        strategy,
        policy: BatchPolicy::by_ops(max_ops),
        ..Default::default()
    };
    let mut svc = CommunityService::new(g0, cfg);
    let boot = svc.snapshot();
    eprintln!(
        "booted on {g_name}: |V|={} |E|={} Q={:.4} |Γ|={} ({}, {} worker spawns)",
        boot.vertices,
        boot.edges,
        boot.modularity,
        boot.num_communities(),
        strategy.name(),
        threads.saturating_sub(1),
    );

    // Optional live introspection (PR 8): the HTTP thread reads the
    // lock-free snapshot handle plus a `Copy` summary struct this loop
    // overwrites after each publish — scrapes never block ingest.
    let summary = Arc::new(Mutex::new(svc.metrics().summary()));
    let recent = Arc::new(Mutex::new(RecentEpochs::default()));
    recent.lock().unwrap().push(RecentEpoch::of(&boot));
    let http_bind = opts
        .flags
        .get("http-bind")
        .or_else(|| opts.flags.get("http-port"))
        .cloned();
    let server = match http_bind {
        Some(addr) => {
            let bind = parse_bind(&addr).map_err(anyhow::Error::msg)?;
            let state = ServeState {
                snapshots: Some(svc.handle()),
                summary: Arc::clone(&summary),
                recent: Arc::clone(&recent),
            };
            let srv = IntrospectionServer::start_on(bind, state)
                .with_context(|| format!("binding introspection server on {bind}"))?;
            eprintln!(
                "introspection: http://{}  (/metrics /metrics.json /healthz /epochs)",
                srv.local_addr()
            );
            Some(srv)
        }
        None => None,
    };

    // Optional tracing (PR 7): the session wraps the whole replay, so
    // the Perfetto timeline shows every epoch's apply/detect/publish
    // spans with the per-pass Louvain spans nested inside.
    let trace_session = opts
        .flags
        .get("trace")
        .map(|_| gve_louvain::trace::TraceSession::start());

    let mut epochs: Vec<Arc<EpochSnapshot>> = Vec::new();
    let reader = UpdateStreamReader::open(&stream_path)?;
    for op in reader {
        if let Some(snap) = svc.submit(op?) {
            *summary.lock().unwrap() = svc.metrics().summary();
            recent.lock().unwrap().push(RecentEpoch::of(&snap));
            epochs.push(snap);
        }
    }
    if let Some(snap) = svc.flush() {
        recent.lock().unwrap().push(RecentEpoch::of(&snap));
        epochs.push(snap);
    }
    *summary.lock().unwrap() = svc.metrics().summary();

    if let (Some(session), Some(path)) = (trace_session, opts.flags.get("trace")) {
        let trace = session.finish();
        gve_louvain::trace::chrome::write(&trace, path)
            .with_context(|| format!("writing trace to {path}"))?;
        eprintln!(
            "trace: {} events across {} threads ({} dropped) -> {path} (open in https://ui.perfetto.dev)",
            trace.events.len(),
            trace.threads.len(),
            trace.dropped,
        );
        if trace.dropped > 0 {
            eprintln!(
                "trace: dropped by thread: {}",
                gve_louvain::trace::report::dropped_summary(&trace)
            );
        }
    }

    // --- Per-epoch table.
    let mut t = Table::new(
        "Service replay (per published epoch)",
        &["epoch", "ops", "affected", "apply", "detect", "wall", "Q", "|Γ|", "|V|"],
    );
    for s in &epochs {
        t.row(vec![
            format!("{}", s.epoch),
            format!("{}", s.stats.batch_ops),
            format!("{}", s.stats.affected_seeded),
            fmt_ns(s.stats.apply_ns),
            fmt_ns(s.stats.detect_ns),
            fmt_ns(s.stats.wall_ns()),
            format!("{:.4}", s.modularity),
            format!("{}", s.num_communities()),
            format!("{}", s.vertices),
        ]);
    }
    print!("{}", t.render());

    // --- Summary.
    let m = svc.metrics();
    let pct = m.epoch_percentiles();
    println!(
        "{} epochs | ingest {:.0} ops/s | epoch latency median {} max {} \
         p50 {} p95 {} p99 {} | \
         sustained {:.1}M edges/s | Q {:.4} -> {:.4} (drift {:+.4}, min {:.4})",
        epochs.len(),
        m.ingest_ops_per_sec(),
        fmt_ns(m.median_epoch_ns()),
        fmt_ns(m.max_epoch_ns()),
        fmt_ns(pct.p50),
        fmt_ns(pct.p95),
        fmt_ns(pct.p99),
        edges_per_sec(svc.graph().num_edges(), m.median_epoch_ns().max(1)) / 1e6,
        m.initial_modularity,
        m.last_modularity,
        m.quality_drift(),
        m.min_modularity,
    );

    // Keep the introspection endpoint up after the replay so scrapers
    // can read the end state (replays on smoke sizes finish in ms).
    if let Some(srv) = server {
        let linger = opts.get_i("linger", 0).max(0) as u64;
        if linger > 0 {
            eprintln!(
                "lingering {linger}s with introspection live at http://{}",
                srv.local_addr()
            );
            std::thread::sleep(std::time::Duration::from_secs(linger));
        }
        drop(srv); // stop + join the HTTP thread before exit
    }
    Ok(())
}
