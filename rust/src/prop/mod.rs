//! Mini property-testing framework (the offline registry has no
//! proptest).  Deterministic: cases derive from a seed; on failure the
//! case seed is reported so the exact input can be replayed.
//!
//! ```
//! use gve_louvain::prop::{forall, Gen};
//! forall("sum commutes", 100, |g: &mut Gen| {
//!     let (a, b) = (g.u64(0, 1000), g.u64(0, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::parallel::prng::Xoshiro256;

/// Per-case random input source.
pub struct Gen {
    rng: Xoshiro256,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Self { rng: Xoshiro256::new(case_seed), case_seed }
    }

    /// Uniform u64 in `[lo, hi]`.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.unit_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    /// A vector of `len` values built by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Random membership vector over `n` vertices with ≤ `max_comms`
    /// communities (dense ids not guaranteed).
    pub fn membership(&mut self, n: usize, max_comms: usize) -> Vec<u32> {
        let nc = self.usize(1, max_comms.max(1)) as u64;
        (0..n).map(|_| self.rng.below(nc) as u32).collect()
    }
}

/// Run `cases` cases of `body`; panics with the failing case seed.
pub fn forall(name: &str, cases: usize, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = 0x5eed_0000u64;
    for case in 0..cases {
        let case_seed = base + case as u64;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            body(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by its seed.
pub fn replay(case_seed: u64, body: impl Fn(&mut Gen)) {
    let mut g = Gen::new(case_seed);
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("add-commutes", 50, |g| {
            let (a, b) = (g.u64(0, 1 << 20), g.u64(0, 1 << 20));
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn forall_reports_failing_seed() {
        let caught = std::panic::catch_unwind(|| {
            forall("always-fails", 3, |_g| panic!("boom"));
        });
        let err = caught.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| err.downcast_ref::<&str>().unwrap_or(&"").to_string());
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..32 {
            assert_eq!(a.u64(0, 1000), b.u64(0, 1000));
        }
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(9);
        for _ in 0..1000 {
            let x = g.u64(5, 10);
            assert!((5..=10).contains(&x));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let m = g.membership(50, 8);
        assert_eq!(m.len(), 50);
        assert!(m.iter().all(|&c| c < 8));
    }
}
