//! Report rendering: aligned text/markdown tables + CSV, used by the
//! CLI, the examples, and every bench target.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table (what the benches print).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "## {}", self.title);
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &w));
        let _ = writeln!(out, "{}", w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &w));
        }
        out
    }

    /// Render as GitHub-flavored markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
        }
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(out, "|{}|", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(path, out)
    }
}

/// Format a float with fixed precision (bench-row convenience).
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a ratio as `1.23x`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Sample", &["graph", "q"]);
        t.row(vec!["web".into(), "0.86".into()]);
        t.row(vec!["road-network".into(), "0.95".into()]);
        t
    }

    #[test]
    fn text_render_aligns() {
        let s = sample().render();
        assert!(s.contains("## Sample"));
        assert!(s.contains("road-network"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn markdown_render() {
        let s = sample().render_markdown();
        assert!(s.contains("| graph | q |"));
        assert!(s.contains("|---|---|"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let p = std::env::temp_dir().join("gve_report_test.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
