//! Service replay driver (PR 3): feed a PR-2 churn timeline through a
//! [`CommunityService`] and collect its published epochs — the
//! service-level counterpart of
//! [`dynamic::replay_timeline`](super::dynamic).
//!
//! The timeline machinery keeps replays deterministic: batches are
//! pre-generated (so every run and every strategy sees identical
//! inputs) and ingested via the direct batch path, bypassing the
//! wall-clock flush trigger.  Tests use this to pin service behaviour
//! against the bare `DynamicLouvain` oracle; the bench's `"service"`
//! scenario summarizes the same epochs `louvain_serve` tabulates.

use super::dynamic::ChurnTimeline;
use super::metrics::median;
use crate::graph::Csr;
use crate::service::{CommunityService, EpochSnapshot, ServiceConfig};
use std::sync::Arc;

/// Replay every batch of `timeline` through a fresh service on `g0`;
/// returns the service (for follow-up queries / metrics) and the
/// published [`EpochSnapshot`]s — one per batch, in epoch order.  The
/// snapshots *are* the replay record; there is deliberately no parallel
/// cell struct to keep in sync.  (The initial full run is epoch 0 of
/// the service's metrics but yields no entry here — every config pays
/// it identically, like the PR-2 replay.)
pub fn replay_service(
    g0: &Csr,
    timeline: &ChurnTimeline,
    cfg: ServiceConfig,
) -> (CommunityService, Vec<Arc<EpochSnapshot>>) {
    let mut svc = CommunityService::new(g0.clone(), cfg);
    let epochs = timeline.batches.iter().map(|b| svc.ingest_batch(b)).collect();
    (svc, epochs)
}

/// Aggregate view of one replay (a bench / report row).
#[derive(Clone, Debug)]
pub struct ServiceSummary {
    pub epochs: usize,
    pub total_ops: usize,
    /// Apply + detect across all update epochs.
    pub total_wall_ns: u64,
    pub median_epoch_ns: u64,
    pub max_epoch_ns: u64,
    /// Accepted ops over total wall time.
    pub ops_per_sec: f64,
    pub final_modularity: f64,
    /// Final modularity minus the *initial full run's* — the same
    /// definition as `ServiceMetrics::quality_drift`, so bench cells
    /// and `louvain_serve` report one number for one behaviour.
    pub drift: f64,
}

/// Summarize a replay's published epochs.  `initial_modularity` is the
/// boot epoch's quality (`ServiceMetrics::initial_modularity` — epoch 0
/// is not in the list); empty input → zeroed summary.
pub fn summarize_service(epochs: &[Arc<EpochSnapshot>], initial_modularity: f64) -> ServiceSummary {
    if epochs.is_empty() {
        return ServiceSummary {
            epochs: 0,
            total_ops: 0,
            total_wall_ns: 0,
            median_epoch_ns: 0,
            max_epoch_ns: 0,
            ops_per_sec: 0.0,
            final_modularity: 0.0,
            drift: 0.0,
        };
    }
    let total_ops: usize = epochs.iter().map(|e| e.stats.batch_ops).sum();
    let total_wall_ns: u64 = epochs.iter().map(|e| e.stats.wall_ns()).sum();
    let walls: Vec<f64> = epochs.iter().map(|e| e.stats.wall_ns() as f64).collect();
    ServiceSummary {
        epochs: epochs.len(),
        total_ops,
        total_wall_ns,
        median_epoch_ns: median(&walls) as u64,
        max_epoch_ns: epochs.iter().map(|e| e.stats.wall_ns()).max().unwrap_or(0),
        ops_per_sec: if total_wall_ns == 0 {
            0.0
        } else {
            total_ops as f64 * 1e9 / total_wall_ns as f64
        },
        final_modularity: epochs.last().unwrap().modularity,
        drift: epochs.last().unwrap().modularity - initial_modularity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dynamic::churn_timeline;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::dynamic::SeedStrategy;

    #[test]
    fn replay_produces_one_epoch_per_batch() {
        let g0 = generate(GraphFamily::Web, 9, 17);
        let tl = churn_timeline(&g0, 4, 0.01, 17);
        let (svc, epochs) = replay_service(&g0, &tl, ServiceConfig::default());
        assert_eq!(epochs.len(), 4);
        for (i, e) in epochs.iter().enumerate() {
            assert_eq!(e.epoch, i as u64 + 1);
            assert_eq!(e.stats.batch_ops, tl.batches[i].len());
            assert_eq!(e.edges, tl.graphs[i].num_edges());
            assert!(e.modularity > 0.5);
        }
        // The replay is exact: the service holds the timeline's final graph.
        assert_eq!(svc.graph(), tl.graphs.last().unwrap());
        assert_eq!(svc.epoch(), 4);
        let q0 = svc.metrics().initial_modularity;
        let s = summarize_service(&epochs, q0);
        assert_eq!(s.epochs, 4);
        assert_eq!(s.total_ops, tl.batches.iter().map(|b| b.len()).sum::<usize>());
        assert!(s.total_wall_ns > 0);
        assert!(s.ops_per_sec > 0.0);
        assert_eq!(s.final_modularity, epochs[3].modularity);
        // Drift and wall totals match the service's own metrics (one
        // definition across the bench cells and louvain_serve).
        assert!((s.drift - svc.metrics().quality_drift()).abs() < 1e-12);
        assert_eq!(s.total_wall_ns, svc.metrics().total_wall_ns());
    }

    #[test]
    fn service_epochs_match_the_bare_dynamic_driver() {
        // Same strategy, same timeline, threads=1: the service must
        // publish exactly the partitions DynamicLouvain computes
        // (the service adds snapshots + metrics, not different math).
        use crate::louvain::dynamic::DynamicLouvain;
        use crate::louvain::params::LouvainParams;
        let g0 = generate(GraphFamily::Web, 9, 23);
        let tl = churn_timeline(&g0, 3, 0.01, 23);
        let cfg = ServiceConfig { strategy: SeedStrategy::DeltaScreening, ..Default::default() };
        let (_, epochs) = replay_service(&g0, &tl, cfg);
        let mut dl =
            DynamicLouvain::new(LouvainParams::default(), SeedStrategy::DeltaScreening);
        dl.run_initial(&g0);
        for (i, (g, b)) in tl.graphs.iter().zip(&tl.batches).enumerate() {
            let out = dl.update(g, b);
            assert_eq!(epochs[i].modularity.to_bits(), out.result.modularity.to_bits(), "epoch {}", i + 1);
            assert_eq!(epochs[i].num_communities(), out.result.num_communities);
            assert_eq!(epochs[i].stats.affected_seeded, out.affected_seeded);
        }
    }

    #[test]
    fn summarize_empty_is_zeroed() {
        let s = summarize_service(&[], 0.9);
        assert_eq!(s.epochs, 0);
        assert_eq!(s.ops_per_sec, 0.0);
        assert_eq!(s.drift, 0.0);
    }
}
