//! Hand-rolled `--key value` option parsing shared by the binaries
//! (`repro`, `louvain_serve`) — the offline registry has no clap, and
//! two drifting copies of the same parser is worse than none.

use std::collections::HashMap;

/// Parsed `--key value` options + positional args.  A `--flag`
/// followed by another `--option` (or end of input) gets the value
/// `"true"`.
pub struct Opts {
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Opts {
    pub fn parse(args: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Self { flags, positional }
    }

    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_i(&self, key: &str, default: i64) -> i64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Opts {
        Opts::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn key_value_flags_and_positionals() {
        let o = parse(&["run", "--scale", "12", "--quick", "--seed", "7", "out.json"]);
        assert_eq!(o.get("scale", "0"), "12");
        assert_eq!(o.get_i("seed", 0), 7);
        assert_eq!(o.get("quick", "false"), "true");
        assert_eq!(o.get("missing", "d"), "d");
        assert_eq!(o.get_i("scale", 0), 12);
        assert_eq!(o.positional, vec!["run", "out.json"]);
    }

    #[test]
    fn trailing_flag_and_floats() {
        let o = parse(&["--frac", "0.05", "--verbose"]);
        assert!((o.get_f("frac", 0.0) - 0.05).abs() < 1e-12);
        assert_eq!(o.get_f("other", 0.25), 0.25);
        assert_eq!(o.get("verbose", "false"), "true");
        assert_eq!(o.get_i("frac", 9), 9, "non-integer falls back to default");
    }
}
