//! Hand-rolled `--key value` option parsing shared by the binaries
//! (`repro`, `louvain_serve`) — the offline registry has no clap, and
//! two drifting copies of the same parser is worse than none.

use std::collections::HashMap;

/// Parsed `--key value` options + positional args.  A `--flag`
/// followed by another `--option` (or end of input) gets the value
/// `"true"`.
pub struct Opts {
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Opts {
    pub fn parse(args: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Self { flags, positional }
    }

    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_i(&self, key: &str, default: i64) -> i64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

/// Shared Louvain knob parsing for the binaries: `--threads --seed
/// --schedule --chunk --table --small-degree --hub-degree
/// --prefetch-distance --adaptive-width --serial-pass-threshold
/// --width-gain`, each defaulting to
/// [`LouvainParams::default`].  Unrecognised schedule/table names fall
/// back to the defaults rather than erroring (consistent with the
/// tolerant `get_*` accessors above).
pub fn louvain_params_from(opts: &Opts) -> crate::louvain::LouvainParams {
    use crate::louvain::params::TableKind;
    use crate::parallel::Schedule;
    let d = crate::louvain::LouvainParams::default();
    crate::louvain::LouvainParams {
        threads: opts.get_i("threads", d.threads as i64).max(1) as usize,
        seed: opts.get_i("seed", d.seed as i64) as u64,
        schedule: Schedule::parse(&opts.get("schedule", "")).unwrap_or(d.schedule),
        chunk: opts.get_i("chunk", d.chunk as i64).max(1) as usize,
        table: TableKind::parse(&opts.get("table", "")).unwrap_or(d.table),
        small_degree: opts.get_i("small-degree", d.small_degree as i64).max(0) as usize,
        hub_degree: opts.get_i("hub-degree", d.hub_degree as i64).max(0) as usize,
        prefetch_distance: opts.get_i("prefetch-distance", d.prefetch_distance as i64).max(0)
            as usize,
        // Bare `--adaptive-width` works: valueless flags parse as "true".
        adaptive_width: opts.get("adaptive-width", "false") == "true",
        serial_pass_threshold: opts
            .get_i("serial-pass-threshold", d.serial_pass_threshold as i64)
            .max(0) as usize,
        width_gain: opts.get_f("width-gain", d.width_gain),
        ..d
    }
}

/// Parse a bind address for the serving / introspection listeners
/// (PR 9): either a full `host:port` socket address or a bare port,
/// which binds loopback — the safe default for ports that expose
/// process internals.  `0` (the port) still means OS-assigned.
pub fn parse_bind(s: &str) -> Result<std::net::SocketAddr, String> {
    if let Ok(port) = s.parse::<u16>() {
        return Ok(std::net::SocketAddr::from(([127, 0, 0, 1], port)));
    }
    s.parse::<std::net::SocketAddr>()
        .map_err(|e| format!("bind address {s:?} is neither a port nor host:port ({e})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Opts {
        Opts::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn key_value_flags_and_positionals() {
        let o = parse(&["run", "--scale", "12", "--quick", "--seed", "7", "out.json"]);
        assert_eq!(o.get("scale", "0"), "12");
        assert_eq!(o.get_i("seed", 0), 7);
        assert_eq!(o.get("quick", "false"), "true");
        assert_eq!(o.get("missing", "d"), "d");
        assert_eq!(o.get_i("scale", 0), 12);
        assert_eq!(o.positional, vec!["run", "out.json"]);
    }

    #[test]
    fn trailing_flag_and_floats() {
        let o = parse(&["--frac", "0.05", "--verbose"]);
        assert!((o.get_f("frac", 0.0) - 0.05).abs() < 1e-12);
        assert_eq!(o.get_f("other", 0.25), 0.25);
        assert_eq!(o.get("verbose", "false"), "true");
        assert_eq!(o.get_i("frac", 9), 9, "non-integer falls back to default");
    }

    #[test]
    fn parse_bind_accepts_ports_and_socket_addrs() {
        assert_eq!(parse_bind("9184").unwrap(), "127.0.0.1:9184".parse().unwrap());
        assert_eq!(parse_bind("0").unwrap(), "127.0.0.1:0".parse().unwrap());
        assert_eq!(parse_bind("0.0.0.0:7000").unwrap(), "0.0.0.0:7000".parse().unwrap());
        assert_eq!(parse_bind("[::1]:80").unwrap(), "[::1]:80".parse().unwrap());
        assert!(parse_bind("not-an-addr").is_err());
        assert!(parse_bind("127.0.0.1").is_err(), "host without port");
        assert!(parse_bind("99999").is_err(), "out-of-range port is not an addr either");
    }

    #[test]
    fn louvain_params_from_reads_scan_engine_knobs() {
        let o = parse(&[
            "--threads", "4", "--schedule", "degree-bucketed", "--table", "close-kv",
            "--small-degree", "8", "--hub-degree", "512", "--prefetch-distance", "0",
            "--adaptive-width", "--serial-pass-threshold", "1024", "--width-gain", "2.5",
        ]);
        let p = louvain_params_from(&o);
        assert_eq!(p.threads, 4);
        assert_eq!(p.schedule, crate::parallel::Schedule::DegreeBucketed);
        assert_eq!(p.table, crate::louvain::params::TableKind::CloseKv);
        assert_eq!(p.small_degree, 8);
        assert_eq!(p.hub_degree, 512);
        assert_eq!(p.prefetch_distance, 0);
        assert!(p.adaptive_width, "bare --adaptive-width flag turns the engine on");
        assert_eq!(p.serial_pass_threshold, 1024);
        assert_eq!(p.width_gain, 2.5);

        // Absent / bogus flags fall back to the adopted defaults.
        let d = crate::louvain::LouvainParams::default();
        let p = louvain_params_from(&parse(&["--schedule", "bogus"]));
        assert_eq!(p.schedule, d.schedule);
        assert_eq!(p.small_degree, d.small_degree);
        assert_eq!(p.chunk, d.chunk);
        assert!(!p.adaptive_width);
        assert_eq!(p.serial_pass_threshold, d.serial_pass_threshold);
        assert_eq!(p.width_gain, d.width_gain);
    }
}
