//! The experiment runner: cross-system comparison sweeps with repeats
//! (the paper averages five runs per graph; geometric mean for runtime,
//! arithmetic for modularity).

use super::metrics::{geomean, mean};
use super::suite::SuiteEntry;
use crate::baselines::{run_system, BaselineOutcome, System};
use crate::gpusim::DeviceModel;
use crate::graph::Csr;

/// One (graph × system) aggregate over repeats.
#[derive(Clone, Debug)]
pub struct ComparisonCell {
    pub graph: &'static str,
    pub system: System,
    /// Geometric-mean modeled runtime (ns); `None` = OOM-excluded.
    pub modeled_ns: Option<f64>,
    /// Geometric-mean wall time on this host (ns).
    pub wall_ns: f64,
    /// Arithmetic-mean modularity.
    pub modularity: f64,
    pub num_communities: usize,
    pub passes: usize,
}

/// Run `systems` on one suite graph with repeats.
pub fn compare_on_entry(
    entry: &SuiteEntry,
    scale_offset: i32,
    systems: &[System],
    threads: usize,
    repeats: usize,
    seed: u64,
) -> Vec<ComparisonCell> {
    let g = entry.graph(scale_offset, seed);
    compare_on_graph(&g, entry, systems, threads, repeats, seed)
}

/// Run `systems` on a prebuilt graph (caller controls generation).
pub fn compare_on_graph(
    g: &Csr,
    entry: &SuiteEntry,
    systems: &[System],
    threads: usize,
    repeats: usize,
    seed: u64,
) -> Vec<ComparisonCell> {
    let dev = DeviceModel::default();
    systems
        .iter()
        .map(|&system| {
            let mut walls = Vec::new();
            let mut modeled = Vec::new();
            let mut qs = Vec::new();
            let mut last: Option<BaselineOutcome> = None;
            for r in 0..repeats.max(1) {
                let out = run_system(system, g, threads, seed ^ (r as u64) << 32);
                walls.push(out.wall_ns as f64);
                if let Some(mns) = out.modeled_ns {
                    modeled.push(mns as f64);
                }
                qs.push(out.modularity);
                last = Some(out);
            }
            let last = last.unwrap();
            // Paper-scale OOM gate: GPU systems are excluded on graphs
            // whose *paper-scale* footprint exceeds device memory.
            let paper_oom = match system {
                System::NuLouvain => !dev.nu_louvain_fits(entry.paper_v, entry.paper_e),
                System::CuGraph => !dev.cugraph_fits(entry.paper_v, entry.paper_e),
                _ => false,
            };
            let modeled_ns = if paper_oom || modeled.is_empty() {
                None
            } else {
                Some(geomean(&modeled))
            };
            ComparisonCell {
                graph: entry.name,
                system,
                modeled_ns,
                wall_ns: geomean(&walls),
                modularity: mean(&qs),
                num_communities: last.num_communities,
                passes: last.passes,
            }
        })
        .collect()
}

/// Mean speedup of `a` over `b` across graphs (paper Fig 11b/12b style):
/// geometric mean of per-graph modeled-time ratios where both ran.
pub fn mean_speedup(cells: &[ComparisonCell], a: System, b: System) -> Option<f64> {
    let mut ratios = Vec::new();
    for cell in cells.iter().filter(|c| c.system == a) {
        let other = cells
            .iter()
            .find(|c| c.system == b && c.graph == cell.graph)?;
        if let (Some(ta), Some(tb)) = (cell.modeled_ns, other.modeled_ns) {
            if ta > 0.0 {
                ratios.push(tb / ta);
            }
        }
    }
    if ratios.is_empty() {
        None
    } else {
        Some(geomean(&ratios))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::suite;

    #[test]
    fn comparison_runs_and_aggregates() {
        let entry = suite::find("com-Orkut").unwrap();
        let cells = compare_on_entry(
            entry,
            -3,
            &[System::GveLouvain, System::NetworKit],
            1,
            2,
            42,
        );
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.modularity > 0.2, "{:?}", c.system);
            assert!(c.wall_ns > 0.0);
            assert!(c.modeled_ns.is_some());
        }
    }

    #[test]
    fn paper_scale_oom_gates_apply() {
        // sk-2005 at paper scale OOMs ν-Louvain even though the scaled
        // replica fits this host.
        let entry = suite::find("sk-2005").unwrap();
        let cells = compare_on_entry(entry, -6, &[System::NuLouvain], 1, 1, 42);
        assert!(cells[0].modeled_ns.is_none(), "nu must be OOM-gated on sk-2005");
        let entry2 = suite::find("asia_osm").unwrap();
        let cells2 = compare_on_entry(entry2, -6, &[System::NuLouvain], 1, 1, 42);
        assert!(cells2[0].modeled_ns.is_some());
    }

    #[test]
    fn speedup_computation() {
        let entry = suite::find("asia_osm").unwrap();
        let cells = compare_on_entry(entry, -5, &[System::GveLouvain, System::Vite], 1, 1, 42);
        let s = mean_speedup(&cells, System::GveLouvain, System::Vite).unwrap();
        assert!(s > 0.0);
    }
}
