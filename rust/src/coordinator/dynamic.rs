//! Batch-timeline replay: the dynamic-graph counterpart of
//! [`runner::compare_on_graph`](super::runner::compare_on_graph).
//!
//! A churn timeline (a start graph plus a sequence of
//! [`EdgeBatch`]es, each ~`frac` of the edges) is replayed once per
//! [`SeedStrategy`]; every batch yields a [`BatchCell`] with the
//! measured wall time, modularity, pass count and seeded-affected
//! count, so reports can show per-batch runtime vs. full recompute —
//! the Fig-style comparison of arXiv:2301.12390 on this testbed's
//! planted graphs.

use crate::graph::delta::{DeltaScratch, EdgeBatch};
use crate::graph::generators::churn_batch;
use crate::graph::Csr;
use crate::louvain::dynamic::{DynamicLouvain, SeedStrategy};
use crate::louvain::params::LouvainParams;
use crate::parallel::pool::ParallelOpts;
use crate::parallel::team::Exec;
use std::time::Instant;

/// A generated churn workload: `graphs[i]` is the state after
/// `batches[i]` was applied (all strategies replay identical inputs).
pub struct ChurnTimeline {
    pub batches: Vec<EdgeBatch>,
    pub graphs: Vec<Csr>,
}

/// Generate `n_batches` sequential churn batches of `frac` mutated
/// edges each, starting from `g0`.  Deterministic in `(g0, frac, seed)`.
pub fn churn_timeline(g0: &Csr, n_batches: usize, frac: f64, seed: u64) -> ChurnTimeline {
    let mut batches = Vec::with_capacity(n_batches);
    let mut graphs = Vec::with_capacity(n_batches);
    let mut scratch = DeltaScratch::new();
    let mut cur = g0.clone();
    for i in 0..n_batches {
        let b = churn_batch(&cur, frac, seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut next = Csr::default();
        cur.apply_batch_into(&b, &mut scratch, &mut next, ParallelOpts::default(), Exec::scoped());
        cur = next;
        graphs.push(cur.clone());
        batches.push(b);
    }
    ChurnTimeline { batches, graphs }
}

/// One (strategy × batch) measurement.
#[derive(Clone, Debug)]
pub struct BatchCell {
    pub strategy: SeedStrategy,
    /// 1-based batch index within the timeline.
    pub batch: usize,
    /// Wall time of the update, including screening + seeding overhead.
    pub wall_ns: u64,
    pub modularity: f64,
    pub passes: usize,
    pub affected_seeded: usize,
    /// Directed edge slots of the graph at this point.
    pub edges: usize,
}

/// Replay `timeline` once per strategy with a fresh [`DynamicLouvain`]
/// (initial full run excluded from the cells — every strategy pays it
/// identically).
pub fn replay_timeline(
    g0: &Csr,
    timeline: &ChurnTimeline,
    strategies: &[SeedStrategy],
    params: &LouvainParams,
) -> Vec<BatchCell> {
    let mut cells = Vec::with_capacity(strategies.len() * timeline.batches.len());
    for &strategy in strategies {
        let mut dl = DynamicLouvain::new(params.clone(), strategy);
        dl.run_initial(g0);
        for (i, batch) in timeline.batches.iter().enumerate() {
            let g = &timeline.graphs[i];
            let t0 = Instant::now();
            let out = dl.update(g, batch);
            let wall_ns = t0.elapsed().as_nanos() as u64;
            cells.push(BatchCell {
                strategy,
                batch: i + 1,
                wall_ns,
                modularity: out.result.modularity,
                passes: out.result.passes,
                affected_seeded: out.affected_seeded,
                edges: g.num_edges(),
            });
        }
    }
    cells
}

/// Per-strategy aggregate over a replay's cells.
#[derive(Clone, Debug)]
pub struct StrategySummary {
    pub strategy: SeedStrategy,
    pub batches: usize,
    pub total_wall_ns: u64,
    pub median_wall_ns: u64,
    /// Modularity after the final batch.
    pub final_modularity: f64,
    pub mean_affected: f64,
}

/// Aggregate `cells` per strategy (median via the crate-wide metric).
pub fn summarize(cells: &[BatchCell]) -> Vec<StrategySummary> {
    use super::metrics::median;
    let mut out = Vec::new();
    for strategy in SeedStrategy::ALL {
        let mine: Vec<&BatchCell> = cells.iter().filter(|c| c.strategy == strategy).collect();
        if mine.is_empty() {
            continue;
        }
        let walls: Vec<f64> = mine.iter().map(|c| c.wall_ns as f64).collect();
        out.push(StrategySummary {
            strategy,
            batches: mine.len(),
            total_wall_ns: mine.iter().map(|c| c.wall_ns).sum(),
            median_wall_ns: median(&walls) as u64,
            final_modularity: mine.last().unwrap().modularity,
            mean_affected: mine.iter().map(|c| c.affected_seeded as f64).sum::<f64>()
                / mine.len() as f64,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    #[test]
    fn timeline_is_deterministic_and_consistent() {
        let g0 = generate(GraphFamily::Web, 9, 21);
        let a = churn_timeline(&g0, 3, 0.01, 5);
        let b = churn_timeline(&g0, 3, 0.01, 5);
        assert_eq!(a.graphs, b.graphs);
        assert_eq!(a.batches.len(), 3);
        for g in &a.graphs {
            g.validate().unwrap();
            assert!(g.is_symmetric());
            assert_eq!(g.num_vertices(), g0.num_vertices());
        }
        // Batches actually mutate the graph.
        assert_ne!(a.graphs[0], g0);
        assert_ne!(a.graphs[1], a.graphs[0]);
    }

    #[test]
    fn replay_produces_cells_for_every_strategy_and_batch() {
        let g0 = generate(GraphFamily::Web, 9, 23);
        let tl = churn_timeline(&g0, 3, 0.01, 9);
        let cells = replay_timeline(&g0, &tl, &SeedStrategy::ALL, &LouvainParams::default());
        assert_eq!(cells.len(), 9);
        for c in &cells {
            assert!(c.modularity > 0.5, "{:?} batch {} q={}", c.strategy, c.batch, c.modularity);
            assert!(c.wall_ns > 0);
            assert!(c.affected_seeded <= g0.num_vertices());
        }
        let summaries = summarize(&cells);
        assert_eq!(summaries.len(), 3);
        let q_full = summaries[0].final_modularity;
        for s in &summaries {
            assert_eq!(s.batches, 3);
            assert!((s.final_modularity - q_full).abs() < 0.02, "{:?}", s.strategy);
        }
    }
}
