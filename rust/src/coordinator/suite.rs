//! The evaluation suite: 13 synthetic graphs mirroring Table 2.
//!
//! Each entry names its SuiteSparse counterpart, the generator family
//! standing in for it, the generated scale (log2 vertices, shifted by a
//! CLI-controlled offset), and the *paper-scale* |V| / |E| used by the
//! device memory model to reproduce the OOM exclusions of §5.2.

use crate::graph::generators::{generate, GraphFamily};
use crate::graph::Csr;

/// One suite graph.
#[derive(Clone, Copy, Debug)]
pub struct SuiteEntry {
    /// SuiteSparse name this stands in for.
    pub name: &'static str,
    pub family: GraphFamily,
    /// log2 of generated vertices at offset 0.
    pub scale: u32,
    /// Paper-scale vertex count (Table 2).
    pub paper_v: u64,
    /// Paper-scale directed edge slots (Table 2, "after reverse edges").
    pub paper_e: u64,
}

/// Table 2, scaled down (generated sizes keep the relative ordering and
/// the per-family density signatures).
pub const SUITE: [SuiteEntry; 13] = [
    SuiteEntry { name: "indochina-2004", family: GraphFamily::Web, scale: 12, paper_v: 7_410_000, paper_e: 341_000_000 },
    SuiteEntry { name: "uk-2002", family: GraphFamily::Web, scale: 13, paper_v: 18_500_000, paper_e: 567_000_000 },
    SuiteEntry { name: "arabic-2005", family: GraphFamily::Web, scale: 13, paper_v: 22_700_000, paper_e: 1_210_000_000 },
    SuiteEntry { name: "uk-2005", family: GraphFamily::Web, scale: 14, paper_v: 39_500_000, paper_e: 1_730_000_000 },
    SuiteEntry { name: "webbase-2001", family: GraphFamily::Web, scale: 15, paper_v: 118_000_000, paper_e: 1_890_000_000 },
    SuiteEntry { name: "it-2004", family: GraphFamily::Web, scale: 14, paper_v: 41_300_000, paper_e: 2_190_000_000 },
    SuiteEntry { name: "sk-2005", family: GraphFamily::Web, scale: 14, paper_v: 50_600_000, paper_e: 3_800_000_000 },
    SuiteEntry { name: "com-LiveJournal", family: GraphFamily::Social, scale: 12, paper_v: 4_000_000, paper_e: 69_400_000 },
    SuiteEntry { name: "com-Orkut", family: GraphFamily::Social, scale: 11, paper_v: 3_070_000, paper_e: 234_000_000 },
    SuiteEntry { name: "asia_osm", family: GraphFamily::Road, scale: 14, paper_v: 12_000_000, paper_e: 25_400_000 },
    SuiteEntry { name: "europe_osm", family: GraphFamily::Road, scale: 15, paper_v: 50_900_000, paper_e: 108_000_000 },
    SuiteEntry { name: "kmer_A2a", family: GraphFamily::Kmer, scale: 15, paper_v: 171_000_000, paper_e: 361_000_000 },
    SuiteEntry { name: "kmer_V1r", family: GraphFamily::Kmer, scale: 15, paper_v: 214_000_000, paper_e: 465_000_000 },
];

impl SuiteEntry {
    /// Generate this entry's graph; `offset` shifts the scale (negative
    /// for quick runs, positive for bigger ones).
    pub fn graph(&self, offset: i32, seed: u64) -> Csr {
        let scale = (self.scale as i32 + offset).clamp(6, 22) as u32;
        generate(self.family, scale, seed ^ fnv(self.name))
    }
}

/// Stable per-name seed component.
fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Entries selected by family.
pub fn by_family(f: GraphFamily) -> Vec<&'static SuiteEntry> {
    SUITE.iter().filter(|e| e.family == f).collect()
}

/// A small representative subset (one per family) for quick benches.
pub fn quick() -> Vec<&'static SuiteEntry> {
    vec![&SUITE[0], &SUITE[7], &SUITE[9], &SUITE[11]]
}

/// Look up an entry by its SuiteSparse name.
pub fn find(name: &str) -> Option<&'static SuiteEntry> {
    SUITE.iter().find(|e| e.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_13_graphs_in_4_families() {
        assert_eq!(SUITE.len(), 13);
        assert_eq!(by_family(GraphFamily::Web).len(), 7);
        assert_eq!(by_family(GraphFamily::Social).len(), 2);
        assert_eq!(by_family(GraphFamily::Road).len(), 2);
        assert_eq!(by_family(GraphFamily::Kmer).len(), 2);
    }

    #[test]
    fn graphs_generate_and_are_distinct_per_entry() {
        let a = find("asia_osm").unwrap().graph(-4, 42);
        let b = find("europe_osm").unwrap().graph(-4, 42);
        a.validate().unwrap();
        b.validate().unwrap();
        assert_ne!(a, b, "same-family entries must differ (seed mix)");
    }

    #[test]
    fn paper_sizes_match_table2_ordering() {
        let sk = find("sk-2005").unwrap();
        assert_eq!(sk.paper_e, 3_800_000_000);
        let asia = find("asia_osm").unwrap();
        assert!(asia.paper_e < sk.paper_e / 100);
    }

    #[test]
    fn quick_subset_covers_all_families() {
        let fams: std::collections::BTreeSet<_> =
            quick().iter().map(|e| e.family.name()).collect();
        assert_eq!(fams.len(), 4);
    }
}
