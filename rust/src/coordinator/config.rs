//! TOML-subset config parser (no serde/toml in the offline registry).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / flat arrays, `#` comments.  Enough for the
//! experiment definitions in `configs/*.toml`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed config: section → key → value ("" is the root section).
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let value = parse_value(v.trim()).with_context(|| format!("line {}", ln + 1))?;
            cfg.sections.entry(section.clone()).or_default().insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Fetch `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn get_int(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn get_float(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let cfg = Config::parse(
            r#"
# experiment
name = "fig11"
[run]
threads = 4
tolerance = 0.01
pruning = true
systems = ["gve-louvain", "vite"]
"#,
        )
        .unwrap();
        assert_eq!(cfg.get_str("", "name", ""), "fig11");
        assert_eq!(cfg.get_int("run", "threads", 0), 4);
        assert_eq!(cfg.get_float("run", "tolerance", 0.0), 0.01);
        assert!(cfg.get_bool("run", "pruning", false));
        let arr = cfg.get("run", "systems").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_str(), Some("gve-louvain"));
    }

    #[test]
    fn comments_and_hash_in_strings() {
        let cfg = Config::parse("key = \"a#b\" # trailing\n").unwrap();
        assert_eq!(cfg.get_str("", "key", ""), "a#b");
    }

    #[test]
    fn defaults_apply() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_int("run", "threads", 7), 7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("key garbage\n").is_err());
        assert!(Config::parse("key = [1, 2\n").is_err());
        assert!(Config::parse("key = \"open\n").is_err());
    }

    #[test]
    fn nested_arrays() {
        let cfg = Config::parse("m = [[1, 2], [3]]\n").unwrap();
        let outer = cfg.get("", "m").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[1].as_int(), Some(2));
    }
}
