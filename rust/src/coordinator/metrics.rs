//! Aggregate metrics helpers (the paper reports geometric-mean runtimes
//! and arithmetic-mean modularities — §4.1).

/// Geometric mean (ignores non-positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    let v: Vec<f64> = xs.iter().copied().filter(|&x| x > 0.0).collect();
    if v.is_empty() {
        return 0.0;
    }
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (of a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: u64) -> String {
    let ns = ns as f64;
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Edges/second processing rate (the paper's headline metric).
pub fn edges_per_sec(edges: usize, ns: u64) -> f64 {
    if ns == 0 {
        return 0.0;
    }
    edges as f64 / (ns as f64 / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 0.0, 8.0]) - 4.0).abs() < 1e-12); // zeros ignored
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn mean_median_stddev() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(1_500_000_000), "1.50s");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(1_500), "1.5µs");
        assert_eq!(fmt_ns(42), "42ns");
    }

    #[test]
    fn rate() {
        assert_eq!(edges_per_sec(560_000_000, 1_000_000_000), 560_000_000.0);
        assert_eq!(edges_per_sec(10, 0), 0.0);
    }
}
