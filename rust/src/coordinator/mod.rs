//! L3 coordinator: configuration, the experiment runner, metrics and
//! report generation — the operational shell around the algorithms.
//!
//! * [`suite`] — the 13-graph dataset mirroring Table 2 (name, family,
//!   scale, paper-scale |V|/|E| for the OOM gates);
//! * [`cli`] — the hand-rolled `--key value` option parser shared by
//!   the binaries (no clap in the offline registry);
//! * [`config`] — a TOML-subset parser for `configs/*.toml` experiment
//!   definitions (offline registry has no serde/toml);
//! * [`runner`] — cross-system comparison runs with repeats;
//! * [`dynamic`] — churn-timeline replay: per-batch runtime + quality
//!   of the dynamic seeding strategies vs. full recompute (PR 2);
//! * [`service`] — service replay driver: churn timelines through the
//!   long-lived `CommunityService`, per-epoch cells + summaries (PR 3);
//! * [`metrics`] — stopwatch + aggregate helpers (geomean et al.);
//! * [`report`] — markdown / CSV emitters used by benches and the CLI.

pub mod cli;
pub mod config;
pub mod dynamic;
pub mod metrics;
pub mod report;
pub mod runner;
pub mod service;
pub mod suite;
