//! L3 coordinator: configuration, the experiment runner, metrics and
//! report generation — the operational shell around the algorithms.
//!
//! * [`suite`] — the 13-graph dataset mirroring Table 2 (name, family,
//!   scale, paper-scale |V|/|E| for the OOM gates);
//! * [`config`] — a TOML-subset parser for `configs/*.toml` experiment
//!   definitions (offline registry has no serde/toml);
//! * [`runner`] — cross-system comparison runs with repeats;
//! * [`dynamic`] — churn-timeline replay: per-batch runtime + quality
//!   of the dynamic seeding strategies vs. full recompute (PR 2);
//! * [`metrics`] — stopwatch + aggregate helpers (geomean et al.);
//! * [`report`] — markdown / CSV emitters used by benches and the CLI.

pub mod config;
pub mod dynamic;
pub mod metrics;
pub mod report;
pub mod runner;
pub mod suite;
