//! GPU-semantics simulator hosting ν-Louvain (paper §4.3–4.4, App. A).
//!
//! No GPU exists on this testbed (repro band 0), so the CUDA execution
//! model is *simulated* — not cycle-accurately, but mechanism-accurately
//! for everything the paper's findings rest on (DESIGN.md §2):
//!
//! * **Lock-step warps** ([`warp`]) — 32 consecutive vertices compute
//!   their best community against the shared membership, *then* all
//!   apply: exactly the compute/apply granularity that lets symmetric
//!   vertices swap communities forever (§4.3.1) until Pick-Less breaks
//!   the cycle.
//! * **Per-vertex open-addressing hashtables** ([`hashtable`]) — keys +
//!   values carved out of two `2|E|` buffers at offset `2·O_i`,
//!   capacity `nextPow2(D_i)−1`, four probe sequences (linear /
//!   quadratic / double / quadratic-double, Algorithm 7), f32 or f64
//!   values (Fig 8).
//! * **Thread- vs block-per-vertex kernels** ([`kernels`]) — a degree
//!   switch routes vertices to either kernel (Figs 9–10); warp time is
//!   the max over lanes (divergence), block time divides parallel work
//!   across the block.
//! * **Device cost model** ([`device`]) — an A100-like throughput
//!   model: cycles and bytes accumulated by the kernels are converted
//!   to estimated kernel time with occupancy and launch-overhead
//!   effects, which is what makes late, small passes GPU-unfriendly —
//!   the paper's headline.  It also models device memory footprints
//!   (the OOM gates of §5.2).
//! * **ν-Louvain driver** ([`nulouvain`]) — Algorithms 4–6 with
//!   Pick-Less every ρ iterations (PL4 adopted).

pub mod device;
pub mod hashtable;
pub mod kernels;
pub mod nulouvain;
pub mod warp;

pub use device::DeviceModel;
pub use hashtable::{ProbeStrategy, ValueKind};
pub use nulouvain::{NuLouvain, NuParams, NuResult};
