//! Lock-step warp semantics (§3.5, §4.3.1).
//!
//! A warp is 32 threads executing in lock-step; in the thread-per-vertex
//! kernel, 32 *consecutive* vertices (SM assignment is by vertex id)
//! compute their best community against the shared membership vector and
//! only then apply their moves.  This compute-then-apply granularity is
//! what lets two symmetrically-connected vertices read each other's old
//! community and swap forever — the non-convergence the Pick-Less
//! heuristic exists to break.
//!
//! Divergence: a lock-step warp retires when its slowest lane does, so
//! the cycle cost of a warp is the **max** over lane costs, and idle
//! lanes (pruned / wrong-kernel vertices) still ride along at zero cost.

/// Threads per warp (NVIDIA).
pub const WARP_SIZE: usize = 32;

/// One lane's pending move decision.
#[derive(Clone, Copy, Debug)]
pub struct LaneMove {
    pub vertex: usize,
    pub to: u32,
    pub dq: f64,
}

/// Reusable decision buffer for one warp's compute phase.
#[derive(Debug, Default)]
pub struct WarpDecisions {
    moves: Vec<LaneMove>,
}

impl WarpDecisions {
    pub fn new() -> Self {
        Self { moves: Vec::with_capacity(WARP_SIZE) }
    }

    #[inline]
    pub fn clear(&mut self) {
        self.moves.clear();
    }

    #[inline]
    pub fn push(&mut self, m: LaneMove) {
        self.moves.push(m);
    }

    #[inline]
    pub fn drain(&mut self) -> std::vec::Drain<'_, LaneMove> {
        self.moves.drain(..)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.moves.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }
}

/// Lock-step warp cost: max over lane cycle counts.
#[inline]
pub fn warp_cycles(lane_cycles: &[u64]) -> u64 {
    lane_cycles.iter().copied().max().unwrap_or(0)
}

/// Iterate `0..n` in warp-sized id ranges.
pub fn warps(n: usize) -> impl Iterator<Item = std::ops::Range<usize>> {
    (0..n.div_ceil(WARP_SIZE)).map(move |w| {
        let lo = w * WARP_SIZE;
        lo..(lo + WARP_SIZE).min(n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warps_cover_range_in_order() {
        let rs: Vec<_> = warps(70).collect();
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0], 0..32);
        assert_eq!(rs[1], 32..64);
        assert_eq!(rs[2], 64..70);
    }

    #[test]
    fn warps_empty() {
        assert_eq!(warps(0).count(), 0);
    }

    #[test]
    fn warp_cycles_is_lane_max() {
        assert_eq!(warp_cycles(&[3, 9, 1]), 9);
        assert_eq!(warp_cycles(&[]), 0);
    }

    #[test]
    fn decisions_buffer_reuse() {
        let mut d = WarpDecisions::new();
        d.push(LaneMove { vertex: 1, to: 2, dq: 0.5 });
        assert_eq!(d.len(), 1);
        let taken: Vec<_> = d.drain().collect();
        assert_eq!(taken.len(), 1);
        assert!(d.is_empty());
    }
}
