//! Per-vertex open-addressing hashtables (paper §4.3.2, Fig 6, Alg. 7).
//!
//! Two contiguous buffers `buf_k: u32[2|E|]` and `buf_v: V[2|E|]` hold
//! every vertex's table; vertex `i`'s table lives at offset `2·O_i`
//! (its CSR offset doubled) with capacity `p1 = nextPow2(D_i) − 1`
//! (always ≥ D_i, load factor < 100%).  The secondary prime is
//! `p2 = nextPow2(p1) − 1 > p1`.
//!
//! Four collision-resolution strategies (Fig 7):
//! * `Linear`            — δ = 1 each retry;
//! * `Quadratic`         — δ doubles each retry;
//! * `Double`            — δ = k mod p2, fixed;
//! * `QuadraticDouble`   — δ ← 2δ + (k mod p2) (Algorithm 7 line 17;
//!   the adopted hybrid).
//!
//! Values are `f32` or `f64` (Fig 8 ablation) behind [`ValueKind`].
//! Every operation reports its probe count so the device model can
//! charge divergence/conflict costs.

/// Empty-slot marker (φ in Algorithm 7).
pub const EMPTY: u32 = u32::MAX;

/// Collision resolution strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProbeStrategy {
    Linear,
    Quadratic,
    Double,
    QuadraticDouble,
}

impl ProbeStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ProbeStrategy::Linear => "linear",
            ProbeStrategy::Quadratic => "quadratic",
            ProbeStrategy::Double => "double",
            ProbeStrategy::QuadraticDouble => "quadratic-double",
        }
    }

    pub const ALL: [ProbeStrategy; 4] = [
        ProbeStrategy::Linear,
        ProbeStrategy::Quadratic,
        ProbeStrategy::Double,
        ProbeStrategy::QuadraticDouble,
    ];
}

/// Hashtable value precision (Fig 8: `Float` adopted over `Double`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ValueKind {
    F32,
    F64,
}

impl ValueKind {
    pub fn name(self) -> &'static str {
        match self {
            ValueKind::F32 => "f32",
            ValueKind::F64 => "f64",
        }
    }
}

/// Smallest power of two strictly greater than `x` (the paper's
/// `nextPow2`), so capacity `nextPow2(D)−1 ≥ D` for all `D ≥ 1`.
#[inline]
pub fn next_pow2_above(x: u32) -> u32 {
    let mut p = 1u32;
    while p <= x {
        p <<= 1;
    }
    p
}

/// The shared hashtable buffers (`buf_k`, `buf_v`).
pub struct PerVertexTables {
    keys: Vec<u32>,
    // Stored as f64; writes round-trip through f32 when kind == F32 so
    // numerics match a real f32 buffer bit-for-bit.
    values: Vec<f64>,
    kind: ValueKind,
    strategy: ProbeStrategy,
    pub max_retries: u32,
}

/// One vertex's table view: `[offset, offset + p1)` of the buffers.
#[derive(Clone, Copy, Debug)]
pub struct TableRegion {
    pub offset: usize,
    /// Capacity `p1` (also the modulus of hash 1).
    pub p1: u32,
    /// Secondary prime-ish modulus `p2 > p1`.
    pub p2: u32,
}

impl TableRegion {
    /// Region for a vertex with CSR offset `o` and degree `d`
    /// (Fig 6: offset `2·O_i`, capacity `nextPow2(D_i) − 1`).
    pub fn for_vertex(o: usize, d: usize) -> Self {
        let p1 = (next_pow2_above(d as u32) - 1).max(1);
        // p2 must exceed p1: for p1 = 2^k − 1 that is 2^{k+1} − 1.
        let p2 = 2 * p1 + 1;
        Self { offset: 2 * o, p1, p2 }
    }
}

/// Result of an accumulate: probes used, or failure after MAX_RETRIES.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbeOutcome {
    pub probes: u32,
    pub ok: bool,
}

impl PerVertexTables {
    /// Allocate buffers of `2·e` slots (e = directed edge slots).
    pub fn new(e: usize, kind: ValueKind, strategy: ProbeStrategy) -> Self {
        Self {
            keys: vec![EMPTY; 2 * e],
            values: vec![0.0; 2 * e],
            kind,
            strategy,
            max_retries: 64,
        }
    }

    pub fn kind(&self) -> ValueKind {
        self.kind
    }

    pub fn strategy(&self) -> ProbeStrategy {
        self.strategy
    }

    /// Clear a region (hashtableClear); returns slots touched.
    pub fn clear(&mut self, r: TableRegion) -> u32 {
        for i in 0..r.p1 as usize {
            self.keys[r.offset + i] = EMPTY;
            self.values[r.offset + i] = 0.0;
        }
        r.p1
    }

    /// `H[k] += v` with the configured probe sequence (Algorithm 7).
    pub fn accumulate(&mut self, r: TableRegion, k: u32, v: f64) -> ProbeOutcome {
        let p1 = r.p1 as u64;
        let p2 = r.p2 as u64;
        let mut i = k as u64;
        let mut di = 1u64;
        for t in 0..self.max_retries {
            let s = r.offset + (i % p1) as usize;
            let cur = self.keys[s];
            if cur == k || cur == EMPTY {
                if cur == EMPTY {
                    self.keys[s] = k;
                }
                let add = match self.kind {
                    ValueKind::F64 => v,
                    ValueKind::F32 => ((self.values[s] as f32) + (v as f32)) as f64 - self.values[s],
                };
                self.values[s] += add;
                return ProbeOutcome { probes: t + 1, ok: true };
            }
            // Next slot per strategy.
            i = i.wrapping_add(di);
            di = match self.strategy {
                ProbeStrategy::Linear => 1,
                ProbeStrategy::Quadratic => di.wrapping_mul(2),
                ProbeStrategy::Double => (k as u64 % p2).max(1),
                ProbeStrategy::QuadraticDouble => di.wrapping_mul(2).wrapping_add(k as u64 % p2),
            };
        }
        // Fallback: linear sweep from the last position. Quadratic-style
        // step sequences over a 2^k−1 modulus can cycle on a slot subset;
        // a real deployment sizes tables so this is rare (§A.0.4 "avoided
        // by ensuring the hashtable is appropriately sized") — the sweep
        // keeps the simulation robust and charges the extra probes.
        for t in 0..r.p1 {
            let s = r.offset + (i.wrapping_add(t as u64) % p1) as usize;
            let cur = self.keys[s];
            if cur == k || cur == EMPTY {
                if cur == EMPTY {
                    self.keys[s] = k;
                }
                let add = match self.kind {
                    ValueKind::F64 => v,
                    ValueKind::F32 => ((self.values[s] as f32) + (v as f32)) as f64 - self.values[s],
                };
                self.values[s] += add;
                return ProbeOutcome { probes: self.max_retries + t + 1, ok: true };
            }
        }
        ProbeOutcome { probes: self.max_retries + r.p1, ok: false }
    }

    /// Visit `(key, value)` pairs of a region.
    pub fn for_each(&self, r: TableRegion, mut f: impl FnMut(u32, f64)) {
        for i in 0..r.p1 as usize {
            let k = self.keys[r.offset + i];
            if k != EMPTY {
                f(k, self.values[r.offset + i]);
            }
        }
    }

    /// Value for `key` (0 if absent), plus probes used to find it.
    pub fn get(&self, r: TableRegion, key: u32) -> (f64, u32) {
        let p1 = r.p1 as u64;
        let p2 = r.p2 as u64;
        let mut i = key as u64;
        let mut di = 1u64;
        for t in 0..self.max_retries {
            let s = r.offset + (i % p1) as usize;
            let cur = self.keys[s];
            if cur == key {
                return (self.values[s], t + 1);
            }
            if cur == EMPTY {
                return (0.0, t + 1);
            }
            i = i.wrapping_add(di);
            di = match self.strategy {
                ProbeStrategy::Linear => 1,
                ProbeStrategy::Quadratic => di.wrapping_mul(2),
                ProbeStrategy::Double => (key as u64 % p2).max(1),
                ProbeStrategy::QuadraticDouble => di.wrapping_mul(2).wrapping_add(key as u64 % p2),
            };
        }
        // Same fallback as `accumulate`.
        for t in 0..r.p1 {
            let s = r.offset + (i.wrapping_add(t as u64) % p1) as usize;
            let cur = self.keys[s];
            if cur == key {
                return (self.values[s], self.max_retries + t + 1);
            }
            if cur == EMPTY {
                return (0.0, self.max_retries + t + 1);
            }
        }
        (0.0, self.max_retries + r.p1)
    }

    /// Number of occupied slots in a region.
    pub fn len(&self, r: TableRegion) -> usize {
        (0..r.p1 as usize).filter(|&i| self.keys[r.offset + i] != EMPTY).count()
    }

    pub fn is_empty(&self, r: TableRegion) -> bool {
        self.len(r) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_pow2_above_matches_paper_capacity_rule() {
        assert_eq!(next_pow2_above(1), 2); // D=1 -> p1=1
        assert_eq!(next_pow2_above(2), 4); // D=2 -> p1=3
        assert_eq!(next_pow2_above(3), 4);
        assert_eq!(next_pow2_above(4), 8); // D=4 -> p1=7
        assert_eq!(next_pow2_above(7), 8);
        // Capacity >= degree for all D in 1..=4096.
        for d in 1u32..=4096 {
            assert!(next_pow2_above(d) - 1 >= d);
        }
    }

    #[test]
    fn region_layout_matches_fig6() {
        let r = TableRegion::for_vertex(10, 4);
        assert_eq!(r.offset, 20);
        assert_eq!(r.p1, 7);
        assert_eq!(r.p2, 15);
    }

    #[test]
    fn accumulate_and_get_all_strategies() {
        for s in ProbeStrategy::ALL {
            let mut t = PerVertexTables::new(64, ValueKind::F64, s);
            let r = TableRegion::for_vertex(0, 8); // p1 = 15
            for (k, v) in [(3u32, 1.0), (18, 2.0), (3, 0.5), (33, 4.0)] {
                assert!(t.accumulate(r, k, v).ok, "{s:?}");
            }
            // 3, 18, 33 all hash to 3 mod 15: collision chains exercised.
            assert_eq!(t.get(r, 3).0, 1.5, "{s:?}");
            assert_eq!(t.get(r, 18).0, 2.0, "{s:?}");
            assert_eq!(t.get(r, 33).0, 4.0, "{s:?}");
            assert_eq!(t.len(r), 3);
        }
    }

    #[test]
    fn fills_to_capacity_without_failure() {
        for s in ProbeStrategy::ALL {
            let mut t = PerVertexTables::new(64, ValueKind::F64, s);
            let r = TableRegion::for_vertex(0, 8); // p1 = 15
            for k in 0..8u32 {
                // Load factor ≈ 53% max (8 keys / 15 slots).
                let out = t.accumulate(r, k, 1.0);
                assert!(out.ok, "{s:?} failed at key {k}");
            }
            assert_eq!(t.len(r), 8);
        }
    }

    #[test]
    fn collision_chains_resolve() {
        // All keys hash to slot 1 mod 15; pure-quadratic (doubling) probing
        // cannot traverse 2^m−1 moduli from a single start slot, which is
        // exactly why the paper hybridizes it with double hashing.
        for s in [ProbeStrategy::Linear, ProbeStrategy::Double, ProbeStrategy::QuadraticDouble] {
            let mut t = PerVertexTables::new(64, ValueKind::F64, s);
            let r = TableRegion::for_vertex(0, 8); // p1 = 15
            let mut worst = 0;
            // All ≡ 1 (mod 15); chosen so the double-hash step stays
            // co-prime with p1 (a real deployment sizes p1/p2 so that
            // pathological steps are rare; Algorithm 7 tolerates the rest
            // via MAX_RETRIES).
            for (n, key) in [1u32, 16, 76, 106, 166, 256].into_iter().enumerate() {
                let out = t.accumulate(r, key, 1.0);
                assert!(out.ok, "{s:?} failed at key #{n} ({key})");
                worst = worst.max(out.probes);
            }
            assert_eq!(t.len(r), 6, "{s:?}");
            assert!(worst >= 2, "{s:?}: collisions expected");
        }
    }

    #[test]
    fn linear_probing_clusters_more_than_double() {
        // Adversarial: many keys mapping near slot 0. Linear probing's
        // clustering must cost more probes than double hashing.
        let mut probes = std::collections::HashMap::new();
        for s in [ProbeStrategy::Linear, ProbeStrategy::Double] {
            let mut t = PerVertexTables::new(2048, ValueKind::F64, s);
            let r = TableRegion::for_vertex(0, 512); // p1 = 1023
            let mut total = 0u64;
            for k in 0..400u32 {
                total += t.accumulate(r, k * 1023 + (k % 3), 1.0).probes as u64;
            }
            probes.insert(s, total);
        }
        assert!(
            probes[&ProbeStrategy::Linear] > probes[&ProbeStrategy::Double],
            "{probes:?}"
        );
    }

    #[test]
    fn f32_values_round_to_f32_precision() {
        let mut t32 = PerVertexTables::new(16, ValueKind::F32, ProbeStrategy::QuadraticDouble);
        let mut t64 = PerVertexTables::new(16, ValueKind::F64, ProbeStrategy::QuadraticDouble);
        let r = TableRegion::for_vertex(0, 4);
        // Accumulate values that lose precision in f32.
        for _ in 0..10 {
            t32.accumulate(r, 1, 0.1);
            t64.accumulate(r, 1, 0.1);
        }
        let v32 = t32.get(r, 1).0;
        let v64 = t64.get(r, 1).0;
        assert_ne!(v32, v64, "f32 path must differ from f64");
        let mut acc = 0f32;
        for _ in 0..10 {
            acc += 0.1f32;
        }
        assert!((v32 - acc as f64).abs() < 1e-12, "v32={v32} acc={acc}");
    }

    #[test]
    fn clear_resets_region_only() {
        let mut t = PerVertexTables::new(32, ValueKind::F64, ProbeStrategy::Linear);
        let r1 = TableRegion::for_vertex(0, 4); // offset 0, p1 7
        let r2 = TableRegion::for_vertex(8, 4); // offset 16, p1 7
        t.accumulate(r1, 2, 1.0);
        t.accumulate(r2, 2, 5.0);
        t.clear(r1);
        assert!(t.is_empty(r1));
        assert_eq!(t.get(r2, 2).0, 5.0);
    }

    #[test]
    fn overload_reports_failure() {
        let mut t = PerVertexTables::new(8, ValueKind::F64, ProbeStrategy::Linear);
        let r = TableRegion::for_vertex(0, 2); // p1 = 3 slots
        assert!(t.accumulate(r, 0, 1.0).ok);
        assert!(t.accumulate(r, 1, 1.0).ok);
        assert!(t.accumulate(r, 2, 1.0).ok);
        // Fourth distinct key cannot fit in 3 slots.
        assert!(!t.accumulate(r, 5, 1.0).ok);
    }
}
