//! Simulated ν-Louvain kernels (Algorithms 5–6).
//!
//! * [`move_iteration`] — one local-moving iteration.  Thread-per-vertex
//!   work (degree < `switch_move`) runs in lock-step warps of 32
//!   consecutive vertices (compute-all → apply-all: the swap-producing
//!   granularity); block-per-vertex work (high degree) runs one vertex
//!   per 128-thread block with intra-block parallel scanning.
//! * [`aggregate`] — the aggregation phase on per-community hashtables
//!   carved from the shared buffers, again kernel-partitioned by a
//!   degree switch (`switch_agg`).
//!
//! Every simulated operation charges cycles/bytes into [`KernelWork`]
//! (thread- and block-kernel work tracked separately so Figs 9/10 can
//! sweep the switch degree).

use super::device::{cycles, KernelWork};
use super::hashtable::{PerVertexTables, TableRegion};
use super::nulouvain::NuParams;
use super::warp::{warp_cycles, warps, LaneMove, WarpDecisions, WARP_SIZE};
use crate::graph::csr::HoleyCsr;
use crate::graph::Csr;
use crate::louvain::aggregation::sort_rows;
use crate::louvain::modularity::delta_modularity;
use crate::louvain::Counters;
use crate::parallel::scan::exclusive_scan_serial;

/// Output of one simulated local-moving iteration.
#[derive(Debug, Default)]
pub struct MoveIterationOutput {
    pub dq: f64,
    pub moves: u64,
    /// Thread-per-vertex kernel work.
    pub work_thread: KernelWork,
    /// Block-per-vertex kernel work.
    pub work_block: KernelWork,
    pub counters: Counters,
    /// Accumulates failed probes (table overflow; should stay 0).
    pub failed_probes: u64,
}

/// One lock-step local-moving iteration over all vertices.
#[allow(clippy::too_many_arguments)]
pub fn move_iteration(
    g: &Csr,
    memb: &mut [u32],
    k: &[f64],
    sigma: &mut [f64],
    affected: &mut [u32],
    tables: &mut PerVertexTables,
    params: &NuParams,
    m: f64,
    pick_less: bool,
) -> MoveIterationOutput {
    let n = g.num_vertices();
    let mut out = MoveIterationOutput::default();
    // A real iteration is several device-wide launches: clear, scan,
    // best-pick/apply, ΔQ reduction + the host sync reading ΔQ back.
    out.work_thread.launches = 3;
    out.work_block.launches = 3;
    let mut decisions = WarpDecisions::new();
    let mut lane_cycles = [0u64; WARP_SIZE];

    // Lock-step granularity only matters while several warps are
    // resident.  A graph smaller than a few warps runs effectively
    // serialized on real hardware, and an all-lanes-at-once apply on a
    // handful of super-vertices can collapse every community into one
    // (a state none of the lanes evaluated).  The paper's graphs never
    // shrink this far (τ_agg stops first); below the threshold we apply
    // moves immediately (async), which is also what eliminates the
    // pathology on device.
    let lockstep = n >= params.lockstep_min;

    // --- Thread-per-vertex kernel: warps of 32 consecutive vertices,
    // compute-then-apply (lock-step).
    for warp in warps(n) {
        decisions.clear();
        let mut lanes = 0usize;
        let mut any = false;
        for (lane, i) in warp.clone().enumerate() {
            lane_cycles[lane] = 0;
            lanes = lane + 1;
            let d = g.degree(i);
            if d == 0 || d >= params.switch_move {
                continue; // idle lane (other kernel or isolated)
            }
            if affected[i] == 0 {
                continue; // pruned
            }
            affected[i] = 0;
            any = true;
            let (cyc, best) =
                scan_and_pick(g, memb, k, sigma, tables, i, m, pick_less, false, &mut out);
            lane_cycles[lane] = cyc;
            if let Some(mv) = best {
                if lockstep {
                    decisions.push(mv);
                } else {
                    apply_move(g, memb, k, sigma, affected, mv, &mut out);
                }
            }
            out.counters.vertices_processed += 1;
        }
        if any {
            out.work_thread.warps += 1;
            out.work_thread.warp_cycles += warp_cycles(&lane_cycles[..lanes]);
        }
        // Apply phase: all lanes commit against the state they all read.
        for mv in decisions.drain() {
            apply_move(g, memb, k, sigma, affected, mv, &mut out);
        }
    }

    // --- Block-per-vertex kernel: one vertex per block, applied
    // immediately (high-degree vertices are asymmetric; swap cycles
    // come from the lock-step low-degree warps).
    for i in 0..n {
        let d = g.degree(i);
        if d < params.switch_move {
            continue;
        }
        if affected[i] == 0 {
            out.counters.vertices_pruned += 1;
            continue;
        }
        affected[i] = 0;
        let (cyc, best) =
            scan_and_pick(g, memb, k, sigma, tables, i, m, pick_less, true, &mut out);
        // Block of `block_size` threads: parallel scan divides edge work,
        // atomics serialize on hot table slots (charged in scan_and_pick
        // via probe counts; here we divide the data-parallel share).
        let block_warps = (params.block_size / WARP_SIZE as u64).max(1);
        let par_cyc = cyc / params.block_size + (cyc % params.block_size != 0) as u64;
        out.work_block.warps += block_warps;
        out.work_block.warp_cycles += par_cyc.max(1) * block_warps;
        out.counters.vertices_processed += 1;
        if let Some(mv) = best {
            apply_move(g, memb, k, sigma, affected, mv, &mut out);
        }
    }

    // Prune accounting for the thread kernel happens inside the warp
    // loop; count of skipped lanes is derivable from processed.
    out
}

/// scanCommunities + best-community selection for one vertex.
/// Returns (cycles, Some(move) if an admissible improving move exists).
#[allow(clippy::too_many_arguments)]
fn scan_and_pick(
    g: &Csr,
    memb: &[u32],
    k: &[f64],
    sigma: &[f64],
    tables: &mut PerVertexTables,
    i: usize,
    m: f64,
    pick_less: bool,
    is_block: bool,
    out: &mut MoveIterationOutput,
) -> (u64, Option<LaneMove>) {
    let d = g.degree(i);
    let region = TableRegion::for_vertex(g.offsets[i], d);
    let mut cyc = tables.clear(region) as u64 * cycles::CLEAR;
    let (ts, ws) = g.edges(i);
    let ci = memb[i];
    for (t, w) in ts.iter().zip(ws) {
        if *t as usize == i {
            continue;
        }
        let pj = tables.accumulate(region, memb[*t as usize], *w as f64);
        if !pj.ok {
            out.failed_probes += 1;
        }
        cyc += cycles::EDGE_SCAN + pj.probes as u64 * cycles::PROBE + cycles::ATOMIC;
        out.counters.table_ops += 1;
    }
    out.counters.edges_scanned_move += d as u64;
    // Bytes: CSR slot reads coalesce (8 B), but the membership gather and
    // hashtable probes are scattered — each costs a full 32 B transaction
    // on HBM (the uncoalesced-access reality that keeps GPU Louvain
    // memory-bound; calibrated against Fig 13's parity result).
    let kernel_bytes = d as u64 * (8 + 32 + 64);

    let (k_to_d, probes_d) = tables.get(region, ci);
    cyc += probes_d as u64 * cycles::PROBE;
    let sigma_d = sigma[ci as usize];
    let k_i = k[i];

    let mut best: Option<LaneMove> = None;
    let mut best_dq = 0.0f64;
    tables.for_each(region, |c, k_to_c| {
        if c == ci {
            return;
        }
        if pick_less && c >= ci {
            return; // Algorithm 5 line 24
        }
        let dq = delta_modularity(k_to_c, k_to_d, k_i, sigma[c as usize], sigma_d, m);
        if dq > best_dq {
            best_dq = dq;
            best = Some(LaneMove { vertex: i, to: c, dq });
        }
    });
    cyc += region.p1 as u64 * cycles::BEST_PICK;

    if is_block {
        out.work_block.bytes += kernel_bytes;
    } else {
        out.work_thread.bytes += kernel_bytes;
    }
    (cyc, best)
}

/// Commit a move: Σ updates (atomics), membership store, neighbour marks.
fn apply_move(
    g: &Csr,
    memb: &mut [u32],
    k: &[f64],
    sigma: &mut [f64],
    affected: &mut [u32],
    mv: LaneMove,
    out: &mut MoveIterationOutput,
) {
    let i = mv.vertex;
    let d = memb[i];
    if d == mv.to {
        return;
    }
    sigma[d as usize] -= k[i];
    sigma[mv.to as usize] += k[i];
    memb[i] = mv.to;
    out.dq += mv.dq;
    out.moves += 1;
    out.work_thread.warp_cycles += 2 * cycles::ATOMIC;
    for (t, _) in g.neighbours(i) {
        affected[t as usize] = 1;
    }
    out.work_thread.bytes += g.degree(i) as u64 * 4;
}

/// Output of the simulated aggregation phase.
pub struct AggregateOutput {
    pub graph: Csr,
    pub work_thread: KernelWork,
    pub work_block: KernelWork,
    pub counters: Counters,
}

/// Simulated aggregation (Algorithm 6): community-vertices CSR, then
/// per-community hashtable merge into a holey CSR.
pub fn aggregate(
    g: &Csr,
    memb: &[u32],
    n_comm: usize,
    tables: &mut PerVertexTables,
    params: &NuParams,
) -> AggregateOutput {
    let n = g.num_vertices();
    let mut counters = Counters::default();
    let mut work_thread = KernelWork { launches: 2, ..Default::default() };
    let mut work_block = KernelWork { launches: 1, ..Default::default() };

    // countCommunityVertices + exclusiveScan (charged as one thread kernel).
    let mut counts = vec![0usize; n_comm + 1];
    for &c in memb {
        counts[c as usize] += 1;
    }
    exclusive_scan_serial(&mut counts);
    let comm_vertices = HoleyCsr::with_offsets(counts);
    for i in 0..n {
        comm_vertices.push_edge(memb[i] as usize, i as u32, 0.0);
    }
    work_thread.warps += (n as u64).div_ceil(WARP_SIZE as u64);
    work_thread.warp_cycles += (n as u64) * 2;
    work_thread.bytes += n as u64 * 8;

    // communityTotalDegree + exclusiveScan -> holey CSR offsets.
    let mut tot_deg = vec![0usize; n_comm + 1];
    for i in 0..n {
        tot_deg[memb[i] as usize] += g.degree(i);
    }
    // Community hashtable regions reuse the CSR offset rule (offset 2·O_c).
    let comm_offsets: Vec<usize> = {
        let mut t = tot_deg.clone();
        exclusive_scan_serial(&mut t);
        t
    };
    exclusive_scan_serial(&mut tot_deg);
    let holey = HoleyCsr::with_offsets(tot_deg);

    // Per-community merge, kernel-partitioned by total degree.
    let mut lane_cycles = [0u64; WARP_SIZE];
    for warp in warps(n_comm) {
        let mut lanes = 0usize;
        let mut any_thread = false;
        for (lane, c) in warp.clone().enumerate() {
            lane_cycles[lane] = 0;
            lanes = lane + 1;
            let members = comm_vertices.edges(c).0;
            if members.is_empty() {
                continue;
            }
            let deg_c = comm_offsets[c + 1] - comm_offsets[c];
            if deg_c == 0 {
                continue; // isolated members only: no edges to merge
            }
            let is_block = deg_c >= params.switch_agg;
            let region = TableRegion::for_vertex(comm_offsets[c], deg_c);
            let mut cyc = tables.clear(region) as u64 * cycles::CLEAR;
            for &i in members {
                for (j, w) in g.neighbours(i as usize) {
                    let pr = tables.accumulate(region, memb[j as usize], w as f64);
                    cyc += cycles::EDGE_SCAN + pr.probes as u64 * cycles::PROBE + cycles::ATOMIC;
                    counters.table_ops += 1;
                }
                counters.edges_scanned_agg += g.degree(i as usize) as u64;
            }
            let mut row_len = 0u64;
            tables.for_each(region, |dcomm, w| {
                holey.push_edge(c, dcomm, w as f32);
                row_len += 1;
            });
            cyc += row_len * cycles::ATOMIC;
            let bytes = (deg_c as u64) * (8 + 32 + 64) + row_len * 32;
            if is_block {
                let bw = (params.block_size / WARP_SIZE as u64).max(1);
                work_block.warps += bw;
                work_block.warp_cycles += (cyc / params.block_size).max(1) * bw;
                work_block.bytes += bytes;
            } else {
                any_thread = true;
                lane_cycles[lane] = cyc;
                work_thread.bytes += bytes;
            }
        }
        if any_thread {
            work_thread.warps += 1;
            work_thread.warp_cycles += warp_cycles(&lane_cycles[..lanes]);
        }
    }

    let mut graph = holey.compact();
    sort_rows(&mut graph);
    AggregateOutput { graph, work_thread, work_block, counters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::hashtable::{ProbeStrategy, ValueKind};
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::modularity::modularity;

    fn nu_params() -> NuParams {
        // Tests exercise lock-step semantics even on tiny graphs.
        NuParams { lockstep_min: 0, ..NuParams::default() }
    }

    fn init(g: &Csr) -> (Vec<u32>, Vec<f64>, Vec<f64>, Vec<u32>, PerVertexTables) {
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n as u32).collect();
        let k = g.vertex_weights();
        let sigma = k.clone();
        let affected = vec![1u32; n];
        let tables =
            PerVertexTables::new(g.num_edges().max(1), ValueKind::F32, ProbeStrategy::QuadraticDouble);
        (memb, k, sigma, affected, tables)
    }

    #[test]
    fn symmetric_pair_swaps_without_pick_less() {
        // Two vertices 0,1 connected to each other and each to both of a
        // pair of anchors — engineered so each prefers the *other's*
        // community while anchors hold still. In lock-step they swap.
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let (mut memb, k, mut sigma, mut aff, mut tables) = init(&g);
        let m = g.total_weight();
        let p = nu_params();
        // Iteration 1 without pick-less: both see the other's community
        // and both move -> memberships swap, state cycles.
        let before = memb.clone();
        let o1 = move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p, m, false);
        assert_eq!(o1.moves, 2, "both lanes moved in lock-step");
        assert_eq!(memb, vec![1, 0], "swapped");
        aff.iter_mut().for_each(|a| *a = 1);
        let o2 = move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p, m, false);
        assert_eq!(o2.moves, 2);
        assert_eq!(memb, before, "swapped back: the §4.3.1 cycle");
    }

    #[test]
    fn pick_less_breaks_the_swap() {
        let g = GraphBuilder::new(2).edge(0, 1, 1.0).build_undirected();
        let (mut memb, k, mut sigma, mut aff, mut tables) = init(&g);
        let m = g.total_weight();
        let p = nu_params();
        let o = move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p, m, true);
        // Only the higher-id vertex may move down; vertex 0 is blocked.
        assert_eq!(o.moves, 1);
        assert_eq!(memb, vec![0, 0]);
    }

    #[test]
    fn moves_have_positive_dq_and_sigma_consistent() {
        let g = generate(GraphFamily::Web, 9, 5);
        let (mut memb, k, mut sigma, mut aff, mut tables) = init(&g);
        let m = g.total_weight();
        let p = nu_params();
        let o = move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p, m, false);
        assert!(o.dq > 0.0);
        assert!(o.moves > 0);
        assert_eq!(o.failed_probes, 0, "hashtables must never overflow");
        let n = g.num_vertices();
        let mut want = vec![0f64; n];
        for v in 0..n {
            want[memb[v] as usize] += k[v];
        }
        for c in 0..n {
            assert!((sigma[c] - want[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn kernel_partition_by_switch_degree() {
        let g = generate(GraphFamily::Web, 9, 7);
        let (mut memb, k, mut sigma, mut aff, mut tables) = init(&g);
        let m = g.total_weight();
        // switch = 1: everything block-per-vertex.
        let p_all_block = NuParams { switch_move: 1, ..nu_params() };
        let o = move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p_all_block, m, false);
        assert_eq!(o.work_thread.warps, 0);
        assert!(o.work_block.warps > 0);
        // switch = huge: everything thread-per-vertex.
        let (mut memb, k, mut sigma, mut aff, mut tables) = init(&g);
        let p_all_thread = NuParams { switch_move: usize::MAX, ..nu_params() };
        let o = move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p_all_thread, m, false);
        assert!(o.work_thread.warps > 0);
        assert_eq!(o.work_block.warps, 0);
    }

    #[test]
    fn aggregate_preserves_total_weight_and_matches_cpu() {
        use crate::louvain::aggregation::aggregate_csr;
        use crate::louvain::hashtable::TablePool;
        use crate::louvain::params::{LouvainParams, TableKind};
        let g = generate(GraphFamily::Social, 9, 9);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n).map(|v| (v % 37) as u32).collect();
        let mut tables =
            PerVertexTables::new(g.num_edges(), ValueKind::F64, ProbeStrategy::QuadraticDouble);
        let out = aggregate(&g, &memb, 37, &mut tables, &nu_params());
        out.graph.validate().unwrap();
        assert!((out.graph.total_weight() - g.total_weight()).abs() < 1e-5 * g.total_weight());
        // Cross-check against the CPU aggregation.
        let pool = TablePool::new(TableKind::FarKv, 37, 1);
        let cpu = aggregate_csr(&g, &memb, 37, &pool, &LouvainParams::default());
        assert_eq!(out.graph.offsets, cpu.graph.offsets);
        assert_eq!(out.graph.targets, cpu.graph.targets);
        for (a, b) in out.graph.weights.iter().zip(&cpu.graph.weights) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn iterating_improves_modularity() {
        let g = generate(GraphFamily::Web, 9, 11);
        let (mut memb, k, mut sigma, mut aff, mut tables) = init(&g);
        let m = g.total_weight();
        let p = nu_params();
        let q0 = modularity(&g, &memb);
        for li in 0..5 {
            let pl = (li + p.rho / 2) % p.rho == 0;
            move_iteration(&g, &mut memb, &k, &mut sigma, &mut aff, &mut tables, &p, m, pl);
        }
        let q1 = modularity(&g, &memb);
        assert!(q1 > q0 + 0.2, "q0={q0} q1={q1}");
    }
}
