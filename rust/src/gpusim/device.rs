//! A100-like device cost model.
//!
//! The simulator's kernels accumulate *work counters* (warp-cycles,
//! bytes touched, atomic conflicts, kernel launches); this model maps
//! them to estimated device time.  Absolute numbers are calibration
//! constants, but the *shape* effects the paper reports all emerge
//! structurally:
//!
//! * throughput phase — many resident warps: time ≈ cycles / (SM·slots);
//! * occupancy collapse — few active warps in late passes: time stops
//!   scaling with work and launch overhead dominates (§5.2.3's "reduced
//!   workload and parallelism in later passes");
//! * memory-bound phase — bytes / bandwidth when that exceeds compute;
//! * OOM gates — footprint model vs the 80 GB budget (§5.2.1/5.2.2).

/// Cycle costs of simulated operations (coarse A100-class numbers).
pub mod cycles {
    /// Per neighbour slot scanned (load edge + membership).
    pub const EDGE_SCAN: u64 = 6;
    /// Per hashtable probe step (serially dependent scattered load).
    pub const PROBE: u64 = 25;
    /// Per atomic CAS/add including same-slot contention serialization
    /// (lanes of a warp accumulating into one community's slot).
    pub const ATOMIC: u64 = 120;
    /// Per hashtable slot cleared.
    pub const CLEAR: u64 = 2;
    /// Per candidate evaluated in the best-pick reduction.
    pub const BEST_PICK: u64 = 6;
}

/// Work accumulated by a simulated kernel invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelWork {
    /// Σ over warps of per-warp cycles (lane-max within each warp).
    pub warp_cycles: u64,
    /// Number of warp-equivalents launched.
    pub warps: u64,
    /// Global-memory bytes moved.
    pub bytes: u64,
    /// Kernel launches.
    pub launches: u64,
}

impl KernelWork {
    pub fn merge(&mut self, o: &KernelWork) {
        self.warp_cycles += o.warp_cycles;
        self.warps += o.warps;
        self.bytes += o.bytes;
        self.launches += o.launches;
    }
}

/// The device model (defaults ≈ NVIDIA A100 SXM, §5.1.1).
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    pub sms: u64,
    /// Resident warp slots per SM.
    pub warp_slots_per_sm: u64,
    pub warp_size: u64,
    pub clock_ghz: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Fixed overhead per kernel launch, ns.
    pub launch_ns: u64,
    /// Device memory budget, bytes (80 GB on the paper's A100).
    pub memory_bytes: u64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        Self {
            sms: 108,
            warp_slots_per_sm: 64,
            warp_size: 32,
            clock_ghz: 1.41,
            // Effective HBM bandwidth for the scatter-dominated access
            // stream of Louvain (peak 1935 GB/s; scattered 32 B
            // transactions achieve ~35-40% of peak on A100-class parts).
            mem_bw_gbps: 700.0,
            launch_ns: 4_000,
            memory_bytes: 80_000_000_000,
        }
    }
}

impl DeviceModel {
    /// Estimated time of one kernel invocation, in nanoseconds.
    pub fn kernel_ns(&self, w: &KernelWork) -> u64 {
        if w.warps == 0 {
            return w.launches * self.launch_ns;
        }
        // Occupancy: effective parallelism is capped by resident slots
        // AND by the actual number of warps (the late-pass collapse).
        let slots = self.sms * self.warp_slots_per_sm;
        let effective = w.warps.min(slots).max(1);
        let compute_ns = (w.warp_cycles as f64 / effective as f64 / self.clock_ghz) as u64;
        let memory_ns = (w.bytes as f64 / self.mem_bw_gbps) as u64; // GB/s == B/ns
        compute_ns.max(memory_ns) + w.launches * self.launch_ns
    }

    /// Device occupancy of an invocation in `[0, 1]`.
    pub fn occupancy(&self, w: &KernelWork) -> f64 {
        let slots = (self.sms * self.warp_slots_per_sm) as f64;
        (w.warps as f64 / slots).min(1.0)
    }

    /// ν-Louvain device footprint for a graph with `n` vertices and `e`
    /// directed edge slots (per §4.3.2: CSR + double-buffered
    /// super-vertex CSR + the two `2|E|` hashtable buffers + O(N)
    /// vectors).
    pub fn nu_louvain_bytes(&self, n: u64, e: u64) -> u64 {
        let csr = n * 8 + e * 8; // offsets + (target, weight)
        let csr_next = csr; // double buffer for aggregation
        let tables = 2 * e * (4 + 4); // buf_k (u32) + buf_v (f32) of size 2E
        let vectors = n * (4 + 8 + 8 + 4); // C, K, Σ, flags
        csr + csr_next + tables + vectors
    }

    /// cuGraph-like footprint (higher constant per edge: RAPIDS
    /// primitives keep additional edge-partition copies; calibrated so
    /// the paper's five OOM graphs OOM and the rest fit).
    pub fn cugraph_bytes(&self, n: u64, e: u64) -> u64 {
        n * 48 + e * 68
    }

    /// Does a ν-Louvain run on (n, e) fit in device memory?
    pub fn nu_louvain_fits(&self, n: u64, e: u64) -> bool {
        self.nu_louvain_bytes(n, e) <= self.memory_bytes
    }

    pub fn cugraph_fits(&self, n: u64, e: u64) -> bool {
        self.cugraph_bytes(n, e) <= self.memory_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_phase_scales_with_work() {
        let d = DeviceModel::default();
        let w1 = KernelWork { warp_cycles: 1_000_000, warps: 100_000, bytes: 0, launches: 1 };
        let w2 = KernelWork { warp_cycles: 2_000_000, warps: 100_000, bytes: 0, launches: 1 };
        assert!(d.kernel_ns(&w2) > d.kernel_ns(&w1));
    }

    #[test]
    fn occupancy_collapse_in_small_kernels() {
        let d = DeviceModel::default();
        // Same cycles-per-warp, 100× fewer warps: time barely drops once
        // below the slot count (108·64 = 6912 warps).
        let big = KernelWork { warp_cycles: 6912 * 1000, warps: 6912, bytes: 0, launches: 1 };
        let small = KernelWork { warp_cycles: 69 * 1000, warps: 69, bytes: 0, launches: 1 };
        let t_big = d.kernel_ns(&big);
        let t_small = d.kernel_ns(&small);
        // 100x less work but NOT 100x faster (only ~1x: same per-warp depth).
        assert!(t_small * 50 > t_big, "t_small={t_small} t_big={t_big}");
        assert!(d.occupancy(&small) < 0.011);
        assert!((d.occupancy(&big) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn launch_overhead_floors_empty_kernels() {
        let d = DeviceModel::default();
        let w = KernelWork { warp_cycles: 0, warps: 0, bytes: 0, launches: 3 };
        assert_eq!(d.kernel_ns(&w), 3 * d.launch_ns);
    }

    #[test]
    fn memory_bound_kernels_follow_bandwidth() {
        let d = DeviceModel::default();
        let w = KernelWork { warp_cycles: 1, warps: 7000, bytes: 700_000_000, launches: 0 };
        // 0.7 GB at 700 GB/s effective = 1 ms.
        assert_eq!(d.kernel_ns(&w), 1_000_000);
    }

    #[test]
    fn oom_gates_match_paper_table() {
        let d = DeviceModel::default();
        // Paper |E| (directed slots) per graph; ν-Louvain OOMs only on
        // sk-2005, cuGraph on arabic-2005 and larger web graphs.
        let sk2005 = (50_600_000u64, 3_800_000_000u64);
        let it2004 = (41_300_000u64, 2_190_000_000u64);
        let arabic = (22_700_000u64, 1_210_000_000u64);
        let uk2002 = (18_500_000u64, 567_000_000u64);
        assert!(!d.nu_louvain_fits(sk2005.0, sk2005.1), "nu must OOM on sk-2005");
        assert!(d.nu_louvain_fits(it2004.0, it2004.1), "nu must fit it-2004");
        assert!(!d.cugraph_fits(arabic.0, arabic.1), "cuGraph must OOM on arabic-2005");
        assert!(d.cugraph_fits(uk2002.0, uk2002.1), "cuGraph must fit uk-2002");
    }

    #[test]
    fn merge_accumulates() {
        let mut a = KernelWork { warp_cycles: 1, warps: 2, bytes: 3, launches: 4 };
        a.merge(&KernelWork { warp_cycles: 10, warps: 20, bytes: 30, launches: 40 });
        assert_eq!(a.warp_cycles, 11);
        assert_eq!(a.warps, 22);
        assert_eq!(a.bytes, 33);
        assert_eq!(a.launches, 44);
    }
}
