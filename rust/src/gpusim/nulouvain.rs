//! ν-Louvain driver (Algorithm 4) on the GPU simulator.
//!
//! Same pass structure as GVE-Louvain, with the GPU-specific pieces of
//! §4.3: Pick-Less mode every ρ iterations (`(l_i + ρ/2) mod ρ == 0`,
//! Algorithm 5 line 4), per-vertex open-addressing hashtables, kernel
//! partitioning by switch degree, and the device cost model that turns
//! accumulated kernel work into estimated A100 time.

use super::device::{DeviceModel, KernelWork};
use super::hashtable::{PerVertexTables, ProbeStrategy, ValueKind};
use super::kernels::{aggregate, move_iteration};
use crate::graph::Csr;
use crate::louvain::dendrogram;
use crate::louvain::modularity::modularity;
use crate::louvain::renumber::renumber_communities;
use crate::louvain::Counters;
use std::time::Instant;

/// Parameters of a ν-Louvain run (§4.3 list: defaults are the adopted
/// configuration — PL4, switch 64/128, quadratic-double, f32 values).
#[derive(Clone, Copy, Debug)]
pub struct NuParams {
    pub max_passes: usize,
    pub max_iterations: usize,
    pub tolerance: f64,
    pub tolerance_drop: f64,
    pub aggregation_tolerance: f64,
    /// Pick-Less period ρ (Fig 5: 4 adopted; 0 disables PL entirely).
    pub rho: usize,
    /// Thread-vs-block switch degree, local-moving (Fig 9: 64).
    pub switch_move: usize,
    /// Thread-vs-block switch degree, aggregation (Fig 10: 128).
    pub switch_agg: usize,
    pub probe: ProbeStrategy,
    pub values: ValueKind,
    /// Threads per block for the block-per-vertex kernels.
    pub block_size: u64,
    /// Below this many vertices, lock-step apply degrades to immediate
    /// (async) apply — see `kernels::move_iteration` for the rationale.
    pub lockstep_min: usize,
    pub device: DeviceModel,
}

impl Default for NuParams {
    fn default() -> Self {
        Self {
            max_passes: 10,
            max_iterations: 20,
            tolerance: 0.01,
            tolerance_drop: 10.0,
            aggregation_tolerance: 0.8,
            rho: 4,
            switch_move: 64,
            switch_agg: 128,
            probe: ProbeStrategy::QuadraticDouble,
            values: ValueKind::F32,
            block_size: 128,
            lockstep_min: 128,
            device: DeviceModel::default(),
        }
    }
}

/// Is Pick-Less mode active in iteration `li` (Algorithm 5 line 4)?
#[inline]
pub fn pick_less_active(li: usize, rho: usize) -> bool {
    rho != 0 && (li + rho / 2) % rho == 0
}

/// Per-pass statistics with estimated device time per phase.
#[derive(Clone, Debug, Default)]
pub struct NuPassStats {
    pub vertices: usize,
    pub edges: usize,
    pub iterations: usize,
    pub communities: usize,
    /// Estimated device time of this pass's local-moving kernels (ns).
    pub move_est_ns: u64,
    /// Estimated device time of this pass's aggregation kernels (ns).
    pub agg_est_ns: u64,
    /// Estimated other device/host work (init, renumber, dendrogram).
    pub other_est_ns: u64,
    pub dq: f64,
    /// Mean occupancy of this pass's local-moving launches.
    pub occupancy: f64,
}

/// Result of a ν-Louvain run.
#[derive(Debug, Default)]
pub struct NuResult {
    pub membership: Vec<u32>,
    pub modularity: f64,
    pub num_communities: usize,
    pub passes: usize,
    /// Estimated total device time (the simulator's "GPU runtime").
    pub est_gpu_ns: u64,
    /// Host wall time of the simulation itself (not the GPU estimate).
    pub sim_wall_ns: u64,
    pub pass_stats: Vec<NuPassStats>,
    pub counters: Counters,
    /// Total kernel work (for roofline-style reporting).
    pub work: KernelWork,
    /// Would this run fit on the modeled device?
    pub fits_memory: bool,
}

impl NuResult {
    pub fn phase_split(&self) -> (f64, f64, f64) {
        let mv: u64 = self.pass_stats.iter().map(|p| p.move_est_ns).sum();
        let ag: u64 = self.pass_stats.iter().map(|p| p.agg_est_ns).sum();
        let tot = self.est_gpu_ns.max(1) as f64;
        (mv as f64 / tot, ag as f64 / tot, ((tot - mv as f64 - ag as f64) / tot).max(0.0))
    }

    pub fn first_pass_fraction(&self) -> f64 {
        let f = self
            .pass_stats
            .first()
            .map(|p| p.move_est_ns + p.agg_est_ns + p.other_est_ns)
            .unwrap_or(0) as f64;
        f / self.est_gpu_ns.max(1) as f64
    }
}

/// The ν-Louvain algorithm object.
pub struct NuLouvain {
    pub params: NuParams,
}

impl NuLouvain {
    pub fn new(params: NuParams) -> Self {
        Self { params }
    }

    /// Run on `g`.
    pub fn run(&self, g: &Csr) -> NuResult {
        let p = &self.params;
        let dev = &p.device;
        let t_start = Instant::now();
        let n0 = g.num_vertices();
        let m = g.total_weight();
        let mut result = NuResult {
            membership: (0..n0 as u32).collect(),
            fits_memory: dev.nu_louvain_fits(n0 as u64, g.num_edges() as u64),
            ..Default::default()
        };
        if n0 == 0 || m == 0.0 {
            result.num_communities = n0;
            return result;
        }

        let mut owned: Option<Csr> = None;
        let mut tau = p.tolerance;

        for pass in 0..p.max_passes {
            let gp: &Csr = owned.as_ref().unwrap_or(g);
            let np = gp.num_vertices();

            let k: Vec<f64> = gp.vertex_weights();
            let mut sigma = k.clone();
            let mut membership: Vec<u32> = (0..np as u32).collect();
            let mut affected = vec![1u32; np];
            let mut tables = PerVertexTables::new(gp.num_edges().max(1), p.values, p.probe);
            // Init kernels: vertexWeights + resets (memory-bound sweep).
            let init_work = KernelWork {
                warp_cycles: (gp.num_edges() as u64) * 2,
                warps: (np as u64).div_ceil(32),
                bytes: gp.num_edges() as u64 * 8 + np as u64 * 24,
                launches: 3,
            };
            let mut stats = NuPassStats {
                vertices: np,
                edges: gp.num_edges(),
                other_est_ns: dev.kernel_ns(&init_work),
                ..Default::default()
            };
            result.work.merge(&init_work);

            // Local-moving (Algorithm 5).
            let mut iterations = 0usize;
            let mut occupancy_sum = 0.0;
            for li in 0..p.max_iterations {
                let pl = pick_less_active(li, p.rho);
                let out = move_iteration(
                    gp, &mut membership, &k, &mut sigma, &mut affected, &mut tables, p, m, pl,
                );
                iterations += 1;
                stats.dq += out.dq;
                stats.move_est_ns += dev.kernel_ns(&out.work_thread) + dev.kernel_ns(&out.work_block);
                occupancy_sum += dev.occupancy(&out.work_thread);
                result.work.merge(&out.work_thread);
                result.work.merge(&out.work_block);
                result.counters.merge(&out.counters);
                if out.dq <= tau {
                    break;
                }
            }
            stats.iterations = iterations;
            stats.occupancy = occupancy_sum / iterations.max(1) as f64;

            let n_comm = renumber_communities(&mut membership);
            stats.communities = n_comm;
            let converged = iterations <= 1;
            let low_shrink = (n_comm as f64) / (np as f64) > p.aggregation_tolerance;
            dendrogram::lookup(&mut result.membership, &membership);

            if converged || low_shrink || pass + 1 == p.max_passes {
                result.pass_stats.push(stats);
                result.passes = pass + 1;
                break;
            }

            // Aggregation (Algorithm 6).
            let agg = aggregate(gp, &membership, n_comm, &mut tables, p);
            stats.agg_est_ns = dev.kernel_ns(&agg.work_thread) + dev.kernel_ns(&agg.work_block);
            result.work.merge(&agg.work_thread);
            result.work.merge(&agg.work_block);
            result.counters.merge(&agg.counters);
            owned = Some(agg.graph);
            tau /= p.tolerance_drop;

            result.pass_stats.push(stats);
            result.passes = pass + 1;
        }

        result.num_communities = renumber_communities(&mut result.membership);
        result.modularity = modularity(g, &result.membership);
        result.est_gpu_ns = result
            .pass_stats
            .iter()
            .map(|s| s.move_est_ns + s.agg_est_ns + s.other_est_ns)
            .sum();
        result.sim_wall_ns = t_start.elapsed().as_nanos() as u64;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::{gve::GveLouvain, params::LouvainParams};

    #[test]
    fn pick_less_schedule_matches_algorithm5() {
        // ρ=4: PL active when (li + 2) % 4 == 0 -> li = 2, 6, 10, ...
        let active: Vec<usize> = (0..12).filter(|&li| pick_less_active(li, 4)).collect();
        assert_eq!(active, vec![2, 6, 10]);
        // ρ=0 disables.
        assert!((0..20).all(|li| !pick_less_active(li, 0)));
    }

    #[test]
    fn nu_louvain_finds_communities_on_all_families() {
        for f in GraphFamily::ALL {
            let g = generate(f, 10, 3);
            let out = NuLouvain::new(NuParams::default()).run(&g);
            assert!(out.modularity > 0.3, "{f:?}: q={}", out.modularity);
            assert!(out.num_communities > 1, "{f:?}");
            assert!(out.est_gpu_ns > 0);
            assert!(out.fits_memory);
        }
    }

    #[test]
    fn nu_quality_close_to_gve() {
        for f in [GraphFamily::Web, GraphFamily::Road] {
            let g = generate(f, 10, 13);
            let nu = NuLouvain::new(NuParams::default()).run(&g);
            let gve = GveLouvain::new(LouvainParams::default()).run(&g);
            // Paper Fig 13c: ν-Louvain ~0.5% lower modularity on average.
            assert!(
                nu.modularity > gve.modularity - 0.08,
                "{f:?}: nu={} gve={}",
                nu.modularity,
                gve.modularity
            );
        }
    }

    #[test]
    fn disabling_pick_less_hurts_convergence_or_quality() {
        // Road lattices have exactly the symmetric adjacent-id pairs that
        // trigger swap cycles (§4.3.1).
        let g = generate(GraphFamily::Road, 10, 5);
        let with_pl = NuLouvain::new(NuParams::default()).run(&g);
        let no_pl = NuLouvain::new(NuParams { rho: 0, ..Default::default() }).run(&g);
        let iters = |r: &NuResult| r.pass_stats.iter().map(|p| p.iterations).sum::<usize>();
        assert!(
            iters(&no_pl) > iters(&with_pl) || no_pl.modularity < with_pl.modularity,
            "no-PL: iters={} q={}; PL4: iters={} q={}",
            iters(&no_pl),
            no_pl.modularity,
            iters(&with_pl),
            with_pl.modularity
        );
    }

    #[test]
    fn later_passes_have_lower_occupancy() {
        let g = generate(GraphFamily::Road, 12, 7);
        let out = NuLouvain::new(NuParams::default()).run(&g);
        assert!(out.passes >= 2, "need multiple passes, got {}", out.passes);
        let first = out.pass_stats.first().unwrap().occupancy;
        let last = out.pass_stats.last().unwrap().occupancy;
        assert!(last <= first, "occupancy should collapse: first={first} last={last}");
    }

    #[test]
    fn est_time_accounts_all_phases() {
        let g = generate(GraphFamily::Web, 10, 9);
        let out = NuLouvain::new(NuParams::default()).run(&g);
        let (mv, ag, other) = out.phase_split();
        assert!((mv + ag + other - 1.0).abs() < 1e-6);
        assert!(mv > 0.0);
        assert!(out.first_pass_fraction() > 0.3);
    }

    #[test]
    fn f32_and_f64_values_agree_on_quality() {
        let g = generate(GraphFamily::Web, 10, 11);
        let q32 = NuLouvain::new(NuParams { values: ValueKind::F32, ..Default::default() }).run(&g).modularity;
        let q64 = NuLouvain::new(NuParams { values: ValueKind::F64, ..Default::default() }).run(&g).modularity;
        // Fig 8: f32 maintains community quality.
        assert!((q32 - q64).abs() < 0.02, "q32={q32} q64={q64}");
    }

    #[test]
    fn probe_strategies_same_communities_different_probes() {
        let g = generate(GraphFamily::Social, 9, 17);
        let mut qualities = Vec::new();
        for s in ProbeStrategy::ALL {
            let out = NuLouvain::new(NuParams { probe: s, ..Default::default() }).run(&g);
            assert_eq!(out.counters.table_ops > 0, true);
            qualities.push(out.modularity);
        }
        for q in &qualities {
            assert!((q - qualities[0]).abs() < 0.05, "{qualities:?}");
        }
    }
}
