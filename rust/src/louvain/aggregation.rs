//! Aggregation phase (Algorithm 3): communities → super-vertex graph.
//!
//! Two implementations, ablated in Fig 2:
//!
//! * [`aggregate_csr`] — the adopted design: community-vertices CSR via
//!   parallel prefix sum, super-vertex graph into a preallocated
//!   *holey* CSR (offsets over-estimate each super-vertex degree with
//!   the community's total degree), 2.2× faster;
//! * [`aggregate_2d`] — `Vec<Vec<_>>` 2-D arrays allocated during the
//!   algorithm (the ablation baseline).
//!
//! Both scan with `self = true` (Algorithm 3 line 15): the weight to
//! the own community becomes the super-vertex self-loop, carrying
//! `σ_c` forward so later passes see correct internal weights.
//!
//! The `_with` variants take an [`Exec`] (so the pass loop's persistent
//! worker team is reused instead of spawning threads per sub-loop) and,
//! for the CSR path, an [`AggScratch`] whose count arrays and holey
//! CSRs are *logically shrunk* across passes instead of reallocated —
//! the zero-allocation pass-workspace contract.  [`aggregate_csr_into`]
//! goes one step further and compacts the super-vertex graph into a
//! caller-owned `Csr` (the pass loop's ping-pong pair), removing the
//! last per-pass allocation on this path.  The plain wrappers keep the
//! original spawn-per-loop, allocate-per-call signatures for baselines
//! and tests.

use super::hashtable::TablePool;
use super::params::LouvainParams;
use super::Counters;
use crate::graph::csr::HoleyCsr;
use crate::graph::Csr;
use crate::parallel::pool::{ChunkRecord, ParallelOpts, RawSend};
use crate::parallel::prefetch::prefetch_read;
use crate::parallel::scan::exclusive_scan_exec;
use crate::parallel::schedule::{DealSpec, ScanOrder, Schedule};
use crate::parallel::team::Exec;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Result of an aggregation phase.
pub struct AggOutcome {
    pub graph: Csr,
    pub counters: Counters,
    pub loops: Vec<(Schedule, Vec<ChunkRecord>)>,
}

/// Result of an aggregation into a caller-owned output graph
/// ([`aggregate_csr_into`]): everything of [`AggOutcome`] except the
/// graph, which the caller already holds.
pub struct AggInfo {
    pub counters: Counters,
    pub loops: Vec<(Schedule, Vec<ChunkRecord>)>,
}

/// Reusable aggregation scratch: the community-count and total-degree
/// arrays plus both holey CSRs (community-vertices and super-vertex).
/// The first pass (the largest graph) sizes every buffer; later passes
/// reuse the allocations.
pub struct AggScratch {
    counts: Vec<usize>,
    tot_deg: Vec<usize>,
    comm_vertices: HoleyCsr,
    holey: HoleyCsr,
    /// Degree-bucketed community order for the fill loop (PR 6; built
    /// only under `Schedule::DegreeBucketed`).
    order: ScanOrder,
}

impl AggScratch {
    pub fn new() -> Self {
        Self {
            counts: Vec::new(),
            tot_deg: Vec::new(),
            comm_vertices: HoleyCsr::with_offsets(vec![0]),
            holey: HoleyCsr::with_offsets(vec![0]),
            order: ScanOrder::default(),
        }
    }

    /// Heap bytes reserved by the aggregation buffers (capacity; PR 8
    /// memory accounting — all high-water-mark scratch).
    pub fn reserved_bytes(&self) -> usize {
        let us = std::mem::size_of::<usize>();
        self.counts.capacity() * us
            + self.tot_deg.capacity() * us
            + self.comm_vertices.reserved_bytes()
            + self.holey.reserved_bytes()
            + self.order.reserved_bytes()
    }
}

impl Default for AggScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// CSR + prefix-sum aggregation with fresh scratch on the scoped pool
/// (the original signature; baselines and tests use this).
pub fn aggregate_csr(
    g: &Csr,
    membership: &[u32],
    n_comm: usize,
    pool: &TablePool,
    params: &LouvainParams,
) -> AggOutcome {
    aggregate_csr_with(g, membership, n_comm, pool, params, Exec::scoped(), &mut AggScratch::new())
}

/// CSR + prefix-sum aggregation (the adopted design) on `exec`,
/// reusing `scratch` across calls and allocating a fresh output graph.
pub fn aggregate_csr_with(
    g: &Csr,
    membership: &[u32],
    n_comm: usize,
    pool: &TablePool,
    params: &LouvainParams,
    exec: Exec,
    scratch: &mut AggScratch,
) -> AggOutcome {
    let mut graph = Csr::default();
    let info =
        aggregate_csr_into(g, membership, n_comm, pool, params, None, exec, scratch, &mut graph);
    AggOutcome { graph, counters: info.counters, loops: info.loops }
}

/// CSR + prefix-sum aggregation into a caller-owned output graph: the
/// pass loop hands in one slot of its ping-pong pair
/// ([`LouvainWorkspace`](super::workspace::LouvainWorkspace)), so the
/// super-vertex `Csr` is compacted in place and steady-state passes
/// allocate nothing (PR 2 satellite; previously every pass built a
/// fresh graph here).
///
/// `vertex_order` (PR 10) is the pass's degree-bucketed *vertex*
/// `ScanOrder` (the one local-moving already uses); when given under
/// `Schedule::DegreeBucketed`, the degree-proportional vertex loops
/// (the community-count and total-degree scatters behind
/// `agg.offsets`) are dealt through it so the heavy tail drains first.
/// Those loops accumulate with order-independent atomic adds, and the
/// compact loops copy disjoint rows, so bucketed dealing is
/// bit-identical to flat dealing (asserted in `tests/late_pass.rs`).
/// The member-scatter loop building the community-vertices CSR stays
/// flat: member order there feeds f64 accumulation order in the fill.
#[allow(clippy::too_many_arguments)]
pub fn aggregate_csr_into(
    g: &Csr,
    membership: &[u32],
    n_comm: usize,
    pool: &TablePool,
    params: &LouvainParams,
    vertex_order: Option<&ScanOrder>,
    exec: Exec,
    scratch: &mut AggScratch,
    out: &mut Csr,
) -> AggInfo {
    let n = g.num_vertices();
    let opts = ParallelOpts {
        threads: params.threads,
        schedule: params.schedule,
        chunk: params.chunk,
        record: params.record_chunks,
    };
    let mut counters = Counters::default();
    let mut loops = Vec::new();

    // Degree-bucketed dealing for the vertex-space scatters (PR 10):
    // positions are remapped through the pass's vertex order, so the
    // heavy tail is dealt first in small dynamic chunks.  Both scatters
    // accumulate with relaxed atomic adds — visit order cannot change
    // the sums — so this is purely a scheduling change.
    let vspec = vertex_order
        .filter(|o| params.schedule == Schedule::DegreeBucketed && o.ids.len() == n)
        .map(|o| (o.spec(), &o.ids[..]));

    // --- Community-vertices CSR G'_{C'} (lines 3-6).
    let sub_span = |name| crate::trace::span(name, crate::trace::Category::Agg, [n_comm as u64; 4]);
    let community_order_span = sub_span("agg.community_order");
    scratch.counts.clear();
    scratch.counts.resize(n_comm + 1, 0);
    {
        let counts_at: &[AtomicUsize] = unsafe {
            &*(scratch.counts.as_mut_slice() as *mut [usize] as *const [AtomicUsize])
        };
        let s = match vspec {
            Some((spec, ids)) => exec.run_ctx_spec(n, opts, spec, |_| (), |_, range| {
                for pos in range {
                    let i = ids[pos] as usize;
                    counts_at[membership[i] as usize].fetch_add(1, Ordering::Relaxed);
                }
            }),
            None => exec.run(n, opts, |range| {
                for i in range {
                    counts_at[membership[i] as usize].fetch_add(1, Ordering::Relaxed);
                }
            }),
        };
        if params.record_chunks {
            loops.push((params.schedule, s.chunks));
        }
    }
    exclusive_scan_exec(&mut scratch.counts, params.threads, exec);
    scratch.comm_vertices.reset_with_offsets(&mut scratch.counts);
    {
        // Deliberately flat even under DegreeBucketed: the member order
        // this scatter claims per community is the order the fill loop
        // accumulates f64 weights in — re-dealing it would change
        // accumulation order and break bucketed-vs-flat bit-exactness.
        let cv = &scratch.comm_vertices;
        let s = exec.run(n, opts, |range| {
            for i in range {
                cv.push_edge(membership[i] as usize, i as u32, 0.0);
            }
        });
        if params.record_chunks {
            loops.push((params.schedule, s.chunks));
        }
    }
    drop(community_order_span);

    // --- Super-vertex graph offsets: community total degree (lines 8-9).
    let offsets_span = sub_span("agg.offsets");
    scratch.tot_deg.clear();
    scratch.tot_deg.resize(n_comm + 1, 0);
    {
        let td: &[AtomicUsize] = unsafe {
            &*(scratch.tot_deg.as_mut_slice() as *mut [usize] as *const [AtomicUsize])
        };
        let s = match vspec {
            Some((spec, ids)) => exec.run_ctx_spec(n, opts, spec, |_| (), |_, range| {
                for pos in range {
                    let i = ids[pos] as usize;
                    td[membership[i] as usize].fetch_add(g.degree(i), Ordering::Relaxed);
                }
            }),
            None => exec.run(n, opts, |range| {
                for i in range {
                    td[membership[i] as usize].fetch_add(g.degree(i), Ordering::Relaxed);
                }
            }),
        };
        if params.record_chunks {
            loops.push((params.schedule, s.chunks));
        }
    }
    exclusive_scan_exec(&mut scratch.tot_deg, params.threads, exec);
    scratch.holey.reset_with_offsets(&mut scratch.tot_deg);
    drop(offsets_span);

    // --- Fill the holey CSR (lines 11-17).
    //
    // Under DegreeBucketed the communities are ordered by *total
    // degree* (the row's scan cost and its distinct-key upper bound):
    // heavy communities are dealt first in small dynamic chunks.  The
    // same bound routes each row into the SmallTable fast path or the
    // pooled slab; rows are target-sorted afterwards, so the community
    // visit order cannot change the output graph.
    let scatter_span = sub_span("agg.scatter");
    let scanned = AtomicU64::new(0);
    let ops = AtomicU64::new(0);
    let small_scans = AtomicU64::new(0);
    let large_scans = AtomicU64::new(0);
    let pf = params.prefetch_distance;
    if params.schedule == Schedule::DegreeBucketed {
        let (order, holey) = (&mut scratch.order, &scratch.holey);
        order.build_exec(
            n_comm,
            params.small_degree,
            params.hub_degree,
            |c| holey.capacity(c),
            ParallelOpts { record: false, ..opts },
            exec,
        );
    }
    {
        let cv = &scratch.comm_vertices;
        let holey = &scratch.holey;
        let order = (params.schedule == Schedule::DegreeBucketed).then_some(&scratch.order);
        let spec = order.map(|o| o.spec()).unwrap_or(DealSpec::Flat);
        let s = exec.run_ctx_spec(
            n_comm,
            opts,
            spec,
            |tid| pool.hybrid_table(tid, params.small_degree),
            |table, range| {
                let mut l_scanned = 0u64;
                let mut l_ops = 0u64;
                let mut l_small = 0u64;
                let mut l_large = 0u64;
                for pos in range {
                    let c = match order {
                        Some(o) => o.ids[pos] as usize,
                        None => pos,
                    };
                    let members = cv.edges(c).0;
                    if members.is_empty() {
                        continue;
                    }
                    // capacity(c) = the community's total degree: an
                    // upper bound on this row's distinct keys.
                    table.begin_row(holey.capacity(c));
                    for &i in members {
                        // scanCommunities with self = true.
                        let (ts, ws) = g.edges(i as usize);
                        for idx in 0..ts.len() {
                            if pf > 0 {
                                if let Some(&tf) = ts.get(idx + pf) {
                                    prefetch_read(membership, tf as usize);
                                }
                            }
                            table.accumulate(membership[ts[idx] as usize], ws[idx] as f64);
                        }
                        l_ops += ts.len() as u64;
                        l_scanned += ts.len() as u64;
                    }
                    if table.used_small() {
                        l_small += 1;
                    } else {
                        l_large += 1;
                    }
                    table.for_each(|d, w| {
                        holey.push_edge(c, d, w as f32);
                    });
                }
                scanned.fetch_add(l_scanned, Ordering::Relaxed);
                ops.fetch_add(l_ops, Ordering::Relaxed);
                small_scans.fetch_add(l_small, Ordering::Relaxed);
                large_scans.fetch_add(l_large, Ordering::Relaxed);
            },
        );
        if params.record_chunks {
            loops.push((params.schedule, s.chunks));
        }
    }
    counters.edges_scanned_agg = scanned.load(Ordering::Relaxed);
    counters.table_ops = ops.load(Ordering::Relaxed);
    counters.small_path_scans = small_scans.load(Ordering::Relaxed);
    counters.large_path_scans = large_scans.load(Ordering::Relaxed);
    drop(scatter_span);

    // --- Compact + normalize row order (prefix-sum over used degrees,
    // then chunked copy; both on `exec`, into the caller's graph).
    // Under DegreeBucketed the row copy and the per-row sort are dealt
    // through the fill's community order (PR 10): rows are disjoint, so
    // draining the heavy-community tail first changes nothing but the
    // schedule.
    let mut compact_span = sub_span("agg.compact");
    let cdeal = (params.schedule == Schedule::DegreeBucketed)
        .then_some(&scratch.order)
        .filter(|o| o.ids.len() == n_comm)
        .map(|o| (o.spec(), &o.ids[..]));
    let s_compact = scratch.holey.compact_into_spec(out, opts, cdeal, exec);
    let s = sort_rows_parallel(out, opts, cdeal, exec);
    if let Some(g) = compact_span.as_mut() {
        g.args = [n_comm as u64, out.num_edges() as u64, 0, 0];
    }
    drop(compact_span);
    if params.record_chunks {
        loops.push((params.schedule, s_compact.chunks));
        loops.push((params.schedule, s.chunks));
    }
    AggInfo { counters, loops }
}

/// Parallel per-row sort (rows are disjoint slices; embarrassingly
/// parallel, recorded for the scaling replay).  Rows of degree ≤ 8 —
/// which dominate late passes, where super-vertices are near-singleton
/// — take an in-place insertion sort with no buffer traffic (PR 2
/// satellite); longer rows go through the per-thread pair buffer, so
/// steady-state sorting allocates only when a row outgrows every
/// previous row on that worker.
/// `deal` (PR 10) optionally re-deals the rows through a bucketed
/// order (spec + position→row ids): rows are disjoint, so any dealing
/// yields the same graph.
fn sort_rows_parallel(
    g: &mut Csr,
    opts: ParallelOpts,
    deal: Option<(DealSpec, &[u32])>,
    exec: Exec,
) -> crate::parallel::pool::WorkStats {
    const SMALL_ROW: usize = 8;
    let n = g.num_vertices();
    let offsets = &g.offsets;
    let tp = RawSend(g.targets.as_mut_ptr());
    let wp = RawSend(g.weights.as_mut_ptr());
    let (spec, ids) = match deal {
        Some((spec, ids)) => (spec, Some(ids)),
        None => (DealSpec::Flat, None),
    };
    exec.run_ctx_spec(
        n,
        ParallelOpts { chunk: opts.chunk.min(512), ..opts },
        spec,
        |_tid| Vec::<(u32, f32)>::new(),
        move |buf, range| {
            let (tp, wp) = (tp, wp);
            for pos in range {
                let v = match ids {
                    Some(ids) => ids[pos] as usize,
                    None => pos,
                };
                let (lo, hi) = (offsets[v], offsets[v + 1]);
                // SAFETY: rows are disjoint; each v visited by one chunk.
                let ts = unsafe { std::slice::from_raw_parts_mut(tp.0.add(lo), hi - lo) };
                let ws = unsafe { std::slice::from_raw_parts_mut(wp.0.add(lo), hi - lo) };
                if ts.len() <= SMALL_ROW {
                    // Insertion sort keeping (target, weight) in step.
                    for a in 1..ts.len() {
                        let (t, w) = (ts[a], ws[a]);
                        let mut b = a;
                        while b > 0 && ts[b - 1] > t {
                            ts[b] = ts[b - 1];
                            ws[b] = ws[b - 1];
                            b -= 1;
                        }
                        ts[b] = t;
                        ws[b] = w;
                    }
                    continue;
                }
                buf.clear();
                buf.extend(ts.iter().copied().zip(ws.iter().copied()));
                buf.sort_unstable_by_key(|p| p.0);
                for (k, (t, w)) in buf.iter().enumerate() {
                    ts[k] = *t;
                    ws[k] = *w;
                }
            }
        },
    )
}

/// 2-D array aggregation with fresh allocations on the scoped pool
/// (the original signature).
pub fn aggregate_2d(
    g: &Csr,
    membership: &[u32],
    n_comm: usize,
    pool: &TablePool,
    params: &LouvainParams,
) -> AggOutcome {
    aggregate_2d_with(g, membership, n_comm, pool, params, Exec::scoped())
}

/// 2-D array (`Vec<Vec>`) aggregation — the Fig 2 ablation baseline.
/// Allocates per-community vectors during the algorithm (that *is* the
/// ablated behaviour, so no scratch reuse here), but still runs its
/// loops on `exec`.
pub fn aggregate_2d_with(
    g: &Csr,
    membership: &[u32],
    n_comm: usize,
    pool: &TablePool,
    params: &LouvainParams,
    exec: Exec,
) -> AggOutcome {
    let n = g.num_vertices();
    let mut counters = Counters::default();

    // Community membership lists as 2-D arrays (allocation-heavy).
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); n_comm];
    for i in 0..n {
        members[membership[i] as usize].push(i as u32);
    }

    // Per-community adjacency as freshly allocated rows.
    let rows: Vec<std::sync::Mutex<Vec<(u32, f32)>>> =
        (0..n_comm).map(|_| std::sync::Mutex::new(Vec::new())).collect();
    let scanned = AtomicU64::new(0);
    let opts = ParallelOpts {
        threads: params.threads,
        schedule: params.schedule,
        chunk: params.chunk,
        record: false,
    };
    let members_ref = &members;
    exec.run_ctx(
        n_comm,
        opts,
        |tid| pool.table(tid),
        |table, range| {
            let mut l_scanned = 0u64;
            for c in range {
                if members_ref[c].is_empty() {
                    continue;
                }
                table.clear();
                for &i in &members_ref[c] {
                    for (j, w) in g.neighbours(i as usize) {
                        table.accumulate(membership[j as usize], w as f64);
                    }
                    l_scanned += g.degree(i as usize) as u64;
                }
                let mut row = Vec::new(); // the ablated allocation
                table.for_each(|d, w| row.push((d, w as f32)));
                *rows[c].lock().unwrap() = row;
            }
            scanned.fetch_add(l_scanned, Ordering::Relaxed);
        },
    );
    counters.edges_scanned_agg = scanned.load(Ordering::Relaxed);

    // Assemble CSR from the 2-D structure.
    let mut offsets = vec![0usize; n_comm + 1];
    let rows: Vec<Vec<(u32, f32)>> = rows.into_iter().map(|m| m.into_inner().unwrap()).collect();
    for (c, row) in rows.iter().enumerate() {
        offsets[c + 1] = offsets[c] + row.len();
    }
    let mut targets = Vec::with_capacity(offsets[n_comm]);
    let mut weights = Vec::with_capacity(offsets[n_comm]);
    for row in &rows {
        for &(d, w) in row {
            targets.push(d);
            weights.push(w);
        }
    }
    let mut graph = Csr { offsets, targets, weights };
    sort_rows(&mut graph);
    AggOutcome { graph, counters, loops: Vec::new() }
}

/// Sort each adjacency row by target id (normalizes hashtable iteration
/// order so all table kinds produce byte-identical super-vertex graphs).
pub fn sort_rows(g: &mut Csr) {
    let n = g.num_vertices();
    for v in 0..n {
        let (lo, hi) = (g.offsets[v], g.offsets[v + 1]);
        let mut pairs: Vec<(u32, f32)> = g.targets[lo..hi]
            .iter()
            .copied()
            .zip(g.weights[lo..hi].iter().copied())
            .collect();
        pairs.sort_unstable_by_key(|p| p.0);
        for (k, (t, w)) in pairs.into_iter().enumerate() {
            g.targets[lo + k] = t;
            g.weights[lo + k] = w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::params::TableKind;
    use crate::parallel::team::Team;

    fn params() -> LouvainParams {
        LouvainParams::default()
    }

    #[test]
    fn two_triangles_aggregate_to_two_supervertices() {
        let g = GraphBuilder::new(6)
            .edge(0, 1, 1.0).edge(1, 2, 1.0).edge(0, 2, 1.0)
            .edge(3, 4, 1.0).edge(4, 5, 1.0).edge(3, 5, 1.0)
            .edge(2, 3, 1.0)
            .build_undirected();
        let memb = vec![0u32, 0, 0, 1, 1, 1];
        let pool = TablePool::new(TableKind::FarKv, 2, 1);
        let out = aggregate_csr(&g, &memb, 2, &pool, &params());
        let sg = &out.graph;
        sg.validate().unwrap();
        assert_eq!(sg.num_vertices(), 2);
        // Self-loops: 2*σ_c = 6 (three internal edges, both directions);
        // bridge: weight 1 each way.
        assert_eq!(sg.edges(0).0, &[0, 1]);
        assert_eq!(sg.edges(0).1, &[6.0, 1.0]);
        assert_eq!(sg.edges(1).1, &[1.0, 6.0]);
        // m is preserved.
        assert!((sg.total_weight() - g.total_weight()).abs() < 1e-9);
    }

    #[test]
    fn total_weight_preserved_across_families() {
        for f in GraphFamily::ALL {
            let g = generate(f, 9, 3);
            let n = g.num_vertices();
            // Arbitrary 8-way partition.
            let memb: Vec<u32> = (0..n).map(|v| (v % 8) as u32).collect();
            let pool = TablePool::new(TableKind::FarKv, 8, 1);
            let out = aggregate_csr(&g, &memb, 8, &pool, &params());
            assert!(
                (out.graph.total_weight() - g.total_weight()).abs() < 1e-6 * g.total_weight(),
                "{f:?}"
            );
            assert!(out.graph.is_symmetric(), "{f:?}");
        }
    }

    #[test]
    fn csr_and_2d_produce_identical_graphs() {
        for f in [GraphFamily::Web, GraphFamily::Road] {
            let g = generate(f, 9, 13);
            let n = g.num_vertices();
            let memb: Vec<u32> = (0..n).map(|v| (v % 50) as u32).collect();
            let pool = TablePool::new(TableKind::FarKv, 50, 1);
            let a = aggregate_csr(&g, &memb, 50, &pool, &params());
            let b = aggregate_2d(&g, &memb, 50, &pool, &params());
            assert_eq!(a.graph, b.graph, "{f:?}");
        }
    }

    #[test]
    fn table_kinds_produce_identical_supergraphs() {
        let g = generate(GraphFamily::Social, 8, 19);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n).map(|v| (v % 13) as u32).collect();
        let mut graphs = Vec::new();
        for kind in [TableKind::Map, TableKind::CloseKv, TableKind::FarKv] {
            let pool = TablePool::new(kind, 13, 1);
            let p = LouvainParams { table: kind, ..params() };
            graphs.push(aggregate_csr(&g, &memb, 13, &pool, &p).graph);
        }
        assert_eq!(graphs[0], graphs[1]);
        assert_eq!(graphs[1], graphs[2]);
    }

    #[test]
    fn multithreaded_matches_single_threaded() {
        let g = generate(GraphFamily::Web, 10, 29);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n).map(|v| (v % 97) as u32).collect();
        let pool1 = TablePool::new(TableKind::FarKv, 97, 1);
        let pool4 = TablePool::new(TableKind::FarKv, 97, 4);
        let a = aggregate_csr(&g, &memb, 97, &pool1, &LouvainParams { threads: 1, ..params() });
        let b = aggregate_csr(&g, &memb, 97, &pool4, &LouvainParams { threads: 4, ..params() });
        assert_eq!(a.graph, b.graph);
    }

    #[test]
    fn team_path_with_scratch_reuse_matches_scoped() {
        // The pass-loop configuration: one team + one scratch reused
        // across shrinking "passes"; output must equal the fresh-scratch
        // scoped path every time.
        let team = Team::new(4);
        let mut scratch = AggScratch::new();
        let g = generate(GraphFamily::Web, 10, 31);
        let n = g.num_vertices();
        let p = LouvainParams { threads: 4, ..params() };
        for ncomm in [211usize, 97, 13] {
            let memb: Vec<u32> = (0..n).map(|v| (v % ncomm) as u32).collect();
            let mut pool_slot = None;
            let pool = TablePool::ensure(&mut pool_slot, TableKind::FarKv, ncomm, 4);
            let fresh = aggregate_csr(&g, &memb, ncomm, pool, &p);
            let reused = aggregate_csr_with(
                &g, &memb, ncomm, pool, &p, Exec::team(&team), &mut scratch,
            );
            assert_eq!(fresh.graph, reused.graph, "ncomm={ncomm}");
            assert_eq!(
                fresh.counters.edges_scanned_agg,
                reused.counters.edges_scanned_agg
            );
        }
    }

    #[test]
    fn degree_bucketed_matches_dynamic_exactly() {
        // Rows are target-sorted after the fill, so the bucketed
        // community order must produce a bit-identical supergraph, at
        // one thread and several.
        let g = generate(GraphFamily::Web, 10, 43);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n).map(|v| (v % 137) as u32).collect();
        for threads in [1usize, 4] {
            let pool = TablePool::new(TableKind::FarKv, 137, threads);
            let base = aggregate_csr(
                &g, &memb, 137, &pool,
                &LouvainParams { threads, schedule: Schedule::Dynamic, ..params() },
            );
            let bucketed = aggregate_csr(
                &g, &memb, 137, &pool,
                &LouvainParams { threads, schedule: Schedule::DegreeBucketed, ..params() },
            );
            assert_eq!(base.graph, bucketed.graph, "threads={threads}");
            assert_eq!(
                base.counters.edges_scanned_agg,
                bucketed.counters.edges_scanned_agg
            );
            // The Web family's skew puts most communities on the fast path.
            assert!(
                bucketed.counters.small_path_scans + bucketed.counters.large_path_scans > 0
            );
        }
    }

    #[test]
    fn aggregate_into_reuses_output_graph() {
        // The ping-pong contract: aggregating a smaller pass into an
        // already-sized output must not reallocate and must equal the
        // fresh-output path.
        let team = Team::new(2);
        let mut scratch = AggScratch::new();
        let mut out = Csr::default();
        let g = generate(GraphFamily::Web, 10, 37);
        let n = g.num_vertices();
        let p = LouvainParams { threads: 2, ..params() };
        let mut ptrs = None;
        for ncomm in [301usize, 97, 11] {
            let memb: Vec<u32> = (0..n).map(|v| (v % ncomm) as u32).collect();
            let mut pool_slot = None;
            let pool = TablePool::ensure(&mut pool_slot, TableKind::FarKv, ncomm, 2);
            let fresh = aggregate_csr(&g, &memb, ncomm, pool, &p);
            aggregate_csr_into(
                &g, &memb, ncomm, pool, &p, None, Exec::team(&team), &mut scratch, &mut out,
            );
            assert_eq!(fresh.graph, out, "ncomm={ncomm}");
            match ptrs {
                None => ptrs = Some((out.offsets.as_ptr(), out.targets.as_ptr())),
                Some((op, tp)) => {
                    assert_eq!(out.offsets.as_ptr(), op, "offsets realloc at ncomm={ncomm}");
                    assert_eq!(out.targets.as_ptr(), tp, "targets realloc at ncomm={ncomm}");
                }
            }
        }
    }

    #[test]
    fn small_row_fast_path_sorts_like_buffer_path() {
        // Mixed small (≤8) and large rows through the public path: all
        // rows must come out target-sorted with weights in step.
        let g = generate(GraphFamily::Road, 10, 41); // degree ≈ 2: small rows
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n).map(|v| (v % 700) as u32).collect();
        let pool = TablePool::new(TableKind::FarKv, 700, 1);
        let out = aggregate_csr(&g, &memb, 700, &pool, &params());
        for c in 0..out.graph.num_vertices() {
            let ts = out.graph.edges(c).0;
            assert!(ts.windows(2).all(|w| w[0] < w[1]), "row {c} unsorted: {ts:?}");
        }
        assert!((out.graph.total_weight() - g.total_weight()).abs() < 1e-6 * g.total_weight());
    }

    #[test]
    fn empty_communities_get_no_edges() {
        let g = GraphBuilder::new(3).edge(0, 1, 1.0).build_undirected();
        // Community 1 is empty (ids 0 and 2 used).
        let memb = vec![0u32, 0, 2];
        let pool = TablePool::new(TableKind::FarKv, 3, 1);
        let out = aggregate_csr(&g, &memb, 3, &pool, &params());
        assert_eq!(out.graph.degree(1), 0);
        assert_eq!(out.graph.degree(2), 0); // isolated vertex
        assert_eq!(out.graph.edges(0).0, &[0]);
        assert_eq!(out.graph.edges(0).1, &[2.0]);
    }

    #[test]
    fn self_loops_carry_internal_weight_forward() {
        // Path 0-1-2 in one community: internal slots = 4 (two edges × two
        // directions) => self-loop 4.0.
        let g = GraphBuilder::new(3).edge(0, 1, 1.0).edge(1, 2, 1.0).build_undirected();
        let memb = vec![0u32, 0, 0];
        let pool = TablePool::new(TableKind::FarKv, 1, 1);
        let out = aggregate_csr(&g, &memb, 1, &pool, &params());
        assert_eq!(out.graph.edges(0).1, &[4.0]);
        assert!((out.graph.total_weight() - g.total_weight()).abs() < 1e-12);
    }
}
