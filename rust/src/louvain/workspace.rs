//! Zero-allocation pass workspace for the GVE-Louvain pass loop.
//!
//! The paper's hot path (§4.1.9, Far-KV) preallocates every per-thread
//! hashtable once and reuses OpenMP's persistent thread team; the PR-0
//! driver instead rebuilt the [`TablePool`] plus all K'/Σ'/C'/affected
//! buffers from scratch on **every pass** and forked fresh OS threads
//! on every loop.  [`LouvainWorkspace`] is the fix:
//!
//! * the persistent worker [`Team`] is built once (O(1) OS-thread
//!   spawns per run, not O(passes × iterations × loops));
//! * the [`TablePool`] and the K'/Σ'/C'/affected vectors are sized by
//!   the first pass (the largest graph — pass graphs only shrink) and
//!   *logically shrunk* afterwards;
//! * the aggregation scratch ([`AggScratch`]: count arrays + both
//!   holey CSRs) is likewise reused.
//!
//! ## Contract
//!
//! * [`LouvainWorkspace::prepare`] is called once per run with the
//!   input size; it (re)builds the team/pool only when the thread
//!   count, table kind or capacity requirement changed — repeated runs
//!   on the same [`GveLouvain`](super::gve::GveLouvain) object reuse
//!   everything.
//! * [`LouvainWorkspace::begin_pass`] resizes the pass buffers for the
//!   current super-vertex graph without reallocating (capacity is
//!   retained from the first pass).
//! * Fields are `pub(crate)` so the pass loop can split-borrow the
//!   team, pool, buffers and scratch simultaneously.

use super::aggregation::AggScratch;
use super::hashtable::TablePool;
use super::params::LouvainParams;
use crate::parallel::team::Team;

/// Reusable runtime resources of one [`GveLouvain`](super::gve::GveLouvain).
pub struct LouvainWorkspace {
    /// Persistent worker team (spawned once per thread-count change).
    pub(crate) team: Option<Team>,
    /// Per-thread community tables, sized by the largest pass.
    pub(crate) pool: Option<TablePool>,
    /// K': weighted degrees of the current pass graph.
    pub(crate) k: Vec<f64>,
    /// Σ': community weight totals.
    pub(crate) sigma: Vec<f64>,
    /// C': pass-local membership.
    pub(crate) membership: Vec<u32>,
    /// Pruning flags (1 = process).
    pub(crate) affected: Vec<u32>,
    /// Aggregation scratch (counts / total-degree / holey buffers).
    pub(crate) agg: AggScratch,
}

impl LouvainWorkspace {
    pub fn new() -> Self {
        Self {
            team: None,
            pool: None,
            k: Vec::new(),
            sigma: Vec::new(),
            membership: Vec::new(),
            affected: Vec::new(),
            agg: AggScratch::new(),
        }
    }

    /// Ensure the team and table pool exist and fit this run.
    ///
    /// `n_cap` is the input graph's vertex count — an upper bound for
    /// every later pass, so the pool allocated here is never regrown
    /// within the run.
    pub fn prepare(&mut self, params: &LouvainParams, n_cap: usize) {
        let threads = params.threads.max(1);
        if self.team.as_ref().map(Team::threads) != Some(threads) {
            self.team = Some(Team::new(threads));
        }
        TablePool::ensure(&mut self.pool, params.table, n_cap, threads);
    }

    /// Size the pass buffers for an `np`-vertex pass graph.  After the
    /// first pass this never allocates: pass graphs only shrink.
    ///
    /// On return: `membership` is the identity and `affected` is all-1
    /// (the Algorithm 1 lines 4-5 initial state).  `k` and `sigma` are
    /// *not* touched here — the pass loop overwrites both in full
    /// (`vertex_weights_into`, then the Σ' copy), so pre-zeroing them
    /// would just be two dead O(np) sweeps on the hot path.
    pub fn begin_pass(&mut self, np: usize) {
        self.membership.clear();
        self.membership.extend(0..np as u32);
        self.affected.clear();
        self.affected.resize(np, 1);
    }

    /// OS worker threads spawned by this workspace's team so far.
    pub fn spawned_workers(&self) -> usize {
        self.team.as_ref().map(Team::spawned_workers).unwrap_or(0)
    }
}

impl Default for LouvainWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::louvain::params::TableKind;

    #[test]
    fn prepare_reuses_team_and_pool_across_runs() {
        let mut ws = LouvainWorkspace::new();
        let p = LouvainParams { threads: 3, ..Default::default() };
        ws.prepare(&p, 1000);
        assert_eq!(ws.spawned_workers(), 2);
        let pool_ptr = ws.pool.as_ref().unwrap().storage_ptr(0);
        let team_ptr = ws.team.as_ref().unwrap() as *const Team;

        // A second (smaller) run must reuse both.
        ws.prepare(&p, 100);
        assert_eq!(ws.spawned_workers(), 2);
        assert_eq!(ws.pool.as_ref().unwrap().storage_ptr(0), pool_ptr);
        assert_eq!(ws.team.as_ref().unwrap() as *const Team, team_ptr);

        // Changing the thread count rebuilds the team (only then).
        let p4 = LouvainParams { threads: 4, ..Default::default() };
        ws.prepare(&p4, 100);
        assert_eq!(ws.spawned_workers(), 3);
    }

    #[test]
    fn prepare_rebuilds_pool_on_kind_or_capacity_change() {
        let mut ws = LouvainWorkspace::new();
        let p = LouvainParams::default();
        ws.prepare(&p, 100);
        assert_eq!(ws.pool.as_ref().unwrap().kind(), TableKind::FarKv);
        let ptr = ws.pool.as_ref().unwrap().storage_ptr(0);
        // Larger input: must grow.
        ws.prepare(&p, 10_000);
        assert!(ws.pool.as_ref().unwrap().capacity() >= 10_000);
        // Different table kind: must rebuild.
        let pm = LouvainParams { table: TableKind::Map, ..Default::default() };
        ws.prepare(&pm, 100);
        assert_eq!(ws.pool.as_ref().unwrap().kind(), TableKind::Map);
        let _ = ptr;
    }

    #[test]
    fn begin_pass_shrinks_without_reallocating() {
        let mut ws = LouvainWorkspace::new();
        ws.begin_pass(1000);
        assert_eq!(ws.membership.len(), 1000);
        assert_eq!(ws.membership[999], 999);
        assert!(ws.affected.iter().all(|&a| a == 1));
        let (mp, ap) = (ws.membership.as_ptr(), ws.affected.as_ptr());
        // Later (smaller) passes keep the same allocations.
        for np in [400, 50, 7] {
            ws.begin_pass(np);
            assert_eq!(ws.membership.len(), np);
            assert_eq!(ws.affected.len(), np);
            assert_eq!(ws.membership.as_ptr(), mp);
            assert_eq!(ws.affected.as_ptr(), ap);
            assert_eq!(ws.membership.last().copied(), Some(np as u32 - 1));
        }
    }
}
