//! Zero-allocation pass workspace for the GVE-Louvain pass loop.
//!
//! The paper's hot path (§4.1.9, Far-KV) preallocates every per-thread
//! hashtable once and reuses OpenMP's persistent thread team; the PR-0
//! driver instead rebuilt the [`TablePool`] plus all K'/Σ'/C'/affected
//! buffers from scratch on **every pass** and forked fresh OS threads
//! on every loop.  [`LouvainWorkspace`] is the fix:
//!
//! * the persistent worker [`Team`] is built once (O(1) OS-thread
//!   spawns per run, not O(passes × iterations × loops));
//! * the [`TablePool`] and the K'/Σ'/C'/affected vectors are sized by
//!   the first pass (the largest graph — pass graphs only shrink) and
//!   *logically shrunk* afterwards;
//! * the aggregation scratch ([`AggScratch`]: count arrays + both
//!   holey CSRs) is likewise reused;
//! * the super-vertex graph lives in a ping-pong `Csr` pair (PR 2):
//!   each pass reads one slot while aggregation compacts into the
//!   other, so even the output graph stops allocating per pass.
//!
//! ## Contract
//!
//! * [`LouvainWorkspace::prepare`] is called once per run with the
//!   input size; it (re)builds the team/pool only when the thread
//!   count, table kind or capacity requirement changed — repeated runs
//!   on the same [`GveLouvain`](super::gve::GveLouvain) object reuse
//!   everything.
//! * [`LouvainWorkspace::begin_pass`] resizes the pass buffers for the
//!   current super-vertex graph without reallocating (capacity is
//!   retained from the first pass).
//! * Fields are `pub(crate)` so the pass loop can split-borrow the
//!   team, pool, buffers and scratch simultaneously.

use super::aggregation::AggScratch;
use super::hashtable::TablePool;
use super::params::LouvainParams;
use crate::graph::Csr;
use crate::parallel::pool::ParallelOpts;
use crate::parallel::schedule::ScanOrder;
use crate::parallel::team::{shared_team, Exec, Team};
use std::sync::Arc;

/// Reusable runtime resources of one [`GveLouvain`](super::gve::GveLouvain).
pub struct LouvainWorkspace {
    /// Persistent worker team — the *process-wide shared* team of this
    /// width (PR 3, ROADMAP "process-wide team sharing"): every
    /// workspace asking for `T` threads holds the same `Arc<Team>`, so
    /// a service or bench building many `GveLouvain` objects spawns
    /// `T - 1` OS workers once per process, not once per object.
    pub(crate) team: Option<Arc<Team>>,
    /// Per-thread community tables, sized by the largest pass.
    pub(crate) pool: Option<TablePool>,
    /// K': weighted degrees of the current pass graph.
    pub(crate) k: Vec<f64>,
    /// Σ': community weight totals.
    pub(crate) sigma: Vec<f64>,
    /// C': pass-local membership.
    pub(crate) membership: Vec<u32>,
    /// Pruning flags (1 = process).
    pub(crate) affected: Vec<u32>,
    /// Aggregation scratch (counts / total-degree / holey buffers).
    pub(crate) agg: AggScratch,
    /// Super-vertex graph ping-pong pair: the pass loop reads one slot
    /// while aggregation compacts into the other, so no pass allocates
    /// a fresh `Csr` once the first aggregation sized them (PR 2).
    pub(crate) super_a: Csr,
    pub(crate) super_b: Csr,
    /// Rank table for the parallel community renumbering.
    pub(crate) renumber_scratch: Vec<usize>,
    /// Degree-bucketed vertex order for the local-moving scan loops,
    /// rebuilt once per pass under `Schedule::DegreeBucketed` (PR 6).
    pub(crate) scan_order: ScanOrder,
}

impl LouvainWorkspace {
    pub fn new() -> Self {
        Self {
            team: None,
            pool: None,
            k: Vec::new(),
            sigma: Vec::new(),
            membership: Vec::new(),
            affected: Vec::new(),
            agg: AggScratch::new(),
            super_a: Csr::default(),
            super_b: Csr::default(),
            renumber_scratch: Vec::new(),
            scan_order: ScanOrder::default(),
        }
    }

    /// Ensure the team and table pool exist and fit this run.
    ///
    /// `n_cap` is the input graph's vertex count — an upper bound for
    /// every later pass, so the pool allocated here is never regrown
    /// within the run.
    pub fn prepare(&mut self, params: &LouvainParams, n_cap: usize) {
        let threads = params.threads.max(1);
        self.ensure_team(threads);
        // First-touch the Far-KV slabs from their owning workers when
        // the pool is (re)built (PR 6 satellite, ROADMAP NUMA item);
        // reused pools keep their page placement.
        let exec = match &self.team {
            Some(t) if threads > 1 => Exec::team(t),
            _ => Exec::scoped(),
        };
        TablePool::ensure_with_exec(&mut self.pool, params.table, n_cap, threads, exec);
    }

    /// Ensure the (shared) team exists at this width — the team half of
    /// [`Self::prepare`], callable without a capacity for helpers that
    /// only need an executor (delta-screening marking, service stats).
    pub(crate) fn ensure_team(&mut self, threads: usize) {
        let threads = threads.max(1);
        if self.team.as_ref().map(|t| t.threads()) != Some(threads) {
            self.team = Some(shared_team(threads));
        }
    }

    /// Size the pass buffers for an `np`-vertex pass graph.  After the
    /// first pass this never allocates: pass graphs only shrink.
    ///
    /// On return: `membership` is the identity and `affected` is all-1
    /// (the Algorithm 1 lines 4-5 initial state).  `k` and `sigma` are
    /// *not* touched here — the pass loop overwrites both in full
    /// (`vertex_weights_into`, then the Σ' copy), so pre-zeroing them
    /// would just be two dead O(np) sweeps on the hot path.
    pub fn begin_pass(&mut self, np: usize) {
        self.membership.clear();
        self.membership.extend(0..np as u32);
        self.affected.clear();
        self.affected.resize(np, 1);
    }

    /// OS worker threads spawned by this workspace's team so far.
    pub fn spawned_workers(&self) -> usize {
        self.team.as_ref().map(|t| t.spawned_workers()).unwrap_or(0)
    }

    /// Byte-level memory accounting over every long-lived buffer this
    /// workspace owns (PR 8).  "Reserved" is allocator capacity;
    /// "used" is logical length — the gap is the shrink-only reuse
    /// slack the zero-allocation contract deliberately keeps (pass
    /// buffers are sized by the *first* pass and logically shrunk).
    pub fn mem_report(&self) -> WorkspaceMem {
        let f64s = std::mem::size_of::<f64>();
        let u32s = std::mem::size_of::<u32>();
        let us = std::mem::size_of::<usize>();
        let vec_pairs = [
            (self.k.capacity() * f64s, self.k.len() * f64s),
            (self.sigma.capacity() * f64s, self.sigma.len() * f64s),
            (self.membership.capacity() * u32s, self.membership.len() * u32s),
            (self.affected.capacity() * u32s, self.affected.len() * u32s),
            (self.renumber_scratch.capacity() * us, self.renumber_scratch.len() * us),
        ];
        let pass_reserved: usize = vec_pairs.iter().map(|&(r, _)| r).sum::<usize>()
            + self.scan_order.reserved_bytes();
        let pass_used: usize = vec_pairs.iter().map(|&(_, u)| u).sum::<usize>()
            + self.scan_order.ids.len() * u32s;
        WorkspaceMem {
            table_pool: self.pool.as_ref().map(|p| p.reserved_bytes()).unwrap_or(0),
            pass_buffers_reserved: pass_reserved,
            pass_buffers_used: pass_used,
            agg_scratch: self.agg.reserved_bytes(),
            super_graphs_reserved: self.super_a.reserved_bytes() + self.super_b.reserved_bytes(),
            super_graphs_used: self.super_a.used_bytes() + self.super_b.used_bytes(),
        }
    }

    /// Publish the current [`Self::mem_report`] into the process
    /// registry's byte gauges (one call per run, after the pass loop).
    pub fn publish_mem_gauges(&self) {
        if !crate::obs::enabled() {
            return;
        }
        use crate::obs::sites::mem_bytes;
        let m = self.mem_report();
        mem_bytes("reserved", "table_pool").set(m.table_pool as i64);
        mem_bytes("reserved", "workspace").set((m.pass_buffers_reserved + m.agg_scratch) as i64);
        mem_bytes("used", "workspace").set(m.pass_buffers_used as i64);
        mem_bytes("reserved", "super_graphs").set(m.super_graphs_reserved as i64);
        mem_bytes("used", "super_graphs").set(m.super_graphs_used as i64);
    }
}

/// One workspace's byte-level footprint (PR 8; see
/// [`LouvainWorkspace::mem_report`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkspaceMem {
    /// Per-thread community-table slabs (capacity == use by design).
    pub table_pool: usize,
    /// K'/Σ'/C'/affected/renumber/scan-order capacities.
    pub pass_buffers_reserved: usize,
    /// Same buffers at their current logical lengths.
    pub pass_buffers_used: usize,
    /// Aggregation scratch (high-water-mark storage; reserved only).
    pub agg_scratch: usize,
    /// Super-vertex ping-pong pair capacities.
    pub super_graphs_reserved: usize,
    pub super_graphs_used: usize,
}

impl WorkspaceMem {
    pub fn total_reserved(&self) -> usize {
        self.table_pool + self.pass_buffers_reserved + self.agg_scratch
            + self.super_graphs_reserved
    }

    pub fn total_used(&self) -> usize {
        self.pass_buffers_used + self.super_graphs_used
    }
}

/// Parallel pass-buffer init (PR 2 satellite: the identity membership
/// and all-1 affected fills were serial O(np) scans per pass).  Same
/// postcondition as [`LouvainWorkspace::begin_pass`], but both fills
/// run as chunked loops on `exec`.  Free function over the split
/// borrows because the pass loop holds `&Team`/`&TablePool` borrows of
/// the same workspace while it runs.
pub(crate) fn begin_pass_par(
    membership: &mut Vec<u32>,
    affected: &mut Vec<u32>,
    np: usize,
    opts: ParallelOpts,
    exec: Exec,
) {
    let opts = ParallelOpts { record: false, ..opts };
    // resize (not clear+resize): every slot is overwritten by the
    // chunked fills, so only growth needs the element init.
    membership.resize(np, 0);
    exec.run_disjoint_mut(&mut membership[..], opts, |r, chunk| {
        for (k, x) in chunk.iter_mut().enumerate() {
            *x = (r.start + k) as u32;
        }
    });
    affected.resize(np, 0);
    exec.run_disjoint_mut(&mut affected[..], opts, |_r, chunk| {
        chunk.fill(1);
    });
}

/// Seeded pass-buffer init (the dynamic-Louvain warm start): membership
/// is copied from a previous run, affected either copied (delta
/// screening) or all-1 (naive-dynamic).
pub(crate) fn begin_pass_seeded(
    membership: &mut Vec<u32>,
    affected: &mut Vec<u32>,
    seed_membership: &[u32],
    seed_affected: Option<&[u32]>,
) {
    membership.clear();
    membership.extend_from_slice(seed_membership);
    affected.clear();
    match seed_affected {
        Some(a) => affected.extend_from_slice(a),
        None => affected.resize(seed_membership.len(), 1),
    }
}

impl Default for LouvainWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::louvain::params::TableKind;

    #[test]
    fn prepare_reuses_team_and_pool_across_runs() {
        let mut ws = LouvainWorkspace::new();
        let p = LouvainParams { threads: 3, ..Default::default() };
        ws.prepare(&p, 1000);
        assert_eq!(ws.spawned_workers(), 2);
        let pool_ptr = ws.pool.as_ref().unwrap().storage_ptr(0);
        let team_ptr = Arc::as_ptr(ws.team.as_ref().unwrap());

        // A second (smaller) run must reuse both.
        ws.prepare(&p, 100);
        assert_eq!(ws.spawned_workers(), 2);
        assert_eq!(ws.pool.as_ref().unwrap().storage_ptr(0), pool_ptr);
        assert_eq!(Arc::as_ptr(ws.team.as_ref().unwrap()), team_ptr);

        // Changing the thread count swaps to that width's team (only then).
        let p4 = LouvainParams { threads: 4, ..Default::default() };
        ws.prepare(&p4, 100);
        assert_eq!(ws.spawned_workers(), 3);

        // Process-wide sharing: a second workspace at the same width
        // holds the *same* team, not a fresh spawn (PR 3).
        let mut ws2 = LouvainWorkspace::new();
        ws2.prepare(&p4, 50);
        assert_eq!(
            Arc::as_ptr(ws.team.as_ref().unwrap()),
            Arc::as_ptr(ws2.team.as_ref().unwrap()),
        );
    }

    #[test]
    fn prepare_rebuilds_pool_on_kind_or_capacity_change() {
        let mut ws = LouvainWorkspace::new();
        let p = LouvainParams::default();
        ws.prepare(&p, 100);
        assert_eq!(ws.pool.as_ref().unwrap().kind(), TableKind::FarKv);
        let ptr = ws.pool.as_ref().unwrap().storage_ptr(0);
        // Larger input: must grow.
        ws.prepare(&p, 10_000);
        assert!(ws.pool.as_ref().unwrap().capacity() >= 10_000);
        // Different table kind: must rebuild.
        let pm = LouvainParams { table: TableKind::Map, ..Default::default() };
        ws.prepare(&pm, 100);
        assert_eq!(ws.pool.as_ref().unwrap().kind(), TableKind::Map);
        let _ = ptr;
    }

    #[test]
    fn begin_pass_par_matches_serial_contract() {
        use crate::parallel::team::{Exec, Team};
        let team = Team::new(4);
        let opts = ParallelOpts { threads: 4, chunk: 64, ..ParallelOpts::default() };
        let (mut memb, mut aff) = (Vec::new(), Vec::new());
        for np in [1000usize, 400, 1, 0, 700] {
            begin_pass_par(&mut memb, &mut aff, np, opts, Exec::team(&team));
            let mut ws = LouvainWorkspace::new();
            ws.begin_pass(np);
            assert_eq!(memb, ws.membership, "np={np}");
            assert_eq!(aff, ws.affected, "np={np}");
        }
    }

    #[test]
    fn begin_pass_seeded_copies_seed() {
        let (mut memb, mut aff) = (vec![9u32; 3], vec![9u32; 3]);
        begin_pass_seeded(&mut memb, &mut aff, &[2, 0, 2, 1], None);
        assert_eq!(memb, vec![2, 0, 2, 1]);
        assert_eq!(aff, vec![1, 1, 1, 1]);
        begin_pass_seeded(&mut memb, &mut aff, &[0, 0], Some(&[1, 0]));
        assert_eq!(memb, vec![0, 0]);
        assert_eq!(aff, vec![1, 0]);
    }

    #[test]
    fn begin_pass_shrinks_without_reallocating() {
        let mut ws = LouvainWorkspace::new();
        ws.begin_pass(1000);
        assert_eq!(ws.membership.len(), 1000);
        assert_eq!(ws.membership[999], 999);
        assert!(ws.affected.iter().all(|&a| a == 1));
        let (mp, ap) = (ws.membership.as_ptr(), ws.affected.as_ptr());
        // Later (smaller) passes keep the same allocations.
        for np in [400, 50, 7] {
            ws.begin_pass(np);
            assert_eq!(ws.membership.len(), np);
            assert_eq!(ws.affected.len(), np);
            assert_eq!(ws.membership.as_ptr(), mp);
            assert_eq!(ws.affected.as_ptr(), ap);
            assert_eq!(ws.membership.last().copied(), Some(np as u32 - 1));
        }
    }
}
