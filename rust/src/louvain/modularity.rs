//! Modularity (Eq. 1) and delta-modularity (Eq. 2).
//!
//! Conventions (matching the paper's definitions in §3.1 and making
//! Eq. 1 agree with the standard `L_c/m − (k_c/2m)²` form):
//!
//! * `σ_c`  — sum over *directed slots* internal to `c`
//!   (`Σ_{i∈c} K_{i→c}`): each undirected internal edge counts twice,
//!   a self-loop slot once.
//! * `Σ_c`  — total weighted degree of members (`Σ_{i∈c} K_i`).
//! * `m`    — half the total slot weight.

use crate::graph::Csr;

/// Per-community `(σ_c, Σ_c)` accumulated over the graph.
pub fn community_weights(g: &Csr, membership: &[u32]) -> (Vec<f64>, Vec<f64>) {
    let nc = membership.iter().copied().max().map(|c| c as usize + 1).unwrap_or(0);
    let mut sigma = vec![0f64; nc];
    let mut big = vec![0f64; nc];
    for v in 0..g.num_vertices() {
        let cv = membership[v] as usize;
        let (ts, ws) = g.edges(v);
        for (t, w) in ts.iter().zip(ws) {
            big[cv] += *w as f64;
            if membership[*t as usize] as usize == cv {
                sigma[cv] += *w as f64;
            }
        }
    }
    (sigma, big)
}

/// Modularity `Q` of a membership (Eq. 1).
pub fn modularity(g: &Csr, membership: &[u32]) -> f64 {
    let m = g.total_weight();
    if m == 0.0 {
        return 0.0;
    }
    let (sigma, big) = community_weights(g, membership);
    sigma
        .iter()
        .zip(&big)
        .map(|(&s, &b)| s / (2.0 * m) - (b / (2.0 * m)).powi(2))
        .sum()
}

/// Delta-modularity of moving `i` from community `d` to `c` (Eq. 2).
///
/// * `k_to_c` / `k_to_d` — `K_{i→c}` / `K_{i→d}` (scan, self excluded);
/// * `k_i` — weighted degree of `i`;
/// * `sigma_c` / `sigma_d` — `Σ_c` / `Σ_d` *before* the move.
#[inline]
pub fn delta_modularity(k_to_c: f64, k_to_d: f64, k_i: f64, sigma_c: f64, sigma_d: f64, m: f64) -> f64 {
    (k_to_c - k_to_d) / m - k_i * (k_i + sigma_c - sigma_d) / (2.0 * m * m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};

    fn two_pairs() -> Csr {
        GraphBuilder::new(4).edge(0, 1, 1.0).edge(2, 3, 1.0).build_undirected()
    }

    #[test]
    fn modularity_two_disjoint_edges() {
        // Known value: 0.5 (see module docs for the convention check).
        let g = two_pairs();
        let q = modularity(&g, &[0, 0, 1, 1]);
        assert!((q - 0.5).abs() < 1e-12, "q={q}");
    }

    #[test]
    fn modularity_single_community_is_zero() {
        // Q = σ/(2m) − (Σ/2m)² = 1 − 1 = 0 when all vertices share one
        // community.
        let g = two_pairs();
        let q = modularity(&g, &[0, 0, 0, 0]);
        assert!(q.abs() < 1e-12, "q={q}");
    }

    #[test]
    fn modularity_singletons_negative_or_zero() {
        let g = two_pairs();
        let q = modularity(&g, &[0, 1, 2, 3]);
        assert!(q < 0.0, "q={q}");
    }

    #[test]
    fn modularity_range_on_random_graphs() {
        for f in GraphFamily::ALL {
            let g = generate(f, 9, 11);
            let n = g.num_vertices();
            let singleton: Vec<u32> = (0..n as u32).collect();
            let q = modularity(&g, &singleton);
            assert!((-0.5..=1.0).contains(&q), "{f:?} q={q}");
        }
    }

    #[test]
    fn delta_modularity_matches_recomputation() {
        // Moving a vertex and recomputing Q from scratch must equal
        // Q_before + ΔQ (the fundamental Eq. 2 invariant).
        let g = generate(GraphFamily::Web, 8, 5);
        let n = g.num_vertices();
        let m = g.total_weight();
        // Random-ish initial membership: two halves.
        let mut memb: Vec<u32> = (0..n).map(|v| (v % 2) as u32).collect();
        let (sigma_dummy, big) = community_weights(&g, &memb);
        let _ = sigma_dummy;
        let q0 = modularity(&g, &memb);

        // Pick vertex 3, move 0 -> 1 (or 1 -> 0).
        let i = 3usize;
        let d = memb[i] as usize;
        let c = 1 - d;
        let mut k_to = [0f64; 2];
        for (t, w) in g.neighbours(i) {
            if t as usize == i {
                continue;
            }
            k_to[memb[t as usize] as usize] += w as f64;
        }
        let k_i = g.vertex_weight(i);
        let dq = delta_modularity(k_to[c], k_to[d], k_i, big[c], big[d], m);

        memb[i] = c as u32;
        let q1 = modularity(&g, &memb);
        assert!((q1 - q0 - dq).abs() < 1e-9, "q0={q0} q1={q1} dq={dq}");
    }

    #[test]
    fn community_weights_totals() {
        let g = generate(GraphFamily::Social, 8, 7);
        let n = g.num_vertices();
        let memb: Vec<u32> = (0..n).map(|v| (v % 5) as u32).collect();
        let (sigma, big) = community_weights(&g, &memb);
        let m = g.total_weight();
        // Σ over all c of Σ_c = 2m; σ_c ≤ Σ_c.
        assert!((big.iter().sum::<f64>() - 2.0 * m).abs() < 1e-9);
        for (s, b) in sigma.iter().zip(&big) {
            assert!(*s <= b + 1e-9);
        }
    }

    #[test]
    fn empty_graph_modularity_zero() {
        let g = Csr { offsets: vec![0], targets: vec![], weights: vec![] };
        assert_eq!(modularity(&g, &[]), 0.0);
    }
}
