//! Dendrogram lookup (Algorithm 1 lines 11 & 14).
//!
//! After each pass the top-level membership `C` (over the *original*
//! vertices) is re-pointed through the pass-level membership `C'` (over
//! the current super-vertices): `C[v] = C'[C[v]]`.

/// `top[v] = pass[top[v]]` for all original vertices.
pub fn lookup(top: &mut [u32], pass: &[u32]) {
    for c in top.iter_mut() {
        debug_assert!((*c as usize) < pass.len(), "dangling dendrogram pointer");
        *c = pass[*c as usize];
    }
}

/// Fold a whole dendrogram (list of per-pass memberships) into a flat
/// original-vertex membership.
pub fn flatten(levels: &[Vec<u32>]) -> Vec<u32> {
    match levels.split_first() {
        None => Vec::new(),
        Some((first, rest)) => {
            let mut top = first.clone();
            for pass in rest {
                lookup(&mut top, pass);
            }
            top
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_chains_memberships() {
        // 4 vertices -> 3 communities -> 2 communities.
        let mut top = vec![0, 1, 2, 1];
        let pass = vec![1, 0, 1];
        lookup(&mut top, &pass);
        assert_eq!(top, vec![1, 0, 1, 0]);
    }

    #[test]
    fn flatten_matches_sequential_lookup() {
        let levels = vec![vec![0, 1, 2, 1], vec![1, 0, 1], vec![0, 0]];
        let flat = flatten(&levels);
        assert_eq!(flat, vec![0, 0, 0, 0]);
    }

    #[test]
    fn flatten_single_level_is_copy() {
        let levels = vec![vec![3, 1, 4]];
        assert_eq!(flatten(&levels), vec![3, 1, 4]);
    }

    #[test]
    fn flatten_empty() {
        assert!(flatten(&[]).is_empty());
    }
}
