//! All tunables of GVE-Louvain (paper §4.1 / §4.3).

use crate::parallel::schedule::{Schedule, DEFAULT_CHUNK};

/// Which per-thread community table to use (§4.1.9, Fig 2 "hashtable").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TableKind {
    /// C++ `std::map`-style ordered map (the slow baseline, 4.4× worse).
    Map,
    /// Key-list + full-size values array, all threads' tables packed in
    /// one contiguous slab (NetworKit-style; false-sharing prone).
    CloseKv,
    /// Key-list + full-size values array, per-thread allocations far
    /// apart (the adopted design, 1.3× over Close-KV).
    FarKv,
}

impl TableKind {
    pub fn name(self) -> &'static str {
        match self {
            TableKind::Map => "map",
            TableKind::CloseKv => "close-kv",
            TableKind::FarKv => "far-kv",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "map" => Some(TableKind::Map),
            "close-kv" => Some(TableKind::CloseKv),
            "far-kv" => Some(TableKind::FarKv),
            _ => None,
        }
    }
}

/// How the aggregation phase stores intermediate structures
/// (§4.1.7–4.1.8, Fig 2 "CSR vs 2D").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AggregationKind {
    /// Preallocated CSRs + parallel prefix sum (the adopted design).
    Csr,
    /// `Vec<Vec<_>>` two-dimensional arrays (2.2× slower ablation).
    TwoDim,
}

/// Parameters of a Louvain run. `Default` is the paper's adopted
/// configuration (§4.1 / Fig 2).
#[derive(Clone, Debug)]
pub struct LouvainParams {
    pub max_passes: usize,
    /// Iteration cap per local-moving phase (§4.1.2: 20).
    pub max_iterations: usize,
    /// Initial per-iteration tolerance τ (§4.1.4: 0.01).
    pub tolerance: f64,
    /// Threshold-scaling drop rate (§4.1.3: 10; 1 disables).
    pub tolerance_drop: f64,
    /// Aggregation tolerance τ_agg (§4.1.5: 0.8; 1 disables).
    pub aggregation_tolerance: f64,
    /// Vertex pruning (§4.1.6).
    pub pruning: bool,
    /// OpenMP-style loop schedule (§4.1.1: dynamic, chunk 2048).
    pub schedule: Schedule,
    pub chunk: usize,
    pub threads: usize,
    pub table: TableKind,
    pub aggregation: AggregationKind,
    /// Record per-chunk work for the strong-scaling replay model.
    pub record_chunks: bool,
    pub seed: u64,
    /// Degree-aware scan engine (PR 6): rows with degree ≤ this scan
    /// into the stack-resident `SmallTable` instead of the Far-KV slab
    /// (no |V|-sized touch, no clear).  0 disables the fast path.
    /// Forced to 0 under `TableKind::Map` to keep the Fig 2 Map
    /// ablation pure.
    pub small_degree: usize,
    /// Bucket boundary for `Schedule::DegreeBucketed`: vertices with
    /// degree > this form the heavy tail, drained first with small
    /// dynamic chunks.  Clamped up to `small_degree`.
    pub hub_degree: usize,
    /// Lookahead distance (in neighbours) for the software prefetch of
    /// `membership[neighbour]` in the scan loops.  0 disables; a no-op
    /// on targets without a prefetch intrinsic.
    pub prefetch_distance: usize,
    /// Adaptive late-pass engine (PR 10): when true, each pass picks an
    /// effective width ≤ `threads` from the pass workload (directed
    /// edge slots vs `serial_pass_threshold` × `width_gain`), down to a
    /// dispatch-free serial fast path.  Off by default — fixed-width
    /// behaviour is bit-identical to earlier PRs, and adaptive runs are
    /// bit-identical to fixed-width runs anyway (asserted in
    /// `tests/late_pass.rs`); the knob only changes scheduling.
    pub adaptive_width: bool,
    /// Passes with at most this many directed edge slots run serially
    /// on the calling thread (no team dispatch, no barrier, worker-0
    /// scratch) when `adaptive_width` is on.
    pub serial_pass_threshold: usize,
    /// Directed edge slots each additional worker must pay for, in
    /// units of `serial_pass_threshold`: the cost model grants
    /// `ceil(edges / (serial_pass_threshold × width_gain))` workers.
    /// Larger values shrink the team sooner.
    pub width_gain: f64,
}

impl Default for LouvainParams {
    fn default() -> Self {
        Self {
            max_passes: 10,
            max_iterations: 20,
            tolerance: 0.01,
            tolerance_drop: 10.0,
            aggregation_tolerance: 0.8,
            pruning: true,
            schedule: Schedule::Dynamic,
            chunk: DEFAULT_CHUNK,
            threads: 1,
            table: TableKind::FarKv,
            aggregation: AggregationKind::Csr,
            record_chunks: false,
            seed: 42,
            small_degree: 16,
            hub_degree: 256,
            prefetch_distance: 8,
            adaptive_width: false,
            serial_pass_threshold: 8192,
            width_gain: 1.0,
        }
    }
}

impl LouvainParams {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads, ..Self::default() }
    }

    /// The naive configuration Fig 2 ablates against: no pruning, no
    /// threshold scaling, strict tolerance, no aggregation tolerance.
    pub fn naive() -> Self {
        Self {
            max_iterations: 100,
            tolerance: 1e-6,
            tolerance_drop: 1.0,
            aggregation_tolerance: 1.0,
            pruning: false,
            schedule: Schedule::Static,
            table: TableKind::Map,
            aggregation: AggregationKind::TwoDim,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_adopted_values() {
        let p = LouvainParams::default();
        assert_eq!(p.max_iterations, 20);
        assert_eq!(p.tolerance, 0.01);
        assert_eq!(p.tolerance_drop, 10.0);
        assert_eq!(p.aggregation_tolerance, 0.8);
        assert!(p.pruning);
        assert_eq!(p.schedule, Schedule::Dynamic);
        assert_eq!(p.chunk, 2048);
        assert_eq!(p.table, TableKind::FarKv);
        assert_eq!(p.aggregation, AggregationKind::Csr);
        assert_eq!(p.small_degree, 16);
        assert_eq!(p.hub_degree, 256);
        assert_eq!(p.prefetch_distance, 8);
        assert!(!p.adaptive_width);
        assert_eq!(p.serial_pass_threshold, 8192);
        assert_eq!(p.width_gain, 1.0);
    }

    #[test]
    fn table_kind_parse_round_trips() {
        for k in [TableKind::Map, TableKind::CloseKv, TableKind::FarKv] {
            assert_eq!(TableKind::parse(k.name()), Some(k));
        }
        assert_eq!(TableKind::parse("bogus"), None);
    }

    #[test]
    fn naive_disables_optimizations() {
        let p = LouvainParams::naive();
        assert!(!p.pruning);
        assert_eq!(p.tolerance_drop, 1.0);
        assert_eq!(p.aggregation_tolerance, 1.0);
        assert_eq!(p.table, TableKind::Map);
    }
}
