//! Incrementally-seeded Louvain over evolving graphs (PR 2 tentpole).
//!
//! Static GVE-Louvain recomputes communities from scratch after every
//! change, throwing away two things the codebase already maintains: the
//! previous run's membership, and the per-vertex `affected` pruning
//! flags of Algorithm 2 (hardwired to all-1 by the static driver).
//! [`DynamicLouvain`] retains the membership across a batch timeline
//! and re-enters the pass loop through
//! [`GveLouvain::run_seeded`] with one of three seeding strategies —
//! the protocol of Sahu, "Enhancing Efficiency in Parallel Louvain
//! Algorithm for Community Detection" (arXiv:2301.12390), whose
//! vertex-pruning lineage traces to Lu & Halappanavar
//! (arXiv:1410.1237):
//!
//! * [`SeedStrategy::FullRecompute`] — the static baseline: singleton
//!   start, every vertex affected.
//! * [`SeedStrategy::NaiveDynamic`] — warm start from the previous
//!   membership, every vertex affected.  Converges in far fewer
//!   iterations because most vertices have nowhere better to go.
//! * [`SeedStrategy::DeltaScreening`] — warm start *and* a screened
//!   `affected` seed: only vertices that a batch edge could actually
//!   move are processed; everything else is pruned on sight.
//!
//! ## Screening rule (affected-flag contract)
//!
//! A change *qualifies* when it can make someone's current community
//! suboptimal: an **insertion** `(u, v)` joining *different*
//! communities (the new edge tempts either endpoint across), or a
//! **deletion** `(u, v)` inside *one* community (the community may no
//! longer be worth staying in).  Intra-community insertions and
//! inter-community deletions only reinforce the current assignment and
//! mark nothing.  Each qualifying change marks `u`, `v` and their
//! immediate neighbourhoods.
//!
//! Where the literature rule (Zarayeneh-style screening) additionally
//! marks *entire communities* of the endpoints, this implementation
//! delegates community-wide effects to the move-propagation marking
//! that [`local_moving`](super::local_moving::local_moving) already
//! performs — a marked vertex that moves re-marks its neighbours, so
//! the affected set grows exactly as far as the perturbation actually
//! propagates.  This is deliberate: the planted families have few,
//! large communities (tens of communities of hundreds of members), so
//! wholesale community marking degenerates to the naive-dynamic seed
//! on every realistic batch; frontier-based growth keeps the seed
//! proportional to the perturbation instead.  Seeding is still a
//! superset heuristic, not exact — quality is pinned to full
//! recompute within ε by `tests/dynamic_louvain.rs`.
//!
//! `affected` seeds require `params.pruning` (the default); with
//! pruning off the flags are ignored and delta screening degenerates
//! to naive-dynamic.

use super::gve::{GveLouvain, LouvainResult, PassSeed};
use super::params::LouvainParams;
use crate::graph::delta::EdgeBatch;
use crate::graph::Csr;
use crate::parallel::atomics::as_atomic_u32;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How a [`DynamicLouvain`] seeds each batch's run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SeedStrategy {
    /// Static baseline: rerun GVE-Louvain from singletons.
    FullRecompute,
    /// Warm-start membership, all vertices affected.
    NaiveDynamic,
    /// Warm-start membership, screened affected flags.
    DeltaScreening,
}

impl SeedStrategy {
    pub fn name(self) -> &'static str {
        match self {
            SeedStrategy::FullRecompute => "full",
            SeedStrategy::NaiveDynamic => "naive-dynamic",
            SeedStrategy::DeltaScreening => "delta-screening",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => Some(SeedStrategy::FullRecompute),
            "naive-dynamic" | "naive" => Some(SeedStrategy::NaiveDynamic),
            "delta-screening" | "delta" => Some(SeedStrategy::DeltaScreening),
            _ => None,
        }
    }

    pub const ALL: [SeedStrategy; 3] = [
        SeedStrategy::FullRecompute,
        SeedStrategy::NaiveDynamic,
        SeedStrategy::DeltaScreening,
    ];
}

/// One batch update's outcome.
#[derive(Debug)]
pub struct DynamicOutcome {
    pub result: LouvainResult,
    pub strategy: SeedStrategy,
    /// Vertices seeded as affected (`|V|` for full / naive-dynamic;
    /// the screened count for delta screening).
    pub affected_seeded: usize,
}

/// Louvain driver for evolving graphs: owns the algorithm object (and
/// through it the persistent team + zero-allocation pass workspace),
/// retains the previous membership, and reruns after each batch with
/// the configured [`SeedStrategy`].
pub struct DynamicLouvain {
    strategy: SeedStrategy,
    algo: GveLouvain,
    /// Previous run's full-resolution membership (dense ids).
    membership: Option<Vec<u32>>,
    /// Screened pruning seed (reused across batches).
    affected: Vec<u32>,
}

impl DynamicLouvain {
    pub fn new(params: LouvainParams, strategy: SeedStrategy) -> Self {
        Self {
            strategy,
            algo: GveLouvain::new(params),
            membership: None,
            affected: Vec::new(),
        }
    }

    pub fn strategy(&self) -> SeedStrategy {
        self.strategy
    }

    pub fn params(&self) -> &LouvainParams {
        &self.algo.params
    }

    /// Previous run's membership, if any run has completed.
    pub fn membership(&self) -> Option<&[u32]> {
        self.membership.as_deref()
    }

    /// OS workers spawned by the owned team — O(1) across the whole
    /// timeline, like the static driver across passes.
    pub fn spawned_workers(&self) -> usize {
        self.algo.spawned_workers()
    }

    /// Crate-internal: run `f` on the algorithm's persistent team (see
    /// [`GveLouvain::with_team_exec`]) — the service applies batches
    /// and computes snapshot stats on the same workers detection uses.
    pub(crate) fn with_team_exec<R>(
        &self,
        f: impl FnOnce(crate::parallel::team::Exec<'_>, crate::parallel::pool::ParallelOpts) -> R,
    ) -> R {
        self.algo.with_team_exec(f)
    }

    /// Initial full run on `g` (every strategy starts cold).
    pub fn run_initial(&mut self, g: &Csr) -> LouvainResult {
        let out = self.algo.run(g);
        self.membership = Some(out.membership.clone());
        out
    }

    /// Re-detect communities on `g`, the graph *after* `batch` was
    /// applied (see [`Csr::apply_batch`]).  A *grown* vertex set (batch
    /// ops referencing new ids — see `graph::delta`) stays warm: new
    /// vertices enter as singletons with their own (unused, in-range)
    /// community id.  Falls back to a full run only when no previous
    /// state exists or the graph shrank.
    pub fn update(&mut self, g: &Csr, batch: &EdgeBatch) -> DynamicOutcome {
        let n = g.num_vertices();
        if let Some(m) = self.membership.as_mut() {
            // Vertex growth (PR 3): id v >= old |V| exceeds every
            // previous dense community id, so `C[v] = v` is a fresh
            // singleton and the seed contract (ids < |V|) holds.
            if m.len() < n {
                m.extend(m.len() as u32..n as u32);
            }
        }
        let warm = self
            .membership
            .as_ref()
            .map(|m| m.len() == n)
            .unwrap_or(false);
        let (result, affected_seeded) = if !warm || self.strategy == SeedStrategy::FullRecompute {
            (self.algo.run(g), n)
        } else if self.strategy == SeedStrategy::NaiveDynamic {
            let prev = self.membership.as_ref().unwrap();
            let out = self
                .algo
                .run_seeded(g, PassSeed { membership: prev, affected: None });
            (out, n)
        } else {
            let marked = self.mark_affected(g, batch);
            let prev = self.membership.as_ref().unwrap();
            let out = self.algo.run_seeded(
                g,
                PassSeed { membership: prev, affected: Some(&self.affected) },
            );
            (out, marked)
        };
        self.membership = Some(result.membership.clone());
        DynamicOutcome { result, strategy: self.strategy, affected_seeded }
    }

    /// Apply the screening rule (module docs) into `self.affected`;
    /// returns the number of marked vertices.
    ///
    /// Runs on the algorithm's persistent team (PR 3 satellite —
    /// previously a serial O(n + Σ deg(endpoint)) scan): the zero-fill,
    /// the per-change marking and the final count are chunked loops;
    /// marks are relaxed atomic stores (same-value races are benign,
    /// the idiom of the renumbering flag pass).
    fn mark_affected(&mut self, g: &Csr, batch: &EdgeBatch) -> usize {
        let n = g.num_vertices();
        let Self { algo, membership, affected, .. } = self;
        let prev: &[u32] = membership.as_deref().expect("screening needs a previous run");
        algo.with_team_exec(|exec, opts| {
            affected.resize(n, 0);
            exec.run_disjoint_mut(&mut affected[..], opts, |_r, chunk| chunk.fill(0));
            let flags = as_atomic_u32(&mut affected[..]);
            let mark = |v: usize| {
                flags[v].store(1, Ordering::Relaxed);
                for &t in g.edges(v).0 {
                    flags[t as usize].store(1, Ordering::Relaxed);
                }
            };
            let ins = &batch.insertions;
            exec.run(ins.len(), opts, |r| {
                for &(u, v, _w) in &ins[r] {
                    let (u, v) = (u as usize, v as usize);
                    if prev[u] != prev[v] {
                        mark(u);
                        mark(v);
                    }
                }
            });
            let dels = &batch.deletions;
            exec.run(dels.len(), opts, |r| {
                for &(u, v) in &dels[r] {
                    let (u, v) = (u as usize, v as usize);
                    if prev[u] == prev[v] {
                        mark(u);
                        if u != v {
                            mark(v);
                        }
                    }
                }
            });
            let total = AtomicUsize::new(0);
            exec.run(n, opts, |r| {
                let local: usize =
                    r.map(|i| flags[i].load(Ordering::Relaxed) as usize).sum();
                total.fetch_add(local, Ordering::Relaxed);
            });
            total.into_inner()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{churn_batch, generate, GraphFamily};
    use crate::parallel::pool::ParallelOpts;
    use crate::parallel::team::Exec;

    fn two_triangles() -> Csr {
        GraphBuilder::new(6)
            .edge(0, 1, 1.0).edge(1, 2, 1.0).edge(0, 2, 1.0)
            .edge(3, 4, 1.0).edge(4, 5, 1.0).edge(3, 5, 1.0)
            .edge(2, 3, 1.0)
            .build_undirected()
    }

    #[test]
    fn update_without_initial_run_falls_back_to_full() {
        let g = two_triangles();
        let mut dl = DynamicLouvain::new(LouvainParams::default(), SeedStrategy::DeltaScreening);
        let out = dl.update(&g, &EdgeBatch::new());
        assert_eq!(out.affected_seeded, g.num_vertices());
        assert_eq!(out.result.num_communities, 2);
        assert!(dl.membership().is_some());
    }

    #[test]
    fn empty_batch_preserves_partition_under_screening() {
        let g = two_triangles();
        let mut dl = DynamicLouvain::new(LouvainParams::default(), SeedStrategy::DeltaScreening);
        let first = dl.run_initial(&g);
        let out = dl.update(&g, &EdgeBatch::new());
        assert_eq!(out.affected_seeded, 0, "empty batch must screen everything out");
        assert_eq!(out.result.num_communities, first.num_communities);
        assert!((out.result.modularity - first.modularity).abs() < 1e-12);
        // Same partition up to labels.
        for (a, b) in [(0usize, 1usize), (1, 2), (3, 4)] {
            assert_eq!(
                first.membership[a] == first.membership[b],
                out.result.membership[a] == out.result.membership[b]
            );
        }
    }

    #[test]
    fn screening_marks_endpoints_and_their_neighbourhoods() {
        let g = two_triangles();
        let mut dl = DynamicLouvain::new(LouvainParams::default(), SeedStrategy::DeltaScreening);
        dl.run_initial(&g);
        // Delete an intra-community edge of the {0,1,2} triangle.
        let g2 = {
            let mut b = EdgeBatch::new();
            b.delete(0, 1);
            g.apply_batch(&b, ParallelOpts::default(), Exec::scoped())
        };
        let mut b = EdgeBatch::new();
        b.delete(0, 1);
        let marked = dl.mark_affected(&g2, &b);
        // Endpoints 0 and 1 plus their shared neighbour 2 are marked;
        // the other triangle stays screened out entirely.
        assert!(dl.affected[0] == 1 && dl.affected[1] == 1 && dl.affected[2] == 1);
        assert_eq!(dl.affected[3], 0);
        assert_eq!(dl.affected[4], 0);
        assert_eq!(dl.affected[5], 0);
        assert_eq!(marked, 3);
    }

    #[test]
    fn parallel_marking_matches_the_serial_rule() {
        // Oracle: the screening rule applied serially.
        fn serial_mark(g: &Csr, prev: &[u32], batch: &EdgeBatch) -> Vec<u32> {
            let mut affected = vec![0u32; g.num_vertices()];
            let mut mark = |v: usize, affected: &mut Vec<u32>| {
                affected[v] = 1;
                for &t in g.edges(v).0 {
                    affected[t as usize] = 1;
                }
            };
            for &(u, v, _w) in &batch.insertions {
                if prev[u as usize] != prev[v as usize] {
                    mark(u as usize, &mut affected);
                    mark(v as usize, &mut affected);
                }
            }
            for &(u, v) in &batch.deletions {
                if prev[u as usize] == prev[v as usize] {
                    mark(u as usize, &mut affected);
                    if u != v {
                        mark(v as usize, &mut affected);
                    }
                }
            }
            affected
        }

        let g0 = generate(GraphFamily::Web, 10, 3);
        let b = churn_batch(&g0, 0.02, 11);
        let g1 = g0.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        for threads in [1usize, 4] {
            let params = LouvainParams { threads, ..Default::default() };
            let mut dl = DynamicLouvain::new(params, SeedStrategy::DeltaScreening);
            dl.run_initial(&g0);
            let prev = dl.membership().unwrap().to_vec();
            let want = serial_mark(&g1, &prev, &b);
            let marked = dl.mark_affected(&g1, &b);
            assert_eq!(dl.affected, want, "threads={threads}");
            assert_eq!(marked, want.iter().map(|&a| a as usize).sum::<usize>());
        }
    }

    #[test]
    fn vertex_growth_warm_starts_instead_of_full_recompute() {
        let g = two_triangles();
        let mut dl = DynamicLouvain::new(LouvainParams::default(), SeedStrategy::DeltaScreening);
        dl.run_initial(&g);
        // Attach a brand-new vertex 6 to the {3,4,5} triangle; the
        // batch itself grows the graph (PR 3).
        let mut b = EdgeBatch::new();
        b.insert(5, 6, 2.0);
        b.insert(4, 6, 2.0);
        let g2 = g.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        assert_eq!(g2.num_vertices(), 7);
        let out = dl.update(&g2, &b);
        assert_eq!(out.result.membership.len(), 7);
        // Still screened — not a cold full-recompute fallback: the
        // untouched {0,1,2} triangle stays out of the seed.
        assert!(
            out.affected_seeded < g2.num_vertices(),
            "growth fell back to full (seeded {})",
            out.affected_seeded
        );
        // The newcomer joins its neighbours' community.
        assert_eq!(out.result.membership[6], out.result.membership[5]);
        assert_eq!(out.result.num_communities, 2);
    }

    #[test]
    fn strategies_agree_on_a_small_timeline() {
        let g0 = generate(GraphFamily::Web, 9, 13);
        let mut graphs = Vec::new();
        let mut batches = Vec::new();
        let mut cur = g0.clone();
        for i in 0..4 {
            let b = churn_batch(&cur, 0.01, 100 + i);
            cur = cur.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
            graphs.push(cur.clone());
            batches.push(b);
        }
        let mut finals = Vec::new();
        for strategy in SeedStrategy::ALL {
            let mut dl = DynamicLouvain::new(LouvainParams::default(), strategy);
            dl.run_initial(&g0);
            let mut q = 0.0;
            for (gi, b) in graphs.iter().zip(&batches) {
                let out = dl.update(gi, b);
                q = out.result.modularity;
                assert_eq!(out.result.membership.len(), gi.num_vertices());
            }
            finals.push(q);
        }
        // Warm-started strategies stay within ε of the full recompute.
        assert!((finals[1] - finals[0]).abs() < 0.02, "naive vs full: {finals:?}");
        assert!((finals[2] - finals[0]).abs() < 0.02, "delta vs full: {finals:?}");
    }

    #[test]
    fn delta_screening_seeds_fewer_vertices() {
        // Sparse family: the screened seed must be a small fraction of
        // the graph (dense families can saturate at high churn — the
        // win there comes from the warm start).
        let g0 = generate(GraphFamily::Road, 11, 29);
        let b = churn_batch(&g0, 0.01, 7);
        let g1 = g0.apply_batch(&b, ParallelOpts::default(), Exec::scoped());
        let mut dl = DynamicLouvain::new(LouvainParams::default(), SeedStrategy::DeltaScreening);
        dl.run_initial(&g0);
        let out = dl.update(&g1, &b);
        assert!(
            out.affected_seeded * 2 < g1.num_vertices(),
            "screening marked too much ({} of {})",
            out.affected_seeded,
            g1.num_vertices()
        );
        assert!(out.affected_seeded > 0, "a non-empty churn batch must mark something");
    }
}
