//! GVE-Louvain driver (Algorithm 1): the pass loop tying together
//! local-moving, renumbering, dendrogram lookup and aggregation, with
//! threshold scaling and the aggregation tolerance.
//!
//! Runtime resources live in a [`LouvainWorkspace`]: one persistent
//! worker [`Team`](crate::parallel::team::Team) (OS-thread spawns are
//! O(1) per run, not per loop), one
//! [`TablePool`](super::hashtable::TablePool) and one set of pass
//! buffers sized by the first pass and logically shrunk afterwards.
//! Repeated `run` calls on the same object reuse all of it.

use super::aggregation::{aggregate_2d_with, aggregate_csr_into, AggInfo};
use super::local_moving::local_moving;
use super::modularity::modularity;
use super::params::{AggregationKind, LouvainParams};
use super::renumber::renumber_communities_exec;
use super::workspace::{begin_pass_par, begin_pass_seeded, LouvainWorkspace};
use super::Counters;
use crate::graph::Csr;
use crate::parallel::pool::{ChunkRecord, ParallelOpts};
use crate::parallel::scatter::scatter_add_f64;
use crate::parallel::schedule::Schedule;
use crate::parallel::team::Exec;
use crate::trace;
use std::sync::Mutex;
use std::time::Instant;

/// Per-pass statistics (feeds Figs 14/17: phase and pass splits).
#[derive(Clone, Debug, Default)]
pub struct PassStats {
    /// Vertices of `G'` at this pass.
    pub vertices: usize,
    /// Directed edge slots of `G'` at this pass.
    pub edges: usize,
    /// Local-moving iterations (`l_i`).
    pub iterations: usize,
    /// Communities after this pass's local-moving.
    pub communities: usize,
    pub move_ns: u64,
    pub agg_ns: u64,
    pub other_ns: u64,
    /// Width this pass actually ran at (PR 10): `params.threads` for
    /// fixed-width runs; the cost model's pick — down to 1 for the
    /// dispatch-free serial fast path — when `adaptive_width` is on.
    pub effective_threads: usize,
    /// Total accepted ΔQ.
    pub dq: f64,
    /// Work-counter delta of *this pass* (move + aggregation; PR 7 —
    /// run-global totals in [`LouvainResult::counters`] are the sum of
    /// these).  Surfaces the per-pass small-path fraction the paper's
    /// shrinking-workload argument needs.
    pub counters: Counters,
}

/// Result of a full Louvain run.
#[derive(Debug, Default)]
pub struct LouvainResult {
    /// Final community of every original vertex (dense ids).
    pub membership: Vec<u32>,
    /// Modularity of `membership` on the input graph.
    pub modularity: f64,
    pub num_communities: usize,
    pub passes: usize,
    pub total_ns: u64,
    pub pass_stats: Vec<PassStats>,
    pub counters: Counters,
    /// Recorded parallel loops (for the scaling replay model).
    pub loops: Vec<(Schedule, Vec<ChunkRecord>)>,
    /// Wall time not covered by recorded parallel loops.
    pub serial_ns: u64,
}

impl LouvainResult {
    /// Phase split: `(move, aggregate, other)` fractions of total time.
    pub fn phase_split(&self) -> (f64, f64, f64) {
        let mv: u64 = self.pass_stats.iter().map(|p| p.move_ns).sum();
        let ag: u64 = self.pass_stats.iter().map(|p| p.agg_ns).sum();
        let tot = self.total_ns.max(1) as f64;
        let (mv, ag) = (mv as f64, ag as f64);
        (mv / tot, ag / tot, ((tot - mv - ag) / tot).max(0.0))
    }

    /// Fraction of runtime spent in the first pass.
    pub fn first_pass_fraction(&self) -> f64 {
        let first = self
            .pass_stats
            .first()
            .map(|p| p.move_ns + p.agg_ns + p.other_ns)
            .unwrap_or(0) as f64;
        first / self.total_ns.max(1) as f64
    }
}

/// First-pass seed for warm-started runs (see
/// [`GveLouvain::run_seeded`] and [`louvain::dynamic`](super::dynamic)).
#[derive(Clone, Copy, Debug)]
pub struct PassSeed<'a> {
    /// Initial pass-0 membership: one (dense, in-range) community id
    /// per vertex — typically the previous run's result.
    pub membership: &'a [u32],
    /// Initial pass-0 pruning flags (1 = process); `None` = all-1.
    /// Only honoured when `params.pruning` is on (the flags *are* the
    /// pruning machinery).
    pub affected: Option<&'a [u32]>,
}

/// The GVE-Louvain algorithm object.
///
/// Owns a [`LouvainWorkspace`] behind a `Mutex` (so the object stays
/// `Sync`): the persistent worker team, the
/// [`TablePool`](super::hashtable::TablePool) and all pass buffers are
/// built on the first `run` and reused by every pass and every
/// subsequent `run`.
pub struct GveLouvain {
    pub params: LouvainParams,
    workspace: Mutex<LouvainWorkspace>,
}

impl GveLouvain {
    pub fn new(params: LouvainParams) -> Self {
        Self { params, workspace: Mutex::new(LouvainWorkspace::new()) }
    }

    /// OS worker threads spawned by this object so far — stays at
    /// `threads - 1` regardless of passes, iterations or repeated
    /// runs (the O(1)-spawn guarantee; asserted by tests).
    pub fn spawned_workers(&self) -> usize {
        self.lock_workspace().spawned_workers()
    }

    /// Run on `g`; returns the result with full metrics.
    pub fn run(&self, g: &Csr) -> LouvainResult {
        let mut ws = self.lock_workspace();
        self.run_in(g, &mut ws, None)
    }

    /// Run on `g` with a warm-started first pass (the
    /// [`louvain::dynamic`](super::dynamic) entry point): pass 0 begins
    /// from `seed.membership` instead of singletons, with Σ' rebuilt by
    /// a parallel scatter-add, and — when `seed.affected` is given and
    /// pruning is on — only the flagged vertices are processed until
    /// moves propagate the flags outward.  Passes ≥ 1 are ordinary
    /// GVE-Louvain.
    pub fn run_seeded(&self, g: &Csr, seed: PassSeed<'_>) -> LouvainResult {
        let mut ws = self.lock_workspace();
        self.run_in(g, &mut ws, Some(seed))
    }

    /// Run `f` with this object's persistent team executor and the
    /// run's (unrecorded) loop options, building the team on first use.
    /// Crate-internal hook for helpers that piggyback on the workspace
    /// *between* runs — the delta-screening marking pass and the
    /// service snapshot stats — so they parallelize on the same workers
    /// as the pass loop instead of spawning their own.
    pub(crate) fn with_team_exec<R>(&self, f: impl FnOnce(Exec<'_>, ParallelOpts) -> R) -> R {
        let mut ws = self.lock_workspace();
        ws.ensure_team(self.params.threads);
        let opts = ParallelOpts {
            threads: self.params.threads,
            schedule: self.params.schedule,
            chunk: self.params.chunk,
            record: false,
        };
        f(Exec::team(ws.team.as_deref().expect("ensure_team built the team")), opts)
    }

    /// Poison-tolerant workspace lock: a caught-and-reraised worker
    /// panic mid-run must not turn this object permanently dead — the
    /// workspace holds no invariants a panic can break (every pass
    /// rebuilds buffer contents from scratch; the team survives panics
    /// by design).
    fn lock_workspace(&self) -> std::sync::MutexGuard<'_, LouvainWorkspace> {
        self.workspace.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn run_in(&self, g: &Csr, ws: &mut LouvainWorkspace, seed: Option<PassSeed<'_>>) -> LouvainResult {
        let p = &self.params;
        let t_start = Instant::now();
        let n0 = g.num_vertices();
        let m = g.total_weight();
        let mut result = LouvainResult {
            membership: (0..n0 as u32).collect(),
            ..Default::default()
        };
        if n0 == 0 || m == 0.0 {
            result.num_communities = n0;
            return result;
        }
        if let Some(s) = &seed {
            assert_eq!(s.membership.len(), n0, "seed membership length != |V|");
            if let Some(a) = s.affected {
                assert_eq!(a.len(), n0, "seed affected length != |V|");
            }
            // Real assert, not debug: local_moving does unchecked Σ'
            // indexing on the strength of this contract (O(n) once per
            // seeded run — negligible).
            assert!(
                s.membership.iter().all(|&c| (c as usize) < n0),
                "seed membership contains a community id >= |V|"
            );
        }

        // All runtime resources up front: one team, one pool (sized by
        // the input graph — the largest pass), reused below.  The
        // split-borrow destructuring lets the pass loop hold the team
        // and pool alongside `&mut` pass buffers *and* read one slot of
        // the super-graph ping-pong pair while aggregation writes the
        // other.
        ws.prepare(p, n0);
        let LouvainWorkspace {
            team,
            pool,
            k,
            sigma,
            membership,
            affected,
            agg,
            super_a,
            super_b,
            renumber_scratch,
            scan_order,
        } = ws;
        let team = team.as_deref().expect("prepare built the team");
        let exec = Exec::team(team);
        let pool = pool.as_ref().expect("prepare built the pool");

        let opts = ParallelOpts {
            threads: p.threads,
            schedule: p.schedule,
            chunk: p.chunk,
            record: p.record_chunks,
        };
        // Unrecorded variant for bookkeeping loops (init / renumber /
        // scatter) so the Fig 16 replay keeps its PR-1 loop inventory.
        let aux_opts = ParallelOpts { record: false, ..opts };
        let mut tau = p.tolerance;

        // Adaptive late-pass engine (PR 10): snapshot the team's
        // cumulative per-worker busy slots around each pass; the deltas
        // feed the next pass's width choice.
        let mut busy_before = if p.adaptive_width { team.worker_busy_ns() } else { Vec::new() };
        let mut prev_busy: Option<Vec<u64>> = None;

        for pass in 0..p.max_passes {
            // Super-vertex graph ping-pong: read one slot, aggregate
            // into the other — no per-pass graph allocation.
            let (gp, next): (&Csr, &mut Csr) = if pass == 0 {
                (g, &mut *super_a)
            } else if pass % 2 == 1 {
                (&*super_a, &mut *super_b)
            } else {
                (&*super_b, &mut *super_a)
            };
            let np = gp.num_vertices();

            // Pick this pass's effective width (PR 10).  `w == threads`
            // with identical params/opts/exec when adaptive is off; the
            // serial fast path swaps in the inline scoped executor — no
            // dispatch, no barrier, no `team.job` span, and (at one
            // thread) bit-identical chunk dealing to the team path.
            let w = choose_width(p, pass, np, gp.num_edges(), prev_busy.as_deref());
            let serial = p.adaptive_width && w == 1;
            let pass_params = LouvainParams { threads: w, ..p.clone() };
            let pass_opts = ParallelOpts { threads: w, ..opts };
            let pass_aux = ParallelOpts { record: false, ..pass_opts };
            let pass_exec = if serial { Exec::scoped() } else { exec };

            let t_pass = Instant::now();
            let _pass_span = trace::span(
                "pass",
                trace::Category::Pass,
                [pass as u64, np as u64, gp.num_edges() as u64, w as u64],
            );

            // Init: K', Σ', C' (Algorithm 1 lines 4-5) into the reused
            // pass buffers — all parallel loops now (identity /
            // affected fills included).  K' is recorded for the
            // scaling replay like the PR-1 layout expects.
            match (&seed, pass) {
                (Some(s), 0) => begin_pass_seeded(membership, affected, s.membership, s.affected),
                _ => begin_pass_par(membership, affected, np, pass_aux, pass_exec),
            }
            let stats = gp.vertex_weights_into(k, pass_opts, pass_exec);
            if p.record_chunks {
                result.loops.push((p.schedule, stats.chunks));
            }
            if seed.is_some() && pass == 0 {
                // Warm start: Σ'[c] = Σ K'[v] over members of c.
                sigma.clear();
                sigma.resize(np, 0.0);
                scatter_add_f64(&membership[..], &k[..], &mut sigma[..], pass_aux, pass_exec);
            } else {
                // Singleton start: Σ' is a copy of K'.
                sigma.clear();
                sigma.extend_from_slice(&k[..]);
            }

            // Degree-bucketed scheduling (PR 6): partition this pass's
            // vertex ids once into low/mid/high-degree buckets; the
            // local-moving iterations reuse the order unchanged.
            let order = if p.schedule == Schedule::DegreeBucketed {
                scan_order.build_exec(np, p.small_degree, p.hub_degree, |v| gp.degree(v), pass_aux, pass_exec);
                Some(&*scan_order)
            } else {
                None
            };

            // Local-moving phase (line 6).
            let t0 = Instant::now();
            let mut move_span = trace::span("move", trace::Category::Move, [pass as u64, 0, 0, 0]);
            let mv = local_moving(
                gp,
                &mut membership[..],
                &k[..],
                &mut sigma[..],
                &mut affected[..],
                pool,
                &pass_params,
                m,
                tau,
                order,
                pass_exec,
            );
            if let Some(g) = move_span.as_mut() {
                g.args = [pass as u64, mv.iterations as u64, mv.counters.moves_applied, 0];
            }
            drop(move_span);
            let move_ns = t0.elapsed().as_nanos() as u64;
            result.counters.merge(&mv.counters);
            result.loops.extend(mv.loops);

            // Community count + convergence checks (lines 7-9).
            let n_comm =
                renumber_communities_exec(&mut membership[..], renumber_scratch, pass_aux, pass_exec);
            let converged = mv.iterations <= 1;
            let low_shrink = (n_comm as f64) / (np as f64) > p.aggregation_tolerance;

            // Fold this pass into the top-level membership (lines 11/14;
            // a parallel loop in the paper, recorded for the replay).
            {
                let pass_memb: &[u32] = &membership[..];
                let stats = pass_exec.run_disjoint_mut(&mut result.membership, pass_opts, |_r, chunk| {
                    for c in chunk.iter_mut() {
                        *c = pass_memb[*c as usize];
                    }
                });
                if p.record_chunks {
                    result.loops.push((p.schedule, stats.chunks));
                }
            }

            let mut stats = PassStats {
                vertices: np,
                edges: gp.num_edges(),
                iterations: mv.iterations,
                communities: n_comm,
                move_ns,
                agg_ns: 0,
                other_ns: 0,
                effective_threads: w,
                dq: mv.dq_total,
                counters: mv.counters,
            };

            if converged || low_shrink || pass + 1 == p.max_passes {
                // Everything not covered by the move phase is "other".
                stats.other_ns =
                    (t_pass.elapsed().as_nanos() as u64).saturating_sub(stats.move_ns);
                snapshot_pass_counters(pass, &stats);
                result.pass_stats.push(stats);
                result.passes = pass + 1;
                break;
            }

            // Aggregation phase (line 12), on the same team with the
            // reused scratch, compacted into the other ping-pong slot.
            let t2 = Instant::now();
            let _agg_span =
                trace::span("agg", trace::Category::Agg, [pass as u64, n_comm as u64, 0, 0]);
            let agg_info = match p.aggregation {
                AggregationKind::Csr => aggregate_csr_into(
                    gp,
                    &membership[..],
                    n_comm,
                    pool,
                    &pass_params,
                    order,
                    pass_exec,
                    agg,
                    next,
                ),
                AggregationKind::TwoDim => {
                    let o = aggregate_2d_with(gp, &membership[..], n_comm, pool, &pass_params, pass_exec);
                    *next = o.graph;
                    AggInfo { counters: o.counters, loops: o.loops }
                }
            };
            drop(_agg_span);
            stats.agg_ns = t2.elapsed().as_nanos() as u64;
            // Full aggregation-counter merge (PR 7): the pass snapshot
            // and the run totals now both include the aggregation rows'
            // small/large path split (previously dropped run-globally).
            stats.counters.merge(&agg_info.counters);
            result.counters.merge(&agg_info.counters);
            result.loops.extend(agg_info.loops);

            // Threshold scaling (line 13).
            tau /= p.tolerance_drop;

            // Pass time not spent moving or aggregating — init,
            // renumber, fold *and* post-aggregation work (previously
            // dropped, skewing the Fig 14 phase split).
            stats.other_ns = (t_pass.elapsed().as_nanos() as u64)
                .saturating_sub(stats.move_ns + stats.agg_ns);
            snapshot_pass_counters(pass, &stats);
            result.pass_stats.push(stats);
            result.passes = pass + 1;

            // This pass's per-worker busy split, for the next width
            // choice.  A serial pass advances no team slot — the deltas
            // are all zero and the refinement guard skips them.
            if p.adaptive_width {
                let now = team.worker_busy_ns();
                prev_busy = Some(
                    now.iter().zip(&busy_before).map(|(a, b)| a.saturating_sub(*b)).collect(),
                );
                busy_before = now;
            }
        }

        result.num_communities =
            renumber_communities_exec(&mut result.membership, renumber_scratch, aux_opts, exec);
        // Detection time excludes the final quality evaluation (the paper
        // reports Q separately from runtime).
        result.total_ns = t_start.elapsed().as_nanos() as u64;
        result.modularity = modularity(g, &result.membership);
        let par_ns: u64 = result
            .loops
            .iter()
            .flat_map(|(_, c)| c.iter().map(|r| r.ns))
            .sum();
        result.serial_ns = result.total_ns.saturating_sub(par_ns);
        // Live-registry mirror (PR 8): one batch of counter adds per
        // *run* from the already-aggregated totals — the pass/iteration
        // hot paths record nothing registry-side — plus the workspace
        // byte gauges while the buffers are still borrowed-for-read.
        if crate::obs::enabled() {
            use crate::obs::sites;
            sites::louvain_runs().inc();
            sites::louvain_passes().add(result.passes as u64);
            sites::louvain_move_iterations()
                .add(result.pass_stats.iter().map(|s| s.iterations as u64).sum());
            sites::louvain_moves_applied().add(result.counters.moves_applied);
            sites::louvain_small_path_scans().add(result.counters.small_path_scans);
            sites::louvain_large_path_scans().add(result.counters.large_path_scans);
            ws.publish_mem_gauges();
        }
        result
    }
}

/// Workload-aware width policy (PR 10, the adaptive late-pass engine).
///
/// Inputs are the pass's super-graph size (|V'| and directed edge
/// slots) plus the previous pass's measured per-worker busy-ns split
/// from the [`Team`](crate::parallel::team::Team) stats slots.  Policy:
///
/// * adaptive off (the default) or `threads == 1`: always full width —
///   behaviour is byte-identical to earlier PRs.
/// * `edges <= serial_pass_threshold`: width 1, and the pass loop takes
///   the **serial fast path** (`Exec::scoped` at one thread — no
///   dispatch, no barrier, no `team.job`, worker-0 scratch).  Checked
///   on pass 0 too, so the threshold boundary is deterministic.
/// * pass 0 above the threshold: full width (the input graph is the
///   one workload the caller sized `threads` for).
/// * later passes: a linear model grants one worker per
///   `serial_pass_threshold × width_gain` units of demand
///   (`max(edges, |V'|)` — init/renumber loops are vertex-bound), then
///   a shrink-only refinement caps the width at the number of workers
///   the *previous* pass kept meaningfully busy (busy-ns within 8× of
///   the busiest), so a pass whose predecessor starved most of the
///   team does not wake it again.
///
/// Width only changes scheduling, never results: every pass loop is
/// order-deterministic per row at any width (asserted across families
/// and thread counts in `tests/late_pass.rs`).
fn choose_width(
    p: &LouvainParams,
    pass: usize,
    vertices: usize,
    edges: usize,
    prev_busy: Option<&[u64]>,
) -> usize {
    let full = p.threads.max(1);
    if !p.adaptive_width || full == 1 {
        return full;
    }
    if edges <= p.serial_pass_threshold {
        return 1;
    }
    if pass == 0 {
        return full;
    }
    let gain = if p.width_gain > 0.0 { p.width_gain } else { 1.0 };
    let unit = (p.serial_pass_threshold.max(1) as f64) * gain;
    let demand = edges.max(vertices);
    let mut w = ((demand as f64 / unit).ceil() as usize).clamp(1, full);
    if let Some(busy) = prev_busy {
        let top = busy.iter().copied().max().unwrap_or(0);
        if top > 0 {
            let active = busy.iter().filter(|&&b| b.saturating_mul(8) >= top).count();
            w = w.min(active.max(1));
        }
    }
    w
}

/// Emit the finished pass's `Counters` snapshot as a trace instant so a
/// Perfetto timeline carries the per-pass small/large path split — and,
/// since PR 10, the width the pass ran at — next to the `pass` span it
/// belongs to (PR 7).
fn snapshot_pass_counters(pass: usize, stats: &PassStats) {
    trace::instant(
        "pass.counters",
        trace::Category::Counter,
        [
            pass as u64,
            stats.effective_threads as u64,
            stats.counters.small_path_scans,
            stats.counters.large_path_scans,
        ],
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::params::TableKind;

    #[test]
    fn two_triangles_full_run() {
        let g = GraphBuilder::new(6)
            .edge(0, 1, 1.0).edge(1, 2, 1.0).edge(0, 2, 1.0)
            .edge(3, 4, 1.0).edge(4, 5, 1.0).edge(3, 5, 1.0)
            .edge(2, 3, 1.0)
            .build_undirected();
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(out.num_communities, 2);
        assert!((out.modularity - 0.35714).abs() < 1e-3, "q={}", out.modularity);
        assert_eq!(out.membership[0], out.membership[2]);
        assert_ne!(out.membership[0], out.membership[3]);
    }

    #[test]
    fn planted_web_graph_recovers_high_modularity() {
        let g = generate(GraphFamily::Web, 11, 42);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert!(out.modularity > 0.8, "web q={}", out.modularity);
        assert!(out.num_communities > 1);
        assert!(out.passes >= 1);
    }

    #[test]
    fn social_graph_gets_lower_modularity_than_web() {
        let web = GveLouvain::new(LouvainParams::default()).run(&generate(GraphFamily::Web, 10, 1));
        let soc = GveLouvain::new(LouvainParams::default()).run(&generate(GraphFamily::Social, 10, 1));
        assert!(
            web.modularity > soc.modularity + 0.1,
            "web={} social={}",
            web.modularity,
            soc.modularity
        );
    }

    #[test]
    fn road_graph_many_communities() {
        let g = generate(GraphFamily::Road, 12, 2);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert!(out.modularity > 0.6, "road q={}", out.modularity);
        assert!(out.num_communities > 20, "communities={}", out.num_communities);
    }

    #[test]
    fn membership_is_dense_and_in_range() {
        let g = generate(GraphFamily::Kmer, 10, 3);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        let max = *out.membership.iter().max().unwrap() as usize;
        assert_eq!(max + 1, out.num_communities);
    }

    #[test]
    fn deterministic_single_thread() {
        let g = generate(GraphFamily::Web, 10, 7);
        let a = GveLouvain::new(LouvainParams::default()).run(&g);
        let b = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(a.membership, b.membership);
        assert_eq!(a.modularity, b.modularity);
        assert_eq!(a.passes, b.passes);
    }

    #[test]
    fn pass_stats_cover_runtime() {
        let g = generate(GraphFamily::Web, 10, 9);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(out.pass_stats.len(), out.passes);
        let (mv, ag, other) = out.phase_split();
        assert!((mv + ag + other - 1.0).abs() < 1e-6);
        assert!(mv > 0.0);
        assert!(out.first_pass_fraction() > 0.0);
        // First pass has the full graph.
        assert_eq!(out.pass_stats[0].vertices, g.num_vertices());
    }

    #[test]
    fn empty_and_trivial_graphs() {
        let empty = Csr { offsets: vec![0], targets: vec![], weights: vec![] };
        let out = GveLouvain::new(LouvainParams::default()).run(&empty);
        assert_eq!(out.num_communities, 0);

        let lonely = GraphBuilder::new(3).build_undirected();
        let out = GveLouvain::new(LouvainParams::default()).run(&lonely);
        assert_eq!(out.num_communities, 3); // no edges: everyone alone
    }

    #[test]
    fn naive_params_still_correct_but_more_work() {
        let g = generate(GraphFamily::Web, 10, 11);
        let fast = GveLouvain::new(LouvainParams::default()).run(&g);
        let naive = GveLouvain::new(LouvainParams { table: TableKind::FarKv, ..LouvainParams::naive() }).run(&g);
        assert!((fast.modularity - naive.modularity).abs() < 0.05,
                "fast={} naive={}", fast.modularity, naive.modularity);
        // The naive config runs more local-moving iterations.
        let fast_iters: usize = fast.pass_stats.iter().map(|p| p.iterations).sum();
        let naive_iters: usize = naive.pass_stats.iter().map(|p| p.iterations).sum();
        assert!(naive_iters >= fast_iters);
    }

    #[test]
    fn aggregation_tolerance_stops_early() {
        let g = generate(GraphFamily::Social, 10, 13);
        let strict = GveLouvain::new(LouvainParams { aggregation_tolerance: 1.0, ..Default::default() }).run(&g);
        let loose = GveLouvain::new(LouvainParams { aggregation_tolerance: 0.5, ..Default::default() }).run(&g);
        assert!(loose.passes <= strict.passes);
    }

    #[test]
    fn multithreaded_quality_close_to_single() {
        let g = generate(GraphFamily::Web, 11, 17);
        let q1 = GveLouvain::new(LouvainParams::with_threads(1)).run(&g).modularity;
        let q4 = GveLouvain::new(LouvainParams::with_threads(4)).run(&g).modularity;
        assert!((q1 - q4).abs() < 0.02, "q1={q1} q4={q4}");
    }

    #[test]
    fn os_spawns_are_o1_per_run_and_resources_reused() {
        // A multi-pass, multi-iteration 4-thread run must spawn exactly
        // `threads - 1` OS workers, once — not per pass / iteration /
        // loop — and the TablePool plus pass buffers must be allocated
        // once and reused (stable storage pointers).
        let g = generate(GraphFamily::Social, 11, 5);
        let algo = GveLouvain::new(LouvainParams::with_threads(4));
        let out = algo.run(&g);
        // Many parallel loops ran: passes × (iterations + init + fold +
        // aggregation sub-loops); the scoped path would have spawned
        // threads for every one of them.
        let iters: usize = out.pass_stats.iter().map(|p| p.iterations).sum();
        assert!(out.passes * (iters + 2) >= 3, "degenerate run");
        assert_eq!(algo.spawned_workers(), 3, "spawns must be O(1) in passes/iterations");

        let (pool_ptr, k_ptr) = {
            let ws = algo.workspace.lock().unwrap();
            (ws.pool.as_ref().unwrap().storage_ptr(0), ws.k.as_ptr())
        };
        // A second run on the same object reuses workers, pool and buffers.
        let out2 = algo.run(&g);
        assert_eq!(algo.spawned_workers(), 3);
        {
            let ws = algo.workspace.lock().unwrap();
            assert_eq!(ws.pool.as_ref().unwrap().storage_ptr(0), pool_ptr);
            assert_eq!(ws.k.as_ptr(), k_ptr);
        }
        // And still produces a sane result.
        assert!((out.modularity - out2.modularity).abs() < 0.05);
    }

    #[test]
    fn repeated_runs_on_one_object_match_fresh_objects() {
        // Workspace reuse must not leak state between runs.
        let g = generate(GraphFamily::Web, 10, 21);
        let algo = GveLouvain::new(LouvainParams::default());
        let a = algo.run(&g);
        let b = algo.run(&g);
        let fresh = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(a.membership, b.membership);
        assert_eq!(a.membership, fresh.membership);
        assert_eq!(a.modularity, fresh.modularity);
        assert_eq!(a.passes, fresh.passes);
    }

    #[test]
    fn other_ns_accounts_for_post_aggregation_time() {
        // The Fig 14 phase split: every pass's other_ns is populated
        // and move+agg+other covers the whole pass wall time.
        let g = generate(GraphFamily::Social, 10, 23);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        for (i, ps) in out.pass_stats.iter().enumerate() {
            assert!(ps.other_ns > 0, "pass {i} dropped its other time");
        }
        let covered: u64 = out
            .pass_stats
            .iter()
            .map(|p| p.move_ns + p.agg_ns + p.other_ns)
            .sum();
        // Pass times cover most of the run (final renumber + Q eval are
        // outside passes).
        assert!(covered <= out.total_ns);
        assert!(covered * 10 >= out.total_ns * 5, "covered={covered} total={}", out.total_ns);
    }

    #[test]
    fn first_pass_fraction_divides_by_total_wall_time() {
        // Hand-built result with a measurable non-pass tail (setup +
        // final renumber): the fraction is pass-0 time over *total*
        // wall time, not over the pass-stats sum — 500/1000 here, not
        // 500/700.
        let result = LouvainResult {
            total_ns: 1_000,
            pass_stats: vec![
                PassStats { move_ns: 300, agg_ns: 100, other_ns: 100, ..Default::default() },
                PassStats { move_ns: 100, agg_ns: 50, other_ns: 50, ..Default::default() },
            ],
            ..Default::default()
        };
        assert!((result.first_pass_fraction() - 0.5).abs() < 1e-12);
        // No passes → 0, and the max(1) guard keeps an empty result finite.
        assert_eq!(LouvainResult::default().first_pass_fraction(), 0.0);
    }

    #[test]
    fn choose_width_policy_shape() {
        let p = LouvainParams {
            adaptive_width: true,
            threads: 8,
            serial_pass_threshold: 1000,
            width_gain: 1.0,
            ..LouvainParams::default()
        };
        // Off → always full width.
        let off = LouvainParams { adaptive_width: false, ..p.clone() };
        assert_eq!(choose_width(&off, 3, 10, 10, None), 8);
        // At or below the serial threshold → width 1, pass 0 included.
        assert_eq!(choose_width(&p, 0, 500, 1000, None), 1);
        assert_eq!(choose_width(&p, 2, 500, 900, None), 1);
        // Pass 0 above the threshold → full width.
        assert_eq!(choose_width(&p, 0, 500, 1001, None), 8);
        // Later passes: linear in demand, clamped to [1, threads].
        assert_eq!(choose_width(&p, 1, 100, 2500, None), 3);
        assert_eq!(choose_width(&p, 1, 100, 1_000_000, None), 8);
        // Vertex-bound demand counts too (init/renumber are O(|V'|)).
        assert_eq!(choose_width(&p, 1, 4500, 1001, None), 5);
        // width_gain scales the per-worker grant.
        let costly = LouvainParams { width_gain: 2.0, ..p.clone() };
        assert_eq!(choose_width(&costly, 1, 100, 2500, None), 2);
        // Shrink-only refinement: capped at the previous pass's active
        // workers (busy within 8× of the busiest)...
        assert_eq!(choose_width(&p, 1, 100, 1_000_000, Some(&[800, 700, 90, 0])), 2);
        // ...but an all-idle previous pass (serial fast path) is ignored.
        assert_eq!(choose_width(&p, 1, 100, 1_000_000, Some(&[0, 0, 0, 0])), 8);
    }

    #[test]
    fn record_chunks_collects_loops() {
        let g = generate(GraphFamily::Web, 9, 19);
        let out = GveLouvain::new(LouvainParams { record_chunks: true, ..Default::default() }).run(&g);
        assert!(!out.loops.is_empty());
        let covered: usize = out.loops[0].1.iter().map(|c| c.len).sum();
        assert_eq!(covered, g.num_vertices());
    }
}
