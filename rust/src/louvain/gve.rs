//! GVE-Louvain driver (Algorithm 1): the pass loop tying together
//! local-moving, renumbering, dendrogram lookup and aggregation, with
//! threshold scaling and the aggregation tolerance.

use super::aggregation::{aggregate_2d, aggregate_csr};
use super::dendrogram;
use super::hashtable::TablePool;
use super::local_moving::local_moving;
use super::modularity::modularity;
use super::params::{AggregationKind, LouvainParams};
use super::renumber::renumber_communities;
use super::Counters;
use crate::graph::Csr;
use crate::parallel::pool::ChunkRecord;
use crate::parallel::schedule::Schedule;
use std::time::Instant;

/// Per-pass statistics (feeds Figs 14/17: phase and pass splits).
#[derive(Clone, Debug, Default)]
pub struct PassStats {
    /// Vertices of `G'` at this pass.
    pub vertices: usize,
    /// Directed edge slots of `G'` at this pass.
    pub edges: usize,
    /// Local-moving iterations (`l_i`).
    pub iterations: usize,
    /// Communities after this pass's local-moving.
    pub communities: usize,
    pub move_ns: u64,
    pub agg_ns: u64,
    pub other_ns: u64,
    /// Total accepted ΔQ.
    pub dq: f64,
}

/// Result of a full Louvain run.
#[derive(Debug, Default)]
pub struct LouvainResult {
    /// Final community of every original vertex (dense ids).
    pub membership: Vec<u32>,
    /// Modularity of `membership` on the input graph.
    pub modularity: f64,
    pub num_communities: usize,
    pub passes: usize,
    pub total_ns: u64,
    pub pass_stats: Vec<PassStats>,
    pub counters: Counters,
    /// Recorded parallel loops (for the scaling replay model).
    pub loops: Vec<(Schedule, Vec<ChunkRecord>)>,
    /// Wall time not covered by recorded parallel loops.
    pub serial_ns: u64,
}

impl LouvainResult {
    /// Phase split: `(move, aggregate, other)` fractions of total time.
    pub fn phase_split(&self) -> (f64, f64, f64) {
        let mv: u64 = self.pass_stats.iter().map(|p| p.move_ns).sum();
        let ag: u64 = self.pass_stats.iter().map(|p| p.agg_ns).sum();
        let tot = self.total_ns.max(1) as f64;
        let (mv, ag) = (mv as f64, ag as f64);
        (mv / tot, ag / tot, ((tot - mv - ag) / tot).max(0.0))
    }

    /// Fraction of runtime spent in the first pass.
    pub fn first_pass_fraction(&self) -> f64 {
        let first = self
            .pass_stats
            .first()
            .map(|p| p.move_ns + p.agg_ns + p.other_ns)
            .unwrap_or(0) as f64;
        first / self.total_ns.max(1) as f64
    }
}

/// The GVE-Louvain algorithm object.
pub struct GveLouvain {
    pub params: LouvainParams,
}

impl GveLouvain {
    pub fn new(params: LouvainParams) -> Self {
        Self { params }
    }

    /// Run on `g`; returns the result with full metrics.
    pub fn run(&self, g: &Csr) -> LouvainResult {
        let p = &self.params;
        let t_start = Instant::now();
        let n0 = g.num_vertices();
        let m = g.total_weight();
        let mut result = LouvainResult {
            membership: (0..n0 as u32).collect(),
            ..Default::default()
        };
        if n0 == 0 || m == 0.0 {
            result.num_communities = n0;
            return result;
        }

        let mut owned: Option<Csr> = None; // super-vertex graph (pass >= 1)
        let mut tau = p.tolerance;

        for pass in 0..p.max_passes {
            let gp: &Csr = owned.as_ref().unwrap_or(g);
            let np = gp.num_vertices();
            let t_pass = Instant::now();

            // Init: K', Σ', C' (Algorithm 1 lines 4-5). K' is a parallel
            // loop (recorded for the scaling replay like the others).
            let k: Vec<f64> = {
                let mut k = vec![0f64; np];
                let opts = crate::parallel::pool::ParallelOpts {
                    threads: p.threads,
                    schedule: p.schedule,
                    chunk: p.chunk,
                    record: p.record_chunks,
                };
                struct SendPtr(*mut f64);
                unsafe impl Send for SendPtr {}
                unsafe impl Sync for SendPtr {}
                let ptr = SendPtr(k.as_mut_ptr());
                let stats = crate::parallel::pool::parallel_for(np, opts, |r| {
                    let ptr = &ptr;
                    for i in r {
                        // SAFETY: disjoint indices per chunk.
                        unsafe { *ptr.0.add(i) = gp.vertex_weight(i) };
                    }
                });
                if p.record_chunks {
                    result.loops.push((p.schedule, stats.chunks));
                }
                k
            };
            let mut sigma = k.clone();
            let mut membership: Vec<u32> = (0..np as u32).collect();
            let mut affected = vec![1u32; np];
            let pool = TablePool::new(p.table, np, p.threads);
            let t_init = t_pass.elapsed().as_nanos() as u64;

            // Local-moving phase (line 6).
            let t0 = Instant::now();
            let mv = local_moving(
                gp, &mut membership, &k, &mut sigma, &mut affected, &pool, p, m, tau,
            );
            let move_ns = t0.elapsed().as_nanos() as u64;
            result.counters.merge(&mv.counters);
            result.loops.extend(mv.loops);

            // Community count + convergence checks (lines 7-9).
            let t1 = Instant::now();
            let n_comm = renumber_communities(&mut membership);
            let converged = mv.iterations <= 1;
            let low_shrink = (n_comm as f64) / (np as f64) > p.aggregation_tolerance;

            // Fold this pass into the top-level membership (lines 11/14;
            // a parallel loop in the paper, recorded for the replay).
            {
                struct SendPtr(*mut u32);
                unsafe impl Send for SendPtr {}
                unsafe impl Sync for SendPtr {}
                let opts = crate::parallel::pool::ParallelOpts {
                    threads: p.threads,
                    schedule: p.schedule,
                    chunk: p.chunk,
                    record: p.record_chunks,
                };
                let top = &mut result.membership;
                let ptr = SendPtr(top.as_mut_ptr());
                let pass_memb = &membership;
                let stats = crate::parallel::pool::parallel_for(top.len(), opts, |r| {
                    let ptr = &ptr;
                    for i in r {
                        // SAFETY: disjoint indices per chunk.
                        unsafe {
                            let c = *ptr.0.add(i);
                            *ptr.0.add(i) = pass_memb[c as usize];
                        }
                    }
                });
                if p.record_chunks {
                    result.loops.push((p.schedule, stats.chunks));
                }
            }
            let mut other_ns = t_init + t1.elapsed().as_nanos() as u64;

            let mut stats = PassStats {
                vertices: np,
                edges: gp.num_edges(),
                iterations: mv.iterations,
                communities: n_comm,
                move_ns,
                agg_ns: 0,
                other_ns,
                dq: mv.dq_total,
            };

            if converged || low_shrink || pass + 1 == p.max_passes {
                result.pass_stats.push(stats);
                result.passes = pass + 1;
                break;
            }

            // Aggregation phase (line 12).
            let t2 = Instant::now();
            let agg = match p.aggregation {
                AggregationKind::Csr => aggregate_csr(gp, &membership, n_comm, &pool, p),
                AggregationKind::TwoDim => aggregate_2d(gp, &membership, n_comm, &pool, p),
            };
            stats.agg_ns = t2.elapsed().as_nanos() as u64;
            result.counters.edges_scanned_agg += agg.counters.edges_scanned_agg;
            result.counters.table_ops += agg.counters.table_ops;
            result.loops.extend(agg.loops);
            owned = Some(agg.graph);

            // Threshold scaling (line 13).
            tau /= p.tolerance_drop;

            let _ = other_ns;
            result.pass_stats.push(stats);
            result.passes = pass + 1;
        }

        result.num_communities = renumber_communities(&mut result.membership);
        // Detection time excludes the final quality evaluation (the paper
        // reports Q separately from runtime).
        result.total_ns = t_start.elapsed().as_nanos() as u64;
        result.modularity = modularity(g, &result.membership);
        let par_ns: u64 = result
            .loops
            .iter()
            .flat_map(|(_, c)| c.iter().map(|r| r.ns))
            .sum();
        result.serial_ns = result.total_ns.saturating_sub(par_ns);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::params::TableKind;

    #[test]
    fn two_triangles_full_run() {
        let g = GraphBuilder::new(6)
            .edge(0, 1, 1.0).edge(1, 2, 1.0).edge(0, 2, 1.0)
            .edge(3, 4, 1.0).edge(4, 5, 1.0).edge(3, 5, 1.0)
            .edge(2, 3, 1.0)
            .build_undirected();
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(out.num_communities, 2);
        assert!((out.modularity - 0.35714).abs() < 1e-3, "q={}", out.modularity);
        assert_eq!(out.membership[0], out.membership[2]);
        assert_ne!(out.membership[0], out.membership[3]);
    }

    #[test]
    fn planted_web_graph_recovers_high_modularity() {
        let g = generate(GraphFamily::Web, 11, 42);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert!(out.modularity > 0.8, "web q={}", out.modularity);
        assert!(out.num_communities > 1);
        assert!(out.passes >= 1);
    }

    #[test]
    fn social_graph_gets_lower_modularity_than_web() {
        let web = GveLouvain::new(LouvainParams::default()).run(&generate(GraphFamily::Web, 10, 1));
        let soc = GveLouvain::new(LouvainParams::default()).run(&generate(GraphFamily::Social, 10, 1));
        assert!(
            web.modularity > soc.modularity + 0.1,
            "web={} social={}",
            web.modularity,
            soc.modularity
        );
    }

    #[test]
    fn road_graph_many_communities() {
        let g = generate(GraphFamily::Road, 12, 2);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert!(out.modularity > 0.6, "road q={}", out.modularity);
        assert!(out.num_communities > 20, "communities={}", out.num_communities);
    }

    #[test]
    fn membership_is_dense_and_in_range() {
        let g = generate(GraphFamily::Kmer, 10, 3);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        let max = *out.membership.iter().max().unwrap() as usize;
        assert_eq!(max + 1, out.num_communities);
    }

    #[test]
    fn deterministic_single_thread() {
        let g = generate(GraphFamily::Web, 10, 7);
        let a = GveLouvain::new(LouvainParams::default()).run(&g);
        let b = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(a.membership, b.membership);
        assert_eq!(a.modularity, b.modularity);
        assert_eq!(a.passes, b.passes);
    }

    #[test]
    fn pass_stats_cover_runtime() {
        let g = generate(GraphFamily::Web, 10, 9);
        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        assert_eq!(out.pass_stats.len(), out.passes);
        let (mv, ag, other) = out.phase_split();
        assert!((mv + ag + other - 1.0).abs() < 1e-6);
        assert!(mv > 0.0);
        assert!(out.first_pass_fraction() > 0.0);
        // First pass has the full graph.
        assert_eq!(out.pass_stats[0].vertices, g.num_vertices());
    }

    #[test]
    fn empty_and_trivial_graphs() {
        let empty = Csr { offsets: vec![0], targets: vec![], weights: vec![] };
        let out = GveLouvain::new(LouvainParams::default()).run(&empty);
        assert_eq!(out.num_communities, 0);

        let lonely = GraphBuilder::new(3).build_undirected();
        let out = GveLouvain::new(LouvainParams::default()).run(&lonely);
        assert_eq!(out.num_communities, 3); // no edges: everyone alone
    }

    #[test]
    fn naive_params_still_correct_but_more_work() {
        let g = generate(GraphFamily::Web, 10, 11);
        let fast = GveLouvain::new(LouvainParams::default()).run(&g);
        let naive = GveLouvain::new(LouvainParams { table: TableKind::FarKv, ..LouvainParams::naive() }).run(&g);
        assert!((fast.modularity - naive.modularity).abs() < 0.05,
                "fast={} naive={}", fast.modularity, naive.modularity);
        // The naive config runs more local-moving iterations.
        let fast_iters: usize = fast.pass_stats.iter().map(|p| p.iterations).sum();
        let naive_iters: usize = naive.pass_stats.iter().map(|p| p.iterations).sum();
        assert!(naive_iters >= fast_iters);
    }

    #[test]
    fn aggregation_tolerance_stops_early() {
        let g = generate(GraphFamily::Social, 10, 13);
        let strict = GveLouvain::new(LouvainParams { aggregation_tolerance: 1.0, ..Default::default() }).run(&g);
        let loose = GveLouvain::new(LouvainParams { aggregation_tolerance: 0.5, ..Default::default() }).run(&g);
        assert!(loose.passes <= strict.passes);
    }

    #[test]
    fn multithreaded_quality_close_to_single() {
        let g = generate(GraphFamily::Web, 11, 17);
        let q1 = GveLouvain::new(LouvainParams::with_threads(1)).run(&g).modularity;
        let q4 = GveLouvain::new(LouvainParams::with_threads(4)).run(&g).modularity;
        assert!((q1 - q4).abs() < 0.02, "q1={q1} q4={q4}");
    }

    #[test]
    fn record_chunks_collects_loops() {
        let g = generate(GraphFamily::Web, 9, 19);
        let out = GveLouvain::new(LouvainParams { record_chunks: true, ..Default::default() }).run(&g);
        assert!(!out.loops.is_empty());
        let covered: usize = out.loops[0].1.iter().map(|c| c.len).sum();
        assert_eq!(covered, g.num_vertices());
    }
}
