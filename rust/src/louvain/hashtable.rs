//! Per-thread collision-free community tables (§4.1.9, Fig 3).
//!
//! Three designs, ablated in Fig 2 ("hashtable": Far-KV 4.4× over Map,
//! 1.3× over Close-KV):
//!
//! * [`TableKind::Map`] — an ordered map per scan (C++ `std::map`
//!   analogue).
//! * [`TableKind::CloseKv`] — key-list + full-size (`|V|`) values
//!   array, with **all threads' arrays packed into one contiguous
//!   slab** and all key counts sharing a cache line: the NetworKit-like
//!   layout whose false sharing the paper blames for its slowdown.
//! * [`TableKind::FarKv`] — same key-list + values-array design but
//!   every thread's arrays (and its count) are **independent heap
//!   allocations padded apart** (Fig 3): the adopted design.
//!
//! The value associated with a key is stored at the index pointed to by
//! the key (collision-free by construction); `keys` records which slots
//! are dirty so `clear()` is O(#keys), not O(|V|).

use super::params::TableKind;
use std::collections::BTreeMap;

/// Pool owning the backing storage for every thread's table.
pub struct TablePool {
    kind: TableKind,
    n: usize,
    threads: usize,
    // Close-KV: one slab for all threads; counts share a cache line.
    close_keys: Vec<u32>,
    close_values: Vec<f64>,
    close_counts: Vec<u32>,
    // Far-KV: independent allocations per thread.
    far: Vec<FarStorage>,
}

/// One thread's Far-KV storage; `_pad` keeps allocations apart even if
/// the allocator would otherwise pack them.
struct FarStorage {
    keys: Vec<u32>,
    values: Vec<f64>,
    count: Box<u32>,
    _pad: Vec<u8>,
}

impl TablePool {
    /// Build a pool for `threads` tables over community ids `< n`.
    pub fn new(kind: TableKind, n: usize, threads: usize) -> Self {
        let threads = threads.max(1);
        match kind {
            TableKind::Map => Self { kind, n, threads, close_keys: vec![], close_values: vec![], close_counts: vec![], far: vec![] },
            TableKind::CloseKv => Self {
                kind,
                n,
                threads,
                close_keys: vec![0; n * threads],
                close_values: vec![0.0; n * threads],
                close_counts: vec![0; threads],
                far: vec![],
            },
            TableKind::FarKv => Self {
                kind,
                n,
                threads,
                close_keys: vec![],
                close_values: vec![],
                close_counts: vec![],
                far: (0..threads)
                    .map(|_| FarStorage {
                        keys: vec![0; n],
                        values: vec![0.0; n],
                        count: Box::new(0),
                        _pad: vec![0; 4096],
                    })
                    .collect(),
            },
        }
    }

    pub fn kind(&self) -> TableKind {
        self.kind
    }

    /// Largest community id (exclusive) the tables can hold.
    pub fn capacity(&self) -> usize {
        self.n
    }

    /// Number of per-thread tables.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Reuse `slot`'s pool when its kind, capacity and thread count
    /// suffice; otherwise (re)build it.  This is how the pass loops
    /// keep `TablePool` allocation O(1) per run: the first pass (the
    /// largest graph) sizes the pool, later passes reuse it.
    ///
    /// Correctness of reuse rests on the table contract: users call
    /// `clear()` before each scan, and `clear()` zeroes exactly the
    /// slots recorded in the key list, so leftover keys from a previous
    /// (larger) pass are wiped on first touch.
    pub fn ensure<'a>(
        slot: &'a mut Option<TablePool>,
        kind: TableKind,
        n: usize,
        threads: usize,
    ) -> &'a TablePool {
        let reusable = slot
            .as_ref()
            .map(|p| p.kind == kind && p.n >= n && p.threads >= threads.max(1))
            .unwrap_or(false);
        if !reusable {
            *slot = Some(TablePool::new(kind, n, threads));
        }
        slot.as_ref().unwrap()
    }

    /// Address of thread `tid`'s value storage (null for `Map`, which
    /// owns no pooled storage).  Tests use this to assert the pool is
    /// *reused*, not reallocated, across passes and runs.
    #[doc(hidden)]
    pub fn storage_ptr(&self, tid: usize) -> *const f64 {
        assert!(tid < self.threads, "tid {tid} >= threads {}", self.threads);
        match self.kind {
            TableKind::Map => std::ptr::null(),
            TableKind::CloseKv => self.close_values[tid * self.n..].as_ptr(),
            TableKind::FarKv => self.far[tid].values.as_ptr(),
        }
    }

    /// Hand out thread `tid`'s table view.
    ///
    /// Contract: at most one live view per `tid` at a time (the
    /// fork-join loops in this crate guarantee it — `init(tid)` runs
    /// once per worker per loop).
    pub fn table(&self, tid: usize) -> CommunityTable {
        assert!(tid < self.threads, "tid {tid} >= threads {}", self.threads);
        match self.kind {
            TableKind::Map => CommunityTable::Map(BTreeMap::new()),
            TableKind::CloseKv => CommunityTable::Kv(KvView {
                keys: self.close_keys[tid * self.n..].as_ptr() as *mut u32,
                values: self.close_values[tid * self.n..].as_ptr() as *mut f64,
                count: (&self.close_counts[tid]) as *const u32 as *mut u32,
                cap: self.n,
            }),
            TableKind::FarKv => {
                let f = &self.far[tid];
                CommunityTable::Kv(KvView {
                    keys: f.keys.as_ptr() as *mut u32,
                    values: f.values.as_ptr() as *mut f64,
                    count: (&*f.count) as *const u32 as *mut u32,
                    cap: self.n,
                })
            }
        }
    }
}

/// A per-thread community table (enum-dispatched).
pub enum CommunityTable {
    Map(BTreeMap<u32, f64>),
    Kv(KvView),
}

/// Raw view into KV storage (collision-free: value slot == key).
pub struct KvView {
    keys: *mut u32,
    values: *mut f64,
    count: *mut u32,
    cap: usize,
}

// SAFETY: views are handed to exactly one worker thread at a time (see
// `TablePool::table`); distinct tids view disjoint storage.
unsafe impl Send for KvView {}

impl CommunityTable {
    /// Remove all entries (O(#keys) for KV designs).
    #[inline]
    pub fn clear(&mut self) {
        match self {
            CommunityTable::Map(m) => m.clear(),
            CommunityTable::Kv(kv) => unsafe {
                let cnt = *kv.count as usize;
                for i in 0..cnt {
                    let k = *kv.keys.add(i) as usize;
                    *kv.values.add(k) = 0.0;
                }
                *kv.count = 0;
            },
        }
    }

    /// `table[c] += w` (records the key on first touch).
    #[inline]
    pub fn accumulate(&mut self, c: u32, w: f64) {
        match self {
            CommunityTable::Map(m) => {
                *m.entry(c).or_insert(0.0) += w;
            }
            CommunityTable::Kv(kv) => unsafe {
                debug_assert!((c as usize) < kv.cap);
                let slot = kv.values.add(c as usize);
                if *slot == 0.0 {
                    *kv.keys.add(*kv.count as usize) = c;
                    *kv.count += 1;
                }
                *slot += w;
            },
        }
    }

    /// Value for community `c` (0 when absent).
    #[inline]
    pub fn get(&self, c: u32) -> f64 {
        match self {
            CommunityTable::Map(m) => m.get(&c).copied().unwrap_or(0.0),
            CommunityTable::Kv(kv) => unsafe {
                debug_assert!((c as usize) < kv.cap);
                *kv.values.add(c as usize)
            },
        }
    }

    /// Number of recorded keys (KV may count a key twice if a zero
    /// weight was accumulated; harmless for all users).
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            CommunityTable::Map(m) => m.len(),
            CommunityTable::Kv(kv) => unsafe { *kv.count as usize },
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit `(community, weight)` pairs. KV order is first-touch
    /// order; Map order is ascending key.
    #[inline]
    pub fn for_each(&self, mut f: impl FnMut(u32, f64)) {
        match self {
            CommunityTable::Map(m) => {
                for (&k, &v) in m {
                    f(k, v);
                }
            }
            CommunityTable::Kv(kv) => unsafe {
                let cnt = *kv.count as usize;
                for i in 0..cnt {
                    let k = *kv.keys.add(i);
                    f(k, *kv.values.add(k as usize));
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> [TableKind; 3] {
        [TableKind::Map, TableKind::CloseKv, TableKind::FarKv]
    }

    #[test]
    fn accumulate_get_clear_all_kinds() {
        for kind in kinds() {
            let pool = TablePool::new(kind, 100, 1);
            let mut t = pool.table(0);
            t.accumulate(5, 1.5);
            t.accumulate(5, 2.5);
            t.accumulate(7, 1.0);
            assert_eq!(t.get(5), 4.0, "{kind:?}");
            assert_eq!(t.get(7), 1.0);
            assert_eq!(t.get(9), 0.0);
            t.clear();
            assert_eq!(t.get(5), 0.0, "{kind:?} clear failed");
            assert!(t.is_empty());
        }
    }

    #[test]
    fn for_each_visits_all_entries() {
        for kind in kinds() {
            let pool = TablePool::new(kind, 64, 1);
            let mut t = pool.table(0);
            for c in [3u32, 9, 31, 3, 9] {
                t.accumulate(c, 1.0);
            }
            let mut seen = std::collections::BTreeMap::new();
            t.for_each(|c, w| {
                seen.insert(c, w);
            });
            assert_eq!(seen.len(), 3, "{kind:?}");
            assert_eq!(seen[&3], 2.0);
            assert_eq!(seen[&9], 2.0);
            assert_eq!(seen[&31], 1.0);
        }
    }

    #[test]
    fn threads_have_isolated_tables() {
        for kind in [TableKind::CloseKv, TableKind::FarKv] {
            let pool = TablePool::new(kind, 32, 4);
            std::thread::scope(|s| {
                for tid in 0..4 {
                    let pool = &pool;
                    s.spawn(move || {
                        let mut t = pool.table(tid);
                        for i in 0..32u32 {
                            t.accumulate(i, (tid + 1) as f64);
                        }
                        for i in 0..32u32 {
                            assert_eq!(t.get(i), (tid + 1) as f64, "{kind:?} tid={tid}");
                        }
                        t.clear();
                    });
                }
            });
        }
    }

    #[test]
    fn reuse_after_clear_is_clean() {
        for kind in kinds() {
            let pool = TablePool::new(kind, 16, 1);
            for round in 1..=3 {
                let mut t = pool.table(0);
                t.accumulate(1, round as f64);
                assert_eq!(t.get(1), round as f64, "{kind:?} round {round}");
                t.clear();
            }
        }
    }

    #[test]
    fn ensure_reuses_when_capacity_suffices() {
        for kind in [TableKind::CloseKv, TableKind::FarKv] {
            let mut slot: Option<TablePool> = None;
            let p0 = TablePool::ensure(&mut slot, kind, 100, 2).storage_ptr(0);
            assert!(!p0.is_null());
            // Smaller pass: storage must be reused, not reallocated.
            let p1 = TablePool::ensure(&mut slot, kind, 40, 2).storage_ptr(0);
            assert_eq!(p0, p1, "{kind:?} reallocated on shrink");
            // Larger pass: must grow.
            let pool = TablePool::ensure(&mut slot, kind, 200, 2);
            assert!(pool.capacity() >= 200);
            // Kind change: must rebuild.
            TablePool::ensure(&mut slot, TableKind::Map, 10, 1);
            assert_eq!(slot.as_ref().unwrap().kind(), TableKind::Map);
        }
    }

    #[test]
    fn reused_pool_is_clean_after_dirty_use() {
        // Simulate a pass leaving dirty keys behind, then a smaller
        // "next pass" reusing the pool: first clear() wipes the dirt.
        let mut slot: Option<TablePool> = None;
        {
            let pool = TablePool::ensure(&mut slot, TableKind::FarKv, 100, 1);
            let mut t = pool.table(0);
            t.accumulate(7, 1.0);
            t.accumulate(93, 2.0); // key beyond the next pass's n
        }
        let pool = TablePool::ensure(&mut slot, TableKind::FarKv, 10, 1);
        let mut t = pool.table(0);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.get(7), 0.0);
        t.accumulate(3, 4.0);
        assert_eq!(t.get(3), 4.0);
    }

    #[test]
    #[should_panic]
    fn tid_out_of_range_panics() {
        let pool = TablePool::new(TableKind::FarKv, 8, 2);
        let _ = pool.table(2);
    }
}
