//! Per-thread collision-free community tables (§4.1.9, Fig 3).
//!
//! Three designs, ablated in Fig 2 ("hashtable": Far-KV 4.4× over Map,
//! 1.3× over Close-KV):
//!
//! * [`TableKind::Map`] — an ordered map per scan (C++ `std::map`
//!   analogue).
//! * [`TableKind::CloseKv`] — key-list + full-size (`|V|`) values
//!   array, with **all threads' arrays packed into one contiguous
//!   slab** and all key counts sharing a cache line: the NetworKit-like
//!   layout whose false sharing the paper blames for its slowdown.
//! * [`TableKind::FarKv`] — same key-list + values-array design but
//!   every thread's arrays (and its count) are **independent heap
//!   allocations padded apart** (Fig 3): the adopted design.
//!
//! The value associated with a key is stored at the index pointed to by
//! the key (collision-free by construction); `keys` records which slots
//! are dirty so `clear()` is O(#keys), not O(|V|).
//!
//! PR 6 layers a degree-aware **hybrid** on top: [`HybridTable`] routes
//! rows with degree ≤ `small_degree` into a fixed-size stack-resident
//! [`SmallTable`] (linear key scan, no `|V|`-slab touch, no `clear()`)
//! and keeps the Far-KV slab for the heavy rows.  Iteration stays
//! first-touch ordered on both sides, so the single-thread results are
//! bit-identical to the pure Far-KV path.

use super::params::TableKind;
use crate::parallel::{Exec, ParallelOpts, Schedule};
use std::collections::BTreeMap;

/// Pool owning the backing storage for every thread's table.
pub struct TablePool {
    kind: TableKind,
    n: usize,
    threads: usize,
    // Close-KV: one slab for all threads; counts share a cache line.
    close_keys: Vec<u32>,
    close_values: Vec<f64>,
    close_counts: Vec<u32>,
    // Far-KV: independent allocations per thread.
    far: Vec<FarStorage>,
}

/// One thread's Far-KV storage; `_pad` keeps allocations apart even if
/// the allocator would otherwise pack them.
struct FarStorage {
    keys: Vec<u32>,
    values: Vec<f64>,
    count: Box<u32>,
    _pad: Vec<u8>,
}

impl TablePool {
    /// Build a pool for `threads` tables over community ids `< n`.
    pub fn new(kind: TableKind, n: usize, threads: usize) -> Self {
        let threads = threads.max(1);
        match kind {
            TableKind::Map => Self { kind, n, threads, close_keys: vec![], close_values: vec![], close_counts: vec![], far: vec![] },
            TableKind::CloseKv => Self {
                kind,
                n,
                threads,
                close_keys: vec![0; n * threads],
                close_values: vec![0.0; n * threads],
                close_counts: vec![0; threads],
                far: vec![],
            },
            TableKind::FarKv => Self {
                kind,
                n,
                threads,
                close_keys: vec![],
                close_values: vec![],
                close_counts: vec![],
                far: (0..threads)
                    .map(|_| FarStorage {
                        keys: vec![0; n],
                        values: vec![0.0; n],
                        count: Box::new(0),
                        _pad: vec![0; 4096],
                    })
                    .collect(),
            },
        }
    }

    pub fn kind(&self) -> TableKind {
        self.kind
    }

    /// Largest community id (exclusive) the tables can hold.
    pub fn capacity(&self) -> usize {
        self.n
    }

    /// Number of per-thread tables.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Heap bytes reserved by the pool's backing storage (capacity;
    /// PR 8 memory accounting).  `Map` tables allocate per-scan inside
    /// std — the pool holds nothing for them and reports 0.
    pub fn reserved_bytes(&self) -> usize {
        let close = self.close_keys.capacity() * std::mem::size_of::<u32>()
            + self.close_values.capacity() * std::mem::size_of::<f64>()
            + self.close_counts.capacity() * std::mem::size_of::<u32>();
        let far: usize = self
            .far
            .iter()
            .map(|f| {
                f.keys.capacity() * std::mem::size_of::<u32>()
                    + f.values.capacity() * std::mem::size_of::<f64>()
                    + std::mem::size_of::<u32>()
                    + f._pad.capacity()
            })
            .sum();
        close + far
    }

    /// Reuse `slot`'s pool when its kind, capacity and thread count
    /// suffice; otherwise (re)build it.  This is how the pass loops
    /// keep `TablePool` allocation O(1) per run: the first pass (the
    /// largest graph) sizes the pool, later passes reuse it.
    ///
    /// Correctness of reuse rests on the table contract: users call
    /// `clear()` before each scan, and `clear()` zeroes exactly the
    /// slots recorded in the key list, so leftover keys from a previous
    /// (larger) pass are wiped on first touch.
    pub fn ensure<'a>(
        slot: &'a mut Option<TablePool>,
        kind: TableKind,
        n: usize,
        threads: usize,
    ) -> &'a TablePool {
        let reusable = slot
            .as_ref()
            .map(|p| p.kind == kind && p.n >= n && p.threads >= threads.max(1))
            .unwrap_or(false);
        if !reusable {
            *slot = Some(TablePool::new(kind, n, threads));
        }
        slot.as_ref().unwrap()
    }

    /// [`TablePool::ensure`] with NUMA-style first-touch initialisation
    /// (ROADMAP item): when a Far-KV pool is (re)built for a
    /// multi-thread team, each worker touches one page of every 4 KiB
    /// stretch of *its own* slab from inside a team job, so on
    /// first-touch NUMA systems the pages land on the node that will
    /// scan them.  Reused pools are left alone (their pages are already
    /// placed); Map owns no slab and Close-KV is the deliberately
    /// false-sharing ablation, so both keep the plain path.
    pub fn ensure_with_exec<'a>(
        slot: &'a mut Option<TablePool>,
        kind: TableKind,
        n: usize,
        threads: usize,
        exec: Exec<'_>,
    ) -> &'a TablePool {
        let reusable = slot
            .as_ref()
            .map(|p| p.kind == kind && p.n >= n && p.threads >= threads.max(1))
            .unwrap_or(false);
        let pool = TablePool::ensure(slot, kind, n, threads);
        if !reusable && kind == TableKind::FarKv && threads > 1 {
            pool.first_touch(exec, threads);
        }
        pool
    }

    /// Touch every page of each thread's Far-KV slab from that thread.
    ///
    /// `Static` dealing with chunk 1 over `0..threads` maps index `i`
    /// to tid `i` exactly, so each worker writes only its own storage —
    /// no aliasing, no synchronisation beyond the job barrier.
    fn first_touch(&self, exec: Exec<'_>, threads: usize) {
        use crate::parallel::pool::RawSend;
        const PAGE: usize = 4096;
        let slabs: Vec<(RawSend<u32>, usize, RawSend<f64>, usize)> = self
            .far
            .iter()
            .map(|f| {
                (
                    RawSend(f.keys.as_ptr() as *mut u32),
                    f.keys.len(),
                    RawSend(f.values.as_ptr() as *mut f64),
                    f.values.len(),
                )
            })
            .collect();
        let slabs = &slabs;
        let opts = ParallelOpts { threads, schedule: Schedule::Static, chunk: 1, record: false };
        exec.run(threads.min(slabs.len()), opts, move |r| {
            for i in r {
                let (keys, klen, values, vlen) = slabs[i];
                // SAFETY: index i is dealt to tid i only (Static,
                // chunk 1), so this is the sole writer of slab i; the
                // slabs are freshly allocated zeros, and write_volatile
                // keeps the dead stores from being optimised away.
                unsafe {
                    let mut k = 0;
                    while k < klen {
                        keys.0.add(k).write_volatile(0);
                        k += PAGE / std::mem::size_of::<u32>();
                    }
                    let mut v = 0;
                    while v < vlen {
                        values.0.add(v).write_volatile(0.0);
                        v += PAGE / std::mem::size_of::<f64>();
                    }
                }
            }
        });
    }

    /// Address of thread `tid`'s value storage (null for `Map`, which
    /// owns no pooled storage).  Tests use this to assert the pool is
    /// *reused*, not reallocated, across passes and runs.
    #[doc(hidden)]
    pub fn storage_ptr(&self, tid: usize) -> *const f64 {
        assert!(tid < self.threads, "tid {tid} >= threads {}", self.threads);
        match self.kind {
            TableKind::Map => std::ptr::null(),
            TableKind::CloseKv => self.close_values[tid * self.n..].as_ptr(),
            TableKind::FarKv => self.far[tid].values.as_ptr(),
        }
    }

    /// Hand out thread `tid`'s table view.
    ///
    /// Contract: at most one live view per `tid` at a time (the
    /// fork-join loops in this crate guarantee it — `init(tid)` runs
    /// once per worker per loop).
    pub fn table(&self, tid: usize) -> CommunityTable {
        assert!(tid < self.threads, "tid {tid} >= threads {}", self.threads);
        match self.kind {
            TableKind::Map => CommunityTable::Map(BTreeMap::new()),
            TableKind::CloseKv => CommunityTable::Kv(KvView {
                keys: self.close_keys[tid * self.n..].as_ptr() as *mut u32,
                values: self.close_values[tid * self.n..].as_ptr() as *mut f64,
                count: (&self.close_counts[tid]) as *const u32 as *mut u32,
                cap: self.n,
            }),
            TableKind::FarKv => {
                let f = &self.far[tid];
                CommunityTable::Kv(KvView {
                    keys: f.keys.as_ptr() as *mut u32,
                    values: f.values.as_ptr() as *mut f64,
                    count: (&*f.count) as *const u32 as *mut u32,
                    cap: self.n,
                })
            }
        }
    }

    /// Hand out thread `tid`'s degree-aware hybrid table (PR 6): rows
    /// with degree ≤ `small_degree` scan into the stack-resident
    /// [`SmallTable`], the rest into this pool's table.  Same
    /// one-live-view-per-tid contract as [`TablePool::table`].
    ///
    /// Under [`TableKind::Map`] the fast path is forced off
    /// (`small_degree = 0`) so the Fig 2 Map ablation measures the pure
    /// ordered-map design.
    pub fn hybrid_table(&self, tid: usize, small_degree: usize) -> HybridTable {
        let small_degree = if self.kind == TableKind::Map { 0 } else { small_degree };
        HybridTable {
            small: SmallTable::new(),
            big: self.table(tid),
            small_degree,
            use_small: false,
            small_rows: 0,
            big_rows: 0,
            spills: 0,
        }
    }
}

/// A per-thread community table (enum-dispatched).
pub enum CommunityTable {
    Map(BTreeMap<u32, f64>),
    Kv(KvView),
}

/// Raw view into KV storage (collision-free: value slot == key).
pub struct KvView {
    keys: *mut u32,
    values: *mut f64,
    count: *mut u32,
    cap: usize,
}

// SAFETY: views are handed to exactly one worker thread at a time (see
// `TablePool::table`); distinct tids view disjoint storage.
unsafe impl Send for KvView {}

impl CommunityTable {
    /// Remove all entries (O(#keys) for KV designs).
    #[inline]
    pub fn clear(&mut self) {
        match self {
            CommunityTable::Map(m) => m.clear(),
            CommunityTable::Kv(kv) => unsafe {
                let cnt = *kv.count as usize;
                for i in 0..cnt {
                    let k = *kv.keys.add(i) as usize;
                    *kv.values.add(k) = 0.0;
                }
                *kv.count = 0;
            },
        }
    }

    /// `table[c] += w` (records the key on first touch).
    #[inline]
    pub fn accumulate(&mut self, c: u32, w: f64) {
        match self {
            CommunityTable::Map(m) => {
                *m.entry(c).or_insert(0.0) += w;
            }
            CommunityTable::Kv(kv) => unsafe {
                debug_assert!((c as usize) < kv.cap);
                let slot = kv.values.add(c as usize);
                if *slot == 0.0 {
                    *kv.keys.add(*kv.count as usize) = c;
                    *kv.count += 1;
                }
                *slot += w;
            },
        }
    }

    /// Value for community `c` (0 when absent).
    #[inline]
    pub fn get(&self, c: u32) -> f64 {
        match self {
            CommunityTable::Map(m) => m.get(&c).copied().unwrap_or(0.0),
            CommunityTable::Kv(kv) => unsafe {
                debug_assert!((c as usize) < kv.cap);
                *kv.values.add(c as usize)
            },
        }
    }

    /// Number of recorded keys (KV may count a key twice if a zero
    /// weight was accumulated; harmless for all users).
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            CommunityTable::Map(m) => m.len(),
            CommunityTable::Kv(kv) => unsafe { *kv.count as usize },
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit `(community, weight)` pairs. KV order is first-touch
    /// order; Map order is ascending key.
    #[inline]
    pub fn for_each(&self, mut f: impl FnMut(u32, f64)) {
        match self {
            CommunityTable::Map(m) => {
                for (&k, &v) in m {
                    f(k, v);
                }
            }
            CommunityTable::Kv(kv) => unsafe {
                let cnt = *kv.count as usize;
                for i in 0..cnt {
                    let k = *kv.keys.add(i);
                    f(k, *kv.values.add(k as usize));
                }
            },
        }
    }
}

/// Distinct-key capacity of the [`SmallTable`] fast path.
///
/// Chosen above the default `small_degree` knob (16) so a fast-path row
/// only spills when the knob is raised past the capacity: 32 keys ×
/// (4 + 8) bytes = 384 B of hot stack, well inside one L1 way.
pub const SMALL_TABLE_CAP: usize = 32;

/// Fixed-size stack-resident community table for low-degree rows.
///
/// A linear-scanned key/value array: at degree ≤ 16 a branchy linear
/// scan over ≤ 16 packed keys beats the Far-KV design's scattered
/// `values[c]` accesses (each a potential cache miss in a |V|-sized
/// slab) — and a row reset is `len = 0` instead of an O(#keys)
/// `clear()`.  Entries stay in first-touch order, matching the KV key
/// list exactly.
pub struct SmallTable {
    keys: [u32; SMALL_TABLE_CAP],
    values: [f64; SMALL_TABLE_CAP],
    len: usize,
}

impl SmallTable {
    pub fn new() -> Self {
        Self { keys: [0; SMALL_TABLE_CAP], values: [0.0; SMALL_TABLE_CAP], len: 0 }
    }
}

impl Default for SmallTable {
    fn default() -> Self {
        Self::new()
    }
}

/// Degree-aware hybrid community table (PR 6, the scan-engine core).
///
/// Per row, [`HybridTable::begin_row`] picks a side by degree: the
/// [`SmallTable`] for rows with degree ≤ `small_degree`, the pooled
/// [`CommunityTable`] otherwise.  Only the chosen side is reset, so a
/// low-degree row costs zero slab traffic.
///
/// **Bit-exactness contract** (vs a pure Far-KV scan, single thread):
/// both sides accumulate each community's weight into a single `f64`
/// slot in arrival order and iterate entries in first-touch order, so
/// every partial sum — and therefore every Δq comparison downstream —
/// is bitwise identical.  A row that overflows the small side
/// ([`SMALL_TABLE_CAP`] distinct keys) spills into the big table in
/// first-touch order (`0.0 + partial_sum` copies are exact) and
/// continues there, preserving the contract.
pub struct HybridTable {
    small: SmallTable,
    big: CommunityTable,
    small_degree: usize,
    use_small: bool,
    small_rows: u64,
    big_rows: u64,
    spills: u64,
}

impl HybridTable {
    /// Start scanning a row of `degree` neighbours: route it and reset
    /// the chosen side.  (The other side keeps its dirt; each side is
    /// reset at the start of the next row that uses it.)
    #[inline]
    pub fn begin_row(&mut self, degree: usize) {
        self.use_small = self.small_degree > 0 && degree <= self.small_degree;
        if self.use_small {
            self.small.len = 0;
            self.small_rows += 1;
        } else {
            self.big.clear();
            self.big_rows += 1;
        }
    }

    /// `table[c] += w` (first-touch key recording on both sides).
    #[inline]
    pub fn accumulate(&mut self, c: u32, w: f64) {
        if self.use_small {
            for i in 0..self.small.len {
                if self.small.keys[i] == c {
                    self.small.values[i] += w;
                    return;
                }
            }
            if self.small.len < SMALL_TABLE_CAP {
                self.small.keys[self.small.len] = c;
                self.small.values[self.small.len] = w;
                self.small.len += 1;
                return;
            }
            self.spill();
            self.big.accumulate(c, w);
        } else {
            self.big.accumulate(c, w);
        }
    }

    /// Move a full small side into the big table (first-touch order
    /// preserved) and continue the row there.
    #[cold]
    fn spill(&mut self) {
        self.big.clear();
        for i in 0..self.small.len {
            self.big.accumulate(self.small.keys[i], self.small.values[i]);
        }
        self.use_small = false;
        self.spills += 1;
        // The row was already counted as small in begin_row; spills are
        // reported separately so the counters still sum to #rows.
    }

    /// Value for community `c` (0 when absent).
    #[inline]
    pub fn get(&self, c: u32) -> f64 {
        if self.use_small {
            for i in 0..self.small.len {
                if self.small.keys[i] == c {
                    return self.small.values[i];
                }
            }
            0.0
        } else {
            self.big.get(c)
        }
    }

    /// Distinct keys recorded for the current row.
    #[inline]
    pub fn len(&self) -> usize {
        if self.use_small {
            self.small.len
        } else {
            self.big.len()
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit `(community, weight)` pairs in first-touch order (both
    /// sides — the order the tie-breaking first-max-wins rule sees).
    #[inline]
    pub fn for_each(&self, mut f: impl FnMut(u32, f64)) {
        if self.use_small {
            for i in 0..self.small.len {
                f(self.small.keys[i], self.small.values[i]);
            }
        } else {
            self.big.for_each(f);
        }
    }

    /// Whether the *current* row is on the small side (false after a
    /// spill).
    #[inline]
    pub fn used_small(&self) -> bool {
        self.use_small
    }

    /// Rows routed to the small side so far (spilled rows included).
    pub fn small_rows(&self) -> u64 {
        self.small_rows
    }

    /// Rows routed to the big side so far (spills not re-counted).
    pub fn big_rows(&self) -> u64 {
        self.big_rows
    }

    /// Small-side rows that overflowed into the big table.
    pub fn spills(&self) -> u64 {
        self.spills
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> [TableKind; 3] {
        [TableKind::Map, TableKind::CloseKv, TableKind::FarKv]
    }

    #[test]
    fn accumulate_get_clear_all_kinds() {
        for kind in kinds() {
            let pool = TablePool::new(kind, 100, 1);
            let mut t = pool.table(0);
            t.accumulate(5, 1.5);
            t.accumulate(5, 2.5);
            t.accumulate(7, 1.0);
            assert_eq!(t.get(5), 4.0, "{kind:?}");
            assert_eq!(t.get(7), 1.0);
            assert_eq!(t.get(9), 0.0);
            t.clear();
            assert_eq!(t.get(5), 0.0, "{kind:?} clear failed");
            assert!(t.is_empty());
        }
    }

    #[test]
    fn for_each_visits_all_entries() {
        for kind in kinds() {
            let pool = TablePool::new(kind, 64, 1);
            let mut t = pool.table(0);
            for c in [3u32, 9, 31, 3, 9] {
                t.accumulate(c, 1.0);
            }
            let mut seen = std::collections::BTreeMap::new();
            t.for_each(|c, w| {
                seen.insert(c, w);
            });
            assert_eq!(seen.len(), 3, "{kind:?}");
            assert_eq!(seen[&3], 2.0);
            assert_eq!(seen[&9], 2.0);
            assert_eq!(seen[&31], 1.0);
        }
    }

    #[test]
    fn threads_have_isolated_tables() {
        for kind in [TableKind::CloseKv, TableKind::FarKv] {
            let pool = TablePool::new(kind, 32, 4);
            std::thread::scope(|s| {
                for tid in 0..4 {
                    let pool = &pool;
                    s.spawn(move || {
                        let mut t = pool.table(tid);
                        for i in 0..32u32 {
                            t.accumulate(i, (tid + 1) as f64);
                        }
                        for i in 0..32u32 {
                            assert_eq!(t.get(i), (tid + 1) as f64, "{kind:?} tid={tid}");
                        }
                        t.clear();
                    });
                }
            });
        }
    }

    #[test]
    fn reuse_after_clear_is_clean() {
        for kind in kinds() {
            let pool = TablePool::new(kind, 16, 1);
            for round in 1..=3 {
                let mut t = pool.table(0);
                t.accumulate(1, round as f64);
                assert_eq!(t.get(1), round as f64, "{kind:?} round {round}");
                t.clear();
            }
        }
    }

    #[test]
    fn ensure_reuses_when_capacity_suffices() {
        for kind in [TableKind::CloseKv, TableKind::FarKv] {
            let mut slot: Option<TablePool> = None;
            let p0 = TablePool::ensure(&mut slot, kind, 100, 2).storage_ptr(0);
            assert!(!p0.is_null());
            // Smaller pass: storage must be reused, not reallocated.
            let p1 = TablePool::ensure(&mut slot, kind, 40, 2).storage_ptr(0);
            assert_eq!(p0, p1, "{kind:?} reallocated on shrink");
            // Larger pass: must grow.
            let pool = TablePool::ensure(&mut slot, kind, 200, 2);
            assert!(pool.capacity() >= 200);
            // Kind change: must rebuild.
            TablePool::ensure(&mut slot, TableKind::Map, 10, 1);
            assert_eq!(slot.as_ref().unwrap().kind(), TableKind::Map);
        }
    }

    #[test]
    fn reused_pool_is_clean_after_dirty_use() {
        // Simulate a pass leaving dirty keys behind, then a smaller
        // "next pass" reusing the pool: first clear() wipes the dirt.
        let mut slot: Option<TablePool> = None;
        {
            let pool = TablePool::ensure(&mut slot, TableKind::FarKv, 100, 1);
            let mut t = pool.table(0);
            t.accumulate(7, 1.0);
            t.accumulate(93, 2.0); // key beyond the next pass's n
        }
        let pool = TablePool::ensure(&mut slot, TableKind::FarKv, 10, 1);
        let mut t = pool.table(0);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.get(7), 0.0);
        t.accumulate(3, 4.0);
        assert_eq!(t.get(3), 4.0);
    }

    #[test]
    #[should_panic]
    fn tid_out_of_range_panics() {
        let pool = TablePool::new(TableKind::FarKv, 8, 2);
        let _ = pool.table(2);
    }

    #[test]
    fn hybrid_small_rows_match_farkv_bitwise() {
        // Same accumulation stream through a small-degree hybrid row
        // and a pure Far-KV table: values and iteration order must be
        // bitwise identical (the single-thread parity contract).
        let pool = TablePool::new(TableKind::FarKv, 100, 1);
        let stream = [(5u32, 0.1), (7, 0.25), (5, 0.3), (9, 1.5), (7, 0.125), (5, 0.7)];
        let mut hybrid = pool.hybrid_table(0, 16);
        hybrid.begin_row(stream.len());
        let mut pure = pool.table(0);
        pure.clear();
        for &(c, w) in &stream {
            hybrid.accumulate(c, w);
            pure.accumulate(c, w);
        }
        assert!(hybrid.used_small());
        let mut a = Vec::new();
        hybrid.for_each(|c, w| a.push((c, w.to_bits())));
        let mut b = Vec::new();
        pure.for_each(|c, w| b.push((c, w.to_bits())));
        assert_eq!(a, b, "order or bits diverged");
        for c in [5u32, 7, 9, 11] {
            assert_eq!(hybrid.get(c).to_bits(), pure.get(c).to_bits());
        }
    }

    #[test]
    fn hybrid_routes_by_degree_and_resets_per_row() {
        let pool = TablePool::new(TableKind::FarKv, 64, 1);
        let mut t = pool.hybrid_table(0, 4);
        t.begin_row(3); // small
        t.accumulate(1, 1.0);
        assert!(t.used_small());
        t.begin_row(10); // big
        t.accumulate(2, 2.0);
        assert!(!t.used_small());
        assert_eq!(t.get(1), 0.0, "big side must not see small-side dirt");
        t.begin_row(2); // small again: previous small row's entries gone
        assert!(t.is_empty());
        assert_eq!(t.get(1), 0.0);
        assert_eq!(t.small_rows(), 2);
        assert_eq!(t.big_rows(), 1);
    }

    #[test]
    fn hybrid_spills_at_capacity_boundary() {
        let pool = TablePool::new(TableKind::FarKv, 1000, 1);
        // Exactly CAP distinct keys: stays small, no spill.
        let mut t = pool.hybrid_table(0, 1000);
        t.begin_row(SMALL_TABLE_CAP);
        for c in 0..SMALL_TABLE_CAP as u32 {
            t.accumulate(c, c as f64 + 0.5);
        }
        assert!(t.used_small());
        assert_eq!(t.spills(), 0);
        assert_eq!(t.len(), SMALL_TABLE_CAP);
        // One more distinct key: spills into the big table, first-touch
        // order preserved, values exact.
        t.accumulate(900, 9.0);
        assert!(!t.used_small());
        assert_eq!(t.spills(), 1);
        assert_eq!(t.len(), SMALL_TABLE_CAP + 1);
        let mut order = Vec::new();
        t.for_each(|c, w| order.push((c, w)));
        let mut expect: Vec<(u32, f64)> =
            (0..SMALL_TABLE_CAP as u32).map(|c| (c, c as f64 + 0.5)).collect();
        expect.push((900, 9.0));
        assert_eq!(order, expect);
        // Accumulating into an existing key after the spill keeps working.
        t.accumulate(0, 1.0);
        assert_eq!(t.get(0), 1.5);
        assert_eq!(t.len(), SMALL_TABLE_CAP + 1);
    }

    #[test]
    fn hybrid_under_map_forces_big_path() {
        let pool = TablePool::new(TableKind::Map, 32, 1);
        let mut t = pool.hybrid_table(0, 16);
        t.begin_row(2); // degree ≤ small_degree, but Map disables the fast path
        t.accumulate(3, 1.0);
        assert!(!t.used_small());
        assert_eq!(t.big_rows(), 1);
        assert_eq!(t.get(3), 1.0);
    }

    #[test]
    fn hybrid_zero_small_degree_disables_fast_path() {
        let pool = TablePool::new(TableKind::FarKv, 32, 1);
        let mut t = pool.hybrid_table(0, 0);
        t.begin_row(1);
        assert!(!t.used_small());
    }

    #[test]
    fn ensure_with_exec_first_touches_and_reuses() {
        use crate::parallel::Team;
        let team = Team::new(3);
        let mut slot: Option<TablePool> = None;
        let p0 =
            TablePool::ensure_with_exec(&mut slot, TableKind::FarKv, 5000, 3, Exec::team(&team))
                .storage_ptr(2);
        // Slabs stay zeroed and usable after the touch pass.
        {
            let pool = slot.as_ref().unwrap();
            for tid in 0..3 {
                let mut t = pool.table(tid);
                t.clear();
                assert!(t.is_empty());
                t.accumulate(4999, 1.0);
                assert_eq!(t.get(4999), 1.0);
                t.clear();
            }
        }
        // Shrinking reuse must not rebuild or re-touch.
        let p1 =
            TablePool::ensure_with_exec(&mut slot, TableKind::FarKv, 100, 3, Exec::team(&team))
                .storage_ptr(2);
        assert_eq!(p0, p1, "reallocated on shrink");
        // Scoped exec and single-thread pools take the plain path.
        let mut solo: Option<TablePool> = None;
        TablePool::ensure_with_exec(&mut solo, TableKind::FarKv, 64, 1, Exec::scoped());
        assert_eq!(solo.as_ref().unwrap().threads(), 1);
    }
}
