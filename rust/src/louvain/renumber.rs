//! Community renumbering (Algorithm 1 line 10).
//!
//! After local-moving, community ids are a sparse subset of `0..|V'|`;
//! the aggregation phase needs them dense in `0..|Γ|`.

/// Renumber communities to dense ids preserving first-appearance order.
/// Returns the number of communities `|Γ|`.
pub fn renumber_communities(membership: &mut [u32]) -> usize {
    let n = membership.len();
    if n == 0 {
        return 0;
    }
    let max = membership.iter().copied().max().unwrap() as usize;
    let mut remap = vec![u32::MAX; max + 1];
    let mut next = 0u32;
    for c in membership.iter_mut() {
        let slot = &mut remap[*c as usize];
        if *slot == u32::MAX {
            *slot = next;
            next += 1;
        }
        *c = *slot;
    }
    next as usize
}

/// Count distinct communities without renumbering.
pub fn count_communities(membership: &[u32]) -> usize {
    if membership.is_empty() {
        return 0;
    }
    let max = membership.iter().copied().max().unwrap() as usize;
    let mut seen = vec![false; max + 1];
    let mut n = 0usize;
    for &c in membership {
        if !seen[c as usize] {
            seen[c as usize] = true;
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renumber_dense_and_stable() {
        let mut m = vec![7, 3, 7, 9, 3];
        let n = renumber_communities(&mut m);
        assert_eq!(n, 3);
        assert_eq!(m, vec![0, 1, 0, 2, 1]);
    }

    #[test]
    fn renumber_already_dense_is_identity_up_to_order() {
        let mut m = vec![0, 1, 2, 1];
        let n = renumber_communities(&mut m);
        assert_eq!(n, 3);
        assert_eq!(m, vec![0, 1, 2, 1]);
    }

    #[test]
    fn renumber_empty() {
        let mut m: Vec<u32> = vec![];
        assert_eq!(renumber_communities(&mut m), 0);
    }

    #[test]
    fn count_matches_renumber() {
        let m = vec![5, 5, 2, 9, 2, 0];
        assert_eq!(count_communities(&m), 4);
        let mut mm = m.clone();
        assert_eq!(renumber_communities(&mut mm), 4);
    }
}
