//! Community renumbering (Algorithm 1 line 10).
//!
//! After local-moving, community ids are a sparse subset of `0..|V'|`;
//! the aggregation phase needs them dense in `0..|Γ|`.
//!
//! Two implementations:
//!
//! * [`renumber_communities`] — the serial reference: dense ids in
//!   *first-appearance* order (kept for the baselines and the PJRT
//!   driver, whose outputs are pinned by tests).
//! * [`renumber_communities_exec`] — the parallel version on the pass
//!   loop's hot path (PR 2 satellite: this was a serial O(n) scan per
//!   pass): flag used ids, prefix-sum the flags into dense ranks,
//!   remap.  Dense ids come out in *ascending-old-id* order — a
//!   relabeling of the same partition, identical for every thread
//!   count (the first-appearance order of the serial scan cannot be
//!   reproduced without a sequential dependency).

use crate::parallel::pool::ParallelOpts;
use crate::parallel::scan::exclusive_scan_exec;
use crate::parallel::team::Exec;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Renumber communities to dense ids preserving first-appearance order.
/// Returns the number of communities `|Γ|`.
pub fn renumber_communities(membership: &mut [u32]) -> usize {
    let n = membership.len();
    if n == 0 {
        return 0;
    }
    let max = membership.iter().copied().max().unwrap() as usize;
    let mut remap = vec![u32::MAX; max + 1];
    let mut next = 0u32;
    for c in membership.iter_mut() {
        let slot = &mut remap[*c as usize];
        if *slot == u32::MAX {
            *slot = next;
            next += 1;
        }
        *c = *slot;
    }
    next as usize
}

/// Parallel renumbering to dense ids in ascending-old-id order.
///
/// Requires every community id to be `< membership.len()` (true on the
/// pass loop: community ids are vertex ids of `G'`).  `scratch` is a
/// workspace-owned buffer reused across passes; returns `|Γ|`.
pub fn renumber_communities_exec(
    membership: &mut [u32],
    scratch: &mut Vec<usize>,
    opts: ParallelOpts,
    exec: Exec,
) -> usize {
    let n = membership.len();
    if n == 0 {
        return 0;
    }
    debug_assert!(membership.iter().all(|&c| (c as usize) < n), "community id out of range");
    // Phase 1: flag used ids (benign same-value races).  The zero-fill
    // is a chunked parallel loop too — a serial clear+resize here would
    // sneak the O(n) scan this function exists to remove back in.
    scratch.resize(n, 0);
    exec.run_disjoint_mut(&mut scratch[..], opts, |_r, chunk| {
        chunk.fill(0);
    });
    {
        let flags: &[AtomicUsize] =
            unsafe { &*(scratch.as_mut_slice() as *mut [usize] as *const [AtomicUsize]) };
        let memb: &[u32] = membership;
        exec.run(n, opts, |r| {
            for i in r {
                flags[memb[i] as usize].store(1, Ordering::Relaxed);
            }
        });
    }
    // Phase 2: exclusive scan turns flags into dense ranks; the grand
    // total is the community count.
    let total = exclusive_scan_exec(scratch, opts.threads, exec);
    // Phase 3: remap through the rank table.
    {
        let rank: &[usize] = &scratch[..];
        exec.run_disjoint_mut(membership, opts, |_r, chunk| {
            for c in chunk.iter_mut() {
                *c = rank[*c as usize] as u32;
            }
        });
    }
    total
}

/// Count distinct communities without renumbering.
pub fn count_communities(membership: &[u32]) -> usize {
    if membership.is_empty() {
        return 0;
    }
    let max = membership.iter().copied().max().unwrap() as usize;
    let mut seen = vec![false; max + 1];
    let mut n = 0usize;
    for &c in membership {
        if !seen[c as usize] {
            seen[c as usize] = true;
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renumber_dense_and_stable() {
        let mut m = vec![7, 3, 7, 9, 3];
        let n = renumber_communities(&mut m);
        assert_eq!(n, 3);
        assert_eq!(m, vec![0, 1, 0, 2, 1]);
    }

    #[test]
    fn renumber_already_dense_is_identity_up_to_order() {
        let mut m = vec![0, 1, 2, 1];
        let n = renumber_communities(&mut m);
        assert_eq!(n, 3);
        assert_eq!(m, vec![0, 1, 2, 1]);
    }

    #[test]
    fn renumber_empty() {
        let mut m: Vec<u32> = vec![];
        assert_eq!(renumber_communities(&mut m), 0);
    }

    #[test]
    fn count_matches_renumber() {
        let m = vec![5, 5, 2, 9, 2, 0];
        assert_eq!(count_communities(&m), 4);
        let mut mm = m.clone();
        assert_eq!(renumber_communities(&mut mm), 4);
    }

    #[test]
    fn exec_renumber_dense_ascending_order() {
        let mut m = vec![5, 5, 2, 9, 2, 0];
        let mut scratch = Vec::new();
        let n = renumber_communities_exec(&mut m, &mut scratch, ParallelOpts::default(), Exec::scoped());
        assert_eq!(n, 4);
        // Ascending-old-id order: 0→0, 2→1, 5→2, 9→3.
        assert_eq!(m, vec![2, 2, 1, 3, 1, 0]);
    }

    #[test]
    fn exec_renumber_matches_serial_count_and_partition() {
        use crate::parallel::prng::Xoshiro256;
        use crate::parallel::team::Team;
        let team = Team::new(4);
        let mut rng = Xoshiro256::new(3);
        for n in [1usize, 17, 1000, 40_000] {
            let base: Vec<u32> = (0..n).map(|_| rng.below(n as u64) as u32).collect();
            let mut serial = base.clone();
            let ns = renumber_communities(&mut serial);
            for exec in [Exec::scoped(), Exec::team(&team)] {
                let mut par = base.clone();
                let mut scratch = Vec::new();
                let opts = ParallelOpts { threads: 4, chunk: 64, ..Default::default() };
                let np = renumber_communities_exec(&mut par, &mut scratch, opts, exec);
                assert_eq!(np, ns, "n={n}");
                // Ids dense and the partition identical up to relabeling:
                // same-old-id pairs stay together, distinct stay apart.
                if n > 0 {
                    assert_eq!(*par.iter().max().unwrap() as usize + 1, np);
                }
                for i in 0..n.min(500) {
                    for j in (i + 1)..n.min(500) {
                        assert_eq!(base[i] == base[j], par[i] == par[j], "n={n} ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn exec_renumber_empty() {
        let mut m: Vec<u32> = vec![];
        let mut s = Vec::new();
        assert_eq!(
            renumber_communities_exec(&mut m, &mut s, ParallelOpts::default(), Exec::scoped()),
            0
        );
    }
}
