//! Local-moving phase (Algorithm 2).
//!
//! Asynchronous: threads read neighbour memberships as they go (relaxed
//! atomics — the paper's OpenMP implementation has the same benign
//! races), move vertices greedily to the best-ΔQ community, update `Σ'`
//! atomically, and (with pruning, §4.1.6) mark moved vertices'
//! neighbours for reprocessing.

use super::hashtable::TablePool;
use super::modularity::delta_modularity;
use super::params::LouvainParams;
use super::Counters;
use crate::graph::Csr;
use crate::parallel::atomics::{as_atomic_f64, as_atomic_u32, AtomicF64};
use crate::parallel::pool::{ChunkRecord, ParallelOpts};
use crate::parallel::prefetch::prefetch_read;
use crate::parallel::schedule::{DealSpec, ScanOrder, Schedule};
use crate::parallel::team::Exec;
use crate::trace;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Result of one local-moving phase.
#[derive(Debug, Default)]
pub struct MoveOutcome {
    /// Iterations performed (`l_i`).
    pub iterations: usize,
    /// Sum of accepted ΔQ over all iterations.
    pub dq_total: f64,
    pub counters: Counters,
    /// Per-iteration chunk records for the scaling replay model
    /// (empty unless `params.record_chunks`).
    pub loops: Vec<(Schedule, Vec<ChunkRecord>)>,
}

/// Run the local-moving phase on `g` (`G'`).
///
/// * `membership` — `C'`, updated in place;
/// * `vertex_weight` — `K'` (read-only);
/// * `sigma` — `Σ'`, updated in place;
/// * `affected` — pruning flags (1 = process); all-1 on entry for a
///   fresh pass. Ignored (all vertices processed) when
///   `params.pruning` is false.
/// * `tau` — this pass's convergence tolerance;
/// * `order` — degree-bucketed scan order for
///   [`Schedule::DegreeBucketed`]; `None` iterates vertex ids directly
///   (every other schedule);
/// * `exec` — the executor: the pass loop hands in its persistent
///   [`Team`](crate::parallel::team::Team); tests may use
///   [`Exec::scoped`] for the spawn-per-loop reference path.
#[allow(clippy::too_many_arguments)]
pub fn local_moving(
    g: &Csr,
    membership: &mut [u32],
    vertex_weight: &[f64],
    sigma: &mut [f64],
    affected: &mut [u32],
    pool: &TablePool,
    params: &LouvainParams,
    m: f64,
    tau: f64,
    order: Option<&ScanOrder>,
    exec: Exec,
) -> MoveOutcome {
    let n = g.num_vertices();
    let memb = as_atomic_u32(membership);
    let sig = as_atomic_f64(sigma);
    let flags = as_atomic_u32(affected);
    let pf = params.prefetch_distance;

    let mut out = MoveOutcome::default();
    let opts = ParallelOpts {
        threads: params.threads,
        schedule: params.schedule,
        chunk: params.chunk,
        record: params.record_chunks,
    };
    let spec = order.map(|o| o.spec()).unwrap_or(DealSpec::Flat);
    // Hoisted: tracing state cannot change mid-phase (a session wraps
    // whole runs), so the disabled cost here is one relaxed load total.
    let traced = trace::enabled();

    for _li in 0..params.max_iterations {
        let mut iter_span = if traced {
            trace::span("move.iter", trace::Category::Move, [_li as u64, 0, 0, 0])
        } else {
            None
        };
        // Per-bucket scan time (low/mid/high), accumulated per chunk:
        // BucketDealer chunks never straddle bucket boundaries, so one
        // Instant pair per body invocation attributes cleanly.
        let bucket_ns = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
        let time_buckets = traced && order.is_some();
        let dq_iter = AtomicF64::new(0.0);
        let scanned = AtomicU64::new(0);
        let moves = AtomicU64::new(0);
        let table_ops = AtomicU64::new(0);
        let processed = AtomicU64::new(0);
        let pruned = AtomicU64::new(0);
        let small_scans = AtomicU64::new(0);
        let large_scans = AtomicU64::new(0);

        let stats = exec.run_ctx_spec(
            n,
            opts,
            spec,
            |tid| pool.hybrid_table(tid, params.small_degree),
            |table, range| {
                let chunk_start = range.start;
                let t_chunk = if time_buckets { Some(Instant::now()) } else { None };
                let mut l_dq = 0.0f64;
                let mut l_scanned = 0u64;
                let mut l_moves = 0u64;
                let mut l_ops = 0u64;
                let mut l_proc = 0u64;
                let mut l_pruned = 0u64;
                let mut l_small = 0u64;
                let mut l_large = 0u64;
                for pos in range {
                    // Under DegreeBucketed the dealt range indexes the
                    // scan order's positions; otherwise it *is* the ids.
                    let i = match order {
                        Some(o) => o.ids[pos] as usize,
                        None => pos,
                    };
                    if params.pruning {
                        // Claim-and-clear the processed mark (prune).
                        if flags[i].swap(0, Ordering::Relaxed) == 0 {
                            l_pruned += 1;
                            continue;
                        }
                    }
                    l_proc += 1;
                    let (ts, ws) = g.edges(i);
                    if ts.is_empty() {
                        continue;
                    }
                    // scanCommunities (self = false). Hot loop: unchecked
                    // indexing (targets are validated at CSR build time)
                    // — see EXPERIMENTS.md §Perf.  Degree routes the row
                    // into the SmallTable or the pooled slab (PR 6).
                    table.begin_row(ts.len());
                    for idx in 0..ts.len() {
                        if pf > 0 {
                            // Pull the membership word we'll gather `pf`
                            // neighbours from now into cache.
                            if let Some(&tf) = ts.get(idx + pf) {
                                prefetch_read(memb, tf as usize);
                            }
                        }
                        // SAFETY: idx < ts.len() == ws.len().
                        let t = unsafe { *ts.get_unchecked(idx) };
                        let w = unsafe { *ws.get_unchecked(idx) };
                        if t as usize == i {
                            continue;
                        }
                        // SAFETY: `validate()` guarantees t < |V'|.
                        let cj = unsafe { memb.get_unchecked(t as usize) }
                            .load(Ordering::Relaxed);
                        table.accumulate(cj, w as f64);
                    }
                    if table.used_small() {
                        l_small += 1;
                    } else {
                        l_large += 1;
                    }
                    l_ops += ts.len() as u64;
                    l_scanned += ts.len() as u64;

                    let d = memb[i].load(Ordering::Relaxed);
                    let k_i = vertex_weight[i];
                    let k_to_d = table.get(d);
                    let sigma_d = sig[d as usize].load();

                    // Choose best community (first max wins ties).
                    let mut best_c = d;
                    let mut best_dq = 0.0f64;
                    table.for_each(|c, k_to_c| {
                        if c == d {
                            return;
                        }
                        // SAFETY: community ids are vertex ids of G' (< |V'|).
                        let sigma_c = unsafe { sig.get_unchecked(c as usize) }.load();
                        let dq = delta_modularity(k_to_c, k_to_d, k_i, sigma_c, sigma_d, m);
                        if dq > best_dq {
                            best_dq = dq;
                            best_c = c;
                        }
                    });

                    if best_c != d && best_dq > 0.0 {
                        sig[d as usize].fetch_sub(k_i);
                        sig[best_c as usize].fetch_add(k_i);
                        memb[i].store(best_c, Ordering::Relaxed);
                        l_dq += best_dq;
                        l_moves += 1;
                        if params.pruning {
                            for t in ts {
                                flags[*t as usize].store(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                dq_iter.fetch_add(l_dq);
                scanned.fetch_add(l_scanned, Ordering::Relaxed);
                moves.fetch_add(l_moves, Ordering::Relaxed);
                table_ops.fetch_add(l_ops, Ordering::Relaxed);
                processed.fetch_add(l_proc, Ordering::Relaxed);
                pruned.fetch_add(l_pruned, Ordering::Relaxed);
                small_scans.fetch_add(l_small, Ordering::Relaxed);
                large_scans.fetch_add(l_large, Ordering::Relaxed);
                if let (Some(t), Some(o)) = (t_chunk, order) {
                    let b = if chunk_start < o.lo_end {
                        0
                    } else if chunk_start < o.mid_end {
                        1
                    } else {
                        2
                    };
                    bucket_ns[b].fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            },
        );

        out.iterations += 1;
        let dq = dq_iter.load();
        out.dq_total += dq;
        out.counters.edges_scanned_move += scanned.load(Ordering::Relaxed);
        out.counters.moves_applied += moves.load(Ordering::Relaxed);
        out.counters.table_ops += table_ops.load(Ordering::Relaxed);
        out.counters.vertices_processed += processed.load(Ordering::Relaxed);
        out.counters.vertices_pruned += pruned.load(Ordering::Relaxed);
        out.counters.small_path_scans += small_scans.load(Ordering::Relaxed);
        out.counters.large_path_scans += large_scans.load(Ordering::Relaxed);
        if let Some(g) = iter_span.as_mut() {
            g.args = [
                _li as u64,
                processed.load(Ordering::Relaxed),
                moves.load(Ordering::Relaxed),
                pruned.load(Ordering::Relaxed),
            ];
        }
        drop(iter_span);
        // Per-iteration counter *deltas* (PR 8 satellite): the atomics
        // above are fresh each iteration, so their loads are exactly
        // this iteration's work — `pass.counters` only snapshots once
        // per pass, which hides how pruning converges *within* one.
        if traced {
            trace::instant(
                "move.iter.counters",
                trace::Category::Counter,
                [
                    _li as u64,
                    small_scans.load(Ordering::Relaxed),
                    large_scans.load(Ordering::Relaxed),
                    table_ops.load(Ordering::Relaxed),
                ],
            );
        }
        // Same delta into the live registry's convergence histogram:
        // one zero-alloc record per iteration, nothing per vertex.
        crate::obs::sites::louvain_move_iter_moves().record(moves.load(Ordering::Relaxed));
        if time_buckets {
            trace::instant(
                "move.buckets",
                trace::Category::Move,
                [
                    _li as u64,
                    bucket_ns[0].load(Ordering::Relaxed),
                    bucket_ns[1].load(Ordering::Relaxed),
                    bucket_ns[2].load(Ordering::Relaxed),
                ],
            );
        }
        if params.record_chunks {
            out.loops.push((params.schedule, stats.chunks));
        }
        if dq <= tau {
            break; // locally converged (Algorithm 2 line 14)
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::louvain::modularity::modularity;
    use crate::louvain::params::TableKind;

    fn setup(g: &Csr) -> (Vec<u32>, Vec<f64>, Vec<f64>, Vec<u32>) {
        let n = g.num_vertices();
        let membership: Vec<u32> = (0..n as u32).collect();
        let k: Vec<f64> = g.vertex_weights();
        let sigma = k.clone();
        let affected = vec![1u32; n];
        (membership, k, sigma, affected)
    }

    #[test]
    fn two_triangles_find_the_obvious_communities() {
        // Two triangles joined by one bridge edge.
        let g = GraphBuilder::new(6)
            .edge(0, 1, 1.0).edge(1, 2, 1.0).edge(0, 2, 1.0)
            .edge(3, 4, 1.0).edge(4, 5, 1.0).edge(3, 5, 1.0)
            .edge(2, 3, 1.0)
            .build_undirected();
        let (mut memb, k, mut sigma, mut aff) = setup(&g);
        let params = LouvainParams::default();
        let pool = TablePool::new(TableKind::FarKv, 6, 1);
        let m = g.total_weight();
        let out = local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped());
        assert!(out.iterations >= 1);
        assert_eq!(memb[0], memb[1]);
        assert_eq!(memb[1], memb[2]);
        assert_eq!(memb[3], memb[4]);
        assert_eq!(memb[4], memb[5]);
        assert_ne!(memb[0], memb[3]);
        assert!(out.dq_total > 0.0);
    }

    #[test]
    fn moves_never_decrease_modularity() {
        for f in GraphFamily::ALL {
            let g = generate(f, 9, 17);
            let n = g.num_vertices();
            let (mut memb, k, mut sigma, mut aff) = setup(&g);
            let q0 = modularity(&g, &(0..n as u32).collect::<Vec<_>>());
            let params = LouvainParams::default();
            let pool = TablePool::new(TableKind::FarKv, n, 1);
            let m = g.total_weight();
            local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped());
            let q1 = modularity(&g, &memb);
            assert!(q1 >= q0 - 1e-9, "{f:?}: q0={q0} q1={q1}");
        }
    }

    #[test]
    fn sigma_stays_consistent_with_membership() {
        let g = generate(GraphFamily::Web, 9, 23);
        let n = g.num_vertices();
        let (mut memb, k, mut sigma, mut aff) = setup(&g);
        let params = LouvainParams::default();
        let pool = TablePool::new(TableKind::FarKv, n, 1);
        let m = g.total_weight();
        local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped());
        // Σ'[c] must equal the sum of K over members of c.
        let mut want = vec![0f64; n];
        for v in 0..n {
            want[memb[v] as usize] += k[v];
        }
        for c in 0..n {
            assert!((sigma[c] - want[c]).abs() < 1e-6, "Σ[{c}]={} want {}", sigma[c], want[c]);
        }
    }

    #[test]
    fn table_kinds_agree_single_thread() {
        let g = generate(GraphFamily::Social, 8, 29);
        let n = g.num_vertices();
        let m = g.total_weight();
        let mut results = Vec::new();
        for kind in [TableKind::Map, TableKind::CloseKv, TableKind::FarKv] {
            let (mut memb, k, mut sigma, mut aff) = setup(&g);
            let params = LouvainParams { table: kind, ..Default::default() };
            let pool = TablePool::new(kind, n, 1);
            local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped());
            results.push(modularity(&g, &memb));
        }
        // Map iterates keys in ascending order, KV in first-touch order:
        // tie-breaks may differ, but quality must agree closely.
        assert!((results[0] - results[2]).abs() < 0.02, "{results:?}");
        assert!((results[1] - results[2]).abs() < 1e-12, "{results:?}");
    }

    #[test]
    fn pruning_and_no_pruning_reach_similar_quality() {
        let g = generate(GraphFamily::Web, 9, 31);
        let n = g.num_vertices();
        let m = g.total_weight();
        let mut qs = Vec::new();
        for pruning in [false, true] {
            let (mut memb, k, mut sigma, mut aff) = setup(&g);
            let params = LouvainParams { pruning, ..Default::default() };
            let pool = TablePool::new(TableKind::FarKv, n, 1);
            let out = local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped());
            if pruning {
                assert!(out.counters.vertices_pruned > 0, "pruning never skipped a vertex");
            }
            qs.push(modularity(&g, &memb));
        }
        assert!((qs[0] - qs[1]).abs() < 0.03, "{qs:?}");
    }

    #[test]
    fn max_iterations_caps_work() {
        let g = generate(GraphFamily::Social, 9, 37);
        let n = g.num_vertices();
        let (mut memb, k, mut sigma, mut aff) = setup(&g);
        let params = LouvainParams { max_iterations: 3, ..Default::default() };
        let pool = TablePool::new(TableKind::FarKv, n, 1);
        let out = local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, g.total_weight(), 0.0, None, Exec::scoped());
        assert!(out.iterations <= 3);
    }

    #[test]
    fn multithreaded_run_is_sane() {
        let g = generate(GraphFamily::Web, 10, 41);
        let n = g.num_vertices();
        let (mut memb, k, mut sigma, mut aff) = setup(&g);
        let params = LouvainParams { threads: 4, ..Default::default() };
        let pool = TablePool::new(TableKind::FarKv, n, 4);
        let m = g.total_weight();
        local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, Exec::scoped());
        let q = modularity(&g, &memb);
        assert!(q > 0.4, "multithreaded local-moving broke quality: q={q}");
        // Σ invariant still holds after concurrent updates.
        let mut want = vec![0f64; n];
        for v in 0..n {
            want[memb[v] as usize] += k[v];
        }
        for c in 0..n {
            assert!((sigma[c] - want[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn team_path_matches_scoped_path_exactly_single_thread() {
        use crate::parallel::team::Team;
        // One thread is deterministic on both executors: membership,
        // Σ' and total ΔQ must agree bit-for-bit.
        let team = Team::new(1);
        for f in [GraphFamily::Web, GraphFamily::Social] {
            let g = generate(f, 9, 43);
            let n = g.num_vertices();
            let m = g.total_weight();
            let params = LouvainParams::default();

            let (mut memb_a, k, mut sigma_a, mut aff_a) = setup(&g);
            let pool_a = TablePool::new(TableKind::FarKv, n, 1);
            let a = local_moving(&g, &mut memb_a, &k, &mut sigma_a, &mut aff_a, &pool_a, &params, m, 1e-9, None, Exec::scoped());

            let (mut memb_b, _, mut sigma_b, mut aff_b) = setup(&g);
            let pool_b = TablePool::new(TableKind::FarKv, n, 1);
            let b = local_moving(&g, &mut memb_b, &k, &mut sigma_b, &mut aff_b, &pool_b, &params, m, 1e-9, None, Exec::team(&team));

            assert_eq!(memb_a, memb_b, "{f:?}");
            assert_eq!(sigma_a, sigma_b, "{f:?}");
            assert_eq!(a.dq_total, b.dq_total, "{f:?}");
            assert_eq!(a.iterations, b.iterations, "{f:?}");
        }
    }

    #[test]
    fn team_path_quality_matches_scoped_multithreaded() {
        use crate::parallel::team::Team;
        let team = Team::new(4);
        let g = generate(GraphFamily::Web, 10, 47);
        let n = g.num_vertices();
        let m = g.total_weight();
        let params = LouvainParams { threads: 4, ..Default::default() };
        let mut qs = Vec::new();
        for exec in [Exec::scoped(), Exec::team(&team)] {
            let (mut memb, k, mut sigma, mut aff) = setup(&g);
            let pool = TablePool::new(TableKind::FarKv, n, 4);
            local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, m, 1e-9, None, exec);
            qs.push(modularity(&g, &memb));
        }
        // Benign races make 4-thread runs nondeterministic on both
        // paths; quality must still agree closely.
        assert!((qs[0] - qs[1]).abs() < 0.02, "{qs:?}");
    }

    #[test]
    fn isolated_vertices_stay_put() {
        let g = GraphBuilder::new(5).edge(0, 1, 1.0).build_undirected();
        let (mut memb, k, mut sigma, mut aff) = setup(&g);
        let params = LouvainParams::default();
        let pool = TablePool::new(TableKind::FarKv, 5, 1);
        local_moving(&g, &mut memb, &k, &mut sigma, &mut aff, &pool, &params, g.total_weight(), 1e-9, None, Exec::scoped());
        for v in 2..5 {
            assert_eq!(memb[v], v as u32);
        }
    }
}
