//! GVE-Louvain: the paper's multicore Louvain implementation.
//!
//! Structure follows the paper's Algorithms 1–3:
//!
//! * [`params`] — all tunables of §4.1 (schedule, iteration cap,
//!   tolerance + drop rate, aggregation tolerance, pruning, hashtable
//!   design, aggregation strategy);
//! * [`modularity`] — Eq. 1 / Eq. 2;
//! * [`hashtable`] — per-thread community tables: `Map` (std::map-like
//!   BTreeMap), `CloseKv`, `FarKv` (§4.1.9, Fig 3);
//! * [`local_moving`] — Algorithm 2 with vertex pruning;
//! * [`aggregation`] — Algorithm 3 (prefix-sum CSR + holey CSR) and the
//!   2-D-array ablation variant (§4.1.7–4.1.8);
//! * [`renumber`] / [`dendrogram`] — community renumbering and
//!   dendrogram lookup;
//! * [`workspace`] — the zero-allocation pass workspace: persistent
//!   worker team, table pool and pass buffers reused across passes;
//! * [`gve`] — the pass loop (Algorithm 1) with phase/pass metrics;
//! * [`dynamic`] — incrementally-seeded Louvain over evolving graphs
//!   (PR 2): warm-started and delta-screened batch updates driving the
//!   existing pruning flags instead of full recomputation.

pub mod aggregation;
pub mod dendrogram;
pub mod dynamic;
pub mod gve;
pub mod hashtable;
pub mod local_moving;
pub mod modularity;
pub mod params;
pub mod renumber;
pub mod workspace;

pub use dynamic::{DynamicLouvain, DynamicOutcome, SeedStrategy};
pub use gve::{GveLouvain, LouvainResult, PassSeed, PassStats};
pub use params::LouvainParams;
pub use workspace::LouvainWorkspace;

/// Work counters shared by CPU and GPU paths; they feed the device cost
/// models and the phase-split reports.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    /// Edge slots scanned during local-moving.
    pub edges_scanned_move: u64,
    /// Edge slots scanned during aggregation.
    pub edges_scanned_agg: u64,
    /// Accepted community moves.
    pub moves_applied: u64,
    /// Hashtable accumulate operations.
    pub table_ops: u64,
    /// Vertices processed (local-moving iterations summed).
    pub vertices_processed: u64,
    /// Vertices skipped by pruning.
    pub vertices_pruned: u64,
    /// Rows whose scan *completed* in the `SmallTable` fast path
    /// (PR 6; a row that spilled counts as large — the slab did the
    /// work).
    pub small_path_scans: u64,
    /// Rows whose scan completed in the pooled big table.
    pub large_path_scans: u64,
}

impl Counters {
    pub fn merge(&mut self, o: &Counters) {
        self.edges_scanned_move += o.edges_scanned_move;
        self.edges_scanned_agg += o.edges_scanned_agg;
        self.moves_applied += o.moves_applied;
        self.table_ops += o.table_ops;
        self.vertices_processed += o.vertices_processed;
        self.vertices_pruned += o.vertices_pruned;
        self.small_path_scans += o.small_path_scans;
        self.large_path_scans += o.large_path_scans;
    }
}
