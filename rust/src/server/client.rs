//! Minimal wire-protocol client (PR 9): the ingest and subscribe halves
//! the tests and the bench drive against a live [`LouvainServer`].
//!
//! [`Client`] is the write half: it streams Ops frames and respects the
//! server's backpressure through an **ack window** — at most
//! `ack_window` edge ops may be unacknowledged before `send_ops`
//! blocks reading acks.  Combined with the server's bounded queue and
//! the TCP window this bounds the bytes in flight end to end; no side
//! ever buffers an unbounded backlog.
//!
//! [`Subscriber`] is the read half: it is primed with a full snapshot
//! on connect and then folds every Delta frame into its mirror
//! membership, so a consumer reconstructs each epoch *exactly* without
//! ever re-reading a full membership (unless the server decides a full
//! frame is cheaper — renumber-invalidating epochs).

use super::frame::{
    encode_frame, read_frame, Frame, Role, PROTOCOL_VERSION,
};
use crate::graph::delta::StreamOp;
use crate::service::delta::EpochDelta;
use anyhow::{bail, Context, Result};
use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};

/// Edge ops that may be in flight (sent, not yet acked) before
/// [`Client::send_ops`] stalls to drain acks.
pub const DEFAULT_ACK_WINDOW: u64 = 4096;

/// Ingest-side connection: streams ops, tracks cumulative acks.
pub struct Client {
    stream: TcpStream,
    server_epoch: u64,
    /// Edge ops sent (commits excluded — they carry no ack weight).
    sent: u64,
    accepted: u64,
    rejected: u64,
    ack_window: u64,
}

/// What a cleanly finished ingest connection saw.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientReport {
    /// Edge ops the server admitted from this connection.
    pub accepted: u64,
    /// Edge ops the growth guard rejected.
    pub rejected: u64,
    /// Latest epoch id carried by the final ack.
    pub epoch: u64,
}

impl Client {
    /// Connect, handshake (Hello → Welcome), default ack window.
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Self::connect_with_window(addr, DEFAULT_ACK_WINDOW)
    }

    /// [`Self::connect`] with an explicit ack window (tests shrink it
    /// to force the stall path).
    pub fn connect_with_window(addr: SocketAddr, ack_window: u64) -> Result<Self> {
        let mut stream = TcpStream::connect(addr).context("connect to louvain server")?;
        stream.set_nodelay(true)?;
        stream.write_all(&encode_frame(&Frame::Hello { role: Role::Ingest }))?;
        let server_epoch = expect_welcome(&mut stream)?;
        Ok(Self {
            stream,
            server_epoch,
            sent: 0,
            accepted: 0,
            rejected: 0,
            ack_window: ack_window.max(1),
        })
    }

    /// Epoch the server reported most recently (Welcome, then acks).
    pub fn server_epoch(&self) -> u64 {
        self.server_epoch
    }

    /// Cumulative `(accepted, rejected)` acknowledged so far.
    pub fn acked(&self) -> (u64, u64) {
        (self.accepted, self.rejected)
    }

    /// Edge ops sent but not yet acknowledged.
    pub fn in_flight(&self) -> u64 {
        self.sent - self.accepted - self.rejected
    }

    /// Send one Ops frame; stall on acks while the window is exceeded.
    pub fn send_ops(&mut self, ops: &[StreamOp]) -> Result<()> {
        self.stream.write_all(&encode_frame(&Frame::Ops { ops: ops.to_vec() }))?;
        self.sent += ops.iter().filter(|o| !matches!(o, StreamOp::Commit)).count() as u64;
        while self.in_flight() > self.ack_window {
            self.read_ack()?;
        }
        Ok(())
    }

    /// Send an explicit epoch boundary ([`StreamOp::Commit`]).
    pub fn commit(&mut self) -> Result<()> {
        self.send_ops(&[StreamOp::Commit])
    }

    /// Block until every sent op has been acknowledged (admitted to the
    /// server's pending batch or rejected) — without closing the
    /// connection.  After this, dropping the connection cannot lose
    /// anything: the drain-on-shutdown guarantee covers admitted ops.
    pub fn sync(&mut self) -> Result<()> {
        while self.in_flight() > 0 {
            self.read_ack()?;
        }
        Ok(())
    }

    fn read_ack(&mut self) -> Result<()> {
        match read_frame(&mut self.stream)? {
            Some(Frame::Ack { accepted, rejected, epoch }) => {
                self.accepted = accepted;
                self.rejected = rejected;
                self.server_epoch = epoch;
                Ok(())
            }
            Some(Frame::Error { code, message }) => {
                bail!("server error {code}: {message}")
            }
            Some(other) => bail!("expected ack, got {other:?}"),
            None => bail!("server closed the connection mid-stream"),
        }
    }

    /// Clean shutdown: send Bye, drain acks until every sent op is
    /// accounted for (the server's final ack), report.
    pub fn finish(mut self) -> Result<ClientReport> {
        self.stream.write_all(&encode_frame(&Frame::Bye))?;
        loop {
            if self.accepted + self.rejected == self.sent {
                break;
            }
            match read_frame(&mut self.stream)? {
                Some(Frame::Ack { accepted, rejected, epoch }) => {
                    self.accepted = accepted;
                    self.rejected = rejected;
                    self.server_epoch = epoch;
                }
                Some(Frame::Error { code, message }) => {
                    bail!("server error {code}: {message}")
                }
                Some(other) => bail!("expected ack, got {other:?}"),
                None => bail!(
                    "server closed before acking everything ({} of {} edge ops)",
                    self.accepted + self.rejected,
                    self.sent
                ),
            }
        }
        Ok(ClientReport {
            accepted: self.accepted,
            rejected: self.rejected,
            epoch: self.server_epoch,
        })
    }
}

/// One event off the subscription stream.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochUpdate {
    pub epoch: u64,
    /// Whether this arrived as a full Snapshot frame (subscribe
    /// priming and renumber-invalidating epochs) or a compact Delta.
    pub full: bool,
    /// Vertices whose community changed (full frames count every
    /// vertex — the mirror is rebuilt).
    pub changed: usize,
    pub modularity: f64,
    pub num_communities: u32,
}

/// Subscribe-side connection: mirrors the membership epoch by epoch.
pub struct Subscriber {
    stream: TcpStream,
    epoch: u64,
    modularity: f64,
    num_communities: u32,
    membership: Vec<u32>,
}

impl Subscriber {
    /// Connect, handshake, and prime on the initial full snapshot.
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let mut stream = TcpStream::connect(addr).context("connect to louvain server")?;
        stream.set_nodelay(true)?;
        stream.write_all(&encode_frame(&Frame::Hello { role: Role::Subscribe }))?;
        expect_welcome(&mut stream)?;
        match read_frame(&mut stream)? {
            Some(Frame::Snapshot { epoch, num_communities, modularity, membership }) => {
                Ok(Self { stream, epoch, modularity, num_communities, membership })
            }
            Some(Frame::Error { code, message }) => bail!("server error {code}: {message}"),
            other => bail!("expected priming snapshot, got {other:?}"),
        }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn modularity(&self) -> f64 {
        self.modularity
    }

    pub fn num_communities(&self) -> u32 {
        self.num_communities
    }

    /// The mirror membership as of the last event.
    pub fn membership(&self) -> &[u32] {
        &self.membership
    }

    /// Block for the next epoch event; `None` on clean server close.
    pub fn next_event(&mut self) -> Result<Option<EpochUpdate>> {
        match read_frame(&mut self.stream)? {
            None => Ok(None),
            Some(Frame::Snapshot { epoch, num_communities, modularity, membership }) => {
                let changed = membership.len();
                self.epoch = epoch;
                self.modularity = modularity;
                self.num_communities = num_communities;
                self.membership = membership;
                Ok(Some(EpochUpdate { epoch, full: true, changed, modularity, num_communities }))
            }
            Some(Frame::Delta {
                epoch,
                base_epoch,
                vertices,
                num_communities,
                modularity,
                changes,
            }) => {
                if base_epoch != self.epoch {
                    bail!(
                        "delta base epoch {base_epoch} does not match mirror epoch {}",
                        self.epoch
                    );
                }
                if let Some(&(v, _)) = changes.iter().find(|&&(v, _)| v >= vertices) {
                    bail!("delta change vertex {v} out of range (|V|={vertices})");
                }
                let changed = changes.len();
                let delta = EpochDelta {
                    epoch,
                    base_epoch,
                    vertices: vertices as usize,
                    num_communities: num_communities as usize,
                    modularity,
                    changes,
                };
                delta.apply_to(&mut self.membership);
                self.epoch = epoch;
                self.modularity = modularity;
                self.num_communities = num_communities;
                Ok(Some(EpochUpdate { epoch, full: false, changed, modularity, num_communities }))
            }
            Some(Frame::Error { code, message }) => bail!("server error {code}: {message}"),
            Some(other) => bail!("unexpected frame on subscription stream: {other:?}"),
        }
    }
}

/// Read the handshake answer; returns the server's current epoch.
fn expect_welcome(stream: &mut TcpStream) -> Result<u64> {
    match read_frame(stream)? {
        Some(Frame::Welcome { version, epoch }) => {
            if version != PROTOCOL_VERSION {
                bail!("protocol version mismatch: server {version}, client {PROTOCOL_VERSION}");
            }
            Ok(epoch)
        }
        Some(Frame::Error { code, message }) => bail!("server error {code}: {message}"),
        other => bail!("expected welcome, got {other:?}"),
    }
}
