//! The serving daemon: a [`CommunityService`] behind a TCP front end
//! (PR 9 tentpole).
//!
//! ## Threading model
//!
//! The service keeps its PR-3 single-writer contract — exactly one
//! thread ever holds `&mut CommunityService`:
//!
//! ```text
//!  reader (per conn) ──┐
//!  reader (per conn) ──┼── bounded sync_channel<Msg> ──▶ ingest thread
//!  tick (timer)      ──┘                                  (owns the service)
//!                                                             │ publishes
//!  writer (per conn) ◀── bounded outbox<Arc<[u8]>> ───────────┘
//! ```
//!
//! * **Readers** (one per connection) parse frames off the socket and
//!   forward ops into the queue.  When the queue is full they block —
//!   which stops reading that socket, fills the peer's TCP window and
//!   surfaces to the client as an ack-window stall.  That is the whole
//!   backpressure story: bounded queue, bounded outboxes, no unbounded
//!   buffer anywhere.
//! * **The ingest thread** constructs the service (boot detection runs
//!   here), drains the queue, drives [`CommunityService::submit`], and
//!   — on every published epoch — computes the membership delta vs the
//!   previous snapshot and fans it out.  A timer thread injects
//!   [`Msg::Tick`]s so [`CommunityService::poll`] runs even when every
//!   stream goes quiet: the max-latency flush bound finally works
//!   without an external driver loop (ROADMAP item).
//! * **Writers** (one per connection) drain an outbox of pre-encoded
//!   frames.  The ingest thread only ever `try_send`s into outboxes: a
//!   subscriber that stops draining is dropped, never waited on.
//!
//! ## Shutdown drain
//!
//! [`LouvainServer::shutdown`] stops the accept loop, shuts down every
//! socket, and joins the ingest thread.  `std::sync::mpsc` guarantees
//! `recv` returns every message buffered before the last sender
//! dropped, so ops already queued (and therefore acked or about to be
//! acked) are applied, a final [`CommunityService::flush`] cuts any
//! pending partial batch into a last epoch, and only then does the
//! report come back: nothing acknowledged is ever lost.

use super::frame::{encoded, Frame, FrameError, Role, ERR_UNEXPECTED_TYPE, PROTOCOL_VERSION};
use crate::graph::delta::StreamOp;
use crate::graph::Csr;
use crate::obs::http::ServeState;
use crate::obs::sites;
use crate::service::delta::epoch_delta;
use crate::service::metrics::RecentEpoch;
use crate::service::{CommunityService, EpochSnapshot, ServiceConfig, SnapshotHandle};
use crate::trace::{self, Category};
use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Everything configurable about a [`LouvainServer`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Listen address; loopback + ephemeral port by default (tests and
    /// local tooling resolve it via [`LouvainServer::local_addr`]).
    pub bind: SocketAddr,
    pub service: ServiceConfig,
    /// Depth of the reader → ingest op queue (messages, not ops).
    pub queue_depth: usize,
    /// Depth of each connection's outbox (frames).
    pub outbox_depth: usize,
    /// Cadence of the timer tick driving [`CommunityService::poll`].
    pub tick: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            bind: SocketAddr::from(([127, 0, 0, 1], 0)),
            service: ServiceConfig::default(),
            queue_depth: 256,
            outbox_depth: 64,
            tick: Duration::from_millis(5),
        }
    }
}

/// What the ingest thread reports when the daemon drains and stops.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerReport {
    /// Edge ops admitted across all connections.
    pub ops_accepted: u64,
    /// Edge ops dropped by the growth guard.
    pub ops_rejected: u64,
    /// Update epochs published (boot excluded).
    pub epochs_published: u64,
    /// Last epoch id at shutdown.
    pub final_epoch: u64,
}

/// Messages into the single-writer ingest thread.
enum Msg {
    Connect { conn: u64, role: Role, outbox: SyncSender<Arc<[u8]>> },
    Ops { conn: u64, ops: Vec<StreamOp> },
    Bye { conn: u64 },
    Disconnect { conn: u64 },
    Tick,
}

/// Per-ingest-connection admission state.
struct ConnState {
    outbox: SyncSender<Arc<[u8]>>,
    accepted: u64,
    rejected: u64,
    /// An ack failed to enqueue; retry on the next tick.  Acks are
    /// cumulative, so coalescing dropped ones is lossless.
    ack_dirty: bool,
}

/// A running daemon; dropping it (or calling [`Self::shutdown`]) drains
/// and stops every thread.
pub struct LouvainServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    sockets: Arc<Mutex<HashMap<u64, TcpStream>>>,
    accept_join: Option<JoinHandle<()>>,
    tick_join: Option<JoinHandle<()>>,
    ingest_join: Option<JoinHandle<ServerReport>>,
    handle: SnapshotHandle,
    state: ServeState,
}

impl LouvainServer {
    /// Bind, boot the service on `g0` (the initial detection runs on
    /// the ingest thread; this call waits for epoch 0), and start
    /// accepting connections.
    pub fn start(g0: Csr, cfg: ServerConfig) -> std::io::Result<Self> {
        let listener = TcpListener::bind(cfg.bind)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let sockets: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::default();
        let state = ServeState::default();

        let (msg_tx, msg_rx) = sync_channel::<Msg>(cfg.queue_depth.max(1));
        let (boot_tx, boot_rx) = std::sync::mpsc::channel::<SnapshotHandle>();

        let ingest_join = {
            let service_cfg = cfg.service.clone();
            let summary = Arc::clone(&state.summary);
            let recent = Arc::clone(&state.recent);
            std::thread::Builder::new().name("gve-srv-ingest".into()).spawn(move || {
                ingest_loop(g0, service_cfg, msg_rx, boot_tx, summary, recent)
            })?
        };
        let handle = boot_rx.recv().map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::Other, "ingest thread died during boot")
        })?;
        let state = ServeState { snapshots: Some(Arc::clone(&handle)), ..state };

        let tick_join = {
            let stop = Arc::clone(&stop);
            let tx = msg_tx.clone();
            let tick = cfg.tick.max(Duration::from_millis(1));
            std::thread::Builder::new().name("gve-srv-tick".into()).spawn(move || {
                while !stop.load(Relaxed) {
                    std::thread::sleep(tick);
                    if tx.send(Msg::Tick).is_err() {
                        break;
                    }
                }
            })?
        };

        let accept_join = {
            let stop = Arc::clone(&stop);
            let sockets = Arc::clone(&sockets);
            let outbox_depth = cfg.outbox_depth.max(2);
            std::thread::Builder::new().name("gve-srv-accept".into()).spawn(move || {
                accept_loop(listener, stop, sockets, msg_tx, outbox_depth)
            })?
        };

        Ok(Self {
            addr,
            stop,
            sockets,
            accept_join: Some(accept_join),
            tick_join: Some(tick_join),
            ingest_join: Some(ingest_join),
            handle,
            state,
        })
    }

    /// The bound address (resolves an ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Lock-free reader handle to the current epoch — the same query
    /// surface in-process readers always had.
    pub fn handle(&self) -> SnapshotHandle {
        Arc::clone(&self.handle)
    }

    /// State for an [`IntrospectionServer`](crate::obs::http::IntrospectionServer):
    /// the ingest thread keeps the summary and the recent-epoch ring
    /// fresh, so `/epochs` works unchanged next to the wire protocol.
    pub fn serve_state(&self) -> ServeState {
        self.state.clone()
    }

    /// Drain and stop: refuse new connections, shut every socket down,
    /// apply everything already queued, cut a final epoch from any
    /// pending partial batch, then join all threads.
    pub fn shutdown(mut self) -> ServerReport {
        self.shutdown_inner().unwrap_or_default()
    }

    fn shutdown_inner(&mut self) -> Option<ServerReport> {
        let ingest = self.ingest_join.take()?;
        self.stop.store(true, Relaxed);
        // Wake the blocking accept() so it can observe the stop flag;
        // its exit drops the master msg sender.
        let _ = TcpStream::connect(self.addr);
        // Shut down every live socket: readers unblock, forward their
        // Disconnects and drop their senders.
        for (_, s) in self.sockets.lock().unwrap_or_else(|e| e.into_inner()).drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.tick_join.take() {
            let _ = j.join();
        }
        // All senders gone → the ingest thread drains the queue, cuts
        // the final epoch and returns its report.
        ingest.join().ok()
    }
}

impl Drop for LouvainServer {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    sockets: Arc<Mutex<HashMap<u64, TcpStream>>>,
    msg_tx: SyncSender<Msg>,
    outbox_depth: usize,
) {
    let mut next_id = 0u64;
    for conn in listener.incoming() {
        if stop.load(Relaxed) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let conn_id = next_id;
        next_id += 1;
        if let Ok(clone) = stream.try_clone() {
            sockets.lock().unwrap_or_else(|e| e.into_inner()).insert(conn_id, clone);
        }
        let tx = msg_tx.clone();
        let sockets = Arc::clone(&sockets);
        let spawned = std::thread::Builder::new()
            .name(format!("gve-srv-conn-{conn_id}"))
            .spawn(move || {
                sites::server_connections_opened().inc();
                sites::server_connections_active().add(1);
                reader_loop(conn_id, stream, &tx, outbox_depth);
                // Reader done (EOF, error, or protocol violation):
                // tell ingest, then forget the socket.
                let _ = tx.send(Msg::Disconnect { conn: conn_id });
                sockets.lock().unwrap_or_else(|e| e.into_inner()).remove(&conn_id);
                sites::server_connections_active().sub(1);
            });
        if spawned.is_err() {
            sockets.lock().unwrap_or_else(|e| e.into_inner()).remove(&conn_id);
        }
    }
}

/// Parse frames off one connection until EOF or a violation.
fn reader_loop(conn: u64, mut stream: TcpStream, tx: &SyncSender<Msg>, outbox_depth: usize) {
    let _ = stream.set_nodelay(true);

    // Handshake: the first frame must be a Hello.  Violations here are
    // answered directly on the socket — no writer thread exists yet.
    let role = match super::frame::read_frame(&mut stream) {
        Ok(Some(Frame::Hello { role })) => role,
        Ok(Some(_)) | Ok(None) => {
            send_error_direct(&mut stream, ERR_UNEXPECTED_TYPE, "expected hello");
            return;
        }
        Err(FrameError::Protocol { code, message }) => {
            send_error_direct(&mut stream, code, &message);
            return;
        }
        Err(FrameError::Io(_)) => return,
    };

    // Writer thread: drains pre-encoded frames onto the socket.  On
    // write failure it exits and drops its receiver, so later
    // try_sends see Disconnected and the ingest thread forgets us.
    let (outbox_tx, outbox_rx) = sync_channel::<Arc<[u8]>>(outbox_depth);
    let Ok(wstream) = stream.try_clone() else { return };
    let writer = std::thread::Builder::new()
        .name(format!("gve-srv-write-{conn}"))
        .spawn(move || writer_loop(outbox_rx, wstream));
    if writer.is_err() {
        return;
    }
    if tx.send(Msg::Connect { conn, role, outbox: outbox_tx.clone() }).is_err() {
        return;
    }
    // Subscribers never send again (except Bye); their reader holds no
    // outbox so a dropped subscriber's writer can exit immediately.
    let mut outbox_tx = (role == Role::Ingest).then_some(outbox_tx);

    loop {
        match super::frame::read_frame(&mut stream) {
            Ok(None) => return, // clean EOF
            Ok(Some(Frame::Ops { ops })) if role == Role::Ingest => {
                sites::server_frames_rx().inc();
                sites::server_ops_rx().add(ops.len() as u64);
                let msg = Msg::Ops { conn, ops };
                // Backpressure: a full queue blocks this reader, which
                // stops draining the socket and stalls the client.
                match tx.try_send(msg) {
                    Ok(()) => {}
                    Err(TrySendError::Full(msg)) => {
                        sites::server_ingest_stalls().inc();
                        if tx.send(msg).is_err() {
                            return;
                        }
                    }
                    Err(TrySendError::Disconnected(_)) => return,
                }
            }
            Ok(Some(Frame::Bye)) => {
                sites::server_frames_rx().inc();
                if tx.send(Msg::Bye { conn }).is_err() {
                    return;
                }
                // Drop our outbox clone: once ingest releases its
                // sender after the final ack, the writer drains and
                // half-closes, handing the client its EOF.
                outbox_tx = None;
            }
            Ok(Some(_)) => {
                sites::server_frames_rx().inc();
                send_error_outbox(&outbox_tx, ERR_UNEXPECTED_TYPE, "unexpected frame type");
                return;
            }
            Err(FrameError::Protocol { code, message }) => {
                send_error_outbox(&outbox_tx, code, &message);
                return;
            }
            Err(FrameError::Io(_)) => return, // abrupt disconnect
        }
    }
}

fn send_error_direct(stream: &mut TcpStream, code: u16, message: &str) {
    use std::io::Write as _;
    sites::server_errors_tx().inc();
    let bytes = super::frame::encode_frame(&Frame::Error { code, message: message.into() });
    let _ = stream.write_all(&bytes);
    let _ = stream.shutdown(Shutdown::Both);
}

fn send_error_outbox(outbox: &Option<SyncSender<Arc<[u8]>>>, code: u16, message: &str) {
    if let Some(tx) = outbox {
        sites::server_errors_tx().inc();
        let _ = tx.try_send(encoded(&Frame::Error { code, message: message.into() }));
    }
}

fn writer_loop(rx: Receiver<Arc<[u8]>>, mut stream: TcpStream) {
    use std::io::Write as _;
    while let Ok(frame) = rx.recv() {
        if stream.write_all(&frame).is_err() {
            return;
        }
    }
    // All senders released: everything queued is flushed; half-close
    // so a draining client sees EOF after the final frame.
    let _ = stream.shutdown(Shutdown::Write);
}

/// The single-writer loop: owns the service for the daemon's lifetime.
fn ingest_loop(
    g0: Csr,
    cfg: ServiceConfig,
    rx: Receiver<Msg>,
    boot_tx: std::sync::mpsc::Sender<SnapshotHandle>,
    summary: Arc<Mutex<crate::service::ServiceSummary>>,
    recent: Arc<Mutex<crate::service::RecentEpochs>>,
) -> ServerReport {
    let mut svc = CommunityService::new(g0, cfg);
    let mut prev = svc.snapshot();
    *summary.lock().unwrap_or_else(|e| e.into_inner()) = svc.metrics().summary();
    recent.lock().unwrap_or_else(|e| e.into_inner()).push(RecentEpoch::of(&prev));
    // If start() already gave up, connections can't exist; keep going
    // anyway so shutdown still joins a live thread.
    let _ = boot_tx.send(svc.handle());

    let mut conns: HashMap<u64, ConnState> = HashMap::new();
    let mut subs: HashMap<u64, SyncSender<Arc<[u8]>>> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Connect { conn, role, outbox } => {
                let welcome = encoded(&Frame::Welcome {
                    version: PROTOCOL_VERSION,
                    epoch: prev.epoch,
                });
                match role {
                    Role::Ingest => {
                        if outbox.try_send(welcome).is_ok() {
                            conns.insert(
                                conn,
                                ConnState { outbox, accepted: 0, rejected: 0, ack_dirty: false },
                            );
                        }
                    }
                    Role::Subscribe => {
                        // Prime with Welcome + the current full epoch;
                        // deltas stream from here on.
                        let snap_frame = encoded(&full_snapshot_frame(&prev));
                        if outbox.try_send(welcome).is_ok()
                            && outbox.try_send(snap_frame).is_ok()
                        {
                            sites::server_snapshots_tx().inc();
                            subs.insert(conn, outbox);
                        }
                    }
                }
            }
            Msg::Ops { conn, ops } => {
                let rejected_before = svc.metrics().ops_rejected;
                let mut sp =
                    trace::span("server.ingest", Category::Server, [conn, ops.len() as u64, 0, 0]);
                let edge_ops = ops
                    .iter()
                    .filter(|o| !matches!(o, StreamOp::Commit))
                    .count() as u64;
                for op in ops {
                    if let Some(snap) = svc.submit(op) {
                        publish(&svc, &snap, &mut prev, &mut subs, &summary, &recent);
                    }
                }
                let rejected = svc.metrics().ops_rejected - rejected_before;
                if let Some(g) = sp.as_mut() {
                    g.args[2] = rejected;
                }
                drop(sp);
                if let Some(c) = conns.get_mut(&conn) {
                    c.rejected += rejected;
                    c.accepted += edge_ops - rejected;
                    c.ack_dirty = !send_ack(c, prev.epoch);
                }
            }
            Msg::Bye { conn } => {
                if let Some(snap) = svc.flush() {
                    publish(&svc, &snap, &mut prev, &mut subs, &summary, &recent);
                }
                if let Some(mut c) = conns.remove(&conn) {
                    // Final ack: bounded retries — the writer is
                    // draining unless the client stopped reading, and
                    // a client that stopped reading forfeits it.
                    for _ in 0..200 {
                        if send_ack(&mut c, prev.epoch) {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                // Dropping ConnState releases the outbox; the writer
                // flushes and half-closes.
                subs.remove(&conn);
            }
            Msg::Disconnect { conn } => {
                conns.remove(&conn);
                subs.remove(&conn);
            }
            Msg::Tick => {
                if let Some(snap) = svc.poll() {
                    publish(&svc, &snap, &mut prev, &mut subs, &summary, &recent);
                }
                for c in conns.values_mut() {
                    if c.ack_dirty {
                        c.ack_dirty = !send_ack(c, prev.epoch);
                    }
                }
            }
        }
    }

    // Every sender is gone (accept loop, tick, all readers): the recv
    // above has already drained everything that was queued.  Cut any
    // pending partial batch into a final epoch so no admitted op is
    // lost, then report.
    if let Some(snap) = svc.flush() {
        publish(&svc, &snap, &mut prev, &mut subs, &summary, &recent);
    }
    *summary.lock().unwrap_or_else(|e| e.into_inner()) = svc.metrics().summary();
    let m = svc.metrics();
    ServerReport {
        ops_accepted: m.ops_ingested,
        ops_rejected: m.ops_rejected,
        epochs_published: m.batches_applied,
        final_epoch: prev.epoch,
    }
}

/// Cumulative ack for one connection; `false` if the outbox was full.
fn send_ack(c: &mut ConnState, epoch: u64) -> bool {
    let frame = encoded(&Frame::Ack { accepted: c.accepted, rejected: c.rejected, epoch });
    !matches!(c.outbox.try_send(frame), Err(TrySendError::Full(_)))
}

fn full_snapshot_frame(snap: &EpochSnapshot) -> Frame {
    Frame::Snapshot {
        epoch: snap.epoch,
        num_communities: snap.num_communities() as u32,
        modularity: snap.modularity,
        membership: snap.membership().to_vec(),
    }
}

/// Fan a published epoch out to subscribers and refresh the
/// introspection state.  Compact delta normally; full snapshot when
/// the delta would not be compact (renumber-invalidating epochs).
fn publish(
    svc: &CommunityService,
    snap: &Arc<EpochSnapshot>,
    prev: &mut Arc<EpochSnapshot>,
    subs: &mut HashMap<u64, SyncSender<Arc<[u8]>>>,
    summary: &Arc<Mutex<crate::service::ServiceSummary>>,
    recent: &Arc<Mutex<crate::service::RecentEpochs>>,
) {
    let delta = epoch_delta(prev, snap);
    let full = delta.is_major();
    let _sp = trace::span(
        "server.publish",
        Category::Server,
        [snap.epoch, delta.changes.len() as u64, subs.len() as u64, full as u64],
    );
    if !subs.is_empty() {
        let frame = if full {
            full_snapshot_frame(snap)
        } else {
            Frame::Delta {
                epoch: delta.epoch,
                base_epoch: delta.base_epoch,
                vertices: delta.vertices as u32,
                num_communities: delta.num_communities as u32,
                modularity: delta.modularity,
                changes: delta.changes,
            }
        };
        let bytes = encoded(&frame);
        subs.retain(|_, tx| match tx.try_send(Arc::clone(&bytes)) {
            Ok(()) => {
                if full {
                    sites::server_snapshots_tx().inc();
                } else {
                    sites::server_deltas_tx().inc();
                }
                true
            }
            Err(TrySendError::Full(_)) => {
                // A subscriber that stopped draining must not be able
                // to slow the epoch stream for everyone else.
                sites::server_subscribers_dropped().inc();
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        });
    }
    *prev = Arc::clone(snap);
    *summary.lock().unwrap_or_else(|e| e.into_inner()) = svc.metrics().summary();
    recent.lock().unwrap_or_else(|e| e.into_inner()).push(RecentEpoch::of(snap));
}
