//! Network serving subsystem (PR 9 tentpole): the long-running daemon
//! that turns [`CommunityService`](crate::service::CommunityService)
//! into a system other processes can talk to.
//!
//! PR 3 built the single-writer service core and PR 8 shipped the read
//! half over HTTP (`obs::http` serving the lock-free snapshot handle).
//! This module is the missing write half plus a push-based read half:
//!
//! * [`frame`] — the length-prefixed binary wire protocol.  Ops frames
//!   speak the `.ups` vocabulary (add / delete / commit) through the
//!   shared [`graph::io`](crate::graph::io) op codec, so wire streams
//!   and replay files are one op language.  Full spec (frame layouts,
//!   backpressure and delta rules) in `rust/src/server/README.md`.
//! * [`daemon`] — [`LouvainServer`]: one reader thread per connection
//!   feeding a bounded MPSC queue, a **single-writer ingest thread**
//!   owning the service, a timer tick driving
//!   [`poll`](crate::service::CommunityService::poll) (the max-latency
//!   flush bound finally works unattended — ROADMAP item), and an
//!   epoch-delta fan-out to subscriber connections with graceful
//!   drain-on-shutdown.
//! * [`client`] — [`Client`] (ingest, ack-window backpressure) and
//!   [`Subscriber`] (delta-stream mirror): the in-process client the
//!   loopback tests and the bench's `"server"` scenario drive.
//!
//! The `louvain_server` binary wraps [`LouvainServer`] with graph
//! boot, knob parsing and the `/epochs` introspection endpoint
//! ([`LouvainServer::serve_state`] plugs straight into
//! [`IntrospectionServer`](crate::obs::http::IntrospectionServer)).

pub mod client;
pub mod daemon;
pub mod frame;

pub use client::{Client, ClientReport, EpochUpdate, Subscriber, DEFAULT_ACK_WINDOW};
pub use daemon::{LouvainServer, ServerConfig, ServerReport};
pub use frame::{Frame, FrameError, Role, MAX_FRAME_LEN, PROTOCOL_VERSION};
