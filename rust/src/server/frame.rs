//! Length-prefixed binary frames for the serving daemon (PR 9).
//!
//! Every frame is `len:u32le` followed by `len` bytes: a one-byte
//! frame type and a fixed little-endian payload.  Ops frames carry the
//! `.ups` vocabulary under the same tag bytes as the text format via
//! the shared [`graph::io`](crate::graph::io) binary op codec, so the
//! wire and the replay files stay one op language.  See
//! `rust/src/server/README.md` for the full layout table and the
//! protocol rules (handshake, acks, backpressure, delta stream).
//!
//! Decoding is defensive at both ends of the connection: the length
//! prefix is validated *before* any allocation and payloads are read
//! in bounded chunks, so a malicious or corrupt peer can cost at most
//! [`MAX_FRAME_LEN`] bytes, never a `len`-sized allocation up front.

use crate::graph::delta::StreamOp;
use crate::graph::io::{decode_ops, encode_op};
use std::io::Read;

/// Protocol version carried in Welcome frames; bump on layout changes.
pub const PROTOCOL_VERSION: u16 = 1;

/// Hello magic: the first bytes a server reads from a well-formed peer.
pub const MAGIC: [u8; 4] = *b"GVL1";

/// Hard ceiling on one frame's body (type byte + payload).  Large
/// enough for a full-snapshot frame on a 64M-vertex graph, small
/// enough that a corrupt length prefix cannot ask for the address
/// space.
pub const MAX_FRAME_LEN: usize = 1 << 28;

/// Error-frame codes (the `code` field of [`Frame::Error`]).
pub const ERR_BAD_HELLO: u16 = 1;
pub const ERR_MALFORMED: u16 = 2;
pub const ERR_UNEXPECTED_TYPE: u16 = 3;
pub const ERR_OVERSIZED: u16 = 4;

const T_HELLO: u8 = 0x01;
const T_WELCOME: u8 = 0x02;
const T_OPS: u8 = 0x10;
const T_ACK: u8 = 0x20;
const T_ERROR: u8 = 0x21;
const T_SNAPSHOT: u8 = 0x31;
const T_DELTA: u8 = 0x32;
const T_BYE: u8 = 0x40;

/// What a connection is for, declared in its Hello frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Sends Ops frames, receives Acks.
    Ingest,
    /// Receives the epoch stream (Snapshot / Delta frames).
    Subscribe,
}

impl Role {
    fn to_byte(self) -> u8 {
        match self {
            Role::Ingest => 0,
            Role::Subscribe => 1,
        }
    }

    fn from_byte(b: u8) -> Option<Role> {
        match b {
            0 => Some(Role::Ingest),
            1 => Some(Role::Subscribe),
            _ => None,
        }
    }
}

/// One wire frame, decoded.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → server, first frame on every connection.
    Hello { role: Role },
    /// Server → client, answers Hello: protocol version + the epoch
    /// the server is currently publishing.
    Welcome { version: u16, epoch: u64 },
    /// Client → server (ingest role): a run of `.ups` ops.
    Ops { ops: Vec<StreamOp> },
    /// Server → client (ingest role): cumulative admission state for
    /// this connection.  `accepted + rejected` equals the edge ops the
    /// server has fully processed from it (commits carry no ack).
    Ack { accepted: u64, rejected: u64, epoch: u64 },
    /// Server → client: protocol violation; the connection closes
    /// after this frame.
    Error { code: u16, message: String },
    /// Server → subscriber: a full membership (on subscribe, and on
    /// epochs where the delta would not be compact — renumbering).
    Snapshot { epoch: u64, num_communities: u32, modularity: f64, membership: Vec<u32> },
    /// Server → subscriber: membership changes vs `base_epoch`.
    Delta {
        epoch: u64,
        base_epoch: u64,
        vertices: u32,
        num_communities: u32,
        modularity: f64,
        changes: Vec<(u32, u32)>,
    },
    /// Client → server: clean end of stream; the server answers with a
    /// final Ack and releases the connection.
    Bye,
}

/// Decode failures: transport errors stay `Io`; everything the peer
/// got wrong is `Protocol` with an error-frame code, so the server can
/// echo it back verbatim in a [`Frame::Error`].
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    Protocol { code: u16, message: String },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io: {e}"),
            FrameError::Protocol { code, message } => {
                write!(f, "protocol error {code}: {message}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

fn malformed(message: impl Into<String>) -> FrameError {
    FrameError::Protocol { code: ERR_MALFORMED, message: message.into() }
}

/// Serialize one frame, length prefix included.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = vec![0u8; 4];
    match frame {
        Frame::Hello { role } => {
            out.push(T_HELLO);
            out.extend_from_slice(&MAGIC);
            out.push(role.to_byte());
        }
        Frame::Welcome { version, epoch } => {
            out.push(T_WELCOME);
            out.extend_from_slice(&version.to_le_bytes());
            out.extend_from_slice(&epoch.to_le_bytes());
        }
        Frame::Ops { ops } => {
            out.push(T_OPS);
            out.extend_from_slice(&(ops.len() as u32).to_le_bytes());
            for op in ops {
                let mut buf = Vec::new();
                encode_op(op, &mut buf);
                out.extend_from_slice(&buf);
            }
        }
        Frame::Ack { accepted, rejected, epoch } => {
            out.push(T_ACK);
            out.extend_from_slice(&accepted.to_le_bytes());
            out.extend_from_slice(&rejected.to_le_bytes());
            out.extend_from_slice(&epoch.to_le_bytes());
        }
        Frame::Error { code, message } => {
            out.push(T_ERROR);
            out.extend_from_slice(&code.to_le_bytes());
            out.extend_from_slice(message.as_bytes());
        }
        Frame::Snapshot { epoch, num_communities, modularity, membership } => {
            out.push(T_SNAPSHOT);
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&(membership.len() as u32).to_le_bytes());
            out.extend_from_slice(&num_communities.to_le_bytes());
            out.extend_from_slice(&modularity.to_le_bytes());
            for &c in membership {
                out.extend_from_slice(&c.to_le_bytes());
            }
        }
        Frame::Delta { epoch, base_epoch, vertices, num_communities, modularity, changes } => {
            out.push(T_DELTA);
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&base_epoch.to_le_bytes());
            out.extend_from_slice(&vertices.to_le_bytes());
            out.extend_from_slice(&num_communities.to_le_bytes());
            out.extend_from_slice(&modularity.to_le_bytes());
            out.extend_from_slice(&(changes.len() as u32).to_le_bytes());
            for &(v, c) in changes {
                out.extend_from_slice(&v.to_le_bytes());
                out.extend_from_slice(&c.to_le_bytes());
            }
        }
        Frame::Bye => out.push(T_BYE),
    }
    let len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&len.to_le_bytes());
    out
}

/// [`encode_frame`] into the shared-buffer form the daemon fans out
/// (one encode, N subscriber outboxes).
pub fn encoded(frame: &Frame) -> std::sync::Arc<[u8]> {
    encode_frame(frame).into()
}

/// Read one frame off `r`.  `Ok(None)` is a clean EOF *at a frame
/// boundary*; EOF mid-frame is an `Io` error (abrupt disconnect).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, FrameError> {
    let mut lenbuf = [0u8; 4];
    if !read_exact_or_clean_eof(r, &mut lenbuf)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len == 0 {
        return Err(malformed("zero-length frame"));
    }
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Protocol {
            code: ERR_OVERSIZED,
            message: format!("frame length {len} exceeds limit {MAX_FRAME_LEN}"),
        });
    }
    // Chunked body read: the claimed length never becomes an upfront
    // allocation, so a corrupt prefix costs only what actually arrives.
    let mut body = Vec::with_capacity(len.min(1 << 16));
    let mut chunk = [0u8; 8192];
    while body.len() < len {
        let want = (len - body.len()).min(chunk.len());
        match r.read(&mut chunk[..want]) {
            Ok(0) => {
                return Err(FrameError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid-frame",
                )))
            }
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    decode_frame(body[0], &body[1..]).map(Some)
}

/// `read_exact`, except zero bytes before the first one is a clean EOF
/// (`Ok(false)`) rather than an error.
fn read_exact_or_clean_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool, FrameError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => {
                return Err(FrameError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid-frame",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(true)
}

/// Decode a frame body (`typ` byte already split off).
pub fn decode_frame(typ: u8, payload: &[u8]) -> Result<Frame, FrameError> {
    let mut cur = Cursor { buf: payload, off: 0 };
    let frame = match typ {
        T_HELLO => {
            let magic = cur.take(4)?;
            if magic != MAGIC {
                return Err(FrameError::Protocol {
                    code: ERR_BAD_HELLO,
                    message: format!("bad hello magic {magic:02x?}"),
                });
            }
            let role = Role::from_byte(cur.u8()?).ok_or_else(|| FrameError::Protocol {
                code: ERR_BAD_HELLO,
                message: "unknown hello role".into(),
            })?;
            Frame::Hello { role }
        }
        T_WELCOME => Frame::Welcome { version: cur.u16()?, epoch: cur.u64()? },
        T_OPS => {
            let count = cur.u32()? as usize;
            let ops = decode_ops(cur.rest(), count)
                .map_err(|e| malformed(format!("ops payload: {e:#}")))?;
            Frame::Ops { ops }
        }
        T_ACK => Frame::Ack { accepted: cur.u64()?, rejected: cur.u64()?, epoch: cur.u64()? },
        T_ERROR => {
            let code = cur.u16()?;
            let message = String::from_utf8_lossy(cur.rest()).into_owned();
            Frame::Error { code, message }
        }
        T_SNAPSHOT => {
            let epoch = cur.u64()?;
            let vertices = cur.u32()? as usize;
            let num_communities = cur.u32()?;
            let modularity = cur.f64()?;
            let mut membership = Vec::with_capacity(vertices.min(1 << 20));
            for _ in 0..vertices {
                membership.push(cur.u32()?);
            }
            cur.finish()?;
            Frame::Snapshot { epoch, num_communities, modularity, membership }
        }
        T_DELTA => {
            let epoch = cur.u64()?;
            let base_epoch = cur.u64()?;
            let vertices = cur.u32()?;
            let num_communities = cur.u32()?;
            let modularity = cur.f64()?;
            let count = cur.u32()? as usize;
            let mut changes = Vec::with_capacity(count.min(1 << 20));
            for _ in 0..count {
                changes.push((cur.u32()?, cur.u32()?));
            }
            cur.finish()?;
            Frame::Delta { epoch, base_epoch, vertices, num_communities, modularity, changes }
        }
        T_BYE => Frame::Bye,
        other => {
            return Err(FrameError::Protocol {
                code: ERR_UNEXPECTED_TYPE,
                message: format!("unknown frame type {other:#04x}"),
            })
        }
    };
    cur.finish()?;
    Ok(frame)
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.buf.len()).ok_or_else(|| {
            malformed(format!("frame truncated at byte {} (wanted {n} more)", self.off))
        })?;
        let s = &self.buf[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, FrameError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.off..];
        self.off = self.buf.len();
        s
    }

    /// Fixed-layout frames must consume their whole body.
    fn finish(&self) -> Result<(), FrameError> {
        if self.off != self.buf.len() {
            return Err(malformed(format!(
                "{} trailing bytes after frame body",
                self.buf.len() - self.off
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: Frame) {
        let bytes = encode_frame(&f);
        let mut r = std::io::Cursor::new(bytes);
        let got = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(got, f);
        // Clean EOF right after a whole frame.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn every_frame_round_trips() {
        round_trip(Frame::Hello { role: Role::Ingest });
        round_trip(Frame::Hello { role: Role::Subscribe });
        round_trip(Frame::Welcome { version: PROTOCOL_VERSION, epoch: 42 });
        round_trip(Frame::Ops {
            ops: vec![
                StreamOp::Insert(1, 2, 0.5),
                StreamOp::Delete(3, 4),
                StreamOp::Commit,
            ],
        });
        round_trip(Frame::Ops { ops: vec![] });
        round_trip(Frame::Ack { accepted: 10, rejected: 2, epoch: 3 });
        round_trip(Frame::Error { code: ERR_MALFORMED, message: "bad ops".into() });
        round_trip(Frame::Snapshot {
            epoch: 9,
            num_communities: 3,
            modularity: 0.73,
            membership: vec![0, 1, 2, 1, 0],
        });
        round_trip(Frame::Snapshot {
            epoch: 0,
            num_communities: 0,
            modularity: 0.0,
            membership: vec![],
        });
        round_trip(Frame::Delta {
            epoch: 10,
            base_epoch: 9,
            vertices: 5,
            num_communities: 3,
            modularity: 0.7,
            changes: vec![(0, 2), (4, 1)],
        });
        round_trip(Frame::Bye);
    }

    #[test]
    fn frames_stream_back_to_back() {
        let mut bytes = encode_frame(&Frame::Bye);
        bytes.extend(encode_frame(&Frame::Ack { accepted: 1, rejected: 0, epoch: 0 }));
        let mut r = std::io::Cursor::new(bytes);
        assert_eq!(read_frame(&mut r).unwrap(), Some(Frame::Bye));
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Ack { accepted: 1, .. })));
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn clean_eof_vs_truncation() {
        // Zero bytes: clean boundary.
        assert!(read_frame(&mut std::io::Cursor::new(vec![])).unwrap().is_none());
        // Partial length prefix: abrupt disconnect.
        let err = read_frame(&mut std::io::Cursor::new(vec![3u8, 0])).unwrap_err();
        assert!(matches!(err, FrameError::Io(_)), "{err}");
        // Full prefix, missing body: abrupt disconnect too.
        let mut bytes = encode_frame(&Frame::Ack { accepted: 1, rejected: 0, epoch: 0 });
        bytes.truncate(bytes.len() - 5);
        let err = read_frame(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(err, FrameError::Io(_)), "{err}");
    }

    #[test]
    fn malformed_frames_get_protocol_errors() {
        // Zero-length frame.
        let err = read_frame(&mut std::io::Cursor::new(vec![0u8; 4])).unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_MALFORMED, .. }), "{err}");
        // Oversized length prefix rejected before any body read.
        let huge = (u32::MAX).to_le_bytes().to_vec();
        let err = read_frame(&mut std::io::Cursor::new(huge)).unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_OVERSIZED, .. }), "{err}");
        // Unknown frame type.
        let err = decode_frame(0x7f, &[]).unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_UNEXPECTED_TYPE, .. }), "{err}");
        // Bad hello magic / role.
        let err = decode_frame(T_HELLO, b"NOPE\x00").unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_BAD_HELLO, .. }), "{err}");
        let err = decode_frame(T_HELLO, b"GVL1\x09").unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_BAD_HELLO, .. }), "{err}");
        // Garbage ops payload (unknown tag).
        let mut body = 1u32.to_le_bytes().to_vec();
        body.push(b'x');
        let err = decode_frame(T_OPS, &body).unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_MALFORMED, .. }), "{err}");
        // Trailing bytes after a fixed-layout body.
        let err = decode_frame(T_BYE, &[1, 2]).unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_MALFORMED, .. }), "{err}");
        // Truncated snapshot membership.
        let snap = Frame::Snapshot {
            epoch: 1,
            num_communities: 1,
            modularity: 0.1,
            membership: vec![0, 0, 0],
        };
        let bytes = encode_frame(&snap);
        let err = decode_frame(bytes[4], &bytes[5..bytes.len() - 2]).unwrap_err();
        assert!(matches!(err, FrameError::Protocol { code: ERR_MALFORMED, .. }), "{err}");
    }
}
