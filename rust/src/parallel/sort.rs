//! Parallel **stable** sort-by-key (PR 3 satellite).
//!
//! `Csr::apply_batch` sorts its mirrored directed-op list by
//! `(src, dst)` and depends on stability: repeated insertions of one
//! pair must keep batch order in *both* mirrored groups so the two
//! directions sum their f32 weights bit-identically (see
//! `graph::delta` and its
//! `repeated_inserts_sum_bit_identically_in_both_directions` test).
//! That rules out `sort_unstable` and per-thread bucket tricks; this
//! module provides the classic stable alternative: cut the slice into
//! one contiguous segment per thread, stably sort each segment in
//! parallel, then merge pairs of neighbouring runs (left-before-right
//! on equal keys) over `ceil(log2 T)` parallel rounds, ping-ponging
//! between the data and a reused scratch buffer.
//!
//! A stable sort has exactly one correct output, so the parallel result
//! is bit-identical to `slice::sort_by_key` at any thread count — the
//! serial fallback below is also the test oracle.

use super::pool::{ParallelOpts, RawSend};
use super::schedule::Schedule;
use super::team::Exec;

/// Stably sort `data` by `key` on `exec`, reusing `scratch` as the
/// merge buffer (grown to `data.len()` on first use, kept after).
///
/// Equivalent to `data.sort_by_key(key)` — including tie order — at
/// every thread count; small inputs and `threads == 1` take the serial
/// path directly.
pub fn sort_by_key_stable_parallel<T, K, F>(
    data: &mut Vec<T>,
    scratch: &mut Vec<T>,
    key: F,
    opts: ParallelOpts,
    exec: Exec,
) where
    T: Copy + Send + Sync,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    /// Below this length the spawn/merge bookkeeping costs more than
    /// the sort itself.
    const MIN_PAR: usize = 1 << 13;
    let n = data.len();
    let threads = opts.threads.max(1);
    if threads <= 1 || n < MIN_PAR {
        data.sort_by_key(key);
        return;
    }

    // Segment bounds: `threads` contiguous runs covering 0..n.
    let bounds: Vec<usize> = (0..=threads).map(|i| i * n / threads).collect();
    // One task per worker; chunk 1 + static dealing keeps task i on a
    // distinct thread without any cross-task imbalance mattering (the
    // merge rounds are the balanced part).
    let task_opts = ParallelOpts {
        threads,
        schedule: Schedule::Static,
        chunk: 1,
        record: false,
    };

    // Phase 1: stable per-segment sorts (disjoint subslices).
    {
        let dp = RawSend(data.as_mut_ptr());
        let bounds = &bounds;
        let key = &key;
        exec.run(threads, task_opts, move |r| {
            let dp = dp;
            for seg in r {
                let (lo, hi) = (bounds[seg], bounds[seg + 1]);
                // SAFETY: segments are disjoint and each `seg` index is
                // dealt to exactly one chunk.
                let s = unsafe { std::slice::from_raw_parts_mut(dp.0.add(lo), hi - lo) };
                s.sort_by_key(key);
            }
        });
    }

    // Phase 2: merge neighbouring runs, doubling run width per round.
    // `src` always holds the current runs; each round writes into
    // `dst`, then the roles swap.  Vec swaps move pointers, not
    // elements, so the caller's `data` ends up holding the result.
    scratch.clear();
    scratch.resize(n, data[0]);
    let mut in_data = true; // current runs live in `data`
    let mut width = 1usize;
    while width < threads {
        let (src, dst): (&[T], &mut Vec<T>) =
            if in_data { (&data[..], &mut *scratch) } else { (&scratch[..], &mut *data) };
        let pairs = threads.div_ceil(2 * width);
        let dp = RawSend(dst.as_mut_ptr());
        let bounds = &bounds;
        let key = &key;
        exec.run(pairs, task_opts, move |r| {
            let dp = dp;
            for p in r {
                let i = p * 2 * width;
                let lo = bounds[i];
                let mid = bounds[(i + width).min(threads)];
                let hi = bounds[(i + 2 * width).min(threads)];
                // SAFETY: pair output ranges [lo, hi) are disjoint.
                let out = unsafe { std::slice::from_raw_parts_mut(dp.0.add(lo), hi - lo) };
                merge_stable(&src[lo..mid], &src[mid..hi], out, key);
            }
        });
        in_data = !in_data;
        width *= 2;
    }
    if !in_data {
        std::mem::swap(data, scratch);
    }
}

/// Stable two-run merge: equal keys take the left run first, so runs
/// that were stably sorted stay stably ordered overall.
fn merge_stable<T: Copy, K: Ord>(a: &[T], b: &[T], out: &mut [T], key: &impl Fn(&T) -> K) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j) = (0usize, 0usize);
    for slot in out.iter_mut() {
        let take_a = j >= b.len() || (i < a.len() && key(&a[i]) <= key(&b[j]));
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::prng::Xoshiro256;
    use crate::parallel::team::Team;

    /// Payload with a tie-breaking tag the key ignores: stability means
    /// tags stay in input order within each key group.
    #[derive(Clone, Copy, Debug, PartialEq)]
    struct Item {
        k: u32,
        tag: u32,
    }

    fn random_items(n: usize, key_space: u64, seed: u64) -> Vec<Item> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|i| Item { k: rng.below(key_space) as u32, tag: i as u32 })
            .collect()
    }

    #[test]
    fn matches_serial_stable_sort_across_sizes_and_threads() {
        let team = Team::new(4);
        for n in [0usize, 1, 7, (1 << 13) - 1, 1 << 13, 50_000] {
            // Small key space forces long tie runs — the stability
            // stress case.
            for key_space in [4u64, 1 << 20] {
                let base = random_items(n, key_space, 9 + n as u64);
                let mut want = base.clone();
                want.sort_by_key(|x| x.k);
                for threads in [1usize, 2, 3, 4] {
                    for exec in [Exec::scoped(), Exec::team(&team)] {
                        let mut got = base.clone();
                        let mut scratch = Vec::new();
                        let opts = ParallelOpts { threads, ..Default::default() };
                        sort_by_key_stable_parallel(&mut got, &mut scratch, |x| x.k, opts, exec);
                        assert_eq!(got, want, "n={n} ks={key_space} t={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn scratch_is_reused_across_calls() {
        let team = Team::new(4);
        let opts = ParallelOpts { threads: 4, ..Default::default() };
        let mut scratch = Vec::new();
        let mut a = random_items(40_000, 100, 1);
        sort_by_key_stable_parallel(&mut a, &mut scratch, |x| x.k, opts, Exec::team(&team));
        assert!(scratch.capacity() >= 40_000);
        let cap = scratch.capacity();
        // A second, smaller sort must not regrow the scratch.
        let mut b = random_items(20_000, 100, 2);
        sort_by_key_stable_parallel(&mut b, &mut scratch, |x| x.k, opts, Exec::team(&team));
        assert_eq!(scratch.capacity(), cap);
        let mut want = random_items(20_000, 100, 2);
        want.sort_by_key(|x| x.k);
        assert_eq!(b, want);
    }

    #[test]
    fn already_sorted_and_reversed_inputs() {
        let team = Team::new(3);
        let opts = ParallelOpts { threads: 3, ..Default::default() };
        let n = 20_000;
        let mut asc: Vec<Item> = (0..n).map(|i| Item { k: i as u32, tag: i as u32 }).collect();
        let want = asc.clone();
        let mut scratch = Vec::new();
        sort_by_key_stable_parallel(&mut asc, &mut scratch, |x| x.k, opts, Exec::team(&team));
        assert_eq!(asc, want);
        let mut desc: Vec<Item> =
            (0..n).map(|i| Item { k: (n - i) as u32, tag: i as u32 }).collect();
        sort_by_key_stable_parallel(&mut desc, &mut scratch, |x| x.k, opts, Exec::team(&team));
        assert!(desc.windows(2).all(|w| w[0].k <= w[1].k));
    }

    #[test]
    fn merge_stable_prefers_left_on_ties() {
        let a = [Item { k: 1, tag: 0 }, Item { k: 2, tag: 1 }];
        let b = [Item { k: 1, tag: 2 }, Item { k: 2, tag: 3 }];
        let mut out = [Item { k: 0, tag: 0 }; 4];
        merge_stable(&a, &b, &mut out, &|x: &Item| x.k);
        assert_eq!(out.map(|x| x.tag), [0, 2, 1, 3]);
    }
}
