//! Persistent worker-team runtime: OpenMP-style thread reuse.
//!
//! The scoped [`super::pool`] forks and joins fresh OS threads on
//! *every* loop invocation — one spawn/join barrier per local-moving
//! iteration, per init loop and per aggregation sub-loop, every pass.
//! The paper's 560 M-edges/s headline rests on OpenMP's *persistent*
//! thread team (§4.1.9): workers are spawned once and parked between
//! parallel regions.  [`Team`] reproduces that contract:
//!
//! * `Team::new(T)` spawns `T - 1` OS workers **once**; the caller
//!   participates as tid 0 (like the OpenMP master), so `T == 1` never
//!   spawns at all.
//! * Each job carries a fresh [`ChunkDealer`] over the existing
//!   [`Schedule`](super::schedule::Schedule) kinds, so chunk dealing is
//!   bit-for-bit identical to the scoped path — the Fig 16 scaling
//!   replay keeps consuming the same [`ChunkRecord`] streams.
//! * Per-chunk costs land in **per-worker slots** (cache-line padded,
//!   locked once per job) merged at join, replacing the scoped path's
//!   single contended `Mutex<WorkStats>`.
//! * Between jobs workers sleep on a condvar; dispatch is one mutex
//!   round-trip plus `notify_all`.
//!
//! Soundness: a job is a type-erased borrow of the dispatcher's stack
//! frame.  [`Team::dispatch`] never returns (and never unwinds) until
//! every worker has finished the job, so the borrow outlives every
//! dereference; worker panics are caught and the first payload is
//! re-raised on the caller after the barrier (message preserved, like
//! the scoped path).
//!
//! [`Exec`] is the call-site handle: `Exec::team(&team)` runs loops on
//! the persistent team, `Exec::scoped()` keeps the PR-0 spawn-per-loop
//! reference path alive for tests and verification.

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Weak};
use std::thread::JoinHandle;

/// Lock ignoring poisoning: panics inside job bodies are caught before
/// any team lock is taken, so a poisoned flag never indicates a broken
/// invariant here — and honouring it would kill the team after the
/// first caught panic.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

use super::pool::{
    parallel_for_ctx, parallel_for_ctx_spec, run_chunks_for_tid, ChunkRecord, ParallelOpts, RawSend,
    WorkStats,
};
use super::schedule::DealSpec;
use crate::trace::{self, TraceSink};

/// Total OS threads ever spawned by [`Team`]s in this process (tests
/// assert spawns per `GveLouvain::run` are O(1) in passes/iterations).
static OS_SPAWNS: AtomicUsize = AtomicUsize::new(0);

/// Process-wide [`Team`] worker spawn count so far.
pub fn os_threads_spawned() -> usize {
    OS_SPAWNS.load(Ordering::Relaxed)
}

/// A type-erased parallel job: worker `tid` runs `call(ptr, tid)`.
#[derive(Clone, Copy)]
struct Job {
    ptr: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: `ptr` points at a `Sync` closure on the dispatching thread's
// stack; `Team::dispatch` blocks (even through panics) until every
// worker has finished the job, so workers only dereference it while
// the closure is alive.
unsafe impl Send for Job {}

struct TeamState {
    /// Bumped once per dispatched job; workers run a job exactly once.
    epoch: u64,
    job: Option<Job>,
    /// Participant count of the current job: workers with `tid >= width`
    /// skip it at the protocol level — they re-sleep without touching
    /// the job pointer or `remaining` (ROADMAP "narrow jobs on a wide
    /// team").  The caller is always participant 0.
    width: usize,
    /// Participating workers still running the current job.
    remaining: usize,
    /// First worker panic payload of the current job, re-raised on the
    /// caller (payload preserved for parity with the scoped path).
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct TeamShared {
    state: Mutex<TeamState>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// The dispatcher waits here for `remaining == 0`.
    done_cv: Condvar,
    /// Serializes dispatchers: a `Team` is `Sync`, so two threads could
    /// otherwise publish jobs concurrently and corrupt the
    /// epoch/remaining protocol the job-lifetime safety rests on.
    run_lock: Mutex<()>,
}

thread_local! {
    /// Address of the `TeamShared` whose job this thread is currently
    /// executing (0 = none).  Turns the nested-dispatch deadlock into
    /// an immediate panic naming the contract.
    static ACTIVE_TEAM: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn worker_loop(shared: &TeamShared, tid: usize, sink: Arc<TraceSink>) {
    // Bind this worker's span ring buffer before the first job: every
    // span the worker ever records lands in its own slot-held sink,
    // with no registry lookup on the hot path.
    trace::install_sink(sink);
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            while !st.shutdown && st.epoch == seen {
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            if st.shutdown {
                return;
            }
            seen = st.epoch;
            if tid >= st.width {
                // Not a participant of this job: skip without touching
                // `job` or `remaining`.  (The job may even be torn down
                // already — the dispatcher's barrier only counts
                // participants — so the pointer must not be read here.)
                continue;
            }
            st.job.expect("epoch bumped without a published job")
        };
        // SAFETY: see `Job` — the dispatcher keeps the closure alive
        // until `remaining` hits zero below.
        let prev_team = ACTIVE_TEAM.replace(shared as *const TeamShared as usize);
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.ptr, tid) }));
        ACTIVE_TEAM.set(prev_team);
        let mut st = lock_ignore_poison(&shared.state);
        if let Err(payload) = result {
            st.panic_payload.get_or_insert(payload);
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_one();
        }
    }
}

/// A persistent worker team (workers spawned once, parked between jobs).
pub struct Team {
    shared: Arc<TeamShared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Per-worker trace sinks (index 0 = worker tid 1), held strongly so
    /// a parked worker's recorded spans survive between trace sessions.
    sinks: Vec<Arc<TraceSink>>,
    /// Cumulative per-member busy nanoseconds (index = member tid, the
    /// caller is 0), cache-padded like the result slots.  Fed by two
    /// clock reads per member per job; the adaptive late-pass engine
    /// snapshots this around a pass and feeds the deltas to its width
    /// cost model (PR 10).
    busy_slots: Vec<BusySlot>,
}

impl Team {
    /// Spawn `threads - 1` parked workers (the caller is tid 0).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(TeamShared {
            state: Mutex::new(TeamState {
                epoch: 0,
                job: None,
                width: 0,
                remaining: 0,
                panic_payload: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            run_lock: Mutex::new(()),
        });
        let mut sinks = Vec::with_capacity(threads.saturating_sub(1));
        let workers = (1..threads)
            .map(|tid| {
                let sh = Arc::clone(&shared);
                let sink = trace::register_named(format!("gve-team-{tid}"));
                sinks.push(Arc::clone(&sink));
                OS_SPAWNS.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("gve-team-{tid}"))
                    .spawn(move || worker_loop(&sh, tid, sink))
                    .expect("spawn team worker")
            })
            .collect();
        let busy_slots = (0..threads).map(|_| BusySlot::default()).collect();
        Self { shared, workers, threads, sinks, busy_slots }
    }

    /// This team's per-worker trace sinks (empty when `threads == 1`).
    pub fn trace_sinks(&self) -> &[Arc<TraceSink>] {
        &self.sinks
    }

    /// Team width (including the participating caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// OS workers this team spawned (`threads - 1`; stable for the
    /// team's whole life — the O(1)-spawn guarantee).
    pub fn spawned_workers(&self) -> usize {
        self.workers.len()
    }

    /// Snapshot of the cumulative per-member busy nanoseconds since
    /// team creation (`len() == threads()`; index = member tid).
    /// Monotone per slot — a caller diffs two snapshots to get one
    /// job's or one pass's per-worker busy split.
    pub fn worker_busy_ns(&self) -> Vec<u64> {
        self.busy_slots
            .iter()
            .map(|s| s.0.load(std::sync::atomic::Ordering::Relaxed))
            .collect()
    }

    /// Run `f(tid)` on members `0..participants`; caller participates
    /// as tid 0, workers with `tid >= participants` re-sleep without
    /// touching the job (the condvar still broadcasts — the skip is in
    /// the epoch/remaining protocol, not the wakeup).  Returns only
    /// after all participants finished, re-raising any panic.
    fn dispatch<F: Fn(usize) + Sync>(&self, f: &F, participants: usize) {
        let participants = participants.clamp(1, self.workers.len() + 1);
        if participants == 1 || self.workers.is_empty() {
            f(0);
            return;
        }
        unsafe fn trampoline<F: Fn(usize) + Sync>(p: *const (), tid: usize) {
            (*(p as *const F))(tid);
        }
        let team_id = Arc::as_ptr(&self.shared) as *const TeamShared as usize;
        assert!(
            ACTIVE_TEAM.get() != team_id,
            "nested Team dispatch: a job body launched another multi-threaded \
             loop on the same team (run it single-threaded or on Exec::scoped)"
        );
        let _dispatcher = lock_ignore_poison(&self.shared.run_lock);
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.job = Some(Job { ptr: f as *const F as *const (), call: trampoline::<F> });
            st.epoch += 1;
            st.width = participants;
            st.remaining = participants - 1;
        }
        self.shared.work_cv.notify_all();
        // Save/restore (not reset): clobbering an enclosing team's
        // marker on cross-team nesting would disarm the guard.
        let prev_team = ACTIVE_TEAM.replace(team_id);
        let caller = catch_unwind(AssertUnwindSafe(|| f(0)));
        ACTIVE_TEAM.set(prev_team);
        // The completion barrier must hold even when the caller's share
        // panicked: workers still borrow this stack frame.
        let mut st = lock_ignore_poison(&self.shared.state);
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        let worker_panic = st.panic_payload.take();
        drop(st);
        match (caller, worker_panic) {
            (Err(payload), _) => resume_unwind(payload),
            (Ok(()), Some(payload)) => resume_unwind(payload),
            (Ok(()), None) => {}
        }
    }

    /// Parallel loop over `0..n` with a per-thread context — the
    /// persistent-team equivalent of
    /// [`parallel_for_ctx`](super::pool::parallel_for_ctx), with
    /// identical chunk dealing and [`ChunkRecord`] semantics.
    ///
    /// `opts.threads` is clamped to the team width; members beyond the
    /// effective count are skipped at the dispatch protocol level
    /// (they never run `init` or touch the job).
    ///
    /// Dispatch is serialized and **non-reentrant**: a job body must
    /// not launch another multi-threaded loop on the *same* team (a
    /// runtime guard panics with a clear message instead of
    /// deadlocking on the dispatcher lock).  Run nested loops
    /// single-threaded or on [`Exec::scoped`] instead — the Louvain
    /// kernels only ever issue loops sequentially from the pass loop.
    pub fn run_ctx<C, I, F>(&self, n: usize, opts: ParallelOpts, init: I, body: F) -> WorkStats
    where
        C: Send,
        I: Fn(usize) -> C + Sync,
        F: Fn(&mut C, Range<usize>) + Sync,
    {
        self.run_ctx_spec(n, opts, DealSpec::Flat, init, body)
    }

    /// [`Team::run_ctx`] with an explicit [`DealSpec`]: the degree-aware
    /// scan loops pass `ScanOrder::spec()` to get the three-legged
    /// bucketed dealer; everything else uses [`DealSpec::Flat`].
    pub fn run_ctx_spec<C, I, F>(
        &self,
        n: usize,
        opts: ParallelOpts,
        spec: DealSpec,
        init: I,
        body: F,
    ) -> WorkStats
    where
        C: Send,
        I: Fn(usize) -> C + Sync,
        F: Fn(&mut C, Range<usize>) + Sync,
    {
        let effective = opts.threads.max(1).min(self.threads);
        let dealer = spec.build(n, effective, opts.schedule, opts.chunk);
        // Result slots exist only on the instrumentation path: without
        // `record`, stats are all zeros in both runtimes, so the common
        // case allocates nothing per loop.
        let slots: Vec<Slot> =
            if opts.record { (0..effective).map(|_| Slot::default()).collect() } else { Vec::new() };
        // One relaxed load per job when tracing is off; when on, the job
        // gets an id correlating the dispatcher's `team.job` span with
        // each member's `worker.busy` slice (barrier wait = job end −
        // that worker's busy end, derivable in Perfetto or report.rs).
        let traced = trace::enabled();
        let job_id = if traced { trace::next_job_id() } else { 0 };
        // Live-registry dispatch accounting (PR 8): the gate is one
        // relaxed load per *job*.  Each member pays two clock reads per
        // job (not per chunk) feeding the team's cumulative busy slots
        // (the adaptive width model's input, PR 10) and, when the
        // registry is on, the busy-ns counter.
        let metered = crate::obs::enabled();
        if metered {
            crate::obs::sites::team_jobs_dispatched().inc();
        }
        let busy_slots = &self.busy_slots;
        let job = |tid: usize| {
            let _busy = if traced {
                trace::span(
                    "worker.busy",
                    trace::Category::Worker,
                    [job_id, tid as u64, 0, 0],
                )
            } else {
                None
            };
            let t_member = std::time::Instant::now();
            let mut ctx = init(tid);
            let (busy, local) = run_chunks_for_tid(&dealer, tid, opts.record, &mut ctx, &body);
            let elapsed = t_member.elapsed().as_nanos() as u64;
            busy_slots[tid].0.fetch_add(elapsed, std::sync::atomic::Ordering::Relaxed);
            if metered {
                crate::obs::sites::team_worker_busy_ns().add(elapsed);
            }
            if opts.record {
                // One uncontended lock per member per job (vs the
                // scoped path's shared Mutex<WorkStats>).
                let mut s = lock_ignore_poison(&slots[tid].0);
                s.busy = busy;
                s.chunks = local;
            }
        };
        {
            let _job_span = if traced {
                trace::span(
                    "team.job",
                    trace::Category::Dispatch,
                    [job_id, effective as u64, n as u64, 0],
                )
            } else {
                None
            };
            if effective == 1 {
                job(0); // inline: no wakeup, no barrier — still traced
            } else {
                self.dispatch(&job, effective);
            }
        }
        let mut out = WorkStats { chunks: Vec::new(), busy_ns: vec![0; effective] };
        for (tid, slot) in slots.iter().enumerate() {
            let mut s = lock_ignore_poison(&slot.0);
            out.busy_ns[tid] = s.busy;
            out.chunks.append(&mut s.chunks);
        }
        out
    }

    /// Context-free loop on the team.
    pub fn run<F>(&self, n: usize, opts: ParallelOpts, body: F) -> WorkStats
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.run_ctx(n, opts, |_| (), |_, r| body(r))
    }

    /// Disjoint-chunk mutation on the team — see
    /// [`parallel_for_disjoint_mut`](super::pool::parallel_for_disjoint_mut).
    pub fn run_disjoint_mut<T, F>(&self, data: &mut [T], opts: ParallelOpts, body: F) -> WorkStats
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        Exec::team(self).run_disjoint_mut(data, opts, body)
    }
}

/// Process-wide team registry for [`shared_team`]: one live [`Team`]
/// per width, held weakly so an unused team still shuts its workers
/// down when the last owner drops it.
static SHARED_TEAMS: Mutex<Vec<(usize, Weak<Team>)>> = Mutex::new(Vec::new());

/// A process-wide shared [`Team`] of the given width.
///
/// Every caller asking for the same `threads` gets the *same* team
/// (ROADMAP "process-wide team sharing"): a service handling many
/// graphs, or benches building one `GveLouvain` per measurement, stop
/// paying `threads - 1` OS spawns per object.  Concurrent dispatchers
/// are safe — [`Team::dispatch`] serializes them — they just share the
/// workers.  The registry holds [`Weak`] references, so a width's team
/// is torn down (workers joined) when its last `Arc` drops and respawned
/// on the next request.
pub fn shared_team(threads: usize) -> Arc<Team> {
    let threads = threads.max(1);
    let mut reg = lock_ignore_poison(&SHARED_TEAMS);
    if let Some(t) = reg
        .iter()
        .find(|(w, _)| *w == threads)
        .and_then(|(_, t)| t.upgrade())
    {
        return t;
    }
    let team = Arc::new(Team::new(threads));
    reg.retain(|(_, t)| t.strong_count() > 0);
    reg.push((threads, Arc::downgrade(&team)));
    team
}

impl Drop for Team {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-worker result slot; the alignment keeps neighbouring slots off
/// each other's cache lines (the Far-KV lesson applied to stats).
#[repr(align(64))]
#[derive(Default)]
struct Slot(Mutex<SlotData>);

/// Per-member cumulative busy-ns slot (PR 10), padded like [`Slot`].
#[repr(align(64))]
#[derive(Default)]
struct BusySlot(std::sync::atomic::AtomicU64);

#[derive(Default)]
struct SlotData {
    busy: u64,
    chunks: Vec<ChunkRecord>,
}

/// Executor handle threaded through the Louvain kernels: either a
/// persistent [`Team`] (the fast path) or the scoped spawn-per-loop
/// reference path in [`super::pool`], kept for verification.
#[derive(Clone, Copy, Default)]
pub struct Exec<'t> {
    team: Option<&'t Team>,
}

impl<'t> Exec<'t> {
    /// Spawn-per-loop reference path (PR-0 semantics).
    pub fn scoped() -> Self {
        Self { team: None }
    }

    /// Run loops on a persistent team.
    pub fn team(team: &'t Team) -> Self {
        Self { team: Some(team) }
    }

    /// True when backed by a persistent team.
    pub fn is_team(self) -> bool {
        self.team.is_some()
    }

    /// [`parallel_for_ctx`]-compatible loop on this executor.
    pub fn run_ctx<C, I, F>(self, n: usize, opts: ParallelOpts, init: I, body: F) -> WorkStats
    where
        C: Send,
        I: Fn(usize) -> C + Sync,
        F: Fn(&mut C, Range<usize>) + Sync,
    {
        match self.team {
            Some(t) => t.run_ctx(n, opts, init, body),
            None => parallel_for_ctx(n, opts, init, body),
        }
    }

    /// [`Exec::run_ctx`] with an explicit [`DealSpec`] (degree-bucketed
    /// dealing for the Louvain scan loops).
    pub fn run_ctx_spec<C, I, F>(
        self,
        n: usize,
        opts: ParallelOpts,
        spec: DealSpec,
        init: I,
        body: F,
    ) -> WorkStats
    where
        C: Send,
        I: Fn(usize) -> C + Sync,
        F: Fn(&mut C, Range<usize>) + Sync,
    {
        match self.team {
            Some(t) => t.run_ctx_spec(n, opts, spec, init, body),
            None => parallel_for_ctx_spec(n, opts, spec, init, body),
        }
    }

    /// Context-free loop on this executor.
    pub fn run<F>(self, n: usize, opts: ParallelOpts, body: F) -> WorkStats
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.run_ctx(n, opts, |_| (), |_, r| body(r))
    }

    /// Disjoint-chunk mutation on this executor: `body(range, chunk)`
    /// receives `data[range]` exclusively.  This is the one place that
    /// turns the dealer's disjoint-cover contract into `&mut` slices;
    /// [`Team::run_disjoint_mut`] and
    /// [`parallel_for_disjoint_mut`](super::pool::parallel_for_disjoint_mut)
    /// are thin wrappers over it.
    pub fn run_disjoint_mut<T, F>(self, data: &mut [T], opts: ParallelOpts, body: F) -> WorkStats
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        let n = data.len();
        let ptr = RawSend(data.as_mut_ptr());
        self.run(n, opts, move |r| {
            let p = ptr;
            // SAFETY: the dealer hands each index of 0..n to exactly one
            // chunk (asserted by the schedule tests), so these slices
            // never alias.
            let chunk = unsafe { std::slice::from_raw_parts_mut(p.0.add(r.start), r.len()) };
            body(r, chunk);
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::schedule::Schedule;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn opts(threads: usize, schedule: Schedule, chunk: usize, record: bool) -> ParallelOpts {
        ParallelOpts { threads, schedule, chunk, record }
    }

    #[test]
    fn covers_all_indices_every_schedule_under_reuse() {
        // ONE team reused across every schedule kind and width — the
        // persistent-runtime contract the Louvain pass loop relies on.
        let team = Team::new(4);
        for round in 0..3 {
            for s in Schedule::ALL {
                for t in [1, 2, 4] {
                    let n = 10_001;
                    let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                    team.run(n, opts(t, s, 64, false), |r| {
                        for i in r {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    assert!(
                        hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                        "{s:?} t={t} round={round}"
                    );
                }
            }
        }
        assert_eq!(team.spawned_workers(), 3);
    }

    #[test]
    fn chunk_records_match_scoped_path() {
        // Chunk (start, len) sequences are schedule-deterministic, so
        // team and scoped runs must produce the same chunk multiset —
        // the Fig 16 replay depends on this.
        let team = Team::new(3);
        for s in Schedule::ALL {
            let o = opts(3, s, 128, true);
            let body = |r: Range<usize>| {
                std::hint::black_box(r.sum::<usize>());
            };
            let a = team.run(5000, o, body);
            let b = parallel_for_ctx(5000, o, |_| (), |_, r| body(r));
            let key = |st: &WorkStats| {
                let mut v: Vec<(usize, usize)> =
                    st.chunks.iter().map(|c| (c.start, c.len)).collect();
                v.sort_unstable();
                v
            };
            assert_eq!(key(&a), key(&b), "{s:?}");
            assert_eq!(a.busy_ns.len(), b.busy_ns.len(), "{s:?}");
        }
    }

    #[test]
    fn per_thread_contexts_are_isolated() {
        let team = Team::new(4);
        let n = 5000;
        let collected = Mutex::new(Vec::<usize>::new());
        team.run_ctx(
            n,
            opts(4, Schedule::Dynamic, 17, false),
            |_tid| Vec::<usize>::new(),
            |ctx, r| {
                ctx.extend(r.clone());
                collected.lock().unwrap().extend(r);
            },
        );
        let mut v = collected.into_inner().unwrap();
        v.sort_unstable();
        assert_eq!(v, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn spawns_once_and_never_again() {
        let before = os_threads_spawned();
        let team = Team::new(4);
        // Other tests may spawn their own teams concurrently, so the
        // global counter only admits a lower bound.
        assert!(os_threads_spawned() - before >= 3);
        for _ in 0..50 {
            team.run(1000, opts(4, Schedule::Dynamic, 64, false), |r| {
                std::hint::black_box(r.len());
            });
        }
        // 50 loops, zero additional OS threads (other tests may spawn
        // their own teams concurrently, so only assert on this team).
        assert_eq!(team.spawned_workers(), 3);
    }

    #[test]
    fn single_thread_team_never_spawns() {
        let team = Team::new(1);
        team.run(100, ParallelOpts::default(), |r| {
            std::hint::black_box(r.len());
        });
        assert_eq!(team.spawned_workers(), 0);
    }

    #[test]
    fn opts_threads_clamped_to_team_width() {
        let team = Team::new(2);
        let n = 4097;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let stats = team.run(n, opts(8, Schedule::Static, 64, true), |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(stats.busy_ns.len(), 2);
    }

    #[test]
    fn narrow_jobs_skip_non_participants() {
        // A 2-thread job on a 6-wide team must only ever run init/body
        // on tids 0 and 1 — the other four workers are skipped at the
        // dispatch protocol level (ROADMAP item).
        let team = Team::new(6);
        for _ in 0..20 {
            let inits = AtomicUsize::new(0);
            let max_tid = AtomicUsize::new(0);
            let n = 4001;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            team.run_ctx(
                n,
                opts(2, Schedule::Dynamic, 64, false),
                |tid| {
                    inits.fetch_add(1, Ordering::Relaxed);
                    max_tid.fetch_max(tid, Ordering::Relaxed);
                },
                |_, r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            assert_eq!(inits.load(Ordering::Relaxed), 2);
            assert!(max_tid.load(Ordering::Relaxed) < 2);
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
        // Full-width jobs still engage everyone afterwards.
        let inits = AtomicUsize::new(0);
        team.run_ctx(
            6, // one Static chunk per tid with chunk=1
            opts(6, Schedule::Static, 1, false),
            |_tid| inits.fetch_add(1, Ordering::Relaxed),
            |_, _r| {},
        );
        assert_eq!(inits.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn run_ctx_spec_bucketed_covers_on_team() {
        let team = Team::new(4);
        for t in [1, 4] {
            let n = 6007;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            team.run_ctx_spec(
                n,
                opts(t, Schedule::DegreeBucketed, 128, false),
                DealSpec::Bucketed { lo_end: 4000, mid_end: 5500 },
                |_tid| (),
                |_, r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "t={t}");
        }
    }

    #[test]
    fn zero_length_loop_is_noop() {
        let team = Team::new(2);
        let stats = team.run(0, opts(2, Schedule::Dynamic, 64, false), |_r| {
            panic!("must not run")
        });
        assert_eq!(stats.total_ns(), 0);
    }

    #[test]
    fn record_collects_chunk_costs() {
        let team = Team::new(2);
        let stats = team.run(1000, opts(2, Schedule::Dynamic, 100, true), |r| {
            std::hint::black_box(r.sum::<usize>());
        });
        let total: usize = stats.chunks.iter().map(|c| c.len).sum();
        assert_eq!(total, 1000);
        assert_eq!(stats.busy_ns.len(), 2);
        assert!(stats.critical_ns() <= stats.total_ns());
    }

    #[test]
    fn team_survives_worker_panic() {
        let team = Team::new(2);
        let hit = catch_unwind(AssertUnwindSafe(|| {
            team.run(100, opts(2, Schedule::Static, 1, false), |r| {
                if r.start == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(hit.is_err());
        // The team is still usable after the panic round-trip.
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        team.run(n, opts(2, Schedule::Dynamic, 64, false), |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_same_team_dispatch_panics_not_deadlocks() {
        let team = Team::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            team.run(10, opts(2, Schedule::Static, 1, false), |_r| {
                // Illegal: a multi-threaded loop on the same team from
                // inside a job body.
                team.run(10, opts(2, Schedule::Static, 1, false), |_r2| {});
            });
        }));
        assert!(result.is_err(), "nested dispatch must panic, not hang");
        // The team survives and still works.
        let n = 100;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        team.run(n, opts(2, Schedule::Dynamic, 8, false), |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn shared_team_is_one_team_per_width() {
        let a = shared_team(3);
        let b = shared_team(3);
        assert!(Arc::ptr_eq(&a, &b), "same width must share one team");
        assert_eq!(a.spawned_workers(), 2);
        let c = shared_team(2);
        assert!(!Arc::ptr_eq(&a, &c), "different widths are different teams");
        // Both usable, including concurrently from two dispatcher threads.
        std::thread::scope(|s| {
            for t in [&a, &c] {
                s.spawn(move || {
                    let n = 4001;
                    let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                    t.run(n, opts(t.threads(), Schedule::Dynamic, 64, false), |r| {
                        for i in r {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
                });
            }
        });
        // Dropping every strong ref tears the width down; the next
        // request respawns a fresh team.
        let a_ptr = Arc::as_ptr(&a);
        drop((a, b));
        let d = shared_team(3);
        assert_eq!(d.spawned_workers(), 2);
        let _ = a_ptr; // may or may not be reused by the allocator
    }

    #[test]
    fn busy_slots_accumulate_for_participants_only() {
        let team = Team::new(4);
        assert_eq!(team.worker_busy_ns().len(), 4);
        let before = team.worker_busy_ns();
        for _ in 0..50 {
            team.run(100_000, opts(2, Schedule::Static, 4096, false), |r| {
                let mut acc = 0u64;
                for i in r {
                    acc = acc.wrapping_add(std::hint::black_box(i as u64));
                }
                std::hint::black_box(acc);
            });
        }
        let after = team.worker_busy_ns();
        // The caller (tid 0) did real work across 50 jobs; slots are
        // monotone; non-participants (tid >= width 2) never ran.
        assert!(after[0] > before[0], "caller slot must advance");
        assert!(after.iter().zip(&before).all(|(a, b)| a >= b));
        assert_eq!(after[2], before[2]);
        assert_eq!(after[3], before[3]);
    }

    #[test]
    fn disjoint_mut_writes_every_slot_once() {
        let team = Team::new(4);
        let mut data = vec![0u64; 9001];
        team.run_disjoint_mut(&mut data, opts(4, Schedule::Guided, 32, false), |r, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x += (r.start + k) as u64 + 1;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64 + 1, "slot {i}");
        }
    }

    #[test]
    fn exec_dispatches_both_paths_identically() {
        let team = Team::new(3);
        for exec in [Exec::scoped(), Exec::team(&team)] {
            let n = 3000;
            let mut out = vec![0u32; n];
            exec.run_disjoint_mut(&mut out, opts(3, Schedule::Dynamic, 128, false), |r, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (r.start + k) as u32 * 2;
                }
            });
            assert!(out.iter().enumerate().all(|(i, &x)| x == i as u32 * 2));
        }
        assert!(Exec::team(&team).is_team());
        assert!(!Exec::scoped().is_team());
    }
}
