//! Replay scheduler: model `T`-core execution from measured chunk costs.
//!
//! The paper's strong-scaling study (Fig 16: 10.4× at 32 threads,
//! ≈1.6× per thread doubling, NUMA/hyper-threading penalty at 64)
//! requires a multicore box; this testbed has **one** physical core, so
//! wall-clock multi-thread timings only measure contention.  Instead we
//! measure per-chunk work once (single-threaded, `ParallelOpts::record`)
//! and *replay* the chunks through the same schedule semantics onto `T`
//! modeled cores (greedy list scheduling), then add the measured serial
//! sections (Amdahl) and a per-loop fork-join overhead.
//!
//! This reproduces exactly the effects the paper discusses: dynamic
//! scheduling absorbing degree skew, the serial fraction capping
//! speedup, and a configurable NUMA/SMT penalty beyond the physical
//! core count (DESIGN.md §2 documents the substitution).

use super::pool::ChunkRecord;
use super::schedule::Schedule;
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Machine model for the replay.
#[derive(Clone, Copy, Debug)]
pub struct MachineModel {
    /// Physical cores before SMT/NUMA effects kick in.
    pub physical_cores: usize,
    /// Multiplicative efficiency of threads beyond `physical_cores`
    /// (paper: 64 threads on 32 cores gives 11.4× vs 10.4× at 32).
    pub smt_efficiency: f64,
    /// Fork-join overhead per parallel loop per thread (ns).
    pub fork_join_ns: u64,
    /// Memory-bandwidth saturation: fraction of chunk cost that is
    /// memory-bound and does not scale past `bw_saturation_threads`.
    pub mem_bound_fraction: f64,
    pub bw_saturation_threads: usize,
}

impl Default for MachineModel {
    /// Dual Xeon Gold 6226R-like model (paper §5.1.1): 32 physical
    /// cores, DRAM saturating around 16 threads for the memory-bound
    /// share of Louvain's irregular access stream.
    fn default() -> Self {
        Self {
            physical_cores: 32,
            smt_efficiency: 0.55,
            fork_join_ns: 1_500,
            mem_bound_fraction: 0.55,
            bw_saturation_threads: 16,
        }
    }
}

/// Outcome of replaying one parallel loop on `t` modeled cores.
#[derive(Clone, Copy, Debug)]
pub struct ReplayOutcome {
    /// Modeled span of the loop (ns).
    pub span_ns: u64,
    /// Total work replayed (ns).
    pub work_ns: u64,
}

/// Replay recorded chunks onto `t` cores under `schedule` semantics.
///
/// `Dynamic`/`Guided` use greedy list scheduling (earliest-free core
/// takes the next chunk — the steady-state behaviour of a shared
/// counter).  `Static` assigns chunk *i* to core `i % t`; `Auto` splits
/// the chunk list into `t` contiguous runs.
pub fn replay_loop(chunks: &[ChunkRecord], t: usize, schedule: Schedule, model: &MachineModel) -> ReplayOutcome {
    let t = t.max(1);
    let work_ns: u64 = chunks.iter().map(|c| c.ns).sum();
    let span_sched = match schedule {
        Schedule::Dynamic | Schedule::Guided => {
            // Earliest-free-core greedy assignment in recorded order.
            let mut heap: BinaryHeap<Reverse<u64>> = (0..t).map(|_| Reverse(0u64)).collect();
            for c in chunks {
                let Reverse(free) = heap.pop().unwrap();
                heap.push(Reverse(free + c.ns));
            }
            heap.into_iter().map(|Reverse(x)| x).max().unwrap_or(0)
        }
        Schedule::Static => {
            let mut busy = vec![0u64; t];
            for (i, c) in chunks.iter().enumerate() {
                busy[i % t] += c.ns;
            }
            busy.into_iter().max().unwrap_or(0)
        }
        Schedule::Auto => {
            let per = chunks.len().div_ceil(t);
            let mut max = 0u64;
            for block in chunks.chunks(per.max(1)) {
                let s: u64 = block.iter().map(|c| c.ns).sum();
                max = max.max(s);
            }
            max
        }
    };
    // Bandwidth floor: the memory-bound share of the total work cannot
    // complete faster than `bw_saturation_threads` cores' worth of
    // traffic, no matter how many threads run.
    let mem_floor = (work_ns as f64 * model.mem_bound_fraction
        / model.bw_saturation_threads as f64) as u64;
    let span = apply_smt(span_sched, t, model).max(mem_floor);
    ReplayOutcome { span_ns: span + model.fork_join_ns * (t as u64).min(8), work_ns }
}

/// SMT/NUMA derating past the physical core count.
fn apply_smt(span: u64, t: usize, model: &MachineModel) -> u64 {
    if t <= model.physical_cores {
        return span;
    }
    // Threads beyond physical cores contribute at `smt_efficiency`:
    // recompute the span as if capacity were cores + eff*(t-cores).
    let capacity = model.physical_cores as f64 + model.smt_efficiency * (t - model.physical_cores) as f64;
    (span as f64 * t as f64 / capacity) as u64
}

/// Modeled total runtime for a full algorithm run at `t` threads:
/// replayed parallel loops + measured serial time.
pub fn modeled_runtime_ns(
    loops: &[(Schedule, Vec<ChunkRecord>)],
    serial_ns: u64,
    t: usize,
    model: &MachineModel,
) -> u64 {
    let par: u64 = loops.iter().map(|(s, c)| replay_loop(c, t, *s, model).span_ns).sum();
    par + serial_ns
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(costs: &[u64]) -> Vec<ChunkRecord> {
        costs
            .iter()
            .enumerate()
            .map(|(i, &ns)| ChunkRecord { thread: 0, start: i * 10, len: 10, ns })
            .collect()
    }

    fn flat_model() -> MachineModel {
        MachineModel {
            physical_cores: 1024,
            smt_efficiency: 1.0,
            fork_join_ns: 0,
            mem_bound_fraction: 0.0,
            bw_saturation_threads: 1024,
        }
    }

    #[test]
    fn one_core_replay_is_total_work() {
        let chunks = mk(&[5, 10, 15]);
        let out = replay_loop(&chunks, 1, Schedule::Dynamic, &flat_model());
        assert_eq!(out.span_ns, 30);
        assert_eq!(out.work_ns, 30);
    }

    #[test]
    fn dynamic_balances_skew_better_than_static() {
        // One huge chunk + many small: dynamic puts smalls elsewhere.
        let mut costs = vec![1000u64];
        costs.extend(std::iter::repeat(10).take(100));
        let chunks = mk(&costs);
        let m = flat_model();
        let dyn_span = replay_loop(&chunks, 4, Schedule::Dynamic, &m).span_ns;
        let auto_span = replay_loop(&chunks, 4, Schedule::Auto, &m).span_ns;
        assert!(dyn_span <= auto_span, "dynamic {dyn_span} vs auto {auto_span}");
        assert_eq!(dyn_span, 1000); // the big chunk dominates, rest overlaps
    }

    #[test]
    fn static_round_robin_span() {
        let chunks = mk(&[10, 10, 10, 10]);
        let span = replay_loop(&chunks, 2, Schedule::Static, &flat_model()).span_ns;
        assert_eq!(span, 20);
    }

    #[test]
    fn speedup_monotone_until_cores() {
        let chunks = mk(&vec![50u64; 256]);
        let m = flat_model();
        let mut prev = u64::MAX;
        for t in [1, 2, 4, 8, 16] {
            let s = replay_loop(&chunks, t, Schedule::Dynamic, &m).span_ns;
            assert!(s <= prev, "span grew at t={t}");
            prev = s;
        }
    }

    #[test]
    fn smt_derates_past_physical_cores() {
        let chunks = mk(&vec![50u64; 512]);
        let m = MachineModel { physical_cores: 4, smt_efficiency: 0.5, fork_join_ns: 0, mem_bound_fraction: 0.0, bw_saturation_threads: 1024 };
        let at4 = replay_loop(&chunks, 4, Schedule::Dynamic, &m).span_ns;
        let at8 = replay_loop(&chunks, 8, Schedule::Dynamic, &m).span_ns;
        // 8 threads on 4 cores w/ 0.5 SMT: capacity 6 => better than 4 but
        // not 2x.
        assert!(at8 < at4);
        assert!((at8 as f64) > at4 as f64 / 2.0);
    }

    #[test]
    fn amdahl_serial_floor() {
        let chunks = mk(&vec![10u64; 100]);
        let loops = vec![(Schedule::Dynamic, chunks)];
        let m = flat_model();
        let t1 = modeled_runtime_ns(&loops, 500, 1, &m);
        let t64 = modeled_runtime_ns(&loops, 500, 64, &m);
        assert!(t64 >= 500); // serial floor
        assert!(t1 > t64);
        let speedup = t1 as f64 / t64 as f64;
        assert!(speedup < 3.0, "serial fraction must cap speedup, got {speedup}");
    }
}
