//! OpenMP-like parallel substrate.
//!
//! The paper parallelizes with OpenMP and *ablates the loop schedule*
//! (§4.1.1: static / dynamic / guided / auto, chunk 2048).  The offline
//! registry has no rayon, so this module provides the substrate from
//! scratch: a persistent worker [`team`] (spawn-once, park between
//! loops — the hot path), a scoped fork-join [`pool`] kept as the
//! reference path, chunk [`schedule`]s matching OpenMP semantics (plus
//! the degree-bucketed dealer for the Louvain scan loops), a cfg-gated
//! software [`prefetch`] hint for the membership gather, a
//! parallel prefix [`scan`], parallel [`scatter`] accumulators
//! (warm-start Σ' init and batch-delta counting), a parallel *stable*
//! [`sort`] (the batch-delta op sort), CAS-loop [`atomics`]
//! for `f64`, deterministic [`prng`]s, and a [`replay`] model that
//! list-schedules measured chunk costs onto `T` modeled cores for the
//! strong-scaling study (this testbed exposes a single core; see
//! DESIGN.md §2).

pub mod atomics;
pub mod pool;
pub mod prefetch;
pub mod prng;
pub mod replay;
pub mod scan;
pub mod scatter;
pub mod schedule;
pub mod sort;
pub mod team;

pub use pool::{
    parallel_for, parallel_for_ctx, parallel_for_ctx_spec, parallel_for_disjoint_mut, ParallelOpts,
    WorkStats,
};
pub use schedule::{DealSpec, ScanOrder, Schedule};
pub use team::{shared_team, Exec, Team};
