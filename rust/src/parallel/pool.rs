//! Scoped fork-join `parallel_for` with OpenMP-style schedules.
//!
//! This is the **reference path**: each invocation forks `threads`
//! workers over `0..n`, deals chunks per the chosen [`Schedule`], and
//! joins.  The Louvain hot loops run on the persistent
//! [`Team`](super::team::Team) runtime instead (same dealing, no
//! per-loop spawns); this module stays as the spawn-per-loop oracle the
//! team is tested against, and for one-shot callers.  Workers own a per-thread context
//! (GVE-Louvain hangs its per-thread hashtable there) created by an
//! `init` closure — the Far-KV vs Close-KV distinction (§4.1.9) lives in
//! *how* those contexts are allocated, not here.
//!
//! When [`ParallelOpts::record`] is set, per-chunk costs and per-thread
//! busy times are collected into [`WorkStats`]; the [`super::replay`]
//! model replays those chunk costs onto `T` modeled cores for the
//! strong-scaling study (Fig 16) since this testbed has one physical
//! core.

use std::sync::Mutex;
use std::time::Instant;

use super::schedule::{DealCursor, DealSpec, Dealer, Schedule, DEFAULT_CHUNK};

/// Options for a parallel loop.
#[derive(Clone, Copy, Debug)]
pub struct ParallelOpts {
    pub threads: usize,
    pub schedule: Schedule,
    pub chunk: usize,
    /// Record per-chunk costs (adds two `Instant::now()` per chunk).
    pub record: bool,
}

impl Default for ParallelOpts {
    fn default() -> Self {
        Self { threads: 1, schedule: Schedule::Dynamic, chunk: DEFAULT_CHUNK, record: false }
    }
}

impl ParallelOpts {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads, ..Self::default() }
    }
}

/// One executed chunk: `[start, start+len)` ran on `thread` for `ns`.
#[derive(Clone, Copy, Debug)]
pub struct ChunkRecord {
    pub thread: usize,
    pub start: usize,
    pub len: usize,
    pub ns: u64,
}

/// Work accounting for one parallel loop.
#[derive(Clone, Debug, Default)]
pub struct WorkStats {
    pub chunks: Vec<ChunkRecord>,
    /// Busy nanoseconds per thread.
    pub busy_ns: Vec<u64>,
}

impl WorkStats {
    /// Total busy time across threads (the "work" W).
    pub fn total_ns(&self) -> u64 {
        self.busy_ns.iter().sum()
    }

    /// Max per-thread busy time (the "span" of this loop under the
    /// schedule that produced it).
    pub fn critical_ns(&self) -> u64 {
        self.busy_ns.iter().copied().max().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &WorkStats) {
        self.chunks.extend_from_slice(&other.chunks);
        if self.busy_ns.len() < other.busy_ns.len() {
            self.busy_ns.resize(other.busy_ns.len(), 0);
        }
        for (a, b) in self.busy_ns.iter_mut().zip(&other.busy_ns) {
            *a += b;
        }
    }
}

/// Drain `dealer`'s chunks for worker `tid` through `body`, timing each
/// chunk when `record` is set.  Returns `(busy_ns, chunk_records)`
/// (both zero/empty otherwise).
///
/// This is the one per-worker inner loop shared by the scoped pool
/// (both the single-thread fast path and the spawned workers) and the
/// persistent [`Team`](super::team::Team): team/scoped replay parity is
/// structural, not test-enforced.
pub(crate) fn run_chunks_for_tid<C, F>(
    dealer: &Dealer,
    tid: usize,
    record: bool,
    ctx: &mut C,
    body: &F,
) -> (u64, Vec<ChunkRecord>)
where
    F: Fn(&mut C, std::ops::Range<usize>) + Sync,
{
    let mut cursor = DealCursor::default();
    let mut busy = 0u64;
    let mut local: Vec<ChunkRecord> = Vec::new();
    while let Some(r) = dealer.next_chunk(tid, &mut cursor) {
        if record {
            let t0 = Instant::now();
            let (start, len) = (r.start, r.len());
            body(ctx, r);
            let ns = t0.elapsed().as_nanos() as u64;
            busy += ns;
            local.push(ChunkRecord { thread: tid, start, len, ns });
        } else {
            body(ctx, r);
        }
    }
    (busy, local)
}

/// Parallel loop over `0..n` with a per-thread context.
///
/// `init(tid)` builds each worker's context before it takes chunks;
/// `body(ctx, range)` processes one chunk.  Returns [`WorkStats`]
/// (empty unless `opts.record`).
pub fn parallel_for_ctx<C, I, F>(n: usize, opts: ParallelOpts, init: I, body: F) -> WorkStats
where
    C: Send,
    I: Fn(usize) -> C + Sync,
    F: Fn(&mut C, std::ops::Range<usize>) + Sync,
{
    parallel_for_ctx_spec(n, opts, DealSpec::Flat, init, body)
}

/// [`parallel_for_ctx`] with an explicit [`DealSpec`] — the degree-aware
/// scan loops pass `ScanOrder::spec()` so chunks come from the
/// three-legged [`BucketDealer`](super::schedule::BucketDealer) instead
/// of a flat dealer.
pub fn parallel_for_ctx_spec<C, I, F>(
    n: usize,
    opts: ParallelOpts,
    spec: DealSpec,
    init: I,
    body: F,
) -> WorkStats
where
    C: Send,
    I: Fn(usize) -> C + Sync,
    F: Fn(&mut C, std::ops::Range<usize>) + Sync,
{
    let threads = opts.threads.max(1);
    let dealer = spec.build(n, threads, opts.schedule, opts.chunk);

    if threads == 1 {
        // Fast path: no spawn, same dealing order.
        let mut ctx = init(0);
        let (busy, chunks) = run_chunks_for_tid(&dealer, 0, opts.record, &mut ctx, &body);
        return WorkStats { chunks, busy_ns: vec![busy] };
    }

    let stats = Mutex::new(WorkStats { chunks: Vec::new(), busy_ns: vec![0; threads] });
    std::thread::scope(|scope| {
        for tid in 0..threads {
            let dealer = &dealer;
            let stats = &stats;
            let init = &init;
            let body = &body;
            scope.spawn(move || {
                let mut ctx = init(tid);
                let (busy, local) = run_chunks_for_tid(dealer, tid, opts.record, &mut ctx, &body);
                let mut s = stats.lock().unwrap();
                s.busy_ns[tid] = busy;
                s.chunks.extend_from_slice(&local);
            });
        }
    });
    stats.into_inner().unwrap()
}

/// Context-free convenience wrapper.
pub fn parallel_for<F>(n: usize, opts: ParallelOpts, body: F) -> WorkStats
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    parallel_for_ctx(n, opts, |_| (), |_, r| body(r))
}

/// Raw-pointer wrapper for disjoint-chunk parallel loops.
///
/// The one place (instead of per-call-site `SendPtr` blocks) carrying
/// the safety contract: the [`ChunkDealer`] hands each index of `0..n`
/// to exactly one chunk (asserted by the schedule tests), so writes
/// through this pointer at chunk-local indices never alias.
#[derive(Clone, Copy)]
pub(crate) struct RawSend<T>(pub *mut T);
unsafe impl<T: Send> Send for RawSend<T> {}
unsafe impl<T: Send> Sync for RawSend<T> {}

/// Parallel loop that hands each chunk a `&mut` sub-slice of `data`.
///
/// The safe replacement for the ad-hoc `SendPtr` blocks that used to
/// live at call sites: `body(range, chunk)` receives `data[range]`
/// exclusively (ranges are disjoint by the dealer contract), so callers
/// write plain safe code.  The single unsafe wrapper lives in
/// [`Exec::run_disjoint_mut`](super::team::Exec::run_disjoint_mut);
/// this is its scoped-path spelling.
pub fn parallel_for_disjoint_mut<T, F>(data: &mut [T], opts: ParallelOpts, body: F) -> WorkStats
where
    T: Send,
    F: Fn(std::ops::Range<usize>, &mut [T]) + Sync,
{
    super::team::Exec::scoped().run_disjoint_mut(data, opts, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_every_schedule_and_threads() {
        for s in Schedule::ALL {
            for t in [1, 2, 4] {
                let n = 10_001;
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                let opts = ParallelOpts { threads: t, schedule: s, chunk: 64, record: false };
                parallel_for(n, opts, |r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "{s:?} t={t}");
            }
        }
    }

    #[test]
    fn per_thread_context_isolated() {
        // Each worker accumulates into its own Vec; the union must be 0..n.
        let n = 5000;
        let collected = Mutex::new(Vec::<usize>::new());
        let opts = ParallelOpts { threads: 4, schedule: Schedule::Dynamic, chunk: 17, record: false };
        parallel_for_ctx(
            n,
            opts,
            |_tid| Vec::<usize>::new(),
            |ctx, r| ctx.extend(r),
        );
        // Rebuild via contexts drained at the end — do it again collecting.
        parallel_for_ctx(
            n,
            opts,
            |_tid| Vec::<usize>::new(),
            |ctx, r| {
                ctx.extend(r.clone());
                collected.lock().unwrap().extend(r);
            },
        );
        let mut v = collected.into_inner().unwrap();
        v.sort_unstable();
        assert_eq!(v, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn record_collects_chunk_costs() {
        let opts = ParallelOpts { threads: 2, schedule: Schedule::Dynamic, chunk: 100, record: true };
        let stats = parallel_for(1000, opts, |r| {
            std::hint::black_box(r.sum::<usize>());
        });
        let total: usize = stats.chunks.iter().map(|c| c.len).sum();
        assert_eq!(total, 1000);
        assert_eq!(stats.busy_ns.len(), 2);
        assert!(stats.total_ns() > 0);
        assert!(stats.critical_ns() <= stats.total_ns());
    }

    #[test]
    fn zero_length_loop_is_noop() {
        let stats = parallel_for(0, ParallelOpts::default(), |_r| panic!("must not run"));
        assert_eq!(stats.total_ns(), 0);
    }

    #[test]
    fn disjoint_mut_covers_every_slot_exactly_once() {
        for s in Schedule::ALL {
            for t in [1, 2, 4] {
                let n = 10_001;
                let mut data = vec![0u32; n];
                parallel_for_disjoint_mut(
                    &mut data,
                    ParallelOpts { threads: t, schedule: s, chunk: 64, record: false },
                    |r, chunk| {
                        assert_eq!(chunk.len(), r.len());
                        for (k, x) in chunk.iter_mut().enumerate() {
                            *x += (r.start + k) as u32 + 1;
                        }
                    },
                );
                assert!(
                    data.iter().enumerate().all(|(i, &x)| x == i as u32 + 1),
                    "{s:?} t={t}"
                );
            }
        }
    }

    #[test]
    fn disjoint_mut_empty_slice_is_noop() {
        let mut data: Vec<u8> = Vec::new();
        let stats = parallel_for_disjoint_mut(&mut data, ParallelOpts::default(), |_r, _c| {
            panic!("must not run")
        });
        assert_eq!(stats.total_ns(), 0);
    }

    #[test]
    fn disjoint_mut_reads_shared_state() {
        // The pattern gve.rs uses for the membership fold: chunk-local
        // writes driven by a shared read-only lookup table.
        let lut: Vec<u32> = (0..100).map(|i| i * 10).collect();
        let mut data: Vec<u32> = (0..100).collect();
        let lut_ref = &lut;
        parallel_for_disjoint_mut(
            &mut data,
            ParallelOpts { threads: 4, schedule: Schedule::Dynamic, chunk: 7, record: false },
            |_r, chunk| {
                for x in chunk.iter_mut() {
                    *x = lut_ref[*x as usize];
                }
            },
        );
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u32 * 10));
    }

    #[test]
    fn ctx_spec_bucketed_covers_all_positions() {
        for t in [1, 3] {
            let n = 3001;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let opts = ParallelOpts {
                threads: t,
                schedule: Schedule::DegreeBucketed,
                chunk: 64,
                record: false,
            };
            parallel_for_ctx_spec(
                n,
                opts,
                DealSpec::Bucketed { lo_end: 2000, mid_end: 2900 },
                |_tid| (),
                |_, r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "t={t}");
        }
    }

    #[test]
    fn single_thread_fast_path_matches() {
        let sum = AtomicUsize::new(0);
        parallel_for(100, ParallelOpts::with_threads(1), |r| {
            sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
        });
        assert_eq!(sum.into_inner(), (0..100).sum::<usize>());
    }
}
