//! Parallel scatter primitives: key-indexed accumulation into dense
//! arrays.
//!
//! Two call sites motivate these (PR 2, the dynamic-graph subsystem):
//!
//! * **Warm-started Σ' init** — a seeded Louvain pass starts from a
//!   non-identity membership, so the community totals are no longer a
//!   copy of `K'` but a scatter-add of `K'[v]` into `Σ'[C[v]]`
//!   ([`scatter_add_f64`]).
//! * **Batch delta application** — `Csr::apply_batch` needs per-vertex
//!   operation counts before it can prefix-sum the merged offsets
//!   ([`scatter_count`]).
//!
//! Both run on an [`Exec`] (persistent team or scoped reference path)
//! and accumulate through relaxed atomics — the same benign-race
//! contract as the local-moving Σ' updates.  Float accumulation order
//! is nondeterministic above one thread; integral values stay exact
//! regardless (f64 addition of integers is associative in range).

use super::atomics::as_atomic_f64;
use super::pool::{ParallelOpts, WorkStats};
use super::team::Exec;
use std::sync::atomic::{AtomicUsize, Ordering};

/// `out[keys[i]] += vals[i]` for every `i`, in parallel chunks.
///
/// `keys` and `vals` must have equal length and every key must index
/// into `out` (checked in debug builds; out-of-range keys panic via the
/// slice index in release too).
pub fn scatter_add_f64(
    keys: &[u32],
    vals: &[f64],
    out: &mut [f64],
    opts: ParallelOpts,
    exec: Exec,
) -> WorkStats {
    assert_eq!(keys.len(), vals.len(), "scatter keys/vals length mismatch");
    debug_assert!(keys.iter().all(|&k| (k as usize) < out.len()));
    let cells = as_atomic_f64(out);
    exec.run(keys.len(), opts, |r| {
        for i in r {
            cells[keys[i] as usize].fetch_add(vals[i]);
        }
    })
}

/// `out[keys[i]] += 1` for every `i`, in parallel chunks (histogram).
pub fn scatter_count(
    keys: &[u32],
    out: &mut [usize],
    opts: ParallelOpts,
    exec: Exec,
) -> WorkStats {
    debug_assert!(keys.iter().all(|&k| (k as usize) < out.len()));
    // Same cast idiom as the aggregation count arrays: usize and
    // AtomicUsize share layout, and the &mut borrow guarantees
    // exclusivity for the scope that splits it across workers.
    let cells: &[AtomicUsize] =
        unsafe { &*(out as *mut [usize] as *const [AtomicUsize]) };
    exec.run(keys.len(), opts, |r| {
        for i in r {
            cells[keys[i] as usize].fetch_add(1, Ordering::Relaxed);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::team::Team;

    #[test]
    fn scatter_add_matches_serial() {
        let keys: Vec<u32> = (0..10_000).map(|i| (i * 7 % 97) as u32).collect();
        let vals: Vec<f64> = (0..10_000).map(|i| (i % 5) as f64).collect();
        let mut want = vec![0.0f64; 97];
        for (k, v) in keys.iter().zip(&vals) {
            want[*k as usize] += v;
        }
        let team = Team::new(4);
        for exec in [Exec::scoped(), Exec::team(&team)] {
            let mut out = vec![0.0f64; 97];
            scatter_add_f64(
                &keys,
                &vals,
                &mut out,
                ParallelOpts { threads: 4, chunk: 64, ..Default::default() },
                exec,
            );
            // Integral values: exact under any interleaving.
            assert_eq!(out, want);
        }
    }

    #[test]
    fn scatter_count_builds_histogram() {
        let keys: Vec<u32> = (0..5000).map(|i| (i % 13) as u32).collect();
        let mut out = vec![0usize; 13];
        scatter_count(
            &keys,
            &mut out,
            ParallelOpts { threads: 4, chunk: 32, ..Default::default() },
            Exec::scoped(),
        );
        let want: usize = out.iter().sum();
        assert_eq!(want, 5000);
        for (k, &c) in out.iter().enumerate() {
            let expect = (0..5000).filter(|i| i % 13 == k).count();
            assert_eq!(c, expect, "bin {k}");
        }
    }

    #[test]
    fn empty_scatter_is_noop() {
        let mut out = vec![1.0f64; 3];
        scatter_add_f64(&[], &[], &mut out, ParallelOpts::default(), Exec::scoped());
        assert_eq!(out, vec![1.0, 1.0, 1.0]);
    }
}
